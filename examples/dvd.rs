//! DvD case study (paper §5.3, Fig 6): population TD3 with a shared
//! critic and an explicit diversity bonus — the log-determinant of the
//! RBF kernel over the policies' actions on probe states. The diversity
//! weight follows a schedule (paper Appendix B.2 replaces DvD's bandit
//! with a schedule).
//!
//!     cargo run --release --example dvd -- [env] [updates]
//!
//! The paper trains pop 5 on Humanoid-v2 with one T4; we default to the
//! halfcheetah-dimension task for the single-core budget (pass `humanoid`
//! after regenerating an artifact for it — see DESIGN.md).

use fastpbrl::coordinator::dvd::DvdLambdaSchedule;
use fastpbrl::coordinator::trainer::{run_training, TrainerConfig};
use fastpbrl::manifest::Manifest;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let env = args.first().cloned().unwrap_or_else(|| "halfcheetah".into());
    let updates: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(10_000);

    let manifest = Manifest::load("artifacts")?;
    let cfg = TrainerConfig::new("dvd", &env)
        .with_pop(5) // same population size as the original study
        .with_updates(updates)
        .with_sync_every(50)
        .with_warmup(1000)
        .with_shared_replay(true) // DvD mixes all agents' data in one buffer
        .with_seed(11)
        .with_csv(format!("results/dvd_{env}.csv"))
        .with_max_seconds(1500.0);
    let mut controller = DvdLambdaSchedule::default_for(updates);
    println!("DvD pop=5 on {env}: {updates} updates, lambda {:.2} -> {:.2}",
             controller.value_at(0), controller.value_at(updates));
    let summary = run_training(&manifest, cfg, &mut controller)?;
    println!(
        "wall {:.1}s | updates {} | env steps {} | best return {:.1} | mean {:.1}",
        summary.wall_seconds, summary.updates, summary.env_steps,
        summary.best_return, summary.mean_return
    );
    println!("curve -> results/dvd_{env}.csv");
    Ok(())
}
