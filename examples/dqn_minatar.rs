//! DQN on the MinAtar-style pixel games — the pixel/discrete pipeline of
//! the paper's Fig 2 DQN rows, run end to end on the population-batched
//! actor path: epsilon-greedy actors on `PopConvNet` block q-values
//! (`PixelActorPool` threads stepping a `PixelVecEnv`), u8-frame block
//! transport into per-agent `PixelReplayBuffer`s (one `push_batch` per
//! run — no per-transition pushes), vectorized device update steps, and
//! periodic parameter publishes back to the actors through the shared
//! `ParamView`. Per-agent exploration epsilons live in the state field
//! `eps_greedy` (the `HyperSpec::dqn` search space).
//!
//!     cargo run --release --example dqn_minatar -- [updates] [pop] [config]
//!
//! Config keys (`[dqn]` section, all optional — the former hardcoded
//! exploration schedule): warmup_steps (500), eps_greedy (0.1 — written
//! into every agent's eps_greedy state field when sample_hypers is
//! false), sync_every (25), ratio (0.25 per-agent updates:env-steps,
//! enforced two-sided — actor throttle + learner gate — with 0 =
//! unthrottled), replay_capacity (20000), actor_threads (1),
//! drain_bound (16384),
//! sample_hypers (true = sample per-agent lr/gamma/eps_greedy from the
//! HyperSpec::dqn priors instead).

use fastpbrl::coordinator::hyperparams::HyperSpec;
use fastpbrl::coordinator::population::Population;
use fastpbrl::data::pipeline::{PixelActorConfig, PixelActorPool, PixelTransitionBlock, Throttle};
use fastpbrl::manifest::{Dtype, Manifest};
use fastpbrl::replay::{PixelReplayBuffer, RatioGate};
use fastpbrl::runtime::Runtime;
use fastpbrl::util::config::Config;
use fastpbrl::util::log::CsvLogger;
use fastpbrl::util::rng::Rng;

/// Insert one drained block into per-agent replay: rows are grouped into
/// runs that target the same buffer and each run lands as one contiguous
/// `push_batch` (frames are already in the buffers' u8 storage format).
/// With today's one-env-per-agent block layout every run has length 1;
/// the grouping mirrors `Trainer::push_block` and starts paying off as
/// soon as a block carries multiple rows per agent (multi-env actors) or
/// replay is shared.
fn push_block(replays: &mut [PixelReplayBuffer], block: &PixelTransitionBlock) {
    let fl = block.frame_len;
    let mut start = 0;
    while start < block.n {
        let a = block.agents[start];
        let mut end = start + 1;
        while end < block.n && block.agents[end] == a {
            end += 1;
        }
        replays[a].push_batch(
            end - start,
            &block.obs[start * fl..end * fl],
            &block.act[start..end],
            &block.rew[start..end],
            &block.next_obs[start * fl..end * fl],
            &block.done[start..end],
        );
        start = end;
    }
}

/// Absorb one drained block (replay insert + episode bookkeeping);
/// returns the number of transitions it carried.
fn absorb_block(
    block: &PixelTransitionBlock,
    replays: &mut [PixelReplayBuffer],
    population: &mut Population,
    best_return: &mut [f64],
) -> u64 {
    push_block(replays, block);
    for ep in &block.episodes {
        best_return[ep.agent] = best_return[ep.agent].max(ep.ret);
        population.returns[ep.agent].push(ep.ret);
    }
    block.n as u64
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let updates: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(2_000);
    let pop: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);
    let cfg = match args.get(2) {
        Some(path) => Config::load(path)?,
        None => Config::new(),
    };
    let warmup_steps = cfg.get_usize("dqn.warmup_steps", 500)?;
    let eps_fallback = cfg.get_f64("dqn.eps_greedy", 0.1)? as f32;
    let sync_every = cfg.get_usize("dqn.sync_every", 25)? as u64;
    let ratio = cfg.get_f64("dqn.ratio", 0.25)?;
    let replay_capacity = cfg.get_usize("dqn.replay_capacity", 20_000)?;
    let n_actor_threads = cfg.get_usize("dqn.actor_threads", 1)?;
    let drain_bound = cfg.get_usize("dqn.drain_bound", 16 * 1024)? as u64;
    let sample_hypers = cfg.get_bool("dqn.sample_hypers", true)?;

    let manifest = Manifest::load("artifacts")?;
    let art = manifest.find("dqn", "minatar", pop, Some(1))?.clone();
    let (h, w, c) = art.env_desc.frame.expect("pixel artifact");
    let frame_len = h * w * c;
    let batch = art.batch;

    let rt = Runtime::cpu()?;
    let exe = rt.load(&art)?;
    let mut rng = Rng::new(5);
    let hyper_spec = if sample_hypers { Some(HyperSpec::dqn()) } else { None };
    let mut population = Population::init(&rt, &art, &mut rng, 13, hyper_spec, 10)?;
    if !sample_hypers {
        // The actor reads the per-agent eps_greedy state field, which the
        // artifact bakes to a constant — make the configured epsilon
        // authoritative when the priors are not sampled.
        let mut host = population.view.with(|h| h.to_vec());
        if let Ok(eps) = art.read_mut(&mut host, "eps_greedy") {
            eps.fill(eps_fallback);
        }
        population.load_host(&rt, host)?;
    }

    let mut replays: Vec<PixelReplayBuffer> =
        (0..pop).map(|_| PixelReplayBuffer::new(replay_capacity, frame_len)).collect();

    // staging for [P, B, ...] batches
    let mut st_obs = vec![0.0f32; pop * batch * frame_len];
    let mut st_act = vec![0i32; pop * batch];
    let mut st_rew = vec![0.0f32; pop * batch];
    let mut st_next = vec![0.0f32; pop * batch * frame_len];
    let mut st_done = vec![0.0f32; pop * batch];
    let mut best_return = vec![f64::NEG_INFINITY; pop];
    let mut csv = CsvLogger::create("results/dqn_minatar.csv",
                                    &["updates", "env_steps", "best_return"])?;

    // Actors: PopConvNet block inference + PixelVecEnv stepping in
    // threads, throttled to the configured per-agent update:env ratio
    // (Throttle counts global env steps, hence the /pop).
    let throttle = Throttle::new();
    let pool = PixelActorPool::spawn(
        &art,
        population.view.clone(),
        PixelActorConfig {
            env: art.env.clone(),
            warmup_steps,
            eps_greedy: eps_fallback,
            seed: 5 ^ 0xAC70,
            ratio: ratio / pop.max(1) as f64,
            lead_steps: 4 * batch as u64 * pop as u64,
            ..Default::default()
        },
        n_actor_threads,
        throttle.clone(),
    )?;

    // Learner-side half of the ratio contract: the Throttle above stops
    // actors from running ahead, this gate stops the learner from
    // re-fitting a nearly static replay when actors are the bottleneck
    // (the two-sided pairing Trainer uses). ratio = 0 disables both
    // sides (unthrottled).
    let mut gate = if ratio > 0.0 {
        Some(RatioGate::new(ratio / pop.max(1) as f64, 64.0, (warmup_steps * pop) as u64))
    } else {
        None
    };
    let mut env_steps: u64 = 0;
    let mut done_updates: u64 = 0;
    let mut since_sync: u64 = 0;
    let start = std::time::Instant::now();

    while done_updates < updates {
        // ---- drain actor blocks into per-agent replay ----------------
        let mut drained = 0u64;
        while let Ok(block) = pool.rx.try_recv() {
            let n = absorb_block(&block, &mut replays, &mut population, &mut best_return);
            env_steps += n;
            drained += n;
            if let Some(g) = gate.as_mut() {
                g.on_env_steps(n);
            }
            pool.recycle(block);
            if drained >= drain_bound {
                break; // bounded drain per iteration
            }
        }
        let may_update = match gate.as_ref() {
            Some(g) => g.may_update(1),
            None => true,
        };
        if replays.iter().any(|r| r.len() < batch) || !may_update {
            // replay warmup / ratio wait: park on the channel instead of
            // busy-spinning a core against the actor threads
            if let Ok(block) = pool.rx.recv_timeout(std::time::Duration::from_millis(5)) {
                let n = absorb_block(&block, &mut replays, &mut population, &mut best_return);
                env_steps += n;
                if let Some(g) = gate.as_mut() {
                    g.on_env_steps(n);
                }
                pool.recycle(block);
            }
            continue;
        }

        // ---- one vectorized DQN update -------------------------------
        for (a, buf) in replays.iter().enumerate() {
            buf.sample_into(
                &mut rng,
                batch,
                &mut st_obs[a * batch * frame_len..(a + 1) * batch * frame_len],
                &mut st_act[a * batch..(a + 1) * batch],
                &mut st_rew[a * batch..(a + 1) * batch],
                &mut st_next[a * batch * frame_len..(a + 1) * batch * frame_len],
                &mut st_done[a * batch..(a + 1) * batch],
            );
        }
        let mut bufs = Vec::new();
        for inp in &art.inputs[1..] {
            let b = match (inp.name.as_str(), inp.dtype.clone()) {
                ("obs", _) => rt.upload_f32(&st_obs, &inp.shape)?,
                ("act", Dtype::I32) => rt.upload_i32(&st_act, &inp.shape)?,
                ("rew", _) => rt.upload_f32(&st_rew, &inp.shape)?,
                ("next_obs", _) => rt.upload_f32(&st_next, &inp.shape)?,
                ("done", _) => rt.upload_f32(&st_done, &inp.shape)?,
                other => anyhow::bail!("unexpected input {other:?}"),
            };
            bufs.push(b);
        }
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        population.train_state.step(&exe, &refs)?;
        throttle.updates.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if let Some(g) = gate.as_mut() {
            g.on_update_steps(1);
        }
        done_updates += 1;
        since_sync += 1;

        // ---- publish parameters to the actor pool --------------------
        if since_sync >= sync_every.max(1) || done_updates >= updates {
            since_sync = 0;
            // one contiguous device download, published to the ParamView;
            // actors refresh their PopConvNet with one memcpy per field
            population.sync_to_host()?;
            let best = best_return.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            csv.row(&[done_updates as f64, env_steps as f64,
                      if best.is_finite() { best } else { -1.0 }])?;
        }
    }
    pool.stop();
    csv.flush()?;
    let host = population.train_state.to_host()?;
    let loss = art.read(&host, "loss")?;
    let best = best_return.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    println!(
        "dqn_minatar: {done_updates} updates, {env_steps} env steps in {:.1}s; \
         best episode return {best:.1}; final loss {:?}",
        start.elapsed().as_secs_f64(),
        &loss[..loss.len().min(4)]
    );
    println!("curve -> results/dqn_minatar.csv");
    Ok(())
}
