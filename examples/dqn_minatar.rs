//! DQN on the MinAtar-style Breakout — the pixel/discrete pipeline of the
//! paper's Fig 2 DQN rows, run end to end: conv-net q-network (population-
//! vectorized with the grouped-conv trick), epsilon-greedy actors on the
//! native conv forward pass, per-agent pixel replay, periodic hard target
//! copies inside the vectorized artifact.
//!
//!     cargo run --release --example dqn_minatar -- [updates] [pop]

use fastpbrl::envs::minatar::Breakout;
use fastpbrl::envs::PixelEnv;
use fastpbrl::manifest::{Dtype, Manifest};
use fastpbrl::nn::from_state::convnet_from_state;
use fastpbrl::replay::PixelReplayBuffer;
use fastpbrl::runtime::{Runtime, TrainState};
use fastpbrl::util::log::CsvLogger;
use fastpbrl::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let updates: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2_000);
    let pop: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);

    let manifest = Manifest::load("artifacts")?;
    let art = manifest.find("dqn", "minatar", pop, Some(1))?.clone();
    let (h, w, c) = art.env_desc.frame.expect("pixel artifact");
    let n_actions = art.env_desc.n_actions;
    let frame_len = h * w * c;
    let batch = art.batch;

    let rt = Runtime::cpu()?;
    let exe = rt.load(&art)?;
    let mut rng = Rng::new(5);
    let mut ts = TrainState::init(&rt, &art, &mut rng, 13)?;

    let mut envs: Vec<Breakout> = (0..pop).map(|_| Breakout::new()).collect();
    let mut replays: Vec<PixelReplayBuffer> =
        (0..pop).map(|_| PixelReplayBuffer::new(20_000, frame_len)).collect();
    let mut obs: Vec<Vec<f32>> = (0..pop).map(|_| vec![0.0; frame_len]).collect();
    let mut next_obs = vec![0.0f32; frame_len];
    for (i, env) in envs.iter_mut().enumerate() {
        env.reset(&mut rng, &mut obs[i]);
    }
    let host0 = ts.to_host()?;
    let mut nets: Vec<_> = (0..pop)
        .map(|a| convnet_from_state(&art, &host0, "q", a, (h, w, c)).unwrap())
        .collect();

    // staging for [P, B, ...] batches
    let mut st_obs = vec![0.0f32; pop * batch * frame_len];
    let mut st_act = vec![0i32; pop * batch];
    let mut st_rew = vec![0.0f32; pop * batch];
    let mut st_next = vec![0.0f32; pop * batch * frame_len];
    let mut st_done = vec![0.0f32; pop * batch];
    let mut q = vec![0.0f32; n_actions];
    let mut returns = vec![0.0f64; pop];
    let mut best_return = vec![f64::NEG_INFINITY; pop];
    let mut ep_steps = vec![0usize; pop];
    let mut csv = CsvLogger::create("results/dqn_minatar.csv",
                                    &["updates", "env_steps", "best_return"])?;

    let warmup = 500usize;
    let sync_every = 25usize;
    let mut env_steps = 0usize;
    let start = std::time::Instant::now();

    for u in 0..updates {
        // ---- act: 4 env steps per agent per update (ratio 0.25) ---------
        for _ in 0..4 {
            for a in 0..pop {
                let eps = if env_steps < warmup { 1.0 } else { 0.1 };
                let action = if rng.uniform() < eps {
                    rng.below(n_actions)
                } else {
                    nets[a].forward(&obs[a], &mut q);
                    (0..n_actions).max_by(|&i, &j| q[i].partial_cmp(&q[j]).unwrap()).unwrap()
                };
                let (r, done) = envs[a].step(action, &mut rng, &mut next_obs);
                replays[a].push(&obs[a], action, r, &next_obs, done);
                obs[a].copy_from_slice(&next_obs);
                returns[a] += r as f64;
                ep_steps[a] += 1;
                env_steps += 1;
                if done || ep_steps[a] >= envs[a].horizon() {
                    best_return[a] = best_return[a].max(returns[a]);
                    returns[a] = 0.0;
                    ep_steps[a] = 0;
                    envs[a].reset(&mut rng, &mut obs[a]);
                }
            }
        }
        if replays.iter().any(|r| r.len() < batch) {
            continue;
        }
        // ---- one vectorized DQN update -----------------------------------
        for a in 0..pop {
            replays[a].sample_into(
                &mut rng,
                batch,
                &mut st_obs[a * batch * frame_len..(a + 1) * batch * frame_len],
                &mut st_act[a * batch..(a + 1) * batch],
                &mut st_rew[a * batch..(a + 1) * batch],
                &mut st_next[a * batch * frame_len..(a + 1) * batch * frame_len],
                &mut st_done[a * batch..(a + 1) * batch],
            );
        }
        let mut bufs = Vec::new();
        for inp in &art.inputs[1..] {
            let b = match (inp.name.as_str(), inp.dtype.clone()) {
                ("obs", _) => rt.upload_f32(&st_obs, &inp.shape)?,
                ("act", Dtype::I32) => rt.upload_i32(&st_act, &inp.shape)?,
                ("rew", _) => rt.upload_f32(&st_rew, &inp.shape)?,
                ("next_obs", _) => rt.upload_f32(&st_next, &inp.shape)?,
                ("done", _) => rt.upload_f32(&st_done, &inp.shape)?,
                other => anyhow::bail!("unexpected input {other:?}"),
            };
            bufs.push(b);
        }
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        ts.step(&exe, &refs)?;

        // ---- parameter sync to the native actor nets ---------------------
        if (u + 1) % sync_every == 0 {
            let host = ts.to_host()?;
            for (a, net) in nets.iter_mut().enumerate() {
                *net = convnet_from_state(&art, &host, "q", a, (h, w, c))?;
            }
            let best = best_return.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            csv.row(&[(u + 1) as f64, env_steps as f64,
                      if best.is_finite() { best } else { -1.0 }])?;
        }
    }
    csv.flush()?;
    let host = ts.to_host()?;
    let loss = art.read(&host, "loss")?;
    let best = best_return.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    println!(
        "dqn_minatar: {updates} updates, {env_steps} env steps in {:.1}s; \
         best episode return {best:.1}; final loss {:?}",
        start.elapsed().as_secs_f64(),
        &loss[..loss.len().min(4)]
    );
    println!("curve -> results/dqn_minatar.csv");
    Ok(())
}
