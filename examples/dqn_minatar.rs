//! DQN on the MinAtar-style pixel games — the paper's Fig 2 DQN rows —
//! through the SAME generic `Trainer` loop as the continuous tasks: the
//! pixel path is a `Domain` implementation (`Trainer::<Pixel>`), not a
//! bespoke learner. Epsilon-greedy actors on `PopConvNet` block q-values
//! feed u8-frame blocks into per-agent `PixelReplayBuffer`s, the shared
//! loop drives vectorized device update steps, enforces the two-sided
//! update:env ratio, publishes parameters every `sync_every` executions,
//! and logs the learning curve.
//!
//!     cargo run --release --example dqn_minatar -- [updates] [pop] [config]
//!
//! Config keys (`[dqn]` section, all optional): warmup_steps (500),
//! eps_greedy (0.1 — baked into every agent's eps_greedy state field
//! when sample_hypers is false), sync_every (25), ratio (0.25 per-agent
//! updates:env-steps, two-sided, 0 = unthrottled), replay_capacity
//! (20000), actor_threads (1), drain_bound (16384), sample_hypers (true
//! = per-agent lr/gamma/eps_greedy sampled from the HyperSpec::dqn
//! priors).

use fastpbrl::coordinator::hyperparams::HyperSpec;
use fastpbrl::coordinator::trainer::{NoController, Pixel, Trainer, TrainerConfig};
use fastpbrl::manifest::Manifest;
use fastpbrl::util::config::Config;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let updates: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(2_000);
    let pop: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);
    let file = match args.get(2) {
        Some(path) => Config::load(path)?,
        None => Config::new(),
    };
    let mut cfg = TrainerConfig::new("dqn", "minatar")
        .with_pop(pop)
        .with_updates(updates)
        .with_seed(5)
        .with_csv("results/dqn_minatar.csv");
    cfg.num_steps = Some(1);
    cfg.warmup_steps = file.get_usize("dqn.warmup_steps", 500)?;
    cfg.eps_greedy = file.get_f64("dqn.eps_greedy", 0.1)? as f32;
    cfg.sync_every = file.get_usize("dqn.sync_every", 25)? as u64;
    cfg.ratio = file.get_f64("dqn.ratio", 0.25)?;
    cfg.replay_capacity = file.get_usize("dqn.replay_capacity", 20_000)?;
    cfg.n_actor_threads = file.get_usize("dqn.actor_threads", 1)?;
    cfg.drain_bound = file.get_usize("dqn.drain_bound", 16 * 1024)? as u64;
    if file.get_bool("dqn.sample_hypers", true)? {
        cfg.hyper_spec = Some(HyperSpec::dqn());
    }

    let manifest = Manifest::load("artifacts")?;
    let mut trainer = Trainer::<Pixel>::new(&manifest, cfg)?;
    let summary = trainer.run(&mut NoController)?;
    // best_return is the best per-agent windowed MEAN return (the PBT
    // fitness), not the single best episode the pre-unification example
    // tracked — label it accordingly.
    println!(
        "dqn_minatar: {} updates, {} env steps in {:.1}s; best windowed mean return {:.1}",
        summary.updates, summary.env_steps, summary.wall_seconds, summary.best_return
    );
    println!("curve -> results/dqn_minatar.csv");
    Ok(())
}
