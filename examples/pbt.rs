//! PBT case study (paper §5.1, Figs 5 & 7): tune TD3/SAC hyperparameters
//! on a locomotion task by evolving a population — best-agent return is
//! logged against both wall time (Fig 5) and env timesteps (Fig 7).
//!
//!     cargo run --release --example pbt -- [env] [algo] [pop] [updates]
//!
//! Defaults are scaled to this machine's single CPU core (the paper uses
//! pop 80 on 4 T4s; comparisons within the run are preserved — see
//! DESIGN.md "Scale note"). The CSV has wall_s AND env_steps columns, so
//! one run regenerates both figures' series.

use fastpbrl::coordinator::hyperparams::HyperSpec;
use fastpbrl::coordinator::pbt::{Explore, PbtController};
use fastpbrl::coordinator::trainer::{run_training, TrainerConfig};
use fastpbrl::manifest::Manifest;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let env = args.first().cloned().unwrap_or_else(|| "halfcheetah".into());
    let algo = args.get(1).cloned().unwrap_or_else(|| "td3".into());
    let pop: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);
    let updates: u64 = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(20_000);

    let manifest = Manifest::load("artifacts")?;
    let spec = HyperSpec::for_algo(&algo)?;
    // Evolution cadence scaled with total budget (paper: every 100k of
    // multi-million-step runs; here: 8 generations).
    let interval = (updates / 8).max(1);
    let mut controller = PbtController::new(spec.clone(), interval, 0.3, Explore::Resample);

    let cfg = TrainerConfig::new(&algo, &env)
        .with_pop(pop)
        .with_updates(updates)
        .with_sync_every(50)
        .with_warmup(1000)
        .with_seed(7)
        .with_csv(format!("results/pbt_{algo}_{env}.csv"))
        .with_max_seconds(1800.0)
        .with_hypers(spec);
    println!("PBT {algo} pop={pop} on {env}: {updates} updates, evolve every {interval}");
    let summary = run_training(&manifest, cfg, &mut controller)?;
    println!(
        "wall {:.1}s | updates {} | env steps {} | best return {:.1} | mean {:.1}",
        summary.wall_seconds, summary.updates, summary.env_steps,
        summary.best_return, summary.mean_return
    );
    println!("evolution events: {}", controller.history.len());
    for (gen, loser, parent) in controller.history.iter().take(10) {
        println!("  at {gen} updates: agent {loser} <- clone of {parent}");
    }
    println!("curves (wall_s + env_steps axes) -> results/pbt_{algo}_{env}.csv");
    Ok(())
}
