//! CEM-RL case study (paper §5.2, Figs 6 & 8): population of 10 TD3
//! agents sharing critic parameters, policies evolved by the
//! Cross-Entropy Method. `--ordering seq` runs the original CEM-RL
//! update interleaving; `vec` (default) runs the paper's §4.2
//! vectorizable modification — Fig 8 compares the two orderings'
//! sample-efficiency, Fig 4 their speed.
//!
//!     cargo run --release --example cemrl -- [env] [iters] [vec|seq]

use fastpbrl::coordinator::cem::{run_cemrl, CemRlConfig};
use fastpbrl::manifest::Manifest;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let env = args.first().cloned().unwrap_or_else(|| "halfcheetah".into());
    let iters: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let ordering = args.get(2).cloned().unwrap_or_else(|| "vec".into());

    let manifest = Manifest::load("artifacts")?;
    let cfg = CemRlConfig {
        env: env.clone(),
        pop: 10, // same population size as the original study
        iters,
        rounds_per_iter: 20,
        steps_per_iter: 2000,
        warmup_steps: 1000,
        eval_episodes: 1,
        seed: 3,
        csv_path: format!("results/cemrl_{ordering}_{env}.csv"),
        max_seconds: 1500.0,
        ordering: ordering.clone(),
        ..CemRlConfig::default()
    };
    println!("CEM-RL ({ordering}) pop=10 on {env}: {iters} iterations");
    let summary = run_cemrl(&manifest, &cfg)?;
    println!(
        "wall {:.1}s | updates {} | env steps {} | best {:.1} | mean {:.1} | mu {:.1}",
        summary.wall_seconds, summary.updates, summary.env_steps,
        summary.best_return, summary.mean_return, summary.mu_return
    );
    println!("{}", summary.timers.report());
    println!("curve -> results/cemrl_{ordering}_{env}.csv");
    Ok(())
}
