//! End-to-end quickstart: train a population of 4 TD3 agents on the
//! pendulum swing-up through the whole three-layer stack (Pallas kernel →
//! jax update artifact → rust coordinator) and log the learning curve.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the repo's end-to-end validation driver: it proves all layers
//! compose — actors collect data with the native policy, batches stream to
//! the PJRT-compiled vectorized update, the critic loss falls, and episode
//! returns improve over the random baseline. Results land in
//! `results/quickstart.csv` and are summarized in EXPERIMENTS.md.

use fastpbrl::coordinator::trainer::{run_training, NoController, TrainerConfig};
use fastpbrl::manifest::Manifest;
use fastpbrl::telemetry::TelemetryConfig;

fn main() -> anyhow::Result<()> {
    let updates: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20_000);
    let manifest = Manifest::load("artifacts")?;
    let cfg = TrainerConfig::new("td3", "pendulum")
        .with_pop(4)
        .with_updates(updates)
        .with_sync_every(50)
        .with_warmup(500)
        .with_seed(1)
        .with_csv("results/quickstart.csv")
        // live snapshots: watch with `fastpbrl top results` while running
        .with_telemetry(TelemetryConfig::jsonl("results/telemetry.jsonl"))
        .with_max_seconds(900.0);
    println!(
        "quickstart: TD3 population of {} on pendulum, {} update steps",
        cfg.pop, updates
    );
    let summary = run_training(&manifest, cfg, &mut NoController)?;
    println!(
        "wall {:.1}s | updates {} | env steps {} | best return {:.1} | mean {:.1}",
        summary.wall_seconds, summary.updates, summary.env_steps,
        summary.best_return, summary.mean_return
    );
    println!("{}", summary.timers.report());
    println!("learning curve -> results/quickstart.csv");
    println!("telemetry stream -> results/telemetry.jsonl (fastpbrl top results)");
    // Random pendulum policies score ~ -1200..-1600; a learning population
    // should clear -900 within the default budget.
    if summary.best_return > -900.0 {
        println!("OK: population learned (best > -900)");
    } else {
        println!("WARNING: best return {:.1} still at random level — run longer",
                 summary.best_return);
    }
    Ok(())
}
