"""L1 correctness: Pallas population-batched linear vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; gradients of the custom VJP are checked
against jax.grad of the reference. This is the CORE correctness signal of
the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pop_linear as pk
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

ACTS = ["none", "relu", "tanh"]


def rand(rng, shape, dtype=np.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=shape).astype(dtype) * scale)


@st.composite
def pbio(draw):
    p = draw(st.integers(1, 5))
    b = draw(st.integers(1, 9))
    i = draw(st.integers(1, 17))
    o = draw(st.integers(1, 13))
    return p, b, i, o


@settings(max_examples=25, deadline=None)
@given(dims=pbio(), act=st.sampled_from(ACTS), seed=st.integers(0, 2**31 - 1))
def test_forward_matches_reference(dims, act, seed):
    p, b, i, o = dims
    rng = np.random.default_rng(seed)
    x, w, bias = rand(rng, (p, b, i)), rand(rng, (p, i, o)), rand(rng, (p, o))
    y = pk.pop_linear(x, w, bias, act)
    yr = ref.pop_linear_ref(x, w, bias, act)
    assert y.shape == (p, b, o)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(dims=pbio(), act=st.sampled_from(ACTS), seed=st.integers(0, 2**31 - 1))
def test_custom_vjp_matches_reference_grads(dims, act, seed):
    p, b, i, o = dims
    rng = np.random.default_rng(seed)
    x, w, bias = rand(rng, (p, b, i)), rand(rng, (p, i, o)), rand(rng, (p, o))

    def f(x, w, bias):
        return jnp.sum(jnp.cos(pk.pop_linear(x, w, bias, act)))

    def fr(x, w, bias):
        return jnp.sum(jnp.cos(ref.pop_linear_ref(x, w, bias, act)))

    g = jax.grad(f, argnums=(0, 1, 2))(x, w, bias)
    gr = jax.grad(fr, argnums=(0, 1, 2))(x, w, bias)
    for a, r in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=1e-4,
                                   atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(
    dims=pbio(),
    block_b=st.integers(1, 8),
    block_o=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_tiled_forward_matches(dims, block_b, block_o, seed):
    """The VMEM tiling knobs must never change the numerics."""
    p, b, i, o = dims
    rng = np.random.default_rng(seed)
    x, w, bias = rand(rng, (p, b, i)), rand(rng, (p, i, o)), rand(rng, (p, o))
    y = pk.pop_linear(x, w, bias, "relu", block_b, block_o)
    yr = ref.pop_linear_ref(x, w, bias, "relu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(dims=pbio(), pop_block=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
def test_pop_block_grid_matches(dims, pop_block, seed):
    """The population-tiling knob (TPU: 1 member/program; CPU: all) must
    never change numerics, forward or backward."""
    p, b, i, o = dims
    rng = np.random.default_rng(seed)
    x, w, bias = rand(rng, (p, b, i)), rand(rng, (p, i, o)), rand(rng, (p, o))

    def f(x, w, bias):
        return jnp.sum(jnp.sin(pk.pop_linear(x, w, bias, "tanh", None, None,
                                             pop_block)))

    def fr(x, w, bias):
        return jnp.sum(jnp.sin(ref.pop_linear_ref(x, w, bias, "tanh")))

    np.testing.assert_allclose(np.asarray(f(x, w, bias)),
                               np.asarray(fr(x, w, bias)), rtol=1e-5, atol=1e-5)
    g = jax.grad(f, argnums=(0, 1, 2))(x, w, bias)
    gr = jax.grad(fr, argnums=(0, 1, 2))(x, w, bias)
    for a, r in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=1e-4,
                                   atol=1e-5)


def test_bf16_forward_close_to_f32():
    rng = np.random.default_rng(0)
    p, b, i, o = 2, 4, 8, 8
    x = rand(rng, (p, b, i))
    w = rand(rng, (p, i, o))
    bias = rand(rng, (p, o))
    y32 = pk.pop_linear(x, w, bias, "tanh")
    y16 = pk.pop_linear(
        x.astype(jnp.bfloat16), w.astype(jnp.bfloat16), bias.astype(jnp.bfloat16),
        "tanh")
    assert y16.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(y16, np.float32), np.asarray(y32), rtol=0.1, atol=0.1)


def test_unknown_activation_rejected():
    x = jnp.zeros((1, 1, 1))
    w = jnp.zeros((1, 1, 1))
    b = jnp.zeros((1, 1))
    with pytest.raises(ValueError):
        pk.pop_linear(x, w, b, "gelu")


def test_use_pallas_switch_routes_to_ref():
    rng = np.random.default_rng(3)
    x, w, b = rand(rng, (2, 3, 4)), rand(rng, (2, 4, 5)), rand(rng, (2, 5))
    try:
        pk.set_use_pallas(False)
        y_ref_path = pk.pop_linear(x, w, b, "relu")
    finally:
        pk.set_use_pallas(True)
    y_pallas = pk.pop_linear(x, w, b, "relu")
    np.testing.assert_allclose(np.asarray(y_ref_path), np.asarray(y_pallas),
                               rtol=1e-5, atol=1e-6)


def test_members_are_independent():
    """Member p's output must depend only on member p's inputs."""
    rng = np.random.default_rng(4)
    x, w, b = rand(rng, (3, 4, 5)), rand(rng, (3, 5, 2)), rand(rng, (3, 2))
    y = np.asarray(pk.pop_linear(x, w, b, "none"))
    # perturb member 1's weights only
    w2 = w.at[1].add(1.0)
    y2 = np.asarray(pk.pop_linear(x, w2, b, "none"))
    np.testing.assert_array_equal(y[0], y2[0])
    np.testing.assert_array_equal(y[2], y2[2])
    assert not np.allclose(y[1], y2[1])
