"""L2 update-step semantics for all algorithms (small nets, fast).

The decisive checks:
  * pallas-vs-reference A/B: the whole TD3 update must produce identical
    states whether pop_linear routes through Pallas or the jnp oracle;
  * repeated same-batch updates reduce the critic loss (learning signal);
  * per-agent isolation: one agent's batch never touches another's params;
  * delayed policy updates, target syncs, masked Adam;
  * shared-critic seq/vec variants both train; DvD's diversity term
    pushes policies apart.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import pop_linear as pk
from compile.layout import Layout
from compile.updates import common, dqn, sac, shared_critic as sc, td3

jax.config.update("jax_platform_name", "cpu")


def np_batches(bargs, seed=0, num_steps=1):
    rng = np.random.default_rng(seed)
    out = []
    for a in bargs:
        shape = a.shape if num_steps == 1 else (num_steps,) + a.shape
        if a.dtype == "i32":
            out.append(jnp.asarray(rng.integers(0, 3, shape), jnp.int32))
        elif a.name == "done":
            out.append(jnp.asarray((rng.random(shape) < 0.1), jnp.float32))
        else:
            out.append(jnp.asarray(rng.normal(size=shape), jnp.float32))
    return out


def metric(layout: Layout, state, name):
    o = layout.offsets[name]
    f = layout.field(name)
    return np.asarray(state)[o:o + f.size].reshape(f.shape)


# ---------------------------------------------------------------------------
# TD3
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def td3_setup():
    layout, update, bargs = td3.make_update(3, 5, 2, 8, hidden=(16, 16))
    flat = layout.init_numpy(0)
    td3.sync_targets_numpy(layout, flat)
    return layout, jax.jit(update), bargs, flat


def test_td3_loss_decreases_on_fixed_batch(td3_setup):
    layout, update, bargs, flat = td3_setup
    batches = np_batches(bargs, 1)
    s = update(jnp.asarray(flat), *batches)
    first = metric(layout, s, "critic_loss").copy()
    for _ in range(30):
        s = update(s, *batches)
    last = metric(layout, s, "critic_loss")
    assert np.all(np.isfinite(np.asarray(s)))
    assert np.all(last < first), f"{first} -> {last}"


def test_td3_pallas_and_reference_paths_agree(td3_setup):
    layout, _, bargs, flat = td3_setup
    _, update_fn, _ = td3.make_update(3, 5, 2, 8, hidden=(16, 16))
    batches = np_batches(bargs, 2)
    try:
        pk.set_use_pallas(False)
        s_ref = jax.jit(update_fn)(jnp.asarray(flat), *batches)
        s_ref.block_until_ready()
    finally:
        pk.set_use_pallas(True)
    s_pal = jax.jit(update_fn)(jnp.asarray(flat), *batches)
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-5)


def test_td3_agents_are_isolated(td3_setup):
    layout, update, bargs, flat = td3_setup
    b1 = np_batches(bargs, 3)
    # change ONLY agent 2's batch
    b2 = [b.at[2].add(1.0) if b.ndim >= 2 else b for b in b1]
    # two steps so the delayed policy update fires at least once
    s1 = update(update(jnp.asarray(flat), *b1), *b1)
    s2 = update(update(jnp.asarray(flat), *b2), *b2)
    for name in ("policy/w0", "q1/w0"):
        f = layout.field(name)
        a1 = metric(layout, s1, name)
        a2 = metric(layout, s2, name)
        np.testing.assert_array_equal(a1[0], a2[0], err_msg=f"{name} agent0")
        np.testing.assert_array_equal(a1[1], a2[1], err_msg=f"{name} agent1")
        assert not np.allclose(a1[2], a2[2]), f"{name} agent2 should differ"


def test_td3_delayed_policy_update_respects_freq(td3_setup):
    layout, update, bargs, flat = td3_setup
    # freq=1: policy moves every step; freq->0: policy frozen
    f = layout.field("policy_freq")
    o = layout.offsets["policy_freq"]
    frozen = flat.copy()
    frozen[o:o + f.size] = 1e-7
    batches = np_batches(bargs, 4)
    s = update(jnp.asarray(frozen), *batches)
    w_before = flat[layout.offsets["policy/w0"]:
                    layout.offsets["policy/w0"] + layout.field("policy/w0").size]
    w_after = metric(layout, s, "policy/w0").reshape(-1)
    np.testing.assert_array_equal(w_after, w_before)
    # critic still trains
    assert np.all(metric(layout, s, "critic_loss") > 0)


def test_td3_step_counter_and_rng_advance(td3_setup):
    layout, update, bargs, flat = td3_setup
    batches = np_batches(bargs, 5)
    s1 = update(jnp.asarray(flat), *batches)
    s2 = update(s1, *batches)
    assert np.all(metric(layout, s2, "step").view(np.uint32) == 2)
    k1 = metric(layout, s1, "rng").view(np.uint32)
    k2 = metric(layout, s2, "rng").view(np.uint32)
    assert not np.array_equal(k1, k2)


def test_td3_num_steps_scan_equals_sequential_calls():
    layout, upd1, bargs = td3.make_update(2, 4, 2, 6, hidden=(8, 8))
    _, updk, _ = td3.make_update(2, 4, 2, 6, num_steps=3, hidden=(8, 8))
    flat = layout.init_numpy(1)
    td3.sync_targets_numpy(layout, flat)
    bk = np_batches(bargs, 6, num_steps=3)
    s_scan = jax.jit(updk)(jnp.asarray(flat), *bk)
    s_seq = jnp.asarray(flat)
    ju = jax.jit(upd1)
    for i in range(3):
        s_seq = ju(s_seq, *[b[i] for b in bk])
    np.testing.assert_allclose(np.asarray(s_scan), np.asarray(s_seq),
                               rtol=1e-5, atol=1e-6)


def test_td3_policy_forward_in_range():
    layout, fwd, bargs = td3.make_policy_forward(2, 4, 3, 5, hidden=(8, 8))
    flat = layout.init_numpy(2)
    obs = np_batches(bargs, 7)[0]
    a = jax.jit(fwd)(jnp.asarray(flat), obs)
    assert a.shape == (2, 5, 3)
    assert np.all(np.abs(np.asarray(a)) <= 1.0)


# ---------------------------------------------------------------------------
# SAC
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sac_setup():
    layout, update, bargs = sac.make_update(2, 5, 2, 8, hidden=(16, 16))
    flat = layout.init_numpy(0)
    sac.sync_targets_numpy(layout, flat)
    return layout, jax.jit(update), bargs, flat


def test_sac_trains_and_stays_finite(sac_setup):
    layout, update, bargs, flat = sac_setup
    # freeze the temperature so the critic target is quasi-stationary and
    # the loss trend is a meaningful learning signal
    frozen = flat.copy()
    o = layout.offsets["lr_alpha"]
    frozen[o:o + layout.field("lr_alpha").size] = 0.0
    batches = np_batches(bargs, 8)
    s = update(jnp.asarray(frozen), *batches)
    first = metric(layout, s, "critic_loss").copy()
    losses = []
    for _ in range(40):
        s = update(s, *batches)
        losses.append(metric(layout, s, "critic_loss").copy())
    assert np.all(np.isfinite(np.asarray(s)))
    # the loss must have meaningfully dipped below its starting point
    min_loss = np.min(np.stack(losses), axis=0)
    assert np.all(min_loss < 0.9 * first), f"{first} -> min {min_loss}"


def test_sac_alpha_responds_to_entropy_target(sac_setup):
    layout, update, bargs, flat = sac_setup
    batches = np_batches(bargs, 9)
    s = jnp.asarray(flat)
    for _ in range(10):
        s = update(s, *batches)
    alpha = metric(layout, s, "alpha")
    assert np.all(alpha > 0)
    ent = metric(layout, s, "entropy")
    assert np.all(np.isfinite(ent))


def test_sac_reward_scale_changes_targets(sac_setup):
    layout, update, bargs, flat = sac_setup
    scaled = flat.copy()
    o = layout.offsets["reward_scale"]
    scaled[o:o + 2] = 10.0
    batches = np_batches(bargs, 10)
    s1 = update(jnp.asarray(flat), *batches)
    s2 = update(jnp.asarray(scaled), *batches)
    assert not np.allclose(metric(layout, s1, "critic_loss"),
                           metric(layout, s2, "critic_loss"))


# ---------------------------------------------------------------------------
# DQN
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dqn_setup():
    layout, update, bargs = dqn.make_update(2, 6, 6, 2, 3, 4, target_period=5)
    flat = layout.init_numpy(0)
    dqn.sync_targets_numpy(layout, flat)
    return layout, jax.jit(update), bargs, flat


def test_dqn_trains(dqn_setup):
    layout, update, bargs, flat = dqn_setup
    batches = np_batches(bargs, 11)
    s = update(jnp.asarray(flat), *batches)
    first = metric(layout, s, "loss").copy()
    for _ in range(20):
        s = update(s, *batches)
    assert np.all(np.isfinite(np.asarray(s)))
    assert np.all(metric(layout, s, "loss") <= first)


def test_dqn_hard_target_copy_happens_at_period(dqn_setup):
    layout, update, bargs, flat = dqn_setup
    batches = np_batches(bargs, 12)
    s = jnp.asarray(flat)
    name_on, name_t = "q/conv/w", "q_t/conv/w"
    for step in range(1, 7):
        s = update(s, *batches)
        on = metric(layout, s, name_on)
        tg = metric(layout, s, name_t)
        if step % 5 == 0:
            np.testing.assert_array_equal(on, tg)
        else:
            assert not np.array_equal(on, tg), f"step {step}: target stale copy"


def test_dqn_conv_group_and_vmap_agree():
    l1, u1, bargs = dqn.make_update(2, 6, 6, 2, 3, 4, conv_method="group")
    _, u2, _ = dqn.make_update(2, 6, 6, 2, 3, 4, conv_method="vmap")
    flat = l1.init_numpy(3)
    dqn.sync_targets_numpy(l1, flat)
    batches = np_batches(bargs, 13)
    s1 = jax.jit(u1)(jnp.asarray(flat), *batches)
    s2 = jax.jit(u2)(jnp.asarray(flat), *batches)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# Shared critic (CEM-RL) + DvD
# ---------------------------------------------------------------------------


def test_shared_critic_both_orderings_train():
    for ordering in ("vec", "seq"):
        layout, update, bargs = sc.make_update(3, 5, 2, 6, ordering=ordering,
                                               hidden=(8, 8))
        flat = layout.init_numpy(0)
        sc.sync_targets_numpy(layout, flat)
        batches = np_batches(bargs, 14)
        ju = jax.jit(update)
        s = ju(jnp.asarray(flat), *batches)
        first = metric(layout, s, "critic_loss").copy()
        for _ in range(10):
            s = ju(s, *batches)
        assert np.all(np.isfinite(np.asarray(s))), ordering
        assert metric(layout, s, "critic_loss")[0] < first[0], ordering


def test_shared_critic_counts_match_population():
    layout, update, bargs = sc.make_update(4, 5, 2, 6, ordering="vec",
                                           hidden=(8, 8))
    flat = layout.init_numpy(1)
    sc.sync_targets_numpy(layout, flat)
    s = jax.jit(update)(jnp.asarray(flat), *np_batches(bargs, 15))
    # one round = P critic sub-updates
    cstep = metric(layout, s, "cstep").view(np.uint32)
    assert cstep[0] == 4
    step = metric(layout, s, "step").view(np.uint32)
    np.testing.assert_array_equal(step, 1)


def test_dvd_diversity_term_separates_policies():
    def run(dvd):
        layout, update, bargs = sc.make_update(
            3, 5, 2, 6, ordering="vec", hidden=(8, 8), dvd=dvd, dvd_probes=4)
        flat = layout.init_numpy(7)
        sc.sync_targets_numpy(layout, flat)
        if dvd:
            o = layout.offsets["lambda_div"]
            flat[o] = 5.0  # strong diversity pressure
        batches = np_batches(bargs, 16)
        ju = jax.jit(update)
        s = jnp.asarray(flat)
        for _ in range(15):
            s = ju(s, *batches)
        # pairwise distance between policy weight rows
        w = metric(layout, s, "policy/w0")
        d = 0.0
        for i in range(3):
            for j in range(i + 1, 3):
                d += float(np.sum((w[i] - w[j]) ** 2))
        return d, s

    d_plain, _ = run(False)
    d_dvd, s = run(True)
    assert np.all(np.isfinite(np.asarray(s)))
    assert d_dvd > d_plain, f"diversity {d_dvd} should exceed plain {d_plain}"


def test_dvd_logdet_matches_numpy():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(5, 5)).astype(np.float32)
    k = a @ a.T + 5.0 * np.eye(5, dtype=np.float32)
    ours = float(sc._logdet_psd(jnp.asarray(k)))
    expected = float(np.linalg.slogdet(k)[1])
    assert abs(ours - expected) < 1e-3


def test_delayed_mask_average_rate():
    step = jnp.arange(1000, dtype=jnp.uint32)
    for freq in (0.2, 0.5, 1.0):
        m = common.delayed_mask(step, jnp.full((1000,), freq))
        rate = float(jnp.mean(m))
        assert abs(rate - freq) < 0.01, f"freq {freq}: rate {rate}"
