"""Flat-state layout: pack/unpack round trips, offsets, init specs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.layout import Field, Layout

jax.config.update("jax_platform_name", "cpu")


def toy_layout():
    return Layout([
        Field("w", (2, 3), "f32", "lecun_uniform:3", "policy"),
        Field("lr", (2,), "f32", "const:0.001", "hyper"),
        Field("rng", (2, 2), "u32", "key", "rng"),
        Field("step", (2,), "u32", "step", "step"),
        Field("loss", (2,), "f32", "zeros", "metric"),
    ])


def test_offsets_are_contiguous():
    lo = toy_layout()
    assert lo.offsets["w"] == 0
    assert lo.offsets["lr"] == 6
    assert lo.offsets["rng"] == 8
    assert lo.offsets["step"] == 12
    assert lo.offsets["loss"] == 14
    assert lo.size == 16


def test_pack_unpack_roundtrip_including_u32():
    lo = toy_layout()
    vals = {
        "w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "lr": jnp.asarray([1e-3, 2e-3], jnp.float32),
        "rng": jnp.asarray([[1, 2], [3, 0xFFFFFFFF]], jnp.uint32),
        "step": jnp.asarray([7, 9], jnp.uint32),
        "loss": jnp.asarray([0.5, -0.5], jnp.float32),
    }
    flat = lo.pack(vals)
    assert flat.shape == (16,)
    out = lo.unpack(flat)
    for k, v in vals.items():
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(v), err_msg=k)
        assert out[k].dtype == v.dtype


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_init_numpy_respects_specs(seed):
    lo = toy_layout()
    flat = lo.init_numpy(seed)
    assert flat.dtype == np.float32
    w = flat[0:6]
    bound = np.sqrt(3.0 / 3.0)
    assert np.all(np.abs(w) <= bound)
    np.testing.assert_allclose(flat[6:8], 1e-3)
    keys = flat[8:12].view(np.uint32)
    assert len(set(keys.tolist())) == 4  # distinct key material
    steps = flat[12:14].view(np.uint32)
    np.testing.assert_array_equal(steps, 0)


def test_duplicate_names_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        Layout([Field("a", (1,)), Field("a", (2,))])


def test_pack_missing_field_rejected():
    lo = toy_layout()
    with pytest.raises(ValueError, match="missing"):
        lo.pack({"w": jnp.zeros((2, 3))})


def test_group_selection():
    lo = toy_layout()
    vals = lo.unpack(jnp.zeros(lo.size))
    hyper = lo.group(vals, "hyper")
    assert list(hyper) == ["lr"]
    assert [f.name for f in lo.group_fields("rng")] == ["rng"]


def test_manifest_shape():
    lo = toy_layout()
    m = lo.manifest()
    assert [e["name"] for e in m] == ["w", "lr", "rng", "step", "loss"]
    e = m[0]
    assert e["offset"] == 0 and e["size"] == 6 and e["shape"] == [2, 3]
    assert e["dtype"] == "f32" and e["group"] == "policy"


def test_read_inside_jit():
    lo = toy_layout()

    @jax.jit
    def get_step(flat):
        return lo.read(flat, "step")

    flat = jnp.asarray(lo.init_numpy(0))
    s = get_step(flat)
    assert s.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(s), [0, 0])
