"""AOT driver: lower every update-step variant to HLO text + manifest.

Python runs ONCE, at build time (``make artifacts``): each (algorithm, env,
population-size, num-steps) combination is traced, lowered to StableHLO,
converted to an XlaComputation and dumped as **HLO text** — the interchange
format the rust runtime can load (``HloModuleProto::from_text_file``).
Serialized protos are NOT used: jax >= 0.5 emits 64-bit instruction ids
that xla_extension 0.5.1 rejects; the text parser reassigns ids.

``artifacts/manifest.json`` describes every artifact: the flat-state layout
(field offsets/shapes/dtypes/init specs/groups), the batch inputs, env
dims, and output shapes — everything ``rust/src/manifest.rs`` needs to
initialize states, drive ``execute_b`` and read metrics.

Usage (from ``python/``):
    python -m compile.aot --out-dir ../artifacts --set default
    python -m compile.aot --out-dir ../artifacts --set bench
    python -m compile.aot --out-dir ../artifacts --spec td3:halfcheetah:p8:k1:b256:h256
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .layout import Layout
from .updates import common, dqn, sac, shared_critic, td3

# ---------------------------------------------------------------------------
# Environment registry (tensor shapes only; dynamics live in rust/src/envs).
# Dims follow the MuJoCo Gym tasks the paper trains on (Ant uses the
# 27-dim proprioceptive observation, without contact forces).
# ---------------------------------------------------------------------------

ENVS: Dict[str, common.EnvSpec] = {
    "halfcheetah": common.EnvSpec("halfcheetah", obs_dim=17, act_dim=6),
    "hopper": common.EnvSpec("hopper", obs_dim=11, act_dim=3),
    "walker2d": common.EnvSpec("walker2d", obs_dim=17, act_dim=6),
    "ant": common.EnvSpec("ant", obs_dim=27, act_dim=8),
    "humanoid": common.EnvSpec("humanoid", obs_dim=376, act_dim=17),
    "swimmer": common.EnvSpec("swimmer", obs_dim=8, act_dim=2),
    "pendulum": common.EnvSpec("pendulum", obs_dim=3, act_dim=1),
    "minatar": common.EnvSpec("minatar", frame=(10, 10, 4), n_actions=3),
    "asterix": common.EnvSpec("asterix", frame=(10, 10, 4), n_actions=5),
    "spaceinvaders": common.EnvSpec("spaceinvaders", frame=(10, 10, 4),
                                    n_actions=4),
    # the paper's original Atari frame scale (Mnih conv stack; Fig 2 DQN
    # rows at full scale — generate on demand, it is large)
    "atari": common.EnvSpec("atari", frame=(84, 84, 4), n_actions=6),
}


@dataclasses.dataclass(frozen=True)
class Spec:
    algo: str          # td3 | sac | dqn | cem | cemseq | dvd | td3fwd | sacfwd | dqnfwd
    env: str
    pop: int
    num_steps: int = 1
    batch: int = 256
    hidden: Tuple[int, ...] = (256, 256)

    @property
    def name(self) -> str:
        h = "x".join(str(d) for d in self.hidden)
        return f"{self.algo}_{self.env}_p{self.pop}_k{self.num_steps}_b{self.batch}_h{h}"


def parse_spec(text: str) -> Spec:
    """Parse 'algo:env:p4:k1:b256:h256x256'."""
    parts = text.split(":")
    algo, env = parts[0], parts[1]
    kw: Dict[str, object] = {}
    for p in parts[2:]:
        if p.startswith("p"):
            kw["pop"] = int(p[1:])
        elif p.startswith("k"):
            kw["num_steps"] = int(p[1:])
        elif p.startswith("b"):
            kw["batch"] = int(p[1:])
        elif p.startswith("h"):
            kw["hidden"] = tuple(int(d) for d in p[1:].split("x"))
        else:
            raise ValueError(f"bad spec token {p!r} in {text!r}")
    return Spec(algo, env, **kw)


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def build(spec: Spec):
    """Returns (layout, fn, batch_args, out_desc, sync_groups).

    An algo ending in "ref" builds the same computation with the pure-jnp
    reference kernel instead of Pallas (the L1 ablation of DESIGN.md §Perf
    — lowering both lets the rust benches A/B the kernel's lowered form).
    """
    e = ENVS[spec.env]
    if spec.algo == "td3" or spec.algo == "td3ref":
        layout, fn, bargs = td3.make_update(
            spec.pop, e.obs_dim, e.act_dim, spec.batch, spec.num_steps,
            spec.hidden)
        return layout, fn, bargs, "state", ["policy", "critic"]
    if spec.algo == "sac":
        layout, fn, bargs = sac.make_update(
            spec.pop, e.obs_dim, e.act_dim, spec.batch, spec.num_steps,
            spec.hidden)
        return layout, fn, bargs, "state", ["critic"]
    if spec.algo == "dqn":
        h, w, c = e.frame
        layout, fn, bargs = dqn.make_update(
            spec.pop, h, w, c, e.n_actions, spec.batch, spec.num_steps)
        return layout, fn, bargs, "state", ["critic"]
    if spec.algo in ("cem", "cemseq", "dvd"):
        layout, fn, bargs = shared_critic.make_update(
            spec.pop, e.obs_dim, e.act_dim, spec.batch,
            ordering="seq" if spec.algo == "cemseq" else "vec",
            num_steps=spec.num_steps, hidden=spec.hidden,
            dvd=spec.algo == "dvd")
        return layout, fn, bargs, "state", ["policy", "critic"]
    if spec.algo == "td3fwd":
        layout, fn, bargs = td3.make_policy_forward(
            spec.pop, e.obs_dim, e.act_dim, spec.batch, spec.hidden)
        return layout, fn, bargs, "actions", []
    if spec.algo == "sacfwd":
        layout, fn, bargs = sac.make_policy_forward(
            spec.pop, e.obs_dim, e.act_dim, spec.batch, spec.hidden)
        return layout, fn, bargs, "actions", []
    if spec.algo == "dqnfwd":
        h, w, c = e.frame
        layout, fn, bargs = dqn.make_q_forward(
            spec.pop, h, w, c, e.n_actions, spec.batch)
        return layout, fn, bargs, "qvalues", []
    raise ValueError(f"unknown algo {spec.algo!r}")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def lower_spec(spec: Spec, out_dir: str) -> dict:
    from .kernels import pop_linear as pk

    # "...ref" algos trace through the jnp oracle instead of Pallas
    pk.set_use_pallas(not spec.algo.endswith("ref"))
    try:
        return _lower_spec_inner(spec, out_dir)
    finally:
        pk.set_use_pallas(True)


def _lower_spec_inner(spec: Spec, out_dir: str) -> dict:
    layout, fn, bargs, out_kind, sync_groups = build(spec)
    e = ENVS[spec.env]
    state_arg = jax.ShapeDtypeStruct((layout.size,), jnp.float32)
    batch_shapes = []
    for a in bargs:
        shape = a.shape if spec.num_steps == 1 or out_kind != "state" \
            else (spec.num_steps,) + a.shape
        batch_shapes.append(jax.ShapeDtypeStruct(shape, a.jnp_dtype()))

    t0 = time.time()
    lowered = jax.jit(fn).lower(state_arg, *batch_shapes)
    text = to_hlo_text(lowered)
    dt = time.time() - t0

    fname = f"{spec.name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)

    inputs = [{"name": "state", "shape": [layout.size], "dtype": "f32"}]
    for a, sh in zip(bargs, batch_shapes):
        inputs.append({"name": a.name, "shape": list(sh.shape),
                       "dtype": a.dtype})
    env_desc = {"obs_dim": e.obs_dim, "act_dim": e.act_dim}
    if e.frame != (0, 0, 0):
        env_desc = {"frame": list(e.frame), "n_actions": e.n_actions}
    print(f"  lowered {spec.name}: state={layout.size} f32, "
          f"{len(text)} chars, {dt:.1f}s", file=sys.stderr)
    return {
        "file": fname,
        "algo": spec.algo,
        "env": spec.env,
        "env_desc": env_desc,
        "pop": spec.pop,
        "num_steps": spec.num_steps,
        "batch": spec.batch,
        "hidden": list(spec.hidden),
        "state_size": layout.size,
        "output": out_kind,
        "sync_target_groups": sync_groups,
        "fields": layout.manifest(),
        "inputs": inputs,
        "lower_seconds": round(dt, 2),
    }


# ---------------------------------------------------------------------------
# Artifact sets
# ---------------------------------------------------------------------------

# Small, fast set: enough for `cargo test` + the examples. Hidden sizes are
# scaled to the single-CPU-core substrate (see DESIGN.md); the bench set
# uses the paper's 256x256.
DEFAULT_SET: List[Spec] = [
    # fast tests + quickstart (pendulum, tiny nets)
    Spec("td3", "pendulum", 1, 1, 32, (32, 32)),
    Spec("td3", "pendulum", 4, 1, 64, (32, 32)),
    Spec("td3fwd", "pendulum", 1, 1, 16, (32, 32)),
    Spec("td3fwd", "pendulum", 4, 1, 1, (32, 32)),
    Spec("sac", "pendulum", 4, 1, 64, (32, 32)),
    Spec("sacfwd", "pendulum", 4, 1, 1, (32, 32)),
    # paper-shaped nets on halfcheetah (examples pbt/cemrl/dvd)
    Spec("td3", "halfcheetah", 1, 1, 256, (256, 256)),
    Spec("td3", "halfcheetah", 8, 1, 256, (64, 64)),
    Spec("td3", "halfcheetah", 8, 10, 256, (64, 64)),
    Spec("td3fwd", "halfcheetah", 8, 1, 1, (64, 64)),
    Spec("sac", "halfcheetah", 8, 1, 256, (64, 64)),
    Spec("sacfwd", "halfcheetah", 8, 1, 1, (64, 64)),
    Spec("cem", "halfcheetah", 10, 1, 64, (64, 64)),
    Spec("cemseq", "halfcheetah", 10, 1, 64, (64, 64)),
    Spec("dvd", "halfcheetah", 5, 1, 64, (64, 64)),
    Spec("td3fwd", "halfcheetah", 10, 1, 1, (64, 64)),
    Spec("td3fwd", "halfcheetah", 5, 1, 1, (64, 64)),
    # dqn on the minatar substitute
    Spec("dqn", "minatar", 1, 1, 32),
    Spec("dqn", "minatar", 2, 1, 32),
    Spec("dqnfwd", "minatar", 1, 1, 8),
    Spec("dqnfwd", "minatar", 2, 1, 1),
]

# Fig 2 / Fig 3 / Fig 4 / Table 3 sweeps (paper-sized nets).
BENCH_POPS = [1, 2, 5, 10, 20]
BENCH_SET: List[Spec] = (
    [Spec("td3", "halfcheetah", p, 1, 256) for p in BENCH_POPS]
    + [Spec("td3", "halfcheetah", p, 10, 256) for p in BENCH_POPS]
    + [Spec("sac", "halfcheetah", p, 1, 256) for p in BENCH_POPS]
    + [Spec("dqn", "minatar", p, 1, 32) for p in BENCH_POPS]
    + [Spec("cem", "halfcheetah", p, 1, 256) for p in [1, 2, 5, 10]]
    + [Spec("cemseq", "halfcheetah", p, 1, 256) for p in [1, 2, 5, 10]]
    # L1 ablation: the same TD3 update lowered through the jnp reference
    # kernel instead of Pallas (interpret-mode overhead study, §Perf)
    + [Spec("td3ref", "halfcheetah", p, 1, 256) for p in BENCH_POPS]
)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--set", choices=["default", "bench", "all", "none"],
                    default="default")
    ap.add_argument("--spec", action="append", default=[],
                    help="extra artifact spec algo:env:pN:kN:bN:hAxB")
    args = ap.parse_args()

    specs: List[Spec] = []
    if args.set in ("default", "all"):
        specs += DEFAULT_SET
    if args.set in ("bench", "all"):
        specs += BENCH_SET
    specs += [parse_spec(s) for s in args.spec]

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"version": 1, "artifacts": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    t0 = time.time()
    for spec in specs:
        if spec.name in manifest["artifacts"] and os.path.exists(
                os.path.join(args.out_dir, f"{spec.name}.hlo.txt")):
            print(f"  cached  {spec.name}", file=sys.stderr)
            continue
        manifest["artifacts"][spec.name] = lower_spec(spec, args.out_dir)
        # write incrementally so an interrupted run keeps its progress
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1)
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"artifacts: {len(manifest['artifacts'])} total, "
          f"{time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
