"""Pure-jnp oracle for the Pallas population-batched linear kernel.

This is the correctness signal for L1: ``python/tests/test_kernels.py``
sweeps shapes/dtypes with hypothesis and asserts the Pallas forward and
backward match these reference implementations, and that the custom-VJP
gradients match ``jax.grad`` of this reference.
"""

from __future__ import annotations

import jax.numpy as jnp


def _apply_act(z, activation: str):
    if activation == "none":
        return z
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "tanh":
        return jnp.tanh(z)
    raise ValueError(f"unknown activation {activation!r}")


def pop_linear_ref(x, w, b, activation: str = "none"):
    """``act(x @ w + b)`` with a leading population axis.

    x: [P, B, I], w: [P, I, O], b: [P, O] -> [P, B, O]
    """
    z = jnp.einsum("pbi,pio->pbo", x, w) + b[:, None, :]
    return _apply_act(z, activation).astype(x.dtype)


def pop_linear_bwd_ref(x, w, y, g, activation: str):
    """Reference VJP written in terms of the post-activation output ``y``."""
    if activation == "none":
        dz = g
    elif activation == "relu":
        dz = g * (y > 0).astype(g.dtype)
    elif activation == "tanh":
        dz = g * (1.0 - y * y)
    else:
        raise ValueError(f"unknown activation {activation!r}")
    dx = jnp.einsum("pbo,pio->pbi", dz, w).astype(x.dtype)
    dw = jnp.einsum("pbi,pbo->pio", x, dz).astype(w.dtype)
    db = jnp.sum(dz, axis=1).astype(w.dtype)
    return dx, dw, db
