"""L1: population-batched affine transform as a Pallas kernel.

The hot spot of every population update step in the paper is the
population-batched linear layer (the jax analogue of the paper's Appendix-C
``VectorizedLinearLayer``)::

    y[p, b, o] = act(sum_i x[p, b, i] * w[p, i, o] + bias[p, o])

We implement the forward pass and the full backward pass (dx, dw, db) as
Pallas kernels wrapped in a ``jax.custom_vjp`` so that gradients of the L2
update functions flow through Pallas end to end.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper batches
cuBLAS GEMMs over the population on GPUs. On TPU the population axis becomes
the Pallas *grid* — one program instance per population member, which is
perfect data parallelism with no cross-member traffic — and the per-member
GEMM is tiled so the working set fits VMEM and feeds the 128x128 MXU. The
``block_b``/``block_o`` knobs expose that tiling; on the CPU interpret path
(this image) the default is "no tiling" (one program per member) because
interpret-mode grids lower to XLA while-loops whose trip count we want to
keep small.

All kernels run under ``interpret=True`` so they lower to plain HLO the
PJRT CPU client can execute (real-TPU lowering emits Mosaic custom-calls).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ACTIVATIONS = ("none", "relu", "tanh")

# Flipped to False by tests to route every pop_linear call through the
# pure-jnp reference (kernels/ref.py); the L2 update functions are written
# against this module only, so the switch gives a one-line A/B of the whole
# model with and without Pallas.
_USE_PALLAS = True


def set_use_pallas(flag: bool) -> None:
    global _USE_PALLAS
    _USE_PALLAS = bool(flag)


def _apply_act(z, activation: str):
    if activation == "none":
        return z
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "tanh":
        return jnp.tanh(z)
    raise ValueError(f"unknown activation {activation!r}")


def _act_bwd_from_out(y, g, activation: str):
    """dL/dz given dL/dy and the *post*-activation value y.

    Both relu and tanh admit a backward pass in terms of the output alone,
    which lets the VJP save one residual instead of two.
    """
    if activation == "none":
        return g
    if activation == "relu":
        return g * (y > 0).astype(g.dtype)
    if activation == "tanh":
        return g * (1.0 - y * y)
    raise ValueError(f"unknown activation {activation!r}")


# --------------------------------------------------------------------------
# Forward kernel
# --------------------------------------------------------------------------


def _fwd_kernel(x_ref, w_ref, b_ref, y_ref, *, activation: str):
    # One grid step owns one (member-block, batch-tile, out-tile) block.
    x = x_ref[...]  # [pb, bb, i]
    w = w_ref[...]  # [pb, i, bo]
    b = b_ref[...]  # [pb, 1, bo]
    z = jnp.einsum("pbi,pio->pbo", x, w,
                   preferred_element_type=jnp.float32) + b
    y_ref[...] = _apply_act(z, activation).astype(y_ref.dtype)


def _blk(total: int, want: Optional[int]) -> int:
    """Resolve a tile size: None = whole axis; non-divisors fall back to
    the whole axis (edge handling is not worth interpret overhead on CPU;
    on TPU pad instead)."""
    if want is None:
        return total
    b = min(want, total)
    return b if total % b == 0 else total


def _fwd_pallas(x, w, b, activation: str, block_b: Optional[int],
                block_o: Optional[int], pop_block: Optional[int]):
    p, bsz, i = x.shape
    o = w.shape[2]
    pb = _blk(p, pop_block)
    bb = _blk(bsz, block_b)
    bo = _blk(o, block_o)
    # Grid: population tiles first (embarrassing parallelism), then row/col
    # tiles of the member GEMM. On TPU, pop_block=1 gives the one-member-
    # per-TensorCore-program schedule (DESIGN.md §Hardware-Adaptation); on
    # the CPU interpret path the default pop_block=None collapses the grid
    # to a single program, because interpret-mode grid steps lower to an
    # XLA while-loop with dynamic slicing whose overhead scales with the
    # trip count (measured 3.6x at P=20 — see EXPERIMENTS.md §Perf).
    grid = (p // pb, bsz // bb, o // bo)
    b2 = b.reshape(p, 1, o)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((pb, bb, i), lambda pi, bi, oi: (pi, bi, 0)),
            pl.BlockSpec((pb, i, bo), lambda pi, bi, oi: (pi, 0, oi)),
            pl.BlockSpec((pb, 1, bo), lambda pi, bi, oi: (pi, 0, oi)),
        ],
        out_specs=pl.BlockSpec((pb, bb, bo), lambda pi, bi, oi: (pi, bi, oi)),
        out_shape=jax.ShapeDtypeStruct((p, bsz, o), x.dtype),
        interpret=True,
    )(x, w, b2)


# --------------------------------------------------------------------------
# Backward kernels
# --------------------------------------------------------------------------


def _bwd_kernel(x_ref, w_ref, y_ref, g_ref, dx_ref, dw_ref, db_ref, *, activation: str):
    x = x_ref[...]  # [pb, b, i]
    w = w_ref[...]  # [pb, i, o]
    y = y_ref[...]  # [pb, b, o]
    g = g_ref[...]  # [pb, b, o]
    dz = _act_bwd_from_out(y, g, activation)
    dx_ref[...] = jnp.einsum("pbo,pio->pbi", dz, w,
                             preferred_element_type=jnp.float32).astype(dx_ref.dtype)
    dw_ref[...] = jnp.einsum("pbi,pbo->pio", x, dz,
                             preferred_element_type=jnp.float32).astype(dw_ref.dtype)
    db_ref[...] = jnp.sum(dz, axis=1).astype(db_ref.dtype)


def _bwd_pallas(x, w, y, g, activation: str, pop_block: Optional[int]):
    p, bsz, i = x.shape
    o = w.shape[2]
    pb = _blk(p, pop_block)
    kern = functools.partial(_bwd_kernel, activation=activation)
    return pl.pallas_call(
        kern,
        grid=(p // pb,),
        in_specs=[
            pl.BlockSpec((pb, bsz, i), lambda pi: (pi, 0, 0)),
            pl.BlockSpec((pb, i, o), lambda pi: (pi, 0, 0)),
            pl.BlockSpec((pb, bsz, o), lambda pi: (pi, 0, 0)),
            pl.BlockSpec((pb, bsz, o), lambda pi: (pi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((pb, bsz, i), lambda pi: (pi, 0, 0)),
            pl.BlockSpec((pb, i, o), lambda pi: (pi, 0, 0)),
            pl.BlockSpec((pb, o), lambda pi: (pi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, bsz, i), x.dtype),
            jax.ShapeDtypeStruct((p, i, o), w.dtype),
            jax.ShapeDtypeStruct((p, o), w.dtype),
        ],
        interpret=True,
    )(x, w, y, g)


# --------------------------------------------------------------------------
# Public entry point with custom VJP
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def pop_linear(x, w, b, activation: str = "none",
               block_b: Optional[int] = None, block_o: Optional[int] = None,
               pop_block: Optional[int] = None):
    """Population-batched affine transform ``act(x @ w + b)``.

    Args:
      x: ``f32[P, B, I]`` per-member activations.
      w: ``f32[P, I, O]`` per-member weights.
      b: ``f32[P, O]`` per-member biases.
      activation: one of ``none|relu|tanh`` (fused into the kernel).
      block_b / block_o: optional VMEM tile sizes for the batch and output
        axes (TPU knob; ``None`` = whole axis).
      pop_block: population members per grid step. ``1`` is the TPU layout
        (one member per TensorCore program — perfect data parallelism);
        ``None`` (default) collapses the population into one program,
        which is what the CPU interpret path wants (its grid steps lower
        to an XLA while-loop whose overhead scales with the trip count —
        the §Perf ablation measured 3.6x at P=20).

    Returns:
      ``f32[P, B, O]``.
    """
    if activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    if not _USE_PALLAS:
        from . import ref

        return ref.pop_linear_ref(x, w, b, activation)
    return _fwd_pallas(x, w, b, activation, block_b, block_o, pop_block)


def _pop_linear_fwd(x, w, b, activation, block_b, block_o, pop_block):
    if not _USE_PALLAS:
        from . import ref

        y = ref.pop_linear_ref(x, w, b, activation)
        return y, (x, w, y)
    y = _fwd_pallas(x, w, b, activation, block_b, block_o, pop_block)
    return y, (x, w, y)


def _pop_linear_bwd(activation, block_b, block_o, pop_block, res, g):
    x, w, y = res
    if not _USE_PALLAS:
        from . import ref

        dx, dw, db = ref.pop_linear_bwd_ref(x, w, y, g, activation)
        return dx, dw, db
    dx, dw, db = _bwd_pallas(x, w, y, g, activation, pop_block)
    return dx, dw, db


pop_linear.defvjp(_pop_linear_fwd, _pop_linear_bwd)
