"""Flat train-state record: the interchange format between L2 and L3.

Every update-step artifact takes the *entire* train state of the population
(parameters, target parameters, Adam moments, per-agent hyperparameters, RNG
keys, step counters, metric slots) as ONE flat ``f32[S]`` vector and returns
the new vector. This gives the Rust coordinator a zero-copy round trip
through ``execute_b`` — parameters never visit host memory between update
steps, which is the paper's "multiple update steps without copying to host"
optimization taken to its limit.

``u32`` fields (RNG keys, step counters) are stored bit-cast into f32 lanes
(``lax.bitcast_convert_type``), so the record stays a single homogeneous
array. Metric slots are declared as ordinary (ignored-on-input) fields so
the output shape equals the input shape.

The layout (field name -> offset/size/shape/dtype/init/group) is serialized
into ``artifacts/manifest.json`` and mirrored by ``rust/src/manifest.rs``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Initialization specs understood by both python (tests) and rust (runtime):
#   zeros | ones | const:<v> | lecun_uniform:<fan_in> | uniform:<lo>,<hi>
#   | orthogonal-free variance scaling is intentionally not used (keep the
#     generator trivially portable across languages)
#   key  -- RNG key material: filled with per-agent seed material
#   step -- u32 step counter, starts at 0


@dataclasses.dataclass(frozen=True)
class Field:
    name: str
    shape: Tuple[int, ...]
    dtype: str = "f32"  # f32 | u32
    init: str = "zeros"
    group: str = "misc"  # policy|policy_target|critic|critic_target|opt|hyper|rng|step|metric|misc
    per_agent: bool = True  # leading axis is the population axis

    @property
    def size(self) -> int:
        return int(math.prod(self.shape)) if self.shape else 1


class Layout:
    """Ordered collection of fields packed into one flat f32 vector."""

    def __init__(self, fields: Sequence[Field]):
        names = [f.name for f in fields]
        if len(set(names)) != len(names):
            dup = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(f"duplicate field names: {dup}")
        self.fields: List[Field] = list(fields)
        self.offsets: Dict[str, int] = {}
        off = 0
        for f in self.fields:
            self.offsets[f.name] = off
            off += f.size
        self.size = off
        self._by_name = {f.name: f for f in self.fields}

    def field(self, name: str) -> Field:
        return self._by_name[name]

    def names(self) -> List[str]:
        return [f.name for f in self.fields]

    # ------------------------------------------------------------------
    # jax-side access
    # ------------------------------------------------------------------

    def read(self, state, name: str):
        """Slice one field out of the flat state (jax traceable)."""
        f = self._by_name[name]
        seg = jax.lax.dynamic_slice(state, (self.offsets[name],), (f.size,))
        seg = seg.reshape(f.shape)
        if f.dtype == "u32":
            seg = jax.lax.bitcast_convert_type(seg, jnp.uint32)
        return seg

    def unpack(self, state) -> Dict[str, jnp.ndarray]:
        return {f.name: self.read(state, f.name) for f in self.fields}

    def pack(self, values: Dict[str, jnp.ndarray]):
        """Concatenate all fields (in layout order) back into a flat f32."""
        missing = [f.name for f in self.fields if f.name not in values]
        if missing:
            raise ValueError(f"pack missing fields: {missing}")
        parts = []
        for f in self.fields:
            v = values[f.name]
            if f.dtype == "u32":
                v = jax.lax.bitcast_convert_type(v.astype(jnp.uint32), jnp.float32)
            parts.append(v.reshape(-1).astype(jnp.float32))
        return jnp.concatenate(parts) if parts else jnp.zeros((0,), jnp.float32)

    def group(self, values: Dict[str, jnp.ndarray], group: str) -> Dict[str, jnp.ndarray]:
        return {f.name: values[f.name] for f in self.fields if f.group == group}

    def group_fields(self, group: str) -> List[Field]:
        return [f for f in self.fields if f.group == group]

    # ------------------------------------------------------------------
    # numpy-side init (python tests; rust mirrors the same spec semantics)
    # ------------------------------------------------------------------

    def init_numpy(self, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        out = np.zeros(self.size, dtype=np.float32)
        for f in self.fields:
            seg = _init_field(f, rng, seed)
            if f.dtype == "u32":
                seg = seg.astype(np.uint32).view(np.float32)
            out[self.offsets[f.name]:self.offsets[f.name] + f.size] = (
                seg.astype(np.float32).reshape(-1)
            )
        return out

    # ------------------------------------------------------------------
    # manifest serialization
    # ------------------------------------------------------------------

    def manifest(self) -> List[dict]:
        return [
            {
                "name": f.name,
                "offset": self.offsets[f.name],
                "size": f.size,
                "shape": list(f.shape),
                "dtype": f.dtype,
                "init": f.init,
                "group": f.group,
                "per_agent": f.per_agent,
            }
            for f in self.fields
        ]


def _init_field(f: Field, rng: np.random.Generator, seed: int) -> np.ndarray:
    spec = f.init
    if spec == "zeros":
        return np.zeros(f.shape, np.float32)
    if spec == "ones":
        return np.ones(f.shape, np.float32)
    if spec == "step":
        return np.zeros(f.shape, np.uint32)
    if spec == "key":
        # Per-agent threefry key material: distinct, deterministic in seed.
        n = f.size
        vals = np.arange(n, dtype=np.uint64) * np.uint64(0x9E3779B97F4A7C15) + np.uint64(seed)
        vals ^= vals >> np.uint64(31)
        return (vals & np.uint64(0xFFFFFFFF)).astype(np.uint32).reshape(f.shape)
    if spec.startswith("const:"):
        return np.full(f.shape, float(spec.split(":", 1)[1]), np.float32)
    if spec.startswith("lecun_uniform:"):
        fan_in = int(spec.split(":", 1)[1])
        bound = math.sqrt(3.0 / max(fan_in, 1))
        return rng.uniform(-bound, bound, f.shape).astype(np.float32)
    if spec.startswith("uniform:"):
        lo, hi = (float(v) for v in spec.split(":", 1)[1].split(","))
        return rng.uniform(lo, hi, f.shape).astype(np.float32)
    raise ValueError(f"unknown init spec {spec!r} for field {f.name}")
