"""L2 network definitions built on the L1 Pallas kernel.

MLPs (TD3/SAC actors and critics) route every affine transform through
``kernels.pop_linear`` so the Pallas kernel sits on the hot path of both the
forward and the backward pass. The DQN conv stack uses the grouped-conv
trick from the paper (``feature_group_count = population``), with a
``vmap`` variant kept for the ablation bench.
"""

from __future__ import annotations

import math
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import pop_linear as pk
from .layout import Field

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_fields(prefix: str, pop: int, in_dim: int, hidden: Sequence[int],
               out_dim: int, group: str, final_uniform: float = 0.0) -> List[Field]:
    """Layout fields for a population-batched MLP.

    ``final_uniform > 0`` initializes the last layer from U(-b, b) (the
    small-final-layer convention of TD3/SAC actor/critic heads).
    """
    dims = [in_dim] + list(hidden) + [out_dim]
    fields: List[Field] = []
    n_layers = len(dims) - 1
    for li, (i, o) in enumerate(zip(dims[:-1], dims[1:])):
        last = li == n_layers - 1
        if last and final_uniform > 0.0:
            w_init = f"uniform:{-final_uniform},{final_uniform}"
            b_init = f"uniform:{-final_uniform},{final_uniform}"
        else:
            w_init = f"lecun_uniform:{i}"
            b_init = f"lecun_uniform:{i}"
        fields.append(Field(f"{prefix}/w{li}", (pop, i, o), "f32", w_init, group))
        fields.append(Field(f"{prefix}/b{li}", (pop, o), "f32", b_init, group))
    return fields


def mlp_apply(params: Params, prefix: str, x: jnp.ndarray, *,
              hidden_act: str = "relu", final_act: str = "none") -> jnp.ndarray:
    """Apply a population-batched MLP. x: [P, B, I] -> [P, B, O]."""
    layers = sorted(
        {int(k.rsplit("/w", 1)[1]) for k in params if k.startswith(f"{prefix}/w")}
    )
    h = x
    for li in layers:
        act = final_act if li == layers[-1] else hidden_act
        h = pk.pop_linear(h, params[f"{prefix}/w{li}"], params[f"{prefix}/b{li}"], act)
    return h


def mlp_num_layers(params: Params, prefix: str) -> int:
    return len([k for k in params if k.startswith(f"{prefix}/w")])


# ---------------------------------------------------------------------------
# Conv (DQN)
# ---------------------------------------------------------------------------


def conv_fields(prefix: str, pop: int, in_ch: int, features: int,
                ksize: int, group: str) -> List[Field]:
    fan_in = in_ch * ksize * ksize
    return [
        Field(f"{prefix}/w", (pop, ksize, ksize, in_ch, features), "f32",
              f"lecun_uniform:{fan_in}", group),
        Field(f"{prefix}/b", (pop, features), "f32", f"lecun_uniform:{fan_in}", group),
    ]


def pop_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *,
             method: str = "group",
             strides: Tuple[int, int] = (1, 1)) -> jnp.ndarray:
    """Population-batched 2D valid conv.

    x: [P, B, H, W, C], w: [P, kh, kw, C, F], b: [P, F] -> [P, B, H', W', F]

    ``method='group'`` folds the population into the channel axis and uses
    ``feature_group_count`` (the trick the paper reports as faster than
    vmap for convolutions); ``method='vmap'`` is the ablation baseline.
    """
    p, bsz, h, wd, c = x.shape
    _, kh, kw, _, f = w.shape
    if method == "group":
        # [P,B,H,W,C] -> [B,H,W,P*C]; filters [kh,kw,C,P*F]
        xt = x.transpose(1, 2, 3, 0, 4).reshape(bsz, h, wd, p * c)
        wt = w.transpose(1, 2, 3, 0, 4).reshape(kh, kw, c, p * f)
        y = jax.lax.conv_general_dilated(
            xt, wt, window_strides=strides, padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=p,
        )
        ho, wo = y.shape[1], y.shape[2]
        y = y.reshape(bsz, ho, wo, p, f).transpose(3, 0, 1, 2, 4)
    elif method == "vmap":
        def one(xi, wi):
            return jax.lax.conv_general_dilated(
                xi, wi, window_strides=strides, padding="VALID",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
        y = jax.vmap(one)(x, w)
    else:
        raise ValueError(f"unknown conv method {method!r}")
    return y + b[:, None, None, None, :]


def conv_out_hw(h: int, w: int, k: int, s: int) -> Tuple[int, int]:
    """VALID-conv output spatial dims."""
    return (h - k) // s + 1, (w - k) // s + 1


# ---------------------------------------------------------------------------
# Algorithm-specific heads
# ---------------------------------------------------------------------------


def actor_apply(params: Params, prefix: str, obs: jnp.ndarray) -> jnp.ndarray:
    """Deterministic tanh actor (TD3): obs [P,B,O] -> actions in [-1,1]."""
    return mlp_apply(params, prefix, obs, hidden_act="relu", final_act="tanh")


def gaussian_actor_apply(params: Params, prefix: str, obs: jnp.ndarray
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SAC squashed-Gaussian actor head: returns (mu, log_std)."""
    out = mlp_apply(params, prefix, obs, hidden_act="relu", final_act="none")
    mu, log_std = jnp.split(out, 2, axis=-1)
    log_std = jnp.clip(log_std, -20.0, 2.0)
    return mu, log_std


def critic_apply(params: Params, prefix: str, obs: jnp.ndarray,
                 act: jnp.ndarray) -> jnp.ndarray:
    """Q(s, a) critic: returns [P, B]."""
    x = jnp.concatenate([obs, act], axis=-1)
    q = mlp_apply(params, prefix, x, hidden_act="relu", final_act="none")
    return q[..., 0]


def dqn_apply(params: Params, prefix: str, obs: jnp.ndarray, *,
              conv_method: str = "group") -> jnp.ndarray:
    """MinAtar-scale DQN: conv(16,3x3) relu -> fc(128) relu -> fc(A).

    obs: [P, B, H, W, C] -> q-values [P, B, A].
    """
    h = pop_conv(obs, params[f"{prefix}/conv/w"], params[f"{prefix}/conv/b"],
                 method=conv_method)
    h = jnp.maximum(h, 0.0)
    p, bsz = h.shape[0], h.shape[1]
    h = h.reshape(p, bsz, -1)
    return mlp_apply(params, f"{prefix}/head", h,
                     hidden_act="relu", final_act="none")


def dqn_fields(prefix: str, pop: int, h: int, w: int, c: int, n_actions: int,
               group: str, conv_features: int = 16, fc: int = 128) -> List[Field]:
    ho, wo = h - 2, w - 2  # 3x3 valid conv
    flat = ho * wo * conv_features
    fields = conv_fields(f"{prefix}/conv", pop, c, conv_features, 3, group)
    fields += mlp_fields(f"{prefix}/head", pop, flat, [fc], n_actions, group)
    return fields


# Mnih et al. (2013/2015) Atari DQN architecture — used for the Fig 2 DQN
# rows at the paper's original 84x84x4 frame scale: conv(32,8x8,s4) relu,
# conv(64,4x4,s2) relu, conv(64,3x3,s1) relu, fc(512) relu, fc(A).
ATARI_CONVS: Tuple[Tuple[int, int, int], ...] = ((32, 8, 4), (64, 4, 2), (64, 3, 1))


def dqn_atari_apply(params: Params, prefix: str, obs: jnp.ndarray, *,
                    conv_method: str = "group") -> jnp.ndarray:
    """Full Atari DQN stack. obs: [P, B, 84, 84, 4] -> q [P, B, A]."""
    h = obs
    for li, (_, k, s) in enumerate(ATARI_CONVS):
        h = pop_conv(h, params[f"{prefix}/conv{li}/w"],
                     params[f"{prefix}/conv{li}/b"],
                     method=conv_method, strides=(s, s))
        h = jnp.maximum(h, 0.0)
    p, bsz = h.shape[0], h.shape[1]
    h = h.reshape(p, bsz, -1)
    return mlp_apply(params, f"{prefix}/head", h,
                     hidden_act="relu", final_act="none")


def dqn_atari_fields(prefix: str, pop: int, h: int, w: int, c: int,
                     n_actions: int, group: str, fc: int = 512) -> List[Field]:
    fields: List[Field] = []
    ch = c
    hh, ww = h, w
    for li, (feats, k, s) in enumerate(ATARI_CONVS):
        fields += conv_fields(f"{prefix}/conv{li}", pop, ch, feats, k, group)
        hh, ww = conv_out_hw(hh, ww, k, s)
        ch = feats
    flat = hh * ww * ch
    fields += mlp_fields(f"{prefix}/head", pop, flat, [fc], n_actions, group)
    return fields
