"""Shared-critic population TD3 — the CEM-RL update of §4.2 / Fig 4.

CEM-RL (Pourchot & Sigaud, 2019) shares one twin critic across the whole
population while each member owns its policy. The original ("seq")
ordering intertwines critic updates between sequential per-agent policy
updates, which forbids vectorization over the population. The paper's
second-order modification ("vec") keeps the same number of critic updates
but pushes each batch through *all* policy networks in parallel and
averages the critic loss over the population, after which all policy
updates happen in one vectorized shot.

One lowered "round" performs, for population size P:
  seq: for i in 0..P: critic step (batch_i, target-policy_i); policy_i step
  vec: for i in 0..P: critic step (batch_i, loss averaged over all target
       policies); then one parallel policy step for all P members
so both variants do P critic updates and P policy updates per round on the
same data budget — Fig 4 times one round.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .. import networks, optim
from ..layout import Field, Layout
from . import common

TAU = 0.005
NOISE_CLIP = 0.5
HIDDEN = (256, 256)


def build_layout(pop: int, obs_dim: int, act_dim: int, hidden=HIDDEN,
                 with_dvd: bool = False) -> Layout:
    fields: List[Field] = []
    fields += networks.mlp_fields("policy", pop, obs_dim, hidden, act_dim,
                                  "policy", final_uniform=3e-3)
    fields += networks.mlp_fields("policy_t", pop, obs_dim, hidden, act_dim,
                                  "policy_target", final_uniform=3e-3)
    for q in ("q1", "q2"):
        fields += networks.mlp_fields(q, 1, obs_dim + act_dim, hidden, 1,
                                      "critic", final_uniform=3e-3)
        fields += networks.mlp_fields(f"{q}_t", 1, obs_dim + act_dim, hidden, 1,
                                      "critic_target", final_uniform=3e-3)
    # the shared critic's leading axis is 1, not the population axis
    fields = [_shared(f) if f.group in ("critic", "critic_target") else f
              for f in fields]
    fields += optim.adam_fields("adam_policy",
                                [f for f in fields if f.group == "policy"])
    fields += optim.adam_fields("adam_critic",
                                [f for f in fields if f.group == "critic"])
    fields += [
        common.hyper_field("lr_policy", pop, 3e-4),
        Field("lr_critic", (1,), "f32", "const:3e-4", "hyper", False),
        Field("gamma", (1,), "f32", "const:0.99", "hyper", False),
        Field("noise", (1,), "f32", "const:0.2", "hyper", False),
        common.hyper_field("expl_noise", pop, 0.1),
        Field("rng", (pop, 2), "u32", "key", "rng"),
        Field("step", (pop,), "u32", "step", "step"),
        Field("cstep", (1,), "u32", "step", "step", False),
        Field("critic_loss", (1,), "f32", "zeros", "metric", False),
        common.metric_field("policy_loss", pop),
        Field("q_mean", (1,), "f32", "zeros", "metric", False),
    ]
    if with_dvd:
        fields += [
            Field("lambda_div", (1,), "f32", "const:0.1", "hyper", False),
            Field("div_kernel_len", (1,), "f32", "const:1.0", "hyper", False),
            Field("div_loss", (1,), "f32", "zeros", "metric", False),
        ]
    return Layout(fields)


def _shared(f: Field) -> Field:
    return Field(f.name, f.shape, f.dtype, f.init, f.group, per_agent=False)


def sync_targets_numpy(layout: Layout, flat) -> None:
    for f in layout.fields:
        if f.group in ("policy_target", "critic_target"):
            src = f.name.replace("_t/", "/", 1)
            so, fo = layout.offsets[src], layout.offsets[f.name]
            flat[fo:fo + f.size] = flat[so:so + f.size]


def _critic_q(critic: Dict[str, jnp.ndarray], prefix: str, obs, act):
    """Shared critic on population-shaped inputs: [P,B,·] -> [P,B]."""
    p, b = obs.shape[0], obs.shape[1]
    x = jnp.concatenate([obs, act], axis=-1).reshape(1, p * b, -1)
    q = networks.mlp_apply(critic, prefix, x, hidden_act="relu",
                           final_act="none")
    return q[0, :, 0].reshape(p, b)


def _logdet_psd(k):
    """log-det of a small PSD matrix via hand-rolled Cholesky.

    ``jnp.linalg.slogdet`` lowers to LAPACK typed-FFI custom-calls that
    xla_extension 0.5.1 (the rust runtime) rejects; an unrolled Cholesky
    over the (small, static) population size lowers to plain HLO and is
    differentiable by jax autodiff.
    """
    n = k.shape[0]
    l = jnp.zeros_like(k)
    logdet = jnp.zeros(())
    for i in range(n):
        s = k[i, i] - jnp.sum(l[i, :i] ** 2)
        lii = jnp.sqrt(jnp.maximum(s, 1e-10))
        logdet = logdet + 2.0 * jnp.log(lii)
        l = l.at[i, i].set(lii)
        if i + 1 < n:
            col = (k[i + 1:, i] - l[i + 1:, :i] @ l[i, :i]) / lii
            l = l.at[i + 1:, i].set(col)
    return logdet


def _sub(s, prefix):
    return {k[len(prefix):]: v for k, v in s.items() if k.startswith(prefix)}


def _rekey_sub(params, old, new):
    return {k.replace(f"{old}/", f"{new}/", 1): v for k, v in params.items()
            if k.startswith(f"{old}/")}


def make_update(pop: int, obs_dim: int, act_dim: int, batch: int,
                ordering: str = "vec", num_steps: int = 1, hidden=HIDDEN,
                dvd: bool = False, dvd_probes: int = 20):
    """Returns (layout, update_fn, batch_args).

    ordering: 'vec' (paper's modification, vectorizable) or 'seq'
    (original CEM-RL interleaving — the Fig 4 baseline).
    dvd: add the DvD (Parker-Holder et al., 2020) log-det diversity bonus
    to the vectorized policy update.
    """
    if ordering not in ("vec", "seq"):
        raise ValueError(f"ordering must be vec|seq, got {ordering!r}")
    if dvd and ordering != "vec":
        raise ValueError("DvD requires the vectorized ordering")
    layout = build_layout(pop, obs_dim, act_dim, hidden, with_dvd=dvd)
    batch_args = common.transition_batch_args(pop, batch, obs_dim, act_dim)

    def critic_step(critic, m_c, v_c, cstep, critic_t, policy_t, lr_c, gamma,
                    noise_sigma, key, obs_i, act_i, rew_i, next_obs_i, done_i,
                    avg_over_pop: bool):
        """One shared-critic Adam step from one batch.

        avg_over_pop=False: targets from ONE policy (inputs already [1,B,·]).
        avg_over_pop=True:  batch tiled over all P target policies, loss
        averaged over the population (the §4.2 modification).
        """
        p_eff = policy_t["policy_t/w0"].shape[0] if avg_over_pop else 1
        nobs = jnp.broadcast_to(next_obs_i, (p_eff,) + next_obs_i.shape[1:]) \
            if avg_over_pop else next_obs_i
        noise = jax.random.normal(key, (p_eff,) + (batch, act_dim)) * noise_sigma
        noise = jnp.clip(noise, -NOISE_CLIP, NOISE_CLIP)
        next_a = networks.actor_apply(policy_t, "policy_t", nobs)
        next_a = jnp.clip(next_a + noise, -1.0, 1.0)
        q1_t = _critic_q(critic_t, "q1_t", nobs, next_a)
        q2_t = _critic_q(critic_t, "q2_t", nobs, next_a)
        target = rew_i + gamma * (1.0 - done_i) * jnp.minimum(q1_t, q2_t)
        target = jax.lax.stop_gradient(target)
        obs_b = jnp.broadcast_to(obs_i, (p_eff,) + obs_i.shape[1:]) \
            if avg_over_pop else obs_i
        act_b = jnp.broadcast_to(act_i, (p_eff,) + act_i.shape[1:]) \
            if avg_over_pop else act_i

        def loss_fn(cp):
            q1 = _critic_q(cp, "q1", obs_b, act_b)
            q2 = _critic_q(cp, "q2", obs_b, act_b)
            l = jnp.mean((q1 - target) ** 2 + (q2 - target) ** 2)
            return l, jnp.mean(q1)

        (loss, qm), grads = jax.value_and_grad(loss_fn, has_aux=True)(critic)
        critic, m_c, v_c = optim.adam_update(
            critic, grads, m_c, v_c, cstep, lr_c)
        critic_t = optim.polyak(
            critic_t,
            {**_rekey_sub(critic, "q1", "q1_t"),
             **_rekey_sub(critic, "q2", "q2_t")}, TAU)
        return critic, m_c, v_c, critic_t, loss, qm

    def policy_step_all(policy, m_p, v_p, step, critic, lr_p, obs,
                        lam=None, klen=None, probes=None):
        """Vectorized policy update for all P members (+ optional DvD)."""

        def loss_fn(pp):
            a = networks.actor_apply(pp, "policy", obs)
            q = _critic_q(critic, "q1", obs, a)
            per_agent = -jnp.mean(q, axis=1)
            total = jnp.sum(per_agent)
            dloss = jnp.zeros(())
            if lam is not None:
                # DvD: embed each member by its actions on shared probe
                # states; maximize log-det of the RBF kernel matrix.
                pa = networks.actor_apply(pp, "policy", probes)  # [P,M,A]
                e = pa.reshape(pa.shape[0], -1)
                d2 = jnp.sum((e[:, None, :] - e[None, :, :]) ** 2, axis=-1)
                k = jnp.exp(-d2 / (2.0 * klen ** 2))
                k = k + 1e-4 * jnp.eye(k.shape[0])
                dloss = -_logdet_psd(k)
                total = total + lam * dloss
            return total, (per_agent, dloss)

        (_, (ploss, dloss)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(policy)
        policy, m_p, v_p = optim.adam_update(policy, grads, m_p, v_p, step,
                                             lr_p)
        return policy, m_p, v_p, ploss, dloss

    def single_step(state, xs):
        obs, act, rew, next_obs, done = xs
        s = layout.unpack(state)
        policy = layout.group(s, "policy")
        policy_t = layout.group(s, "policy_target")
        critic = layout.group(s, "critic")
        critic_t = layout.group(s, "critic_target")
        m_p, v_p = _sub(s, "adam_policy/m/"), _sub(s, "adam_policy/v/")
        m_c, v_c = _sub(s, "adam_critic/m/"), _sub(s, "adam_critic/v/")
        gamma = s["gamma"][0]
        noise_sigma = s["noise"][0]
        lr_c = s["lr_critic"]
        rng, k_crit = common.split_keys(s["rng"], 2)

        if ordering == "vec":
            # P critic sub-steps, each averaging the loss over the whole
            # population of target policies (scan keeps the artifact small).
            def body(carry, xs_i):
                critic, m_c, v_c, critic_t, cstep, closs, qm = carry
                obs_i, act_i, rew_i, next_obs_i, done_i, key_i = xs_i
                critic, m_c, v_c, critic_t, l, q = critic_step(
                    critic, m_c, v_c, cstep, critic_t, policy_t, lr_c, gamma,
                    noise_sigma, key_i, obs_i[None], act_i[None], rew_i[None],
                    next_obs_i[None], done_i[None], avg_over_pop=True)
                return (critic, m_c, v_c, critic_t, cstep + 1,
                        closs + l, qm + q), ()

            keys = jax.vmap(lambda k: jax.random.fold_in(k, 7))(k_crit)
            (critic, m_c, v_c, critic_t, cstep, closs, qm), _ = jax.lax.scan(
                body,
                (critic, m_c, v_c, critic_t, s["cstep"], jnp.zeros(()),
                 jnp.zeros(())),
                (obs, act, rew, next_obs, done, keys), length=pop)
            closs, qm = closs / pop, qm / pop

            probes = lam = klen = None
            if dvd:
                probes = jnp.broadcast_to(obs[0, :dvd_probes],
                                          (pop, dvd_probes, obs_dim))
                lam = s["lambda_div"][0]
                klen = s["div_kernel_len"][0]
            policy, m_p, v_p, ploss, dloss = policy_step_all(
                policy, m_p, v_p, s["step"], critic, s["lr_policy"], obs,
                lam=lam, klen=klen, probes=probes)
            policy_t = optim.polyak(
                policy_t, _rekey_sub(policy, "policy", "policy_t"), TAU)
            new_step = s["step"] + 1
        else:
            # Original CEM-RL interleaving: agent i's critic update uses
            # agent i's target policy only, then agent i's policy updates.
            # The row-slicing data dependence is what blocks vectorization.
            def body(carry, xs_i):
                (critic, m_c, v_c, critic_t, cstep, policy, m_p, v_p,
                 policy_t, closs, qm, ploss) = carry
                obs_i, act_i, rew_i, next_obs_i, done_i, key_i, i = xs_i
                pt_i = {k: jax.lax.dynamic_slice_in_dim(v, i, 1, 0)
                        for k, v in policy_t.items()}
                critic, m_c, v_c, critic_t, l, q = critic_step(
                    critic, m_c, v_c, cstep, critic_t, pt_i, lr_c, gamma,
                    noise_sigma, key_i, obs_i[None], act_i[None], rew_i[None],
                    next_obs_i[None], done_i[None], avg_over_pop=False)

                p_i = {k: jax.lax.dynamic_slice_in_dim(v, i, 1, 0)
                       for k, v in policy.items()}
                mp_i = {k: jax.lax.dynamic_slice_in_dim(v, i, 1, 0)
                        for k, v in m_p.items()}
                vp_i = {k: jax.lax.dynamic_slice_in_dim(v, i, 1, 0)
                        for k, v in v_p.items()}
                step_i = jax.lax.dynamic_slice_in_dim(s["step"], i, 1, 0)
                lr_i = jax.lax.dynamic_slice_in_dim(s["lr_policy"], i, 1, 0)
                p_i, mp_i, vp_i, pl, _ = policy_step_all(
                    p_i, mp_i, vp_i, step_i, critic, lr_i, obs_i[None])
                policy = {k: jax.lax.dynamic_update_slice_in_dim(
                    policy[k], p_i[k], i, 0) for k in policy}
                m_p = {k: jax.lax.dynamic_update_slice_in_dim(
                    m_p[k], mp_i[k], i, 0) for k in m_p}
                v_p = {k: jax.lax.dynamic_update_slice_in_dim(
                    v_p[k], vp_i[k], i, 0) for k in v_p}
                pt_new = optim.polyak(pt_i, _rekey_sub(p_i, "policy",
                                                       "policy_t"), TAU)
                policy_t = {k: jax.lax.dynamic_update_slice_in_dim(
                    policy_t[k], pt_new[k], i, 0) for k in policy_t}
                ploss = jax.lax.dynamic_update_slice_in_dim(
                    ploss, pl, i, 0)
                return (critic, m_c, v_c, critic_t, cstep + 1, policy, m_p,
                        v_p, policy_t, closs + l, qm + q, ploss), ()

            keys = jax.vmap(lambda k: jax.random.fold_in(k, 7))(k_crit)
            init = (critic, m_c, v_c, critic_t, s["cstep"], policy, m_p, v_p,
                    policy_t, jnp.zeros(()), jnp.zeros(()),
                    jnp.zeros((pop,), jnp.float32))
            (critic, m_c, v_c, critic_t, cstep, policy, m_p, v_p, policy_t,
             closs, qm, ploss), _ = jax.lax.scan(
                body, init,
                (obs, act, rew, next_obs, done, keys,
                 jnp.arange(pop, dtype=jnp.int32)), length=pop)
            closs, qm = closs / pop, qm / pop
            dloss = jnp.zeros(())
            new_step = s["step"] + 1

        out = dict(s)
        out.update(policy)
        out.update(policy_t)
        out.update(critic)
        out.update(critic_t)
        for k, val in m_p.items():
            out[f"adam_policy/m/{k}"] = val
        for k, val in v_p.items():
            out[f"adam_policy/v/{k}"] = val
        for k, val in m_c.items():
            out[f"adam_critic/m/{k}"] = val
        for k, val in v_c.items():
            out[f"adam_critic/v/{k}"] = val
        out["rng"] = rng
        out["step"] = new_step
        out["cstep"] = cstep
        out["critic_loss"] = closs[None]
        out["policy_loss"] = ploss
        out["q_mean"] = qm[None]
        if dvd:
            out["div_loss"] = dloss[None]
        return layout.pack(out)

    def update(state, *batches):
        return common.scan_steps(single_step, num_steps, state, batches)

    return layout, update, batch_args
