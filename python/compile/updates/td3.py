"""Population-vectorized TD3 update step (Fujimoto et al., 2018).

One lowered call updates all N members of the population: twin critics with
clipped double-Q targets and target-policy smoothing, delayed policy and
target updates, per-agent Adam with per-agent (PBT-tunable) hyperparameters.

Hyperparameters exposed to PBT match Appendix B.1 of the paper:
lr_policy, lr_critic, policy_freq (update frequency w.r.t. the critic),
noise (target policy smoothing sigma), and gamma. ``expl_noise`` is carried
in the state for the actors (L3) but unused by the update itself.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .. import networks, optim
from ..layout import Field, Layout
from . import common

TAU = 0.005
NOISE_CLIP = 0.5
HIDDEN = (256, 256)


def build_layout(pop: int, obs_dim: int, act_dim: int,
                 hidden=HIDDEN) -> Layout:
    fields: List[Field] = []
    fields += networks.mlp_fields("policy", pop, obs_dim, hidden, act_dim,
                                  "policy", final_uniform=3e-3)
    fields += networks.mlp_fields("policy_t", pop, obs_dim, hidden, act_dim,
                                  "policy_target", final_uniform=3e-3)
    for q in ("q1", "q2"):
        fields += networks.mlp_fields(q, pop, obs_dim + act_dim, hidden, 1,
                                      "critic", final_uniform=3e-3)
        fields += networks.mlp_fields(f"{q}_t", pop, obs_dim + act_dim, hidden, 1,
                                      "critic_target", final_uniform=3e-3)
    fields += optim.adam_fields("adam_policy", [f for f in fields if f.group == "policy"])
    fields += optim.adam_fields("adam_critic", [f for f in fields if f.group == "critic"])
    fields += [
        common.hyper_field("lr_policy", pop, 3e-4),
        common.hyper_field("lr_critic", pop, 3e-4),
        common.hyper_field("gamma", pop, 0.99),
        common.hyper_field("noise", pop, 0.2),
        common.hyper_field("policy_freq", pop, 0.5),
        common.hyper_field("expl_noise", pop, 0.1),
        Field("rng", (pop, 2), "u32", "key", "rng"),
        Field("step", (pop,), "u32", "step", "step"),
        common.metric_field("critic_loss", pop),
        common.metric_field("policy_loss", pop),
        common.metric_field("q_mean", pop),
    ]
    return Layout(fields)


def _target_sync(layout: Layout, s: Dict[str, jnp.ndarray]) -> None:
    """Start targets equal to their online nets (applied at init by L3).

    Target fields get their own random init in the layout; the Rust runtime
    copies online -> target after init using the manifest groups. Python
    tests use `sync_targets_numpy`.
    """


def sync_targets_numpy(layout: Layout, flat) -> None:
    """In-place online->target copy on a numpy flat state (test helper)."""
    import numpy as np

    for f in layout.fields:
        if f.group in ("policy_target", "critic_target"):
            src = f.name.replace("_t/", "/", 1)
            so, fo = layout.offsets[src], layout.offsets[f.name]
            flat[fo:fo + f.size] = flat[so:so + f.size]


def make_update(pop: int, obs_dim: int, act_dim: int, batch: int,
                num_steps: int = 1, hidden=HIDDEN):
    """Returns (layout, update_fn, batch_args)."""
    layout = build_layout(pop, obs_dim, act_dim, hidden)
    batch_args = common.transition_batch_args(pop, batch, obs_dim, act_dim)

    def single_step(state, xs):
        obs, act, rew, next_obs, done = xs
        s = layout.unpack(state)
        policy = layout.group(s, "policy")
        policy_t = layout.group(s, "policy_target")
        critic = layout.group(s, "critic")
        critic_t = layout.group(s, "critic_target")
        step = s["step"]
        rng, k_noise = common.split_keys(s["rng"], 2)

        # ---- critic update (every step) ------------------------------
        noise = common.pop_normal(k_noise, (batch, act_dim))
        noise = jnp.clip(noise * s["noise"][:, None, None],
                         -NOISE_CLIP, NOISE_CLIP)
        next_a = networks.actor_apply(policy_t, "policy_t", next_obs)
        next_a = jnp.clip(next_a + noise, -1.0, 1.0)
        q1_t = networks.critic_apply(critic_t, "q1_t", next_obs, next_a)
        q2_t = networks.critic_apply(critic_t, "q2_t", next_obs, next_a)
        target = rew + s["gamma"][:, None] * (1.0 - done) * jnp.minimum(q1_t, q2_t)
        target = jax.lax.stop_gradient(target)

        def critic_loss_fn(cp):
            q1 = networks.critic_apply(cp, "q1", obs, act)
            q2 = networks.critic_apply(cp, "q2", obs, act)
            per_agent = jnp.mean((q1 - target) ** 2 + (q2 - target) ** 2, axis=1)
            # sum over agents: gradients stay per-agent independent
            return jnp.sum(per_agent), (per_agent, jnp.mean(q1, axis=1))

        (_, (closs, qmean)), cgrads = jax.value_and_grad(
            critic_loss_fn, has_aux=True)(critic)
        m_c = {k[len("adam_critic/m/"):]: v for k, v in s.items()
               if k.startswith("adam_critic/m/")}
        v_c = {k[len("adam_critic/v/"):]: v for k, v in s.items()
               if k.startswith("adam_critic/v/")}
        critic, m_c, v_c = optim.adam_update(
            critic, cgrads, m_c, v_c, step, s["lr_critic"])

        # ---- delayed policy + target updates -------------------------
        mask = common.delayed_mask(step, s["policy_freq"])

        def policy_loss_fn(pp):
            a = networks.actor_apply(pp, "policy", obs)
            q = networks.critic_apply(critic, "q1", obs, a)
            per_agent = -jnp.mean(q, axis=1)
            return jnp.sum(per_agent), per_agent

        (_, ploss), pgrads = jax.value_and_grad(
            policy_loss_fn, has_aux=True)(policy)
        m_p = {k[len("adam_policy/m/"):]: v for k, v in s.items()
               if k.startswith("adam_policy/m/")}
        v_p = {k[len("adam_policy/v/"):]: v for k, v in s.items()
               if k.startswith("adam_policy/v/")}
        policy, m_p, v_p = optim.adam_update(
            policy, pgrads, m_p, v_p, step, s["lr_policy"], mask=mask)

        policy_t = optim.polyak(
            {k: policy_t[k] for k in policy_t}, _rekey(policy, "policy", "policy_t"),
            TAU, mask=mask)
        critic_t = optim.polyak(
            {k: critic_t[k] for k in critic_t},
            {**_rekey_sub(critic, "q1", "q1_t"), **_rekey_sub(critic, "q2", "q2_t")},
            TAU, mask=mask)

        out = dict(s)
        out.update(policy)
        out.update(policy_t)
        out.update(critic)
        out.update(critic_t)
        for k, v in m_p.items():
            out[f"adam_policy/m/{k}"] = v
        for k, v in v_p.items():
            out[f"adam_policy/v/{k}"] = v
        for k, v in m_c.items():
            out[f"adam_critic/m/{k}"] = v
        for k, v in v_c.items():
            out[f"adam_critic/v/{k}"] = v
        out["rng"] = rng
        out["step"] = step + 1
        out["critic_loss"] = closs
        out["policy_loss"] = ploss
        out["q_mean"] = qmean
        return layout.pack(out)

    def update(state, *batches):
        return common.scan_steps(single_step, num_steps, state, batches)

    return layout, update, batch_args


def _rekey(params: Dict[str, jnp.ndarray], old: str, new: str):
    return {k.replace(f"{old}/", f"{new}/", 1): v for k, v in params.items()}


def _rekey_sub(params: Dict[str, jnp.ndarray], old: str, new: str):
    return {k.replace(f"{old}/", f"{new}/", 1): v for k, v in params.items()
            if k.startswith(f"{old}/")}


def make_policy_forward(pop: int, obs_dim: int, act_dim: int, batch: int,
                        hidden=HIDDEN):
    """Deterministic actor forward over the flat state (rust-nn parity)."""
    layout = build_layout(pop, obs_dim, act_dim, hidden)

    def forward(state, obs):
        s = layout.unpack(state)
        return networks.actor_apply(layout.group(s, "policy"), "policy", obs)

    return layout, forward, [common.BatchArg("obs", (pop, batch, obs_dim))]
