"""Population-vectorized DQN update step (Mnih et al., 2013).

MinAtar-scale conv net (see DESIGN.md substitutions: one CPU core cannot
drive 84x84x4 Atari frames, so the pixel pipeline is reproduced at 10x10x4
with the same conv->fc architecture). Periodic hard target-network copies
are realized with a per-agent step-mask so the whole population stays
vectorized.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from .. import networks, optim
from ..layout import Field, Layout
from . import common

TARGET_PERIOD = 200


def _arch_for(h: int) -> str:
    """MinAtar-scale net for small frames; the full Mnih stack at 84x84."""
    return "atari" if h >= 84 else "minatar"


def _fields(prefix, pop, h, w, c, n_actions, group, arch):
    if arch == "atari":
        return networks.dqn_atari_fields(prefix, pop, h, w, c, n_actions, group)
    return networks.dqn_fields(prefix, pop, h, w, c, n_actions, group)


def _apply(params, prefix, obs, conv_method, arch):
    if arch == "atari":
        return networks.dqn_atari_apply(params, prefix, obs,
                                        conv_method=conv_method)
    return networks.dqn_apply(params, prefix, obs, conv_method=conv_method)


def build_layout(pop: int, h: int, w: int, c: int, n_actions: int) -> Layout:
    arch = _arch_for(h)
    fields: List[Field] = []
    fields += _fields("q", pop, h, w, c, n_actions, "critic", arch)
    fields += _fields("q_t", pop, h, w, c, n_actions, "critic_target", arch)
    fields += optim.adam_fields("adam", [f for f in fields if f.group == "critic"])
    fields += [
        common.hyper_field("lr", pop, 1e-4),
        common.hyper_field("gamma", pop, 0.99),
        common.hyper_field("eps_greedy", pop, 0.05),
        Field("rng", (pop, 2), "u32", "key", "rng"),
        Field("step", (pop,), "u32", "step", "step"),
        common.metric_field("loss", pop),
        common.metric_field("q_mean", pop),
    ]
    return Layout(fields)


def sync_targets_numpy(layout: Layout, flat) -> None:
    for f in layout.fields:
        if f.group == "critic_target":
            src = f.name.replace("q_t/", "q/", 1)
            so, fo = layout.offsets[src], layout.offsets[f.name]
            flat[fo:fo + f.size] = flat[so:so + f.size]


def batch_args(pop: int, batch: int, h: int, w: int, c: int) -> List[common.BatchArg]:
    return [
        common.BatchArg("obs", (pop, batch, h, w, c)),
        common.BatchArg("act", (pop, batch), "i32"),
        common.BatchArg("rew", (pop, batch)),
        common.BatchArg("next_obs", (pop, batch, h, w, c)),
        common.BatchArg("done", (pop, batch)),
    ]


def make_update(pop: int, h: int, w: int, c: int, n_actions: int, batch: int,
                num_steps: int = 1, conv_method: str = "group",
                target_period: int = TARGET_PERIOD):
    layout = build_layout(pop, h, w, c, n_actions)
    bargs = batch_args(pop, batch, h, w, c)
    arch = _arch_for(h)

    def single_step(state, xs):
        obs, act, rew, next_obs, done = xs
        s = layout.unpack(state)
        q_params = layout.group(s, "critic")
        qt_params = layout.group(s, "critic_target")
        step = s["step"]

        q_next = _apply(qt_params, "q_t", next_obs, conv_method, arch)
        target = rew + s["gamma"][:, None] * (1.0 - done) * jnp.max(q_next, axis=-1)
        target = jax.lax.stop_gradient(target)

        def loss_fn(qp):
            q_all = _apply(qp, "q", obs, conv_method, arch)
            onehot = jax.nn.one_hot(act, n_actions, dtype=q_all.dtype)
            q_sel = jnp.sum(q_all * onehot, axis=-1)
            td = q_sel - target
            # Huber (the DQN error-clipping trick)
            huber = jnp.where(jnp.abs(td) <= 1.0, 0.5 * td ** 2,
                              jnp.abs(td) - 0.5)
            per_agent = jnp.mean(huber, axis=1)
            return jnp.sum(per_agent), (per_agent, jnp.mean(q_sel, axis=1))

        (_, (loss, qmean)), grads = jax.value_and_grad(loss_fn, has_aux=True)(q_params)
        m = {k[len("adam/m/"):]: v for k, v in s.items() if k.startswith("adam/m/")}
        v = {k[len("adam/v/"):]: v for k, v in s.items() if k.startswith("adam/v/")}
        q_params, m, v = optim.adam_update(q_params, grads, m, v, step, s["lr"])

        # periodic hard target copy (per-agent mask keeps it vectorized)
        copy = ((step + 1) % target_period == 0).astype(jnp.float32)
        new_t = {}
        for k, tv in qt_params.items():
            ok = k.replace("q_t/", "q/", 1)
            cb = copy.reshape((pop,) + (1,) * (tv.ndim - 1))
            new_t[k] = cb * q_params[ok] + (1.0 - cb) * tv

        out = dict(s)
        out.update(q_params)
        out.update(new_t)
        for k, val in m.items():
            out[f"adam/m/{k}"] = val
        for k, val in v.items():
            out[f"adam/v/{k}"] = val
        out["step"] = step + 1
        out["loss"] = loss
        out["q_mean"] = qmean
        return layout.pack(out)

    def update(state, *batches):
        return common.scan_steps(single_step, num_steps, state, batches)

    return layout, update, bargs


def make_q_forward(pop: int, h: int, w: int, c: int, n_actions: int,
                   batch: int, conv_method: str = "group"):
    """Greedy-action Q forward (rust-nn conv parity)."""
    layout = build_layout(pop, h, w, c, n_actions)

    arch = _arch_for(h)

    def forward(state, obs):
        s = layout.unpack(state)
        return _apply(layout.group(s, "critic"), "q", obs, conv_method, arch)

    return layout, forward, [common.BatchArg("obs", (pop, batch, h, w, c))]
