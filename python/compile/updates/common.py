"""Shared plumbing for the population update-step functions."""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..layout import Field, Layout


@dataclasses.dataclass(frozen=True)
class BatchArg:
    """One batch input of the lowered update function."""
    name: str
    shape: Tuple[int, ...]  # per-step shape, WITHOUT the num_steps axis
    dtype: str = "f32"      # f32 | i32

    def jnp_dtype(self):
        return {"f32": jnp.float32, "i32": jnp.int32}[self.dtype]


@dataclasses.dataclass(frozen=True)
class EnvSpec:
    """Tensor-shape description of an environment family member."""
    name: str
    obs_dim: int = 0
    act_dim: int = 0
    # pixel-env extras (DQN)
    frame: Tuple[int, int, int] = (0, 0, 0)  # H, W, C
    n_actions: int = 0


def split_keys(keys: jnp.ndarray, n: int) -> List[jnp.ndarray]:
    """Split per-agent threefry keys [P, 2] u32 into n fresh key sets."""
    splits = jax.vmap(lambda k: jax.random.split(k, n))(keys)  # [P, n, 2]
    return [splits[:, i, :] for i in range(n)]


def pop_normal(keys: jnp.ndarray, shape: Tuple[int, ...]) -> jnp.ndarray:
    """Per-agent standard normals: keys [P,2] -> [P, *shape]."""
    return jax.vmap(lambda k: jax.random.normal(k, shape))(keys)


def pop_uniform(keys: jnp.ndarray, shape: Tuple[int, ...]) -> jnp.ndarray:
    return jax.vmap(lambda k: jax.random.uniform(k, shape))(keys)


def delayed_mask(step: jnp.ndarray, freq: jnp.ndarray) -> jnp.ndarray:
    """Per-agent {0,1} mask realizing an average update rate ``freq``.

    ``floor((t+1)*f) > floor(t*f)`` fires exactly round(T*f) times in T
    steps, deterministically — the PBT-tunable analogue of TD3's
    policy_delay (freq = 1/delay).
    """
    t = step.astype(jnp.float32)
    f = jnp.clip(freq, 1e-6, 1.0)
    return (jnp.floor((t + 1.0) * f) > jnp.floor(t * f)).astype(jnp.float32)


def scan_steps(
    single_step: Callable[[jnp.ndarray, Tuple[jnp.ndarray, ...]], jnp.ndarray],
    num_steps: int,
    state: jnp.ndarray,
    batches: Sequence[jnp.ndarray],
) -> jnp.ndarray:
    """Chain ``num_steps`` update steps inside one lowered computation.

    ``batches`` carry a leading ``num_steps`` axis when num_steps > 1; the
    whole chain compiles to a single ``lax.scan`` so the paper's
    "num_steps=50 in one execution call" trick is one artifact.
    """
    if num_steps == 1:
        return single_step(state, tuple(batches))

    def body(carry, xs):
        return single_step(carry, xs), ()

    out, _ = jax.lax.scan(body, state, tuple(batches), length=num_steps)
    return out


def transition_batch_args(pop: int, batch: int, obs_dim: int, act_dim: int
                          ) -> List[BatchArg]:
    """The (s, a, r, s', d) batch of the continuous-control algorithms."""
    return [
        BatchArg("obs", (pop, batch, obs_dim)),
        BatchArg("act", (pop, batch, act_dim)),
        BatchArg("rew", (pop, batch)),
        BatchArg("next_obs", (pop, batch, obs_dim)),
        BatchArg("done", (pop, batch)),
    ]


def hyper_field(name: str, pop: int, default: float) -> Field:
    return Field(name, (pop,), "f32", f"const:{default}", "hyper")


def metric_field(name: str, pop: int) -> Field:
    return Field(name, (pop,), "f32", "zeros", "metric")
