"""Population-vectorized SAC update step (Haarnoja et al., 2018).

Squashed-Gaussian actor, twin critics, learned temperature (one per
population member). Hyperparameters exposed to PBT match Appendix B.1:
lr_policy, lr_critic, lr_alpha, target_entropy (as a multiplier of the
default -|A|), reward_scale, gamma.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from .. import networks, optim
from ..layout import Field, Layout
from . import common

TAU = 0.005
HIDDEN = (256, 256)
LOG_EPS = 1e-6


def build_layout(pop: int, obs_dim: int, act_dim: int, hidden=HIDDEN) -> Layout:
    fields: List[Field] = []
    fields += networks.mlp_fields("policy", pop, obs_dim, hidden, 2 * act_dim,
                                  "policy", final_uniform=3e-3)
    for q in ("q1", "q2"):
        fields += networks.mlp_fields(q, pop, obs_dim + act_dim, hidden, 1,
                                      "critic", final_uniform=3e-3)
        fields += networks.mlp_fields(f"{q}_t", pop, obs_dim + act_dim, hidden, 1,
                                      "critic_target", final_uniform=3e-3)
    fields.append(Field("log_alpha", (pop,), "f32", "zeros", "alpha"))
    fields += optim.adam_fields("adam_policy", [f for f in fields if f.group == "policy"])
    fields += optim.adam_fields("adam_critic", [f for f in fields if f.group == "critic"])
    fields += optim.adam_fields("adam_alpha", [f for f in fields if f.group == "alpha"])
    fields += [
        common.hyper_field("lr_policy", pop, 3e-4),
        common.hyper_field("lr_critic", pop, 3e-4),
        common.hyper_field("lr_alpha", pop, 3e-4),
        common.hyper_field("target_entropy_mult", pop, 1.0),
        common.hyper_field("reward_scale", pop, 1.0),
        common.hyper_field("gamma", pop, 0.99),
        Field("rng", (pop, 2), "u32", "key", "rng"),
        Field("step", (pop,), "u32", "step", "step"),
        common.metric_field("critic_loss", pop),
        common.metric_field("policy_loss", pop),
        common.metric_field("alpha", pop),
        common.metric_field("entropy", pop),
    ]
    return Layout(fields)


def sync_targets_numpy(layout: Layout, flat) -> None:
    for f in layout.fields:
        if f.group == "critic_target":
            src = f.name.replace("_t/", "/", 1)
            so, fo = layout.offsets[src], layout.offsets[f.name]
            flat[fo:fo + f.size] = flat[so:so + f.size]


def _sample(policy: Dict[str, jnp.ndarray], obs, keys, act_dim: int):
    """Reparameterized tanh-Gaussian sample + log-prob. -> (a, logp)."""
    mu, log_std = networks.gaussian_actor_apply(policy, "policy", obs)
    std = jnp.exp(log_std)
    eps = common.pop_normal(keys, (obs.shape[1], act_dim))
    pre = mu + std * eps
    a = jnp.tanh(pre)
    logp = -0.5 * (eps ** 2 + 2.0 * log_std + jnp.log(2.0 * jnp.pi))
    logp = jnp.sum(logp, axis=-1)
    logp -= jnp.sum(jnp.log(1.0 - a ** 2 + LOG_EPS), axis=-1)
    return a, logp


def make_update(pop: int, obs_dim: int, act_dim: int, batch: int,
                num_steps: int = 1, hidden=HIDDEN):
    layout = build_layout(pop, obs_dim, act_dim, hidden)
    batch_args = common.transition_batch_args(pop, batch, obs_dim, act_dim)
    default_target_entropy = -float(act_dim)

    def single_step(state, xs):
        obs, act, rew, next_obs, done = xs
        s = layout.unpack(state)
        policy = layout.group(s, "policy")
        critic = layout.group(s, "critic")
        critic_t = layout.group(s, "critic_target")
        step = s["step"]
        alpha = jnp.exp(s["log_alpha"])
        rng, k_next, k_pi = common.split_keys(s["rng"], 3)
        target_entropy = default_target_entropy * s["target_entropy_mult"]

        # ---- critic update -------------------------------------------
        next_a, next_logp = _sample(policy, next_obs, k_next, act_dim)
        q1_t = networks.critic_apply(critic_t, "q1_t", next_obs, next_a)
        q2_t = networks.critic_apply(critic_t, "q2_t", next_obs, next_a)
        soft_v = jnp.minimum(q1_t, q2_t) - alpha[:, None] * next_logp
        target = s["reward_scale"][:, None] * rew \
            + s["gamma"][:, None] * (1.0 - done) * soft_v
        target = jax.lax.stop_gradient(target)

        def critic_loss_fn(cp):
            q1 = networks.critic_apply(cp, "q1", obs, act)
            q2 = networks.critic_apply(cp, "q2", obs, act)
            per_agent = jnp.mean((q1 - target) ** 2 + (q2 - target) ** 2, axis=1)
            return jnp.sum(per_agent), per_agent

        (_, closs), cgrads = jax.value_and_grad(critic_loss_fn, has_aux=True)(critic)
        m_c = _sub(s, "adam_critic/m/")
        v_c = _sub(s, "adam_critic/v/")
        critic, m_c, v_c = optim.adam_update(critic, cgrads, m_c, v_c, step,
                                             s["lr_critic"])

        # ---- policy update -------------------------------------------
        def policy_loss_fn(pp):
            a, logp = _sample(pp, obs, k_pi, act_dim)
            q1 = networks.critic_apply(critic, "q1", obs, a)
            q2 = networks.critic_apply(critic, "q2", obs, a)
            q = jnp.minimum(q1, q2)
            per_agent = jnp.mean(alpha[:, None] * logp - q, axis=1)
            return jnp.sum(per_agent), (per_agent, jnp.mean(-logp, axis=1))

        (_, (ploss, entropy)), pgrads = jax.value_and_grad(
            policy_loss_fn, has_aux=True)(policy)
        m_p = _sub(s, "adam_policy/m/")
        v_p = _sub(s, "adam_policy/v/")
        policy, m_p, v_p = optim.adam_update(policy, pgrads, m_p, v_p, step,
                                             s["lr_policy"])

        # ---- temperature update --------------------------------------
        def alpha_loss_fn(la):
            # standard SAC temperature objective, entropy from policy sample
            return jnp.sum(-la["log_alpha"] * (jax.lax.stop_gradient(
                -entropy) + target_entropy))

        agrads = jax.grad(alpha_loss_fn)({"log_alpha": s["log_alpha"]})
        m_a = _sub(s, "adam_alpha/m/")
        v_a = _sub(s, "adam_alpha/v/")
        new_alpha, m_a, v_a = optim.adam_update(
            {"log_alpha": s["log_alpha"]}, agrads, m_a, v_a, step, s["lr_alpha"])

        critic_t = optim.polyak(
            critic_t,
            {**_rekey_sub(critic, "q1", "q1_t"), **_rekey_sub(critic, "q2", "q2_t")},
            TAU)

        out = dict(s)
        out.update(policy)
        out.update(critic)
        out.update(critic_t)
        out["log_alpha"] = new_alpha["log_alpha"]
        _write_sub(out, "adam_policy", m_p, v_p)
        _write_sub(out, "adam_critic", m_c, v_c)
        _write_sub(out, "adam_alpha", m_a, v_a)
        out["rng"] = rng
        out["step"] = step + 1
        out["critic_loss"] = closs
        out["policy_loss"] = ploss
        out["alpha"] = jnp.exp(new_alpha["log_alpha"])
        out["entropy"] = entropy
        return layout.pack(out)

    def update(state, *batches):
        return common.scan_steps(single_step, num_steps, state, batches)

    return layout, update, batch_args


def make_policy_forward(pop: int, obs_dim: int, act_dim: int, batch: int,
                        hidden=HIDDEN):
    """Deterministic (mean) actor forward for rust-nn parity tests."""
    layout = build_layout(pop, obs_dim, act_dim, hidden)

    def forward(state, obs):
        s = layout.unpack(state)
        mu, _ = networks.gaussian_actor_apply(layout.group(s, "policy"),
                                              "policy", obs)
        return jnp.tanh(mu)

    return layout, forward, [common.BatchArg("obs", (pop, batch, obs_dim))]


def _sub(s: Dict[str, jnp.ndarray], prefix: str) -> Dict[str, jnp.ndarray]:
    return {k[len(prefix):]: v for k, v in s.items() if k.startswith(prefix)}


def _write_sub(out: Dict[str, jnp.ndarray], prefix: str, m, v) -> None:
    for k, val in m.items():
        out[f"{prefix}/m/{k}"] = val
    for k, val in v.items():
        out[f"{prefix}/v/{k}"] = val


def _rekey_sub(params: Dict[str, jnp.ndarray], old: str, new: str):
    return {k.replace(f"{old}/", f"{new}/", 1): v for k, v in params.items()
            if k.startswith(f"{old}/")}
