"""Population-vectorized Adam (hand-rolled; optax is not in the image).

The twist over textbook Adam is that the learning rate is a *vector* over
the population axis — PBT tunes it per agent — and updates can be masked
per agent (TD3's delayed policy updates, DQN's periodic target copies).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from .layout import Field

Params = Dict[str, jnp.ndarray]

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def adam_fields(prefix: str, param_fields: List[Field]) -> List[Field]:
    """First/second-moment slots mirroring a set of parameter fields."""
    out: List[Field] = []
    for f in param_fields:
        out.append(Field(f"{prefix}/m/{f.name}", f.shape, "f32", "zeros", "opt",
                         f.per_agent))
        out.append(Field(f"{prefix}/v/{f.name}", f.shape, "f32", "zeros", "opt",
                         f.per_agent))
    return out


def _bc(vec: jnp.ndarray, like: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a per-agent vector [P] against a [P, ...] tensor."""
    return vec.reshape(vec.shape + (1,) * (like.ndim - vec.ndim))


def adam_update(
    params: Params,
    grads: Params,
    m: Params,
    v: Params,
    step: jnp.ndarray,       # u32 [P] (or scalar [1] for shared params)
    lr: jnp.ndarray,         # f32 [P] (or [1])
    mask: Optional[jnp.ndarray] = None,  # f32 [P] in {0,1}: apply update or not
    b1: float = ADAM_B1,
    b2: float = ADAM_B2,
    eps: float = ADAM_EPS,
) -> Tuple[Params, Params, Params]:
    """One (optionally masked) Adam step. Returns (params', m', v').

    Masked members keep params *and* moments unchanged, exactly as if the
    step had not happened for them — the step counter passed in must then
    also not advance for those members (callers handle that).
    """
    t = (step + 1).astype(jnp.float32)
    new_p: Params = {}
    new_m: Params = {}
    new_v: Params = {}
    for k, p in params.items():
        g = grads[k]
        mk = b1 * m[k] + (1.0 - b1) * g
        vk = b2 * v[k] + (1.0 - b2) * g * g
        tb = _bc(t, p)
        mhat = mk / (1.0 - b1 ** tb)
        vhat = vk / (1.0 - b2 ** tb)
        upd = _bc(lr, p) * mhat / (jnp.sqrt(vhat) + eps)
        if mask is not None:
            mb = _bc(mask, p)
            new_p[k] = p - mb * upd
            new_m[k] = mb * mk + (1.0 - mb) * m[k]
            new_v[k] = mb * vk + (1.0 - mb) * v[k]
        else:
            new_p[k] = p - upd
            new_m[k] = mk
            new_v[k] = vk
    return new_p, new_m, new_v


def polyak(target: Params, online: Params, tau: float,
           mask: Optional[jnp.ndarray] = None) -> Params:
    """Soft target update, optionally masked per agent."""
    out: Params = {}
    for k, tp in target.items():
        nt = (1.0 - tau) * tp + tau * online[k]
        if mask is not None:
            mb = _bc(mask, tp)
            nt = mb * nt + (1.0 - mb) * tp
        out[k] = nt
    return out
