//! Vendored **stub** of the `xla` PJRT bindings (xla-rs-compatible API
//! surface), so the crate graph resolves offline: the real bindings need
//! a registry pin plus a local `xla_extension` install that the CI/build
//! images do not ship. Every runtime entry point reports PJRT as
//! unavailable through the normal error path — `PjRtClient::cpu()` fails
//! cleanly, `Runtime::cpu()` surfaces the message, and the integration
//! tests (which already skip without artifacts) stay green — while the
//! type signatures match exactly the subset of the real crate the
//! coordinator uses (client/compile/upload/execute/download). Re-point
//! the root `Cargo.toml` `xla` dependency at a real xla-rs checkout to
//! execute the AOT-lowered artifacts.

use std::fmt;
use std::path::Path;

/// Message-only mirror of the real bindings' error type.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT is unavailable in this build (vendored xla stub; \
         point Cargo.toml at real xla bindings to execute artifacts)"
    ))
}

/// Element types the host-buffer APIs accept.
pub trait NativeType: Copy + Default {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u8 {}

/// PJRT client handle. The stub cannot construct one, so every
/// buffer/executable method below is statically unreachable at runtime.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "HloModuleProto::from_text_file({:?})",
            path.as_ref()
        )))
    }
}

pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }

    pub fn copy_raw_to_host_sync<T: NativeType>(&self, _dst: &mut [T], _offset: usize)
                                                -> Result<()> {
        Err(unavailable("PjRtBuffer::copy_raw_to_host_sync"))
    }
}

pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        Err(unavailable("Literal::get_first_element"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_fails_cleanly_with_stub_message() {
        let err = PjRtClient::cpu().err().expect("stub must not hand out clients");
        let msg = format!("{err}");
        assert!(msg.contains("PJRT is unavailable"), "{msg}");
        assert!(msg.contains("stub"), "{msg}");
    }

    #[test]
    fn hlo_text_load_fails_cleanly() {
        assert!(HloModuleProto::from_text_file("artifacts/nope.hlo.txt").is_err());
    }
}
