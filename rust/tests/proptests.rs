//! Property-based tests over coordinator invariants (hand-rolled
//! generators — proptest is not in the image; `fastpbrl`'s own RNG drives
//! hundreds of randomized cases per property).

use fastpbrl::coordinator::cem::Cem;
use fastpbrl::coordinator::hyperparams::{Dist, HyperSpec};
use fastpbrl::manifest::{Artifact, Dtype, EnvDesc, Field};
use fastpbrl::replay::{RatioGate, ReplayBuffer};
use fastpbrl::util::json::Json;
use fastpbrl::util::rng::Rng;
use fastpbrl::util::stats::{argsort_desc, percentile};

// ---------------------------------------------------------------------------
// generators
// ---------------------------------------------------------------------------

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => {
            // use values that round-trip exactly through the writer
            let v = (rng.below(2_000_001) as f64 - 1_000_000.0) / 64.0;
            Json::Num(v)
        }
        3 => {
            let n = rng.below(8);
            let s: String = (0..n)
                .map(|_| {
                    let c = rng.below(94) as u8 + 32;
                    c as char
                })
                .collect();
            Json::Str(s)
        }
        4 => {
            let n = rng.below(4);
            Json::Arr((0..n).map(|_| random_json(rng, depth - 1)).collect())
        }
        _ => {
            let n = rng.below(4);
            let mut m = std::collections::BTreeMap::new();
            for i in 0..n {
                m.insert(format!("k{i}"), random_json(rng, depth - 1));
            }
            Json::Obj(m)
        }
    }
}

fn random_layout(rng: &mut Rng, pop: usize) -> Artifact {
    let groups = ["policy", "critic", "opt", "hyper"];
    let n_fields = 2 + rng.below(6);
    let mut fields = Vec::new();
    let mut off = 0usize;
    for i in 0..n_fields {
        let rank = 1 + rng.below(3);
        let mut shape = vec![pop];
        for _ in 1..rank {
            shape.push(1 + rng.below(5));
        }
        let size: usize = shape.iter().product();
        fields.push(Field {
            name: format!("f{i}"),
            offset: off,
            size,
            shape,
            dtype: Dtype::F32,
            init: "zeros".into(),
            group: groups[rng.below(groups.len())].into(),
            per_agent: true,
        });
        off += size;
    }
    Artifact::new(
        "prop".into(),
        std::path::PathBuf::new(),
        "td3".into(),
        "pendulum".into(),
        EnvDesc::default(),
        pop,
        1,
        4,
        vec![],
        off,
        "state".into(),
        vec![],
        fields,
        vec![],
    )
}

// ---------------------------------------------------------------------------
// properties
// ---------------------------------------------------------------------------

#[test]
fn prop_json_roundtrips() {
    let mut rng = Rng::new(1);
    for _ in 0..300 {
        let j = random_json(&mut rng, 3);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(j, back, "roundtrip failed for {text}");
    }
}

#[test]
fn prop_replay_samples_only_live_window() {
    let mut rng = Rng::new(2);
    for case in 0..100 {
        let cap = 1 + rng.below(32);
        let mut buf = ReplayBuffer::new(cap, 1, 1);
        let n = 1 + rng.below(100);
        for i in 0..n {
            let v = i as f32;
            buf.push(&[v], &[v], v, &[v], false);
        }
        assert_eq!(buf.len(), n.min(cap));
        let lo = n.saturating_sub(cap) as f32;
        let (mut o, mut a, mut r, mut no, mut d) =
            (vec![0.0], vec![0.0], vec![0.0], vec![0.0], vec![0.0]);
        for _ in 0..20 {
            buf.sample_into(&mut rng, 1, &mut o, &mut a, &mut r, &mut no, &mut d);
            assert!(r[0] >= lo && r[0] < n as f32, "case {case}: stale sample");
            // row alignment across SoA arrays
            assert_eq!(o[0], r[0]);
            assert_eq!(a[0], r[0]);
        }
    }
}

#[test]
fn prop_copy_agent_is_row_copy_and_preserves_others() {
    let mut rng = Rng::new(3);
    for _ in 0..100 {
        let pop = 2 + rng.below(6);
        let art = random_layout(&mut rng, pop);
        let mut state: Vec<f32> = (0..art.state_size).map(|i| i as f32).collect();
        let before = state.clone();
        let src = rng.below(pop);
        let dst = rng.below(pop);
        let groups: Vec<&str> = vec!["policy", "opt"];
        art.copy_agent(&mut state, &groups, src, dst);
        for f in &art.fields {
            let stride = f.agent_stride();
            for agent in 0..pop {
                let row = &state[f.offset + agent * stride..f.offset + (agent + 1) * stride];
                let expect_src = agent == dst && dst != src
                    && groups.contains(&f.group.as_str());
                if expect_src {
                    let srow =
                        &before[f.offset + src * stride..f.offset + (src + 1) * stride];
                    assert_eq!(row, srow, "field {} dst row", f.name);
                } else {
                    let orow =
                        &before[f.offset + agent * stride..f.offset + (agent + 1) * stride];
                    assert_eq!(row, orow, "field {} agent {agent} must be untouched",
                               f.name);
                }
            }
        }
    }
}

#[test]
fn prop_agent_vector_roundtrip() {
    let mut rng = Rng::new(4);
    for _ in 0..100 {
        let pop = 1 + rng.below(5);
        let art = random_layout(&mut rng, pop);
        let mut state: Vec<f32> = (0..art.state_size).map(|_| rng.normal() as f32).collect();
        let agent = rng.below(pop);
        let groups: Vec<&str> = vec!["policy", "critic"];
        let v = art.agent_vector(&state, &groups, agent);
        // scatter back zeros then restore: exact roundtrip
        let zeros = vec![0.0f32; v.len()];
        art.set_agent_vector(&mut state, &groups, agent, &zeros);
        assert_eq!(art.agent_vector(&state, &groups, agent), zeros);
        art.set_agent_vector(&mut state, &groups, agent, &v);
        assert_eq!(art.agent_vector(&state, &groups, agent), v);
    }
}

#[test]
fn prop_ratio_gate_never_exceeds_target() {
    let mut rng = Rng::new(5);
    for _ in 0..100 {
        let target = 0.1 + rng.uniform() * 2.0;
        let mut g = RatioGate::new(target, 0.0, 0);
        for _ in 0..200 {
            if rng.below(2) == 0 {
                g.on_env_steps(1 + rng.below(5) as u64);
            } else {
                let n = 1 + rng.below(3) as u64;
                if g.may_update(n) {
                    g.on_update_steps(n);
                }
            }
            if g.env_steps() > 0 {
                assert!(
                    g.update_steps() as f64 <= target * g.env_steps() as f64 + 1e-9,
                    "ratio exceeded: {} updates vs {} env steps (target {target})",
                    g.update_steps(),
                    g.env_steps()
                );
            }
        }
    }
}

#[test]
fn prop_cem_mu_stays_in_elite_hull() {
    let mut rng = Rng::new(6);
    for _ in 0..100 {
        let dim = 1 + rng.below(8);
        let mut cem = Cem::new(vec![0.0; dim], 1.0, 0.5);
        cem.noise = 0.0;
        let n_elites = 1 + rng.below(6);
        let elites: Vec<Vec<f32>> = (0..n_elites)
            .map(|_| (0..dim).map(|_| rng.normal() as f32 * 3.0).collect())
            .collect();
        let refs: Vec<&[f32]> = elites.iter().map(|e| e.as_slice()).collect();
        cem.update(&refs);
        for d in 0..dim {
            let lo = refs.iter().map(|e| e[d]).fold(f32::INFINITY, f32::min);
            let hi = refs.iter().map(|e| e[d]).fold(f32::NEG_INFINITY, f32::max);
            assert!(cem.mu[d] >= lo - 1e-5 && cem.mu[d] <= hi + 1e-5);
            assert!(cem.var[d] >= 0.0);
        }
    }
}

#[test]
fn prop_percentile_within_sample_bounds() {
    let mut rng = Rng::new(7);
    for _ in 0..200 {
        let n = 1 + rng.below(50);
        let mut v: Vec<f64> = (0..n).map(|_| rng.normal() * 10.0).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = rng.uniform() * 100.0;
        let p = percentile(&v, q);
        assert!(p >= v[0] - 1e-12 && p <= v[n - 1] + 1e-12);
    }
}

#[test]
fn prop_argsort_desc_is_sorted_permutation() {
    let mut rng = Rng::new(8);
    for _ in 0..200 {
        let n = 1 + rng.below(30);
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let idx = argsort_desc(&xs);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..n).collect::<Vec<_>>());
        for w in idx.windows(2) {
            assert!(xs[w[0]] >= xs[w[1]]);
        }
    }
}

#[test]
fn prop_hyper_samples_in_support() {
    let mut rng = Rng::new(9);
    let spec = HyperSpec::td3();
    for _ in 0..500 {
        for (_, dist) in &spec.entries {
            let v = dist.sample(&mut rng);
            let (lo, hi) = dist.support();
            assert!(v >= lo && v <= hi);
            let p = dist.perturb(v, &mut rng);
            assert!(p >= lo && p <= hi);
        }
    }
}

#[test]
fn prop_dist_perturb_is_bounded_multiplicative() {
    let mut rng = Rng::new(10);
    let d = Dist::LogUniform(1e-6, 1e6);
    for _ in 0..300 {
        let v = rng.log_uniform_in(1e-3, 1e3);
        let p = d.perturb(v, &mut rng);
        let ratio = p / v;
        assert!((ratio - 0.8).abs() < 1e-9 || (ratio - 1.25).abs() < 1e-9);
    }
}

#[test]
fn prop_mlp_linear_layer_is_matvec() {
    let mut rng = Rng::new(11);
    for _ in 0..100 {
        let i = 1 + rng.below(10);
        let o = 1 + rng.below(10);
        let mut w = vec![0.0f32; i * o];
        let mut b = vec![0.0f32; o];
        rng.fill_normal(&mut w, 1.0);
        rng.fill_normal(&mut b, 1.0);
        let mut x = vec![0.0f32; i];
        rng.fill_normal(&mut x, 1.0);
        let mut mlp = fastpbrl::nn::Mlp::new(
            fastpbrl::nn::Activation::None,
            fastpbrl::nn::Activation::None,
        );
        mlp.push_layer(w.clone(), b.clone(), i, o);
        let y = mlp.forward_vec(&x);
        for oo in 0..o {
            let mut expect = b[oo];
            for ii in 0..i {
                expect += x[ii] * w[ii * o + oo];
            }
            assert!((y[oo] - expect).abs() < 1e-4, "{} vs {}", y[oo], expect);
        }
    }
}

/// Acceptance gate: PopMlp forward matches the scalar Mlp within 1e-5 on
/// randomized weights for pop ∈ {1, 4, 16}.
#[test]
fn prop_pop_mlp_matches_scalar_members() {
    let mut rng = Rng::new(13);
    for &pop in &[1usize, 4, 16] {
        for case in 0..20 {
            let dims = [
                1 + rng.below(10),
                1 + rng.below(24),
                1 + rng.below(24),
                1 + rng.below(6),
            ];
            // per-member random stacks, then packed [P, in, out] assembly
            let members: Vec<Vec<(Vec<f32>, Vec<f32>)>> = (0..pop)
                .map(|_| {
                    dims.windows(2)
                        .map(|d| {
                            let mut w = vec![0.0f32; d[0] * d[1]];
                            let mut b = vec![0.0f32; d[1]];
                            rng.fill_normal(&mut w, 0.8);
                            rng.fill_normal(&mut b, 0.3);
                            (w, b)
                        })
                        .collect()
                })
                .collect();
            let mut net = fastpbrl::nn::PopMlp::new(
                pop,
                fastpbrl::nn::Activation::Relu,
                fastpbrl::nn::Activation::Tanh,
            );
            for (li, d) in dims.windows(2).enumerate() {
                let mut w = Vec::new();
                let mut b = Vec::new();
                for m in &members {
                    w.extend_from_slice(&m[li].0);
                    b.extend_from_slice(&m[li].1);
                }
                net.push_layer(w, b, d[0], d[1]);
            }
            // rows in random member order, with repeats (exercises the
            // member-run blocking)
            let rows = pop + rng.below(4);
            let ids: Vec<usize> = (0..rows).map(|_| rng.below(pop)).collect();
            let mut obs = vec![0.0f32; rows * dims[0]];
            rng.fill_normal(&mut obs, 1.0);
            let mut got = vec![0.0f32; rows * dims[3]];
            net.forward_block(&ids, &obs, &mut got);
            for (k, &m) in ids.iter().enumerate() {
                let mut scalar = fastpbrl::nn::Mlp::new(
                    fastpbrl::nn::Activation::Relu,
                    fastpbrl::nn::Activation::Tanh,
                );
                for (li, d) in dims.windows(2).enumerate() {
                    scalar.push_layer(
                        members[m][li].0.clone(),
                        members[m][li].1.clone(),
                        d[0],
                        d[1],
                    );
                }
                let want = scalar.forward_vec(&obs[k * dims[0]..(k + 1) * dims[0]]);
                for (j, &wv) in want.iter().enumerate() {
                    let gv = got[k * dims[3] + j];
                    assert!(
                        (gv - wv).abs() < 1e-5,
                        "pop {pop} case {case} row {k} member {m} out {j}: {gv} vs {wv}"
                    );
                }
            }
        }
    }
}

/// push_batch is observationally identical to repeated push: identical
/// contents sampled with identical rng streams return identical batches.
#[test]
fn prop_push_batch_behaves_like_repeated_push() {
    let mut rng = Rng::new(14);
    for case in 0..100 {
        let cap = 1 + rng.below(24);
        let (od, ad) = (1 + rng.below(3), 1 + rng.below(2));
        let mut a = ReplayBuffer::new(cap, od, ad);
        let mut b = ReplayBuffer::new(cap, od, ad);
        for _ in 0..5 {
            let n = 1 + rng.below(2 * cap); // may wrap more than once
            let mut obs = vec![0.0f32; n * od];
            let mut act = vec![0.0f32; n * ad];
            let mut rew = vec![0.0f32; n];
            let mut nobs = vec![0.0f32; n * od];
            let mut done = vec![0.0f32; n];
            rng.fill_normal(&mut obs, 1.0);
            rng.fill_normal(&mut act, 1.0);
            rng.fill_normal(&mut rew, 1.0);
            rng.fill_normal(&mut nobs, 1.0);
            for d in done.iter_mut() {
                *d = (rng.below(2) == 0) as u8 as f32;
            }
            a.push_batch(n, &obs, &act, &rew, &nobs, &done);
            for r in 0..n {
                b.push(
                    &obs[r * od..(r + 1) * od],
                    &act[r * ad..(r + 1) * ad],
                    rew[r],
                    &nobs[r * od..(r + 1) * od],
                    done[r] > 0.5,
                );
            }
        }
        assert_eq!(a.len(), b.len(), "case {case}");
        assert_eq!(a.total_inserted, b.total_inserted, "case {case}");
        let batch = 1 + rng.below(8);
        let mut ra = Rng::new(500 + case as u64);
        let mut rb = Rng::new(500 + case as u64);
        let (mut oa, mut aa, mut wa, mut na, mut da) = (
            vec![0.0f32; batch * od],
            vec![0.0f32; batch * ad],
            vec![0.0f32; batch],
            vec![0.0f32; batch * od],
            vec![0.0f32; batch],
        );
        let (mut ob, mut ab, mut wb, mut nb, mut db) = (
            vec![0.0f32; batch * od],
            vec![0.0f32; batch * ad],
            vec![0.0f32; batch],
            vec![0.0f32; batch * od],
            vec![0.0f32; batch],
        );
        for _ in 0..10 {
            a.sample_into(&mut ra, batch, &mut oa, &mut aa, &mut wa, &mut na, &mut da);
            b.sample_into(&mut rb, batch, &mut ob, &mut ab, &mut wb, &mut nb, &mut db);
            assert_eq!(oa, ob, "case {case}");
            assert_eq!(aa, ab, "case {case}");
            assert_eq!(wa, wb, "case {case}");
            assert_eq!(na, nb, "case {case}");
            assert_eq!(da, db, "case {case}");
        }
    }
}

/// The trainer's ratio pairing — actors throttled through [`Throttle`]'s
/// shared counters (bounded by `lead` env steps), the learner gated by
/// [`RatioGate`] (bounded by `slack` update steps) — must make joint
/// progress at every target and land on updates/env ≈ target, each side
/// inside its own band. Randomized interleavings at the paper's target
/// range, including draws pinned to the exact liveness boundary.
#[test]
fn prop_joint_throttle_ratio_gate_converges() {
    use fastpbrl::data::pipeline::Throttle;
    use std::sync::atomic::Ordering;

    let mut rng = Rng::new(15);
    for &target in &[0.25f64, 0.5, 1.0, 4.0] {
        for case in 0..25 {
            let slack = [0.0, 2.0, 16.0][rng.below(3)];
            let mut lead = 1 + rng.below(64) as u64;
            // Liveness floor: one update spends one unit of learner
            // credit and one env step costs `target`, so the two bands
            // together must cover `1 + target` (the same floor
            // `may_step_env` carries). Pin too-tight draws to the exact
            // boundary instead of discarding them, so the edge stays
            // covered.
            if target * lead as f64 + slack < 1.0 + target {
                lead = ((1.0 + target - slack) / target).ceil() as u64;
            }
            let warmup = rng.below(40) as u64;
            let throttle = Throttle::new();
            let mut gate = RatioGate::new(target, slack, warmup);
            let total_updates = 300u64;
            let mut iters = 0u64;
            while gate.update_steps() < total_updates {
                iters += 1;
                assert!(iters < 200_000, "no convergence: target {target} case {case}");
                let actor_ok = throttle.may_step_with(target, warmup, lead);
                let learner_ok = gate.may_update(1);
                assert!(
                    actor_ok || learner_ok,
                    "deadlock: target {target} slack {slack} lead {lead} case {case} \
                     ({} env steps, {} updates)",
                    gate.env_steps(),
                    gate.update_steps()
                );
                if learner_ok && (!actor_ok || rng.below(2) == 0) {
                    gate.on_update_steps(1);
                    throttle.updates.fetch_add(1, Ordering::Relaxed);
                } else {
                    throttle.env_steps.fetch_add(1, Ordering::Relaxed);
                    gate.on_env_steps(1);
                }
            }
            let env_pw = gate.env_steps().saturating_sub(warmup) as f64;
            let upd = gate.update_steps() as f64;
            // the learner never leads the target line by more than slack...
            assert!(
                upd <= target * env_pw + slack + 1e-6,
                "learner over band: target {target} case {case}: {upd} updates \
                 vs {env_pw} counted env steps (slack {slack})"
            );
            // ...and actors never lead it by more than their lead allowance
            assert!(
                target * env_pw <= upd + target * (lead as f64 + 1.0) + 1e-6,
                "actors over band: target {target} case {case}: {env_pw} counted \
                 env steps vs {upd} updates (lead {lead})"
            );
        }
    }
}

/// Acceptance gate: the register-tiled matmat matches the reference
/// per-row kernel within a scaled 1e-5 across odd / non-tile-multiple
/// dims (in/out 1..=67, rows 1..=33) and both hot-path activations.
#[test]
fn prop_tiled_matmat_matches_reference() {
    use fastpbrl::nn::kernels::{matmat_reference, matmat_tiled};
    use fastpbrl::nn::Activation;

    let mut rng = Rng::new(16);
    for case in 0..150 {
        let i = 1 + rng.below(67);
        let o = 1 + rng.below(67);
        let rows = 1 + rng.below(33);
        let act = if case % 2 == 0 { Activation::Relu } else { Activation::Tanh };
        let mut w = vec![0.0f32; i * o];
        let mut b = vec![0.0f32; o];
        let mut x = vec![0.0f32; rows * i];
        rng.fill_normal(&mut w, 0.8);
        rng.fill_normal(&mut b, 0.3);
        rng.fill_normal(&mut x, 1.0);
        // sprinkle exact zeros so the reference side exercises both
        // matvec regimes too
        for v in x.iter_mut() {
            if rng.below(5) == 0 {
                *v = 0.0;
            }
        }
        let mut want = vec![0.0f32; rows * o];
        let mut got = vec![0.0f32; rows * o];
        matmat_reference(&w, &b, &x, &mut want, i, o, rows, act);
        matmat_tiled(&w, &b, &x, &mut got, i, o, rows, act);
        for (k, (&gv, &wv)) in got.iter().zip(&want).enumerate() {
            let tol = 1e-5f32 * wv.abs().max(1.0);
            assert!(
                (gv - wv).abs() <= tol,
                "case {case} ({i}x{o}, {rows} rows, {act:?}) out {k}: {gv} vs {wv}"
            );
        }
    }
}

/// Acceptance gate: the im2col conv matches the direct sparsity-skipping
/// kernel within 1e-5 on real frames from all three MinAtar envs, with
/// per-member random filters at pop ∈ {1, 4, 16}.
#[test]
fn prop_im2col_conv_matches_direct_on_minatar_frames() {
    use fastpbrl::envs::make_pixel_env;
    use fastpbrl::nn::kernels::{conv2d_im2col_relu, conv2d_valid_relu};

    let k = 3usize;
    let feats = 16usize;
    let mut rng = Rng::new(17);
    for env_name in ["breakout", "asterix", "spaceinvaders"] {
        let mut env = make_pixel_env(env_name).unwrap();
        let (h, w, c) = env.frame();
        let (ho, wo) = (h - k + 1, w - k + 1);
        let mut frame = vec![0.0f32; h * w * c];
        env.reset(&mut rng, &mut frame);
        for &pop in &[1usize, 4, 16] {
            for member in 0..pop {
                // advance the env so every member sees a different frame
                for _ in 0..3 {
                    let action = rng.below(env.n_actions());
                    let (_rew, done) = env.step(action, &mut rng, &mut frame);
                    if done {
                        env.reset(&mut rng, &mut frame);
                    }
                }
                let mut cw = vec![0.0f32; k * k * c * feats];
                let mut cb = vec![0.0f32; feats];
                rng.fill_normal(&mut cw, 0.5);
                rng.fill_normal(&mut cb, 0.2);
                let mut want = vec![0.0f32; ho * wo * feats];
                let mut got = vec![0.0f32; ho * wo * feats];
                let mut scratch: Vec<f32> = Vec::new();
                conv2d_valid_relu(&cw, &cb, &frame, &mut want, k, k, c, feats, h, w);
                conv2d_im2col_relu(
                    &cw, &cb, &frame, &mut got, &mut scratch, k, k, c, feats, h, w,
                );
                for (j, (&gv, &wv)) in got.iter().zip(&want).enumerate() {
                    let tol = 1e-5f32 * wv.abs().max(1.0);
                    assert!(
                        (gv - wv).abs() <= tol,
                        "{env_name} pop {pop} member {member} out {j}: {gv} vs {wv}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_config_roundtrip_values() {
    let mut rng = Rng::new(12);
    for _ in 0..100 {
        let a = rng.below(1000);
        let b = rng.uniform() * 10.0;
        let text = format!("[s]\nx = {a}\ny = {b}\nz = true\n");
        let c = fastpbrl::util::config::Config::parse(&text).unwrap();
        assert_eq!(c.get_usize("s.x", 0).unwrap(), a);
        assert!((c.get_f64("s.y", 0.0).unwrap() - b).abs() < 1e-9);
        assert!(c.get_bool("s.z", false).unwrap());
    }
}
