//! Resilience integration tests behind the `fault-inject` feature
//! (`cargo test --features fault-inject --test fault_injection`): a
//! deterministic [`FaultPlan`] crashes actor threads, stalls their loops,
//! and NaN-poisons population members; the supervision layer must absorb
//! every fault and the run must still complete.
//!
//! The pool-level tests build a synthetic pendulum artifact so they run
//! everywhere (real actor threads, envs, panics — no AOT artifacts or
//! XLA runtime needed). The trainer-level acceptance tests drive full
//! training runs and skip gracefully when `make artifacts` has not run.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use fastpbrl::coordinator::population::ParamView;
use fastpbrl::coordinator::trainer::{Continuous, NoController, Trainer, TrainerConfig};
use fastpbrl::data::pipeline::{ActorConfig, ActorPool, PolicyKind, Throttle};
use fastpbrl::data::supervisor::FaultPlan;
use fastpbrl::manifest::{Artifact, Dtype, EnvDesc, Field, Manifest};
use fastpbrl::runtime::runstate::{RunState, RUN_STATE_SCHEMA};
use fastpbrl::runtime::watchdog::{run_watchdog, WatchdogConfig, WatchdogOutcome};
use fastpbrl::util::rng::Rng;

/// A minimal continuous-control artifact matching the native pendulum
/// env (obs_dim 3, act_dim 1): one linear policy layer per member.
fn toy_artifact(pop: usize) -> Artifact {
    let mut fields = Vec::new();
    let mut off = 0;
    let mut push = |name: &str, shape: Vec<usize>| {
        let size: usize = shape.iter().product();
        fields.push(Field {
            name: name.into(),
            offset: off,
            size,
            shape,
            dtype: Dtype::F32,
            init: "zeros".into(),
            group: "policy".into(),
            per_agent: true,
        });
        off += size;
    };
    push("policy/w0", vec![pop, 3, 1]);
    push("policy/b0", vec![pop, 1]);
    Artifact::new(
        "toy_pendulum".into(),
        PathBuf::new(),
        "td3".into(),
        "pendulum".into(),
        EnvDesc { obs_dim: 3, act_dim: 1, ..Default::default() },
        pop,
        1,
        4,
        vec![],
        off,
        "state".into(),
        vec![],
        fields,
        vec![],
    )
}

fn actor_cfg(plan: Arc<FaultPlan>) -> ActorConfig {
    ActorConfig {
        env: "pendulum".into(),
        policy: PolicyKind::Td3,
        warmup_steps: 0,
        queue_cap: 64,
        seed: 7,
        ratio: 0.0, // unthrottled: no learner in these tests
        fault_plan: Some(plan),
        ..Default::default()
    }
}

#[test]
fn injected_panic_is_reported_and_respawn_restores_flow() {
    let art = toy_artifact(2);
    let view = ParamView::new(art.init_state(&mut Rng::new(0), 0));
    let plan = Arc::new(FaultPlan {
        actor_panics: vec![(0, 3)],
        ..Default::default()
    });
    let mut pool =
        ActorPool::spawn(&art, view, actor_cfg(plan), 1, Throttle::new()).unwrap();

    // the thread runs a few iterations, then the plan kills it
    let deadline = Instant::now() + Duration::from_secs(20);
    let exit = loop {
        assert!(Instant::now() < deadline, "no exit event before deadline");
        if let Some(e) = pool.poll_exit() {
            break e;
        }
        // keep the channel drained so the actor never blocks on send
        if let Ok(b) = pool.rx.recv_timeout(Duration::from_millis(5)) {
            pool.recycle(b);
        }
    };
    assert_eq!(exit.thread, 0);
    assert_eq!(exit.agents, vec![0, 1]);
    assert!(exit.cause.is_failure());
    let msg = format!("{:?}", exit.cause);
    assert!(msg.contains("fault-inject"), "unexpected cause: {msg}");

    // respawn: generation 1 skips the plan, so transitions flow again
    assert!(pool.respawn(0));
    let block = pool
        .rx
        .recv_timeout(Duration::from_secs(20))
        .expect("respawned actor produces blocks");
    pool.recycle(block);
    pool.stop();
}

#[test]
fn injected_stall_trips_the_heartbeat_watchdog() {
    let art = toy_artifact(2);
    let view = ParamView::new(art.init_state(&mut Rng::new(1), 0));
    let plan = Arc::new(FaultPlan {
        actor_stalls: vec![(0, 2, 600)],
        ..Default::default()
    });
    let pool = ActorPool::spawn(&art, view, actor_cfg(plan), 1, Throttle::new()).unwrap();

    // the 600 ms injected sleep must become visible as a stale heartbeat
    // under a 100 ms watchdog timeout
    let deadline = Instant::now() + Duration::from_secs(20);
    let mut tripped = false;
    while Instant::now() < deadline {
        if pool.heartbeats().is_stalled(0, 100) {
            tripped = true;
            break;
        }
        if let Ok(b) = pool.rx.try_recv() {
            pool.recycle(b);
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(tripped, "watchdog never flagged the injected stall");
    pool.stop();
}

// ---- trainer-level acceptance (needs `make artifacts`) ----------------

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping fault-injection acceptance test (no artifacts): {e}");
            None
        }
    }
}

fn base_cfg(updates: u64) -> TrainerConfig {
    TrainerConfig {
        env: "pendulum".into(),
        algo: "td3".into(),
        pop: 4,
        total_updates: updates,
        sync_every: 25,
        warmup_steps: 100,
        replay_capacity: 10_000,
        seed: 42,
        max_seconds: 120.0,
        ..TrainerConfig::default()
    }
}

/// The headline acceptance test: a run with an injected actor panic AND
/// an injected NaN-poisoned member completes, reports the recovery in
/// its summary, and lands within a (generous, seed-noise-sized)
/// tolerance of the unfaulted baseline's windowed return.
#[test]
fn faulted_run_completes_and_recovers() {
    let Some(m) = manifest() else { return };
    let updates = 300;

    let mut baseline = Trainer::<Continuous>::new(&m, base_cfg(updates)).unwrap();
    let base = baseline.run(&mut NoController).unwrap();
    assert_eq!(base.actor_restarts, 0);
    assert_eq!(base.members_repaired, 0);

    let plan = Arc::new(FaultPlan {
        actor_panics: vec![(0, 40)], // thread 0 dies mid-run
        nan_members: vec![(1, updates / 2)], // member 1 diverges mid-run
        ..Default::default()
    });
    let mut cfg = base_cfg(updates);
    cfg.fault_plan = Some(plan);
    cfg.restart_backoff_ms = 10; // fast respawn: keep the test quick
    let mut faulted = Trainer::<Continuous>::new(&m, cfg).unwrap();
    let summary = faulted.run(&mut NoController).unwrap();

    assert_eq!(summary.updates, updates, "faulted run must still complete");
    assert!(
        summary.actor_restarts >= 1,
        "injected panic must be recovered by a respawn: {summary:?}"
    );
    assert!(
        summary.members_repaired >= 1,
        "injected NaN member must be quarantine-repaired: {summary:?}"
    );
    assert!(summary.mean_return.is_finite());
    // same budget, same seed: the repaired run should not collapse
    // (tolerance sized for short-run pendulum seed noise)
    let tolerance = 0.5 * base.mean_return.abs() + 200.0;
    assert!(
        summary.mean_return >= base.mean_return - tolerance,
        "faulted {} vs baseline {} (tolerance {})",
        summary.mean_return,
        base.mean_return,
        tolerance
    );
}

/// Checkpoint lineage end-to-end: corrupt the newest generation after a
/// run and `Trainer::new` must auto-resume from an older healthy one
/// instead of erroring or starting fresh.
#[test]
fn trainer_resumes_from_lineage_after_corruption() {
    let Some(m) = manifest() else { return };
    let dir = std::env::temp_dir().join("fastpbrl_fault_lineage");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("ckpt.bin");

    let mut cfg = base_cfg(200);
    cfg.checkpoint_path = ckpt.to_string_lossy().into_owned();
    cfg.sync_every = 20; // several checkpoint generations per run
    let mut trainer = Trainer::<Continuous>::new(&m, cfg.clone()).unwrap();
    trainer.run(&mut NoController).unwrap();
    drop(trainer);

    // corrupt the newest generation (and therefore the base hard link)
    let mut newest: Option<(u64, std::path::PathBuf)> = None;
    for entry in std::fs::read_dir(&dir).unwrap().flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        if let Some(seq) = name.strip_prefix("ckpt.bin.").and_then(|s| s.parse::<u64>().ok())
        {
            if newest.as_ref().is_none_or(|(n, _)| seq > *n) {
                newest = Some((seq, entry.path()));
            }
        }
    }
    let (_, newest_path) = newest.expect("run left checkpoint generations behind");
    let mut bytes = std::fs::read(&newest_path).unwrap();
    let n = bytes.len();
    bytes[n - 3] ^= 0xFF;
    std::fs::write(&newest_path, bytes).unwrap();

    // a new trainer must fall back down the lineage and resume
    let resumed = Trainer::<Continuous>::new(&m, cfg).unwrap();
    assert!(
        resumed.population.train_state.updates_done > 0,
        "expected resume from an older checkpoint generation"
    );
}

/// Runtime-fault acceptance: an injected device loss mid-run must be
/// recovered *in place* — runtime rebuilt, executables reloaded, the
/// population re-uploaded from the host mirror — and the run completes
/// with the recovery visible in the summary.
#[test]
fn injected_device_loss_recovers_in_place() {
    let Some(m) = manifest() else { return };
    let updates = 300;
    let plan = Arc::new(FaultPlan {
        device_errors: vec![updates / 3],
        ..Default::default()
    });
    let mut cfg = base_cfg(updates);
    cfg.fault_plan = Some(plan);
    cfg.runtime_retry_backoff_ms = 1;
    let mut trainer = Trainer::<Continuous>::new(&m, cfg).unwrap();
    let summary = trainer.run(&mut NoController).unwrap();
    assert_eq!(summary.updates, updates, "run must complete despite the device loss");
    assert!(
        summary.device_restarts >= 1,
        "injected device loss must be recovered by a runtime rebuild: {summary:?}"
    );
    assert!(summary.mean_return.is_finite());
}

// ---- process watchdog (scripted /bin/sh children — no artifacts) ------

fn watchdog_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fastpbrl_fault_wd_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn sh_watchdog(dir: &std::path::Path, script: String) -> WatchdogConfig {
    WatchdogConfig {
        program: PathBuf::from("/bin/sh"),
        args: vec!["-c".into(), script],
        run_dir: dir.to_path_buf(),
        backoff_base_ms: 10,
        backoff_cap_ms: 20,
        heartbeat_timeout_secs: 0.0, // exit-status only unless a test opts in
        poll_ms: 10,
        ..WatchdogConfig::default()
    }
}

#[test]
fn watchdog_restarts_a_crashing_child_until_it_succeeds() {
    let dir = watchdog_dir("retry");
    let counter = dir.join("attempts");
    // fails twice, succeeds on the third incarnation
    let script = format!(
        "n=$(cat {c} 2>/dev/null || echo 0); n=$((n+1)); echo $n > {c}; [ $n -ge 3 ]",
        c = counter.display()
    );
    let mut cfg = sh_watchdog(&dir, script);
    cfg.crash_loop_threshold = 0; // the fast failures here are the point
    let report = run_watchdog(&cfg).unwrap();
    assert_eq!(report.outcome, WatchdogOutcome::Completed, "{report:?}");
    assert_eq!(report.restarts, 2);
    assert!(report.last_failure.is_none());
    assert_eq!(std::fs::read_to_string(&counter).unwrap().trim(), "3");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn watchdog_diagnoses_a_crash_loop_instead_of_burning_the_budget() {
    let dir = watchdog_dir("crashloop");
    let mut cfg = sh_watchdog(&dir, "exit 7".into());
    cfg.max_process_restarts = 10;
    cfg.crash_loop_window_secs = 30.0;
    cfg.crash_loop_threshold = 3;
    let report = run_watchdog(&cfg).unwrap();
    assert_eq!(report.outcome, WatchdogOutcome::CrashLoop, "{report:?}");
    // third consecutive fast failure trips the detector: only 2 restarts
    assert_eq!(report.restarts, 2);
    assert!(report.last_failure.unwrap().contains('7'));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn watchdog_gives_up_when_the_restart_budget_is_spent() {
    let dir = watchdog_dir("budget");
    let mut cfg = sh_watchdog(&dir, "exit 1".into());
    cfg.max_process_restarts = 2;
    cfg.crash_loop_threshold = 0;
    let report = run_watchdog(&cfg).unwrap();
    assert_eq!(report.outcome, WatchdogOutcome::BudgetExhausted, "{report:?}");
    assert_eq!(report.restarts, 2);
    assert!(report.last_failure.is_some());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn watchdog_kills_a_silent_child_as_stalled() {
    let dir = watchdog_dir("stall");
    // the child never touches the heartbeat or telemetry, so the spawn
    // instant is its only liveness signal — the stall timeout kills it
    let mut cfg = sh_watchdog(&dir, "sleep 30".into());
    cfg.heartbeat_timeout_secs = 0.3;
    cfg.max_process_restarts = 0;
    cfg.crash_loop_threshold = 0;
    let started = Instant::now();
    let report = run_watchdog(&cfg).unwrap();
    assert_eq!(report.outcome, WatchdogOutcome::BudgetExhausted, "{report:?}");
    assert!(report.last_failure.unwrap().contains("stalled"));
    assert!(
        started.elapsed() < Duration::from_secs(20),
        "stalled child must be killed, not waited out"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn watchdog_adopts_the_argv_recorded_in_run_json() {
    let dir = watchdog_dir("runjson");
    let marker = dir.join("adopted");
    // a prior incarnation recorded what it was actually running
    RunState {
        schema: RUN_STATE_SCHEMA,
        argv: vec![
            "fastpbrl".into(),
            "-c".into(),
            format!("echo ok > {}", marker.display()),
        ],
        checkpoint_base: dir.join("ckpt.bin").to_string_lossy().into_owned(),
        seed: 7,
        config_digest: "deadbeefdeadbeef".into(),
    }
    .save(&dir)
    .unwrap();
    // the command line disagrees (and would fail); run.json must win
    let cfg = sh_watchdog(&dir, "exit 1".into());
    let report = run_watchdog(&cfg).unwrap();
    assert_eq!(report.outcome, WatchdogOutcome::Completed, "{report:?}");
    assert_eq!(report.restarts, 0);
    assert!(marker.exists(), "recorded argv was not executed");
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---- watchdog + trainer end-to-end (needs `make artifacts`) -----------

/// Not a test of its own: the child incarnation that
/// [`watchdog_resumes_after_child_abort`] supervises. Spawned via
/// `current_exe() watchdog_child_trainer --exact`, gated on an env var
/// so it is a no-op in normal suite runs. Runs a checkpointed training
/// run; the first incarnation aborts mid-run via the fault plan, the
/// resumed one completes and writes a summary JSON for the parent.
#[test]
fn watchdog_child_trainer() {
    if std::env::var("FASTPBRL_WD_CHILD").is_err() {
        return;
    }
    let Some(m) = manifest() else { return };
    let updates: u64 = std::env::var("FASTPBRL_WD_UPDATES").unwrap().parse().unwrap();
    let abort_at: u64 = std::env::var("FASTPBRL_WD_ABORT_AT").unwrap().parse().unwrap();
    let mut cfg = base_cfg(updates);
    cfg.checkpoint_path = std::env::var("FASTPBRL_WD_CKPT").unwrap();
    cfg.sync_every = 20;
    if abort_at > 0 {
        cfg.fault_plan = Some(Arc::new(FaultPlan {
            process_abort: Some(abort_at),
            ..Default::default()
        }));
    }
    let mut trainer = Trainer::<Continuous>::new(&m, cfg).unwrap();
    let resumed_at =
        if trainer.resumed { trainer.population.train_state.updates_done } else { 0 };
    let s = trainer.run(&mut NoController).unwrap();
    std::fs::write(
        std::env::var("FASTPBRL_WD_SUMMARY").unwrap(),
        format!(
            "{{\"updates\":{},\"mean_return\":{},\"resumed_at\":{}}}\n",
            s.updates, s.mean_return, resumed_at
        ),
    )
    .unwrap();
}

/// The headline watchdog acceptance test: the child trainer is killed
/// mid-run (`abort()` from its fault plan), the watchdog restarts it,
/// the restart resumes from the lineage's `last_good`, and the completed
/// run lands within tolerance of an unfaulted baseline.
#[test]
fn watchdog_resumes_after_child_abort() {
    let Some(m) = manifest() else { return };
    let updates = 300u64;

    let mut baseline = Trainer::<Continuous>::new(&m, base_cfg(updates)).unwrap();
    let base = baseline.run(&mut NoController).unwrap();
    drop(baseline);

    let dir = watchdog_dir("abort");
    let ckpt = dir.join("ckpt.bin");
    let summary_path = dir.join("summary.json");
    let cfg = WatchdogConfig {
        program: std::env::current_exe().unwrap(),
        args: vec!["watchdog_child_trainer".into(), "--exact".into(), "--nocapture".into()],
        envs: vec![
            ("FASTPBRL_WD_CHILD".into(), "1".into()),
            ("FASTPBRL_WD_CKPT".into(), ckpt.to_string_lossy().into_owned()),
            ("FASTPBRL_WD_UPDATES".into(), updates.to_string()),
            ("FASTPBRL_WD_ABORT_AT".into(), (updates / 2).to_string()),
            ("FASTPBRL_WD_SUMMARY".into(), summary_path.to_string_lossy().into_owned()),
        ],
        run_dir: dir.clone(),
        backoff_base_ms: 10,
        backoff_cap_ms: 50,
        heartbeat_timeout_secs: 0.0, // exit-status only: CI boxes can be slow
        poll_ms: 20,
        ..WatchdogConfig::default()
    };
    let report = run_watchdog(&cfg).unwrap();
    assert_eq!(report.outcome, WatchdogOutcome::Completed, "{report:?}");
    assert_eq!(report.restarts, 1, "exactly one abort was injected: {report:?}");

    let text = std::fs::read_to_string(&summary_path)
        .expect("the completing incarnation writes its summary");
    let j = fastpbrl::util::json::Json::parse(text.trim()).unwrap();
    let num = |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap();
    assert_eq!(num("updates") as u64, updates, "resumed run must finish the budget");
    assert!(
        num("resumed_at") > 0.0,
        "the restarted incarnation must resume from the lineage, not start fresh: {text}"
    );
    let tolerance = 0.5 * base.mean_return.abs() + 200.0;
    assert!(
        num("mean_return") >= base.mean_return - tolerance,
        "resumed {} vs baseline {} (tolerance {})",
        num("mean_return"),
        base.mean_return,
        tolerance
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
