//! Telemetry smoke tests: a real training run with the registry enabled
//! must emit a parseable JSONL snapshot stream with non-zero phase
//! timers, and `fastpbrl top` must render it. The training-backed test
//! is skipped gracefully when `make artifacts` has not run; the exporter
//! round-trip below it runs everywhere.
//!
//! These tests live in their own integration binary (own process), so
//! flipping the process-wide registry switch cannot race the library
//! unit tests.

use fastpbrl::coordinator::trainer::{NoController, Trainer, TrainerConfig};
use fastpbrl::coordinator::trainer::Continuous;
use fastpbrl::manifest::Manifest;
use fastpbrl::telemetry::{self, top, TelemetryConfig};

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping telemetry smoke test (no artifacts): {e}");
            None
        }
    }
}

#[test]
fn training_emits_parseable_snapshot_stream() {
    let Some(m) = manifest() else { return };
    let dir = std::env::temp_dir().join("fastpbrl_it_telemetry");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let cfg = TrainerConfig {
        env: "pendulum".into(),
        algo: "td3".into(),
        pop: 4,
        total_updates: 200,
        sync_every: 25,
        warmup_steps: 100,
        replay_capacity: 10_000,
        seed: 42,
        max_seconds: 120.0,
        telemetry: TelemetryConfig {
            enabled: true,
            jsonl_path: dir.display().to_string(),
            prometheus_path: dir.join("metrics.prom").display().to_string(),
            snapshot_secs: 0.05,
        },
        ..TrainerConfig::default()
    };
    let mut trainer = Trainer::<Continuous>::new(&m, cfg).unwrap();
    let summary = trainer.run(&mut NoController).unwrap();
    assert_eq!(summary.updates, 200);

    // the stream lands at the run-dir convention `fastpbrl top` uses
    let stream = top::resolve_stream(&dir);
    assert_eq!(stream, dir.join("telemetry.jsonl"));
    let text = std::fs::read_to_string(&stream).unwrap();
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    assert!(!lines.is_empty(), "no snapshots written");

    // every line parses; the last one carries the full run
    let snap = top::latest_snapshot(&stream).unwrap().expect("final snapshot");
    for line in &lines {
        fastpbrl::util::json::Json::parse(line).unwrap();
    }

    // learner counters match the run's own summary
    let updates = snap.counter("learner.updates").expect("learner.updates");
    assert_eq!(updates.value, summary.updates);
    let env_steps = snap.counter("learner.env_steps").expect("learner.env_steps");
    assert_eq!(env_steps.value, summary.env_steps);

    // non-zero phase timers for the hot learner stages
    for phase in ["drain", "sample", "upload", "update_exec", "host_sync"] {
        let h = snap.hist(&format!("learner.phase.{phase}")).expect(phase);
        assert!(h.count > 0, "phase {phase} never recorded");
        assert!(h.sum > 0, "phase {phase} has zero total time");
    }
    // and Summary's run-local timer agrees the stage ran
    assert!(summary.timers.total("update_exec") > 0.0);

    // actor threads recorded steps and stage timings
    let t0_steps = snap.counter("actor.0.env_steps").expect("actor.0.env_steps");
    assert!(t0_steps.value > 0);
    assert!(snap.hist("actor.0.phase.env_step").expect("env_step hist").count > 0);

    // replay fill gauges exist (per-agent buffers count as stripes)
    assert!(snap.gauge("replay.stripe.0.fill").is_some());

    // supervision counters are registered even on a healthy run
    assert_eq!(snap.counter("supervisor.actor_restarts").map(|c| c.value), Some(0));

    // kernel dispatch counters ticked on the native actor forward path
    let kernel_total: u64 = snap
        .counters
        .iter()
        .filter(|c| c.name.starts_with("kernels."))
        .map(|c| c.value)
        .sum();
    assert!(kernel_total > 0, "no kernel dispatch recorded");

    // the Prometheus dump was rewritten alongside the stream
    let prom = std::fs::read_to_string(dir.join("metrics.prom")).unwrap();
    assert!(prom.contains("# TYPE fastpbrl_learner_updates counter"), "{prom}");

    // `fastpbrl top` renders the stream
    let table = top::render(&snap);
    assert!(table.contains("update:env"), "{table}");
    assert!(table.contains("update_exec"), "{table}");
    assert!(table.contains("#0"), "{table}");

    let _ = std::fs::remove_dir_all(&dir);
}

/// Exporter round-trip against the live global registry — no artifacts
/// needed, so CI always exercises the write/parse path.
#[test]
fn exporter_streams_global_registry_snapshots() {
    let dir = std::env::temp_dir().join("fastpbrl_it_telemetry_exporter");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = TelemetryConfig {
        enabled: true,
        jsonl_path: dir.join("stream.jsonl").display().to_string(),
        prometheus_path: String::new(),
        snapshot_secs: 1000.0, // only explicit flushes write
    };
    telemetry::configure(&cfg);
    let mut exporter =
        fastpbrl::telemetry::export::Exporter::from_config(&cfg).unwrap().unwrap();
    let c = telemetry::counter("it_exporter.events");
    c.add(5);
    exporter.flush();
    c.add(2);
    exporter.flush();

    let stream = top::resolve_stream(&dir.join("stream.jsonl"));
    let snap = top::latest_snapshot(&stream).unwrap().expect("snapshot");
    let got = snap.counter("it_exporter.events").expect("counter in stream");
    assert_eq!(got.value, 7);
    let text = std::fs::read_to_string(&stream).unwrap();
    assert_eq!(text.lines().count(), 2, "one line per flush");
    let _ = std::fs::remove_dir_all(&dir);
}
