//! Integration tests over the real AOT artifacts: PJRT load/compile,
//! device-resident update steps, and rust-native vs HLO forward parity.
//!
//! Requires `make artifacts` (skipped gracefully otherwise so `cargo test`
//! works in a fresh checkout; CI runs `make test` which builds them).

use fastpbrl::manifest::Manifest;
use fastpbrl::nn::from_state::{mlp_from_state, policy_activations};
use fastpbrl::runtime::{Runtime, TrainState};
use fastpbrl::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping integration test (no artifacts): {e}");
            None
        }
    }
}

fn upload_batches(
    rt: &Runtime,
    art: &fastpbrl::manifest::Artifact,
    rng: &mut Rng,
) -> Vec<xla::PjRtBuffer> {
    art.inputs[1..]
        .iter()
        .map(|inp| {
            let n = inp.numel();
            match inp.dtype {
                fastpbrl::manifest::Dtype::I32 => {
                    let data: Vec<i32> = (0..n).map(|_| rng.below(3) as i32).collect();
                    rt.upload_i32(&data, &inp.shape).unwrap()
                }
                _ => {
                    let mut data = vec![0.0f32; n];
                    // "done" flags should be 0/1; small normals fine elsewhere
                    if inp.name == "done" {
                        for v in data.iter_mut() {
                            *v = (rng.below(10) == 0) as u8 as f32;
                        }
                    } else {
                        rng.fill_normal(&mut data, 0.5);
                    }
                    rt.upload_f32(&data, &inp.shape).unwrap()
                }
            }
        })
        .collect()
}

#[test]
fn td3_update_advances_state_on_device() {
    let Some(m) = manifest() else { return };
    let art = m.find("td3", "pendulum", 1, Some(1)).unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(art).unwrap();
    assert!(exe.compile_seconds > 0.0);

    let mut rng = Rng::new(0);
    let mut ts = TrainState::init(&rt, art, &mut rng, 42).unwrap();
    let host0 = ts.to_host().unwrap();

    let batches = upload_batches(&rt, art, &mut rng);
    let refs: Vec<&xla::PjRtBuffer> = batches.iter().collect();
    // Chain several steps without any host copy in between.
    for _ in 0..3 {
        ts.step(&exe, &refs).unwrap();
    }
    assert_eq!(ts.updates_done, 3);

    let host1 = ts.to_host().unwrap();
    assert!(host1.iter().all(|v| v.is_finite()), "non-finite state");
    // step counter advanced (u32 bit-cast in the state)
    let step = art.read(&host1, "step").unwrap()[0].to_bits();
    assert_eq!(step, 3);
    // parameters moved
    let w0_before = art.read(&host0, "policy/w0").unwrap();
    let w0_after = art.read(&host1, "policy/w0").unwrap();
    assert!(w0_before.iter().zip(w0_after).any(|(a, b)| a != b));
    // metrics populated
    let closs = art.read(&host1, "critic_loss").unwrap();
    assert!(closs[0].is_finite() && closs[0] != 0.0);
}

#[test]
fn native_mlp_matches_hlo_policy_forward() {
    let Some(m) = manifest() else { return };
    let art = m.find("td3fwd", "pendulum", 1, None).unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(art).unwrap();

    let mut rng = Rng::new(7);
    let host = art.init_state(&mut rng, 9);
    let state_buf = rt.upload_f32(&host, &[art.state_size]).unwrap();

    // batch of observations [1, B, obs]
    let obs_inp = &art.inputs[1];
    let n = obs_inp.numel();
    let mut obs = vec![0.0f32; n];
    rng.fill_normal(&mut obs, 1.0);
    let obs_buf = rt.upload_f32(&obs, &obs_inp.shape).unwrap();

    let out = exe.run(&[&state_buf, &obs_buf]).unwrap();
    let hlo_actions = fastpbrl::runtime::Executable::download_f32(&out).unwrap();

    let (ha, fa) = policy_activations("td3");
    let mut mlp = mlp_from_state(art, &host, "policy", 0, ha, fa).unwrap();
    let b = obs_inp.shape[1];
    let obs_dim = obs_inp.shape[2];
    let act_dim = mlp.out_dim();
    for i in 0..b {
        let native = mlp.forward_vec(&obs[i * obs_dim..(i + 1) * obs_dim]);
        for (j, &nv) in native.iter().enumerate() {
            let hv = hlo_actions[i * act_dim + j];
            assert!(
                (nv - hv).abs() < 1e-5,
                "parity mismatch at obs {i} dim {j}: native {nv} vs hlo {hv}"
            );
        }
    }
}

#[test]
fn vectorized_and_sequential_states_share_layout_semantics() {
    // Same seed material semantics: a pop-4 artifact's per-agent slices can
    // be read back through the manifest accessors.
    let Some(m) = manifest() else { return };
    let art = m.find("td3", "pendulum", 4, Some(1)).unwrap();
    let mut rng = Rng::new(3);
    let host = art.init_state(&mut rng, 1);
    for agent in 0..4 {
        let w = art.read_agent(&host, "policy/w0", agent).unwrap();
        assert!(w.iter().any(|&v| v != 0.0), "agent {agent} uninitialized");
    }
    // target groups synced at init
    let (p, t) = (
        art.read(&host, "policy/w0").unwrap(),
        art.read(&host, "policy_t/w0").unwrap(),
    );
    assert_eq!(p, t);
}

#[test]
fn dqn_update_runs_with_i32_actions() {
    let Some(m) = manifest() else { return };
    let art = m.find("dqn", "minatar", 1, Some(1)).unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(art).unwrap();
    let mut rng = Rng::new(11);
    let mut ts = TrainState::init(&rt, art, &mut rng, 5).unwrap();
    let batches = upload_batches(&rt, art, &mut rng);
    let refs: Vec<&xla::PjRtBuffer> = batches.iter().collect();
    ts.step(&exe, &refs).unwrap();
    let host = ts.to_host().unwrap();
    assert!(host.iter().all(|v| v.is_finite()));
    let loss = art.read(&host, "loss").unwrap();
    assert!(loss[0].is_finite());
}

#[test]
fn native_convnet_matches_hlo_q_forward() {
    let Some(m) = manifest() else { return };
    let Ok(art) = m.find("dqnfwd", "minatar", 1, None) else {
        eprintln!("skipping (no dqnfwd artifact)");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(art).unwrap();

    let mut rng = Rng::new(21);
    let host = art.init_state(&mut rng, 4);
    let state_buf = rt.upload_f32(&host, &[art.state_size]).unwrap();

    let obs_inp = &art.inputs[1];
    let (h, w, c) = art.env_desc.frame.unwrap();
    let b = obs_inp.shape[1];
    let frame_len = h * w * c;
    // binary MinAtar-like frames
    let mut obs = vec![0.0f32; obs_inp.numel()];
    for v in obs.iter_mut() {
        *v = (rng.below(5) == 0) as u8 as f32;
    }
    let obs_buf = rt.upload_f32(&obs, &obs_inp.shape).unwrap();
    let out = exe.run(&[&state_buf, &obs_buf]).unwrap();
    let hlo_q = fastpbrl::runtime::Executable::download_f32(&out).unwrap();

    let mut net = fastpbrl::nn::from_state::convnet_from_state(
        art, &host, "q", 0, (h, w, c)).unwrap();
    let n_actions = art.env_desc.n_actions;
    for i in 0..b {
        let native = net.forward_vec(&obs[i * frame_len..(i + 1) * frame_len]);
        for (j, &nv) in native.iter().enumerate() {
            let hv = hlo_q[i * n_actions + j];
            assert!(
                (nv - hv).abs() < 1e-4,
                "conv parity mismatch frame {i} action {j}: native {nv} vs hlo {hv}"
            );
        }
    }
}

#[test]
fn actor_pool_streams_transitions_and_episodes() {
    let Some(m) = manifest() else { return };
    let art = m.find("td3", "pendulum", 4, Some(1)).unwrap();
    let mut rng = Rng::new(31);
    let host = art.init_state(&mut rng, 6);
    let view = fastpbrl::coordinator::population::ParamView::new(host);
    let throttle = fastpbrl::data::pipeline::Throttle::new();
    let pool = fastpbrl::data::pipeline::ActorPool::spawn(
        art,
        view,
        fastpbrl::data::pipeline::ActorConfig {
            env: "pendulum".into(),
            warmup_steps: 10,
            ratio: 0.0, // unthrottled for the test
            seed: 5,
            ..Default::default()
        },
        1,
        throttle.clone(),
    )
    .unwrap();
    let mut steps = 0usize;
    let mut episodes = 0usize;
    let mut seen_agents = [false; 4];
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    while (steps < 1200 || episodes == 0) && std::time::Instant::now() < deadline {
        if let Ok(block) = pool.rx.recv_timeout(std::time::Duration::from_millis(500)) {
            assert!(block.n >= 1);
            assert_eq!(block.obs_dim, 3);
            assert_eq!(block.act_dim, 1);
            for k in 0..block.n {
                assert!(block.agents[k] < 4);
                assert_eq!(block.obs_row(k).len(), 3);
                assert_eq!(block.act_row(k).len(), 1);
                assert!(block.act_row(k)[0].abs() <= 1.0);
                assert!(block.rew[k].is_finite());
                seen_agents[block.agents[k]] = true;
            }
            steps += block.n;
            for ep in &block.episodes {
                assert!(ep.agent < 4);
                assert!(ep.steps <= 200); // pendulum horizon
                episodes += 1;
            }
            // exercise the allocation-free return lane
            pool.recycle(block);
        }
    }
    pool.stop();
    assert!(steps >= 1200, "actors produced only {steps} transitions");
    assert!(episodes >= 1, "no episode boundaries reported");
    assert!(seen_agents.iter().all(|&s| s), "all agents must act: {seen_agents:?}");
}
