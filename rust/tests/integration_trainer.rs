//! End-to-end integration tests over the coordinator: full training loops
//! (actors + replay + vectorized device updates + controllers) on the fast
//! pendulum artifacts. Skipped gracefully when `make artifacts` has not
//! run yet.

use fastpbrl::coordinator::dvd::DvdLambdaSchedule;
use fastpbrl::coordinator::hyperparams::HyperSpec;
use fastpbrl::coordinator::pbt::{Explore, PbtController};
use fastpbrl::coordinator::trainer::{Controller, NoController, Trainer, TrainerConfig};
use fastpbrl::manifest::Manifest;

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping integration test (no artifacts): {e}");
            None
        }
    }
}

fn base_cfg(updates: u64) -> TrainerConfig {
    TrainerConfig {
        env: "pendulum".into(),
        algo: "td3".into(),
        pop: 4,
        total_updates: updates,
        sync_every: 25,
        warmup_steps: 100,
        replay_capacity: 10_000,
        seed: 42,
        max_seconds: 120.0,
        ..TrainerConfig::default()
    }
}

#[test]
fn trainer_runs_to_completion_and_respects_ratio() {
    let Some(m) = manifest() else { return };
    let mut trainer = Trainer::new(&m, base_cfg(300)).unwrap();
    let summary = trainer.run(&mut NoController).unwrap();
    assert_eq!(summary.updates, 300);
    assert!(summary.env_steps > 0);
    // per-agent update:env ratio stays near 1 (warmup + bounded lead)
    let per_agent_env = summary.env_steps as f64 / 4.0;
    let ratio = summary.updates as f64 / per_agent_env;
    assert!(
        (0.2..=4.0).contains(&ratio),
        "per-agent ratio {ratio} wildly off (env_steps {})",
        summary.env_steps
    );
    // update execution dominates the learner's time budget (the paper's
    // premise: env stepping must not be the bottleneck)
    assert!(summary.timers.total("update_exec") > 0.0);
}

#[test]
fn trainer_reports_finite_fitness_after_episodes() {
    let Some(m) = manifest() else { return };
    let mut cfg = base_cfg(400);
    cfg.warmup_steps = 50;
    let mut trainer = Trainer::new(&m, cfg).unwrap();
    let summary = trainer.run(&mut NoController).unwrap();
    // pendulum episodes are 200 steps; with ~100+ env steps per agent the
    // population should have finished episodes and reported returns
    assert!(
        summary.best_return.is_finite(),
        "no finished episode recorded (env_steps {})",
        summary.env_steps
    );
    assert!(summary.best_return < 0.0); // pendulum returns are negative
}

#[test]
fn pbt_controller_evolves_population_during_training() {
    let Some(m) = manifest() else { return };
    let mut cfg = base_cfg(600);
    cfg.warmup_steps = 50;
    cfg.hyper_spec = Some(HyperSpec::td3());
    let mut pbt = PbtController::new(HyperSpec::td3(), 150, 0.26, Explore::Resample);
    let mut trainer = Trainer::new(&m, cfg).unwrap();
    let summary = trainer.run(&mut pbt).unwrap();
    assert_eq!(summary.updates, 600);
    assert!(
        !pbt.history.is_empty(),
        "PBT should have evolved at least once in 600 updates"
    );
    // after evolution, the loser's hyperparameters lie in the prior support
    let host = trainer.population.view.with(|h| h.to_vec());
    let art = trainer.artifact();
    for agent in 0..art.pop {
        let lr = art.read_agent(&host, "lr_policy", agent).unwrap()[0] as f64;
        assert!((3e-5..=3e-3).contains(&lr), "agent {agent} lr {lr}");
    }
}

#[test]
fn dvd_schedule_writes_lambda_into_state() {
    let Some(m) = manifest() else { return };
    let Ok(art) = m.find("dvd", "halfcheetah", 5, None) else {
        eprintln!("skipping (no dvd artifact)");
        return;
    };
    let mut cfg = base_cfg(120);
    cfg.env = "halfcheetah".into();
    cfg.algo = "dvd".into();
    cfg.pop = art.pop;
    cfg.shared_replay = true;
    cfg.warmup_steps = 100;
    let mut ctrl = DvdLambdaSchedule::default_for(120);
    let expected_start = ctrl.value_at(25) as f32; // first sync at ~25 updates
    let mut trainer = Trainer::new(&m, cfg).unwrap();
    let summary = trainer.run(&mut ctrl).unwrap();
    assert_eq!(summary.updates, 120);
    let host = trainer.population.view.with(|h| h.to_vec());
    let lam = trainer.artifact().read(&host, "lambda_div").unwrap()[0];
    assert!(lam > 0.0 && lam <= expected_start + 1e-3, "lambda {lam}");
}

#[test]
fn sac_trainer_also_composes() {
    let Some(m) = manifest() else { return };
    if m.find("sac", "pendulum", 4, None).is_err() {
        eprintln!("skipping (no sac pendulum artifact)");
        return;
    }
    let mut cfg = base_cfg(200);
    cfg.algo = "sac".into();
    let mut trainer = Trainer::new(&m, cfg).unwrap();
    let summary = trainer.run(&mut NoController).unwrap();
    assert_eq!(summary.updates, 200);
    let host = trainer.population.view.with(|h| h.to_vec());
    let alpha = trainer.artifact().read(&host, "alpha").unwrap();
    assert!(alpha.iter().all(|a| *a > 0.0 && a.is_finite()));
}

/// A controller that counts sync callbacks — verifies the contract that
/// `on_sync` fires every `sync_every` executions.
struct CountingController {
    calls: usize,
}

impl Controller for CountingController {
    fn on_sync(&mut self, _ctx: &mut fastpbrl::coordinator::trainer::EvolveCtx<'_>)
               -> anyhow::Result<()> {
        self.calls += 1;
        Ok(())
    }
}

#[test]
fn controller_sync_cadence_matches_config() {
    let Some(m) = manifest() else { return };
    let mut cfg = base_cfg(200);
    cfg.sync_every = 50;
    let mut ctrl = CountingController { calls: 0 };
    let mut trainer = Trainer::new(&m, cfg).unwrap();
    trainer.run(&mut ctrl).unwrap();
    // 200 updates / 50 per sync = 4 syncs (+1 tolerance for the final flush)
    assert!(
        (4..=5).contains(&ctrl.calls),
        "expected ~4 sync callbacks, got {}",
        ctrl.calls
    );
}

#[test]
fn checkpoint_roundtrip_resumes_training() {
    let Some(m) = manifest() else { return };
    let path = std::env::temp_dir().join("fastpbrl_it_ckpt.bin");
    let _ = std::fs::remove_file(&path);
    let mut cfg = base_cfg(100);
    cfg.checkpoint_path = path.display().to_string();
    let mut t1 = Trainer::new(&m, cfg).unwrap();
    t1.run(&mut NoController).unwrap();
    let ckpt = fastpbrl::runtime::checkpoint::Checkpoint::load(&path).unwrap();
    assert_eq!(ckpt.state.len(), t1.artifact().state_size);

    // a fresh trainer with the same checkpoint path resumes from it
    let mut cfg2 = base_cfg(100);
    cfg2.checkpoint_path = path.display().to_string();
    cfg2.seed = 99; // different seed -> different init unless restored
    let t2 = Trainer::new(&m, cfg2).unwrap();
    let restored = t2.population.view.with(|h| h.to_vec());
    assert_eq!(restored, ckpt.state, "trainer must resume from checkpoint");
    let _ = std::fs::remove_file(&path);
}
