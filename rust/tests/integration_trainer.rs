//! End-to-end integration tests over the coordinator: full training loops
//! (actors + replay + vectorized device updates + controllers) on the fast
//! pendulum artifacts, plus the pixel/DQN domain through the same generic
//! loop. Skipped gracefully when `make artifacts` has not run yet.

use fastpbrl::coordinator::dvd::DvdLambdaSchedule;
use fastpbrl::coordinator::hyperparams::HyperSpec;
use fastpbrl::coordinator::pbt::{Explore, PbtController};
use fastpbrl::coordinator::trainer::{
    run_training, Continuous, Controller, NoController, Pixel, Trainer, TrainerConfig,
};
use fastpbrl::manifest::Manifest;

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping integration test (no artifacts): {e}");
            None
        }
    }
}

fn base_cfg(updates: u64) -> TrainerConfig {
    TrainerConfig {
        env: "pendulum".into(),
        algo: "td3".into(),
        pop: 4,
        total_updates: updates,
        sync_every: 25,
        warmup_steps: 100,
        replay_capacity: 10_000,
        seed: 42,
        max_seconds: 120.0,
        ..TrainerConfig::default()
    }
}

/// The pixel/DQN mirror of `base_cfg` (small budgets; skipped when no
/// dqn artifact has been generated).
fn dqn_cfg(updates: u64) -> TrainerConfig {
    let mut cfg = TrainerConfig::new("dqn", "minatar")
        .with_pop(2)
        .with_updates(updates)
        .with_ratio(0.25)
        .with_warmup(50)
        .with_replay_capacity(5_000)
        .with_seed(42)
        .with_max_seconds(120.0);
    cfg.num_steps = Some(1);
    cfg.sync_every = 10;
    cfg
}

#[test]
fn trainer_runs_to_completion_and_respects_ratio() {
    let Some(m) = manifest() else { return };
    let mut trainer = Trainer::<Continuous>::new(&m, base_cfg(300)).unwrap();
    let summary = trainer.run(&mut NoController).unwrap();
    assert_eq!(summary.updates, 300);
    assert!(summary.env_steps > 0);
    // per-agent update:env ratio stays near 1 (warmup + bounded lead)
    let per_agent_env = summary.env_steps as f64 / 4.0;
    let ratio = summary.updates as f64 / per_agent_env;
    assert!(
        (0.2..=4.0).contains(&ratio),
        "per-agent ratio {ratio} wildly off (env_steps {})",
        summary.env_steps
    );
    // update execution dominates the learner's time budget (the paper's
    // premise: env stepping must not be the bottleneck)
    assert!(summary.timers.total("update_exec") > 0.0);
}

#[test]
fn trainer_reports_finite_fitness_after_episodes() {
    let Some(m) = manifest() else { return };
    let mut cfg = base_cfg(400);
    cfg.warmup_steps = 50;
    let mut trainer = Trainer::<Continuous>::new(&m, cfg).unwrap();
    let summary = trainer.run(&mut NoController).unwrap();
    // pendulum episodes are 200 steps; with ~100+ env steps per agent the
    // population should have finished episodes and reported returns
    assert!(
        summary.best_return.is_finite(),
        "no finished episode recorded (env_steps {})",
        summary.env_steps
    );
    assert!(summary.best_return < 0.0); // pendulum returns are negative
}

#[test]
fn pbt_controller_evolves_population_during_training() {
    let Some(m) = manifest() else { return };
    let mut cfg = base_cfg(600);
    cfg.warmup_steps = 50;
    cfg.hyper_spec = Some(HyperSpec::td3());
    let mut pbt = PbtController::new(HyperSpec::td3(), 150, 0.26, Explore::Resample);
    let mut trainer = Trainer::<Continuous>::new(&m, cfg).unwrap();
    let summary = trainer.run(&mut pbt).unwrap();
    assert_eq!(summary.updates, 600);
    assert!(
        !pbt.history.is_empty(),
        "PBT should have evolved at least once in 600 updates"
    );
    // after evolution, the loser's hyperparameters lie in the prior support
    let host = trainer.population.view.with(|h| h.to_vec());
    let art = trainer.artifact();
    for agent in 0..art.pop {
        let lr = art.read_agent(&host, "lr_policy", agent).unwrap()[0] as f64;
        assert!((3e-5..=3e-3).contains(&lr), "agent {agent} lr {lr}");
    }
}

#[test]
fn dvd_schedule_writes_lambda_into_state() {
    let Some(m) = manifest() else { return };
    let Ok(art) = m.find("dvd", "halfcheetah", 5, None) else {
        eprintln!("skipping (no dvd artifact)");
        return;
    };
    let mut cfg = base_cfg(120);
    cfg.env = "halfcheetah".into();
    cfg.algo = "dvd".into();
    cfg.pop = art.pop;
    cfg.shared_replay = true;
    cfg.warmup_steps = 100;
    let mut ctrl = DvdLambdaSchedule::default_for(120);
    let expected_start = ctrl.value_at(25) as f32; // first sync at ~25 updates
    let mut trainer = Trainer::<Continuous>::new(&m, cfg).unwrap();
    let summary = trainer.run(&mut ctrl).unwrap();
    assert_eq!(summary.updates, 120);
    let host = trainer.population.view.with(|h| h.to_vec());
    let lam = trainer.artifact().read(&host, "lambda_div").unwrap()[0];
    assert!(lam > 0.0 && lam <= expected_start + 1e-3, "lambda {lam}");
}

#[test]
fn sac_trainer_also_composes() {
    let Some(m) = manifest() else { return };
    if m.find("sac", "pendulum", 4, None).is_err() {
        eprintln!("skipping (no sac pendulum artifact)");
        return;
    }
    let mut cfg = base_cfg(200);
    cfg.algo = "sac".into();
    let mut trainer = Trainer::<Continuous>::new(&m, cfg).unwrap();
    let summary = trainer.run(&mut NoController).unwrap();
    assert_eq!(summary.updates, 200);
    let host = trainer.population.view.with(|h| h.to_vec());
    let alpha = trainer.artifact().read(&host, "alpha").unwrap();
    assert!(alpha.iter().all(|a| *a > 0.0 && a.is_finite()));
}

/// A controller that counts sync callbacks — verifies the contract that
/// `on_sync` fires every `sync_every` executions.
struct CountingController {
    calls: usize,
}

impl Controller for CountingController {
    fn on_sync(&mut self, _ctx: &mut fastpbrl::coordinator::trainer::EvolveCtx<'_>)
               -> anyhow::Result<()> {
        self.calls += 1;
        Ok(())
    }
}

#[test]
fn controller_sync_cadence_matches_config() {
    let Some(m) = manifest() else { return };
    let mut cfg = base_cfg(200);
    cfg.sync_every = 50;
    let mut ctrl = CountingController { calls: 0 };
    let mut trainer = Trainer::<Continuous>::new(&m, cfg).unwrap();
    trainer.run(&mut ctrl).unwrap();
    // 200 updates / 50 per sync = 4 syncs (+1 tolerance for the final flush)
    assert!(
        (4..=5).contains(&ctrl.calls),
        "expected ~4 sync callbacks, got {}",
        ctrl.calls
    );
}

#[test]
fn checkpoint_roundtrip_resumes_training() {
    let Some(m) = manifest() else { return };
    let path = std::env::temp_dir().join("fastpbrl_it_ckpt.bin");
    let _ = std::fs::remove_file(&path);
    let mut cfg = base_cfg(100);
    cfg.checkpoint_path = path.display().to_string();
    let mut t1 = Trainer::<Continuous>::new(&m, cfg).unwrap();
    t1.run(&mut NoController).unwrap();
    let ckpt = fastpbrl::runtime::checkpoint::Checkpoint::load(&path).unwrap();
    assert_eq!(ckpt.state.len(), t1.artifact().state_size);

    // a fresh trainer with the same checkpoint path resumes from it
    let mut cfg2 = base_cfg(100);
    cfg2.checkpoint_path = path.display().to_string();
    cfg2.seed = 99; // different seed -> different init unless restored
    let t2 = Trainer::<Continuous>::new(&m, cfg2).unwrap();
    let restored = t2.population.view.with(|h| h.to_vec());
    assert_eq!(restored, ckpt.state, "trainer must resume from checkpoint");
    let _ = std::fs::remove_file(&path);
}

// ---- pixel/DQN domain through the SAME generic loop ---------------------

#[test]
fn pixel_trainer_runs_dqn_through_shared_loop() {
    let Some(m) = manifest() else { return };
    if m.find("dqn", "minatar", 2, None).is_err() {
        eprintln!("skipping (no dqn minatar artifact)");
        return;
    }
    let mut trainer = Trainer::<Pixel>::new(&m, dqn_cfg(60)).unwrap();
    let summary = trainer.run(&mut NoController).unwrap();
    assert_eq!(summary.updates, 60);
    assert!(summary.env_steps > 0);
    assert!(summary.timers.total("update_exec") > 0.0);
}

#[test]
fn pixel_checkpoint_roundtrip_through_shared_loop() {
    let Some(m) = manifest() else { return };
    if m.find("dqn", "minatar", 2, None).is_err() {
        eprintln!("skipping (no dqn minatar artifact)");
        return;
    }
    let path = std::env::temp_dir().join("fastpbrl_it_pixel_ckpt.bin");
    let _ = std::fs::remove_file(&path);
    let mut cfg = dqn_cfg(40);
    cfg.checkpoint_path = path.display().to_string();
    let mut t1 = Trainer::<Pixel>::new(&m, cfg).unwrap();
    t1.run(&mut NoController).unwrap();
    let ckpt = fastpbrl::runtime::checkpoint::Checkpoint::load(&path).unwrap();
    assert_eq!(ckpt.state.len(), t1.artifact().state_size);

    let mut cfg2 = dqn_cfg(40);
    cfg2.checkpoint_path = path.display().to_string();
    cfg2.seed = 99; // different seed -> different init unless restored
    let t2 = Trainer::<Pixel>::new(&m, cfg2).unwrap();
    let restored = t2.population.view.with(|h| h.to_vec());
    assert_eq!(restored, ckpt.state, "pixel trainer must resume from checkpoint");
    let _ = std::fs::remove_file(&path);
}

/// PBT over DQN hyperparameters (per-agent eps_greedy/lr exploit-explore)
/// is a first-class scenario of the unified loop.
#[test]
fn pbt_over_dqn_composes_through_shared_loop() {
    let Some(m) = manifest() else { return };
    if m.find("dqn", "minatar", 2, None).is_err() {
        eprintln!("skipping (no dqn minatar artifact)");
        return;
    }
    let mut cfg = dqn_cfg(120);
    cfg.hyper_spec = Some(HyperSpec::dqn());
    let mut pbt = PbtController::new(HyperSpec::dqn(), 30, 0.26, Explore::Resample);
    let mut trainer = Trainer::<Pixel>::new(&m, cfg).unwrap();
    let summary = trainer.run(&mut pbt).unwrap();
    assert_eq!(summary.updates, 120);
    // evolved or not (episodes may be scarce in a short run), per-agent
    // epsilons must stay inside the dqn prior support
    let host = trainer.population.view.with(|h| h.to_vec());
    let art = trainer.artifact();
    for agent in 0..art.pop {
        let eps = art.read_agent(&host, "eps_greedy", agent).unwrap()[0] as f64;
        assert!((0.01..=0.2).contains(&eps), "agent {agent} eps {eps}");
    }
}

/// The unified entry point dispatches by artifact metadata: the same call
/// drives a continuous artifact and (when present) a pixel one.
#[test]
fn run_training_dispatches_by_artifact_domain() {
    let Some(m) = manifest() else { return };
    let summary = run_training(&m, base_cfg(50), &mut NoController).unwrap();
    assert_eq!(summary.updates, 50);
    if m.find("dqn", "minatar", 2, None).is_ok() {
        let summary = run_training(&m, dqn_cfg(20), &mut NoController).unwrap();
        assert_eq!(summary.updates, 20);
    }
}

/// Domain mismatches fail fast with a pointer to the right trainer
/// instead of panicking inside actor threads.
#[test]
fn mismatched_domain_errors_at_construction() {
    let Some(m) = manifest() else { return };
    let err = Trainer::<Pixel>::new(&m, base_cfg(10)).unwrap_err().to_string();
    assert!(err.contains("Trainer::<Continuous>"), "{err}");
    if m.find("dqn", "minatar", 2, None).is_ok() {
        let err = Trainer::<Continuous>::new(&m, dqn_cfg(10)).unwrap_err().to_string();
        assert!(err.contains("Trainer::<Pixel>"), "{err}");
    }
}
