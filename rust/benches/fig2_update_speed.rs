//! Fig 2: population update-step time vs population size for the three
//! implementation strategies the paper compares, across TD3 / SAC / DQN.
//!
//!   Sequential  — run the single-agent (P=1) executable N times
//!   Vectorized  — run the population-batched (P=N) executable once
//!   Parallel    — N threads, each owning a P=1 executable + state,
//!                 sharing the one accelerator concurrently
//!
//! Plus the paper's `num_steps` variant (k update steps chained in one
//! execution call, no host copies in between — paper uses 50/10, we lower
//! k=10 artifacts). Batches are preloaded on the device before timing, as
//! in the paper's protocol. Speedups are reported w.r.t. Sequential —
//! the analogue of the paper's Torch (Sequential) baseline (no torch in
//! this image; see DESIGN.md "Substitutions").
//!
//! Requires `make bench-artifacts` for the full sweep; falls back to
//! whatever pops exist.

use fastpbrl::bench_support::data::{available_pops, random_batches, require_artifacts};
use fastpbrl::bench_support::harness::{report, Bench, BenchResult};
use fastpbrl::manifest::Manifest;
use fastpbrl::runtime::{Runtime, TrainState};
use fastpbrl::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let rt = Runtime::cpu()?;
    let bench = if std::env::var("BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench { warmup_iters: 2, iters: 12, max_seconds: 25.0 }
    };
    let mut results: Vec<BenchResult> = Vec::new();
    let mut rng = Rng::new(0);

    for (algo, env) in [
        ("td3", "halfcheetah"),
        ("sac", "halfcheetah"),
        ("dqn", "minatar"),
        ("td3ref", "halfcheetah"), // L1 ablation: jnp-ref kernel lowering
    ] {
        let pops = available_pops(&manifest, algo, env, 1);
        if !require_artifacts(&pops, &format!("{algo}/{env} k=1")) {
            continue;
        }
        let p1 = manifest.find(algo, env, 1, Some(1));
        for &pop in &pops {
            // ---- vectorized: one P=pop execution -------------------------
            let art = manifest.find(algo, env, pop, Some(1))?;
            let exe = rt.load(art)?;
            let mut ts = TrainState::init(&rt, art, &mut rng, 1)?;
            let batches = random_batches(&rt, art, &mut rng)?;
            let refs: Vec<&xla::PjRtBuffer> = batches.iter().collect();
            results.push(bench.run(&format!("{algo}_vectorized_p{pop}"), || {
                ts.step(&exe, &refs).unwrap();
                // force completion: read back one scalar
                let _ = ts.fence().unwrap();
            }));

            // ---- sequential: pop executions of the P=1 artifact -----------
            if let Ok(a1) = p1.as_ref() {
                let exe1 = rt.load(a1)?;
                let mut states: Vec<TrainState> = (0..pop)
                    .map(|i| TrainState::init(&rt, a1, &mut rng, i as u64).unwrap())
                    .collect();
                let b1 = random_batches(&rt, a1, &mut rng)?;
                let r1: Vec<&xla::PjRtBuffer> = b1.iter().collect();
                results.push(bench.run(&format!("{algo}_sequential_p{pop}"), || {
                    for ts in states.iter_mut() {
                        ts.step(&exe1, &r1).unwrap();
                    }
                    let _ = states[0].fence().unwrap();
                }));

                // ---- parallel: pop concurrent client threads --------------
                // The PJRT client is not Send (Rc internally), so each
                // thread creates its OWN client + executable + state —
                // which is exactly the paper's one-process-per-agent
                // strategy sharing the accelerator. Setup (client create +
                // compile) happens before the barrier; we time steady-state
                // update throughput only.
                let iters = bench.iters.min(8);
                let barrier = std::sync::Barrier::new(pop + 1);
                let mut wall_ms = f64::NAN;
                std::thread::scope(|scope| {
                    for i in 0..pop {
                        let a1c = (*a1).clone();
                        let barrier = &barrier;
                        scope.spawn(move || {
                            let mut rng = Rng::new(900 + i as u64);
                            let rt = Runtime::cpu().unwrap();
                            let exe = rt.load(&a1c).unwrap();
                            let mut ts =
                                TrainState::init(&rt, &a1c, &mut rng, i as u64).unwrap();
                            let b = random_batches(&rt, &a1c, &mut rng).unwrap();
                            let r: Vec<&xla::PjRtBuffer> = b.iter().collect();
                            barrier.wait(); // start together
                            for _ in 0..iters {
                                ts.step(&exe, &r).unwrap();
                            }
                            let _ = ts.fence().unwrap();
                            barrier.wait(); // finish together
                        });
                    }
                    barrier.wait();
                    let sw = fastpbrl::util::timer::Stopwatch::start();
                    barrier.wait();
                    wall_ms = sw.elapsed_ms();
                });
                let per_iter = wall_ms / iters as f64;
                results.push(BenchResult {
                    name: format!("{algo}_parallel_p{pop}"),
                    iters,
                    mean_ms: per_iter,
                    std_ms: 0.0,
                    p50_ms: per_iter,
                    p90_ms: per_iter,
                    min_ms: per_iter,
                });
            }
        }

        // ---- num_steps variant: k chained updates in one call -----------
        let pops_k = available_pops(&manifest, algo, env, 10);
        for &pop in &pops_k {
            let art = manifest.find(algo, env, pop, Some(10))?;
            let exe = rt.load(art)?;
            let mut ts = TrainState::init(&rt, art, &mut rng, 2)?;
            let batches = random_batches(&rt, art, &mut rng)?;
            let refs: Vec<&xla::PjRtBuffer> = batches.iter().collect();
            let r = bench.run(&format!("{algo}_vectorized_k10_p{pop}"), || {
                ts.step(&exe, &refs).unwrap();
                let _ = ts.fence().unwrap();
            });
            // normalize to per-update-step time for comparability
            results.push(BenchResult {
                name: format!("{algo}_vectorized_k10_p{pop}_per_step"),
                mean_ms: r.mean_ms / 10.0,
                std_ms: r.std_ms / 10.0,
                p50_ms: r.p50_ms / 10.0,
                p90_ms: r.p90_ms / 10.0,
                min_ms: r.min_ms / 10.0,
                ..r
            });
        }
    }

    report("fig2_update_speed", &results)?;

    // ---- speedup table (the paper's reported metric) ---------------------
    println!("\nSpeedup factors w.r.t. Sequential (same population size):");
    println!("{:<10} {:>5} {:>12} {:>12} {:>12}", "algo", "pop", "vectorized", "parallel", "vec_k10");
    for (algo, env) in [("td3", "halfcheetah"), ("sac", "halfcheetah"), ("dqn", "minatar")] {
        for &pop in &available_pops(&manifest, algo, env, 1) {
            let find = |pat: String| {
                results.iter().find(|r| r.name == pat).map(|r| r.mean_ms)
            };
            let seq = find(format!("{algo}_sequential_p{pop}"));
            let vec_ = find(format!("{algo}_vectorized_p{pop}"));
            let par = find(format!("{algo}_parallel_p{pop}"));
            let k10 = find(format!("{algo}_vectorized_k10_p{pop}_per_step"));
            if let (Some(s), Some(v)) = (seq, vec_) {
                println!(
                    "{:<10} {:>5} {:>11.2}x {:>11.2}x {:>11.2}x",
                    algo,
                    pop,
                    s / v,
                    par.map(|p| s / p).unwrap_or(f64::NAN),
                    k10.map(|k| s / k).unwrap_or(f64::NAN),
                );
            }
        }
    }

    // ---- L1 ablation: pallas-interpret vs jnp-reference lowering ---------
    let ablation: Vec<usize> = available_pops(&manifest, "td3ref", "halfcheetah", 1);
    if !ablation.is_empty() {
        println!("\nL1 kernel ablation (vectorized TD3 update, pallas vs jnp-ref lowering):");
        println!("{:>5} {:>12} {:>12} {:>10}", "pop", "pallas_ms", "ref_ms", "ratio");
        for &pop in &ablation {
            let get = |n: String| results.iter().find(|r| r.name == n).map(|r| r.mean_ms);
            if let (Some(p), Some(r)) = (
                get(format!("td3_vectorized_p{pop}")),
                get(format!("td3ref_vectorized_p{pop}")),
            ) {
                println!("{:>5} {:>12.3} {:>12.3} {:>9.2}x", pop, p, r, p / r);
            }
        }
    }
    Ok(())
}
