//! Fig 3 (+ Table 1): comparative cost and runtime of training a
//! population on one accelerator (vectorized) vs allocating one CPU core
//! per agent, as a function of population size.
//!
//! Method (see DESIGN.md "Substitutions"): the accelerator measurements
//! come from this machine's PJRT CPU backend running the *vectorized*
//! artifact; the CPU-per-agent baseline is the measured single-agent
//! update time (its wall time is constant in population size — one core
//! per agent — while its cost scales linearly). Costs use the paper's
//! Table 1 posted prices verbatim, applied per accelerator model so the
//! qualitative crossovers of Fig 3 can be read off. Absolute GPU runtimes
//! are not measurable in this image; the runtime axis therefore reports
//! our substrate's vectorized-vs-sequential ratio.

use fastpbrl::bench_support::cost::{fig3_ratios, PRICES};
use fastpbrl::bench_support::data::{available_pops, random_batches, require_artifacts};
use fastpbrl::bench_support::harness::Bench;
use fastpbrl::manifest::Manifest;
use fastpbrl::runtime::{Runtime, TrainState};
use fastpbrl::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let rt = Runtime::cpu()?;
    let bench = if std::env::var("BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench { warmup_iters: 2, iters: 10, max_seconds: 20.0 }
    };
    let mut rng = Rng::new(0);

    println!("Table 1 — accelerator prices ($/h, averaged posted prices):");
    for (name, price) in PRICES {
        println!("  {name:<10} {price:.3}");
    }

    let (algo, env) = ("td3", "halfcheetah");
    let pops = available_pops(&manifest, algo, env, 1);
    if !require_artifacts(&pops, "td3/halfcheetah k=1") {
        return Ok(());
    }

    // CPU-per-agent baseline: single-agent update time on one core.
    let a1 = manifest.find(algo, env, 1, Some(1))?;
    let exe1 = rt.load(a1)?;
    let mut ts1 = TrainState::init(&rt, a1, &mut rng, 0)?;
    let b1 = random_batches(&rt, a1, &mut rng)?;
    let r1: Vec<&xla::PjRtBuffer> = b1.iter().collect();
    let base = bench.run("cpu_per_agent_baseline", || {
        ts1.step(&exe1, &r1).unwrap();
        let _ = ts1.fence().unwrap();
    });
    println!("\nCPU-per-agent baseline update time: {:.3} ms (constant in pop size)",
             base.mean_ms);

    std::fs::create_dir_all("results")?;
    let mut csv = String::from("accelerator,pop,vec_ms,runtime_ratio,cost_ratio\n");
    println!("\nFig 3 — runtime and cost vs one-CPU-core-per-agent (ratios < 1 favor the accelerator):");
    println!("{:<12} {:>5} {:>10} {:>14} {:>12}", "accelerator", "pop", "vec_ms",
             "runtime_ratio", "cost_ratio");
    for &pop in &pops {
        let art = manifest.find(algo, env, pop, Some(1))?;
        let exe = rt.load(art)?;
        let mut ts = TrainState::init(&rt, art, &mut rng, 1)?;
        let batches = random_batches(&rt, art, &mut rng)?;
        let refs: Vec<&xla::PjRtBuffer> = batches.iter().collect();
        let v = bench.run(&format!("vec_p{pop}"), || {
            ts.step(&exe, &refs).unwrap();
            let _ = ts.fence().unwrap();
        });
        for (acc, _) in PRICES.iter().filter(|(n, _)| *n != "CPU_CORE") {
            if let Some((rt_ratio, cost_ratio)) =
                fig3_ratios(acc, v.mean_ms / 1e3, base.mean_ms / 1e3, pop)
            {
                println!("{:<12} {:>5} {:>10.3} {:>14.3} {:>12.3}",
                         acc, pop, v.mean_ms, rt_ratio, cost_ratio);
                csv.push_str(&format!("{acc},{pop},{:.4},{:.4},{:.4}\n",
                                      v.mean_ms, rt_ratio, cost_ratio));
            }
        }
    }
    std::fs::write("results/fig3_cost_runtime.csv", csv)?;
    println!("-> results/fig3_cost_runtime.csv");
    Ok(())
}
