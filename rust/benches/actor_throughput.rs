//! Actor-path throughput: scalar per-agent inference + per-transition
//! Vec-cloning transport (the pre-vectorization pipeline) vs the
//! population-batched PopMlp + VecEnv + TransitionBlock path, at
//! pop ∈ {1, 4, 16, 64}.
//!
//! Both paths run the same deterministic tanh policy (paper-sized
//! 256x256 hidden MLP) on the same env and end in the same shared replay
//! buffer, so the measured difference is exactly the actor hot path:
//! per-agent dispatch + two heap clones per step vs one blocked forward,
//! one batched env step, and one `push_batch` per iteration.
//!
//! Also A/Bs the `matvec` kernel strategies (relu-sparsity skip vs
//! branch-free dense) on dense and post-relu inputs — the adaptive
//! kernel's two regimes.
//!
//! No artifacts required. Results go to `results/actor_throughput.csv`
//! and `BENCH_actor_throughput.json`.

use std::collections::VecDeque;

use fastpbrl::bench_support::harness::{report, Bench, BenchResult};
use fastpbrl::data::pipeline::TransitionBlock;
use fastpbrl::envs::{make_env, VecEnv};
use fastpbrl::nn::kernels::matmat_tiled;
use fastpbrl::nn::mlp::{matvec_dense, matvec_sparse};
use fastpbrl::nn::{Activation, Mlp, PopMlp};
use fastpbrl::replay::ReplayBuffer;
use fastpbrl::util::json::{arr, num, obj, s, Json};
use fastpbrl::util::rng::Rng;

const ENV: &str = "halfcheetah";
const HIDDEN: [usize; 2] = [256, 256];
const STEPS_PER_ITER: usize = 128;
const REPLAY_CAP: usize = 1 << 15;
const POPS: [usize; 4] = [1, 4, 16, 64];

/// The old transport unit: two obs clones + an act clone per step.
struct OldTransition {
    obs: Vec<f32>,
    act: Vec<f32>,
    rew: f32,
    next_obs: Vec<f32>,
    done: bool,
}

/// Random per-member layer stacks [(w, b); L] for dims.windows(2).
fn random_members(rng: &mut Rng, pop: usize, dims: &[usize]) -> Vec<Vec<(Vec<f32>, Vec<f32>)>> {
    (0..pop)
        .map(|_| {
            dims.windows(2)
                .map(|d| {
                    let bound = (3.0 / d[0] as f32).sqrt();
                    let mut w = vec![0.0f32; d[0] * d[1]];
                    let mut b = vec![0.0f32; d[1]];
                    rng.fill_uniform(&mut w, -bound, bound);
                    rng.fill_uniform(&mut b, -0.05, 0.05);
                    (w, b)
                })
                .collect()
        })
        .collect()
}

fn steps_per_sec(pop: usize, mean_ms: f64) -> f64 {
    (STEPS_PER_ITER * pop) as f64 / (mean_ms / 1e3)
}

fn main() -> anyhow::Result<()> {
    let bench = if std::env::var("BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench { warmup_iters: 2, iters: 15, max_seconds: 20.0 }
    };
    let mut results: Vec<BenchResult> = Vec::new();
    let mut pop_rows: Vec<Json> = Vec::new();

    for &pop in &POPS {
        let mut rng = Rng::new(100 + pop as u64);
        let probe = make_env(ENV)?;
        let (od, ad) = (probe.obs_dim(), probe.act_dim());
        drop(probe);
        let dims = [od, HIDDEN[0], HIDDEN[1], ad];
        let members = random_members(&mut rng, pop, &dims);

        // ---- scalar path: per-agent Mlp + per-transition clones ----------
        let mut mlps: Vec<Mlp> = members
            .iter()
            .map(|layers| {
                let mut m = Mlp::new(Activation::Relu, Activation::Tanh);
                for (li, d) in dims.windows(2).enumerate() {
                    m.push_layer(layers[li].0.clone(), layers[li].1.clone(), d[0], d[1]);
                }
                m
            })
            .collect();
        let mut envs: Vec<_> = (0..pop).map(|_| make_env(ENV).unwrap()).collect();
        let mut obs_rows: Vec<Vec<f32>> = envs
            .iter_mut()
            .map(|e| {
                let mut o = vec![0.0f32; od];
                e.reset(&mut rng, &mut o);
                o
            })
            .collect();
        let mut ep_steps = vec![0usize; pop];
        let mut act = vec![0.0f32; ad];
        let mut next = vec![0.0f32; od];
        let mut queue: VecDeque<OldTransition> = VecDeque::new();
        let mut replay = ReplayBuffer::new(REPLAY_CAP, od, ad);
        let r_scalar = bench.run(&format!("actor_scalar_p{pop}"), || {
            for _ in 0..STEPS_PER_ITER {
                for k in 0..pop {
                    mlps[k].forward(&obs_rows[k], &mut act);
                    let (rew, done) = envs[k].step(&act, &mut next);
                    ep_steps[k] += 1;
                    let horizon_hit = ep_steps[k] >= envs[k].horizon();
                    // the old transport: heap clones into a per-step message
                    queue.push_back(OldTransition {
                        obs: obs_rows[k].clone(),
                        act: act.clone(),
                        rew,
                        next_obs: next.clone(),
                        done,
                    });
                    obs_rows[k].copy_from_slice(&next);
                    if done || horizon_hit {
                        ep_steps[k] = 0;
                        envs[k].reset(&mut rng, &mut obs_rows[k]);
                    }
                }
                while let Some(t) = queue.pop_front() {
                    replay.push(&t.obs, &t.act, t.rew, &t.next_obs, t.done);
                }
            }
        });
        results.push(r_scalar.clone());

        // ---- batched path: PopMlp + VecEnv + TransitionBlock -------------
        let mut pop_net = PopMlp::new(pop, Activation::Relu, Activation::Tanh);
        for (li, d) in dims.windows(2).enumerate() {
            let mut w = Vec::with_capacity(pop * d[0] * d[1]);
            let mut b = Vec::with_capacity(pop * d[1]);
            for m in &members {
                w.extend_from_slice(&m[li].0);
                b.extend_from_slice(&m[li].1);
            }
            pop_net.push_layer(w, b, d[0], d[1]);
        }
        let ids: Vec<usize> = (0..pop).collect();
        let mut venv = VecEnv::new(ENV, pop)?;
        venv.reset_all(&mut rng);
        let mut block = TransitionBlock::new(0, &ids, od, ad);
        let mut acts = vec![0.0f32; pop * ad];
        let mut eps = Vec::new();
        let mut replay_b = ReplayBuffer::new(REPLAY_CAP, od, ad);
        let r_batched = bench.run(&format!("actor_batched_p{pop}"), || {
            for _ in 0..STEPS_PER_ITER {
                pop_net.forward_block(&ids, venv.obs(), &mut acts);
                block.obs.copy_from_slice(venv.obs());
                block.act.copy_from_slice(&acts);
                eps.clear();
                venv.step_into(&mut rng, &acts, &mut block.next_obs, &mut block.rew,
                               &mut block.done, &mut eps);
                block.n = pop;
                replay_b.push_batch(pop, &block.obs, &block.act, &block.rew, &block.next_obs,
                                    &block.done);
                block.reset();
            }
        });
        results.push(r_batched.clone());

        let s_sps = steps_per_sec(pop, r_scalar.mean_ms);
        let b_sps = steps_per_sec(pop, r_batched.mean_ms);
        pop_rows.push(obj(vec![
            ("pop", num(pop as f64)),
            ("scalar_steps_per_sec", num(s_sps)),
            ("batched_steps_per_sec", num(b_sps)),
            ("speedup", num(b_sps / s_sps)),
        ]));
    }

    // ---- matvec kernel A/B: sparsity skip vs branch-free dense -----------
    let mut rng = Rng::new(7);
    let (ki, ko) = (HIDDEN[0], HIDDEN[1]);
    let mut w = vec![0.0f32; ki * ko];
    let mut b = vec![0.0f32; ko];
    rng.fill_uniform(&mut w, -0.1, 0.1);
    rng.fill_uniform(&mut b, -0.1, 0.1);
    // dense input: normalized observations never land on exactly 0.0
    let mut x_dense = vec![0.0f32; ki];
    rng.fill_uniform(&mut x_dense, 0.001, 1.0);
    // post-relu input: roughly half the lanes dead
    let mut x_relu = vec![0.0f32; ki];
    rng.fill_normal(&mut x_relu, 1.0);
    for v in x_relu.iter_mut() {
        *v = v.max(0.0);
    }
    let mut dst = vec![0.0f32; ko];
    let mut sink = 0.0f64;
    let mut kernel_rows: Vec<(String, f64)> = Vec::new();
    for (input_name, x) in [("dense_input", &x_dense), ("relu_input", &x_relu)] {
        for kernel in ["sparse_skip", "dense", "tiled"] {
            let name = format!("matvec_{kernel}_{input_name}");
            let r = bench.run(&name, || {
                for _ in 0..1000 {
                    match kernel {
                        "sparse_skip" => {
                            matvec_sparse(&w, &b, x, &mut dst, ki, ko, Activation::Relu)
                        }
                        "dense" => matvec_dense(&w, &b, x, &mut dst, ki, ko, Activation::Relu),
                        // the register-tiled matmat at rows=1: what the
                        // block path runs when a member owns one row
                        _ => matmat_tiled(&w, &b, x, &mut dst, ki, ko, 1, Activation::Relu),
                    }
                    sink += dst[0] as f64;
                }
            });
            kernel_rows.push((name.clone(), r.mean_ms));
            results.push(r);
        }
    }

    report("actor_throughput", &results)?;

    println!("\nActor steps/sec (batched vs scalar):");
    println!("{:>5} {:>14} {:>14} {:>9}", "pop", "scalar", "batched", "speedup");
    for row in &pop_rows {
        let g = |k: &str| row.get(k).and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
        println!(
            "{:>5} {:>14.0} {:>14.0} {:>8.2}x",
            g("pop"),
            g("scalar_steps_per_sec"),
            g("batched_steps_per_sec"),
            g("speedup")
        );
    }
    println!("(matvec checksum {sink:.3})");

    let json = obj(vec![
        ("bench", s("actor_throughput")),
        ("env", s(ENV)),
        ("hidden", arr(HIDDEN.iter().map(|&h| num(h as f64)).collect())),
        ("steps_per_iter", num(STEPS_PER_ITER as f64)),
        ("results", arr(pop_rows)),
        (
            "matvec_kernel_ms",
            obj(kernel_rows
                .iter()
                .map(|(n, ms)| (n.as_str(), num(*ms)))
                .collect()),
        ),
    ]);
    std::fs::write("BENCH_actor_throughput.json", format!("{json}\n"))?;
    println!("-> BENCH_actor_throughput.json");
    Ok(())
}
