//! Fig 4: runtime of one shared-critic TD3 update round vs population
//! size — the original CEM-RL sequential interleaving ("seq", which
//! cannot vectorize over the population because each critic update
//! depends on the previous agent's policy update) against the paper's
//! §4.2 vectorizable modification ("vec"). One round = P critic updates +
//! P policy updates in both variants (same data budget).

use fastpbrl::bench_support::data::{available_pops, random_batches, require_artifacts};
use fastpbrl::bench_support::harness::{report, Bench, BenchResult};
use fastpbrl::manifest::Manifest;
use fastpbrl::runtime::{Runtime, TrainState};
use fastpbrl::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let rt = Runtime::cpu()?;
    let bench = if std::env::var("BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench { warmup_iters: 2, iters: 10, max_seconds: 25.0 }
    };
    let mut rng = Rng::new(0);
    let mut results: Vec<BenchResult> = Vec::new();

    let env = "halfcheetah";
    let mut pops = available_pops(&manifest, "cem", env, 1);
    let pops_seq = available_pops(&manifest, "cemseq", env, 1);
    pops.retain(|p| pops_seq.contains(p));
    if !require_artifacts(&pops, "cem+cemseq/halfcheetah") {
        return Ok(());
    }

    for &pop in &pops {
        for algo in ["cem", "cemseq"] {
            let art = manifest.find(algo, env, pop, Some(1))?;
            let exe = rt.load(art)?;
            let mut ts = TrainState::init(&rt, art, &mut rng, 3)?;
            let batches = random_batches(&rt, art, &mut rng)?;
            let refs: Vec<&xla::PjRtBuffer> = batches.iter().collect();
            results.push(bench.run(&format!("{algo}_round_p{pop}"), || {
                ts.step(&exe, &refs).unwrap();
                let _ = ts.fence().unwrap();
            }));
        }
    }
    report("fig4_shared_critic", &results)?;

    println!("\nVectorized (\u{a7}4.2) speedup over the original sequential ordering:");
    println!("{:>5} {:>12} {:>12} {:>10}", "pop", "seq_ms", "vec_ms", "speedup");
    for &pop in &pops {
        let get = |n: String| results.iter().find(|r| r.name == n).map(|r| r.mean_ms);
        if let (Some(s), Some(v)) = (
            get(format!("cemseq_round_p{pop}")),
            get(format!("cem_round_p{pop}")),
        ) {
            println!("{:>5} {:>12.3} {:>12.3} {:>9.2}x", pop, s, v, s / v);
        }
    }
    Ok(())
}
