//! Shared-replay ingest A/B: every pusher thread behind one mutex (the
//! single shared buffer's contention profile) vs one stripe per pusher
//! thread ([`ShardedReplay`]), at pop ∈ {4, 16, 64}.
//!
//! Both configurations run the identical workload through the identical
//! [`StripeSink`] ingest path — T threads each pushing pre-filled
//! transport blocks, then a joint length-weighted sampling pass over
//! whatever landed — so the measured difference is exactly the lock
//! contention a single stripe serializes and N stripes remove.
//!
//! No artifacts required. Results go to
//! `results/replay_shard_throughput.csv` and
//! `BENCH_replay_shard_throughput.json`.

use std::sync::Arc;
use std::thread;

use fastpbrl::bench_support::harness::{report, Bench, BenchResult};
use fastpbrl::data::pipeline::{RowSink, TransitionBlock};
use fastpbrl::manifest::Dtype;
use fastpbrl::replay::{Replay, ReplayBuffer, ShardedReplay, Staging};
use fastpbrl::util::json::{arr, num, obj, s, Json};
use fastpbrl::util::rng::Rng;

const OD: usize = 16;
const AD: usize = 4;
const THREADS: usize = 4;
const BLOCKS_PER_THREAD: usize = 256;
const SAMPLE_BATCHES: usize = 64;
const BATCH: usize = 64;
const CAP: usize = 1 << 16;
const POPS: [usize; 3] = [4, 16, 64];

/// One transport block of `pop` rows with synthetic payload (the ingest
/// path never looks at the values, only moves them).
fn filled_block(thread: usize, pop: usize, rng: &mut Rng) -> TransitionBlock {
    let agents: Vec<usize> = (0..pop).collect();
    let mut b = TransitionBlock::new(thread, &agents, OD, AD);
    rng.fill_uniform(&mut b.obs, -1.0, 1.0);
    rng.fill_uniform(&mut b.act, -1.0, 1.0);
    rng.fill_uniform(&mut b.rew, -1.0, 1.0);
    rng.fill_uniform(&mut b.next_obs, -1.0, 1.0);
    b.n = pop;
    b
}

/// Run one configuration: `stripes` ingest stripes fed by [`THREADS`]
/// pusher threads, then [`SAMPLE_BATCHES`] joint samples. Returns the
/// harness result plus ingest rows/sec.
fn run_config(bench: &Bench, name: &str, stripes: usize, pop: usize) -> (BenchResult, f64) {
    let stripe_cap = CAP.div_ceil(stripes).max(1);
    let sharded = ShardedReplay::new(
        (0..stripes).map(|_| ReplayBuffer::new(stripe_cap, OD, AD)).collect::<Vec<_>>(),
    );
    let sinks: Vec<_> = (0..THREADS).map(|t| sharded.sink_for_thread(t)).collect();
    let mut rng = Rng::new(11 + pop as u64 * 31 + stripes as u64);
    let blocks: Vec<Arc<TransitionBlock>> =
        (0..THREADS).map(|t| Arc::new(filled_block(t, pop, &mut rng))).collect();
    let mut staging = Staging::new(
        &[
            (Dtype::F32, BATCH * OD),
            (Dtype::F32, BATCH * AD),
            (Dtype::F32, BATCH),
            (Dtype::F32, BATCH * OD),
            (Dtype::F32, BATCH),
        ],
        1,
    );
    let mut sample_rng = Rng::new(7);
    let result = bench.run(name, || {
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let sink = sinks[t].clone();
                let block = Arc::clone(&blocks[t]);
                thread::spawn(move || {
                    for _ in 0..BLOCKS_PER_THREAD {
                        sink.push_rows(&block, 0, block.n);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for _ in 0..SAMPLE_BATCHES {
            sharded.sample_slot(&mut sample_rng, BATCH, &mut staging, 0);
        }
    });
    let rows_per_sec =
        (THREADS * BLOCKS_PER_THREAD * pop) as f64 / (result.mean_ms / 1e3);
    (result, rows_per_sec)
}

fn main() -> anyhow::Result<()> {
    let bench = if std::env::var("BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench { warmup_iters: 2, iters: 12, max_seconds: 20.0 }
    };
    let mut results: Vec<BenchResult> = Vec::new();
    let mut pop_rows: Vec<Json> = Vec::new();
    let mut table: Vec<(usize, f64, f64)> = Vec::new();

    for &pop in &POPS {
        let (r_single, single) =
            run_config(&bench, &format!("ingest_single_p{pop}"), 1, pop);
        let (r_striped, striped) =
            run_config(&bench, &format!("ingest_striped{THREADS}_p{pop}"), THREADS, pop);
        results.push(r_single);
        results.push(r_striped);
        pop_rows.push(obj(vec![
            ("pop", num(pop as f64)),
            ("threads", num(THREADS as f64)),
            ("single_rows_per_sec", num(single)),
            ("striped_rows_per_sec", num(striped)),
            ("speedup", num(striped / single)),
        ]));
        table.push((pop, single, striped));
    }

    report("replay_shard_throughput", &results)?;

    println!("\nReplay ingest rows/sec ({THREADS} pusher threads, striped vs single):");
    println!("{:>5} {:>14} {:>14} {:>9}", "pop", "single", "striped", "speedup");
    for (pop, single, striped) in &table {
        println!("{pop:>5} {single:>14.0} {striped:>14.0} {:>8.2}x", striped / single);
    }

    let json = obj(vec![
        ("bench", s("replay_shard_throughput")),
        ("obs_dim", num(OD as f64)),
        ("act_dim", num(AD as f64)),
        ("threads", num(THREADS as f64)),
        ("blocks_per_thread", num(BLOCKS_PER_THREAD as f64)),
        ("sample_batches", num(SAMPLE_BATCHES as f64)),
        ("results", arr(pop_rows)),
    ]);
    std::fs::write("BENCH_replay_shard_throughput.json", format!("{json}\n"))?;
    println!("-> BENCH_replay_shard_throughput.json");
    Ok(())
}
