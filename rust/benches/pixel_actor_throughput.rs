//! Pixel/DQN actor-path throughput: a scalar baseline — per-agent
//! ConvNet dispatch + per-transition f32 frame clones through a message
//! queue, i.e. what a thread-split actor/learner port of the old inline
//! `examples/dqn_minatar.rs` loop would do with the pre-vectorization
//! transport (the same baseline shape as `actor_throughput.rs`'s
//! continuous A/B; the old inline loop itself was single-threaded and
//! pushed slices directly, paying no transport at all but also
//! overlapping nothing) — vs the population-batched PopConvNet +
//! PixelVecEnv + PixelTransitionBlock path, at pop ∈ {1, 4, 16, 64}.
//!
//! Both paths run the same epsilon-greedy policy over the same MinAtar
//! Breakout envs (artifact-sized net: conv 16x3x3 + fc 128) and end in
//! per-agent `PixelReplayBuffer`s, so the measured difference is exactly
//! the actor hot path: per-agent dispatch + two f32 frame clones per step
//! vs one blocked conv forward, one batched env step, and u8-quantized
//! `push_batch` runs.
//!
//! Also A/Bs the conv kernels (sparsity-skipping direct loop vs im2col +
//! tiled matmat) on real env frames (sparse binary planes) and dense
//! worst-case frames — the two regimes `conv_block_choice` splits on.
//!
//! No artifacts required. Results go to
//! `results/pixel_actor_throughput.csv` and
//! `BENCH_pixel_actor_throughput.json`.

use std::collections::VecDeque;

use fastpbrl::bench_support::harness::{report, Bench, BenchResult};
use fastpbrl::data::pipeline::{quantize_frames, PixelTransitionBlock};
use fastpbrl::envs::pixel_vec_env::PixelVecEnv;
use fastpbrl::envs::{make_pixel_env, PixelEnv};
use fastpbrl::nn::pop_mlp::PopMlp;
use fastpbrl::nn::{Activation, ConvNet, Mlp, PopConvNet};
use fastpbrl::replay::PixelReplayBuffer;
use fastpbrl::util::json::{arr, num, obj, s, Json};
use fastpbrl::util::rng::Rng;
use fastpbrl::util::stats::argmax;

const ENV: &str = "breakout";
const K: usize = 3;
const FEATURES: usize = 16;
const FC: usize = 128;
const EPS: f64 = 0.05;
const STEPS_PER_ITER: usize = 64;
const REPLAY_CAP: usize = 1 << 14;
const POPS: [usize; 4] = [1, 4, 16, 64];

/// The old transport unit: two f32 frame clones per step.
struct OldPixelTransition {
    obs: Vec<f32>,
    act: usize,
    rew: f32,
    next_obs: Vec<f32>,
    done: bool,
}

struct Member {
    cw: Vec<f32>,
    cb: Vec<f32>,
    head: Vec<(Vec<f32>, Vec<f32>)>,
}

fn random_members(rng: &mut Rng, pop: usize, c: usize, head_dims: &[usize]) -> Vec<Member> {
    (0..pop)
        .map(|_| {
            let fan_in = (K * K * c) as f32;
            let bound = (3.0 / fan_in).sqrt();
            let mut cw = vec![0.0f32; K * K * c * FEATURES];
            let mut cb = vec![0.0f32; FEATURES];
            rng.fill_uniform(&mut cw, -bound, bound);
            rng.fill_uniform(&mut cb, -0.05, 0.05);
            let head = head_dims
                .windows(2)
                .map(|d| {
                    let hb = (3.0 / d[0] as f32).sqrt();
                    let mut w = vec![0.0f32; d[0] * d[1]];
                    let mut b = vec![0.0f32; d[1]];
                    rng.fill_uniform(&mut w, -hb, hb);
                    rng.fill_uniform(&mut b, -0.05, 0.05);
                    (w, b)
                })
                .collect();
            Member { cw, cb, head }
        })
        .collect()
}

fn steps_per_sec(pop: usize, mean_ms: f64) -> f64 {
    (STEPS_PER_ITER * pop) as f64 / (mean_ms / 1e3)
}

fn main() -> anyhow::Result<()> {
    let bench = if std::env::var("BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench { warmup_iters: 2, iters: 15, max_seconds: 20.0 }
    };
    let mut results: Vec<BenchResult> = Vec::new();
    let mut pop_rows: Vec<Json> = Vec::new();

    let probe = make_pixel_env(ENV)?;
    let (h, w, c) = probe.frame();
    let n_actions = probe.n_actions();
    drop(probe);
    let frame_len = h * w * c;
    let flat = (h - K + 1) * (w - K + 1) * FEATURES;
    let head_dims = [flat, FC, n_actions];

    for &pop in &POPS {
        let mut rng = Rng::new(200 + pop as u64);
        let members = random_members(&mut rng, pop, c, &head_dims);

        // ---- scalar path: per-agent ConvNet + per-transition pushes ------
        let mut nets: Vec<ConvNet> = members
            .iter()
            .map(|m| {
                let mut head = Mlp::new(Activation::Relu, Activation::None);
                for (li, d) in head_dims.windows(2).enumerate() {
                    head.push_layer(m.head[li].0.clone(), m.head[li].1.clone(), d[0], d[1]);
                }
                ConvNet::new(m.cw.clone(), m.cb.clone(), K, K, c, FEATURES, h, w, head)
            })
            .collect();
        let mut envs: Vec<_> = (0..pop).map(|_| make_pixel_env(ENV).unwrap()).collect();
        let mut obs_rows: Vec<Vec<f32>> = envs
            .iter_mut()
            .map(|e| {
                let mut o = vec![0.0f32; frame_len];
                e.reset(&mut rng, &mut o);
                o
            })
            .collect();
        let mut ep_steps = vec![0usize; pop];
        let mut q = vec![0.0f32; n_actions];
        let mut next = vec![0.0f32; frame_len];
        let mut queue: VecDeque<OldPixelTransition> = VecDeque::new();
        let mut replays: Vec<PixelReplayBuffer> =
            (0..pop).map(|_| PixelReplayBuffer::new(REPLAY_CAP, frame_len)).collect();
        let r_scalar = bench.run(&format!("pixel_actor_scalar_p{pop}"), || {
            for _ in 0..STEPS_PER_ITER {
                for k in 0..pop {
                    let action = if rng.uniform() < EPS {
                        rng.below(n_actions)
                    } else {
                        nets[k].forward(&obs_rows[k], &mut q);
                        argmax(&q)
                    };
                    let (rew, done) = envs[k].step(action, &mut rng, &mut next);
                    ep_steps[k] += 1;
                    let horizon_hit = ep_steps[k] >= envs[k].horizon();
                    // the old transport: f32 frame clones into a message
                    queue.push_back(OldPixelTransition {
                        obs: obs_rows[k].clone(),
                        act: action,
                        rew,
                        next_obs: next.clone(),
                        done,
                    });
                    obs_rows[k].copy_from_slice(&next);
                    if done || horizon_hit {
                        ep_steps[k] = 0;
                        envs[k].reset(&mut rng, &mut obs_rows[k]);
                    }
                }
                // per-transition pushes, one agent at a time (round-robin
                // order matches the block path's row order)
                let mut agent = 0;
                while let Some(t) = queue.pop_front() {
                    replays[agent].push(&t.obs, t.act, t.rew, &t.next_obs, t.done);
                    agent = (agent + 1) % pop;
                }
            }
        });
        results.push(r_scalar.clone());

        // ---- batched path: PopConvNet + PixelVecEnv + block transport ----
        let mut head = PopMlp::new(pop, Activation::Relu, Activation::None);
        for (li, d) in head_dims.windows(2).enumerate() {
            let mut hw = Vec::with_capacity(pop * d[0] * d[1]);
            let mut hb = Vec::with_capacity(pop * d[1]);
            for m in &members {
                hw.extend_from_slice(&m.head[li].0);
                hb.extend_from_slice(&m.head[li].1);
            }
            head.push_layer(hw, hb, d[0], d[1]);
        }
        let mut cw = Vec::with_capacity(pop * K * K * c * FEATURES);
        let mut cb = Vec::with_capacity(pop * FEATURES);
        for m in &members {
            cw.extend_from_slice(&m.cw);
            cb.extend_from_slice(&m.cb);
        }
        let mut pop_net = PopConvNet::new(pop, cw, cb, K, K, c, FEATURES, h, w, head);
        let ids: Vec<usize> = (0..pop).collect();
        let mut venv = PixelVecEnv::new(ENV, pop)?;
        venv.reset_all(&mut rng);
        let mut block = PixelTransitionBlock::new(0, &ids, frame_len);
        let mut qb = vec![0.0f32; pop * n_actions];
        let mut acts = vec![0usize; pop];
        let mut next_b = vec![0.0f32; pop * frame_len];
        let mut eps_ends = Vec::new();
        let mut replays_b: Vec<PixelReplayBuffer> =
            (0..pop).map(|_| PixelReplayBuffer::new(REPLAY_CAP, frame_len)).collect();
        let r_batched = bench.run(&format!("pixel_actor_batched_p{pop}"), || {
            for _ in 0..STEPS_PER_ITER {
                pop_net.forward_block(&ids, venv.obs(), &mut qb);
                for (k, a) in acts.iter_mut().enumerate() {
                    *a = if rng.uniform() < EPS {
                        rng.below(n_actions)
                    } else {
                        argmax(&qb[k * n_actions..(k + 1) * n_actions])
                    };
                }
                quantize_frames(venv.obs(), &mut block.obs);
                for (d, &a) in block.act.iter_mut().zip(&acts) {
                    *d = a as i32;
                }
                eps_ends.clear();
                venv.step_into(&mut rng, &acts, &mut next_b, &mut block.rew, &mut block.done,
                               &mut eps_ends);
                quantize_frames(&next_b, &mut block.next_obs);
                block.n = pop;
                for k in 0..pop {
                    let agent = block.agents[k];
                    replays_b[agent].push_batch(
                        1,
                        &block.obs[k * frame_len..(k + 1) * frame_len],
                        &block.act[k..k + 1],
                        &block.rew[k..k + 1],
                        &block.next_obs[k * frame_len..(k + 1) * frame_len],
                        &block.done[k..k + 1],
                    );
                }
                block.reset();
            }
        });
        results.push(r_batched.clone());

        let s_sps = steps_per_sec(pop, r_scalar.mean_ms);
        let b_sps = steps_per_sec(pop, r_batched.mean_ms);
        pop_rows.push(obj(vec![
            ("pop", num(pop as f64)),
            ("scalar_steps_per_sec", num(s_sps)),
            ("batched_steps_per_sec", num(b_sps)),
            ("speedup", num(b_sps / s_sps)),
        ]));
    }

    // ---- conv kernel A/B: direct (sparsity skip) vs im2col --------------
    let mut rng = Rng::new(9);
    let member = random_members(&mut rng, 1, c, &head_dims).remove(0);
    // real env frames: sparse binary MinAtar planes
    let mut env = make_pixel_env(ENV)?;
    let mut frame_env = vec![0.0f32; frame_len];
    env.reset(&mut rng, &mut frame_env);
    for _ in 0..20 {
        let action = rng.below(n_actions);
        let (_rew, done) = env.step(action, &mut rng, &mut frame_env);
        if done {
            env.reset(&mut rng, &mut frame_env);
        }
    }
    // dense frames: every lane live (the im2col regime)
    let mut frame_dense = vec![0.0f32; frame_len];
    rng.fill_uniform(&mut frame_dense, 0.001, 1.0);
    let mut conv_out = vec![0.0f32; flat];
    let mut scratch: Vec<f32> = Vec::new();
    let mut sink = 0.0f64;
    let mut kernel_rows: Vec<(String, f64)> = Vec::new();
    for (input_name, frame) in [("env_frame", &frame_env), ("dense_frame", &frame_dense)] {
        for kernel in ["direct", "im2col"] {
            let name = format!("conv_{kernel}_{input_name}");
            let r = bench.run(&name, || {
                for _ in 0..500 {
                    match kernel {
                        "direct" => fastpbrl::nn::kernels::conv2d_valid_relu(
                            &member.cw, &member.cb, frame, &mut conv_out, K, K, c, FEATURES, h, w,
                        ),
                        _ => fastpbrl::nn::kernels::conv2d_im2col_relu(
                            &member.cw,
                            &member.cb,
                            frame,
                            &mut conv_out,
                            &mut scratch,
                            K,
                            K,
                            c,
                            FEATURES,
                            h,
                            w,
                        ),
                    }
                    sink += conv_out[0] as f64;
                }
            });
            kernel_rows.push((name.clone(), r.mean_ms));
            results.push(r);
        }
    }
    println!("(conv checksum {sink:.3})");

    report("pixel_actor_throughput", &results)?;

    println!("\nPixel actor steps/sec (batched vs scalar):");
    println!("{:>5} {:>14} {:>14} {:>9}", "pop", "scalar", "batched", "speedup");
    for row in &pop_rows {
        let g = |k: &str| row.get(k).and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
        println!(
            "{:>5} {:>14.0} {:>14.0} {:>8.2}x",
            g("pop"),
            g("scalar_steps_per_sec"),
            g("batched_steps_per_sec"),
            g("speedup")
        );
    }

    let json = obj(vec![
        ("bench", s("pixel_actor_throughput")),
        ("env", s(ENV)),
        ("conv_features", num(FEATURES as f64)),
        ("fc", num(FC as f64)),
        ("steps_per_iter", num(STEPS_PER_ITER as f64)),
        ("results", arr(pop_rows)),
        (
            "conv_kernel_ms",
            obj(kernel_rows
                .iter()
                .map(|(n, ms)| (n.as_str(), num(*ms)))
                .collect()),
        ),
    ]);
    std::fs::write("BENCH_pixel_actor_throughput.json", format!("{json}\n"))?;
    println!("-> BENCH_pixel_actor_throughput.json");
    Ok(())
}
