//! Table 2: runtime (ms) of a single environment interaction — one policy
//! forward pass + one env step — for every locomotion task, with the
//! TD3 and SAC policy architectures (256x256, the paper's sizes).
//!
//! The paper measures MuJoCo Gym + a JIT-compiled jax policy on one Xeon
//! core (0.65–1.5 ms); here the env is our ODE substitute and the policy
//! is the native rust forward pass the actors actually use.

use fastpbrl::envs::make_env;
use fastpbrl::nn::mlp::{Activation, Mlp};
use fastpbrl::util::rng::Rng;
use fastpbrl::util::stats::Running;
use fastpbrl::util::timer::Stopwatch;

fn make_policy(rng: &mut Rng, obs_dim: usize, act_dim: usize, sac: bool) -> Mlp {
    let out_dim = if sac { 2 * act_dim } else { act_dim };
    let final_act = if sac { Activation::None } else { Activation::Tanh };
    let mut mlp = Mlp::new(Activation::Relu, final_act);
    let dims = [obs_dim, 256, 256, out_dim];
    for win in dims.windows(2) {
        let (i, o) = (win[0], win[1]);
        let bound = (3.0 / i as f32).sqrt();
        let mut w = vec![0.0f32; i * o];
        let mut b = vec![0.0f32; o];
        rng.fill_uniform(&mut w, -bound, bound);
        rng.fill_uniform(&mut b, -bound, bound);
        mlp.push_layer(w, b, i, o);
    }
    mlp
}

fn main() -> anyhow::Result<()> {
    let envs = ["halfcheetah", "swimmer", "walker2d", "humanoid", "hopper", "ant"];
    let steps = if std::env::var("BENCH_QUICK").is_ok() { 300 } else { 2000 };
    let mut rng = Rng::new(0);

    std::fs::create_dir_all("results")?;
    let mut csv = String::from("env,algo,mean_ms,std_ms\n");
    println!("Table 2 — per-interaction runtime (ms): policy forward + env step");
    println!("{:<14} {:>14} {:>14}", "env", "TD3", "SAC");
    for name in envs {
        let mut row = format!("{name:<14}");
        for sac in [false, true] {
            let mut env = make_env(name)?;
            let (od, ad) = (env.obs_dim(), env.act_dim());
            let mut policy = make_policy(&mut rng, od, ad, sac);
            let mut obs = vec![0.0f32; od];
            let mut raw = vec![0.0f32; policy.out_dim()];
            let mut act = vec![0.0f32; ad];
            env.reset(&mut rng, &mut obs);
            let mut stats = Running::new();
            let mut t = 0usize;
            for _ in 0..steps {
                let sw = Stopwatch::start();
                policy.forward(&obs, &mut raw);
                for (a, &r) in act.iter_mut().zip(&raw) {
                    *a = if sac { r.tanh() } else { r };
                }
                let (_, done) = env.step(&act, &mut obs);
                stats.push(sw.elapsed_ms());
                t += 1;
                if done || t >= env.horizon() {
                    env.reset(&mut rng, &mut obs);
                    t = 0;
                }
            }
            row.push_str(&format!(" {:>7.4} ±{:<5.4}", stats.mean(), stats.std()));
            csv.push_str(&format!(
                "{name},{},{:.5},{:.5}\n",
                if sac { "sac" } else { "td3" },
                stats.mean(),
                stats.std()
            ));
        }
        println!("{row}");
    }
    std::fs::write("results/table2_env_step.csv", csv)?;
    println!("-> results/table2_env_step.csv");
    println!(
        "\n(paper Table 2 reports 0.65–1.5 ms on MuJoCo; the ODE substitute is \
         faster, which only relaxes the data-collection constraint of Appendix A)"
    );
    Ok(())
}
