//! Kernel-layer throughput: reference (per-row matvec) vs register-tiled
//! matmat on the paper-sized 256x256 layer at block rows = pop
//! ∈ {1, 4, 16, 64}, and direct (sparsity-skipping) vs im2col conv on a
//! MinAtar-sized frame (10x10x4, 3x3 kernel, 16 features) for both the
//! sparse binary planes the envs emit and dense worst-case frames.
//!
//! The figure of merit is GFLOP/s per kernel variant (one fused
//! multiply-add = 2 flops), which makes the autovectorization win
//! directly visible: the tiled kernel should approach the machine's FMA
//! peak while the reference row loop stays scalar-bound.
//!
//! No artifacts required. Results go to `results/kernel_throughput.csv`
//! and `BENCH_kernel_throughput.json`.

use fastpbrl::bench_support::harness::{gflops, report, Bench, BenchResult};
use fastpbrl::nn::kernels::{
    conv2d_im2col_relu, conv2d_valid_relu, matmat_reference, matmat_tiled,
};
use fastpbrl::nn::Activation;
use fastpbrl::util::json::{arr, num, obj, s, Json};
use fastpbrl::util::rng::Rng;

const DIM: usize = 256; // paper-sized hidden layer
const POPS: [usize; 4] = [1, 4, 16, 64];
const MAT_REPS: usize = 200;
const CONV_REPS: usize = 500;

// MinAtar-sized conv problem (10x10 board, 4 planes, 3x3 HWIO filter).
const FRAME: (usize, usize, usize) = (10, 10, 4);
const K: usize = 3;
const FEATS: usize = 16;

fn main() -> anyhow::Result<()> {
    let bench = if std::env::var("BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench { warmup_iters: 2, iters: 15, max_seconds: 20.0 }
    };
    let mut results: Vec<BenchResult> = Vec::new();
    let mut sink = 0.0f64;

    // ---- matmat: reference row loop vs register-tiled -------------------
    let mut rng = Rng::new(11);
    let mut w = vec![0.0f32; DIM * DIM];
    let mut b = vec![0.0f32; DIM];
    rng.fill_uniform(&mut w, -0.1, 0.1);
    rng.fill_uniform(&mut b, -0.1, 0.1);
    let mut mat_rows: Vec<Json> = Vec::new();
    for &pop in &POPS {
        // dense activations (the post-layernorm/tanh regime: no zeros)
        let mut x = vec![0.0f32; pop * DIM];
        rng.fill_uniform(&mut x, 0.001, 1.0);
        let mut dst = vec![0.0f32; pop * DIM];
        let flops = (2 * pop * DIM * DIM * MAT_REPS) as f64;
        let mut variant_gflops: Vec<(&str, f64)> = Vec::new();
        for kernel in ["reference", "tiled"] {
            let name = format!("matmat_{kernel}_p{pop}");
            let r = bench.run(&name, || {
                for _ in 0..MAT_REPS {
                    match kernel {
                        "reference" => matmat_reference(
                            &w, &b, &x, &mut dst, DIM, DIM, pop, Activation::Relu,
                        ),
                        _ => matmat_tiled(&w, &b, &x, &mut dst, DIM, DIM, pop, Activation::Relu),
                    }
                    sink += dst[0] as f64;
                }
            });
            variant_gflops.push((kernel, gflops(flops, r.mean_ms)));
            results.push(r);
        }
        let (rg, tg) = (variant_gflops[0].1, variant_gflops[1].1);
        mat_rows.push(obj(vec![
            ("pop", num(pop as f64)),
            ("reference_gflops", num(rg)),
            ("tiled_gflops", num(tg)),
            ("speedup", num(if rg > 0.0 { tg / rg } else { 0.0 })),
        ]));
    }

    // ---- conv: direct (sparsity skip) vs im2col + tiled matmat ----------
    let (h, wd, c) = FRAME;
    let (ho, wo) = (h - K + 1, wd - K + 1);
    let fl = h * wd * c;
    let mut cw = vec![0.0f32; K * K * c * FEATS];
    let mut cb = vec![0.0f32; FEATS];
    rng.fill_uniform(&mut cw, -0.3, 0.3);
    rng.fill_uniform(&mut cb, -0.1, 0.1);
    // sparse: MinAtar-like binary planes, ~85% zeros
    let mut frame_sparse = vec![0.0f32; fl];
    for v in frame_sparse.iter_mut() {
        *v = (rng.below(7) == 0) as u8 as f32;
    }
    // dense: every lane live (the im2col kernel's home turf)
    let mut frame_dense = vec![0.0f32; fl];
    rng.fill_uniform(&mut frame_dense, 0.001, 1.0);
    let mut out = vec![0.0f32; ho * wo * FEATS];
    let mut scratch: Vec<f32> = Vec::new();
    let conv_flops = (2 * ho * wo * K * K * c * FEATS * CONV_REPS) as f64;
    let mut conv_rows: Vec<Json> = Vec::new();
    for (input_name, frame) in [("sparse_frame", &frame_sparse), ("dense_frame", &frame_dense)] {
        let mut variant_gflops: Vec<(&str, f64)> = Vec::new();
        for kernel in ["direct", "im2col"] {
            let name = format!("conv_{kernel}_{input_name}");
            let r = bench.run(&name, || {
                for _ in 0..CONV_REPS {
                    match kernel {
                        "direct" => {
                            conv2d_valid_relu(&cw, &cb, frame, &mut out, K, K, c, FEATS, h, wd)
                        }
                        _ => conv2d_im2col_relu(
                            &cw,
                            &cb,
                            frame,
                            &mut out,
                            &mut scratch,
                            K,
                            K,
                            c,
                            FEATS,
                            h,
                            wd,
                        ),
                    }
                    sink += out[0] as f64;
                }
            });
            variant_gflops.push((kernel, gflops(conv_flops, r.mean_ms)));
            results.push(r);
        }
        let (dg, ig) = (variant_gflops[0].1, variant_gflops[1].1);
        conv_rows.push(obj(vec![
            ("input", s(input_name)),
            ("direct_gflops", num(dg)),
            ("im2col_gflops", num(ig)),
            ("speedup", num(if dg > 0.0 { ig / dg } else { 0.0 })),
        ]));
    }

    report("kernel_throughput", &results)?;

    println!("\nmatmat GFLOP/s ({DIM}x{DIM}, rows = pop):");
    println!("{:>5} {:>12} {:>12} {:>9}", "pop", "reference", "tiled", "speedup");
    for row in &mat_rows {
        let g = |k: &str| row.get(k).and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
        println!(
            "{:>5} {:>12.2} {:>12.2} {:>8.2}x",
            g("pop"),
            g("reference_gflops"),
            g("tiled_gflops"),
            g("speedup")
        );
    }
    println!("(checksum {sink:.3})");

    let json = obj(vec![
        ("bench", s("kernel_throughput")),
        ("dim", num(DIM as f64)),
        (
            "frame",
            arr(vec![num(h as f64), num(wd as f64), num(c as f64)]),
        ),
        ("features", num(FEATS as f64)),
        ("matmat", arr(mat_rows)),
        ("conv", arr(conv_rows)),
    ]);
    std::fs::write("BENCH_kernel_throughput.json", format!("{json}\n"))?;
    println!("-> BENCH_kernel_throughput.json");
    Ok(())
}
