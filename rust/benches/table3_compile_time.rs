//! Table 3: initial compilation time for a population-20 update step
//! (TD3 + SAC). In the paper this is jax JIT compilation on each GPU; in
//! this stack the analogue is PJRT compilation of the AOT-lowered HLO at
//! artifact load (jax tracing/lowering already happened at `make
//! artifacts` and its time is recorded in the manifest as
//! `lower_seconds`).

use fastpbrl::manifest::Manifest;
use fastpbrl::runtime::Runtime;
use fastpbrl::util::stats::Running;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let reps = if std::env::var("BENCH_QUICK").is_ok() { 1 } else { 3 };

    std::fs::create_dir_all("results")?;
    let mut csv = String::from("algo,pop,compile_s_mean,compile_s_std\n");
    println!("Table 3 — initial compilation time (s), largest available pops:");
    println!("{:<8} {:>5} {:>16}", "algo", "pop", "compile_s");
    for algo in ["td3", "sac", "dqn", "cem", "cemseq"] {
        // largest pop available for the canonical env
        let art = manifest
            .artifacts
            .values()
            .filter(|a| a.algo == algo && a.output == "state" && a.num_steps == 1)
            .max_by_key(|a| a.pop);
        let Some(art) = art else { continue };
        let mut stats = Running::new();
        for _ in 0..reps {
            // fresh Runtime each rep: defeat the executable cache so we
            // measure a cold compile, as the paper does
            let rt = Runtime::cpu()?;
            let exe = rt.load(art)?;
            stats.push(exe.compile_seconds);
        }
        println!("{:<8} {:>5} {:>11.2} ±{:.2}", algo, art.pop, stats.mean(), stats.std());
        csv.push_str(&format!("{algo},{},{:.3},{:.3}\n", art.pop, stats.mean(),
                              stats.std()));
    }
    std::fs::write("results/table3_compile_time.csv", csv)?;
    println!("-> results/table3_compile_time.csv");
    println!(
        "\n(paper Table 3: 4.8–9.5 s on K80..A100 for pop 20 with 50 chained \
         steps; jax lower times for our artifacts are in artifacts/manifest.json)"
    );
    Ok(())
}
