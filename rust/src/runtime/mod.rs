//! PJRT runtime: load AOT artifacts, compile once, execute from the hot
//! path with device-resident train state.

pub mod checkpoint;
pub mod client;
pub mod runstate;
pub mod state;
pub mod watchdog;

pub use client::{classify_fault, Executable, FaultKind, Runtime};
pub use state::TrainState;
