//! PJRT runtime: load AOT artifacts, compile once, execute from the hot
//! path with device-resident train state.

pub mod checkpoint;
pub mod client;
pub mod state;

pub use client::{Executable, Runtime};
pub use state::TrainState;
