//! Device-resident train state.
//!
//! The flat-state design (DESIGN.md) means the whole population's training
//! state is ONE f32 buffer. `TrainState` keeps it on device and chains it
//! through update calls (`execute_b`), so parameters never touch host
//! memory between update steps — the paper's "multiple update steps
//! without copying back" optimization. Host copies are made only for
//! parameter syncs to the actors and PBT/CEM evolution points.

use crate::manifest::Artifact;
use crate::runtime::client::{Executable, Runtime};
use crate::util::rng::Rng;

pub struct TrainState {
    pub artifact: Artifact,
    /// Device-resident flat state; `None` transiently during swap.
    buf: Option<xla::PjRtBuffer>,
    /// Updates applied since creation.
    pub updates_done: u64,
}

impl TrainState {
    /// Initialize on host per the manifest init specs, then upload.
    pub fn init(rt: &Runtime, artifact: &Artifact, rng: &mut Rng, seed_tag: u64)
                -> anyhow::Result<TrainState> {
        let host = artifact.init_state(rng, seed_tag);
        Self::from_host(rt, artifact, &host)
    }

    pub fn from_host(rt: &Runtime, artifact: &Artifact, host: &[f32])
                     -> anyhow::Result<TrainState> {
        anyhow::ensure!(
            host.len() == artifact.state_size,
            "state size mismatch: host {} vs manifest {}",
            host.len(),
            artifact.state_size
        );
        let buf = rt.upload_f32(host, &[artifact.state_size])?;
        Ok(TrainState { artifact: artifact.clone(), buf: Some(buf), updates_done: 0 })
    }

    pub fn buffer(&self) -> &xla::PjRtBuffer {
        self.buf.as_ref().expect("train state buffer present")
    }

    /// Run one update-step execution (which may contain `num_steps`
    /// chained steps) and adopt the output as the new state.
    pub fn step(&mut self, exe: &Executable, batches: &[&xla::PjRtBuffer])
                -> anyhow::Result<()> {
        let state = self.buf.take().expect("state buffer");
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + batches.len());
        args.push(&state);
        args.extend_from_slice(batches);
        let out = exe.run(&args)?;
        self.buf = Some(out);
        self.updates_done += exe.artifact.num_steps as u64;
        Ok(())
    }

    /// Download the full state to host (param sync / evolution points).
    pub fn to_host(&self) -> anyhow::Result<Vec<f32>> {
        Executable::download_f32(self.buffer())
    }

    /// Block until the pending update has completed on the device. Tries
    /// the one-element raw read first; the TFRT CPU client does not
    /// implement CopyRawToHost, so it falls back to a full literal sync
    /// (on CPU the "download" is a memcpy, a few percent of a step).
    pub fn fence(&self) -> anyhow::Result<f32> {
        let mut one = [0.0f32; 1];
        match self.buffer().copy_raw_to_host_sync(&mut one, 0) {
            Ok(()) => Ok(one[0]),
            Err(_) => {
                let lit = self
                    .buffer()
                    .to_literal_sync()
                    .map_err(|e| anyhow::anyhow!("fence: {e}"))?;
                lit.get_first_element::<f32>()
                    .map_err(|e| anyhow::anyhow!("fence: {e}"))
            }
        }
    }

    /// Replace the device state from a host copy (after PBT/CEM mutation).
    pub fn load_host(&mut self, rt: &Runtime, host: &[f32]) -> anyhow::Result<()> {
        anyhow::ensure!(host.len() == self.artifact.state_size, "state size mismatch");
        self.buf = Some(rt.upload_f32(host, &[self.artifact.state_size])?);
        Ok(())
    }

    /// Read one metric field (downloads the whole state; use sparingly —
    /// metrics are normally read from the periodic host sync).
    pub fn metric(&self, name: &str) -> anyhow::Result<Vec<f32>> {
        let host = self.to_host()?;
        Ok(self.artifact.read(&host, name)?.to_vec())
    }
}
