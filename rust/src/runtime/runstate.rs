//! Durable run state: a `run.json` next to the checkpoint lineage.
//!
//! The watchdog's restart contract is "relaunch the exact run" — but the
//! command line it was handed is only what the *first* launch looked
//! like. The trainer records the run's identity durably: its argv, the
//! lineage base, the seed, and a digest of the run-defining config
//! fields. On restart the watchdog prefers `run.json` over its own
//! remembered arguments, and a trainer launched into a run dir whose
//! recorded digest differs from its own config warns that the dir
//! belonged to a different run before overwriting.
//!
//! The file is written atomically (tmp + rename), same as checkpoints:
//! a crash mid-write leaves either the old `run.json` or none at all.

use std::path::{Path, PathBuf};

use crate::util::json::{arr, num, obj, s, Json};

/// Bumped whenever the `run.json` layout changes incompatibly; a
/// watchdog reading a newer (or older) schema falls back to the command
/// line instead of mis-parsing.
pub const RUN_STATE_SCHEMA: u64 = 1;

/// File name inside the run dir.
pub const RUN_STATE_FILE: &str = "run.json";

/// FNV-1a over arbitrary bytes, hex-encoded — the same cheap stable
/// hash the checkpoint format uses for integrity, here used to
/// fingerprint the run-defining config fields.
pub fn fnv1a_hex(bytes: &[u8]) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    format!("{h:016x}")
}

/// The durable identity of a training run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RunState {
    /// Layout version ([`RUN_STATE_SCHEMA`]).
    pub schema: u64,
    /// Full argv of the trainer process (`argv[0]` is the binary; the
    /// watchdog re-execs its own binary with `argv[1..]`).
    pub argv: Vec<String>,
    /// Checkpoint lineage base path the run saves to / resumes from.
    pub checkpoint_base: String,
    /// Population seed.
    pub seed: u64,
    /// Digest of the run-defining config fields
    /// (`TrainerConfig::config_digest`).
    pub config_digest: String,
}

impl RunState {
    /// Path of the `run.json` inside `run_dir`.
    pub fn path(run_dir: &Path) -> PathBuf {
        run_dir.join(RUN_STATE_FILE)
    }

    /// Atomically write `run.json` into `run_dir`.
    pub fn save(&self, run_dir: &Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(run_dir)?;
        let j = obj(vec![
            ("schema", num(self.schema as f64)),
            ("argv", arr(self.argv.iter().map(|a| s(a)).collect())),
            ("checkpoint_base", s(&self.checkpoint_base)),
            // Seeds are arbitrary u64s; a JSON number would silently lose
            // precision past 2^53, so the seed travels as a string.
            ("seed", s(&self.seed.to_string())),
            ("config_digest", s(&self.config_digest)),
        ]);
        let path = Self::path(run_dir);
        let tmp = run_dir.join(format!("{RUN_STATE_FILE}.tmp"));
        std::fs::write(&tmp, format!("{j}\n"))?;
        std::fs::rename(&tmp, &path)?;
        Ok(())
    }

    /// Read `run.json` from `run_dir`. `Ok(None)` when the file does not
    /// exist (a fresh run dir); `Err` when it exists but cannot be
    /// trusted (parse failure, unknown schema, missing fields).
    pub fn load(run_dir: &Path) -> anyhow::Result<Option<RunState>> {
        let path = Self::path(run_dir);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(anyhow::anyhow!("reading {path:?}: {e}")),
        };
        let j = Json::parse(text.trim())
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
        let schema = j
            .get("schema")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("{path:?}: missing schema"))?
            as u64;
        anyhow::ensure!(
            schema == RUN_STATE_SCHEMA,
            "{path:?}: schema {schema} (this build understands {RUN_STATE_SCHEMA})"
        );
        let argv = j
            .get("argv")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("{path:?}: missing argv"))?
            .iter()
            .map(|a| {
                a.as_str()
                    .map(String::from)
                    .ok_or_else(|| anyhow::anyhow!("{path:?}: non-string argv entry"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let field = |k: &str| -> anyhow::Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(String::from)
                .ok_or_else(|| anyhow::anyhow!("{path:?}: missing {k}"))
        };
        let checkpoint_base = field("checkpoint_base")?;
        let seed = field("seed")?
            .parse::<u64>()
            .map_err(|e| anyhow::anyhow!("{path:?}: bad seed: {e}"))?;
        let config_digest = field("config_digest")?;
        Ok(Some(RunState { schema, argv, checkpoint_base, seed, config_digest }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("fastpbrl_runstate_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample() -> RunState {
        RunState {
            schema: RUN_STATE_SCHEMA,
            argv: vec![
                "fastpbrl".into(),
                "train".into(),
                "--checkpoint".into(),
                "run/ckpt.bin".into(),
            ],
            checkpoint_base: "run/ckpt.bin".into(),
            seed: u64::MAX - 7, // past 2^53: exercises the string encoding
            config_digest: "00ff00ff00ff00ff".into(),
        }
    }

    #[test]
    fn roundtrips_through_disk() {
        let dir = tmp_dir("roundtrip");
        let rs = sample();
        rs.save(&dir).unwrap();
        let back = RunState::load(&dir).unwrap().unwrap();
        assert_eq!(back, rs);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_file_is_none() {
        let dir = tmp_dir("missing");
        assert!(RunState::load(&dir).unwrap().is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unknown_schema_is_an_error() {
        let dir = tmp_dir("schema");
        std::fs::write(
            RunState::path(&dir),
            r#"{"schema":99,"argv":[],"checkpoint_base":"","seed":"0","config_digest":""}"#,
        )
        .unwrap();
        assert!(RunState::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn garbage_is_an_error_not_a_fresh_dir() {
        let dir = tmp_dir("garbage");
        std::fs::write(RunState::path(&dir), "not json").unwrap();
        assert!(RunState::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fnv_digest_is_stable_and_content_sensitive() {
        let a = fnv1a_hex(b"env=pendulum seed=7");
        assert_eq!(a, fnv1a_hex(b"env=pendulum seed=7"));
        assert_ne!(a, fnv1a_hex(b"env=pendulum seed=8"));
        assert_eq!(a.len(), 16);
    }
}
