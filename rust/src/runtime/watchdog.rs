//! Out-of-process supervision: spawn the trainer as a child process,
//! watch its liveness, and restart it from the checkpoint lineage when
//! it crashes or stalls.
//!
//! This is the rung above the in-process fault-tolerance layer: actor
//! supervision and member quarantine survive faults *inside* the
//! trainer, the [`CheckpointLineage`](crate::runtime::checkpoint::CheckpointLineage)
//! survives faults *across* processes — and the watchdog is the agent
//! that actually performs the restart. It never parses training state
//! itself; the restart contract is simply "re-exec the trainer with the
//! same arguments", because `Trainer::new` already auto-resumes from the
//! lineage's `last_good` when `--checkpoint` names an existing base.
//!
//! Liveness is judged from three signals, newest wins:
//! - the child's exit status (`try_wait`),
//! - a heartbeat file the trainer touches from its learner loop
//!   ([`touch_heartbeat`]), and
//! - the telemetry JSONL stream's mtime as a fallback (the exporter
//!   appends a snapshot every `snapshot_secs` while the loop is alive).
//!
//! A child that runs but goes silent past `heartbeat_timeout_secs` is
//! killed and counted as a failure. Failures restart with the same
//! capped exponential backoff the actor supervisor uses
//! ([`RestartPolicy`]), bounded by a `max_process_restarts` budget —
//! and a crash *loop* (N consecutive deaths within seconds of launch:
//! bad config, missing artifacts, poisoned checkpoint dir) exits
//! permanently with a diagnosis line instead of burning the budget on a
//! failure no restart can fix.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, ExitStatus};
use std::time::{Duration, Instant};

use crate::data::supervisor::RestartPolicy;
use crate::runtime::runstate::RunState;
use crate::util::log;

/// Heartbeat file name inside the run dir.
pub const HEARTBEAT_FILE: &str = "heartbeat";

/// How often the trainer's learner loop touches the heartbeat file (it
/// also touches at every sync point). The watchdog's
/// `heartbeat_timeout_secs` should comfortably exceed this.
pub const HEARTBEAT_INTERVAL_SECS: f64 = 5.0;

/// Path of the heartbeat file inside `run_dir`.
pub fn heartbeat_path(run_dir: &Path) -> PathBuf {
    run_dir.join(HEARTBEAT_FILE)
}

/// Touch the run dir's heartbeat file. The *mtime* is the signal; the
/// content (the current update count) is a debugging courtesy.
pub fn touch_heartbeat(run_dir: &Path, updates: u64) -> std::io::Result<()> {
    std::fs::write(heartbeat_path(run_dir), format!("{updates}\n"))
}

/// Watchdog configuration. `program` defaults to the current binary in
/// the CLI path; tests point it at `/bin/sh` to script child behavior.
#[derive(Clone, Debug)]
pub struct WatchdogConfig {
    /// Binary to exec for each trainer incarnation.
    pub program: PathBuf,
    /// Arguments after the program (e.g. `train --checkpoint run/ckpt.bin ...`).
    pub args: Vec<String>,
    /// Extra environment for the child (inherits the watchdog's env too).
    pub envs: Vec<(String, String)>,
    /// The run dir: where `run.json`, the heartbeat file, and the
    /// telemetry stream live (the checkpoint base's parent).
    pub run_dir: PathBuf,
    /// Process restarts allowed over the watchdog's lifetime.
    pub max_process_restarts: u32,
    /// First-restart backoff; doubles per restart, capped.
    pub backoff_base_ms: u64,
    pub backoff_cap_ms: u64,
    /// Kill + restart a child silent for this long (no heartbeat touch,
    /// no telemetry write, measured from the newest signal; the spawn
    /// instant counts as a signal so startup is never a false stall).
    /// `0` disables stall detection — exit status only.
    pub heartbeat_timeout_secs: f64,
    /// A failure this soon after launch counts toward the crash-loop
    /// threshold. `0` disables crash-loop detection.
    pub crash_loop_window_secs: f64,
    /// Consecutive fast failures before giving up permanently. `0`
    /// disables crash-loop detection.
    pub crash_loop_threshold: u32,
    /// Liveness poll interval.
    pub poll_ms: u64,
}

impl Default for WatchdogConfig {
    fn default() -> Self {
        WatchdogConfig {
            program: PathBuf::new(),
            args: Vec::new(),
            envs: Vec::new(),
            run_dir: PathBuf::from("."),
            max_process_restarts: 5,
            backoff_base_ms: 1_000,
            backoff_cap_ms: 60_000,
            heartbeat_timeout_secs: 120.0,
            crash_loop_window_secs: 10.0,
            crash_loop_threshold: 3,
            poll_ms: 200,
        }
    }
}

/// Why the watchdog returned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WatchdogOutcome {
    /// The child exited successfully.
    Completed,
    /// The child kept failing and the restart budget ran out.
    BudgetExhausted,
    /// Crash loop: consecutive failures within seconds of launch — a
    /// condition restarts cannot fix (bad flags, missing artifacts,
    /// unloadable checkpoint dir). No restart was attempted.
    CrashLoop,
}

/// Final report of a watchdog run.
#[derive(Clone, Debug)]
pub struct WatchdogReport {
    pub outcome: WatchdogOutcome,
    /// Restarts actually performed (not counting the initial launch).
    pub restarts: u32,
    /// Human-readable description of the last failure, if any.
    pub last_failure: Option<String>,
}

/// Detects crash loops: `threshold` consecutive failures that each died
/// within `window` of launch. A child that ran longer than the window
/// before failing resets the streak — it made real progress, so a
/// restart (resuming from `last_good`) is still worth the budget.
#[derive(Clone, Debug)]
pub struct CrashLoopDetector {
    window: Duration,
    threshold: u32,
    fast_failures: u32,
}

impl CrashLoopDetector {
    pub fn new(window: Duration, threshold: u32) -> Self {
        CrashLoopDetector { window, threshold, fast_failures: 0 }
    }

    /// Record a failure whose child ran for `run_duration`. Returns
    /// `true` when the crash-loop threshold is hit.
    pub fn on_failure(&mut self, run_duration: Duration) -> bool {
        if self.threshold == 0 || self.window.is_zero() {
            return false;
        }
        if run_duration < self.window {
            self.fast_failures += 1;
        } else {
            self.fast_failures = 0;
        }
        self.fast_failures >= self.threshold
    }

    /// Current consecutive fast-failure count (for diagnostics).
    pub fn streak(&self) -> u32 {
        self.fast_failures
    }
}

/// How a supervised child ended.
enum ChildEnd {
    Exited(ExitStatus),
    /// Killed by the watchdog after going silent.
    Stalled { silent_for: Duration },
}

/// Age of the newest liveness signal: heartbeat mtime, telemetry stream
/// mtime, or the spawn instant — whichever is freshest.
fn liveness_age(run_dir: &Path, spawned: Instant) -> Duration {
    let mut newest = spawned.elapsed();
    for name in [HEARTBEAT_FILE, "telemetry.jsonl"] {
        let age = std::fs::metadata(run_dir.join(name))
            .ok()
            .and_then(|m| m.modified().ok())
            // elapsed() errors when the mtime is in the future (clock
            // skew) — treat that as "fresh right now".
            .map(|t| t.elapsed().unwrap_or(Duration::ZERO));
        if let Some(a) = age {
            newest = newest.min(a);
        }
    }
    newest
}

/// Poll one child to completion (or kill it on stall).
fn supervise(child: &mut Child, cfg: &WatchdogConfig, spawned: Instant) -> anyhow::Result<ChildEnd> {
    loop {
        if let Some(status) = child.try_wait()? {
            return Ok(ChildEnd::Exited(status));
        }
        if cfg.heartbeat_timeout_secs > 0.0 {
            let age = liveness_age(&cfg.run_dir, spawned);
            if age.as_secs_f64() > cfg.heartbeat_timeout_secs {
                let _ = child.kill();
                let _ = child.wait();
                return Ok(ChildEnd::Stalled { silent_for: age });
            }
        }
        std::thread::sleep(Duration::from_millis(cfg.poll_ms.max(10)));
    }
}

/// Supervise trainer incarnations until one completes, the restart
/// budget is exhausted, or a crash loop is diagnosed.
pub fn run_watchdog(cfg: &WatchdogConfig) -> anyhow::Result<WatchdogReport> {
    anyhow::ensure!(!cfg.args.is_empty(), "watchdog: empty child command");
    let policy = RestartPolicy {
        max_restarts: cfg.max_process_restarts,
        backoff_base_ms: cfg.backoff_base_ms.max(1),
        backoff_cap_ms: cfg.backoff_cap_ms.max(cfg.backoff_base_ms.max(1)),
    };
    let mut detector = CrashLoopDetector::new(
        Duration::from_secs_f64(cfg.crash_loop_window_secs.max(0.0)),
        cfg.crash_loop_threshold,
    );
    let mut restarts: u32 = 0;
    let mut args = cfg.args.clone();
    loop {
        // Durable run state beats the remembered command line: a prior
        // incarnation recorded exactly what it was running.
        match RunState::load(&cfg.run_dir) {
            Ok(Some(rs)) if rs.argv.len() > 1 => {
                let recorded: Vec<String> = rs.argv[1..].to_vec();
                if recorded != args {
                    log::warn(&format!(
                        "[watchdog] run.json in {} records different arguments; \
                         launching the recorded run: {}",
                        cfg.run_dir.display(),
                        recorded.join(" ")
                    ));
                    args = recorded;
                }
            }
            Ok(_) => {}
            Err(e) => log::warn(&format!(
                "[watchdog] unreadable run.json ({e:#}); trusting the command line"
            )),
        }
        let spawned = Instant::now();
        log::info(&format!(
            "[watchdog] launching trainer (attempt {}): {} {}",
            restarts + 1,
            cfg.program.display(),
            args.join(" ")
        ));
        let mut child = Command::new(&cfg.program)
            .args(&args)
            .envs(cfg.envs.iter().map(|(k, v)| (k.as_str(), v.as_str())))
            .spawn()
            .map_err(|e| anyhow::anyhow!("spawning {:?}: {e}", cfg.program))?;
        let end = supervise(&mut child, cfg, spawned)?;
        let run_duration = spawned.elapsed();
        let failure = match &end {
            ChildEnd::Exited(st) if st.success() => {
                log::info(&format!(
                    "[watchdog] trainer completed cleanly after {} restart(s)",
                    restarts
                ));
                return Ok(WatchdogReport {
                    outcome: WatchdogOutcome::Completed,
                    restarts,
                    last_failure: None,
                });
            }
            ChildEnd::Exited(st) => format!("{st}"),
            ChildEnd::Stalled { silent_for } => format!(
                "stalled (no heartbeat or telemetry write for {:.1}s); killed",
                silent_for.as_secs_f64()
            ),
        };
        if detector.on_failure(run_duration) {
            let diag = format!(
                "[watchdog] crash loop: {} consecutive failures within {:.1}s of launch \
                 (last: {failure}) — restarts cannot fix this; inspect the trainer's stderr, \
                 the run dir ({}), and the checkpoint lineage before relaunching",
                detector.streak(),
                cfg.crash_loop_window_secs,
                cfg.run_dir.display()
            );
            log::warn(&diag);
            return Ok(WatchdogReport {
                outcome: WatchdogOutcome::CrashLoop,
                restarts,
                last_failure: Some(failure),
            });
        }
        if restarts >= cfg.max_process_restarts {
            log::warn(&format!(
                "[watchdog] trainer failed ({failure}) and the restart budget ({}) is spent; \
                 giving up",
                cfg.max_process_restarts
            ));
            return Ok(WatchdogReport {
                outcome: WatchdogOutcome::BudgetExhausted,
                restarts,
                last_failure: Some(failure),
            });
        }
        restarts += 1;
        let backoff = policy.backoff(restarts);
        log::warn(&format!(
            "[watchdog] trainer failed ({failure}); restart {restarts}/{} in {:.1}s — \
             the next incarnation resumes from the lineage's last_good",
            cfg.max_process_restarts,
            backoff.as_secs_f64()
        ));
        std::thread::sleep(backoff);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crash_loop_detector_counts_consecutive_fast_failures() {
        let mut d = CrashLoopDetector::new(Duration::from_secs(10), 3);
        assert!(!d.on_failure(Duration::from_secs(1)));
        assert!(!d.on_failure(Duration::from_secs(2)));
        assert!(d.on_failure(Duration::from_secs(0)));
    }

    #[test]
    fn crash_loop_detector_resets_on_a_long_run() {
        let mut d = CrashLoopDetector::new(Duration::from_secs(10), 2);
        assert!(!d.on_failure(Duration::from_secs(1)));
        // a child that ran past the window made progress: streak resets
        assert!(!d.on_failure(Duration::from_secs(60)));
        assert_eq!(d.streak(), 0);
        assert!(!d.on_failure(Duration::from_secs(1)));
        assert!(d.on_failure(Duration::from_secs(1)));
    }

    #[test]
    fn crash_loop_detector_disabled_by_zero_threshold_or_window() {
        let mut d = CrashLoopDetector::new(Duration::from_secs(10), 0);
        for _ in 0..20 {
            assert!(!d.on_failure(Duration::ZERO));
        }
        let mut d = CrashLoopDetector::new(Duration::ZERO, 3);
        for _ in 0..20 {
            assert!(!d.on_failure(Duration::ZERO));
        }
    }

    #[test]
    fn heartbeat_touch_updates_liveness_age() {
        let dir = std::env::temp_dir()
            .join(format!("fastpbrl_watchdog_hb_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // Before any touch: the spawn instant is the only signal.
        // (checked_sub: the monotonic clock may not reach back 100s on a
        // freshly booted machine — fall back to a shorter backdate.)
        let backdate = Duration::from_secs(100);
        let spawned = Instant::now()
            .checked_sub(backdate)
            .unwrap_or_else(|| Instant::now().checked_sub(Duration::from_millis(50)).unwrap());
        let before = liveness_age(&dir, spawned);
        assert!(before >= Duration::from_millis(40));
        touch_heartbeat(&dir, 42).unwrap();
        assert!(liveness_age(&dir, spawned) < before);
        assert!(liveness_age(&dir, spawned) < Duration::from_secs(5));
        let content = std::fs::read_to_string(heartbeat_path(&dir)).unwrap();
        assert_eq!(content.trim(), "42");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn watchdog_returns_completed_for_a_clean_child() {
        let dir = std::env::temp_dir()
            .join(format!("fastpbrl_watchdog_ok_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = WatchdogConfig {
            program: PathBuf::from("/bin/sh"),
            args: vec!["-c".into(), "exit 0".into()],
            run_dir: dir.clone(),
            backoff_base_ms: 10,
            backoff_cap_ms: 20,
            heartbeat_timeout_secs: 0.0,
            poll_ms: 10,
            ..WatchdogConfig::default()
        };
        let report = run_watchdog(&cfg).unwrap();
        assert_eq!(report.outcome, WatchdogOutcome::Completed);
        assert_eq!(report.restarts, 0);
        assert!(report.last_failure.is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
