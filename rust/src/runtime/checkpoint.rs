//! Train-state checkpointing: persist/restore the flat state vector with
//! an integrity-checked header so long PBT runs survive restarts.
//!
//! Format (little-endian):
//!   magic  "FPBRL1\0\0"          8 bytes
//!   name_len u32 | artifact name utf-8
//!   state_size u64
//!   updates_done u64
//!   fnv1a-64 of the payload      8 bytes
//!   payload: state_size * f32

use std::io::{Read, Write};
use std::path::Path;

use crate::manifest::Artifact;
use crate::runtime::{Runtime, TrainState};

const MAGIC: &[u8; 8] = b"FPBRL1\0\0";

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub artifact_name: String,
    pub updates_done: u64,
    pub state: Vec<f32>,
}

impl Checkpoint {
    pub fn capture(ts: &TrainState) -> anyhow::Result<Checkpoint> {
        Ok(Checkpoint {
            artifact_name: ts.artifact.name.clone(),
            updates_done: ts.updates_done,
            state: ts.to_host()?,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        // write-then-rename so a crash never leaves a torn checkpoint
        let tmp = path.with_extension("tmp");
        {
            let mut w = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            w.write_all(MAGIC)?;
            let name = self.artifact_name.as_bytes();
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name)?;
            w.write_all(&(self.state.len() as u64).to_le_bytes())?;
            w.write_all(&self.updates_done.to_le_bytes())?;
            let payload: &[u8] = unsafe {
                std::slice::from_raw_parts(
                    self.state.as_ptr() as *const u8,
                    self.state.len() * 4,
                )
            };
            w.write_all(&fnv1a(payload).to_le_bytes())?;
            w.write_all(payload)?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Checkpoint> {
        let mut r = std::io::BufReader::new(std::fs::File::open(path.as_ref())?);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a fastpbrl checkpoint");
        let mut u32b = [0u8; 4];
        r.read_exact(&mut u32b)?;
        let name_len = u32::from_le_bytes(u32b) as usize;
        anyhow::ensure!(name_len < 4096, "corrupt header (name length)");
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let mut u64b = [0u8; 8];
        r.read_exact(&mut u64b)?;
        let state_size_u64 = u64::from_le_bytes(u64b);
        r.read_exact(&mut u64b)?;
        let updates_done = u64::from_le_bytes(u64b);
        r.read_exact(&mut u64b)?;
        let expect_hash = u64::from_le_bytes(u64b);
        // Bound the payload allocation by what the file can actually hold:
        // a corrupt size field must not request a multi-GB buffer (or
        // overflow `* 4` on 32-bit) before the hash check ever runs.
        let file_len = r.get_ref().metadata()?.len();
        anyhow::ensure!(
            state_size_u64.checked_mul(4).is_some_and(|b| b <= file_len),
            "corrupt header (state size {state_size_u64} exceeds file length {file_len})"
        );
        let state_size = state_size_u64 as usize;
        let mut payload = vec![0u8; state_size * 4];
        r.read_exact(&mut payload)?;
        anyhow::ensure!(
            fnv1a(&payload) == expect_hash,
            "checkpoint payload hash mismatch (corrupt or truncated file)"
        );
        let mut state = vec![0f32; state_size];
        for (i, chunk) in payload.chunks_exact(4).enumerate() {
            state[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok(Checkpoint {
            artifact_name: String::from_utf8(name)?,
            updates_done,
            state,
        })
    }

    /// Restore into a fresh device-resident train state. Refuses to
    /// restore across artifacts (layouts would not line up).
    pub fn restore(&self, rt: &Runtime, artifact: &Artifact)
                   -> anyhow::Result<TrainState> {
        anyhow::ensure!(
            self.artifact_name == artifact.name,
            "checkpoint is for artifact {:?}, not {:?}",
            self.artifact_name,
            artifact.name
        );
        anyhow::ensure!(
            self.state.len() == artifact.state_size,
            "checkpoint size {} != artifact state size {}",
            self.state.len(),
            artifact.state_size
        );
        let mut ts = TrainState::from_host(rt, artifact, &self.state)?;
        ts.updates_done = self.updates_done;
        Ok(ts)
    }
}

/// Rotated checkpoint history around a base path: every save writes
/// `<base>.<seq>`, mirrors the newest onto plain `<base>` (so tools that
/// expect a single file keep working), optionally promotes the save to
/// the `<base>.last_good` pointer, and prunes old generations down to
/// `keep_last` — never deleting the `last_good` target.
///
/// `last_good` is only advanced for saves the caller marks `healthy`
/// (i.e. a save whose pre-repair health scan found every member clean),
/// so auto-resume can fall back to a state known-good *before* any
/// divergence, not merely one whose bytes hash correctly.
#[derive(Debug)]
pub struct CheckpointLineage {
    base: std::path::PathBuf,
    keep_last: usize,
    next_seq: u64,
}

impl CheckpointLineage {
    /// Open (or start) the lineage at `base`. Existing `<base>.<seq>`
    /// files are detected so a resumed run continues the numbering
    /// instead of overwriting history.
    pub fn new(base: impl Into<std::path::PathBuf>, keep_last: usize) -> CheckpointLineage {
        let base = base.into();
        Self::sweep_tmp(&base);
        let next_seq = Self::sequence(&base).first().map_or(0, |&(s, _)| s + 1);
        CheckpointLineage { base, keep_last: keep_last.max(1), next_seq }
    }

    /// Remove write-crash leftovers next to the lineage: `<stem>.tmp` (a
    /// torn `Checkpoint::save`), `<stem>.mirror.tmp` (a torn base
    /// mirror), `<stem>.last_good.tmp` (a torn pointer write). Every
    /// writer in this module renames its temp file over the target, so
    /// any `<stem>*.tmp` that survives to the next open is garbage by
    /// construction — never data. A partially-written *generation*
    /// (`<stem>.<seq>` with a bad hash) is left in place: `resume`
    /// already skips it, and deleting it would renumber history.
    fn sweep_tmp(base: &Path) {
        let Some(stem) = base.file_name().and_then(|n| n.to_str()) else { return };
        let dir = if base.parent().is_none_or(|p| p.as_os_str().is_empty()) {
            Path::new(".")
        } else {
            base.parent().unwrap()
        };
        let prefix = format!("{stem}.");
        let Ok(entries) = std::fs::read_dir(dir) else { return };
        for e in entries.flatten() {
            let name = e.file_name();
            let Some(name) = name.to_str() else { continue };
            if name.starts_with(&prefix) && name.ends_with(".tmp") {
                eprintln!("[checkpoint] sweeping stale temp file {}", e.path().display());
                let _ = std::fs::remove_file(e.path());
            }
        }
    }

    /// All `<base>.<seq>` generations on disk, newest first.
    fn sequence(base: &Path) -> Vec<(u64, std::path::PathBuf)> {
        let Some(stem) = base.file_name().and_then(|n| n.to_str()) else {
            return Vec::new();
        };
        let dir = if base.parent().is_none_or(|p| p.as_os_str().is_empty()) {
            Path::new(".")
        } else {
            base.parent().unwrap()
        };
        let prefix = format!("{stem}.");
        let mut out = Vec::new();
        if let Ok(entries) = std::fs::read_dir(dir) {
            for e in entries.flatten() {
                let name = e.file_name();
                let Some(name) = name.to_str() else { continue };
                if let Some(suffix) = name.strip_prefix(&prefix) {
                    if let Ok(seq) = suffix.parse::<u64>() {
                        out.push((seq, e.path()));
                    }
                }
            }
        }
        out.sort_by(|a, b| b.0.cmp(&a.0));
        out
    }

    /// The file the `<base>.last_good` pointer names, if any.
    pub fn last_good_target(base: &Path) -> Option<std::path::PathBuf> {
        let pointer = Self::pointer_path(base);
        let name = std::fs::read_to_string(pointer).ok()?;
        let name = name.trim();
        if name.is_empty() {
            return None;
        }
        Some(base.with_file_name(name))
    }

    fn pointer_path(base: &Path) -> std::path::PathBuf {
        let stem = base.file_name().and_then(|n| n.to_str()).unwrap_or("ckpt");
        base.with_file_name(format!("{stem}.last_good"))
    }

    /// Persist one generation. `healthy` marks the save as a `last_good`
    /// candidate (the caller's health scan found all members clean
    /// *before* any repair this round). Returns the generation's path.
    pub fn save(&mut self, ckpt: &Checkpoint, healthy: bool)
                -> anyhow::Result<std::path::PathBuf> {
        let stem = self
            .base
            .file_name()
            .and_then(|n| n.to_str())
            .ok_or_else(|| anyhow::anyhow!("checkpoint path has no file name"))?
            .to_string();
        let seq_name = format!("{stem}.{}", self.next_seq);
        let seq_path = self.base.with_file_name(&seq_name);
        ckpt.save(&seq_path)?;
        self.next_seq += 1;
        // Mirror onto the plain base path (hard link when the fs allows,
        // else a full copy) so `Checkpoint::load(base)` keeps working.
        // Link/copy under a temp name, then rename over the base: the old
        // remove-then-link sequence left a window with *no* base file at
        // all, where a crash (or a reader racing the save) found the
        // mirror missing instead of merely one generation stale. The
        // rename replaces the base atomically, same as `Checkpoint::save`
        // and the `last_good` pointer write.
        let tmp = self.base.with_file_name(format!("{stem}.mirror.tmp"));
        let _ = std::fs::remove_file(&tmp); // stale leftover from a crash
        if std::fs::hard_link(&seq_path, &tmp).is_err() {
            std::fs::copy(&seq_path, &tmp)?;
        }
        std::fs::rename(&tmp, &self.base)?;
        if healthy {
            // pointer write is tmp+rename for the same torn-write safety
            // as the checkpoint itself
            let pointer = Self::pointer_path(&self.base);
            let tmp = pointer.with_extension("last_good.tmp");
            std::fs::write(&tmp, &seq_name)?;
            std::fs::rename(&tmp, &pointer)?;
        }
        self.prune();
        Ok(seq_path)
    }

    /// Delete generations beyond `keep_last`, sparing the `last_good`
    /// target (the whole point of the pointer is that it stays
    /// restorable no matter how many unhealthy saves follow it).
    fn prune(&self) {
        let protected = Self::last_good_target(&self.base);
        for (_, path) in Self::sequence(&self.base).into_iter().skip(self.keep_last) {
            if protected.as_deref() == Some(path.as_path()) {
                continue;
            }
            let _ = std::fs::remove_file(&path);
        }
    }

    /// Restore the newest generation that both loads (magic + hash) and
    /// passes `validate` — falling back down the lineage, then to the
    /// plain `<base>` file, on any failure. Returns the winning path
    /// alongside the checkpoint; `None` when nothing restorable exists.
    pub fn resume(
        base: &Path,
        mut validate: impl FnMut(&Checkpoint) -> bool,
    ) -> Option<(std::path::PathBuf, Checkpoint)> {
        let mut candidates: Vec<std::path::PathBuf> =
            Self::sequence(base).into_iter().map(|(_, p)| p).collect();
        if base.exists() {
            candidates.push(base.to_path_buf());
        }
        for path in candidates {
            match Checkpoint::load(&path) {
                Ok(c) if validate(&c) => return Some((path, c)),
                Ok(_) => {
                    eprintln!(
                        "[checkpoint] {} loads but fails validation; trying older",
                        path.display()
                    );
                }
                Err(e) => {
                    eprintln!(
                        "[checkpoint] {} unreadable ({e}); trying older",
                        path.display()
                    );
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("fastpbrl_ckpt_{name}"))
    }

    fn toy() -> Checkpoint {
        Checkpoint {
            artifact_name: "td3_pendulum_p1".into(),
            updates_done: 1234,
            state: (0..100).map(|i| i as f32 * 0.5).collect(),
        }
    }

    #[test]
    fn roundtrip() {
        let path = tmpfile("roundtrip");
        let c = toy();
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.artifact_name, c.artifact_name);
        assert_eq!(back.updates_done, 1234);
        assert_eq!(back.state, c.state);
    }

    #[test]
    fn detects_corruption() {
        let path = tmpfile("corrupt");
        toy().save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0xFF; // flip a payload bit
        std::fs::write(&path, bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(err.to_string().contains("hash mismatch"), "{err}");
    }

    #[test]
    fn detects_truncation() {
        let path = tmpfile("trunc");
        toy().save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn rejects_foreign_files() {
        let path = tmpfile("foreign");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(err.to_string().contains("not a fastpbrl checkpoint"));
    }

    /// A corrupt size field must fail the file-length bound up front, not
    /// attempt a huge allocation and fail later (or OOM).
    #[test]
    fn rejects_absurd_state_size_header() {
        let path = tmpfile("hugesize");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(b"xy");
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // state_size: absurd
        bytes.extend_from_slice(&0u64.to_le_bytes()); // updates_done
        bytes.extend_from_slice(&0u64.to_le_bytes()); // hash
        bytes.extend_from_slice(&[0u8; 16]); // token payload
        std::fs::write(&path, bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(err.to_string().contains("corrupt header"), "{err}");
    }

    // ---- lineage -------------------------------------------------------

    fn lineage_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("fastpbrl_lineage_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn ckpt_at(updates: u64) -> Checkpoint {
        Checkpoint {
            artifact_name: "td3_pendulum_p1".into(),
            updates_done: updates,
            state: (0..32).map(|i| (i as f32) + updates as f32).collect(),
        }
    }

    #[test]
    fn lineage_rotates_prunes_and_mirrors_base() {
        let dir = lineage_dir("rotate");
        let base = dir.join("ckpt.bin");
        let mut lin = CheckpointLineage::new(&base, 2);
        for u in 0..5 {
            lin.save(&ckpt_at(u), true).unwrap();
        }
        // keep_last = 2: only generations 3 and 4 survive
        let seqs: Vec<u64> = CheckpointLineage::sequence(&base)
            .into_iter()
            .map(|(s, _)| s)
            .collect();
        assert_eq!(seqs, vec![4, 3]);
        // plain base mirrors the newest generation
        assert_eq!(Checkpoint::load(&base).unwrap().updates_done, 4);
        // a reopened lineage continues numbering instead of clobbering
        let mut again = CheckpointLineage::new(&base, 2);
        again.save(&ckpt_at(9), true).unwrap();
        assert_eq!(Checkpoint::load(&base).unwrap().updates_done, 9);
        assert_eq!(CheckpointLineage::sequence(&base)[0].0, 5);
    }

    /// The base mirror is replaced by rename — never removed first — so
    /// it always names a complete generation, and a stale `.mirror.tmp`
    /// left by a crashed save cannot wedge the next one.
    #[test]
    fn mirror_survives_stale_tmp_and_always_loads() {
        let dir = lineage_dir("mirror");
        let base = dir.join("ckpt.bin");
        let tmp = dir.join("ckpt.bin.mirror.tmp");
        std::fs::write(&tmp, b"torn garbage from a crashed save").unwrap();
        let mut lin = CheckpointLineage::new(&base, 2);
        lin.save(&ckpt_at(1), true).unwrap();
        assert_eq!(Checkpoint::load(&base).unwrap().updates_done, 1);
        assert!(!tmp.exists(), "temp mirror must not outlive the save");
        lin.save(&ckpt_at(2), true).unwrap();
        assert_eq!(Checkpoint::load(&base).unwrap().updates_done, 2);
        // the mirror still shares the generation's inode where hard
        // links work: corrupting the generation corrupts the mirror too
        // (resume_falls_back_down_lineage_on_corruption relies on this)
        assert!(!tmp.exists());
    }

    #[test]
    fn resume_falls_back_down_lineage_on_corruption() {
        let dir = lineage_dir("fallback");
        let base = dir.join("ckpt.bin");
        let mut lin = CheckpointLineage::new(&base, 3);
        lin.save(&ckpt_at(1), true).unwrap();
        let newest = lin.save(&ckpt_at(2), true).unwrap();
        // bit-flip the newest generation (the base hard link shares the
        // inode, so the mirror is corrupt too — the worst case)
        let mut bytes = std::fs::read(&newest).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0xFF;
        std::fs::write(&newest, bytes).unwrap();
        let (path, c) = CheckpointLineage::resume(&base, |_| true).expect("older gen restores");
        assert_eq!(c.updates_done, 1);
        assert!(path.to_string_lossy().ends_with("ckpt.bin.0"));
        // last_good still names the newest (it hashed fine when saved);
        // resume worked anyway because fallback is by lineage order
        assert_eq!(
            CheckpointLineage::last_good_target(&base).unwrap(),
            base.with_file_name("ckpt.bin.1")
        );
    }

    #[test]
    fn last_good_never_advances_past_failed_health_scan() {
        let dir = lineage_dir("lastgood");
        let base = dir.join("ckpt.bin");
        let mut lin = CheckpointLineage::new(&base, 1);
        lin.save(&ckpt_at(1), true).unwrap();
        lin.save(&ckpt_at(2), false).unwrap(); // unhealthy scan: no promotion
        lin.save(&ckpt_at(3), false).unwrap();
        let good = CheckpointLineage::last_good_target(&base).unwrap();
        assert_eq!(good, base.with_file_name("ckpt.bin.0"));
        // pruning (keep_last = 1) spared the last_good target
        assert!(good.exists(), "last_good target must survive pruning");
        // a validator that rejects the unhealthy saves lands on last_good
        let (path, c) = CheckpointLineage::resume(&base, |c| c.updates_done == 1).unwrap();
        assert_eq!(c.updates_done, 1);
        assert_eq!(path, good);
        // a healthy save promotes the pointer again
        lin.save(&ckpt_at(4), true).unwrap();
        assert_eq!(
            CheckpointLineage::last_good_target(&base).unwrap(),
            base.with_file_name("ckpt.bin.3")
        );
    }

    /// Opening a lineage sweeps every `<stem>*.tmp` crash leftover —
    /// torn checkpoint, torn mirror, torn pointer — while sparing
    /// unrelated files and real generations.
    #[test]
    fn new_sweeps_stale_tmp_leftovers() {
        let dir = lineage_dir("sweep");
        let base = dir.join("ckpt.bin");
        let mut lin = CheckpointLineage::new(&base, 3);
        lin.save(&ckpt_at(1), true).unwrap();
        drop(lin);
        let stale = [
            dir.join("ckpt.bin.tmp"),
            dir.join("ckpt.bin.mirror.tmp"),
            dir.join("ckpt.bin.last_good.tmp"),
        ];
        for p in &stale {
            std::fs::write(p, b"torn write from a crashed process").unwrap();
        }
        let unrelated = dir.join("other.tmp");
        std::fs::write(&unrelated, b"not ours").unwrap();
        let mut lin = CheckpointLineage::new(&base, 3);
        for p in &stale {
            assert!(!p.exists(), "{} must be swept", p.display());
        }
        assert!(unrelated.exists(), "files outside the lineage namespace are untouched");
        // the real generation and pointer survived the sweep
        assert!(dir.join("ckpt.bin.0").exists());
        assert_eq!(
            CheckpointLineage::last_good_target(&base).unwrap(),
            base.with_file_name("ckpt.bin.0")
        );
        // and saving still works (numbering unaffected by the sweep)
        let p = lin.save(&ckpt_at(2), true).unwrap();
        assert!(p.to_string_lossy().ends_with("ckpt.bin.1"));
    }

    /// A generation whose write was cut mid-file (crash between
    /// `File::create` of the final name's temp and the rename — or a
    /// torn copy made by an operator) is skipped by `resume`, and a
    /// reopened lineage keeps numbering *after* it rather than reusing
    /// its sequence number.
    #[test]
    fn resume_skips_torn_newest_generation_and_numbering_continues() {
        let dir = lineage_dir("torn_gen");
        let base = dir.join("ckpt.bin");
        let mut lin = CheckpointLineage::new(&base, 4);
        lin.save(&ckpt_at(1), true).unwrap();
        lin.save(&ckpt_at(2), true).unwrap();
        drop(lin);
        // fabricate a partially-written newest generation: the first
        // half of a valid checkpoint's bytes under the next seq name
        let good = std::fs::read(dir.join("ckpt.bin.1")).unwrap();
        std::fs::write(dir.join("ckpt.bin.2"), &good[..good.len() / 2]).unwrap();
        // resume skips the torn .2 and lands on the intact .1
        let (path, c) = CheckpointLineage::resume(&base, |_| true).expect("resumes");
        assert!(path.to_string_lossy().ends_with("ckpt.bin.1"));
        assert_eq!(c.updates_done, 2);
        // a reopened lineage continues after the torn generation: the
        // next save must land on .3, never overwrite .2's number
        let mut lin = CheckpointLineage::new(&base, 4);
        let p = lin.save(&ckpt_at(9), true).unwrap();
        assert!(p.to_string_lossy().ends_with("ckpt.bin.3"), "{}", p.display());
        assert_eq!(Checkpoint::load(&base).unwrap().updates_done, 9);
        let seqs: Vec<u64> = CheckpointLineage::sequence(&base)
            .into_iter()
            .map(|(s, _)| s)
            .collect();
        assert_eq!(seqs, vec![3, 2, 1, 0]);
    }

    #[test]
    fn resume_on_empty_lineage_is_none() {
        let dir = lineage_dir("empty");
        let base = dir.join("ckpt.bin");
        assert!(CheckpointLineage::resume(&base, |_| true).is_none());
        // a bare (pre-lineage) base file still resumes — compatibility
        // with checkpoints written before rotation existed
        ckpt_at(7).save(&base).unwrap();
        let (path, c) = CheckpointLineage::resume(&base, |_| true).unwrap();
        assert_eq!((path, c.updates_done), (base, 7));
    }
}
