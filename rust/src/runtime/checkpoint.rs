//! Train-state checkpointing: persist/restore the flat state vector with
//! an integrity-checked header so long PBT runs survive restarts.
//!
//! Format (little-endian):
//!   magic  "FPBRL1\0\0"          8 bytes
//!   name_len u32 | artifact name utf-8
//!   state_size u64
//!   updates_done u64
//!   fnv1a-64 of the payload      8 bytes
//!   payload: state_size * f32

use std::io::{Read, Write};
use std::path::Path;

use crate::manifest::Artifact;
use crate::runtime::{Runtime, TrainState};

const MAGIC: &[u8; 8] = b"FPBRL1\0\0";

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub artifact_name: String,
    pub updates_done: u64,
    pub state: Vec<f32>,
}

impl Checkpoint {
    pub fn capture(ts: &TrainState) -> anyhow::Result<Checkpoint> {
        Ok(Checkpoint {
            artifact_name: ts.artifact.name.clone(),
            updates_done: ts.updates_done,
            state: ts.to_host()?,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        // write-then-rename so a crash never leaves a torn checkpoint
        let tmp = path.with_extension("tmp");
        {
            let mut w = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            w.write_all(MAGIC)?;
            let name = self.artifact_name.as_bytes();
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name)?;
            w.write_all(&(self.state.len() as u64).to_le_bytes())?;
            w.write_all(&self.updates_done.to_le_bytes())?;
            let payload: &[u8] = unsafe {
                std::slice::from_raw_parts(
                    self.state.as_ptr() as *const u8,
                    self.state.len() * 4,
                )
            };
            w.write_all(&fnv1a(payload).to_le_bytes())?;
            w.write_all(payload)?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Checkpoint> {
        let mut r = std::io::BufReader::new(std::fs::File::open(path.as_ref())?);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a fastpbrl checkpoint");
        let mut u32b = [0u8; 4];
        r.read_exact(&mut u32b)?;
        let name_len = u32::from_le_bytes(u32b) as usize;
        anyhow::ensure!(name_len < 4096, "corrupt header (name length)");
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let mut u64b = [0u8; 8];
        r.read_exact(&mut u64b)?;
        let state_size = u64::from_le_bytes(u64b) as usize;
        r.read_exact(&mut u64b)?;
        let updates_done = u64::from_le_bytes(u64b);
        r.read_exact(&mut u64b)?;
        let expect_hash = u64::from_le_bytes(u64b);
        let mut payload = vec![0u8; state_size * 4];
        r.read_exact(&mut payload)?;
        anyhow::ensure!(
            fnv1a(&payload) == expect_hash,
            "checkpoint payload hash mismatch (corrupt or truncated file)"
        );
        let mut state = vec![0f32; state_size];
        for (i, chunk) in payload.chunks_exact(4).enumerate() {
            state[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok(Checkpoint {
            artifact_name: String::from_utf8(name)?,
            updates_done,
            state,
        })
    }

    /// Restore into a fresh device-resident train state. Refuses to
    /// restore across artifacts (layouts would not line up).
    pub fn restore(&self, rt: &Runtime, artifact: &Artifact)
                   -> anyhow::Result<TrainState> {
        anyhow::ensure!(
            self.artifact_name == artifact.name,
            "checkpoint is for artifact {:?}, not {:?}",
            self.artifact_name,
            artifact.name
        );
        anyhow::ensure!(
            self.state.len() == artifact.state_size,
            "checkpoint size {} != artifact state size {}",
            self.state.len(),
            artifact.state_size
        );
        let mut ts = TrainState::from_host(rt, artifact, &self.state)?;
        ts.updates_done = self.updates_done;
        Ok(ts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("fastpbrl_ckpt_{name}"))
    }

    fn toy() -> Checkpoint {
        Checkpoint {
            artifact_name: "td3_pendulum_p1".into(),
            updates_done: 1234,
            state: (0..100).map(|i| i as f32 * 0.5).collect(),
        }
    }

    #[test]
    fn roundtrip() {
        let path = tmpfile("roundtrip");
        let c = toy();
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.artifact_name, c.artifact_name);
        assert_eq!(back.updates_done, 1234);
        assert_eq!(back.state, c.state);
    }

    #[test]
    fn detects_corruption() {
        let path = tmpfile("corrupt");
        toy().save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0xFF; // flip a payload bit
        std::fs::write(&path, bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(err.to_string().contains("hash mismatch"), "{err}");
    }

    #[test]
    fn detects_truncation() {
        let path = tmpfile("trunc");
        toy().save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn rejects_foreign_files() {
        let path = tmpfile("foreign");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(err.to_string().contains("not a fastpbrl checkpoint"));
    }
}
