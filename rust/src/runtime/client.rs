//! PJRT client wrapper: load HLO-text artifacts, compile once per variant,
//! execute with device-resident buffers from the hot path.
//!
//! Pattern follows `/opt/xla-example/load_hlo`: HLO *text* is the
//! interchange format (`HloModuleProto::from_text_file` reassigns the
//! 64-bit instruction ids jax >= 0.5 emits that xla_extension 0.5.1 would
//! otherwise reject).

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard};

use crate::manifest::{Artifact, BatchInput, Dtype};
use crate::telemetry::Stopwatch;

/// How a PJRT/XLA failure should be handled by the training loop.
///
/// Classification is by message inspection: the PJRT C API surfaces
/// faults as status strings (canonical gRPC-style codes plus prose), and
/// the bindings forward them verbatim, so the strings are the only
/// portable signal. [`classify_fault`] sorts them into three buckets the
/// trainer's recovery wrapper acts on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Transient dispatch failure (queue pressure, allocator pressure,
    /// scheduler hiccup) — worth a bounded retry with backoff against
    /// the same runtime.
    Retryable,
    /// The device (or its runtime) is gone or wedged in an error state —
    /// retrying the same handle cannot help. Rebuild the [`Runtime`],
    /// re-load the executable cache, re-upload state from the host
    /// mirror, and resume in place.
    DeviceLost,
    /// Programming or environment error (shape mismatch, missing
    /// artifact, unsupported op) — propagate; a retry would just fail
    /// identically.
    Fatal,
}

/// Sort a PJRT/XLA error message into a [`FaultKind`].
///
/// Device loss is checked first: a lost device frequently *also* reports
/// canonical transient codes (`UNAVAILABLE` wrapping a device reset), and
/// retrying against a dead device would burn the whole retry budget
/// before the real recovery path runs.
pub fn classify_fault(msg: &str) -> FaultKind {
    let m = msg.to_ascii_lowercase();
    const DEVICE_LOST: &[&str] = &[
        "device_lost",
        "device lost",
        "device is in an error state",
        "device has been removed",
        "device reset",
        "simulated device loss",
    ];
    if DEVICE_LOST.iter().any(|p| m.contains(p)) {
        return FaultKind::DeviceLost;
    }
    const RETRYABLE: &[&str] = &[
        "resource_exhausted",
        "resource exhausted",
        "unavailable",
        "aborted",
        "deadline_exceeded",
        "deadline exceeded",
        "too many pending",
        "try again",
    ];
    if RETRYABLE.iter().any(|p| m.contains(p)) {
        return FaultKind::Retryable;
    }
    FaultKind::Fatal
}

/// Poison-tolerant lock for the executable cache, mirroring the replay
/// stripes: a thread that panicked mid-`load` can only have left the map
/// between complete insertions (entries are built before the lock is
/// taken and inserted whole), so the data behind a poisoned mutex is
/// still valid — every later `load` must keep working instead of
/// propagating the panic forever.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Owns the PJRT client and a cache of compiled executables.
pub struct Runtime {
    pub client: xla::PjRtClient,
    cache: Mutex<BTreeMap<String, std::sync::Arc<Executable>>>,
}

/// One compiled update-step (or forward) computation plus its metadata.
pub struct Executable {
    pub exe: xla::PjRtLoadedExecutable,
    pub artifact: Artifact,
    /// PJRT compile time — the rust analogue of the paper's Table 3
    /// "initial compilation time".
    pub compile_seconds: f64,
}

impl Runtime {
    pub fn cpu() -> anyhow::Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime { client, cache: Mutex::new(BTreeMap::new()) })
    }

    /// Load + compile an artifact (cached by name).
    pub fn load(&self, artifact: &Artifact) -> anyhow::Result<std::sync::Arc<Executable>> {
        if let Some(e) = lock(&self.cache).get(&artifact.name) {
            return Ok(e.clone());
        }
        let sw = Stopwatch::start();
        let proto = xla::HloModuleProto::from_text_file(&artifact.file)
            .map_err(|e| anyhow::anyhow!("parsing {:?}: {e}", artifact.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", artifact.name))?;
        let exec = std::sync::Arc::new(Executable {
            exe,
            artifact: artifact.clone(),
            compile_seconds: sw.elapsed_s(),
        });
        lock(&self.cache).insert(artifact.name.clone(), exec.clone());
        Ok(exec)
    }

    /// Upload a host f32 slice as a device buffer.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload f32 {dims:?}: {e}"))
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload i32 {dims:?}: {e}"))
    }

    /// Upload a batch input described by the manifest (dtype dispatch).
    pub fn upload_batch(&self, input: &BatchInput, f32_data: &[f32], i32_data: &[i32])
                        -> anyhow::Result<xla::PjRtBuffer> {
        match input.dtype {
            Dtype::F32 => self.upload_f32(f32_data, &input.shape),
            Dtype::I32 => self.upload_i32(i32_data, &input.shape),
            Dtype::U32 => anyhow::bail!("u32 batch inputs are not used"),
        }
    }
}

impl Executable {
    /// Execute on device buffers; returns the single output buffer.
    ///
    /// All our artifacts are lowered with `return_tuple=False` and return
    /// exactly one array (the new flat state, or the forward output), so
    /// the result is `outputs[0][0]`.
    pub fn run(&self, args: &[&xla::PjRtBuffer]) -> anyhow::Result<xla::PjRtBuffer> {
        let mut out = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow::anyhow!("executing {}: {e}", self.artifact.name))?;
        anyhow::ensure!(
            !out.is_empty() && !out[0].is_empty(),
            "{}: empty execution result",
            self.artifact.name
        );
        Ok(out.remove(0).remove(0))
    }

    /// Download a device buffer to a host f32 vec.
    pub fn download_f32(buf: &xla::PjRtBuffer) -> anyhow::Result<Vec<f32>> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e}"))?;
        lit.to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("literal to_vec: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_device_lost_markers() {
        for msg in [
            "executing sac_update: DEVICE_LOST: tpu halted",
            "INTERNAL: device is in an error state",
            "the device has been removed from the bus",
            "fault-inject: simulated device loss at 100 updates (DEVICE_LOST)",
        ] {
            assert_eq!(classify_fault(msg), FaultKind::DeviceLost, "{msg}");
        }
    }

    #[test]
    fn classify_retryable_markers() {
        for msg in [
            "executing sac_update: UNAVAILABLE: scheduler busy",
            "RESOURCE_EXHAUSTED: out of transfer slots",
            "ABORTED: collective interrupted",
            "DEADLINE_EXCEEDED: dispatch queue full, try again",
        ] {
            assert_eq!(classify_fault(msg), FaultKind::Retryable, "{msg}");
        }
    }

    #[test]
    fn classify_fatal_by_default() {
        for msg in [
            "INVALID_ARGUMENT: shape mismatch f32[8] vs f32[16]",
            "parsing \"artifacts/sac.hlo\": no such file",
            "literal to_vec: dtype mismatch",
        ] {
            assert_eq!(classify_fault(msg), FaultKind::Fatal, "{msg}");
        }
    }

    #[test]
    fn device_lost_wins_over_retryable_wrapping() {
        // A lost device often surfaces wrapped in a canonical transient
        // code; it must still route to the rebuild path, not the retry
        // loop.
        let msg = "UNAVAILABLE: stream executor reported DEVICE_LOST";
        assert_eq!(classify_fault(msg), FaultKind::DeviceLost);
    }

    #[test]
    fn cache_lock_survives_a_poisoning_panic() {
        let m = Mutex::new(BTreeMap::from([(String::from("a"), 1u32)]));
        let poisoner = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = m.lock().unwrap();
            panic!("poison the executable cache");
        }));
        assert!(poisoner.is_err());
        assert!(m.is_poisoned());
        // The replay-stripe idiom: recover the guard, data is intact.
        let mut g = lock(&m);
        assert_eq!(g.get("a"), Some(&1));
        g.insert(String::from("b"), 2);
        drop(g);
        assert_eq!(lock(&m).len(), 2);
    }
}
