//! PJRT client wrapper: load HLO-text artifacts, compile once per variant,
//! execute with device-resident buffers from the hot path.
//!
//! Pattern follows `/opt/xla-example/load_hlo`: HLO *text* is the
//! interchange format (`HloModuleProto::from_text_file` reassigns the
//! 64-bit instruction ids jax >= 0.5 emits that xla_extension 0.5.1 would
//! otherwise reject).

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::manifest::{Artifact, BatchInput, Dtype};
use crate::telemetry::Stopwatch;

/// Owns the PJRT client and a cache of compiled executables.
pub struct Runtime {
    pub client: xla::PjRtClient,
    cache: Mutex<BTreeMap<String, std::sync::Arc<Executable>>>,
}

/// One compiled update-step (or forward) computation plus its metadata.
pub struct Executable {
    pub exe: xla::PjRtLoadedExecutable,
    pub artifact: Artifact,
    /// PJRT compile time — the rust analogue of the paper's Table 3
    /// "initial compilation time".
    pub compile_seconds: f64,
}

impl Runtime {
    pub fn cpu() -> anyhow::Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime { client, cache: Mutex::new(BTreeMap::new()) })
    }

    /// Load + compile an artifact (cached by name).
    pub fn load(&self, artifact: &Artifact) -> anyhow::Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(&artifact.name) {
            return Ok(e.clone());
        }
        let sw = Stopwatch::start();
        let proto = xla::HloModuleProto::from_text_file(&artifact.file)
            .map_err(|e| anyhow::anyhow!("parsing {:?}: {e}", artifact.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", artifact.name))?;
        let exec = std::sync::Arc::new(Executable {
            exe,
            artifact: artifact.clone(),
            compile_seconds: sw.elapsed_s(),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(artifact.name.clone(), exec.clone());
        Ok(exec)
    }

    /// Upload a host f32 slice as a device buffer.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload f32 {dims:?}: {e}"))
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload i32 {dims:?}: {e}"))
    }

    /// Upload a batch input described by the manifest (dtype dispatch).
    pub fn upload_batch(&self, input: &BatchInput, f32_data: &[f32], i32_data: &[i32])
                        -> anyhow::Result<xla::PjRtBuffer> {
        match input.dtype {
            Dtype::F32 => self.upload_f32(f32_data, &input.shape),
            Dtype::I32 => self.upload_i32(i32_data, &input.shape),
            Dtype::U32 => anyhow::bail!("u32 batch inputs are not used"),
        }
    }
}

impl Executable {
    /// Execute on device buffers; returns the single output buffer.
    ///
    /// All our artifacts are lowered with `return_tuple=False` and return
    /// exactly one array (the new flat state, or the forward output), so
    /// the result is `outputs[0][0]`.
    pub fn run(&self, args: &[&xla::PjRtBuffer]) -> anyhow::Result<xla::PjRtBuffer> {
        let mut out = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow::anyhow!("executing {}: {e}", self.artifact.name))?;
        anyhow::ensure!(
            !out.is_empty() && !out[0].is_empty(),
            "{}: empty execution result",
            self.artifact.name
        );
        Ok(out.remove(0).remove(0))
    }

    /// Download a device buffer to a host f32 vec.
    pub fn download_f32(buf: &xla::PjRtBuffer) -> anyhow::Result<Vec<f32>> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e}"))?;
        lit.to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("literal to_vec: {e}"))
    }
}
