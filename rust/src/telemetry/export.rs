//! Snapshot export: JSONL stream (one [`Snapshot`] per line, tailed by
//! `fastpbrl top`), Prometheus text dump (atomically rewritten file),
//! and the [`Exporter`] the trainer ticks once per loop iteration.

use std::fs;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::telemetry::registry::{CounterSnap, GaugeSnap, HistSnap, Snapshot};
use crate::telemetry::TelemetryConfig;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::log::JsonlLogger;

/// One snapshot as a JSON value (object keys serialize sorted, so the
/// encoding is deterministic — pinned by the golden tests).
pub fn snapshot_to_json(snap: &Snapshot) -> Json {
    obj(vec![
        ("uptime_s", num(snap.uptime_s)),
        (
            "counters",
            arr(snap
                .counters
                .iter()
                .map(|c| {
                    obj(vec![
                        ("name", s(&c.name)),
                        ("value", num(c.value as f64)),
                        ("rate", num(c.rate)),
                    ])
                })
                .collect()),
        ),
        (
            "gauges",
            arr(snap
                .gauges
                .iter()
                .map(|g| obj(vec![("name", s(&g.name)), ("value", num(g.value))]))
                .collect()),
        ),
        (
            "hists",
            arr(snap
                .hists
                .iter()
                .map(|h| {
                    obj(vec![
                        ("name", s(&h.name)),
                        ("count", num(h.count as f64)),
                        ("sum", num(h.sum as f64)),
                        ("p50", num(h.p50)),
                        ("p95", num(h.p95)),
                        ("p99", num(h.p99)),
                    ])
                })
                .collect()),
        ),
    ])
}

fn field(j: &Json, key: &str) -> Result<f64> {
    j.get(key).and_then(Json::as_f64).with_context(|| format!("snapshot field {key:?}"))
}

fn name_of(j: &Json) -> Result<String> {
    Ok(j.get("name").and_then(Json::as_str).context("snapshot field \"name\"")?.to_string())
}

/// Parse one JSONL line back into a [`Snapshot`] (the `fastpbrl top`
/// reader side).
pub fn snapshot_from_json(j: &Json) -> Result<Snapshot> {
    let items = |key: &str| -> Result<&[Json]> {
        j.get(key).and_then(Json::as_arr).with_context(|| format!("snapshot array {key:?}"))
    };
    let mut snap = Snapshot { uptime_s: field(j, "uptime_s")?, ..Snapshot::default() };
    for c in items("counters")? {
        snap.counters.push(CounterSnap {
            name: name_of(c)?,
            value: field(c, "value")? as u64,
            rate: field(c, "rate")?,
        });
    }
    for g in items("gauges")? {
        snap.gauges.push(GaugeSnap { name: name_of(g)?, value: field(g, "value")? });
    }
    for h in items("hists")? {
        snap.hists.push(HistSnap {
            name: name_of(h)?,
            count: field(h, "count")? as u64,
            sum: field(h, "sum")? as u64,
            p50: field(h, "p50")?,
            p95: field(h, "p95")?,
            p99: field(h, "p99")?,
        });
    }
    Ok(snap)
}

/// Dotted metric names -> Prometheus identifiers (`fastpbrl_` prefix,
/// non-alphanumerics to `_`).
fn sanitize(name: &str) -> String {
    name.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect()
}

/// Prometheus floats: integral values print without a decimal point
/// (matches the JSON writer, keeps the goldens stable).
fn fmt_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < (1u64 << 53) as f64 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// The snapshot in Prometheus text exposition format: counters and
/// gauges as single samples, histograms as summaries (quantile series
/// plus `_sum`/`_count`).
pub fn prometheus_text(snap: &Snapshot) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    for c in &snap.counters {
        let n = sanitize(&c.name);
        let _ = writeln!(out, "# TYPE fastpbrl_{n} counter");
        let _ = writeln!(out, "fastpbrl_{n} {}", c.value);
    }
    for g in &snap.gauges {
        let n = sanitize(&g.name);
        let _ = writeln!(out, "# TYPE fastpbrl_{n} gauge");
        let _ = writeln!(out, "fastpbrl_{n} {}", fmt_num(g.value));
    }
    for h in &snap.hists {
        let n = sanitize(&h.name);
        let _ = writeln!(out, "# TYPE fastpbrl_{n} summary");
        let _ = writeln!(out, "fastpbrl_{n}{{quantile=\"0.5\"}} {}", fmt_num(h.p50));
        let _ = writeln!(out, "fastpbrl_{n}{{quantile=\"0.95\"}} {}", fmt_num(h.p95));
        let _ = writeln!(out, "fastpbrl_{n}{{quantile=\"0.99\"}} {}", fmt_num(h.p99));
        let _ = writeln!(out, "fastpbrl_{n}_sum {}", h.sum);
        let _ = writeln!(out, "fastpbrl_{n}_count {}", h.count);
    }
    out
}

/// Write the Prometheus dump atomically (tmp file + rename), so a
/// scraper never reads a half-written exposition.
pub fn write_prometheus(path: &Path, snap: &Snapshot) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            fs::create_dir_all(dir)?;
        }
    }
    let tmp = path.with_extension("prom.tmp");
    fs::write(&tmp, prometheus_text(snap))
        .with_context(|| format!("writing {}", tmp.display()))?;
    fs::rename(&tmp, path).with_context(|| format!("renaming into {}", path.display()))?;
    Ok(())
}

/// Resolve a configured output path: an existing directory gets the
/// default `telemetry.jsonl` file name appended (so `--telemetry <dir>`
/// and `fastpbrl top <dir>` agree on the location).
pub fn resolve_jsonl_path(path: &str) -> PathBuf {
    let p = PathBuf::from(path);
    if p.is_dir() {
        p.join("telemetry.jsonl")
    } else {
        p
    }
}

/// Periodic snapshot writer driven by the learner loop: `tick()` once
/// per iteration, snapshots land every `snapshot_secs`. JSONL write
/// failures degrade (warn once, keep training) via [`JsonlLogger`];
/// Prometheus write failures are silently dropped per attempt (the next
/// tick retries).
pub struct Exporter {
    jsonl: Option<JsonlLogger>,
    prom_path: Option<PathBuf>,
    every: Duration,
    last: Instant,
}

impl Exporter {
    /// Build from a [`TelemetryConfig`]; `Ok(None)` when disabled or no
    /// output is named.
    pub fn from_config(cfg: &TelemetryConfig) -> Result<Option<Exporter>> {
        if !cfg.enabled || (cfg.jsonl_path.is_empty() && cfg.prometheus_path.is_empty()) {
            return Ok(None);
        }
        let jsonl = if cfg.jsonl_path.is_empty() {
            None
        } else {
            Some(JsonlLogger::create(resolve_jsonl_path(&cfg.jsonl_path))?)
        };
        let prom_path = if cfg.prometheus_path.is_empty() {
            None
        } else {
            Some(PathBuf::from(&cfg.prometheus_path))
        };
        Ok(Some(Exporter {
            jsonl,
            prom_path,
            every: Duration::from_secs_f64(cfg.snapshot_secs.max(0.05)),
            last: Instant::now(),
        }))
    }

    /// Where the JSONL stream lands (for logs / `fastpbrl top` hints).
    pub fn jsonl_path(&self) -> Option<&Path> {
        self.jsonl.as_ref().map(|l| l.path.as_path())
    }

    /// Snapshot-and-write if the interval elapsed.
    pub fn tick(&mut self) {
        if self.last.elapsed() >= self.every {
            self.flush();
        }
    }

    /// Snapshot-and-write unconditionally (end of run).
    pub fn flush(&mut self) {
        self.last = Instant::now();
        let snap = crate::telemetry::global().snapshot();
        self.write(&snap);
    }

    fn write(&mut self, snap: &Snapshot) {
        if let Some(w) = self.jsonl.as_mut() {
            w.write(&snapshot_to_json(snap));
        }
        if let Some(p) = &self.prom_path {
            let _ = write_prometheus(p, snap);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_snapshot() -> Snapshot {
        Snapshot {
            uptime_s: 2.0,
            counters: vec![CounterSnap { name: "a.b".into(), value: 3, rate: 1.5 }],
            gauges: vec![GaugeSnap { name: "g".into(), value: 0.5 }],
            hists: vec![HistSnap {
                name: "h".into(),
                count: 2,
                sum: 3,
                p50: 1.0,
                p95: 2.0,
                p99: 2.0,
            }],
        }
    }

    #[test]
    fn jsonl_encoding_is_pinned() {
        let line = snapshot_to_json(&sample_snapshot()).to_string();
        assert_eq!(
            line,
            "{\"counters\":[{\"name\":\"a.b\",\"rate\":1.5,\"value\":3}],\
             \"gauges\":[{\"name\":\"g\",\"value\":0.5}],\
             \"hists\":[{\"count\":2,\"name\":\"h\",\"p50\":1,\"p95\":2,\"p99\":2,\"sum\":3}],\
             \"uptime_s\":2}"
        );
    }

    #[test]
    fn json_roundtrip_preserves_snapshot() {
        let snap = sample_snapshot();
        let j = Json::parse(&snapshot_to_json(&snap).to_string()).unwrap();
        assert_eq!(snapshot_from_json(&j).unwrap(), snap);
    }

    #[test]
    fn prometheus_encoding_is_pinned() {
        let text = prometheus_text(&sample_snapshot());
        let want = "\
# TYPE fastpbrl_a_b counter
fastpbrl_a_b 3
# TYPE fastpbrl_g gauge
fastpbrl_g 0.5
# TYPE fastpbrl_h summary
fastpbrl_h{quantile=\"0.5\"} 1
fastpbrl_h{quantile=\"0.95\"} 2
fastpbrl_h{quantile=\"0.99\"} 2
fastpbrl_h_sum 3
fastpbrl_h_count 2
";
        assert_eq!(text, want);
    }

    #[test]
    fn exporter_disabled_configs_build_nothing() {
        assert!(Exporter::from_config(&TelemetryConfig::off()).unwrap().is_none());
        // enabled but no outputs named
        let cfg = TelemetryConfig { enabled: true, ..TelemetryConfig::off() };
        assert!(Exporter::from_config(&cfg).unwrap().is_none());
    }

    #[test]
    fn prometheus_file_is_written_atomically_in_place() {
        let dir = std::env::temp_dir().join("fastpbrl_test_prom");
        let path = dir.join("metrics.prom");
        write_prometheus(&path, &sample_snapshot()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("fastpbrl_a_b 3"));
        assert!(!path.with_extension("prom.tmp").exists());
    }
}
