//! First-class observability for live training runs.
//!
//! The paper's thesis — population training with minimal overhead over
//! single-agent training — needs *live* evidence, not just offline
//! benches. This subsystem provides it in three layers:
//!
//! - [`registry`]: a process-wide registry of named counters, gauges and
//!   log2-bucketed histograms backed by padded atomic cells. Recording
//!   is a relaxed `fetch_add` through a pre-resolved handle — no locks —
//!   and a single relaxed load + branch when disabled (the default).
//! - [`instrument`]: the timing layer — RAII phase timers for the
//!   learner loop ([`PhaseRecorder`]) and actor threads ([`timed`],
//!   [`ActorMetrics`]), plus the [`Stopwatch`]/[`PhaseTimer`] helpers
//!   folded in from the old `util::timer` (which now re-exports them).
//! - [`export`] / [`top`]: a periodic JSONL snapshot stream and
//!   Prometheus text dump ([`export::Exporter`]), and the `fastpbrl top`
//!   live table that tails the stream ([`top::run_top`]).
//!
//! # Metric catalog
//!
//! | name | kind | meaning |
//! |---|---|---|
//! | `learner.updates` | counter | per-agent update steps applied |
//! | `learner.env_steps` | counter | env steps absorbed by the gate |
//! | `learner.episodes` | counter | episode ends observed |
//! | `learner.phase.{drain,sample,upload,update_exec,host_sync,health_scan,evolve_upload,checkpoint}` | histogram | learner stage wall time, ns |
//! | `actor.{t}.env_steps` | counter | env steps produced by thread `t` |
//! | `actor.{t}.blocks` | counter | transport blocks published |
//! | `actor.{t}.phase.{forward,env_step,publish}` | histogram | actor stage wall time, ns |
//! | `actor.{t}.heartbeat_age_ms` | gauge | ms since thread `t`'s last heartbeat |
//! | `replay.stripe.{i}.fill` | gauge | live rows in stripe `i` |
//! | `replay.stripe.{i}.pushes` | counter | sink pushes into stripe `i` |
//! | `replay.stripe.{i}.contended` | counter | pushes that found the stripe lock held |
//! | `kernels.matmat.{tiled,reference,sparse}` | counter | mat-mat dispatch outcomes |
//! | `kernels.conv.{direct,im2col}` | counter | conv dispatch outcomes |
//! | `supervisor.actor_restarts` | counter | crashed actor threads respawned |
//! | `supervisor.stall_events` | counter | heartbeat stall transitions |
//! | `supervisor.members_repaired` | counter | quarantined members repaired |
//! | `runtime.retries` | counter | transient runtime faults retried in place |
//! | `runtime.device_restarts` | counter | device losses recovered by a runtime rebuild |
//!
//! The supervision and runtime-recovery counters record even with
//! telemetry disabled (they feed
//! [`Summary`](crate::coordinator::trainer::Summary) through
//! [`RunCounter`], one bump site for both views). Everything else is
//! off until [`TelemetryConfig::enabled`] switches the registry on.

pub mod export;
pub mod instrument;
pub mod registry;
pub mod top;

use std::sync::OnceLock;

pub use instrument::{timed, ActorMetrics, PhaseRecorder, PhaseSpan, PhaseTimer, ScopedNs,
                     Stopwatch};
pub use registry::{Counter, CounterSnap, Gauge, GaugeSnap, HistSnap, Histogram, Registry,
                   RunCounter, Snapshot};

/// Telemetry switches carried by
/// [`TrainerConfig`](crate::coordinator::trainer::TrainerConfig).
#[derive(Clone, Debug, PartialEq)]
pub struct TelemetryConfig {
    /// Master switch for the gated record paths.
    pub enabled: bool,
    /// JSONL snapshot stream path ("" = off). A directory resolves to
    /// `<dir>/telemetry.jsonl` — the same convention `fastpbrl top`
    /// uses, so `--telemetry <run-dir>` and `fastpbrl top <run-dir>`
    /// pair up.
    pub jsonl_path: String,
    /// Prometheus text dump path, atomically rewritten per snapshot
    /// ("" = off).
    pub prometheus_path: String,
    /// Seconds between snapshots.
    pub snapshot_secs: f64,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig::off()
    }
}

impl TelemetryConfig {
    /// Telemetry fully off (the default — zero overhead on hot paths).
    pub fn off() -> TelemetryConfig {
        TelemetryConfig {
            enabled: false,
            jsonl_path: String::new(),
            prometheus_path: String::new(),
            snapshot_secs: 1.0,
        }
    }

    /// Enabled, streaming JSONL snapshots to `path`.
    pub fn jsonl(path: impl Into<String>) -> TelemetryConfig {
        TelemetryConfig { enabled: true, jsonl_path: path.into(), ..TelemetryConfig::off() }
    }

    pub fn is_on(&self) -> bool {
        self.enabled
    }
}

static GLOBAL: OnceLock<Registry> = OnceLock::new();

/// The process-wide registry all production call sites record against.
/// Starts disabled; [`configure`] (called at the top of every trainer
/// run) flips it per the run's [`TelemetryConfig`]. The switch is
/// process-wide: concurrent runs in one process share it, last
/// configure wins.
pub fn global() -> &'static Registry {
    GLOBAL.get_or_init(Registry::new)
}

/// Is the global registry currently recording?
#[inline]
pub fn enabled() -> bool {
    global().is_enabled()
}

pub fn set_enabled(on: bool) {
    global().set_enabled(on);
}

/// Apply a run's config to the global registry.
pub fn configure(cfg: &TelemetryConfig) {
    set_enabled(cfg.enabled);
}

/// Get-or-create a counter in the global registry.
pub fn counter(name: &str) -> Counter {
    global().counter(name)
}

/// Get-or-create a gauge in the global registry.
pub fn gauge(name: &str) -> Gauge {
    global().gauge(name)
}

/// Get-or-create a histogram in the global registry.
pub fn histogram(name: &str) -> Histogram {
    global().histogram(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_constructors() {
        let off = TelemetryConfig::default();
        assert!(!off.is_on());
        assert!(off.jsonl_path.is_empty());
        let on = TelemetryConfig::jsonl("run/telemetry.jsonl");
        assert!(on.is_on());
        assert_eq!(on.jsonl_path, "run/telemetry.jsonl");
        assert!((on.snapshot_secs - 1.0).abs() < 1e-12);
    }

    #[test]
    fn global_registry_handles_are_shared() {
        let a = counter("mod_test.shared");
        let b = counter("mod_test.shared");
        a.add_always(2);
        assert_eq!(b.get(), 2);
    }
}
