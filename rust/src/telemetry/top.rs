//! `fastpbrl top`: tail a telemetry JSONL snapshot stream and render a
//! live per-phase / per-actor table — steps/s per actor thread, the
//! update:env ratio, learner phase time breakdown, replay stripe fill,
//! and supervision/kernel counters.
//!
//! The renderer is a pure function of the latest [`Snapshot`]
//! ([`render`]), so the table is golden-testable without a terminal;
//! [`run_top`] adds the tailing loop around it.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::Result;

use crate::telemetry::export::{resolve_jsonl_path, snapshot_from_json};
use crate::telemetry::registry::Snapshot;
use crate::util::json::Json;

/// `<run-dir>` or the JSONL file itself — directories resolve to
/// `<dir>/telemetry.jsonl`, matching the trainer's output convention.
pub fn resolve_stream(path: &Path) -> PathBuf {
    resolve_jsonl_path(&path.to_string_lossy())
}

/// Latest parseable snapshot in the stream (`None`: file missing or no
/// complete line yet — the run may not have started).
pub fn latest_snapshot(file: &Path) -> Result<Option<Snapshot>> {
    let text = match std::fs::read_to_string(file) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let Some(line) = text.lines().rev().find(|l| !l.trim().is_empty()) else {
        return Ok(None);
    };
    let j = Json::parse(line.trim())?;
    Ok(Some(snapshot_from_json(&j)?))
}

fn ms(ns: f64) -> f64 {
    ns / 1e6
}

/// Seconds since the stream file was last written (`None`: missing file
/// or a filesystem that won't report mtime).
pub fn stream_age_secs(file: &Path) -> Option<f64> {
    let mtime = std::fs::metadata(file).ok()?.modified().ok()?;
    // A future mtime (clock skew) reads as a fresh file, not a panic.
    Some(mtime.elapsed().map(|d| d.as_secs_f64()).unwrap_or(0.0))
}

/// Snapshot cadence inferred from the stream itself: the `uptime_s`
/// delta between the last two snapshot lines. `None` until two lines
/// exist or when the delta is non-positive (restarted run).
pub fn stream_cadence_secs(file: &Path) -> Option<f64> {
    let text = std::fs::read_to_string(file).ok()?;
    let uptimes: Vec<f64> = text
        .lines()
        .rev()
        .filter(|l| !l.trim().is_empty())
        .take(2)
        .filter_map(|l| Json::parse(l.trim()).ok())
        .filter_map(|j| j.get("uptime_s").and_then(|u| u.as_f64()))
        .collect();
    match uptimes[..] {
        [newer, older] if newer > older => Some(newer - older),
        _ => None,
    }
}

/// Warning banner when the stream has gone quiet: the writer touches the
/// file every `snapshot_secs`, so an age past ~3 cadences means the run
/// is stalled, crashed, or finished. Pure so the threshold math is
/// testable; `None` means fresh.
pub fn staleness_banner(age_s: Option<f64>, cadence_s: Option<f64>) -> Option<String> {
    let age = age_s?;
    let cadence = cadence_s.unwrap_or(1.0).max(0.1);
    let threshold = (3.0 * cadence).max(2.0);
    if age <= threshold {
        return None;
    }
    Some(format!(
        "*** STALE (age {age:.0}s) — no snapshot for > {threshold:.0}s; \
         run stalled, crashed, or finished ***"
    ))
}

/// Thread/stripe indices present under `prefix{i}suffix` names.
fn indices(names: impl Iterator<Item = String>, prefix: &str, suffix: &str) -> Vec<usize> {
    let mut out: Vec<usize> = names
        .filter_map(|n| {
            n.strip_prefix(prefix)?.strip_suffix(suffix)?.parse::<usize>().ok()
        })
        .collect();
    out.sort_unstable();
    out.dedup();
    out
}

/// Render one snapshot as the `fastpbrl top` table.
pub fn render(snap: &Snapshot) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "fastpbrl top — uptime {:.1}s", snap.uptime_s);

    // ---- learner: updates, env steps, ratio -----------------------------
    let updates = snap.counter("learner.updates");
    let env_steps = snap.counter("learner.env_steps");
    if let (Some(u), Some(e)) = (updates, env_steps) {
        let ratio = if e.value > 0 { u.value as f64 / e.value as f64 } else { 0.0 };
        let _ = writeln!(
            out,
            "learner   {} updates ({:.1}/s)   {} env steps ({:.1}/s)   update:env {:.3}",
            u.value, u.rate, e.value, e.rate, ratio
        );
    }

    // ---- learner phase breakdown ----------------------------------------
    let phases: Vec<_> =
        snap.hists.iter().filter(|h| h.name.starts_with("learner.phase.")).collect();
    if !phases.is_empty() {
        let total_ns: f64 = phases.iter().map(|h| h.sum as f64).sum();
        let _ = writeln!(
            out,
            "{:<14} {:>8} {:>10} {:>10} {:>10} {:>7}",
            "phase", "calls", "total s", "p50 ms", "p99 ms", "share"
        );
        for h in &phases {
            let name = h.name.trim_start_matches("learner.phase.");
            let share = if total_ns > 0.0 { 100.0 * h.sum as f64 / total_ns } else { 0.0 };
            let _ = writeln!(
                out,
                "{:<14} {:>8} {:>10.3} {:>10.3} {:>10.3} {:>6.1}%",
                name,
                h.count,
                h.sum as f64 / 1e9,
                ms(h.p50),
                ms(h.p99),
                share
            );
        }
    }

    // ---- per-actor-thread table -----------------------------------------
    let threads =
        indices(snap.counters.iter().map(|c| c.name.clone()), "actor.", ".env_steps");
    if !threads.is_empty() {
        let _ = writeln!(
            out,
            "{:<8} {:>12} {:>10} {:>12} {:>12} {:>12} {:>10}",
            "actor", "steps", "steps/s", "fwd p50 ms", "env p50 ms", "pub p50 ms", "hb ms"
        );
        for t in threads {
            let steps = snap.counter(&format!("actor.{t}.env_steps"));
            let p50 = |phase: &str| {
                snap.hist(&format!("actor.{t}.phase.{phase}")).map(|h| ms(h.p50)).unwrap_or(0.0)
            };
            let hb = snap
                .gauge(&format!("actor.{t}.heartbeat_age_ms"))
                .map(|g| g.value)
                .unwrap_or(0.0);
            let _ = writeln!(
                out,
                "{:<8} {:>12} {:>10.1} {:>12.3} {:>12.3} {:>12.3} {:>10.0}",
                format!("#{t}"),
                steps.map(|c| c.value).unwrap_or(0),
                steps.map(|c| c.rate).unwrap_or(0.0),
                p50("forward"),
                p50("env_step"),
                p50("publish"),
                hb
            );
        }
    }

    // ---- replay stripes --------------------------------------------------
    let stripes = indices(snap.gauges.iter().map(|g| g.name.clone()), "replay.stripe.", ".fill");
    if !stripes.is_empty() {
        let fills: Vec<f64> = stripes
            .iter()
            .map(|i| {
                snap.gauge(&format!("replay.stripe.{i}.fill")).map(|g| g.value).unwrap_or(0.0)
            })
            .collect();
        let contended: u64 = stripes
            .iter()
            .map(|i| {
                snap.counter(&format!("replay.stripe.{i}.contended"))
                    .map(|c| c.value)
                    .unwrap_or(0)
            })
            .sum();
        let min = fills.iter().copied().fold(f64::INFINITY, f64::min);
        let max = fills.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let _ = writeln!(
            out,
            "replay    {} stripes   fill min {:.0} / max {:.0}   contended pushes {}",
            stripes.len(),
            min,
            max,
            contended
        );
    }

    // ---- supervision + kernel dispatch counters -------------------------
    for prefix in ["supervisor.", "kernels."] {
        let items: Vec<_> =
            snap.counters.iter().filter(|c| c.name.starts_with(prefix)).collect();
        if !items.is_empty() {
            let line = items
                .iter()
                .map(|c| format!("{} {}", c.name, c.value))
                .collect::<Vec<_>>()
                .join("   ");
            let _ = writeln!(out, "{line}");
        }
    }
    out
}

/// Tail the stream at `path` (file or run dir), rendering the latest
/// snapshot every `refresh_s` seconds. `iterations` bounds the number of
/// render cycles (0 = until interrupted).
pub fn run_top(path: &Path, refresh_s: f64, iterations: u64) -> Result<()> {
    let file = resolve_stream(path);
    let mut done = 0u64;
    loop {
        match latest_snapshot(&file) {
            Ok(Some(snap)) => {
                let banner =
                    staleness_banner(stream_age_secs(&file), stream_cadence_secs(&file));
                // clear screen + home, optional staleness banner, then the table
                print!("\x1b[2J\x1b[H");
                if let Some(b) = banner {
                    println!("{b}");
                }
                print!("{}", render(&snap));
                let _ = std::io::stdout().flush();
            }
            Ok(None) => {
                println!("waiting for snapshots at {} …", file.display());
            }
            Err(e) => {
                println!("unreadable snapshot stream {}: {e:#}", file.display());
            }
        }
        done += 1;
        if iterations != 0 && done >= iterations {
            return Ok(());
        }
        std::thread::sleep(Duration::from_secs_f64(refresh_s.max(0.1)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::registry::{CounterSnap, GaugeSnap, HistSnap};

    fn synthetic() -> Snapshot {
        Snapshot {
            uptime_s: 12.5,
            counters: vec![
                CounterSnap { name: "actor.0.env_steps".into(), value: 4000, rate: 320.0 },
                CounterSnap { name: "actor.1.env_steps".into(), value: 3900, rate: 310.0 },
                CounterSnap { name: "kernels.matmat.tiled".into(), value: 77, rate: 6.0 },
                CounterSnap { name: "learner.env_steps".into(), value: 7900, rate: 630.0 },
                CounterSnap { name: "learner.updates".into(), value: 7900, rate: 630.0 },
                CounterSnap { name: "replay.stripe.0.contended".into(), value: 3, rate: 0.2 },
                CounterSnap { name: "supervisor.actor_restarts".into(), value: 1, rate: 0.0 },
            ],
            gauges: vec![
                GaugeSnap { name: "actor.0.heartbeat_age_ms".into(), value: 12.0 },
                GaugeSnap { name: "replay.stripe.0.fill".into(), value: 512.0 },
                GaugeSnap { name: "replay.stripe.1.fill".into(), value: 480.0 },
            ],
            hists: vec![
                HistSnap {
                    name: "actor.0.phase.forward".into(),
                    count: 100,
                    sum: 50_000_000,
                    p50: 400_000.0,
                    p95: 900_000.0,
                    p99: 1_000_000.0,
                },
                HistSnap {
                    name: "learner.phase.drain".into(),
                    count: 200,
                    sum: 2_000_000_000,
                    p50: 9_000_000.0,
                    p95: 20_000_000.0,
                    p99: 30_000_000.0,
                },
                HistSnap {
                    name: "learner.phase.update_exec".into(),
                    count: 150,
                    sum: 6_000_000_000,
                    p50: 30_000_000.0,
                    p95: 60_000_000.0,
                    p99: 80_000_000.0,
                },
            ],
        }
    }

    #[test]
    fn render_covers_every_section() {
        let table = render(&synthetic());
        // learner line with the update:env ratio
        assert!(table.contains("update:env 1.000"), "{table}");
        // phase rows with share of total phase time
        assert!(table.contains("drain"), "{table}");
        assert!(table.contains("update_exec"), "{table}");
        assert!(table.contains("75.0%"), "{table}");
        // both actor threads with steps/s
        assert!(table.contains("#0"), "{table}");
        assert!(table.contains("#1"), "{table}");
        assert!(table.contains("320.0"), "{table}");
        // stripe fill + contention and the counter dumps
        assert!(table.contains("fill min 480 / max 512"), "{table}");
        assert!(table.contains("supervisor.actor_restarts 1"), "{table}");
        assert!(table.contains("kernels.matmat.tiled 77"), "{table}");
    }

    #[test]
    fn render_handles_an_empty_snapshot() {
        let table = render(&Snapshot::default());
        assert!(table.contains("uptime"));
    }

    #[test]
    fn latest_snapshot_tails_the_last_line() {
        let dir = std::env::temp_dir().join("fastpbrl_test_top");
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("telemetry.jsonl");
        assert!(latest_snapshot(&dir.join("missing.jsonl")).unwrap().is_none());
        let s1 = crate::telemetry::export::snapshot_to_json(&synthetic()).to_string();
        let mut older = synthetic();
        older.uptime_s = 1.0;
        let s0 = crate::telemetry::export::snapshot_to_json(&older).to_string();
        std::fs::write(&file, format!("{s0}\n{s1}\n")).unwrap();
        let got = latest_snapshot(&file).unwrap().unwrap();
        assert_eq!(got.uptime_s, 12.5, "must read the newest line");
        // directory form resolves to the conventional file name
        assert_eq!(resolve_stream(&dir), file);
    }

    #[test]
    fn staleness_banner_threshold_math() {
        // No age (missing file) — nothing to warn about.
        assert!(staleness_banner(None, Some(1.0)).is_none());
        // Fresh stream: age within 3x cadence (floored at 2s).
        assert!(staleness_banner(Some(1.0), Some(1.0)).is_none());
        assert!(staleness_banner(Some(2.0), None).is_none());
        // Stale: past the threshold, banner carries the age.
        let b = staleness_banner(Some(47.0), Some(1.0)).unwrap();
        assert!(b.contains("STALE (age 47s)"), "{b}");
        // Slow cadence stretches the threshold: 25s old at 10s cadence is fine.
        assert!(staleness_banner(Some(25.0), Some(10.0)).is_none());
        assert!(staleness_banner(Some(31.0), Some(10.0)).is_some());
        // Degenerate cadence clamps to the 2s floor instead of always firing.
        assert!(staleness_banner(Some(1.5), Some(0.0)).is_none());
    }

    #[test]
    fn cadence_is_inferred_from_uptime_deltas() {
        let dir = std::env::temp_dir().join("fastpbrl_test_top_cadence");
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("telemetry.jsonl");
        let at = |uptime: f64| {
            let mut s = synthetic();
            s.uptime_s = uptime;
            crate::telemetry::export::snapshot_to_json(&s).to_string()
        };
        // One line: no delta yet.
        std::fs::write(&file, format!("{}\n", at(1.0))).unwrap();
        assert!(stream_cadence_secs(&file).is_none());
        // Two lines 2.5s apart in run-uptime.
        std::fs::write(&file, format!("{}\n{}\n", at(1.0), at(3.5))).unwrap();
        let c = stream_cadence_secs(&file).unwrap();
        assert!((c - 2.5).abs() < 1e-9, "cadence {c}");
        // Restarted run (uptime went backwards): no cadence claim.
        std::fs::write(&file, format!("{}\n{}\n", at(9.0), at(0.5))).unwrap();
        assert!(stream_cadence_secs(&file).is_none());
        // A just-written file is fresh, so no banner fires.
        std::fs::write(&file, format!("{}\n{}\n", at(1.0), at(2.0))).unwrap();
        let banner =
            staleness_banner(stream_age_secs(&file), stream_cadence_secs(&file));
        assert!(banner.is_none(), "{banner:?}");
        // Missing file: no age at all.
        assert!(stream_age_secs(&dir.join("missing.jsonl")).is_none());
    }
}
