//! Timing instrumentation: the wall-clock helpers that used to live in
//! `util/timer.rs` ([`Stopwatch`], [`PhaseTimer`]) plus the RAII timers
//! that feed the registry — [`timed`] for per-thread actor phases,
//! [`PhaseRecorder`]/[`PhaseSpan`] for the learner loop stages, and
//! [`ActorMetrics`] bundling one actor thread's handles.
//!
//! Convention: histograms fed by these timers record **nanoseconds**.
//! When telemetry is disabled the RAII guards skip the clock reads
//! entirely (one relaxed load per guard), so instrumented hot paths cost
//! nothing measurable with the switch off.

use std::time::Instant;

use crate::telemetry::registry::{Counter, Histogram};

/// Scoped stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    pub fn restart(&mut self) -> f64 {
        let dt = self.elapsed_s();
        self.start = Instant::now();
        dt
    }
}

/// Accumulates time spent in named phases (update step, env step, sync…).
/// This is the run-local, single-threaded view the trainer's
/// [`Summary`](crate::coordinator::trainer::Summary) carries;
/// [`PhaseRecorder`] layers the registry histograms on top.
#[derive(Default, Debug, Clone)]
pub struct PhaseTimer {
    phases: Vec<(String, f64, u64)>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, phase: &str, seconds: f64) {
        if let Some(e) = self.phases.iter_mut().find(|e| e.0 == phase) {
            e.1 += seconds;
            e.2 += 1;
        } else {
            self.phases.push((phase.to_string(), seconds, 1));
        }
    }

    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let sw = Stopwatch::start();
        let out = f();
        self.add(phase, sw.elapsed_s());
        out
    }

    pub fn total(&self, phase: &str) -> f64 {
        self.phases.iter().find(|e| e.0 == phase).map(|e| e.1).unwrap_or(0.0)
    }

    pub fn count(&self, phase: &str) -> u64 {
        self.phases.iter().find(|e| e.0 == phase).map(|e| e.2).unwrap_or(0)
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, secs, n) in &self.phases {
            out.push_str(&format!(
                "{name}: {secs:.3}s over {n} calls ({:.3} ms/call)\n",
                secs / (*n as f64) * 1e3
            ));
        }
        out
    }
}

/// RAII nanosecond timer: records the guarded scope's duration into the
/// histogram on drop. When the histogram's registry is disabled, no
/// clock is read and nothing is recorded.
pub struct ScopedNs<'a> {
    hist: &'a Histogram,
    start: Option<Instant>,
}

/// Start a [`ScopedNs`] over `hist`.
#[inline]
pub fn timed(hist: &Histogram) -> ScopedNs<'_> {
    ScopedNs { start: hist.is_enabled().then(Instant::now), hist }
}

impl Drop for ScopedNs<'_> {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            self.hist.record(start.elapsed().as_nanos() as u64);
        }
    }
}

/// The learner loop's phase clock: every `add` lands in both the
/// run-local [`PhaseTimer`] (always — `Summary` reports it with
/// telemetry off) and a registry histogram named
/// `{prefix}.{phase}` in nanoseconds (gated on the enabled switch).
pub struct PhaseRecorder {
    timer: PhaseTimer,
    prefix: String,
    hists: Vec<(String, Histogram)>,
}

impl PhaseRecorder {
    /// `prefix` names the histogram family, e.g. `learner.phase`.
    pub fn new(prefix: &str) -> PhaseRecorder {
        PhaseRecorder { timer: PhaseTimer::new(), prefix: prefix.to_string(), hists: Vec::new() }
    }

    fn hist(&mut self, phase: &str) -> &Histogram {
        if let Some(i) = self.hists.iter().position(|(n, _)| n == phase) {
            &self.hists[i].1
        } else {
            let full = format!("{}.{}", self.prefix, phase);
            self.hists.push((phase.to_string(), crate::telemetry::histogram(&full)));
            &self.hists.last().expect("just pushed").1
        }
    }

    /// Record `seconds` spent in `phase` (manual form, for callers that
    /// already hold an `Instant` pair).
    pub fn add(&mut self, phase: &str, seconds: f64) {
        self.timer.add(phase, seconds);
        self.hist(phase).record((seconds * 1e9) as u64);
    }

    /// RAII form: the returned [`PhaseSpan`] records on drop, so early
    /// exits (`?`, `break`, `continue`) are timed correctly.
    pub fn span(&mut self, phase: &'static str) -> PhaseSpan<'_> {
        PhaseSpan { start: Instant::now(), phase, rec: self }
    }

    pub fn timer(&self) -> &PhaseTimer {
        &self.timer
    }

    pub fn into_timer(self) -> PhaseTimer {
        self.timer
    }
}

/// RAII guard from [`PhaseRecorder::span`].
pub struct PhaseSpan<'a> {
    rec: &'a mut PhaseRecorder,
    phase: &'static str,
    start: Instant,
}

impl Drop for PhaseSpan<'_> {
    fn drop(&mut self) {
        let secs = self.start.elapsed().as_secs_f64();
        self.rec.add(self.phase, secs);
    }
}

/// One actor thread's metric handles, registered under `actor.{t}.*`.
/// Created at the top of each actor-loop incarnation: a respawned
/// thread re-resolves the same names and lands in the same cells.
pub struct ActorMetrics {
    /// `actor.{t}.env_steps` — environment steps produced (all agents of
    /// the thread).
    pub env_steps: Counter,
    /// `actor.{t}.blocks` — transport blocks published.
    pub blocks: Counter,
    /// `actor.{t}.phase.forward` — policy/q-net block inference + action
    /// selection, ns.
    pub forward: Histogram,
    /// `actor.{t}.phase.env_step` — vectorized env stepping, ns.
    pub env_step: Histogram,
    /// `actor.{t}.phase.publish` — sink push or channel send + recycle, ns.
    pub publish: Histogram,
}

impl ActorMetrics {
    pub fn for_thread(thread: usize) -> ActorMetrics {
        let c = |k: &str| crate::telemetry::counter(&format!("actor.{thread}.{k}"));
        let h = |k: &str| crate::telemetry::histogram(&format!("actor.{thread}.phase.{k}"));
        ActorMetrics {
            env_steps: c("env_steps"),
            blocks: c("blocks"),
            forward: h("forward"),
            env_step: h("env_step"),
            publish: h("publish"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::registry::Registry;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.elapsed_ms() >= 4.0);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut t = PhaseTimer::new();
        t.add("a", 0.5);
        t.add("a", 0.25);
        t.add("b", 1.0);
        assert!((t.total("a") - 0.75).abs() < 1e-12);
        assert_eq!(t.count("a"), 2);
        assert_eq!(t.count("missing"), 0);
        assert!(t.report().contains("a:"));
    }

    #[test]
    fn timed_records_only_when_enabled() {
        let r = Registry::new();
        let h = r.histogram("scope");
        {
            let _t = timed(&h);
        }
        assert_eq!(h.count(), 0, "disabled: no record, no clock");
        r.set_enabled(true);
        {
            let _t = timed(&h);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(h.count(), 1);
        assert!(h.sum() >= 500_000, "recorded ns, got {}", h.sum());
    }

    #[test]
    fn phase_recorder_feeds_timer_and_histogram() {
        let mut rec = PhaseRecorder::new("test_rec.phase");
        crate::telemetry::set_enabled(true);
        rec.add("drain", 0.002);
        {
            let _span = rec.span("drain");
        }
        crate::telemetry::set_enabled(false);
        assert_eq!(rec.timer().count("drain"), 2);
        assert!(rec.timer().total("drain") >= 0.002);
        let h = crate::telemetry::histogram("test_rec.phase.drain");
        assert_eq!(h.count(), 2);
        assert!(h.sum() >= 2_000_000, "ns convention, got {}", h.sum());
        // the local timer keeps counting with telemetry off
        rec.add("drain", 0.001);
        assert_eq!(rec.timer().count("drain"), 3);
        assert_eq!(h.count(), 2);
        assert_eq!(rec.into_timer().count("drain"), 3);
    }

    #[test]
    fn actor_metrics_share_cells_across_respawn() {
        let a = ActorMetrics::for_thread(901);
        let b = ActorMetrics::for_thread(901);
        a.env_steps.add_always(3);
        b.env_steps.add_always(4);
        assert_eq!(a.env_steps.get(), 7);
    }
}
