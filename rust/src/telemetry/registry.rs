//! The metrics registry: named counters, gauges, and log-bucketed
//! histograms behind lock-free recording handles.
//!
//! Registration (name -> cell) is the cold path and takes a `RwLock`;
//! the handles a caller gets back ([`Counter`], [`Gauge`], [`Histogram`])
//! hold `Arc`s straight to the padded atomic cells, so the hot path is a
//! relaxed `fetch_add` with no lock and no lookup. Every handle also
//! carries the owning registry's enabled flag: when telemetry is off,
//! `add`/`set`/`record` are a single relaxed load and a branch — no
//! stores, no clock reads (see
//! [`timed`](crate::telemetry::instrument::timed)), which is what keeps
//! the instrumented hot paths within the bench budget.
//!
//! [`Registry::snapshot`] walks the cells into a point-in-time
//! [`Snapshot`]: counter values with rates since the previous snapshot,
//! gauge values, and histogram count/sum plus p50/p95/p99 estimated from
//! the log2 buckets (linear interpolation inside the landing bucket).
//! Writers are never blocked by a snapshot in progress.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, RwLock};
use std::time::Instant;

/// One atomic metric cell, padded to a cache line so independent
/// counters never false-share (actor threads hammer their own cells).
#[repr(align(64))]
#[derive(Default)]
struct Cell(AtomicU64);

/// Log2 buckets: bucket 0 holds zeros, bucket `i >= 1` holds
/// `[2^(i-1), 2^i)`, and the last bucket absorbs everything above
/// `2^62`. 64 buckets cover the full `u64` range, which is plenty for
/// nanosecond phase timings (bucket 35 is already ~half a minute).
pub const HIST_BUCKETS: usize = 64;

/// Bucket index for a recorded value (see [`HIST_BUCKETS`]).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }
}

/// `[lo, hi)` value range of bucket `i`; the last bucket's `hi` is
/// saturated to `u64::MAX`.
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    if i == 0 {
        (0, 1)
    } else {
        let lo = 1u64 << (i - 1);
        let hi = if i >= HIST_BUCKETS - 1 { u64::MAX } else { 1u64 << i };
        (lo, hi)
    }
}

/// Quantile estimate from log2 bucket counts: walk the cumulative
/// distribution to the bucket holding the q-th sample, then interpolate
/// linearly inside that bucket's value range. Returns 0 for an empty
/// histogram.
pub fn quantile_from_buckets(buckets: &[u64], q: f64) -> f64 {
    let total: u64 = buckets.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let target = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0);
    let mut cum = 0u64;
    for (i, &c) in buckets.iter().enumerate() {
        if c == 0 {
            continue;
        }
        let before = cum as f64;
        cum += c;
        if cum as f64 >= target {
            let (lo, hi) = bucket_bounds(i);
            let frac = (target - before) / c as f64;
            return lo as f64 + frac * (hi - lo) as f64;
        }
    }
    bucket_bounds(buckets.len().saturating_sub(1)).1 as f64
}

struct HistCells {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: Cell,
    count: Cell,
}

impl HistCells {
    fn new() -> HistCells {
        HistCells {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: Cell::default(),
            count: Cell::default(),
        }
    }
}

/// Monotonic counter handle. `add` is gated on the registry's enabled
/// flag; `add_always` bypasses the gate for run-defining events (actor
/// restarts, member repairs) that [`Summary`](crate::coordinator::trainer::Summary)
/// reports even when telemetry is off.
#[derive(Clone)]
pub struct Counter {
    cell: Arc<Cell>,
    enabled: Arc<AtomicBool>,
}

impl Counter {
    #[inline]
    pub fn add(&self, n: u64) {
        if self.enabled.load(Ordering::Relaxed) {
            self.cell.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Record regardless of the enabled switch (cold-path events only).
    #[inline]
    pub fn add_always(&self, n: u64) {
        self.cell.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.cell.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge handle storing an `f64` (bit-cast into the
/// atomic cell).
#[derive(Clone)]
pub struct Gauge {
    cell: Arc<Cell>,
    enabled: Arc<AtomicBool>,
}

impl Gauge {
    #[inline]
    pub fn set(&self, v: f64) {
        if self.enabled.load(Ordering::Relaxed) {
            self.cell.0.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.cell.0.load(Ordering::Relaxed))
    }
}

/// Log2-bucketed histogram handle. Values are unit-agnostic `u64`s; the
/// phase timers record **nanoseconds** by convention (see
/// [`timed`](crate::telemetry::instrument::timed)).
#[derive(Clone)]
pub struct Histogram {
    cells: Arc<HistCells>,
    enabled: Arc<AtomicBool>,
}

impl Histogram {
    #[inline]
    pub fn record(&self, v: u64) {
        if !self.enabled.load(Ordering::Relaxed) {
            return;
        }
        self.cells.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.cells.sum.0.fetch_add(v, Ordering::Relaxed);
        self.cells.count.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Whether records currently land (drives the skip-the-clock
    /// optimization in the RAII timers).
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn count(&self) -> u64 {
        self.cells.count.0.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.cells.sum.0.load(Ordering::Relaxed)
    }

    fn bucket_counts(&self) -> [u64; HIST_BUCKETS] {
        std::array::from_fn(|i| self.cells.buckets[i].load(Ordering::Relaxed))
    }

    /// Quantile estimate over everything recorded so far.
    pub fn quantile(&self, q: f64) -> f64 {
        quantile_from_buckets(&self.bucket_counts(), q)
    }
}

enum Metric {
    Counter(Arc<Cell>),
    Gauge(Arc<Cell>),
    Hist(Arc<HistCells>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Hist(_) => "histogram",
        }
    }
}

/// One counter in a [`Snapshot`]: cumulative value plus the per-second
/// rate since the previous snapshot of the same registry (first
/// snapshot: averaged over the registry's uptime).
#[derive(Clone, Debug, PartialEq)]
pub struct CounterSnap {
    pub name: String,
    pub value: u64,
    pub rate: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct GaugeSnap {
    pub name: String,
    pub value: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct HistSnap {
    pub name: String,
    pub count: u64,
    pub sum: u64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Point-in-time view of a [`Registry`], sorted by metric name.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    /// Seconds since the registry was created.
    pub uptime_s: f64,
    pub counters: Vec<CounterSnap>,
    pub gauges: Vec<GaugeSnap>,
    pub hists: Vec<HistSnap>,
}

impl Snapshot {
    pub fn counter(&self, name: &str) -> Option<&CounterSnap> {
        self.counters.iter().find(|c| c.name == name)
    }

    pub fn gauge(&self, name: &str) -> Option<&GaugeSnap> {
        self.gauges.iter().find(|g| g.name == name)
    }

    pub fn hist(&self, name: &str) -> Option<&HistSnap> {
        self.hists.iter().find(|h| h.name == name)
    }
}

struct RateState {
    at: f64,
    counters: BTreeMap<String, u64>,
}

/// A named-metric registry. Unit tests build private instances;
/// production code records against the process-wide one behind
/// [`crate::telemetry::global`].
pub struct Registry {
    enabled: Arc<AtomicBool>,
    metrics: RwLock<BTreeMap<String, Metric>>,
    epoch: Instant,
    rates: Mutex<RateState>,
}

/// Poison tolerance: a panicking actor thread can die between a
/// registry lock acquire and release (registration is cold but happens
/// on actor spawn); the map is only ever mutated by complete inserts,
/// so the data behind a poisoned lock is valid.
fn read_lock<T>(l: &RwLock<T>) -> std::sync::RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|p| p.into_inner())
}

fn write_lock<T>(l: &RwLock<T>) -> std::sync::RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|p| p.into_inner())
}

fn mutex_lock<T>(l: &Mutex<T>) -> MutexGuard<'_, T> {
    l.lock().unwrap_or_else(|p| p.into_inner())
}

impl Registry {
    /// A fresh registry, **disabled** — records are no-ops until
    /// [`Registry::set_enabled`] switches them on.
    pub fn new() -> Registry {
        Registry {
            enabled: Arc::new(AtomicBool::new(false)),
            metrics: RwLock::new(BTreeMap::new()),
            epoch: Instant::now(),
            rates: Mutex::new(RateState { at: 0.0, counters: BTreeMap::new() }),
        }
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn uptime_s(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    fn cell(&self, name: &str, make: fn() -> Metric, want: &'static str) -> Metric {
        {
            let m = read_lock(&self.metrics);
            if let Some(existing) = m.get(name) {
                return Self::clone_checked(name, existing, want);
            }
        }
        let mut m = write_lock(&self.metrics);
        let entry = m.entry(name.to_string()).or_insert_with(make);
        Self::clone_checked(name, entry, want)
    }

    fn clone_checked(name: &str, m: &Metric, want: &'static str) -> Metric {
        assert!(
            m.kind() == want,
            "telemetry metric {name:?} already registered as a different kind: \
             is a {}, requested as a {want}",
            m.kind()
        );
        match m {
            Metric::Counter(c) => Metric::Counter(Arc::clone(c)),
            Metric::Gauge(c) => Metric::Gauge(Arc::clone(c)),
            Metric::Hist(h) => Metric::Hist(Arc::clone(h)),
        }
    }

    /// Get-or-create the named counter. Panics if the name is already
    /// registered as a different kind (a programmer error).
    pub fn counter(&self, name: &str) -> Counter {
        match self.cell(name, || Metric::Counter(Arc::new(Cell::default())), "counter") {
            Metric::Counter(cell) => Counter { cell, enabled: Arc::clone(&self.enabled) },
            _ => unreachable!(),
        }
    }

    /// Get-or-create the named gauge.
    pub fn gauge(&self, name: &str) -> Gauge {
        match self.cell(name, || Metric::Gauge(Arc::new(Cell::default())), "gauge") {
            Metric::Gauge(cell) => Gauge { cell, enabled: Arc::clone(&self.enabled) },
            _ => unreachable!(),
        }
    }

    /// Get-or-create the named histogram.
    pub fn histogram(&self, name: &str) -> Histogram {
        match self.cell(name, || Metric::Hist(Arc::new(HistCells::new())), "histogram") {
            Metric::Hist(cells) => Histogram { cells, enabled: Arc::clone(&self.enabled) },
            _ => unreachable!(),
        }
    }

    /// Point-in-time view of every metric. Writers are not blocked:
    /// values are relaxed loads, so a snapshot taken mid-write may be at
    /// most one in-flight record behind per cell — never torn, never
    /// decreasing.
    pub fn snapshot(&self) -> Snapshot {
        let now = self.uptime_s();
        let metrics = read_lock(&self.metrics);
        let mut rates = mutex_lock(&self.rates);
        let dt = now - rates.at;
        let mut snap = Snapshot { uptime_s: now, ..Snapshot::default() };
        for (name, m) in metrics.iter() {
            match m {
                Metric::Counter(c) => {
                    let v = c.0.load(Ordering::Relaxed);
                    let rate = match rates.counters.get(name) {
                        Some(&p) if dt > 1e-9 && v >= p => (v - p) as f64 / dt,
                        None if now > 1e-9 => v as f64 / now,
                        _ => 0.0,
                    };
                    rates.counters.insert(name.clone(), v);
                    snap.counters.push(CounterSnap { name: name.clone(), value: v, rate });
                }
                Metric::Gauge(c) => {
                    snap.gauges.push(GaugeSnap {
                        name: name.clone(),
                        value: f64::from_bits(c.0.load(Ordering::Relaxed)),
                    });
                }
                Metric::Hist(h) => {
                    let buckets: Vec<u64> =
                        h.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
                    snap.hists.push(HistSnap {
                        name: name.clone(),
                        count: h.count.0.load(Ordering::Relaxed),
                        sum: h.sum.0.load(Ordering::Relaxed),
                        p50: quantile_from_buckets(&buckets, 0.50),
                        p95: quantile_from_buckets(&buckets, 0.95),
                        p99: quantile_from_buckets(&buckets, 0.99),
                    });
                }
            }
        }
        rates.at = now;
        snap
    }
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

/// A run-local counter mirrored into a registry [`Counter`]: one `bump`
/// call site increments both, so the run's
/// [`Summary`](crate::coordinator::trainer::Summary) (which must report
/// these even with telemetry off) and the exported metric cannot drift
/// apart. The registry side uses [`Counter::add_always`] — these are
/// rare, run-defining events, and the exported cell is a process-wide
/// total across runs.
pub struct RunCounter {
    local: u64,
    shared: Counter,
}

impl RunCounter {
    pub fn new(shared: Counter) -> RunCounter {
        RunCounter { local: 0, shared }
    }

    pub fn bump(&mut self, n: u64) {
        self.local += n;
        self.shared.add_always(n);
    }

    /// This run's count (not the process-wide registry total).
    pub fn get(&self) -> u64 {
        self.local
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_covers_the_range() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index((1 << 62) - 1), 62);
        assert_eq!(bucket_index(1 << 62), 63);
        assert_eq!(bucket_index(u64::MAX), 63);
        // every bucket's own bounds map back to it
        for i in 0..HIST_BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert_eq!(bucket_index(lo), i, "lo of bucket {i}");
            assert_eq!(bucket_index(hi - 1), i, "hi-1 of bucket {i}");
        }
    }

    #[test]
    fn quantiles_interpolate_within_buckets() {
        // empty histogram
        assert_eq!(quantile_from_buckets(&[0; HIST_BUCKETS], 0.5), 0.0);
        // all mass in one bucket [4, 8): p50 lands mid-bucket
        let mut b = [0u64; HIST_BUCKETS];
        b[3] = 10;
        let p50 = quantile_from_buckets(&b, 0.5);
        assert!((4.0..8.0).contains(&p50), "p50 {p50}");
        assert!(quantile_from_buckets(&b, 0.99) <= 8.0);
        // two buckets, 90/10 split: p50 in the low bucket, p99 in the high
        let mut b = [0u64; HIST_BUCKETS];
        b[1] = 90; // [1, 2)
        b[10] = 10; // [512, 1024)
        assert!(quantile_from_buckets(&b, 0.5) < 2.0);
        let p99 = quantile_from_buckets(&b, 0.99);
        assert!((512.0..=1024.0).contains(&p99), "p99 {p99}");
        // quantiles are monotone in q
        let p95 = quantile_from_buckets(&b, 0.95);
        assert!(p95 <= p99);
    }

    #[test]
    fn histogram_quantiles_track_recorded_values() {
        let r = Registry::new();
        r.set_enabled(true);
        let h = r.histogram("t");
        for v in 1..=100u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.sum(), 5050);
        // log2 buckets bound the error by 2x
        let p50 = h.quantile(0.5);
        assert!((25.0..=100.0).contains(&p50), "p50 {p50}");
        assert!(h.quantile(0.99) >= p50);
    }

    #[test]
    fn disabled_registry_records_nothing() {
        let r = Registry::new();
        let c = r.counter("c");
        let g = r.gauge("g");
        let h = r.histogram("h");
        c.add(5);
        c.inc();
        g.set(3.5);
        h.record(42);
        assert_eq!(c.get(), 0);
        assert_eq!(g.get(), 0.0);
        assert_eq!(h.count(), 0);
        assert!(!h.is_enabled());
        // the always-path still lands (Summary counters)
        c.add_always(2);
        assert_eq!(c.get(), 2);
        // re-enabling makes the gated path live
        r.set_enabled(true);
        c.add(5);
        assert_eq!(c.get(), 7);
    }

    #[test]
    fn handles_share_cells_by_name() {
        let r = Registry::new();
        r.set_enabled(true);
        let a = r.counter("same");
        let b = r.counter("same");
        a.add(2);
        b.add(3);
        assert_eq!(a.get(), 5);
        assert_eq!(b.get(), 5);
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        r.counter("x");
        let _ = r.gauge("x");
    }

    #[test]
    fn snapshot_reports_rates_and_quantiles() {
        let r = Registry::new();
        r.set_enabled(true);
        let c = r.counter("steps");
        c.add(100);
        r.gauge("fill").set(7.0);
        let h = r.histogram("lat");
        h.record(10);
        h.record(1000);
        let s1 = r.snapshot();
        assert_eq!(s1.counter("steps").unwrap().value, 100);
        assert!(s1.counter("steps").unwrap().rate > 0.0, "first snapshot averages over uptime");
        assert_eq!(s1.gauge("fill").unwrap().value, 7.0);
        let hs = s1.hist("lat").unwrap();
        assert_eq!(hs.count, 2);
        assert_eq!(hs.sum, 1010);
        assert!(hs.p50 <= hs.p95 && hs.p95 <= hs.p99);
        // no progress between snapshots -> rate falls to 0
        let s2 = r.snapshot();
        let rate = s2.counter("steps").unwrap().rate;
        assert!(rate >= 0.0 && rate < 1e7, "stale counter rate {rate}");
    }

    #[test]
    fn concurrent_hammer_matches_serial_total() {
        let r = Arc::new(Registry::new());
        r.set_enabled(true);
        let threads = 8;
        let per = 10_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    let c = r.counter("hammer");
                    let h = r.histogram("hammer_h");
                    for i in 0..per {
                        c.inc();
                        h.record(i % 17);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.counter("hammer").get(), threads * per);
        assert_eq!(r.histogram("hammer_h").count(), threads * per);
    }

    #[test]
    fn snapshot_while_writing_is_monotone() {
        let r = Arc::new(Registry::new());
        r.set_enabled(true);
        let writer = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                let c = r.counter("mono");
                for _ in 0..200_000 {
                    c.inc();
                }
            })
        };
        let mut last = 0u64;
        for _ in 0..50 {
            let s = r.snapshot();
            let v = s.counter("mono").unwrap().value;
            assert!(v >= last, "counter went backwards: {v} < {last}");
            last = v;
        }
        writer.join().unwrap();
        assert_eq!(r.counter("mono").get(), 200_000);
    }

    #[test]
    fn run_counter_mirrors_into_registry() {
        let r = Registry::new(); // disabled: the mirror must still land
        let mut rc = RunCounter::new(r.counter("restarts"));
        rc.bump(1);
        rc.bump(2);
        assert_eq!(rc.get(), 3);
        assert_eq!(r.counter("restarts").get(), 3);
    }
}
