//! Artifact manifest: the rust mirror of `python/compile/layout.py`.
//!
//! `artifacts/manifest.json` describes every AOT-lowered computation: the
//! flat-state field layout (offset/shape/dtype/init/group), batch inputs,
//! and env dims. This module parses it and implements the *same* init-spec
//! semantics as the python side so the coordinator can initialize, read
//! and mutate train states without any Python at runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub enum Dtype {
    F32,
    U32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> anyhow::Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "u32" => Dtype::U32,
            "i32" => Dtype::I32,
            other => anyhow::bail!("unknown dtype {other:?}"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct Field {
    pub name: String,
    pub offset: usize,
    pub size: usize,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    pub init: String,
    pub group: String,
    pub per_agent: bool,
}

impl Field {
    /// Size of one agent's slice (leading axis = population).
    pub fn agent_stride(&self) -> usize {
        if self.per_agent && !self.shape.is_empty() && self.shape[0] > 0 {
            self.size / self.shape[0]
        } else {
            self.size
        }
    }
}

#[derive(Clone, Debug)]
pub struct BatchInput {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl BatchInput {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug, Default)]
pub struct EnvDesc {
    pub obs_dim: usize,
    pub act_dim: usize,
    pub frame: Option<(usize, usize, usize)>,
    pub n_actions: usize,
}

#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    pub algo: String,
    pub env: String,
    pub env_desc: EnvDesc,
    pub pop: usize,
    pub num_steps: usize,
    pub batch: usize,
    pub hidden: Vec<usize>,
    pub state_size: usize,
    /// "state" for update steps; "actions"/"qvalues" for forward passes.
    pub output: String,
    pub sync_target_groups: Vec<String>,
    pub fields: Vec<Field>,
    pub inputs: Vec<BatchInput>,
    by_name: BTreeMap<String, usize>,
}

impl Artifact {
    /// Construct an artifact description directly (used by manifest
    /// parsing and by tests that build synthetic layouts).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: String,
        file: PathBuf,
        algo: String,
        env: String,
        env_desc: EnvDesc,
        pop: usize,
        num_steps: usize,
        batch: usize,
        hidden: Vec<usize>,
        state_size: usize,
        output: String,
        sync_target_groups: Vec<String>,
        fields: Vec<Field>,
        inputs: Vec<BatchInput>,
    ) -> Artifact {
        let by_name = fields
            .iter()
            .enumerate()
            .map(|(i, f)| (f.name.clone(), i))
            .collect();
        Artifact {
            name,
            file,
            algo,
            env,
            env_desc,
            pop,
            num_steps,
            batch,
            hidden,
            state_size,
            output,
            sync_target_groups,
            fields,
            inputs,
            by_name,
        }
    }

    pub fn field(&self, name: &str) -> anyhow::Result<&Field> {
        self.by_name
            .get(name)
            .map(|&i| &self.fields[i])
            .ok_or_else(|| anyhow::anyhow!("artifact {} has no field {name:?}", self.name))
    }

    pub fn group_fields(&self, group: &str) -> Vec<&Field> {
        self.fields.iter().filter(|f| f.group == group).collect()
    }

    /// Initialize a flat state following the manifest init specs — the
    /// rust mirror of `Layout.init_numpy`, but with per-call seeding.
    pub fn init_state(&self, rng: &mut Rng, seed_tag: u64) -> Vec<f32> {
        let mut out = vec![0.0f32; self.state_size];
        for f in &self.fields {
            let seg = &mut out[f.offset..f.offset + f.size];
            init_field(f, seg, rng, seed_tag);
        }
        // targets start equal to their online nets
        self.sync_targets(&mut out);
        out
    }

    /// Copy online params onto their `_t/` target twins.
    pub fn sync_targets(&self, state: &mut [f32]) {
        for f in &self.fields {
            if f.group == "policy_target" || f.group == "critic_target" {
                let src_name = f.name.replacen("_t/", "/", 1);
                if let Ok(src) = self.field(&src_name) {
                    debug_assert_eq!(src.size, f.size);
                    let (so, fo, n) = (src.offset, f.offset, f.size);
                    // split to copy within one slice
                    if so < fo {
                        let (a, b) = state.split_at_mut(fo);
                        b[..n].copy_from_slice(&a[so..so + n]);
                    } else {
                        let (a, b) = state.split_at_mut(so);
                        a[fo..fo + n].copy_from_slice(&b[..n]);
                    }
                }
            }
        }
    }

    /// Read a field's raw f32 lane out of a host state copy.
    pub fn read<'a>(&self, state: &'a [f32], name: &str) -> anyhow::Result<&'a [f32]> {
        let f = self.field(name)?;
        Ok(&state[f.offset..f.offset + f.size])
    }

    pub fn read_mut<'a>(&self, state: &'a mut [f32], name: &str)
                        -> anyhow::Result<&'a mut [f32]> {
        let f = self.field(name)?;
        Ok(&mut state[f.offset..f.offset + f.size])
    }

    /// Read one agent's slice of a per-agent field.
    pub fn read_agent<'a>(&self, state: &'a [f32], name: &str, agent: usize)
                          -> anyhow::Result<&'a [f32]> {
        let f = self.field(name)?;
        anyhow::ensure!(f.per_agent, "field {name} is not per-agent");
        anyhow::ensure!(agent < f.shape[0], "agent {agent} out of range");
        let stride = f.agent_stride();
        Ok(&state[f.offset + agent * stride..f.offset + (agent + 1) * stride])
    }

    /// Concatenate agent `agent`'s rows over all per-agent fields of the
    /// given groups into one parameter vector (CEM's genome view).
    pub fn agent_vector(&self, state: &[f32], groups: &[&str], agent: usize) -> Vec<f32> {
        let mut out = Vec::new();
        for f in &self.fields {
            if f.per_agent && groups.iter().any(|g| *g == f.group) {
                let stride = f.agent_stride();
                out.extend_from_slice(
                    &state[f.offset + agent * stride..f.offset + (agent + 1) * stride],
                );
            }
        }
        out
    }

    /// Scatter a parameter vector back into agent `agent`'s rows
    /// (inverse of [`Artifact::agent_vector`]).
    pub fn set_agent_vector(&self, state: &mut [f32], groups: &[&str], agent: usize,
                            vec: &[f32]) {
        let mut k = 0;
        for f in &self.fields {
            if f.per_agent && groups.iter().any(|g| *g == f.group) {
                let stride = f.agent_stride();
                state[f.offset + agent * stride..f.offset + (agent + 1) * stride]
                    .copy_from_slice(&vec[k..k + stride]);
                k += stride;
            }
        }
        debug_assert_eq!(k, vec.len(), "vector length mismatch");
    }

    /// Copy agent `src`'s row into agent `dst` for every per-agent field
    /// in the given groups (PBT exploit step).
    pub fn copy_agent(&self, state: &mut [f32], groups: &[&str], src: usize, dst: usize) {
        for f in &self.fields {
            if !f.per_agent || !groups.iter().any(|g| *g == f.group) {
                continue;
            }
            let stride = f.agent_stride();
            let (so, do_) = (f.offset + src * stride, f.offset + dst * stride);
            if so == do_ {
                continue;
            }
            let (lo, hi, n) = if so < do_ { (so, do_, stride) } else { (do_, so, stride) };
            let (a, b) = state.split_at_mut(hi);
            if so < do_ {
                b[..n].copy_from_slice(&a[lo..lo + n]);
            } else {
                a[lo..lo + n].copy_from_slice(&b[..n]);
            }
        }
    }
}

fn init_field(f: &Field, seg: &mut [f32], rng: &mut Rng, seed_tag: u64) {
    let spec = f.init.as_str();
    if spec == "zeros" {
        seg.fill(0.0);
    } else if spec == "ones" {
        seg.fill(1.0);
    } else if spec == "step" {
        seg.fill(f32::from_bits(0)); // u32 zero
    } else if spec == "key" {
        // distinct per-lane threefry key material (u32 bit-cast into f32),
        // matching layout.py but offset by the caller's seed tag so every
        // population/run gets unique streams.
        for (i, v) in seg.iter_mut().enumerate() {
            let mut x = (i as u64)
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(seed_tag);
            x ^= x >> 31;
            *v = f32::from_bits((x & 0xFFFF_FFFF) as u32);
        }
    } else if let Some(v) = spec.strip_prefix("const:") {
        let x: f32 = v.parse().unwrap_or(0.0);
        seg.fill(x);
    } else if let Some(v) = spec.strip_prefix("lecun_uniform:") {
        let fan_in: f32 = v.parse().unwrap_or(1.0);
        let bound = (3.0 / fan_in.max(1.0)).sqrt();
        rng.fill_uniform(seg, -bound, bound);
    } else if let Some(v) = spec.strip_prefix("uniform:") {
        let parts: Vec<&str> = v.split(',').collect();
        let lo: f32 = parts[0].parse().unwrap_or(0.0);
        let hi: f32 = parts.get(1).and_then(|s| s.parse().ok()).unwrap_or(1.0);
        rng.fill_uniform(seg, lo, hi);
    } else {
        // unknown spec: leave zeros (forward-compatible)
        seg.fill(0.0);
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, Artifact>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e} (run `make artifacts`)"))?;
        let json = Json::parse(&text)?;
        let mut artifacts = BTreeMap::new();
        let arts = json
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts object"))?;
        for (name, a) in arts {
            artifacts.insert(name.clone(), parse_artifact(name, a, &dir)?);
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&Artifact> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "artifact {name:?} not found; available: {:?}",
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Find an artifact by attributes (algo + env + pop [+ num_steps]).
    pub fn find(&self, algo: &str, env: &str, pop: usize, num_steps: Option<usize>)
                -> anyhow::Result<&Artifact> {
        self.artifacts
            .values()
            .find(|a| {
                a.algo == algo
                    && a.env == env
                    && a.pop == pop
                    && num_steps.map(|k| a.num_steps == k).unwrap_or(true)
            })
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact for algo={algo} env={env} pop={pop} k={num_steps:?}; \
                     regenerate with `python -m compile.aot --spec {algo}:{env}:p{pop}:...`"
                )
            })
    }
}

fn req_usize(j: &Json, key: &str) -> anyhow::Result<usize> {
    j.get(key)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow::anyhow!("manifest: missing/invalid {key}"))
}

fn req_str<'a>(j: &'a Json, key: &str) -> anyhow::Result<&'a str> {
    j.get(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow::anyhow!("manifest: missing/invalid {key}"))
}

fn parse_artifact(name: &str, a: &Json, dir: &Path) -> anyhow::Result<Artifact> {
    let mut fields = Vec::new();
    for fj in a.get("fields").and_then(|f| f.as_arr()).unwrap_or(&[]) {
        fields.push(Field {
            name: req_str(fj, "name")?.to_string(),
            offset: req_usize(fj, "offset")?,
            size: req_usize(fj, "size")?,
            shape: fj
                .get("shape")
                .and_then(|s| s.as_arr())
                .map(|v| v.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
            dtype: Dtype::parse(req_str(fj, "dtype")?)?,
            init: req_str(fj, "init")?.to_string(),
            group: req_str(fj, "group")?.to_string(),
            per_agent: fj.get("per_agent").and_then(|v| v.as_bool()).unwrap_or(true),
        });
    }
    let mut inputs = Vec::new();
    for ij in a.get("inputs").and_then(|f| f.as_arr()).unwrap_or(&[]) {
        inputs.push(BatchInput {
            name: req_str(ij, "name")?.to_string(),
            shape: ij
                .get("shape")
                .and_then(|s| s.as_arr())
                .map(|v| v.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
            dtype: Dtype::parse(req_str(ij, "dtype")?)?,
        });
    }
    let ed = a.get("env_desc");
    let env_desc = EnvDesc {
        obs_dim: ed.and_then(|e| e.get("obs_dim")).and_then(|v| v.as_usize()).unwrap_or(0),
        act_dim: ed.and_then(|e| e.get("act_dim")).and_then(|v| v.as_usize()).unwrap_or(0),
        frame: ed.and_then(|e| e.get("frame")).and_then(|v| v.as_arr()).and_then(|v| {
            if v.len() == 3 {
                Some((v[0].as_usize()?, v[1].as_usize()?, v[2].as_usize()?))
            } else {
                None
            }
        }),
        n_actions: ed
            .and_then(|e| e.get("n_actions"))
            .and_then(|v| v.as_usize())
            .unwrap_or(0),
    };
    Ok(Artifact::new(
        name.to_string(),
        dir.join(req_str(a, "file")?),
        req_str(a, "algo")?.to_string(),
        req_str(a, "env")?.to_string(),
        env_desc,
        req_usize(a, "pop")?,
        req_usize(a, "num_steps")?,
        req_usize(a, "batch")?,
        a.get("hidden")
            .and_then(|s| s.as_arr())
            .map(|v| v.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default(),
        req_usize(a, "state_size")?,
        req_str(a, "output")?.to_string(),
        a.get("sync_target_groups")
            .and_then(|s| s.as_arr())
            .map(|v| v.iter().filter_map(|x| x.as_str().map(String::from)).collect())
            .unwrap_or_default(),
        fields,
        inputs,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_artifact() -> Artifact {
        let fields = vec![
            Field {
                name: "policy/w0".into(),
                offset: 0,
                size: 6,
                shape: vec![2, 3],
                dtype: Dtype::F32,
                init: "lecun_uniform:3".into(),
                group: "policy".into(),
                per_agent: true,
            },
            Field {
                name: "policy_t/w0".into(),
                offset: 6,
                size: 6,
                shape: vec![2, 3],
                dtype: Dtype::F32,
                init: "lecun_uniform:3".into(),
                group: "policy_target".into(),
                per_agent: true,
            },
            Field {
                name: "lr".into(),
                offset: 12,
                size: 2,
                shape: vec![2],
                dtype: Dtype::F32,
                init: "const:0.0003".into(),
                group: "hyper".into(),
                per_agent: true,
            },
            Field {
                name: "rng".into(),
                offset: 14,
                size: 4,
                shape: vec![2, 2],
                dtype: Dtype::U32,
                init: "key".into(),
                group: "rng".into(),
                per_agent: true,
            },
        ];
        Artifact::new(
            "toy".into(),
            PathBuf::new(),
            "td3".into(),
            "pendulum".into(),
            EnvDesc::default(),
            2,
            1,
            4,
            vec![3],
            18,
            "state".into(),
            vec!["policy".into()],
            fields,
            vec![],
        )
    }

    #[test]
    fn init_syncs_targets_and_sets_hypers() {
        let a = toy_artifact();
        let mut rng = Rng::new(0);
        let s = a.init_state(&mut rng, 7);
        assert_eq!(s.len(), 18);
        assert_eq!(&s[0..6], &s[6..12], "targets must equal online at init");
        assert!((s[12] - 3e-4).abs() < 1e-9);
        // key material nonzero and distinct
        let keys: Vec<u32> = s[14..18].iter().map(|v| v.to_bits()).collect();
        assert!(keys.iter().all(|&k| k != 0));
        assert_ne!(keys[0], keys[2]);
    }

    #[test]
    fn copy_agent_moves_only_selected_groups() {
        let a = toy_artifact();
        let mut rng = Rng::new(0);
        let mut s = a.init_state(&mut rng, 7);
        // make agents distinct
        for v in a.read_mut(&mut s, "policy/w0").unwrap()[..3].iter_mut() {
            *v = 9.0;
        }
        s[12] = 1.0; // lr agent 0
        a.copy_agent(&mut s, &["policy"], 0, 1);
        let w = a.read(&s, "policy/w0").unwrap();
        assert_eq!(&w[0..3], &w[3..6]);
        // hyper group untouched
        assert!((s[13] - 3e-4).abs() < 1e-9);
    }

    #[test]
    fn read_agent_slices() {
        let a = toy_artifact();
        let mut rng = Rng::new(1);
        let mut s = a.init_state(&mut rng, 0);
        a.read_mut(&mut s, "policy/w0").unwrap()[3..6].fill(5.0);
        let ag1 = a.read_agent(&s, "policy/w0", 1).unwrap();
        assert_eq!(ag1, &[5.0, 5.0, 5.0]);
        assert!(a.read_agent(&s, "policy/w0", 2).is_err());
    }
}
