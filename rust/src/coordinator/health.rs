//! Per-member parameter health: NaN/Inf/norm-explosion scanning over the
//! `[P, ...]` host state and in-place quarantine repair.
//!
//! A single diverged member must not poison a multi-hour population run:
//! its transitions feed shared replay (CEM-RL/DvD) and its NaNs survive
//! every later update step. The repair primitive is the one PBT already
//! uses for exploitation — [`Artifact::copy_agent`] from the best healthy
//! member — so quarantine is "exploit as fault recovery": the diseased
//! member is overwritten wholesale (networks, targets, optimizer state,
//! step counters AND hyperparameters, since divergence is usually
//! hyper-caused) and training continues.
//!
//! The scan runs on the learner thread right after each `to_host` sync
//! (see `Trainer::run`), so it sees exactly the state a checkpoint would
//! persist; `last_good` checkpoint promotion is keyed off
//! [`HealthReport::all_healthy`].

use crate::coordinator::trainer::AGENT_STATE_GROUPS;
use crate::manifest::{Artifact, Dtype};

/// Groups scanned for non-finite values and norm explosion: the f32
/// learnable state. Bit-cast counter/key lanes (group `step`, u32 dtype)
/// are excluded — their bit patterns may alias NaN legitimately.
pub const SCAN_GROUPS: &[&str] = &[
    "policy", "policy_target", "critic", "critic_target", "opt", "alpha",
];

/// Groups overwritten when repairing a quarantined member: the full
/// per-agent training state ([`AGENT_STATE_GROUPS`]) plus `hyper`, so a
/// divergence-inducing hyperparameter row dies with the member.
pub fn repair_groups() -> Vec<&'static str> {
    let mut g = AGENT_STATE_GROUPS.to_vec();
    g.push("hyper");
    g
}

/// Why one member was flagged by [`scan_members`].
#[derive(Clone, Debug, PartialEq)]
pub struct MemberHealth {
    pub member: usize,
    /// NaN/Inf lanes found across the member's scanned fields.
    pub non_finite: usize,
    /// Largest finite |value| seen (norm-explosion evidence).
    pub max_abs: f32,
}

/// One post-sync health scan over all `P` members.
#[derive(Clone, Debug, Default)]
pub struct HealthReport {
    /// Members scanned (the population size).
    pub pop: usize,
    /// Flagged members, ascending by index.
    pub unhealthy: Vec<MemberHealth>,
}

impl HealthReport {
    pub fn all_healthy(&self) -> bool {
        self.unhealthy.is_empty()
    }

    /// Indices of the flagged members.
    pub fn quarantined(&self) -> Vec<usize> {
        self.unhealthy.iter().map(|m| m.member).collect()
    }

    fn is_unhealthy(&self, member: usize) -> bool {
        self.unhealthy.iter().any(|m| m.member == member)
    }
}

/// Scan every member's f32 learnable state ([`SCAN_GROUPS`]) for NaN/Inf
/// lanes and values whose magnitude exceeds `norm_limit`
/// (`norm_limit <= 0` disables the magnitude check). Runs in one linear
/// pass per field; cost is one read of the state copy the trainer
/// already paid `to_host` for.
pub fn scan_members(artifact: &Artifact, state: &[f32], norm_limit: f32) -> HealthReport {
    let pop = artifact.pop;
    let mut non_finite = vec![0usize; pop];
    let mut max_abs = vec![0.0f32; pop];
    for f in &artifact.fields {
        if !f.per_agent || f.dtype != Dtype::F32 {
            continue;
        }
        if !SCAN_GROUPS.iter().any(|g| *g == f.group) {
            continue;
        }
        let stride = f.agent_stride();
        for member in 0..pop.min(if stride == 0 { 0 } else { f.size / stride }) {
            let row = &state[f.offset + member * stride..f.offset + (member + 1) * stride];
            for &v in row {
                if !v.is_finite() {
                    non_finite[member] += 1;
                } else if v.abs() > max_abs[member] {
                    max_abs[member] = v.abs();
                }
            }
        }
    }
    let unhealthy = (0..pop)
        .filter(|&m| non_finite[m] > 0 || (norm_limit > 0.0 && max_abs[m] > norm_limit))
        .map(|m| MemberHealth { member: m, non_finite: non_finite[m], max_abs: max_abs[m] })
        .collect();
    HealthReport { pop, unhealthy }
}

/// What [`repair_members`] did: which donor seeded the copies and which
/// members were overwritten.
#[derive(Clone, Debug, PartialEq)]
pub struct RepairOutcome {
    /// The healthy member whose row was copied into every quarantined one.
    pub donor: usize,
    /// Members repaired in place, ascending by index.
    pub repaired: Vec<usize>,
}

/// Repair every quarantined member in place by copying the best healthy
/// member's full row ([`repair_groups`]) over it. `fitness[m]` ranks
/// donor candidates (windowed return; NaN ranks last — a member with no
/// finished episodes can still donate if nothing better exists). Errors
/// only when no healthy member remains: that run is unrecoverable from
/// live state and must fall back to checkpoint lineage.
pub fn repair_members(
    artifact: &Artifact,
    state: &mut [f32],
    report: &HealthReport,
    fitness: &[f64],
) -> anyhow::Result<RepairOutcome> {
    if report.all_healthy() {
        return Ok(RepairOutcome { donor: 0, repaired: Vec::new() });
    }
    let donor = (0..report.pop)
        .filter(|&m| !report.is_unhealthy(m))
        .max_by(|&a, &b| {
            let fa = fitness.get(a).copied().unwrap_or(f64::NEG_INFINITY);
            let fb = fitness.get(b).copied().unwrap_or(f64::NEG_INFINITY);
            // NaN (no episodes yet) ranks below every real return
            let fa = if fa.is_nan() { f64::NEG_INFINITY } else { fa };
            let fb = if fb.is_nan() { f64::NEG_INFINITY } else { fb };
            fa.partial_cmp(&fb).unwrap()
        })
        .ok_or_else(|| {
            anyhow::anyhow!(
                "all {} population members are unhealthy — no donor for repair",
                report.pop
            )
        })?;
    let groups = repair_groups();
    let mut repaired = Vec::with_capacity(report.unhealthy.len());
    for m in report.quarantined() {
        artifact.copy_agent(state, &groups, donor, m);
        repaired.push(m);
    }
    Ok(RepairOutcome { donor, repaired })
}

/// Fault injection: overwrite one lane of `member`'s first scanned field
/// with NaN, simulating in-training divergence. Test builds only.
#[cfg(feature = "fault-inject")]
pub fn poison_member(artifact: &Artifact, state: &mut [f32], member: usize) {
    for f in &artifact.fields {
        if !f.per_agent || f.dtype != Dtype::F32 {
            continue;
        }
        if !SCAN_GROUPS.iter().any(|g| *g == f.group) {
            continue;
        }
        let stride = f.agent_stride();
        if member < artifact.pop && stride > 0 {
            state[f.offset + member * stride] = f32::NAN;
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{Artifact, EnvDesc, Field};
    use std::path::PathBuf;

    /// Toy layout: per-agent policy + hyper rows plus a u32 `step` lane
    /// whose bit patterns alias NaN (must never be scanned).
    fn toy_artifact(pop: usize) -> Artifact {
        let mut fields = Vec::new();
        let mut off = 0;
        let mut push = |name: &str, shape: Vec<usize>, group: &str, dtype: Dtype| {
            let size: usize = shape.iter().product();
            fields.push(Field {
                name: name.into(),
                offset: off,
                size,
                shape,
                dtype,
                init: "zeros".into(),
                group: group.into(),
                per_agent: true,
            });
            off += size;
        };
        push("policy/w0", vec![pop, 2, 2], "policy", Dtype::F32);
        push("adam_policy/m0", vec![pop, 2, 2], "opt", Dtype::F32);
        push("lr", vec![pop], "hyper", Dtype::F32);
        push("step", vec![pop], "step", Dtype::U32);
        Artifact::new(
            "toy".into(),
            PathBuf::new(),
            "td3".into(),
            "pendulum".into(),
            EnvDesc::default(),
            pop,
            1,
            4,
            vec![],
            off,
            "state".into(),
            vec![],
            fields,
            vec![],
        )
    }

    fn fill_member(art: &Artifact, state: &mut [f32], field: &str, member: usize, v: f32) {
        let f = art.field(field).unwrap();
        let stride = f.agent_stride();
        state[f.offset + member * stride..f.offset + (member + 1) * stride].fill(v);
    }

    #[test]
    fn clean_state_is_healthy_even_with_nan_bitcast_counters() {
        let art = toy_artifact(3);
        let mut state = vec![0.0f32; art.state_size];
        // u32 counter lanes bit-alias NaN: the scan must not care
        let f = art.field("step").unwrap();
        for v in &mut state[f.offset..f.offset + f.size] {
            *v = f32::from_bits(0x7FC0_0001); // a quiet NaN pattern
        }
        let report = scan_members(&art, &state, 1e6);
        assert_eq!(report.pop, 3);
        assert!(report.all_healthy(), "{:?}", report.unhealthy);
    }

    #[test]
    fn scan_flags_nan_inf_and_norm_explosion_per_member() {
        let art = toy_artifact(4);
        let mut state = vec![0.1f32; art.state_size];
        let f = art.field("policy/w0").unwrap();
        let stride = f.agent_stride();
        state[f.offset + stride] = f32::NAN; // member 1
        state[f.offset + 2 * stride + 1] = f32::INFINITY; // member 2
        fill_member(&art, &mut state, "adam_policy/m0", 3, 1e9); // member 3: explosion
        let report = scan_members(&art, &state, 1e6);
        assert_eq!(report.quarantined(), vec![1, 2, 3]);
        assert_eq!(report.unhealthy[0].non_finite, 1);
        assert_eq!(report.unhealthy[1].non_finite, 1);
        assert_eq!(report.unhealthy[2].non_finite, 0);
        assert!(report.unhealthy[2].max_abs > 1e6);
        // norm check off: only the non-finite members remain flagged
        let lax = scan_members(&art, &state, 0.0);
        assert_eq!(lax.quarantined(), vec![1, 2]);
    }

    #[test]
    fn repair_copies_best_healthy_member_including_hypers() {
        let art = toy_artifact(4);
        let mut state = vec![0.0f32; art.state_size];
        for m in 0..4 {
            fill_member(&art, &mut state, "policy/w0", m, m as f32);
            fill_member(&art, &mut state, "lr", m, 0.1 * (m + 1) as f32);
        }
        fill_member(&art, &mut state, "policy/w0", 1, f32::NAN);
        let report = scan_members(&art, &state, 1e6);
        assert_eq!(report.quarantined(), vec![1]);
        // member 3 has the best return among healthy {0, 2, 3}
        let fitness = vec![0.5, 99.0, 1.0, 2.0];
        let out = repair_members(&art, &mut state, &report, &fitness).unwrap();
        assert_eq!(out, RepairOutcome { donor: 3, repaired: vec![1] });
        let f = art.field("policy/w0").unwrap();
        let stride = f.agent_stride();
        assert!(state[f.offset + stride..f.offset + 2 * stride].iter().all(|&v| v == 3.0));
        let lr = art.field("lr").unwrap();
        assert_eq!(state[lr.offset + 1], state[lr.offset + 3]); // hyper row cloned
        assert!(scan_members(&art, &state, 1e6).all_healthy());
    }

    #[test]
    fn repair_tolerates_nan_fitness_and_rejects_total_loss() {
        let art = toy_artifact(2);
        let mut state = vec![0.0f32; art.state_size];
        fill_member(&art, &mut state, "policy/w0", 1, f32::NAN);
        let report = scan_members(&art, &state, 0.0);
        // no finished episodes yet: fitness all NaN, member 0 still donates
        let out =
            repair_members(&art, &mut state, &report, &[f64::NAN, f64::NAN]).unwrap();
        assert_eq!(out.donor, 0);
        assert_eq!(out.repaired, vec![1]);
        // every member poisoned: unrecoverable from live state
        fill_member(&art, &mut state, "policy/w0", 0, f32::NAN);
        fill_member(&art, &mut state, "policy/w0", 1, f32::NAN);
        let report = scan_members(&art, &state, 0.0);
        assert!(repair_members(&art, &mut state, &report, &[1.0, 2.0]).is_err());
    }

    #[test]
    #[cfg(feature = "fault-inject")]
    fn poison_member_is_detected_by_scan() {
        let art = toy_artifact(3);
        let mut state = vec![0.0f32; art.state_size];
        poison_member(&art, &mut state, 2);
        let report = scan_members(&art, &state, 0.0);
        assert_eq!(report.quarantined(), vec![2]);
    }
}
