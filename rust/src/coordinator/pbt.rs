//! Population Based Training controller (Jaderberg et al., 2017; paper
//! §5.1 + Appendix B.1).
//!
//! Every `interval_updates` update steps: rank agents by the mean of their
//! last `k` episode returns, replace the bottom `frac` with copies of
//! agents sampled uniformly from the top `frac` (parameters, targets,
//! optimizer state and step counters — everything in
//! [`AGENT_STATE_GROUPS`]), and give the clones fresh hyperparameters —
//! re-sampled from the prior (B.1) or perturbed (the classic PBT explore).

use crate::coordinator::hyperparams::HyperSpec;
use crate::coordinator::trainer::{Controller, EvolveCtx, AGENT_STATE_GROUPS};
use crate::util::stats::argsort_desc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Explore {
    /// Re-sample from the prior distribution (paper Appendix B.1).
    Resample,
    /// Perturb the parent's value by x0.8 / x1.25 (Jaderberg et al.).
    Perturb,
}

pub struct PbtController {
    pub spec: HyperSpec,
    /// Evolve every this many update steps (paper B.1 uses 100k).
    pub interval_updates: u64,
    /// Fraction replaced / fraction considered elite (paper: 30%).
    pub frac: f64,
    pub explore: Explore,
    last_evolve: u64,
    /// (generation, replaced agent, parent) log for tests/inspection.
    pub history: Vec<(u64, usize, usize)>,
}

impl PbtController {
    pub fn new(spec: HyperSpec, interval_updates: u64, frac: f64, explore: Explore) -> Self {
        assert!(frac > 0.0 && frac < 0.5, "truncation fraction in (0, 0.5)");
        PbtController { spec, interval_updates, frac, explore, last_evolve: 0, history: Vec::new() }
    }
}

impl Controller for PbtController {
    fn name(&self) -> &'static str {
        "pbt"
    }

    fn on_sync(&mut self, ctx: &mut EvolveCtx<'_>) -> anyhow::Result<()> {
        if ctx.updates_done < self.last_evolve + self.interval_updates {
            return Ok(());
        }
        // need at least one finished episode per agent to rank fairly
        if ctx.fitness.iter().any(|f| !f.is_finite()) {
            return Ok(());
        }
        let pop = ctx.artifact.pop;
        let m = ((pop as f64 * self.frac).floor() as usize).max(1);
        if 2 * m > pop {
            return Ok(());
        }
        self.last_evolve = ctx.updates_done;

        let ranked = argsort_desc(ctx.fitness); // best first
        let top = &ranked[..m];
        let bottom = &ranked[pop - m..];
        for &loser in bottom {
            let parent = top[ctx.rng.below(top.len())];
            // exploit: copy the parent's full training state row
            ctx.artifact.copy_agent(ctx.host, AGENT_STATE_GROUPS, parent, loser);
            // explore: new hyperparameters for the clone
            match self.explore {
                Explore::Resample => {
                    self.spec.sample_into(ctx.artifact, ctx.host, loser, ctx.rng)
                }
                Explore::Perturb => {
                    // clone inherits the parent's hypers, then perturbs
                    ctx.artifact.copy_agent(ctx.host, &["hyper"], parent, loser);
                    self.spec.perturb_into(ctx.artifact, ctx.host, loser, ctx.rng)
                }
            }
            ctx.reset_returns.push(loser);
            self.history.push((ctx.updates_done, loser, parent));
        }
        ctx.mutated = true;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{Artifact, Dtype, EnvDesc, Field};
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn toy_artifact(pop: usize) -> Artifact {
        let mut fields = Vec::new();
        let mut off = 0;
        let push = |name: &str, shape: Vec<usize>, group: &str, init: &str,
                        fields: &mut Vec<Field>, off: &mut usize| {
            let size: usize = shape.iter().product();
            fields.push(Field {
                name: name.into(),
                offset: *off,
                size,
                shape,
                dtype: Dtype::F32,
                init: init.into(),
                group: group.into(),
                per_agent: true,
            });
            *off += size;
        };
        push("policy/w0", vec![pop, 2, 2], "policy", "lecun_uniform:2", &mut fields, &mut off);
        push("lr_policy", vec![pop], "hyper", "const:0.0003", &mut fields, &mut off);
        push("gamma", vec![pop], "hyper", "const:0.99", &mut fields, &mut off);
        Artifact::new(
            "toy".into(),
            PathBuf::new(),
            "td3".into(),
            "pendulum".into(),
            EnvDesc::default(),
            pop,
            1,
            4,
            vec![],
            off,
            "state".into(),
            vec![],
            fields,
            vec![],
        )
    }

    fn evolve_once(explore: Explore) -> (Vec<f32>, PbtController, Vec<usize>) {
        let art = toy_artifact(4);
        let mut seed_rng = Rng::new(9);
        let mut host = art.init_state(&mut seed_rng, 0); // hypers at defaults
        // distinct policy rows: agent i filled with i
        for agent in 0..4 {
            let f = art.field("policy/w0").unwrap();
            let stride = f.agent_stride();
            for v in &mut host[f.offset + agent * stride..f.offset + (agent + 1) * stride] {
                *v = agent as f32;
            }
        }
        let fitness = vec![0.1, 3.0, 2.0, -1.0]; // best = 1, worst = 3
        let mut rng = Rng::new(0);
        let mut ctrl = PbtController::new(HyperSpec::td3(), 10, 0.26, explore);
        let mut ctx = EvolveCtx {
            artifact: &art,
            host: &mut host,
            fitness: &fitness,
            rng: &mut rng,
            updates_done: 100,
            env_steps: 100,
            mutated: false,
            reset_returns: Vec::new(),
        };
        ctrl.on_sync(&mut ctx).unwrap();
        assert!(ctx.mutated);
        let resets = ctx.reset_returns.clone();
        drop(ctx);
        (host, ctrl, resets)
    }

    #[test]
    fn worst_agent_becomes_clone_of_best() {
        let (host, ctrl, resets) = evolve_once(Explore::Resample);
        let art = toy_artifact(4);
        // agent 3 (worst) must now hold agent 1's weights (only top-1 elite)
        let w3 = art.read_agent(&host, "policy/w0", 3).unwrap();
        assert!(w3.iter().all(|&v| v == 1.0), "clone mismatch: {w3:?}");
        assert_eq!(resets, vec![3]);
        assert_eq!(ctrl.history.len(), 1);
        assert_eq!(ctrl.history[0].1, 3);
        assert_eq!(ctrl.history[0].2, 1);
    }

    #[test]
    fn resample_draws_from_prior_support() {
        let (host, _, _) = evolve_once(Explore::Resample);
        let art = toy_artifact(4);
        let lr = art.read_agent(&host, "lr_policy", 3).unwrap()[0];
        assert!((3e-5..=3e-3).contains(&(lr as f64)));
        let gamma = art.read_agent(&host, "gamma", 3).unwrap()[0];
        assert!((0.9..=1.0).contains(&(gamma as f64)));
    }

    #[test]
    fn perturb_inherits_then_nudges() {
        let (host, _, _) = evolve_once(Explore::Perturb);
        let art = toy_artifact(4);
        let lr = art.read_agent(&host, "lr_policy", 3).unwrap()[0] as f64;
        // parent lr was 3e-4; perturbation is x0.8 or x1.25
        assert!((lr - 3e-4 * 0.8).abs() < 1e-9 || (lr - 3e-4 * 1.25).abs() < 1e-9,
                "lr={lr}");
    }

    /// PBT exploit/explore over DQN hyperparameters: truncation must
    /// replace a weak agent's per-agent `eps_greedy`/`lr` state fields
    /// (exploit copies the q-net, explore re-samples the hypers) and
    /// flag its episode-return window for clearing.
    #[test]
    fn dqn_truncation_replaces_eps_and_lr_and_resets_returns() {
        let pop = 4;
        let mut fields = Vec::new();
        let mut off = 0;
        let push = |name: &str, shape: Vec<usize>, group: &str, init: &str,
                        fields: &mut Vec<Field>, off: &mut usize| {
            let size: usize = shape.iter().product();
            fields.push(Field {
                name: name.into(),
                offset: *off,
                size,
                shape,
                dtype: Dtype::F32,
                init: init.into(),
                group: group.into(),
                per_agent: true,
            });
            *off += size;
        };
        push("q/w0", vec![pop, 2, 3], "critic", "lecun_uniform:2", &mut fields, &mut off);
        push("lr", vec![pop], "hyper", "const:0.0003", &mut fields, &mut off);
        push("gamma", vec![pop], "hyper", "const:0.99", &mut fields, &mut off);
        push("eps_greedy", vec![pop], "hyper", "const:0.1", &mut fields, &mut off);
        let art = Artifact::new(
            "toy_dqn".into(),
            PathBuf::new(),
            "dqn".into(),
            "minatar".into(),
            EnvDesc { frame: Some((4, 4, 2)), n_actions: 3, ..Default::default() },
            pop,
            1,
            4,
            vec![],
            off,
            "state".into(),
            vec![],
            fields,
            vec![],
        );
        let mut seed_rng = Rng::new(3);
        let mut host = art.init_state(&mut seed_rng, 0);
        // distinct q rows: agent i filled with i; hypers parked OUTSIDE
        // the dqn prior support so replacement is unambiguous
        for agent in 0..pop {
            let f = art.field("q/w0").unwrap();
            let stride = f.agent_stride();
            for v in &mut host[f.offset + agent * stride..f.offset + (agent + 1) * stride] {
                *v = agent as f32;
            }
        }
        art.read_mut(&mut host, "eps_greedy").unwrap().fill(0.5); // > prior max 0.2
        art.read_mut(&mut host, "lr").unwrap().fill(0.5); // > prior max 3e-3

        let fitness = vec![1.0, 9.0, 5.0, -2.0]; // best = 1, worst = 3
        let mut rng = Rng::new(0);
        let mut ctrl = PbtController::new(HyperSpec::dqn(), 10, 0.26, Explore::Resample);
        let mut ctx = EvolveCtx {
            artifact: &art,
            host: &mut host,
            fitness: &fitness,
            rng: &mut rng,
            updates_done: 100,
            env_steps: 100,
            mutated: false,
            reset_returns: Vec::new(),
        };
        ctrl.on_sync(&mut ctx).unwrap();
        assert!(ctx.mutated);
        let resets = ctx.reset_returns.clone();
        drop(ctx);

        // exploit: the loser's q-net is now the winner's copy
        let w3 = art.read_agent(&host, "q/w0", 3).unwrap();
        assert!(w3.iter().all(|&v| v == 1.0), "clone mismatch: {w3:?}");
        // explore: the loser's eps_greedy/lr were re-sampled into the dqn
        // prior support; survivors keep their (out-of-prior) values
        let eps3 = art.read_agent(&host, "eps_greedy", 3).unwrap()[0] as f64;
        assert!((0.01..=0.2).contains(&eps3), "eps {eps3} not re-sampled");
        let lr3 = art.read_agent(&host, "lr", 3).unwrap()[0] as f64;
        assert!((3e-5..=3e-3).contains(&lr3), "lr {lr3} not re-sampled");
        for survivor in 0..3 {
            let eps = art.read_agent(&host, "eps_greedy", survivor).unwrap()[0];
            assert_eq!(eps, 0.5, "survivor {survivor} eps must be untouched");
        }
        // the trainer clears flagged windows at the sync point — emulate
        // that contract on a ReturnWindow
        assert_eq!(resets, vec![3]);
        let mut w = crate::coordinator::population::ReturnWindow::new(4);
        w.push(1.0);
        assert!(w.mean().is_some());
        for &agent in &resets {
            assert_eq!(agent, 3);
            w.clear();
        }
        assert!(w.mean().is_none(), "reset_returns must clear the window");
    }

    #[test]
    fn no_evolution_before_interval_or_without_fitness() {
        let art = toy_artifact(4);
        let mut host = vec![0.0f32; art.state_size];
        let mut rng = Rng::new(0);
        let mut ctrl = PbtController::new(HyperSpec::td3(), 1000, 0.26, Explore::Resample);
        let fitness = vec![1.0, 2.0, 3.0, 4.0];
        let mut ctx = EvolveCtx {
            artifact: &art,
            host: &mut host,
            fitness: &fitness,
            rng: &mut rng,
            updates_done: 100, // < interval
            env_steps: 0,
            mutated: false,
            reset_returns: Vec::new(),
        };
        ctrl.on_sync(&mut ctx).unwrap();
        assert!(!ctx.mutated);
        drop(ctx);
        // infinite fitness (no finished episodes) also blocks
        let fitness = vec![1.0, f64::NEG_INFINITY, 3.0, 4.0];
        let mut ctx = EvolveCtx {
            artifact: &art,
            host: &mut host,
            fitness: &fitness,
            rng: &mut rng,
            updates_done: 5000,
            env_steps: 0,
            mutated: false,
            reset_returns: Vec::new(),
        };
        ctrl.on_sync(&mut ctx).unwrap();
        assert!(!ctx.mutated);
    }
}
