//! The generic population training loop: actors feed replay buffers, the
//! learner drives the vectorized update-step artifact on device-resident
//! state, parameters are published to the actors every `sync_every`
//! updates (the paper's "50 update steps in a row without copying to host"
//! trick), and a pluggable [`Controller`] evolves the population at sync
//! points (PBT truncation, CEM distribution updates, DvD schedules).

use std::time::Instant;

use crate::coordinator::population::Population;
use crate::data::pipeline::{ActorConfig, ActorPool, PolicyKind, Throttle};
use crate::manifest::{Artifact, Dtype, Manifest};
use crate::replay::{RatioGate, ReplayBuffer};
use crate::runtime::Runtime;
use crate::util::log::CsvLogger;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::timer::PhaseTimer;

/// Groups copied wholesale when one agent replaces another.
pub const AGENT_STATE_GROUPS: &[&str] = &[
    "policy", "policy_target", "critic", "critic_target", "opt", "alpha", "step",
];

pub struct TrainerConfig {
    pub env: String,
    pub algo: String,
    /// Population size (must match an available artifact).
    pub pop: usize,
    /// Prefer the artifact with this many chained steps per execution.
    pub num_steps: Option<usize>,
    pub total_updates: u64,
    /// Publish parameters to actors every this many update *executions*.
    pub sync_every: u64,
    pub warmup_steps: usize,
    pub replay_capacity: usize,
    /// Update:env-step ratio target (1.0 = SOTA default).
    pub ratio: f64,
    pub ratio_slack: f64,
    /// One shared replay buffer (CEM-RL/DvD) instead of one per agent.
    pub shared_replay: bool,
    pub n_actor_threads: usize,
    /// Max transitions drained from the actor queue per learner loop
    /// iteration (bounds drain latency in front of the update step).
    pub drain_bound: u64,
    /// Actor backoff sleep while ratio-throttled, in microseconds.
    pub actor_sleep_us: u64,
    pub seed: u64,
    /// CSV output path ("" = no logging).
    pub csv_path: String,
    /// Stop after this many wall-clock seconds (0 = no limit).
    pub max_seconds: f64,
    pub return_window: usize,
    pub hyper_spec: Option<crate::coordinator::hyperparams::HyperSpec>,
    /// Write an integrity-checked checkpoint here at every sync point
    /// ("" = off); restored automatically at startup when present.
    pub checkpoint_path: String,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            env: "pendulum".into(),
            algo: "td3".into(),
            pop: 4,
            num_steps: None,
            total_updates: 2_000,
            sync_every: 50,
            warmup_steps: 500,
            replay_capacity: 100_000,
            ratio: 1.0,
            ratio_slack: 64.0,
            shared_replay: false,
            n_actor_threads: 1,
            drain_bound: 16 * 1024,
            actor_sleep_us: 200,
            seed: 0,
            csv_path: String::new(),
            max_seconds: 0.0,
            return_window: 10,
            hyper_spec: None,
            checkpoint_path: String::new(),
        }
    }
}

/// Everything a controller may inspect/mutate at a sync point.
pub struct EvolveCtx<'a> {
    pub artifact: &'a Artifact,
    pub host: &'a mut Vec<f32>,
    pub fitness: &'a [f64],
    pub rng: &'a mut Rng,
    pub updates_done: u64,
    pub env_steps: u64,
    /// Set true when `host` was mutated (trainer re-uploads it).
    pub mutated: bool,
    /// Episode-return windows to clear for replaced agents.
    pub reset_returns: Vec<usize>,
}

pub trait Controller {
    fn on_sync(&mut self, ctx: &mut EvolveCtx<'_>) -> anyhow::Result<()>;
    fn name(&self) -> &'static str {
        "none"
    }
}

/// No-op controller (plain population training).
pub struct NoController;

impl Controller for NoController {
    fn on_sync(&mut self, _ctx: &mut EvolveCtx<'_>) -> anyhow::Result<()> {
        Ok(())
    }
}

pub struct Summary {
    pub wall_seconds: f64,
    pub updates: u64,
    pub env_steps: u64,
    pub best_return: f64,
    pub mean_return: f64,
    pub timers: PhaseTimer,
}

pub struct Trainer {
    pub cfg: TrainerConfig,
    pub rt: Runtime,
    pub population: Population,
    exe: std::sync::Arc<crate::runtime::Executable>,
    replays: Vec<ReplayBuffer>,
    gate: RatioGate,
    rng: Rng,
    // reusable host staging buffers, one per batch input
    staging_f32: Vec<Vec<f32>>,
    staging_i32: Vec<Vec<i32>>,
}

impl Trainer {
    pub fn new(manifest: &Manifest, cfg: TrainerConfig) -> anyhow::Result<Trainer> {
        let artifact = manifest
            .find(&cfg.algo, &cfg.env, cfg.pop, cfg.num_steps)
            .or_else(|_| manifest.find(&cfg.algo, &cfg.env, cfg.pop, None))?
            .clone();
        anyhow::ensure!(
            artifact.env_desc.obs_dim > 0,
            "Trainer drives continuous-control artifacts; pixel/DQN \
             artifacts run on the block pipeline's pixel path \
             (data::pipeline::PixelActorPool + PixelReplayBuffer — see \
             examples/dqn_minatar.rs for the learner loop)"
        );
        let rt = Runtime::cpu()?;
        let exe = rt.load(&artifact)?;
        let mut rng = Rng::new(cfg.seed);
        let population = Population::init(
            &rt,
            &artifact,
            &mut rng,
            cfg.seed ^ 0xF00D,
            cfg.hyper_spec.clone(),
            cfg.return_window,
        )?;
        let (od, ad) = (artifact.env_desc.obs_dim, artifact.env_desc.act_dim);
        let n_buffers = if cfg.shared_replay { 1 } else { artifact.pop };
        let replays = (0..n_buffers)
            .map(|_| ReplayBuffer::new(cfg.replay_capacity, od, ad))
            .collect();
        let staging_f32 = artifact.inputs[1..]
            .iter()
            .map(|i| {
                if i.dtype == Dtype::F32 { vec![0.0f32; i.numel()] } else { Vec::new() }
            })
            .collect();
        let staging_i32 = artifact.inputs[1..]
            .iter()
            .map(|i| {
                if i.dtype == Dtype::I32 { vec![0i32; i.numel()] } else { Vec::new() }
            })
            .collect();
        // The gate counts *global* env steps but *per-agent* update steps
        // (one vectorized execution = 1 update for each of the P agents),
        // so the per-agent target ratio divides by the population size.
        let gate = RatioGate::new(
            cfg.ratio / artifact.pop.max(1) as f64,
            cfg.ratio_slack,
            (cfg.warmup_steps * artifact.pop) as u64,
        );
        let mut trainer =
            Trainer { cfg, rt, population, exe, replays, gate, rng, staging_f32, staging_i32 };
        // resume from checkpoint when one exists for this artifact
        let ckpt = trainer.cfg.checkpoint_path.clone();
        if !ckpt.is_empty() && std::path::Path::new(&ckpt).exists() {
            let c = crate::runtime::checkpoint::Checkpoint::load(&ckpt)?;
            trainer.population.train_state =
                c.restore(&trainer.rt, &trainer.population.artifact)?;
            trainer.population.view.publish(c.state);
            crate::util::log::info(&format!(
                "resumed from {ckpt} at {} updates", c.updates_done
            ));
        }
        Ok(trainer)
    }

    pub fn artifact(&self) -> &Artifact {
        &self.population.artifact
    }

    fn buffer_for(&self, agent: usize) -> usize {
        if self.cfg.shared_replay {
            0
        } else {
            agent
        }
    }

    /// Insert a transition block into replay: rows are grouped into runs
    /// that target the same buffer (one run per agent, or the whole block
    /// when replay is shared) and each run lands as one `push_batch`.
    fn push_block(&mut self, block: &crate::data::pipeline::TransitionBlock) {
        let (od, ad) = (block.obs_dim, block.act_dim);
        let mut start = 0;
        while start < block.n {
            let b = self.buffer_for(block.agents[start]);
            let mut end = start + 1;
            while end < block.n && self.buffer_for(block.agents[end]) == b {
                end += 1;
            }
            self.replays[b].push_batch(
                end - start,
                &block.obs[start * od..end * od],
                &block.act[start * ad..end * ad],
                &block.rew[start..end],
                &block.next_obs[start * od..end * od],
                &block.done[start..end],
            );
            start = end;
        }
    }

    /// Fill all staging buffers from replay: for every chained step (the
    /// leading `k` axis when num_steps > 1) and every agent, draw a batch.
    fn fill_batches(&mut self) {
        let art = &self.population.artifact;
        let (pop, batch) = (art.pop, art.batch);
        let (od, ad) = (art.env_desc.obs_dim, art.env_desc.act_dim);
        let k = art.num_steps;
        // input order fixed by transition_batch_args: obs, act, rew,
        // next_obs, done — each [k?, P, B, ...]
        for step in 0..k {
            for agent in 0..pop {
                let buf = &self.replays[if self.cfg.shared_replay { 0 } else { agent }];
                let base = step * pop + agent;
                let (s0, rest) = self.staging_f32.split_at_mut(1);
                let (s1, rest) = rest.split_at_mut(1);
                let (s2, rest) = rest.split_at_mut(1);
                let (s3, s4) = rest.split_at_mut(1);
                buf.sample_into(
                    &mut self.rng,
                    batch,
                    &mut s0[0][base * batch * od..(base + 1) * batch * od],
                    &mut s1[0][base * batch * ad..(base + 1) * batch * ad],
                    &mut s2[0][base * batch..(base + 1) * batch],
                    &mut s3[0][base * batch * od..(base + 1) * batch * od],
                    &mut s4[0][base * batch..(base + 1) * batch],
                );
            }
        }
    }

    fn upload_and_step(&mut self, timers: &mut PhaseTimer) -> anyhow::Result<()> {
        let art = self.population.artifact.clone();
        let t0 = Instant::now();
        let mut bufs = Vec::with_capacity(art.inputs.len() - 1);
        for (i, inp) in art.inputs[1..].iter().enumerate() {
            let b = match inp.dtype {
                Dtype::I32 => self.rt.upload_i32(&self.staging_i32[i], &inp.shape)?,
                _ => self.rt.upload_f32(&self.staging_f32[i], &inp.shape)?,
            };
            bufs.push(b);
        }
        timers.add("upload", t0.elapsed().as_secs_f64());
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let t1 = Instant::now();
        self.population.train_state.step(&self.exe, &refs)?;
        timers.add("update_exec", t1.elapsed().as_secs_f64());
        Ok(())
    }

    /// Run the full loop with the given controller.
    pub fn run(&mut self, controller: &mut dyn Controller) -> anyhow::Result<Summary> {
        let art = self.population.artifact.clone();
        let k = art.num_steps as u64;
        let mut timers = PhaseTimer::new();
        let mut csv = if self.cfg.csv_path.is_empty() {
            None
        } else {
            Some(CsvLogger::create(
                &self.cfg.csv_path,
                &[
                    "wall_s", "updates", "env_steps", "best_return", "mean_return",
                    "episodes", "critic_loss", "policy_loss",
                ],
            )?)
        };

        let throttle = Throttle::new();
        let pool = ActorPool::spawn(
            &art,
            self.population.view.clone(),
            ActorConfig {
                env: self.cfg.env.clone(),
                policy: PolicyKind::for_algo(&self.cfg.algo),
                warmup_steps: self.cfg.warmup_steps,
                expl_noise: 0.1,
                // in blocks now: one message carries one transition per
                // agent of the sending thread
                queue_cap: 1024,
                seed: self.cfg.seed ^ 0xAC70,
                ratio: self.cfg.ratio / art.pop.max(1) as f64,
                lead_steps: 4 * art.batch as u64 * art.pop as u64,
                throttle_sleep_us: self.cfg.actor_sleep_us,
            },
            self.cfg.n_actor_threads,
            throttle.clone(),
        )?;

        let start = Instant::now();
        let mut updates: u64 = 0;
        let mut episodes: u64 = 0;
        let mut since_sync: u64 = 0;
        let result = (|| -> anyhow::Result<()> {
            while updates < self.cfg.total_updates {
                if self.cfg.max_seconds > 0.0
                    && start.elapsed().as_secs_f64() > self.cfg.max_seconds
                {
                    break;
                }
                // ---- drain actor messages --------------------------------
                let t0 = Instant::now();
                let mut drained = 0u64;
                while let Ok(block) = pool.rx.try_recv() {
                    self.push_block(&block);
                    self.gate.on_env_steps(block.n as u64);
                    drained += block.n as u64;
                    for ep in &block.episodes {
                        self.population.returns[ep.agent].push(ep.ret);
                        episodes += 1;
                    }
                    pool.recycle(block);
                    if drained >= self.cfg.drain_bound {
                        break; // bounded drain per iteration
                    }
                }
                timers.add("drain", t0.elapsed().as_secs_f64());

                // ---- update step -----------------------------------------
                let min_fill = self.replays.iter().map(|r| r.len()).min().unwrap_or(0);
                if min_fill >= art.batch && self.gate.may_update(k) {
                    let t1 = Instant::now();
                    self.fill_batches();
                    timers.add("sample", t1.elapsed().as_secs_f64());
                    self.upload_and_step(&mut timers)?;
                    self.gate.on_update_steps(k);
                    throttle.updates.fetch_add(k, std::sync::atomic::Ordering::Relaxed);
                    updates += k;
                    since_sync += 1;
                } else {
                    std::thread::yield_now();
                }

                // ---- sync + evolve ---------------------------------------
                if since_sync >= self.cfg.sync_every.max(1)
                    || (since_sync > 0 && updates >= self.cfg.total_updates)
                {
                    since_sync = 0;
                    let t2 = Instant::now();
                    let mut host = self.population.sync_to_host()?;
                    timers.add("host_sync", t2.elapsed().as_secs_f64());
                    let fitness = self.population.fitness();
                    let mut ctx = EvolveCtx {
                        artifact: &art,
                        host: &mut host,
                        fitness: &fitness,
                        rng: &mut self.rng,
                        updates_done: updates,
                        env_steps: self.gate.env_steps(),
                        mutated: false,
                        reset_returns: Vec::new(),
                    };
                    controller.on_sync(&mut ctx)?;
                    let mutated = ctx.mutated;
                    let reset_returns = std::mem::take(&mut ctx.reset_returns);
                    drop(ctx);
                    for agent in reset_returns {
                        self.population.returns[agent].clear();
                    }
                    if mutated {
                        let t3 = Instant::now();
                        self.population.load_host(&self.rt, host)?;
                        timers.add("evolve_upload", t3.elapsed().as_secs_f64());
                    }
                    if !self.cfg.checkpoint_path.is_empty() {
                        let c = crate::runtime::checkpoint::Checkpoint::capture(
                            &self.population.train_state)?;
                        c.save(&self.cfg.checkpoint_path)?;
                    }
                    if let Some(csv) = csv.as_mut() {
                        let f = self.population.fitness();
                        let finite: Vec<f64> =
                            f.iter().copied().filter(|v| v.is_finite()).collect();
                        let best = finite.iter().copied().fold(f64::NEG_INFINITY, f64::max);
                        let metric_mean = |name: &str| -> f64 {
                            self.population
                                .view
                                .with(|h| {
                                    art.read(h, name).ok().map(|v| {
                                        v.iter().map(|&x| x as f64).sum::<f64>()
                                            / v.len().max(1) as f64
                                    })
                                })
                                .unwrap_or(f64::NAN)
                        };
                        csv.row(&[
                            start.elapsed().as_secs_f64(),
                            updates as f64,
                            self.gate.env_steps() as f64,
                            if best.is_finite() { best } else { f64::NAN },
                            stats::mean(&finite),
                            episodes as f64,
                            metric_mean("critic_loss"),
                            metric_mean("policy_loss"),
                        ])?;
                        csv.flush()?;
                    }
                }
            }
            Ok(())
        })();
        pool.stop();
        result?;

        let fitness = self.population.fitness();
        let finite: Vec<f64> = fitness.iter().copied().filter(|v| v.is_finite()).collect();
        Ok(Summary {
            wall_seconds: start.elapsed().as_secs_f64(),
            updates,
            env_steps: self.gate.env_steps(),
            best_return: finite.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            mean_return: stats::mean(&finite),
            timers,
        })
    }
}
