//! The generic population training loop: actors feed replay buffers, the
//! learner drives the vectorized update-step artifact on device-resident
//! state, parameters are published to the actors every `sync_every`
//! updates (the paper's "50 update steps in a row without copying to host"
//! trick), and a pluggable [`Controller`] evolves the population at sync
//! points (PBT truncation, CEM distribution updates, DvD schedules).
//!
//! Replay is layout-agnostic behind `Box<dyn Replay>`: per-agent
//! buffers, one shared buffer drained over the actor channel, or — with
//! `replay_shards > 1` — a [`ShardedReplay`] whose stripes the actors
//! fill directly through per-thread sinks while the learner samples
//! jointly across them (no drain round-trip on the ingest path).
//!
//! One loop serves every workload: [`Trainer`] is generic over a
//! [`Domain`] that bundles what used to be hardcoded per data path — the
//! transport block type, the replay buffer, actor-pool spawn, and the
//! staging-buffer fill layout. [`Continuous`] drives TD3/SAC/CEM-RL/DvD
//! on vector observations; [`Pixel`] drives DQN on frame observations.
//! Controllers, the [`RatioGate`] pairing, checkpoint save/restore and
//! CSV logging all live in the shared loop, so PBT over DQN
//! hyperparameters works exactly like PBT over TD3. [`run_training`]
//! dispatches to the right domain from artifact metadata alone (the CLI
//! entry point).

use std::marker::PhantomData;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::health;
use crate::coordinator::population::{ParamView, Population};
use crate::data::pipeline::{
    ActorConfig, ActorPool, BlockPool, PixelActorConfig, PixelActorPool, PolicyKind, RowSink,
    Throttle, TransitionBlock, TransportBlock,
};
use crate::data::supervisor::{RestartDecision, RestartPolicy, RestartTracker};
use crate::manifest::{Artifact, Dtype, Manifest};
use crate::replay::{PixelReplayBuffer, RatioGate, Replay, ReplayBuffer, ShardedReplay, Staging};
use crate::runtime::checkpoint::{Checkpoint, CheckpointLineage};
use crate::runtime::runstate::{RunState, RUN_STATE_SCHEMA};
use crate::runtime::{classify_fault, watchdog, FaultKind, Runtime, TrainState};
use crate::telemetry::{self, export::Exporter, PhaseRecorder, PhaseTimer, RunCounter,
                       TelemetryConfig};
use crate::util::log::{self, CsvLogger};
use crate::util::rng::Rng;
use crate::util::stats;

/// Groups copied wholesale when one agent replaces another.
pub const AGENT_STATE_GROUPS: &[&str] = &[
    "policy", "policy_target", "critic", "critic_target", "opt", "alpha", "step",
];

/// Full configuration of one training run — one struct for every domain
/// (the pixel keys `eps_greedy`/`expl_noise` simply go unused by domains
/// that do not read them). Construct with struct-update syntax or the
/// builder-style chainers ([`TrainerConfig::new`] + `with_*`).
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub env: String,
    pub algo: String,
    /// Population size (must match an available artifact).
    pub pop: usize,
    /// Prefer the artifact with this many chained steps per execution.
    pub num_steps: Option<usize>,
    pub total_updates: u64,
    /// Publish parameters to actors every this many update *executions*.
    pub sync_every: u64,
    pub warmup_steps: usize,
    pub replay_capacity: usize,
    /// Update:env-step ratio target (1.0 = SOTA default; 0 = unthrottled
    /// on both the actor and the learner side).
    pub ratio: f64,
    pub ratio_slack: f64,
    /// One shared replay buffer (CEM-RL/DvD) instead of one per agent.
    pub shared_replay: bool,
    /// Ingest stripes behind the shared buffer: actors push transport
    /// blocks straight into their own stripe (no learner drain
    /// round-trip) and the learner samples jointly across stripes.
    /// 1 = single buffer through the drain path (the historical layout);
    /// 0 = auto, one stripe per actor thread. Only meaningful with
    /// `shared_replay` — per-agent buffers already have a single writer.
    pub replay_shards: usize,
    pub n_actor_threads: usize,
    /// Max transitions drained from the actor queue per learner loop
    /// iteration (bounds drain latency in front of the update step).
    pub drain_bound: u64,
    /// Actor backoff sleep while ratio-throttled, in microseconds.
    pub actor_sleep_us: u64,
    /// TD3 exploration noise fallback (continuous domain; the per-agent
    /// state field `expl_noise` takes precedence when present).
    pub expl_noise: f32,
    /// Epsilon-greedy exploration fallback (pixel domain; baked into the
    /// per-agent `eps_greedy` state field when `hyper_spec` is `None`,
    /// otherwise the sampled per-agent values are authoritative).
    pub eps_greedy: f32,
    pub seed: u64,
    /// CSV output path ("" = no logging).
    pub csv_path: String,
    /// Stop after this many wall-clock seconds (0 = no limit).
    pub max_seconds: f64,
    pub return_window: usize,
    pub hyper_spec: Option<crate::coordinator::hyperparams::HyperSpec>,
    /// Write an integrity-checked checkpoint here at every sync point
    /// ("" = off); restored automatically at startup when present.
    pub checkpoint_path: String,
    /// Rotated checkpoint generations kept next to `checkpoint_path`
    /// (plus the `last_good` target, which is never pruned).
    pub keep_checkpoints: usize,
    /// Respawn budget per crashed actor thread (0 = never respawn).
    pub max_actor_restarts: u32,
    /// First-respawn backoff in milliseconds; doubles per restart,
    /// capped at 5s.
    pub restart_backoff_ms: u64,
    /// Flag an actor thread as stalled after this many milliseconds
    /// without a heartbeat (0 = watchdog off).
    pub stall_timeout_ms: u64,
    /// Per-member health scan: |param| above this is a norm explosion
    /// (0 = magnitude check off; NaN/Inf are always faults).
    pub health_norm_limit: f64,
    /// Transient PJRT dispatch failures (`FaultKind::Retryable`) retried
    /// per call site before the error propagates (0 = no retries).
    pub runtime_retries: u32,
    /// Backoff before the first runtime retry, in milliseconds; doubles
    /// per attempt within a call site.
    pub runtime_retry_backoff_ms: u64,
    /// Device-loss recoveries (`FaultKind::DeviceLost` → rebuild the
    /// runtime, re-load executables, re-upload the host mirror) allowed
    /// per run before the fault propagates (0 = never recover).
    pub max_device_restarts: u32,
    /// Live-metrics switches: registry on/off, JSONL snapshot stream,
    /// Prometheus dump (see [`crate::telemetry`]). Off by default.
    pub telemetry: TelemetryConfig,
    /// Deterministic fault injection for resilience tests (see
    /// [`FaultPlan`](crate::data::supervisor::FaultPlan)).
    #[cfg(feature = "fault-inject")]
    pub fault_plan: Option<std::sync::Arc<crate::data::supervisor::FaultPlan>>,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            env: "pendulum".into(),
            algo: "td3".into(),
            pop: 4,
            num_steps: None,
            total_updates: 2_000,
            sync_every: 50,
            warmup_steps: 500,
            replay_capacity: 100_000,
            ratio: 1.0,
            ratio_slack: 64.0,
            shared_replay: false,
            replay_shards: 1,
            n_actor_threads: 1,
            drain_bound: 16 * 1024,
            actor_sleep_us: 200,
            expl_noise: 0.1,
            eps_greedy: 0.1,
            seed: 0,
            csv_path: String::new(),
            max_seconds: 0.0,
            return_window: 10,
            hyper_spec: None,
            checkpoint_path: String::new(),
            keep_checkpoints: 3,
            max_actor_restarts: 3,
            restart_backoff_ms: 100,
            stall_timeout_ms: 5_000,
            health_norm_limit: 1e6,
            runtime_retries: 3,
            runtime_retry_backoff_ms: 100,
            max_device_restarts: 2,
            telemetry: TelemetryConfig::off(),
            #[cfg(feature = "fault-inject")]
            fault_plan: None,
        }
    }
}

impl TrainerConfig {
    /// Start a builder chain for the given algo/env pairing; every other
    /// key starts at its [`Default`] value.
    pub fn new(algo: &str, env: &str) -> TrainerConfig {
        TrainerConfig { algo: algo.into(), env: env.into(), ..Default::default() }
    }

    pub fn with_pop(mut self, pop: usize) -> Self {
        self.pop = pop;
        self
    }

    pub fn with_updates(mut self, total_updates: u64) -> Self {
        self.total_updates = total_updates;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_ratio(mut self, ratio: f64) -> Self {
        self.ratio = ratio;
        self
    }

    pub fn with_warmup(mut self, warmup_steps: usize) -> Self {
        self.warmup_steps = warmup_steps;
        self
    }

    pub fn with_sync_every(mut self, sync_every: u64) -> Self {
        self.sync_every = sync_every;
        self
    }

    pub fn with_replay_capacity(mut self, replay_capacity: usize) -> Self {
        self.replay_capacity = replay_capacity;
        self
    }

    pub fn with_shared_replay(mut self, shared: bool) -> Self {
        self.shared_replay = shared;
        self
    }

    pub fn with_replay_shards(mut self, shards: usize) -> Self {
        self.replay_shards = shards;
        self
    }

    pub fn with_eps_greedy(mut self, eps: f32) -> Self {
        self.eps_greedy = eps;
        self
    }

    pub fn with_expl_noise(mut self, noise: f32) -> Self {
        self.expl_noise = noise;
        self
    }

    pub fn with_csv(mut self, path: impl Into<String>) -> Self {
        self.csv_path = path.into();
        self
    }

    pub fn with_checkpoint(mut self, path: impl Into<String>) -> Self {
        self.checkpoint_path = path.into();
        self
    }

    pub fn with_max_seconds(mut self, seconds: f64) -> Self {
        self.max_seconds = seconds;
        self
    }

    pub fn with_hypers(mut self, spec: crate::coordinator::hyperparams::HyperSpec) -> Self {
        self.hyper_spec = Some(spec);
        self
    }

    pub fn with_actor_threads(mut self, n: usize) -> Self {
        self.n_actor_threads = n;
        self
    }

    pub fn with_keep_checkpoints(mut self, n: usize) -> Self {
        self.keep_checkpoints = n;
        self
    }

    pub fn with_max_actor_restarts(mut self, n: u32) -> Self {
        self.max_actor_restarts = n;
        self
    }

    pub fn with_restart_backoff_ms(mut self, ms: u64) -> Self {
        self.restart_backoff_ms = ms;
        self
    }

    pub fn with_stall_timeout_ms(mut self, ms: u64) -> Self {
        self.stall_timeout_ms = ms;
        self
    }

    pub fn with_health_norm_limit(mut self, limit: f64) -> Self {
        self.health_norm_limit = limit;
        self
    }

    pub fn with_runtime_retries(mut self, n: u32) -> Self {
        self.runtime_retries = n;
        self
    }

    pub fn with_runtime_retry_backoff_ms(mut self, ms: u64) -> Self {
        self.runtime_retry_backoff_ms = ms;
        self
    }

    pub fn with_max_device_restarts(mut self, n: u32) -> Self {
        self.max_device_restarts = n;
        self
    }

    /// Stable fingerprint of the run-defining fields — what `run.json`
    /// records so a watchdog restart (or an operator pointing a new
    /// launch at an old run dir) can tell "same run" from "different
    /// run wearing the same checkpoint path". Paths and output knobs
    /// (CSV, telemetry) are deliberately excluded: moving the logs does
    /// not change what is being trained.
    pub fn config_digest(&self) -> String {
        let canon = format!(
            "algo={} env={} pop={} num_steps={:?} total_updates={} sync_every={} \
             warmup={} replay_capacity={} ratio={} ratio_slack={} shared_replay={} \
             replay_shards={} actor_threads={} seed={} hypers={}",
            self.algo,
            self.env,
            self.pop,
            self.num_steps,
            self.total_updates,
            self.sync_every,
            self.warmup_steps,
            self.replay_capacity,
            self.ratio,
            self.ratio_slack,
            self.shared_replay,
            self.replay_shards,
            self.n_actor_threads,
            self.seed,
            self.hyper_spec.is_some(),
        );
        crate::runtime::runstate::fnv1a_hex(canon.as_bytes())
    }

    pub fn with_telemetry(mut self, telemetry: TelemetryConfig) -> Self {
        self.telemetry = telemetry;
        self
    }

    #[cfg(feature = "fault-inject")]
    pub fn with_fault_plan(
        mut self,
        plan: std::sync::Arc<crate::data::supervisor::FaultPlan>,
    ) -> Self {
        self.fault_plan = Some(plan);
        self
    }
}

/// Everything the shared learner loop needs that differs between the
/// continuous-control and the pixel/DQN data paths. A domain bundles the
/// transport block type its actors emit, the replay buffer those blocks
/// land in, how the actor pool is spawned from a [`TrainerConfig`], and
/// which state fields the CSV logger reports — so [`Trainer`] contains
/// no per-path branches at all.
pub trait Domain: Send + Sized + 'static {
    /// Transport block the domain's actor pool emits.
    type Block: TransportBlock;
    /// Replay buffer implementation fed by those blocks (`'static` so the
    /// trainer can hold it boxed — plain, or wrapped in a
    /// [`ShardedReplay`] when ingest striping is on).
    type Replay: Replay<Block = Self::Block> + 'static;

    /// Domain name for logs and error messages.
    const NAME: &'static str;

    /// Can this domain drive `artifact`? Continuous artifacts carry env
    /// vector dims, pixel artifacts a frame shape; a mismatch must error
    /// here with a pointer to the right domain.
    fn check(artifact: &Artifact) -> anyhow::Result<()>;

    /// Construct one replay shard (per agent, or one shared).
    fn make_replay(artifact: &Artifact, capacity: usize) -> Self::Replay;

    /// Domain-specific host-state preparation before the first upload
    /// (e.g. baking the configured epsilon into the per-agent
    /// `eps_greedy` field when hyperparameter sampling is off). Returns
    /// true when `host` was mutated.
    fn prepare_host(artifact: &Artifact, cfg: &TrainerConfig, host: &mut [f32]) -> bool {
        let _ = (artifact, cfg, host);
        false
    }

    /// Spawn the domain's actor pool against the shared parameter view.
    /// A non-empty `sinks` vector switches the pool to direct-ingest
    /// mode: thread `t` pushes rows into `sinks[t % sinks.len()]`
    /// instead of the learner drain channel.
    fn spawn_actors(
        artifact: &Artifact,
        view: ParamView,
        cfg: &TrainerConfig,
        throttle: Throttle,
        sinks: Vec<Arc<dyn RowSink<Self::Block>>>,
    ) -> anyhow::Result<BlockPool<Self::Block>>;

    /// `(CSV column, state field)` pairs whose per-population means are
    /// logged at every sync point.
    fn metrics() -> &'static [(&'static str, &'static str)];
}

/// The continuous-control domain: TD3/SAC policies on vector
/// observations ([`TransitionBlock`] transport into [`ReplayBuffer`]s).
pub struct Continuous;

impl Domain for Continuous {
    type Block = TransitionBlock;
    type Replay = ReplayBuffer;

    const NAME: &'static str = "continuous";

    fn check(artifact: &Artifact) -> anyhow::Result<()> {
        anyhow::ensure!(
            artifact.env_desc.obs_dim > 0 && artifact.env_desc.act_dim > 0,
            "artifact {} carries no continuous env dims (obs_dim/act_dim); \
             pixel/DQN artifacts train through Trainer::<Pixel> (or let \
             run_training dispatch from the artifact metadata)",
            artifact.name
        );
        Ok(())
    }

    fn make_replay(artifact: &Artifact, capacity: usize) -> ReplayBuffer {
        ReplayBuffer::new(capacity, artifact.env_desc.obs_dim, artifact.env_desc.act_dim)
    }

    fn spawn_actors(
        artifact: &Artifact,
        view: ParamView,
        cfg: &TrainerConfig,
        throttle: Throttle,
        sinks: Vec<Arc<dyn RowSink<TransitionBlock>>>,
    ) -> anyhow::Result<ActorPool> {
        ActorPool::spawn_with_sinks(
            artifact,
            view,
            ActorConfig {
                env: cfg.env.clone(),
                policy: PolicyKind::for_algo(&cfg.algo),
                warmup_steps: cfg.warmup_steps,
                expl_noise: cfg.expl_noise,
                // in blocks: one message carries one transition per agent
                // of the sending thread
                queue_cap: 1024,
                seed: cfg.seed ^ 0xAC70,
                ratio: cfg.ratio / artifact.pop.max(1) as f64,
                lead_steps: 4 * artifact.batch as u64 * artifact.pop as u64,
                throttle_sleep_us: cfg.actor_sleep_us,
                #[cfg(feature = "fault-inject")]
                fault_plan: cfg.fault_plan.clone(),
            },
            cfg.n_actor_threads,
            throttle,
            sinks,
        )
    }

    fn metrics() -> &'static [(&'static str, &'static str)] {
        &[("critic_loss", "critic_loss"), ("policy_loss", "policy_loss")]
    }
}

/// The pixel/DQN domain: epsilon-greedy q-policies on frame observations
/// ([`PixelTransitionBlock`](crate::data::pipeline::PixelTransitionBlock)
/// transport into [`PixelReplayBuffer`]s).
pub struct Pixel;

impl Domain for Pixel {
    type Block = crate::data::pipeline::PixelTransitionBlock;
    type Replay = PixelReplayBuffer;

    const NAME: &'static str = "pixel";

    fn check(artifact: &Artifact) -> anyhow::Result<()> {
        anyhow::ensure!(
            artifact.env_desc.frame.is_some(),
            "artifact {} carries no frame shape; continuous-control \
             artifacts train through Trainer::<Continuous> (or let \
             run_training dispatch from the artifact metadata)",
            artifact.name
        );
        Ok(())
    }

    fn make_replay(artifact: &Artifact, capacity: usize) -> PixelReplayBuffer {
        let (h, w, c) = artifact.env_desc.frame.expect("checked by Pixel::check");
        PixelReplayBuffer::new(capacity, h * w * c)
    }

    fn prepare_host(artifact: &Artifact, cfg: &TrainerConfig, host: &mut [f32]) -> bool {
        if cfg.hyper_spec.is_some() {
            // sampled per-agent epsilons are authoritative
            return false;
        }
        // the artifact bakes eps_greedy to a constant; make the
        // configured epsilon authoritative when priors are not sampled
        match artifact.read_mut(host, "eps_greedy") {
            Ok(eps) => {
                eps.fill(cfg.eps_greedy);
                true
            }
            Err(_) => false,
        }
    }

    fn spawn_actors(
        artifact: &Artifact,
        view: ParamView,
        cfg: &TrainerConfig,
        throttle: Throttle,
        sinks: Vec<Arc<dyn RowSink<crate::data::pipeline::PixelTransitionBlock>>>,
    ) -> anyhow::Result<PixelActorPool> {
        PixelActorPool::spawn_with_sinks(
            artifact,
            view,
            PixelActorConfig {
                env: cfg.env.clone(),
                warmup_steps: cfg.warmup_steps,
                eps_greedy: cfg.eps_greedy,
                queue_cap: 1024,
                seed: cfg.seed ^ 0xAC70,
                ratio: cfg.ratio / artifact.pop.max(1) as f64,
                lead_steps: 4 * artifact.batch as u64 * artifact.pop as u64,
                throttle_sleep_us: cfg.actor_sleep_us,
                #[cfg(feature = "fault-inject")]
                fault_plan: cfg.fault_plan.clone(),
            },
            cfg.n_actor_threads,
            throttle,
            sinks,
        )
    }

    fn metrics() -> &'static [(&'static str, &'static str)] {
        &[("loss", "loss")]
    }
}

/// Everything a controller may inspect/mutate at a sync point.
pub struct EvolveCtx<'a> {
    pub artifact: &'a Artifact,
    pub host: &'a mut Vec<f32>,
    pub fitness: &'a [f64],
    pub rng: &'a mut Rng,
    pub updates_done: u64,
    pub env_steps: u64,
    /// Set true when `host` was mutated (trainer re-uploads it).
    pub mutated: bool,
    /// Episode-return windows to clear for replaced agents.
    pub reset_returns: Vec<usize>,
}

pub trait Controller {
    fn on_sync(&mut self, ctx: &mut EvolveCtx<'_>) -> anyhow::Result<()>;
    fn name(&self) -> &'static str {
        "none"
    }
}

/// No-op controller (plain population training).
pub struct NoController;

impl Controller for NoController {
    fn on_sync(&mut self, _ctx: &mut EvolveCtx<'_>) -> anyhow::Result<()> {
        Ok(())
    }
}

#[derive(Clone, Debug)]
pub struct Summary {
    pub wall_seconds: f64,
    pub updates: u64,
    pub env_steps: u64,
    pub best_return: f64,
    pub mean_return: f64,
    /// Crashed actor threads respawned by the supervisor.
    pub actor_restarts: u64,
    /// Stall events flagged by the heartbeat watchdog (a thread can
    /// recover and re-stall; each transition counts once).
    pub stalled_actors: u64,
    /// Quarantined members repaired in place from a healthy donor.
    pub members_repaired: u64,
    /// Transient PJRT dispatch failures absorbed by bounded retry.
    pub runtime_retries: u64,
    /// Device-loss recoveries performed in place (runtime rebuilt,
    /// executable re-loaded, population re-uploaded from the host
    /// mirror).
    pub device_restarts: u64,
    /// Ingest stripes behind the shared replay buffer (1 = unsharded
    /// or per-agent buffers).
    pub replay_shards: usize,
    /// Smallest live length across replay stripes (per-agent buffers
    /// count as one stripe each) when the run ended.
    pub stripe_min_fill: usize,
    /// Largest live length across replay stripes when the run ended.
    pub stripe_max_fill: usize,
    pub timers: PhaseTimer,
}

/// Run-local runtime-fault counters, mirrored into the registry through
/// one bump site each (the same pattern as the supervision counters) so
/// Summary and the exported `runtime.*` metrics cannot drift apart.
struct RecoveryCounters {
    retries: RunCounter,
    device_restarts: RunCounter,
}

/// The population trainer, generic over its [`Domain`] — one learner
/// loop for every algo/env pairing: `Trainer::<Continuous>` for TD3/SAC
/// control tasks, `Trainer::<Pixel>` for DQN on frames, with
/// controllers, ratio pairing, checkpointing and CSV logging shared.
pub struct Trainer<D: Domain> {
    pub cfg: TrainerConfig,
    pub rt: Runtime,
    pub population: Population,
    exe: std::sync::Arc<crate::runtime::Executable>,
    /// Per-agent buffers, one shared buffer, or one [`ShardedReplay`] —
    /// boxed so the learner loop is identical for all three layouts.
    replays: Vec<Box<dyn Replay<Block = D::Block>>>,
    /// Direct-ingest endpoints handed to the actor pool; empty unless
    /// replay is sharded (then the drain channel carries no rows).
    actor_sinks: Vec<Arc<dyn RowSink<D::Block>>>,
    gate: RatioGate,
    rng: Rng,
    /// Reusable host staging buffers, one slot per (step, agent).
    staging: Staging,
    /// Rotated checkpoint history (None when checkpointing is off).
    lineage: Option<CheckpointLineage>,
    /// Run dir (the checkpoint base's parent) where `run.json` and the
    /// watchdog heartbeat live; `None` when checkpointing is off.
    run_dir: Option<std::path::PathBuf>,
    /// Did construction restore from the checkpoint lineage? Gates the
    /// fault-inject process abort to the run's first incarnation (and
    /// lets callers tell a resumed incarnation from a fresh start).
    pub resumed: bool,
    /// One fired-flag per planned device error, so each fires once.
    #[cfg(feature = "fault-inject")]
    device_faults_fired: Vec<bool>,
    _domain: PhantomData<D>,
}

/// The artifact lookup shared by [`Trainer::new`] and [`run_training`]:
/// prefer the configured `num_steps`, fall back to any step count for
/// the same algo/env/pop — one rule, so dispatch and construction can
/// never resolve different artifacts.
fn find_artifact<'a>(manifest: &'a Manifest, cfg: &TrainerConfig) -> anyhow::Result<&'a Artifact> {
    manifest
        .find(&cfg.algo, &cfg.env, cfg.pop, cfg.num_steps)
        .or_else(|_| manifest.find(&cfg.algo, &cfg.env, cfg.pop, None))
}

impl<D: Domain> Trainer<D> {
    pub fn new(manifest: &Manifest, cfg: TrainerConfig) -> anyhow::Result<Trainer<D>> {
        let artifact = find_artifact(manifest, &cfg)?.clone();
        D::check(&artifact)?;
        let rt = Runtime::cpu()?;
        let exe = rt.load(&artifact)?;
        let mut rng = Rng::new(cfg.seed);
        let mut population = Population::init(
            &rt,
            &artifact,
            &mut rng,
            cfg.seed ^ 0xF00D,
            cfg.hyper_spec.clone(),
            cfg.return_window,
        )?;
        // domain hook: e.g. Pixel bakes cfg.eps_greedy into the state
        // when hyperparameter sampling is off
        {
            let mut host = population.view.with(|h| h.to_vec());
            if D::prepare_host(&artifact, &cfg, &mut host) {
                population.load_host(&rt, host)?;
            }
        }
        // Replay layout: per-agent buffers, one shared buffer, or a
        // sharded shared buffer (replay_shards stripes, 0 = one per
        // actor thread). Sharding hands the actors direct-ingest sinks —
        // stripe `s` serves threads `t` with `t % shards == s`, the same
        // routing `ShardedReplay::push_rows` uses — so blocks never make
        // the learner drain round-trip.
        let shards = if cfg.shared_replay {
            if cfg.replay_shards == 0 {
                cfg.n_actor_threads.max(1)
            } else {
                cfg.replay_shards
            }
        } else {
            1
        };
        let mut actor_sinks: Vec<Arc<dyn RowSink<D::Block>>> = Vec::new();
        let replays: Vec<Box<dyn Replay<Block = D::Block>>> = if cfg.shared_replay && shards > 1 {
            // replay_capacity stays the total across stripes
            let stripe_cap = cfg.replay_capacity.div_ceil(shards).max(1);
            let sharded = ShardedReplay::new(
                (0..shards).map(|_| D::make_replay(&artifact, stripe_cap)).collect(),
            );
            actor_sinks = (0..shards)
                .map(|s| Arc::new(sharded.sink_for_thread(s)) as Arc<dyn RowSink<D::Block>>)
                .collect();
            vec![Box::new(sharded) as Box<dyn Replay<Block = D::Block>>]
        } else {
            let n_buffers = if cfg.shared_replay { 1 } else { artifact.pop };
            (0..n_buffers)
                .map(|_| {
                    Box::new(D::make_replay(&artifact, cfg.replay_capacity))
                        as Box<dyn Replay<Block = D::Block>>
                })
                .collect()
        };
        let staging = Staging::for_artifact(&artifact)?;
        // The gate counts *global* env steps but *per-agent* update steps
        // (one vectorized execution = 1 update for each of the P agents),
        // so the per-agent target ratio divides by the population size.
        // ratio <= 0 means unthrottled; the loop bypasses the gate then
        // (the gate itself requires a positive target).
        let gate = RatioGate::new(
            if cfg.ratio > 0.0 { cfg.ratio / artifact.pop.max(1) as f64 } else { 1.0 },
            cfg.ratio_slack,
            (cfg.warmup_steps * artifact.pop) as u64,
        );
        let lineage = if cfg.checkpoint_path.is_empty() {
            None
        } else {
            Some(CheckpointLineage::new(&cfg.checkpoint_path, cfg.keep_checkpoints))
        };
        let run_dir = if cfg.checkpoint_path.is_empty() {
            None
        } else {
            let p = std::path::Path::new(&cfg.checkpoint_path);
            Some(match p.parent() {
                Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
                _ => std::path::PathBuf::from("."),
            })
        };
        let mut trainer = Trainer {
            cfg,
            rt,
            population,
            exe,
            replays,
            actor_sinks,
            gate,
            rng,
            staging,
            lineage,
            run_dir,
            resumed: false,
            #[cfg(feature = "fault-inject")]
            device_faults_fired: Vec::new(),
            _domain: PhantomData,
        };
        #[cfg(feature = "fault-inject")]
        {
            trainer.device_faults_fired = trainer
                .cfg
                .fault_plan
                .as_ref()
                .map(|p| vec![false; p.device_errors.len()])
                .unwrap_or_default();
        }
        // Auto-resume: restore the newest checkpoint in the lineage that
        // loads (magic + hash), matches this artifact, AND passes a
        // member health scan — a checkpoint of a diverged population is
        // skipped in favor of an older healthy one (`last_good`). A
        // fully unrestorable lineage falls through to a fresh start
        // instead of erroring: the run must come up.
        let ckpt = trainer.cfg.checkpoint_path.clone();
        if !ckpt.is_empty() {
            let art = trainer.population.artifact.clone();
            let norm_limit = trainer.cfg.health_norm_limit as f32;
            let found = CheckpointLineage::resume(std::path::Path::new(&ckpt), |c| {
                c.artifact_name == art.name
                    && c.state.len() == art.state_size
                    && health::scan_members(&art, &c.state, norm_limit).all_healthy()
            });
            if let Some((path, c)) = found {
                trainer.population.train_state =
                    c.restore(&trainer.rt, &trainer.population.artifact)?;
                trainer.population.view.publish(c.state);
                trainer.resumed = true;
                log::info(&format!(
                    "resumed from {} at {} updates",
                    path.display(),
                    c.updates_done
                ));
            }
        }
        // Durable run state: record this run's identity (argv, lineage
        // base, seed, config digest) in the run dir so a watchdog restart
        // reconstructs the exact run instead of trusting its remembered
        // command line. A run dir already claimed by a *different*
        // config gets a warning before the record is replaced — the
        // operator may be about to resume someone else's lineage.
        if let Some(dir) = trainer.run_dir.clone() {
            let digest = trainer.cfg.config_digest();
            match RunState::load(&dir) {
                Ok(Some(prev)) if prev.config_digest != digest => log::warn(&format!(
                    "run.json in {} was written by a different config \
                     (digest {} vs {}); replacing the record — if this was \
                     unintentional, this run dir belongs to another run",
                    dir.display(),
                    prev.config_digest,
                    digest
                )),
                Ok(_) => {}
                Err(e) => log::warn(&format!(
                    "unreadable run.json in {} ({e:#}); rewriting it",
                    dir.display()
                )),
            }
            let rs = RunState {
                schema: RUN_STATE_SCHEMA,
                argv: std::env::args().collect(),
                checkpoint_base: trainer.cfg.checkpoint_path.clone(),
                seed: trainer.cfg.seed,
                config_digest: digest,
            };
            if let Err(e) = rs.save(&dir) {
                // best-effort like the CSV/telemetry writers: a read-only
                // run dir degrades durability, never aborts training
                log::warn(&format!("could not write run.json ({e:#}); continuing"));
            }
        }
        Ok(trainer)
    }

    pub fn artifact(&self) -> &Artifact {
        &self.population.artifact
    }

    /// Absorb one drained block: replay insert + ratio bookkeeping +
    /// episode-return windows. Returns how many episodes it carried
    /// (the caller recycles the block).
    fn absorb_block(&mut self, block: &D::Block) -> u64 {
        self.push_block(block);
        self.gate.on_env_steps(block.rows() as u64);
        let mut eps = 0;
        for ep in block.episodes() {
            self.population.returns[ep.agent].push(ep.ret);
            eps += 1;
        }
        eps
    }

    /// Insert a transport block into replay: rows are grouped into runs
    /// that target the same buffer (one run per agent, or the whole block
    /// when replay is shared) and each run lands as one contiguous
    /// insert.
    fn push_block(&mut self, block: &D::Block) {
        let shared = self.cfg.shared_replay;
        let agents = block.agents();
        let n = block.rows();
        let mut start = 0;
        while start < n {
            let b = if shared { 0 } else { agents[start] };
            let mut end = start + 1;
            while end < n && (shared || agents[end] == b) {
                end += 1;
            }
            self.replays[b].push_rows(block, start, end);
            start = end;
        }
    }

    /// Fill all staging buffers from replay: for every chained step (the
    /// leading `k` axis when num_steps > 1) and every agent, draw a
    /// batch into slot `step * pop + agent`.
    fn fill_batches(&mut self) {
        let (pop, batch, k) = {
            let a = &self.population.artifact;
            (a.pop, a.batch, a.num_steps)
        };
        let shared = self.cfg.shared_replay;
        let Trainer { replays, rng, staging, .. } = self;
        for step in 0..k {
            for agent in 0..pop {
                let buf = &replays[if shared { 0 } else { agent }];
                buf.sample_slot(rng, batch, staging, step * pop + agent);
            }
        }
    }

    fn upload_and_step(&mut self, timers: &mut PhaseRecorder) -> anyhow::Result<()> {
        let art = self.population.artifact.clone();
        let t0 = Instant::now();
        let mut bufs = Vec::with_capacity(art.inputs.len() - 1);
        for (i, inp) in art.inputs[1..].iter().enumerate() {
            let b = match inp.dtype {
                Dtype::I32 => self.rt.upload_i32(&self.staging.i32s[i], &inp.shape)?,
                _ => self.rt.upload_f32(&self.staging.f32s[i], &inp.shape)?,
            };
            bufs.push(b);
        }
        timers.add("upload", t0.elapsed().as_secs_f64());
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let t1 = Instant::now();
        self.population.train_state.step(&self.exe, &refs)?;
        timers.add("update_exec", t1.elapsed().as_secs_f64());
        Ok(())
    }

    /// Rebuild the PJRT layer in place after a device loss: fresh
    /// client, re-compiled executable, and train state re-uploaded from
    /// the host mirror the actors read. The mirror was last published at
    /// the previous sync, so updates executed since then are rolled back
    /// — bounded by `sync_every`, the same loss a process restart from
    /// the checkpoint lineage would take.
    fn recover_runtime(&mut self) -> anyhow::Result<()> {
        let art = self.population.artifact.clone();
        let rt = Runtime::cpu()?;
        let exe = rt.load(&art)?;
        let host = self.population.view.with(|h| h.to_vec());
        let updates_done = self.population.train_state.updates_done;
        let mut ts = TrainState::from_host(&rt, &art, &host)?;
        ts.updates_done = updates_done;
        self.population.train_state = ts;
        self.exe = exe;
        self.rt = rt;
        Ok(())
    }

    /// React to a failed runtime call according to its [`FaultKind`]:
    /// `Ok(())` means "handled, try the call again" (after a backoff
    /// sleep or an in-place device recovery); `Err` propagates faults
    /// that are fatal or out of budget.
    fn handle_runtime_fault(
        &mut self,
        what: &str,
        e: anyhow::Error,
        attempt: &mut u32,
        recovery: &mut RecoveryCounters,
    ) -> anyhow::Result<()> {
        match classify_fault(&format!("{e:#}")) {
            FaultKind::Retryable if *attempt < self.cfg.runtime_retries => {
                let backoff_ms = self
                    .cfg
                    .runtime_retry_backoff_ms
                    .max(1)
                    .saturating_mul(1u64 << (*attempt).min(16));
                *attempt += 1;
                recovery.retries.bump(1);
                log::warn(&format!(
                    "{what}: transient PJRT failure ({e:#}); retry {}/{} in {backoff_ms} ms",
                    attempt, self.cfg.runtime_retries
                ));
                std::thread::sleep(std::time::Duration::from_millis(backoff_ms));
                Ok(())
            }
            FaultKind::DeviceLost
                if recovery.device_restarts.get() < self.cfg.max_device_restarts as u64 =>
            {
                recovery.device_restarts.bump(1);
                log::warn(&format!(
                    "{what}: device lost ({e:#}); rebuilding the PJRT runtime and \
                     re-uploading the population from the host mirror \
                     (device restart {}/{}; updates since the last publish roll back)",
                    recovery.device_restarts.get(),
                    self.cfg.max_device_restarts
                ));
                self.recover_runtime().map_err(|re| {
                    anyhow::anyhow!("device-loss recovery failed: {re:#} (original fault: {e:#})")
                })?;
                // fresh retry budget against the rebuilt runtime
                *attempt = 0;
                Ok(())
            }
            _ => Err(e),
        }
    }

    /// Drive one update-step execution through the fault-classification
    /// wrapper (retry transient, rebuild on device loss, propagate the
    /// rest). `updates` is the loop's progress count, used only by the
    /// fault-inject device-error plan.
    fn step_with_recovery(
        &mut self,
        timers: &mut PhaseRecorder,
        recovery: &mut RecoveryCounters,
        updates: u64,
    ) -> anyhow::Result<()> {
        #[cfg(not(feature = "fault-inject"))]
        let _ = updates;
        let mut attempt = 0u32;
        loop {
            #[cfg(feature = "fault-inject")]
            if let Some(e) = self.take_injected_device_fault(updates) {
                self.handle_runtime_fault("update step", e, &mut attempt, recovery)?;
                continue;
            }
            match self.upload_and_step(timers) {
                Ok(()) => return Ok(()),
                Err(e) => self.handle_runtime_fault("update step", e, &mut attempt, recovery)?,
            }
        }
    }

    /// Download the population to host through the same
    /// fault-classification wrapper as the update step. After a
    /// device-loss recovery the re-run download returns the re-uploaded
    /// mirror — exactly the state the actors already hold.
    fn sync_with_recovery(&mut self, recovery: &mut RecoveryCounters) -> anyhow::Result<Vec<f32>> {
        let mut attempt = 0u32;
        loop {
            match self.population.sync_to_host() {
                Ok(host) => return Ok(host),
                Err(e) => self.handle_runtime_fault("host sync", e, &mut attempt, recovery)?,
            }
        }
    }

    /// One planned device error whose update threshold is crossed and
    /// which has not fired yet, as the error the runtime would surface.
    #[cfg(feature = "fault-inject")]
    fn take_injected_device_fault(&mut self, updates: u64) -> Option<anyhow::Error> {
        let plan = self.cfg.fault_plan.clone()?;
        for (i, &at) in plan.device_errors.iter().enumerate() {
            if updates >= at && !self.device_faults_fired[i] {
                self.device_faults_fired[i] = true;
                return Some(anyhow::anyhow!(
                    "fault-inject: simulated device loss at {updates} updates (DEVICE_LOST)"
                ));
            }
        }
        None
    }

    /// Live length of every replay stripe: per-agent buffers count as
    /// one stripe each, a [`ShardedReplay`] reports each stripe.
    fn stripe_lens(&self) -> Vec<usize> {
        self.replays.iter().flat_map(|r| r.stripe_lens()).collect()
    }

    /// Run the full loop with the given controller.
    pub fn run(&mut self, controller: &mut dyn Controller) -> anyhow::Result<Summary> {
        let art = self.population.artifact.clone();
        let k = art.num_steps as u64;
        // Live metrics: flip the process-wide registry per this run's
        // config, start the snapshot exporter (None when off), and
        // record learner stages through the registry-backed recorder
        // (its run-local PhaseTimer feeds Summary either way).
        telemetry::configure(&self.cfg.telemetry);
        let mut exporter = Exporter::from_config(&self.cfg.telemetry)?;
        if let Some(path) = exporter.as_ref().and_then(|e| e.jsonl_path()) {
            log::info(&format!("telemetry snapshots -> {}", path.display()));
        }
        let mut timers = PhaseRecorder::new("learner.phase");
        let c_updates = telemetry::counter("learner.updates");
        let c_env_steps = telemetry::counter("learner.env_steps");
        let c_episodes = telemetry::counter("learner.episodes");
        let mut env_steps_counted: u64 = 0;
        let mut episodes_counted: u64 = 0;
        let mut csv = if self.cfg.csv_path.is_empty() {
            None
        } else {
            let mut cols: Vec<&str> = vec![
                "wall_s", "updates", "env_steps", "best_return", "mean_return", "episodes",
                "actor_restarts", "stalled_actors", "members_repaired", "stripe_min_fill",
                "stripe_max_fill",
            ];
            cols.extend(D::metrics().iter().map(|(col, _)| *col));
            Some(CsvLogger::create(&self.cfg.csv_path, &cols)?)
        };

        let throttle = Throttle::new();
        let mut pool = D::spawn_actors(
            &art,
            self.population.view.clone(),
            &self.cfg,
            throttle.clone(),
            self.actor_sinks.clone(),
        )?;
        // With direct-ingest sinks the drain channel carries no rows:
        // ratio bookkeeping reads the shared env-step counter instead of
        // counting drained rows, and episode returns arrive over the
        // pool's episode lane.
        let sink_mode = !self.actor_sinks.is_empty();
        let mut env_steps_seen: u64 = 0;

        // Supervision state: restart bookkeeping per actor thread, the
        // watchdog's current stall flags, and the Summary counters.
        let mut restarts = RestartTracker::new(
            RestartPolicy {
                max_restarts: self.cfg.max_actor_restarts,
                backoff_base_ms: self.cfg.restart_backoff_ms.max(1),
                backoff_cap_ms: self.cfg.restart_backoff_ms.max(5_000),
            },
            pool.threads(),
        );
        // Run-local counts mirrored into the registry through one bump
        // site each, so Summary and telemetry cannot drift apart.
        let mut actor_restarts = RunCounter::new(telemetry::counter("supervisor.actor_restarts"));
        let mut stall_events = RunCounter::new(telemetry::counter("supervisor.stall_events"));
        let mut members_repaired =
            RunCounter::new(telemetry::counter("supervisor.members_repaired"));
        let mut recovery = RecoveryCounters {
            retries: RunCounter::new(telemetry::counter("runtime.retries")),
            device_restarts: RunCounter::new(telemetry::counter("runtime.device_restarts")),
        };
        let mut stalled_flags = vec![false; pool.threads()];
        let hb_gauges: Vec<telemetry::Gauge> = (0..pool.threads())
            .map(|t| telemetry::gauge(&format!("actor.{t}.heartbeat_age_ms")))
            .collect();
        #[cfg(feature = "fault-inject")]
        let mut nan_faults_fired: Vec<bool> = self
            .cfg
            .fault_plan
            .as_ref()
            .map(|p| vec![false; p.nan_members.len()])
            .unwrap_or_default();

        let start = Instant::now();
        let mut updates: u64 = 0;
        let mut episodes: u64 = 0;
        let mut since_sync: u64 = 0;
        // Watchdog liveness: touch the heartbeat at launch (so startup
        // is never mistaken for a stall), then from the loop every
        // HEARTBEAT_INTERVAL_SECS and at every sync point. A wedged
        // device call freezes the loop and therefore the heartbeat —
        // exactly the condition the watchdog must catch.
        if let Some(dir) = &self.run_dir {
            let _ = watchdog::touch_heartbeat(dir, 0);
        }
        let mut last_heartbeat = Instant::now();
        let result = (|| -> anyhow::Result<()> {
            while updates < self.cfg.total_updates {
                if self.cfg.max_seconds > 0.0
                    && start.elapsed().as_secs_f64() > self.cfg.max_seconds
                {
                    break;
                }
                // ---- supervise the actor pool ----------------------------
                while let Some(exit) = pool.poll_exit() {
                    if !exit.cause.is_failure() {
                        continue; // clean stop (shutdown path)
                    }
                    log::warn(&format!(
                        "actor thread {} (agents {:?}) died: {:?}",
                        exit.thread, exit.agents, exit.cause
                    ));
                    match restarts.on_failure(exit.thread, Instant::now()) {
                        RestartDecision::Scheduled => {}
                        RestartDecision::GaveUp => log::warn(&format!(
                            "actor thread {} exhausted its {} restarts; its agents \
                             stay down for the rest of the run",
                            exit.thread, self.cfg.max_actor_restarts
                        )),
                    }
                }
                for t in restarts.due(Instant::now()) {
                    if pool.respawn(t) {
                        actor_restarts.bump(1);
                        log::info(&format!(
                            "respawned actor thread {t} (restart #{})",
                            actor_restarts.get()
                        ));
                    }
                }
                if telemetry::enabled() {
                    for (t, g) in hb_gauges.iter().enumerate() {
                        g.set(pool.heartbeats().millis_since(t) as f64);
                    }
                }
                if self.cfg.stall_timeout_ms > 0 {
                    for t in 0..pool.threads() {
                        let stalled =
                            pool.heartbeats().is_stalled(t, self.cfg.stall_timeout_ms);
                        if stalled && !stalled_flags[t] {
                            stalled_flags[t] = true;
                            stall_events.bump(1);
                            log::warn(&format!(
                                "actor thread {t} stalled: no heartbeat for {} ms \
                                 (flagging only; threads cannot be force-killed)",
                                pool.heartbeats().millis_since(t)
                            ));
                        } else if !stalled && stalled_flags[t] {
                            stalled_flags[t] = false;
                            log::info(&format!("actor thread {t} resumed heartbeats"));
                        }
                    }
                }

                // ---- drain actor messages --------------------------------
                {
                    let _drain = timers.span("drain");
                    if sink_mode {
                        let now =
                            throttle.env_steps.load(std::sync::atomic::Ordering::Relaxed);
                        self.gate.on_env_steps(now.saturating_sub(env_steps_seen));
                        env_steps_seen = now;
                        while let Some(ep) = pool.poll_episode() {
                            self.population.returns[ep.agent].push(ep.ret);
                            episodes += 1;
                        }
                    }
                    let mut drained = 0u64;
                    while let Ok(block) = pool.rx.try_recv() {
                        drained += block.rows() as u64;
                        episodes += self.absorb_block(&block);
                        pool.recycle(block);
                        if drained >= self.cfg.drain_bound {
                            break; // bounded drain per iteration
                        }
                    }
                }
                // Reconcile the learner counters from the gate's
                // authoritative totals (covers drain, sink and park paths).
                let g_now = self.gate.env_steps();
                c_env_steps.add(g_now.saturating_sub(env_steps_counted));
                env_steps_counted = g_now;
                c_episodes.add(episodes.saturating_sub(episodes_counted));
                episodes_counted = episodes;

                // ---- update step -----------------------------------------
                let min_fill = self.replays.iter().map(|r| r.len()).min().unwrap_or(0);
                let gate_open = self.cfg.ratio <= 0.0 || self.gate.may_update(k);
                if min_fill >= art.batch && gate_open {
                    {
                        let _sample = timers.span("sample");
                        self.fill_batches();
                    }
                    self.step_with_recovery(&mut timers, &mut recovery, updates)?;
                    self.gate.on_update_steps(k);
                    throttle.updates.fetch_add(k, std::sync::atomic::Ordering::Relaxed);
                    updates += k;
                    c_updates.add(k);
                    since_sync += 1;
                } else {
                    // replay warmup / ratio wait: park on the channel
                    // instead of busy-spinning a core against the actor
                    // threads that must produce the missing transitions
                    if let Ok(block) =
                        pool.rx.recv_timeout(std::time::Duration::from_millis(5))
                    {
                        episodes += self.absorb_block(&block);
                        pool.recycle(block);
                    }
                }

                // ---- sync + evolve ---------------------------------------
                if since_sync >= self.cfg.sync_every.max(1)
                    || (since_sync > 0 && updates >= self.cfg.total_updates)
                {
                    since_sync = 0;
                    let mut host = {
                        let _sync = timers.span("host_sync");
                        self.sync_with_recovery(&mut recovery)?
                    };
                    // fault injection: simulate a member diverging by the
                    // time this sync observes the state (fires once per
                    // planned (member, update) entry)
                    #[cfg(feature = "fault-inject")]
                    if let Some(plan) = self.cfg.fault_plan.clone() {
                        for (i, &(m, at)) in plan.nan_members.iter().enumerate() {
                            if updates >= at && !nan_faults_fired[i] {
                                nan_faults_fired[i] = true;
                                health::poison_member(&art, &mut host, m);
                                log::warn(&format!(
                                    "fault-inject: NaN-poisoned member {m} at {updates} updates"
                                ));
                            }
                        }
                    }
                    // ---- member health scan + quarantine repair ----------
                    let scan = {
                        let _scan = timers.span("health_scan");
                        health::scan_members(&art, &host, self.cfg.health_norm_limit as f32)
                    };
                    let scan_clean = scan.all_healthy();
                    let mut repaired_this_sync = false;
                    if !scan_clean {
                        let fitness = self.population.fitness();
                        let outcome =
                            health::repair_members(&art, &mut host, &scan, &fitness)?;
                        members_repaired.bump(outcome.repaired.len() as u64);
                        repaired_this_sync = true;
                        for &m in &outcome.repaired {
                            // the repaired member is a new lineage: its old
                            // returns would poison fitness ranking
                            self.population.returns[m].clear();
                        }
                        log::warn(&format!(
                            "quarantined members {:?} repaired from donor {} \
                             ({} total repairs)",
                            outcome.repaired,
                            outcome.donor,
                            members_repaired.get()
                        ));
                    }
                    let fitness = self.population.fitness();
                    let mut ctx = EvolveCtx {
                        artifact: &art,
                        host: &mut host,
                        fitness: &fitness,
                        rng: &mut self.rng,
                        updates_done: updates,
                        env_steps: self.gate.env_steps(),
                        mutated: repaired_this_sync,
                        reset_returns: Vec::new(),
                    };
                    controller.on_sync(&mut ctx)?;
                    let mutated = ctx.mutated;
                    let reset_returns = std::mem::take(&mut ctx.reset_returns);
                    drop(ctx);
                    for agent in reset_returns {
                        self.population.returns[agent].clear();
                    }
                    if mutated {
                        let _evolve = timers.span("evolve_upload");
                        self.population.load_host(&self.rt, host)?;
                    }
                    if self.lineage.is_some() {
                        let _ckpt = timers.span("checkpoint");
                        let c = Checkpoint::capture(&self.population.train_state)?;
                        // `last_good` advances only when this sync's scan
                        // (before any repair) found every member healthy —
                        // so resume can always reach a pre-divergence state
                        self.lineage.as_mut().unwrap().save(&c, scan_clean)?;
                    }
                    if let Some(dir) = &self.run_dir {
                        let _ = watchdog::touch_heartbeat(dir, updates);
                        last_heartbeat = Instant::now();
                    }
                    // fault injection: kill the whole process at a sync
                    // point so the watchdog restart path can be proven
                    // end to end. Fires after the checkpoint save (the
                    // lineage holds this sync's state) and only in a
                    // first-incarnation run — the restarted process
                    // resumes instead of re-dying.
                    #[cfg(feature = "fault-inject")]
                    if let Some(at) = self.cfg.fault_plan.as_ref().and_then(|p| p.process_abort) {
                        if !self.resumed && self.population.train_state.updates_done >= at {
                            log::warn(&format!(
                                "fault-inject: planned process abort at {} updates (sync point)",
                                self.population.train_state.updates_done
                            ));
                            std::process::abort();
                        }
                    }
                    // One stripe-length walk per sync feeds both the
                    // per-stripe fill gauges and the CSV min/max columns
                    // (same source, so the two views cannot drift).
                    let stripe_lens = if csv.is_some() || telemetry::enabled() {
                        self.stripe_lens()
                    } else {
                        Vec::new()
                    };
                    if telemetry::enabled() {
                        for (i, &len) in stripe_lens.iter().enumerate() {
                            telemetry::gauge(&format!("replay.stripe.{i}.fill"))
                                .set(len as f64);
                        }
                    }
                    if let Some(csv) = csv.as_mut() {
                        let f = self.population.fitness();
                        let finite: Vec<f64> =
                            f.iter().copied().filter(|v| v.is_finite()).collect();
                        let best = finite.iter().copied().fold(f64::NEG_INFINITY, f64::max);
                        let metric_mean = |name: &str| -> f64 {
                            self.population
                                .view
                                .with(|h| {
                                    art.read(h, name).ok().map(|v| {
                                        v.iter().map(|&x| x as f64).sum::<f64>()
                                            / v.len().max(1) as f64
                                    })
                                })
                                .unwrap_or(f64::NAN)
                        };
                        let mut row = vec![
                            start.elapsed().as_secs_f64(),
                            updates as f64,
                            self.gate.env_steps() as f64,
                            if best.is_finite() { best } else { f64::NAN },
                            stats::mean(&finite),
                            episodes as f64,
                            actor_restarts.get() as f64,
                            stalled_flags.iter().filter(|&&s| s).count() as f64,
                            members_repaired.get() as f64,
                            stripe_lens.iter().copied().min().unwrap_or(0) as f64,
                            stripe_lens.iter().copied().max().unwrap_or(0) as f64,
                        ];
                        row.extend(D::metrics().iter().map(|(_, field)| metric_mean(field)));
                        csv.row(&row)?;
                        csv.flush()?;
                    }
                }
                if let Some(dir) = &self.run_dir {
                    if last_heartbeat.elapsed().as_secs_f64()
                        >= watchdog::HEARTBEAT_INTERVAL_SECS
                    {
                        let _ = watchdog::touch_heartbeat(dir, updates);
                        last_heartbeat = Instant::now();
                    }
                }
                if let Some(e) = exporter.as_mut() {
                    e.tick();
                }
            }
            Ok(())
        })();
        pool.stop();
        result?;
        // Final counter reconcile: a `break` (wall-clock budget) can exit
        // between a park-path absorb and the next drain, so bring the
        // exported totals up to the gate's before the last snapshot.
        c_env_steps.add(self.gate.env_steps().saturating_sub(env_steps_counted));
        c_episodes.add(episodes.saturating_sub(episodes_counted));

        let fitness = self.population.fitness();
        let finite: Vec<f64> = fitness.iter().copied().filter(|v| v.is_finite()).collect();
        let stripe_lens = self.stripe_lens();
        if telemetry::enabled() {
            // Summary's stripe min/max and the exported fill gauges come
            // from this same final walk.
            for (i, &len) in stripe_lens.iter().enumerate() {
                telemetry::gauge(&format!("replay.stripe.{i}.fill")).set(len as f64);
            }
        }
        if let Some(e) = exporter.as_mut() {
            e.flush();
        }
        Ok(Summary {
            wall_seconds: start.elapsed().as_secs_f64(),
            updates,
            env_steps: self.gate.env_steps(),
            best_return: finite.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            mean_return: stats::mean(&finite),
            actor_restarts: actor_restarts.get(),
            stalled_actors: stall_events.get(),
            members_repaired: members_repaired.get(),
            runtime_retries: recovery.retries.get(),
            device_restarts: recovery.device_restarts.get(),
            replay_shards: self.actor_sinks.len().max(1),
            stripe_min_fill: stripe_lens.iter().copied().min().unwrap_or(0),
            stripe_max_fill: stripe_lens.iter().copied().max().unwrap_or(0),
            timers: timers.into_timer(),
        })
    }
}

/// Train any algo/env pairing through one entry point: look the artifact
/// up, pick the [`Domain`] from its metadata (pixel artifacts carry a
/// frame shape, continuous ones vector dims), and run the shared loop —
/// controllers, checkpointing and CSV logging included. This is what the
/// `fastpbrl train` subcommand calls, so
/// `fastpbrl train --algo dqn --env minatar` and
/// `fastpbrl train --algo td3 --env pendulum` go down the same path.
pub fn run_training(
    manifest: &Manifest,
    cfg: TrainerConfig,
    controller: &mut dyn Controller,
) -> anyhow::Result<Summary> {
    let artifact = find_artifact(manifest, &cfg)?;
    if artifact.env_desc.frame.is_some() {
        Trainer::<Pixel>::new(manifest, cfg)?.run(controller)
    } else {
        Trainer::<Continuous>::new(manifest, cfg)?.run(controller)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{EnvDesc, Field};
    use std::path::PathBuf;

    fn artifact_with_env(env_desc: EnvDesc, fields: Vec<Field>, state_size: usize) -> Artifact {
        Artifact::new(
            "toy".into(),
            PathBuf::new(),
            "td3".into(),
            "pendulum".into(),
            env_desc,
            2,
            1,
            4,
            vec![],
            state_size,
            "state".into(),
            vec![],
            fields,
            vec![],
        )
    }

    #[test]
    fn builder_chain_sets_fields() {
        let cfg = TrainerConfig::new("dqn", "minatar")
            .with_pop(8)
            .with_updates(123)
            .with_seed(9)
            .with_ratio(0.25)
            .with_warmup(50)
            .with_sync_every(10)
            .with_replay_capacity(777)
            .with_shared_replay(true)
            .with_replay_shards(3)
            .with_eps_greedy(0.05)
            .with_expl_noise(0.2)
            .with_csv("out.csv")
            .with_checkpoint("ckpt.bin")
            .with_max_seconds(3.5)
            .with_actor_threads(2)
            .with_keep_checkpoints(7)
            .with_max_actor_restarts(5)
            .with_restart_backoff_ms(250)
            .with_stall_timeout_ms(1234)
            .with_health_norm_limit(1e5)
            .with_runtime_retries(7)
            .with_runtime_retry_backoff_ms(42)
            .with_max_device_restarts(4)
            .with_telemetry(TelemetryConfig::jsonl("t.jsonl"));
        assert_eq!(cfg.algo, "dqn");
        assert_eq!(cfg.env, "minatar");
        assert_eq!(cfg.pop, 8);
        assert_eq!(cfg.total_updates, 123);
        assert_eq!(cfg.seed, 9);
        assert!((cfg.ratio - 0.25).abs() < 1e-12);
        assert_eq!(cfg.warmup_steps, 50);
        assert_eq!(cfg.sync_every, 10);
        assert_eq!(cfg.replay_capacity, 777);
        assert!(cfg.shared_replay);
        assert_eq!(cfg.replay_shards, 3);
        assert!((cfg.eps_greedy - 0.05).abs() < 1e-7);
        assert!((cfg.expl_noise - 0.2).abs() < 1e-7);
        assert_eq!(cfg.csv_path, "out.csv");
        assert_eq!(cfg.checkpoint_path, "ckpt.bin");
        assert!((cfg.max_seconds - 3.5).abs() < 1e-12);
        assert_eq!(cfg.n_actor_threads, 2);
        assert_eq!(cfg.keep_checkpoints, 7);
        assert_eq!(cfg.max_actor_restarts, 5);
        assert_eq!(cfg.restart_backoff_ms, 250);
        assert_eq!(cfg.stall_timeout_ms, 1234);
        assert!((cfg.health_norm_limit - 1e5).abs() < 1e-9);
        assert_eq!(cfg.runtime_retries, 7);
        assert_eq!(cfg.runtime_retry_backoff_ms, 42);
        assert_eq!(cfg.max_device_restarts, 4);
        assert!(cfg.telemetry.is_on());
        assert_eq!(cfg.telemetry.jsonl_path, "t.jsonl");
        // the config is Clone + Debug (sweeps copy it, tests print it)
        let copy = cfg.clone();
        assert!(format!("{copy:?}").contains("minatar"));
    }

    #[test]
    fn domains_reject_mismatched_artifacts() {
        let continuous =
            artifact_with_env(EnvDesc { obs_dim: 3, act_dim: 1, ..Default::default() },
                              vec![], 0);
        let pixel = artifact_with_env(
            EnvDesc { frame: Some((4, 4, 2)), n_actions: 3, ..Default::default() },
            vec![],
            0,
        );
        assert!(Continuous::check(&continuous).is_ok());
        assert!(Pixel::check(&pixel).is_ok());
        let err = Continuous::check(&pixel).unwrap_err().to_string();
        assert!(err.contains("Trainer::<Pixel>"), "{err}");
        let err = Pixel::check(&continuous).unwrap_err().to_string();
        assert!(err.contains("Trainer::<Continuous>"), "{err}");
    }

    #[test]
    fn pixel_prepare_host_bakes_configured_epsilon() {
        let fields = vec![Field {
            name: "eps_greedy".into(),
            offset: 0,
            size: 2,
            shape: vec![2],
            dtype: Dtype::F32,
            init: "const:0.1".into(),
            group: "hyper".into(),
            per_agent: true,
        }];
        let art = artifact_with_env(
            EnvDesc { frame: Some((4, 4, 2)), n_actions: 3, ..Default::default() },
            fields,
            2,
        );
        let mut host = vec![0.1f32, 0.1];
        let cfg = TrainerConfig::new("dqn", "minatar").with_eps_greedy(0.03);
        assert!(Pixel::prepare_host(&art, &cfg, &mut host));
        assert_eq!(host, vec![0.03, 0.03]);
        // sampled hypers stay authoritative
        let cfg = cfg.with_hypers(crate::coordinator::hyperparams::HyperSpec::dqn());
        let mut host = vec![0.07f32, 0.09];
        assert!(!Pixel::prepare_host(&art, &cfg, &mut host));
        assert_eq!(host, vec![0.07, 0.09]);
    }

    #[test]
    fn config_digest_tracks_run_identity_not_output_paths() {
        let base = TrainerConfig::new("td3", "pendulum").with_pop(4).with_seed(7);
        let same = base.clone();
        assert_eq!(base.config_digest(), same.config_digest());
        // run-defining fields change the digest
        assert_ne!(base.config_digest(), base.clone().with_seed(8).config_digest());
        assert_ne!(base.config_digest(), base.clone().with_pop(8).config_digest());
        assert_ne!(base.config_digest(), base.clone().with_updates(99).config_digest());
        // output/robustness knobs do not — moving the logs or tuning the
        // retry budget is still the same run
        assert_eq!(
            base.config_digest(),
            base.clone().with_csv("elsewhere.csv").config_digest()
        );
        assert_eq!(
            base.config_digest(),
            base.clone().with_runtime_retries(9).config_digest()
        );
        assert_eq!(base.config_digest().len(), 16);
    }

    #[test]
    fn domain_replay_construction_matches_env_dims() {
        let continuous =
            artifact_with_env(EnvDesc { obs_dim: 3, act_dim: 1, ..Default::default() },
                              vec![], 0);
        let buf = Continuous::make_replay(&continuous, 16);
        assert_eq!(Replay::capacity(&buf), 16);
        let pixel = artifact_with_env(
            EnvDesc { frame: Some((4, 4, 2)), n_actions: 3, ..Default::default() },
            vec![],
            0,
        );
        let buf = Pixel::make_replay(&pixel, 8);
        assert_eq!(Replay::capacity(&buf), 8);
    }
}
