//! Population state management: one device-resident vectorized train
//! state + host bookkeeping (recent returns, hyperparameters, actors'
//! parameter view).

use std::sync::{Arc, RwLock};

use crate::coordinator::hyperparams::HyperSpec;
use crate::manifest::Artifact;
use crate::runtime::{Runtime, TrainState};
use crate::util::rng::Rng;

/// Shared, versioned host copy of the flat state for non-blocking actor
/// parameter sync (paper Appendix A: new parameters are published to
/// shared memory while the accelerator keeps running).
#[derive(Clone)]
pub struct ParamView {
    inner: Arc<RwLock<(u64, Vec<f32>)>>,
}

impl ParamView {
    pub fn new(state: Vec<f32>) -> Self {
        ParamView { inner: Arc::new(RwLock::new((1, state))) }
    }

    pub fn publish(&self, state: Vec<f32>) {
        let mut g = self.inner.write().unwrap();
        g.0 += 1;
        g.1 = state;
    }

    pub fn version(&self) -> u64 {
        self.inner.read().unwrap().0
    }

    /// Copy out if the version advanced past `seen`; returns new version.
    pub fn fetch_if_newer(&self, seen: u64, out: &mut Vec<f32>) -> u64 {
        let g = self.inner.read().unwrap();
        if g.0 > seen {
            out.clear();
            out.extend_from_slice(&g.1);
        }
        g.0
    }

    pub fn with<R>(&self, f: impl FnOnce(&[f32]) -> R) -> R {
        let g = self.inner.read().unwrap();
        f(&g.1)
    }
}

/// Recent-episode-return tracker (PBT ranks on the mean of the last k).
#[derive(Clone, Debug)]
pub struct ReturnWindow {
    window: usize,
    values: Vec<f64>,
    pub episodes: u64,
}

impl ReturnWindow {
    pub fn new(window: usize) -> Self {
        ReturnWindow { window, values: Vec::new(), episodes: 0 }
    }

    pub fn push(&mut self, ret: f64) {
        if self.values.len() == self.window {
            self.values.remove(0);
        }
        self.values.push(ret);
        self.episodes += 1;
    }

    pub fn mean(&self) -> Option<f64> {
        if self.values.is_empty() {
            None
        } else {
            Some(self.values.iter().sum::<f64>() / self.values.len() as f64)
        }
    }

    pub fn clear(&mut self) {
        self.values.clear();
    }
}

/// A population of N agents training through one vectorized artifact.
pub struct Population {
    pub artifact: Artifact,
    pub train_state: TrainState,
    pub view: ParamView,
    pub returns: Vec<ReturnWindow>,
    pub hyper_spec: Option<HyperSpec>,
}

impl Population {
    /// Initialize with per-agent random params; if a hyper spec is given,
    /// every agent's tunables are sampled from the priors (PBT init).
    pub fn init(
        rt: &Runtime,
        artifact: &Artifact,
        rng: &mut Rng,
        seed_tag: u64,
        hyper_spec: Option<HyperSpec>,
        return_window: usize,
    ) -> anyhow::Result<Population> {
        let mut host = artifact.init_state(rng, seed_tag);
        if let Some(spec) = &hyper_spec {
            for agent in 0..artifact.pop {
                spec.sample_into(artifact, &mut host, agent, rng);
            }
        }
        let train_state = TrainState::from_host(rt, artifact, &host)?;
        Ok(Population {
            artifact: artifact.clone(),
            train_state,
            view: ParamView::new(host),
            returns: (0..artifact.pop).map(|_| ReturnWindow::new(return_window)).collect(),
            hyper_spec,
        })
    }

    pub fn pop(&self) -> usize {
        self.artifact.pop
    }

    /// Download the device state and publish it to the actors.
    pub fn sync_to_host(&mut self) -> anyhow::Result<Vec<f32>> {
        let host = self.train_state.to_host()?;
        self.view.publish(host.clone());
        Ok(host)
    }

    /// Push a (possibly mutated) host state back to the device and to the
    /// actors (evolution points).
    pub fn load_host(&mut self, rt: &Runtime, host: Vec<f32>) -> anyhow::Result<()> {
        self.train_state.load_host(rt, &host)?;
        self.view.publish(host);
        Ok(())
    }

    /// Mean recent return per agent; agents with no finished episode yet
    /// rank lowest.
    pub fn fitness(&self) -> Vec<f64> {
        self.returns
            .iter()
            .map(|w| w.mean().unwrap_or(f64::NEG_INFINITY))
            .collect()
    }

    pub fn best_agent(&self) -> (usize, f64) {
        let f = self.fitness();
        let mut best = 0;
        for i in 1..f.len() {
            if f[i] > f[best] {
                best = i;
            }
        }
        (best, f[best])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn return_window_slides() {
        let mut w = ReturnWindow::new(3);
        assert_eq!(w.mean(), None);
        for r in [1.0, 2.0, 3.0, 4.0] {
            w.push(r);
        }
        assert_eq!(w.mean(), Some(3.0)); // (2+3+4)/3
        assert_eq!(w.episodes, 4);
    }

    #[test]
    fn param_view_versions() {
        let v = ParamView::new(vec![1.0]);
        let mut buf = Vec::new();
        let ver = v.fetch_if_newer(0, &mut buf);
        assert_eq!(ver, 1);
        assert_eq!(buf, vec![1.0]);
        // no change: buffer untouched
        buf.clear();
        let ver2 = v.fetch_if_newer(ver, &mut buf);
        assert_eq!(ver2, ver);
        assert!(buf.is_empty());
        v.publish(vec![2.0, 3.0]);
        let ver3 = v.fetch_if_newer(ver2, &mut buf);
        assert_eq!(ver3, ver2 + 1);
        assert_eq!(buf, vec![2.0, 3.0]);
    }
}
