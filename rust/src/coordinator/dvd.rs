//! DvD (Parker-Holder et al., 2020; paper §5.3).
//!
//! DvD is the shared-critic population TD3 plus an explicit diversity
//! bonus: the log-determinant of an RBF kernel matrix over policy
//! "behavioral embeddings" (their actions on probe states). The loss term
//! lives in the L2 artifact (`updates/shared_critic.py` with `dvd=True`);
//! the coordinator's contribution is the diversity-weight schedule — the
//! paper replaces DvD's multi-armed bandit with a schedule (Appendix B.2),
//! which this controller implements.

use crate::coordinator::trainer::{Controller, EvolveCtx};

/// Piecewise-linear schedule on the `lambda_div` state field.
pub struct DvdLambdaSchedule {
    /// (update_step, lambda) knots, sorted by step.
    pub knots: Vec<(u64, f64)>,
}

impl DvdLambdaSchedule {
    /// The default B.2-style schedule: start exploratory, anneal to mild.
    pub fn default_for(total_updates: u64) -> Self {
        DvdLambdaSchedule {
            knots: vec![
                (0, 0.5),
                (total_updates / 2, 0.2),
                (total_updates, 0.05),
            ],
        }
    }

    pub fn value_at(&self, step: u64) -> f64 {
        if self.knots.is_empty() {
            return 0.0;
        }
        if step <= self.knots[0].0 {
            return self.knots[0].1;
        }
        for w in self.knots.windows(2) {
            let (s0, v0) = w[0];
            let (s1, v1) = w[1];
            if step <= s1 {
                let t = (step - s0) as f64 / (s1 - s0).max(1) as f64;
                return v0 + t * (v1 - v0);
            }
        }
        self.knots.last().unwrap().1
    }
}

impl Controller for DvdLambdaSchedule {
    fn name(&self) -> &'static str {
        "dvd"
    }

    fn on_sync(&mut self, ctx: &mut EvolveCtx<'_>) -> anyhow::Result<()> {
        let lam = self.value_at(ctx.updates_done) as f32;
        if let Ok(f) = ctx.artifact.field("lambda_div") {
            let cur = ctx.host[f.offset];
            if (cur - lam).abs() > 1e-9 {
                ctx.host[f.offset] = lam;
                ctx.mutated = true;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_interpolates_and_clamps() {
        let s = DvdLambdaSchedule { knots: vec![(0, 1.0), (100, 0.0)] };
        assert_eq!(s.value_at(0), 1.0);
        assert!((s.value_at(50) - 0.5).abs() < 1e-12);
        assert_eq!(s.value_at(100), 0.0);
        assert_eq!(s.value_at(10_000), 0.0);
    }

    #[test]
    fn default_schedule_monotone_decreasing() {
        let s = DvdLambdaSchedule::default_for(1000);
        let mut prev = f64::INFINITY;
        for step in [0u64, 100, 400, 500, 800, 1000] {
            let v = s.value_at(step);
            assert!(v <= prev + 1e-12);
            prev = v;
        }
    }
}
