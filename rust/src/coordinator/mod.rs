//! Population-based training coordinators (PBT, CEM-RL, DvD).
pub mod cem;
pub mod eval;
pub mod dvd;
pub mod health;
pub mod hyperparams;
pub mod pbt;
pub mod population;
pub mod trainer;
