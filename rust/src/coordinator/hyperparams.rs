//! Per-agent hyperparameter spaces (paper Appendix B.1).
//!
//! PBT samples each tunable hyperparameter from a prior distribution at
//! population init and re-samples (or perturbs) it when an agent is
//! replaced. Hyperparameters live *inside* the flat train state (group
//! "hyper"), so mutating them is a host-side write through the manifest.

use crate::manifest::Artifact;
use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub enum Dist {
    /// U(lo, hi)
    Uniform(f64, f64),
    /// exp(U(ln lo, ln hi)) — learning-rate prior
    LogUniform(f64, f64),
    /// Fixed value (not tuned, but kept explicit)
    Fixed(f64),
}

impl Dist {
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            Dist::Uniform(lo, hi) => rng.uniform_in(lo, hi),
            Dist::LogUniform(lo, hi) => rng.log_uniform_in(lo, hi),
            Dist::Fixed(v) => v,
        }
    }

    /// PBT "explore" perturbation: multiply by 0.8 or 1.25, clipped to the
    /// prior's support (Jaderberg et al., 2017).
    pub fn perturb(&self, value: f64, rng: &mut Rng) -> f64 {
        let factor = if rng.below(2) == 0 { 0.8 } else { 1.25 };
        match *self {
            Dist::Uniform(lo, hi) => (value * factor).clamp(lo, hi),
            Dist::LogUniform(lo, hi) => (value * factor).clamp(lo, hi),
            Dist::Fixed(v) => v,
        }
    }

    pub fn support(&self) -> (f64, f64) {
        match *self {
            Dist::Uniform(lo, hi) | Dist::LogUniform(lo, hi) => (lo, hi),
            Dist::Fixed(v) => (v, v),
        }
    }
}

#[derive(Clone, Debug)]
pub struct HyperSpec {
    /// (state field name, prior)
    pub entries: Vec<(String, Dist)>,
}

impl HyperSpec {
    /// TD3 search space from Appendix B.1: policy/critic lrs log-uniform
    /// [3e-5, 3e-3]; policy update frequency U(0.2, 1); smoothing noise
    /// U(0, 1); discount U(0.9, 1).
    pub fn td3() -> HyperSpec {
        HyperSpec {
            entries: vec![
                ("lr_policy".into(), Dist::LogUniform(3e-5, 3e-3)),
                ("lr_critic".into(), Dist::LogUniform(3e-5, 3e-3)),
                ("policy_freq".into(), Dist::Uniform(0.2, 1.0)),
                ("noise".into(), Dist::Uniform(0.0, 1.0)),
                ("gamma".into(), Dist::Uniform(0.9, 1.0)),
            ],
        }
    }

    /// SAC search space from Appendix B.1: three lrs log-uniform
    /// [3e-5, 3e-3]; target entropy multiplier U(0.2, 2); reward scale
    /// U(0.1, 10); discount U(0.9, 1).
    pub fn sac() -> HyperSpec {
        HyperSpec {
            entries: vec![
                ("lr_policy".into(), Dist::LogUniform(3e-5, 3e-3)),
                ("lr_critic".into(), Dist::LogUniform(3e-5, 3e-3)),
                ("lr_alpha".into(), Dist::LogUniform(3e-5, 3e-3)),
                ("target_entropy_mult".into(), Dist::Uniform(0.2, 2.0)),
                ("reward_scale".into(), Dist::Uniform(0.1, 10.0)),
                ("gamma".into(), Dist::Uniform(0.9, 1.0)),
            ],
        }
    }

    /// DQN space (lr + discount + epsilon; the paper only benchmarks DQN
    /// update speed, this space powers the optional dqn PBT example).
    pub fn dqn() -> HyperSpec {
        HyperSpec {
            entries: vec![
                ("lr".into(), Dist::LogUniform(3e-5, 3e-3)),
                ("gamma".into(), Dist::Uniform(0.9, 1.0)),
                ("eps_greedy".into(), Dist::Uniform(0.01, 0.2)),
            ],
        }
    }

    pub fn for_algo(algo: &str) -> anyhow::Result<HyperSpec> {
        Ok(match algo {
            "td3" => Self::td3(),
            "sac" => Self::sac(),
            "dqn" => Self::dqn(),
            other => anyhow::bail!("no hyperparameter space for algo {other:?}"),
        })
    }

    /// Sample fresh values for agent `agent` into the host state. Fields
    /// missing from the artifact are skipped (spec is a superset).
    pub fn sample_into(&self, artifact: &Artifact, state: &mut [f32], agent: usize,
                       rng: &mut Rng) {
        for (name, dist) in &self.entries {
            if let Ok(f) = artifact.field(name) {
                if f.per_agent && agent < f.shape[0] {
                    let stride = f.agent_stride();
                    state[f.offset + agent * stride] = dist.sample(rng) as f32;
                }
            }
        }
    }

    /// Perturb agent's current values (PBT explore-by-perturbation).
    pub fn perturb_into(&self, artifact: &Artifact, state: &mut [f32], agent: usize,
                        rng: &mut Rng) {
        for (name, dist) in &self.entries {
            if let Ok(f) = artifact.field(name) {
                if f.per_agent && agent < f.shape[0] {
                    let stride = f.agent_stride();
                    let idx = f.offset + agent * stride;
                    state[idx] = dist.perturb(state[idx] as f64, rng) as f32;
                }
            }
        }
    }

    /// Read agent's current values (for logging).
    pub fn read(&self, artifact: &Artifact, state: &[f32], agent: usize)
                -> Vec<(String, f64)> {
        let mut out = Vec::new();
        for (name, _) in &self.entries {
            if let Ok(f) = artifact.field(name) {
                if f.per_agent && agent < f.shape[0] {
                    let stride = f.agent_stride();
                    out.push((name.clone(), state[f.offset + agent * stride] as f64));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_within_support() {
        let mut rng = Rng::new(0);
        for dist in [Dist::Uniform(0.2, 1.0), Dist::LogUniform(3e-5, 3e-3)] {
            let (lo, hi) = dist.support();
            for _ in 0..200 {
                let v = dist.sample(&mut rng);
                assert!((lo..=hi).contains(&v), "{dist:?} -> {v}");
            }
        }
    }

    #[test]
    fn perturb_stays_in_support() {
        let mut rng = Rng::new(1);
        let d = Dist::Uniform(0.2, 1.0);
        let mut v = 0.95;
        for _ in 0..50 {
            v = d.perturb(v, &mut rng);
            assert!((0.2..=1.0).contains(&v));
        }
    }

    #[test]
    fn fixed_is_inert() {
        let mut rng = Rng::new(2);
        let d = Dist::Fixed(0.5);
        assert_eq!(d.sample(&mut rng), 0.5);
        assert_eq!(d.perturb(99.0, &mut rng), 0.5);
    }

    #[test]
    fn specs_exist_for_all_algos() {
        for algo in ["td3", "sac", "dqn"] {
            let spec = HyperSpec::for_algo(algo).unwrap();
            assert!(!spec.entries.is_empty());
        }
        assert!(HyperSpec::for_algo("cem").is_err());
    }

    #[test]
    fn log_uniform_spans_decades() {
        let mut rng = Rng::new(3);
        let d = Dist::LogUniform(3e-5, 3e-3);
        let n = 2000;
        let below_mid = (0..n)
            .filter(|_| d.sample(&mut rng) < 3e-4)
            .count() as f64 / n as f64;
        // log-uniform puts ~half the mass below the geometric midpoint
        assert!((below_mid - 0.5).abs() < 0.06, "got {below_mid}");
    }
}
