//! CEM-RL (Pourchot & Sigaud, 2019; paper §5.2).
//!
//! The Cross-Entropy Method maintains a diagonal Gaussian over *policy
//! parameter vectors*. Each iteration: sample a population from the
//! distribution, let half of it undergo TD3 updates against the shared
//! critic (the vectorized §4.2 artifact — the non-trained half simply gets
//! a zero policy learning rate), evaluate everyone, and refit the
//! distribution on the top half.

use crate::coordinator::population::Population;
use crate::manifest::Manifest;
use crate::nn::from_state::mlp_from_state;
use crate::nn::mlp::Activation;
use crate::replay::ReplayBuffer;
use crate::runtime::Runtime;
use crate::util::log::CsvLogger;
use crate::util::rng::Rng;
use crate::util::stats::{argsort_desc, mean};
use crate::telemetry::PhaseTimer;

/// Diagonal-Gaussian CEM over flat parameter vectors.
#[derive(Clone, Debug)]
pub struct Cem {
    pub mu: Vec<f32>,
    pub var: Vec<f32>,
    /// Extra exploration noise added to the variance, decayed each update
    /// (CEM-RL's eps; the paper bumps the initial value to 1e-2).
    pub noise: f64,
    pub noise_decay: f64,
    pub noise_floor: f64,
    /// Fraction of the population used to refit (CEM-RL: one half).
    pub elite_frac: f64,
}

impl Cem {
    pub fn new(mu: Vec<f32>, init_var: f64, elite_frac: f64) -> Self {
        let n = mu.len();
        Cem {
            mu,
            var: vec![init_var as f32; n],
            noise: 1e-2, // paper B.2: increased from CEM-RL's 1e-3
            noise_decay: 0.999,
            noise_floor: 1e-6,
            elite_frac,
        }
    }

    pub fn dim(&self) -> usize {
        self.mu.len()
    }

    pub fn sample_into(&self, rng: &mut Rng, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim());
        for i in 0..out.len() {
            out[i] = self.mu[i] + (self.var[i].max(0.0)).sqrt() * rng.normal() as f32;
        }
    }

    /// Refit on elites (best-first order not required; plain average).
    pub fn update(&mut self, elites: &[&[f32]]) {
        assert!(!elites.is_empty());
        let n = self.dim();
        let m = elites.len() as f32;
        for i in 0..n {
            let mu = elites.iter().map(|e| e[i]).sum::<f32>() / m;
            // variance around the NEW mean + exploration noise
            let var = elites.iter().map(|e| (e[i] - mu) * (e[i] - mu)).sum::<f32>() / m;
            self.mu[i] = mu;
            self.var[i] = var + self.noise as f32;
        }
        self.noise = (self.noise * self.noise_decay).max(self.noise_floor);
    }
}

pub struct CemRlConfig {
    pub env: String,
    pub pop: usize,
    /// Update rounds between evaluations (each round = P critic updates +
    /// one parallel policy update — see updates/shared_critic.py).
    pub rounds_per_iter: usize,
    pub iters: usize,
    pub warmup_steps: usize,
    pub steps_per_iter: usize,
    pub replay_capacity: usize,
    pub eval_episodes: usize,
    pub seed: u64,
    pub csv_path: String,
    pub max_seconds: f64,
    /// "vec" (paper's §4.2 modification) or "seq" (original CEM-RL order).
    pub ordering: String,
}

impl Default for CemRlConfig {
    fn default() -> Self {
        CemRlConfig {
            env: "halfcheetah".into(),
            pop: 10,
            rounds_per_iter: 10,
            iters: 10,
            warmup_steps: 1000,
            steps_per_iter: 1000,
            replay_capacity: 200_000,
            eval_episodes: 1,
            seed: 0,
            csv_path: String::new(),
            max_seconds: 0.0,
            ordering: "vec".into(),
        }
    }
}

pub struct CemRlSummary {
    pub best_return: f64,
    pub mean_return: f64,
    pub mu_return: f64,
    pub wall_seconds: f64,
    pub env_steps: u64,
    pub updates: u64,
    pub timers: PhaseTimer,
}

/// Full CEM-RL training driver (single-threaded data collection; one CPU
/// core is the whole machine here, and CEM-RL's sample->train->eval cycle
/// is easier to audit without actor races).
pub fn run_cemrl(manifest: &Manifest, cfg: &CemRlConfig) -> anyhow::Result<CemRlSummary> {
    let algo = if cfg.ordering == "seq" { "cemseq" } else { "cem" };
    let artifact = manifest.find(algo, &cfg.env, cfg.pop, None)?.clone();
    let rt = Runtime::cpu()?;
    let exe = rt.load(&artifact)?;
    let mut rng = Rng::new(cfg.seed);
    let mut population = Population::init(&rt, &artifact, &mut rng, cfg.seed ^ 0xCE, None, 8)?;
    let mut timers = PhaseTimer::new();

    // CEM distribution seeded at agent 0's initial policy.
    let host0 = population.view.with(|h| h.to_vec());
    let mu0 = artifact.agent_vector(&host0, &["policy"], 0);
    let mut cem = Cem::new(mu0, 1e-3, 0.5);

    let mut replay = ReplayBuffer::new(
        cfg.replay_capacity,
        artifact.env_desc.obs_dim,
        artifact.env_desc.act_dim,
    );
    let mut env = crate::envs::make_env(&cfg.env)?;
    let (od, ad) = (env.obs_dim(), env.act_dim());
    let mut csv = if cfg.csv_path.is_empty() {
        None
    } else {
        Some(CsvLogger::create(
            &cfg.csv_path,
            &["wall_s", "iter", "env_steps", "updates", "best_return",
              "mean_return", "mu_return"],
        )?)
    };

    // warmup with random actions
    let mut obs = vec![0.0f32; od];
    let mut act = vec![0.0f32; ad];
    let mut next_obs = vec![0.0f32; od];
    env.reset(&mut rng, &mut obs);
    let mut ep_steps = 0usize;
    for _ in 0..cfg.warmup_steps {
        rng.fill_uniform(&mut act, -1.0, 1.0);
        let (r, done) = env.step(&act, &mut next_obs);
        replay.push(&obs, &act, r, &next_obs, done);
        obs.copy_from_slice(&next_obs);
        ep_steps += 1;
        if done || ep_steps >= env.horizon() {
            env.reset(&mut rng, &mut obs);
            ep_steps = 0;
        }
    }
    let mut env_steps = cfg.warmup_steps as u64;
    let mut updates = 0u64;
    let start = std::time::Instant::now();

    // staging buffers for one round's batches [P, B, ...]
    let (pop, batch) = (artifact.pop, artifact.batch);
    let mut stage_obs = vec![0.0f32; pop * batch * od];
    let mut stage_act = vec![0.0f32; pop * batch * ad];
    let mut stage_rew = vec![0.0f32; pop * batch];
    let mut stage_next = vec![0.0f32; pop * batch * od];
    let mut stage_done = vec![0.0f32; pop * batch];
    let mut genomes: Vec<Vec<f32>> = vec![vec![0.0; cem.dim()]; pop];
    let mut best = f64::NEG_INFINITY;
    let mut mean_ret = f64::NEG_INFINITY;
    let mut mu_ret = f64::NEG_INFINITY;

    for iter in 0..cfg.iters {
        if cfg.max_seconds > 0.0 && start.elapsed().as_secs_f64() > cfg.max_seconds {
            break;
        }
        // ---- sample new population from the CEM distribution ------------
        let mut host = population.train_state.to_host()?;
        for (i, g) in genomes.iter_mut().enumerate() {
            cem.sample_into(&mut rng, g);
            artifact.set_agent_vector(&mut host, &["policy"], i, g);
            artifact.set_agent_vector(&mut host, &["policy_target"], i, g);
        }
        // fresh policy optimizer state + zero lr for the eval-only half
        for f in &artifact.fields {
            if f.group == "opt" && f.name.starts_with("adam_policy/") {
                host[f.offset..f.offset + f.size].fill(0.0);
            }
        }
        if let Ok(f) = artifact.field("step") {
            host[f.offset..f.offset + f.size].fill(0.0);
        }
        if let Ok(f) = artifact.field("lr_policy") {
            for i in 0..pop {
                host[f.offset + i] = if i < pop / 2 { 3e-4 } else { 0.0 };
            }
        }
        population.load_host(&rt, host)?;

        // ---- collect environment interactions (all members) -------------
        timers.time("collect", || -> anyhow::Result<()> {
            let host = population.view.with(|h| h.to_vec());
            let steps_per_agent = cfg.steps_per_iter / pop.max(1);
            for agent in 0..pop {
                let mut mlp = mlp_from_state(&artifact, &host, "policy", agent,
                                             Activation::Relu, Activation::Tanh)?;
                env.reset(&mut rng, &mut obs);
                let mut eps = 0usize;
                for _ in 0..steps_per_agent {
                    mlp.forward(&obs, &mut act);
                    for a in act.iter_mut() {
                        *a = (*a + 0.1 * rng.normal() as f32).clamp(-1.0, 1.0);
                    }
                    let (r, done) = env.step(&act, &mut next_obs);
                    replay.push(&obs, &act, r, &next_obs, done);
                    obs.copy_from_slice(&next_obs);
                    eps += 1;
                    if done || eps >= env.horizon() {
                        env.reset(&mut rng, &mut obs);
                        eps = 0;
                    }
                }
                env_steps += steps_per_agent as u64;
            }
            Ok(())
        })?;

        // ---- TD3 updates through the shared-critic artifact --------------
        timers.time("train", || -> anyhow::Result<()> {
            for _ in 0..cfg.rounds_per_iter {
                for agent in 0..pop {
                    replay.sample_into(
                        &mut rng,
                        batch,
                        &mut stage_obs[agent * batch * od..(agent + 1) * batch * od],
                        &mut stage_act[agent * batch * ad..(agent + 1) * batch * ad],
                        &mut stage_rew[agent * batch..(agent + 1) * batch],
                        &mut stage_next[agent * batch * od..(agent + 1) * batch * od],
                        &mut stage_done[agent * batch..(agent + 1) * batch],
                    );
                }
                let bufs = [
                    rt.upload_f32(&stage_obs, &[pop, batch, od])?,
                    rt.upload_f32(&stage_act, &[pop, batch, ad])?,
                    rt.upload_f32(&stage_rew, &[pop, batch])?,
                    rt.upload_f32(&stage_next, &[pop, batch, od])?,
                    rt.upload_f32(&stage_done, &[pop, batch])?,
                ];
                let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
                population.train_state.step(&exe, &refs)?;
                updates += pop as u64; // each round performs P critic updates
            }
            Ok(())
        })?;

        // ---- evaluate everyone + the distribution mean -------------------
        let host = population.sync_to_host()?;
        let mut rets = vec![0.0f64; pop];
        timers.time("eval", || -> anyhow::Result<()> {
            for agent in 0..pop {
                let mut mlp = mlp_from_state(&artifact, &host, "policy", agent,
                                             Activation::Relu, Activation::Tanh)?;
                let mut total = 0.0;
                for _ in 0..cfg.eval_episodes {
                    let (ret, _) = crate::envs::rollout(env.as_mut(), &mut rng,
                                                        |o, a| mlp.forward(o, a));
                    total += ret;
                }
                rets[agent] = total / cfg.eval_episodes as f64;
            }
            Ok(())
        })?;
        // genome of each agent AFTER training (trained half moved)
        for (i, g) in genomes.iter_mut().enumerate() {
            *g = artifact.agent_vector(&host, &["policy"], i);
        }
        let ranked = argsort_desc(&rets);
        let n_elite = ((pop as f64 * cem.elite_frac).round() as usize).clamp(1, pop);
        let elites: Vec<&[f32]> = ranked[..n_elite]
            .iter()
            .map(|&i| genomes[i].as_slice())
            .collect();
        cem.update(&elites);

        // evaluate the distribution mean (the CEM-RL reporting convention)
        mu_ret = {
            let mut host_mu = host.clone();
            artifact.set_agent_vector(&mut host_mu, &["policy"], 0, &cem.mu);
            let mut mlp = mlp_from_state(&artifact, &host_mu, "policy", 0,
                                         Activation::Relu, Activation::Tanh)?;
            let (ret, _) = crate::envs::rollout(env.as_mut(), &mut rng,
                                                |o, a| mlp.forward(o, a));
            ret
        };
        best = best.max(rets.iter().copied().fold(f64::NEG_INFINITY, f64::max));
        mean_ret = mean(&rets);
        if let Some(csv) = csv.as_mut() {
            csv.row(&[
                start.elapsed().as_secs_f64(),
                iter as f64,
                env_steps as f64,
                updates as f64,
                best,
                mean_ret,
                mu_ret,
            ])?;
            csv.flush()?;
        }
    }

    Ok(CemRlSummary {
        best_return: best,
        mean_return: mean_ret,
        mu_return: mu_ret,
        wall_seconds: start.elapsed().as_secs_f64(),
        env_steps,
        updates,
        timers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cem_converges_on_sphere() {
        // maximize -||x - target||^2 by CEM alone
        let target = [1.0f32, -2.0, 0.5];
        let mut cem = Cem::new(vec![0.0; 3], 1.0, 0.5);
        cem.noise = 1e-4;
        let mut rng = Rng::new(0);
        let popn = 32;
        let mut samples = vec![vec![0.0f32; 3]; popn];
        for _ in 0..60 {
            let mut scores = vec![0.0f64; popn];
            for (i, s) in samples.iter_mut().enumerate() {
                cem.sample_into(&mut rng, s);
                scores[i] = -s
                    .iter()
                    .zip(&target)
                    .map(|(a, b)| ((a - b) * (a - b)) as f64)
                    .sum::<f64>();
            }
            let ranked = argsort_desc(&scores);
            let elites: Vec<&[f32]> =
                ranked[..16].iter().map(|&i| samples[i].as_slice()).collect();
            cem.update(&elites);
        }
        for (m, t) in cem.mu.iter().zip(&target) {
            assert!((m - t).abs() < 0.15, "mu={:?}", cem.mu);
        }
    }

    #[test]
    fn cem_noise_decays_to_floor() {
        let mut cem = Cem::new(vec![0.0; 2], 0.1, 0.5);
        cem.noise = 1e-2;
        cem.noise_decay = 0.5;
        cem.noise_floor = 1e-3;
        let e = [0.0f32, 0.0];
        for _ in 0..20 {
            cem.update(&[&e]);
        }
        assert!((cem.noise - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn variance_reflects_elite_spread() {
        let mut cem = Cem::new(vec![0.0; 1], 1.0, 0.5);
        cem.noise = 0.0;
        let a = [2.0f32];
        let b = [4.0f32];
        cem.update(&[&a, &b]);
        assert!((cem.mu[0] - 3.0).abs() < 1e-6);
        assert!((cem.var[0] - 1.0).abs() < 1e-6); // var of {2,4} around 3
    }
}
