//! Evaluation protocol + aggregate metrics.
//!
//! The paper (citing Agarwal et al., 2021, "Deep RL at the edge of the
//! statistical precipice") argues population runs double as many-seed
//! benchmarking. This module implements that reporting style: periodic
//! deterministic evaluation episodes, and the rliable-recommended
//! aggregates — interquartile mean (IQM) and stratified-bootstrap
//! confidence intervals — over a population's returns.

use crate::envs::{make_env, rollout};
use crate::manifest::Artifact;
use crate::nn::from_state::{mlp_from_state, policy_activations};
use crate::util::rng::Rng;
use crate::util::stats::percentile;

/// Deterministic evaluation of every population member: `episodes`
/// rollouts each with the mean/greedy policy. Returns per-agent means.
pub fn evaluate_population(
    artifact: &Artifact,
    host_state: &[f32],
    env_name: &str,
    episodes: usize,
    rng: &mut Rng,
) -> anyhow::Result<Vec<f64>> {
    let (ha, fa) = policy_activations(&artifact.algo);
    let sac = artifact.algo.starts_with("sac");
    let mut env = make_env(env_name)?;
    let mut out = Vec::with_capacity(artifact.pop);
    for agent in 0..artifact.pop {
        let mut mlp = mlp_from_state(artifact, host_state, "policy", agent, ha, fa)?;
        let act_dim = env.act_dim();
        let mut total = 0.0;
        for _ in 0..episodes.max(1) {
            let (ret, _) = rollout(env.as_mut(), rng, |obs, act| {
                if sac {
                    // gaussian head: deterministic mean action = tanh(mu)
                    let mut raw = vec![0.0f32; 2 * act_dim];
                    mlp.forward(obs, &mut raw);
                    for (a, &m) in act.iter_mut().zip(&raw[..act_dim]) {
                        *a = m.tanh();
                    }
                } else {
                    mlp.forward(obs, act);
                }
            });
            total += ret;
        }
        out.push(total / episodes.max(1) as f64);
    }
    Ok(out)
}

/// Interquartile mean: the mean of the middle 50% of the sample — robust
/// to stragglers and lucky seeds (rliable's headline aggregate).
pub fn iqm(values: &[f64]) -> f64 {
    assert!(!values.is_empty());
    let mut v: Vec<f64> = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q1 = percentile(&v, 25.0);
    let q3 = percentile(&v, 75.0);
    let mid: Vec<f64> = v.iter().copied().filter(|&x| x >= q1 && x <= q3).collect();
    if mid.is_empty() {
        crate::util::stats::mean(&v)
    } else {
        crate::util::stats::mean(&mid)
    }
}

/// Percentile-bootstrap confidence interval of an aggregate statistic.
pub fn bootstrap_ci(
    values: &[f64],
    stat: impl Fn(&[f64]) -> f64,
    resamples: usize,
    alpha: f64,
    rng: &mut Rng,
) -> (f64, f64) {
    assert!(!values.is_empty());
    let mut stats = Vec::with_capacity(resamples);
    let mut sample = vec![0.0; values.len()];
    for _ in 0..resamples {
        for s in sample.iter_mut() {
            *s = values[rng.below(values.len())];
        }
        stats.push(stat(&sample));
    }
    stats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (
        percentile(&stats, 100.0 * alpha / 2.0),
        percentile(&stats, 100.0 * (1.0 - alpha / 2.0)),
    )
}

/// One-line population report: `IQM [lo, hi] (best b, mean m, n=k)`.
pub fn population_report(returns: &[f64], rng: &mut Rng) -> String {
    let finite: Vec<f64> = returns.iter().copied().filter(|v| v.is_finite()).collect();
    if finite.is_empty() {
        return "no finished episodes yet".into();
    }
    let iqm_v = iqm(&finite);
    let (lo, hi) = bootstrap_ci(&finite, iqm, 500, 0.05, rng);
    let best = finite.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    format!(
        "IQM {:.1} [{:.1}, {:.1}] (best {:.1}, mean {:.1}, n={})",
        iqm_v,
        lo,
        hi,
        best,
        crate::util::stats::mean(&finite),
        finite.len()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iqm_discards_tails() {
        // one huge outlier must not move the IQM much
        let clean = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let outlier = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 1e9];
        assert!((iqm(&clean) - 4.5).abs() < 0.6);
        assert!(iqm(&outlier) < 100.0);
    }

    #[test]
    fn iqm_of_constant_is_constant() {
        assert_eq!(iqm(&[3.0; 10]), 3.0);
        assert_eq!(iqm(&[7.0]), 7.0);
    }

    #[test]
    fn bootstrap_ci_brackets_the_statistic() {
        let mut rng = Rng::new(0);
        let values: Vec<f64> = (0..50).map(|_| rng.normal() * 2.0 + 10.0).collect();
        let point = iqm(&values);
        let (lo, hi) = bootstrap_ci(&values, iqm, 1000, 0.05, &mut rng);
        assert!(lo <= point && point <= hi, "{lo} <= {point} <= {hi}");
        assert!(hi - lo < 3.0, "CI too wide: [{lo}, {hi}]");
    }

    #[test]
    fn ci_narrows_with_sample_size() {
        let mut rng = Rng::new(1);
        let small: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        let large: Vec<f64> = (0..256).map(|_| rng.normal()).collect();
        let (lo_s, hi_s) = bootstrap_ci(&small, iqm, 500, 0.05, &mut rng);
        let (lo_l, hi_l) = bootstrap_ci(&large, iqm, 500, 0.05, &mut rng);
        assert!(hi_l - lo_l < hi_s - lo_s);
    }

    #[test]
    fn report_handles_empty_and_infinite() {
        let mut rng = Rng::new(2);
        assert!(population_report(&[f64::NEG_INFINITY], &mut rng)
            .contains("no finished"));
        let r = population_report(&[1.0, 2.0, f64::NEG_INFINITY, 3.0], &mut rng);
        assert!(r.contains("n=3"), "{r}");
    }
}
