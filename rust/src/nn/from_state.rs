//! Build native inference networks from a host copy of the flat train
//! state, using the manifest layout — the bridge between the learner's
//! device state and the actors' fast path.

use crate::manifest::Artifact;
use crate::nn::conv::ConvNet;
use crate::nn::mlp::{Activation, Mlp};
use crate::nn::pop_conv::PopConvNet;
use crate::nn::pop_mlp::PopMlp;

/// Extract agent `agent`'s MLP with the given field prefix
/// (e.g. "policy"). Layer fields are `{prefix}/w{i}` / `{prefix}/b{i}`
/// with shapes `[P, in, out]` / `[P, out]`.
pub fn mlp_from_state(
    artifact: &Artifact,
    state: &[f32],
    prefix: &str,
    agent: usize,
    hidden_act: Activation,
    final_act: Activation,
) -> anyhow::Result<Mlp> {
    let mut mlp = Mlp::new(hidden_act, final_act);
    for li in 0.. {
        let wname = format!("{prefix}/w{li}");
        if artifact.field(&wname).is_err() {
            break;
        }
        let wf = artifact.field(&wname)?;
        anyhow::ensure!(wf.shape.len() == 3, "{wname}: expected [P, in, out]");
        let (in_dim, out_dim) = (wf.shape[1], wf.shape[2]);
        let w = artifact.read_agent(state, &wname, agent)?;
        let b = artifact.read_agent(state, &format!("{prefix}/b{li}"), agent)?;
        mlp.push_layer(w.to_vec(), b.to_vec(), in_dim, out_dim);
    }
    anyhow::ensure!(mlp.num_layers() > 0, "no layers found for prefix {prefix:?}");
    Ok(mlp)
}

/// Build the WHOLE population's network in packed `[P, in, out]` form with
/// the given field prefix — one contiguous read per manifest field (the
/// fields are already stored member-major, so no per-agent strided copies).
/// Refresh it later with [`PopMlp::sync_from_state`].
pub fn pop_mlp_from_state(
    artifact: &Artifact,
    state: &[f32],
    prefix: &str,
    hidden_act: Activation,
    final_act: Activation,
) -> anyhow::Result<PopMlp> {
    let mut net = PopMlp::new(artifact.pop, hidden_act, final_act);
    for li in 0.. {
        let wname = format!("{prefix}/w{li}");
        if artifact.field(&wname).is_err() {
            break;
        }
        let wf = artifact.field(&wname)?;
        anyhow::ensure!(wf.shape.len() == 3, "{wname}: expected [P, in, out]");
        anyhow::ensure!(
            wf.shape[0] == artifact.pop,
            "{wname}: leading axis {} != pop {}",
            wf.shape[0],
            artifact.pop
        );
        let (in_dim, out_dim) = (wf.shape[1], wf.shape[2]);
        let w = artifact.read(state, &wname)?;
        let b = artifact.read(state, &format!("{prefix}/b{li}"))?;
        net.push_layer(w.to_vec(), b.to_vec(), in_dim, out_dim);
    }
    anyhow::ensure!(net.num_layers() > 0, "no layers found for prefix {prefix:?}");
    Ok(net)
}

/// Refresh an existing MLP's weights in place (no allocation).
pub fn sync_mlp_from_state(
    artifact: &Artifact,
    state: &[f32],
    prefix: &str,
    agent: usize,
    mlp: &mut Mlp,
) -> anyhow::Result<()> {
    for li in 0..mlp.num_layers() {
        let w = artifact.read_agent(state, &format!("{prefix}/w{li}"), agent)?;
        let b = artifact.read_agent(state, &format!("{prefix}/b{li}"), agent)?;
        mlp.set_layer(li, w, b);
    }
    Ok(())
}

/// Extract agent `agent`'s DQN conv net (fields `{prefix}/conv/*` +
/// `{prefix}/head/*`), for frame `[h, w, c]`.
pub fn convnet_from_state(
    artifact: &Artifact,
    state: &[f32],
    prefix: &str,
    agent: usize,
    frame: (usize, usize, usize),
) -> anyhow::Result<ConvNet> {
    let (h, wd, c) = frame;
    let wf = artifact.field(&format!("{prefix}/conv/w"))?;
    anyhow::ensure!(wf.shape.len() == 5, "conv filter must be [P,kh,kw,C,F]");
    let (kh, kw, in_ch, feats) = (wf.shape[1], wf.shape[2], wf.shape[3], wf.shape[4]);
    anyhow::ensure!(in_ch == c, "conv in_ch {in_ch} != frame channels {c}");
    let w = artifact
        .read_agent(state, &format!("{prefix}/conv/w"), agent)?
        .to_vec();
    let b = artifact
        .read_agent(state, &format!("{prefix}/conv/b"), agent)?
        .to_vec();
    let head = mlp_from_state(artifact, state, &format!("{prefix}/head"), agent,
                              Activation::Relu, Activation::None)?;
    Ok(ConvNet::new(w, b, kh, kw, in_ch, feats, h, wd, head))
}

/// Metadata-only validation of a packed conv filter field
/// `{prefix}/conv/w` against a frame `[h, w, c]`; returns
/// `(kh, kw, features)`. This is THE layout invariant for conv nets —
/// shared by [`pop_convnet_from_state`] and the pipeline's spawn-time
/// validation so the check lives exactly once.
pub fn conv_field_dims(
    artifact: &Artifact,
    prefix: &str,
    frame: (usize, usize, usize),
) -> anyhow::Result<(usize, usize, usize)> {
    let (h, wd, c) = frame;
    let name = format!("{prefix}/conv/w");
    let wf = artifact.field(&name)?;
    anyhow::ensure!(wf.shape.len() == 5, "{name}: conv filter must be [P,kh,kw,C,F]");
    anyhow::ensure!(
        wf.shape[0] == artifact.pop,
        "{name}: leading axis {} != pop {}",
        wf.shape[0],
        artifact.pop
    );
    let (kh, kw, in_ch, feats) = (wf.shape[1], wf.shape[2], wf.shape[3], wf.shape[4]);
    anyhow::ensure!(in_ch == c, "{name}: conv in_ch {in_ch} != frame channels {c}");
    anyhow::ensure!(
        kh <= h && kw <= wd,
        "{name}: kernel {kh}x{kw} larger than frame {h}x{wd}"
    );
    Ok((kh, kw, feats))
}

/// Build the WHOLE population's DQN conv net in packed form (fields
/// `{prefix}/conv/*` with filters `[P, kh, kw, C, F]` plus the packed
/// `{prefix}/head/*` MLP), for frame `[h, w, c]` — one contiguous read
/// per manifest field, no per-agent strided copies. Refresh it later with
/// [`PopConvNet::sync_from_state`].
pub fn pop_convnet_from_state(
    artifact: &Artifact,
    state: &[f32],
    prefix: &str,
    frame: (usize, usize, usize),
) -> anyhow::Result<PopConvNet> {
    let (h, wd, c) = frame;
    let (kh, kw, feats) = conv_field_dims(artifact, prefix, frame)?;
    let w = artifact.read(state, &format!("{prefix}/conv/w"))?.to_vec();
    let b = artifact.read(state, &format!("{prefix}/conv/b"))?.to_vec();
    let head = pop_mlp_from_state(artifact, state, &format!("{prefix}/head"),
                                  Activation::Relu, Activation::None)?;
    let flat = (h - kh + 1) * (wd - kw + 1) * feats;
    anyhow::ensure!(
        head.in_dim() == flat,
        "{prefix}/head input dim {} != conv output dim {flat} (frame {h}x{wd}x{c})",
        head.in_dim()
    );
    Ok(PopConvNet::new(artifact.pop, w, b, kh, kw, c, feats, h, wd, head))
}

/// The deterministic-policy activation pair per algorithm.
pub fn policy_activations(algo: &str) -> (Activation, Activation) {
    match algo {
        // SAC's gaussian head outputs (mu, log_std) with no final
        // activation — tanh is applied to mu after slicing.
        "sac" => (Activation::Relu, Activation::None),
        _ => (Activation::Relu, Activation::Tanh),
    }
}
