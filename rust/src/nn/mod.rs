//! Native inference for the actor fast path.
//!
//! Actors step environments thousands of times per parameter sync; going
//! through PJRT for every single-observation forward pass would waste the
//! dispatch overhead the paper's design works to amortize. Instead the
//! coordinator extracts policy weights from the flat train state (via the
//! manifest) and runs a native Rust forward pass whose numerics are tested
//! against the AOT-lowered `*fwd` artifacts (see `rust/tests/`).
//!
//! The population-batched nets are the primary actor-side networks:
//! [`PopMlp`] keeps all P members' MLP weights packed `[P, in, out]` and
//! [`PopConvNet`] keeps all P conv filters packed `[P, kh, kw, C, F]`
//! (both exactly the manifest layout, so a parameter sync is one
//! contiguous copy per field), and each forwards a whole `[n, ...]`
//! observation/frame block in one call. The scalar [`Mlp`] and
//! [`ConvNet`] are their one-member special cases.
//!
//! # Kernel layer
//!
//! Every forward bottoms out in [`kernels`], the SIMD-friendly compute
//! layer both actor paths share:
//!
//! - **Tile shape.** [`kernels::matmat_tiled`] processes fixed
//!   4-row × 8-lane output tiles ([`kernels::TILE_ROWS`] ×
//!   [`kernels::TILE_LANES`]) with unrolled stack accumulators so rustc
//!   autovectorizes the FMA chain to AVX2/NEON; const-generic row bands
//!   and a masked edge kernel cover dims not divisible by the tile.
//! - **Dispatch heuristics.** [`kernels::matvec`] counts zero input
//!   lanes and takes the skip kernel only above
//!   [`kernels::MATVEC_SPARSE_THRESHOLD`] (25%); block-level dispatch
//!   ([`kernels::matmat`], [`kernels::conv_block_choice`]) requires ≥75%
//!   zeros before abandoning the 8-wide dense FMA for scalar skipping.
//!   Conv blocks additionally need `f ≥ 8` and `ho*wo ≥ 4` to pick the
//!   im2col path ([`kernels::conv2d_im2col_relu`]).
//! - **Layout contract.** MLP weights are `[in, out]` row-major (jax
//!   convention) so output lanes are contiguous per input; conv filters
//!   are HWIO `[kh, kw, in_ch, f]`, which *is* the `[kh*kw*in_ch, f]`
//!   im2col weight matrix — no reshuffle needed. im2col gathers each
//!   frame into `[ho*wo, kh*kw*in_ch]` patch rows (kh contiguous copies
//!   of `kw*in_ch` floats each, thanks to HWC adjacency).
//!
//! Kernel selection is overridable per net ([`PopMlp::set_kernel`],
//! [`PopConvNet::set_kernel`]) or process-wide via the `kernels.matmat`
//! / `kernels.conv` config keys ([`kernels::configure`]) for A/B runs;
//! every variant is numerically parity (≤1e-5) by the proptest suite.

pub mod conv;
pub mod from_state;
pub mod kernels;
pub mod mlp;
pub mod pop_conv;
pub mod pop_mlp;

pub use conv::ConvNet;
pub use kernels::{ConvKernel, MatKernel};
pub use mlp::{Activation, Mlp};
pub use pop_conv::PopConvNet;
pub use pop_mlp::PopMlp;
