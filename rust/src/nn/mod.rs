//! Native inference for the actor fast path.
//!
//! Actors step environments thousands of times per parameter sync; going
//! through PJRT for every single-observation forward pass would waste the
//! dispatch overhead the paper's design works to amortize. Instead the
//! coordinator extracts policy weights from the flat train state (via the
//! manifest) and runs a native Rust forward pass whose numerics are tested
//! against the AOT-lowered `*fwd` artifacts (see `rust/tests/`).
//!
//! The population-batched [`PopMlp`] is the primary actor-side network:
//! it keeps all P members' weights packed `[P, in, out]` (the manifest
//! layout) and forwards a whole `[n_agents, obs_dim]` observation block in
//! one call. The scalar [`Mlp`] is its one-member special case.

pub mod conv;
pub mod from_state;
pub mod mlp;
pub mod pop_mlp;

pub use conv::ConvNet;
pub use mlp::{Activation, Mlp};
pub use pop_mlp::PopMlp;
