//! Native inference for the actor fast path.
//!
//! Actors step environments thousands of times per parameter sync; going
//! through PJRT for every single-observation forward pass would waste the
//! dispatch overhead the paper's design works to amortize. Instead the
//! coordinator extracts policy weights from the flat train state (via the
//! manifest) and runs a native Rust forward pass whose numerics are tested
//! against the AOT-lowered `*fwd` artifacts (see `rust/tests/`).

pub mod conv;
pub mod from_state;
pub mod mlp;

pub use conv::ConvNet;
pub use mlp::{Activation, Mlp};
