//! Native inference for the actor fast path.
//!
//! Actors step environments thousands of times per parameter sync; going
//! through PJRT for every single-observation forward pass would waste the
//! dispatch overhead the paper's design works to amortize. Instead the
//! coordinator extracts policy weights from the flat train state (via the
//! manifest) and runs a native Rust forward pass whose numerics are tested
//! against the AOT-lowered `*fwd` artifacts (see `rust/tests/`).
//!
//! The population-batched nets are the primary actor-side networks:
//! [`PopMlp`] keeps all P members' MLP weights packed `[P, in, out]` and
//! [`PopConvNet`] keeps all P conv filters packed `[P, kh, kw, C, F]`
//! (both exactly the manifest layout, so a parameter sync is one
//! contiguous copy per field), and each forwards a whole `[n, ...]`
//! observation/frame block in one call. The scalar [`Mlp`] and
//! [`ConvNet`] are their one-member special cases.

pub mod conv;
pub mod from_state;
pub mod mlp;
pub mod pop_conv;
pub mod pop_mlp;

pub use conv::ConvNet;
pub use mlp::{Activation, Mlp};
pub use pop_conv::PopConvNet;
pub use pop_mlp::PopMlp;
