//! The kernel layer: tiled, SIMD-friendly compute kernels for the native
//! actor hot path.
//!
//! Every forward pass in `nn` bottoms out here. The layer owns
//!
//! * the **matvec** kernels ([`matvec_dense`], [`matvec_sparse`]) and the
//!   zero-counting dispatcher [`matvec`],
//! * the **mat-mat** kernels — the scalar [`matmat_reference`] row loop
//!   and the register-tiled [`matmat_tiled`] — plus the block dispatcher
//!   [`matmat`] / [`matmat_with`],
//! * the **conv** kernels — the direct sparsity-skipping
//!   [`conv2d_valid_relu`] and the [`im2col_gather`] +
//!   [`conv2d_im2col_relu`] path that reduces a VALID conv to ONE tiled
//!   mat-mat — plus the per-block chooser [`conv_block_choice`],
//! * the process-wide kernel selection ([`set_mat_kernel`] /
//!   [`set_conv_kernel`], config keys `kernels.matmat` / `kernels.conv`
//!   via [`configure`]) used for A/Bs; nets also carry a per-instance
//!   override that beats the global.
//!
//! # Tile shape
//!
//! [`matmat_tiled`] processes fixed [`TILE_ROWS`]`x`[`TILE_LANES`]
//! (4 rows x 8 output lanes) register tiles: 32 local accumulators in a
//! `[[f32; 8]; 4]` array, with the 8-lane inner loop over a stack copy of
//! the weight row so rustc unrolls the FMA chain into one 256-bit
//! AVX2/NEON vector op per row per k. Remainder rows (<4) go through the
//! same const-generic micro-kernel at RN ∈ {1,2,3}; remainder lanes (<8)
//! through a masked edge kernel, so no dimension restriction exists —
//! parity with the reference kernel is pinned for every dim in
//! `rust/tests/proptests.rs`.
//!
//! # Layout contract
//!
//! Weights are `[in, out]` row-major (the jax convention the manifest
//! serializes), so for a fixed input index `k` the `out` lanes
//! `w[k*out + o..]` are contiguous — exactly what the 8-lane tile loads.
//! Conv filters are `[kh, kw, in_ch, f]` row-major (HWIO), which *is*
//! `[kh*kw*in_ch, f]` row-major: the im2col patch matrix
//! `[ho*wo, kh*kw*in_ch]` multiplies the filter with no reshuffle.
//!
//! # Dispatch heuristics
//!
//! * [`matvec`] counts zero input lanes and routes to the skip kernel
//!   only at ≥ [`MATVEC_SPARSE_THRESHOLD`] (25%) zeros — the old any-zero
//!   prescan sent a 1-zero-in-256 input to the slow path.
//! * [`matmat`] in `Auto` routes blocks with ≥ [`MATMAT_SPARSE_THRESHOLD`]
//!   (75%) zeros to the per-row skip kernel (scalar skipping beats 8-wide
//!   dense FMA only when most lanes are dead); everything else is tiled.
//! * [`conv_block_choice`] in `Auto` picks the direct kernel for small
//!   outputs (`f <` [`TILE_LANES`] or `ho*wo <` [`TILE_ROWS`], where the
//!   tile never fills) or sparse blocks (≥ [`CONV_SPARSE_THRESHOLD`]
//!   zeros — MinAtar's mostly-empty binary planes), and im2col + tiled
//!   mat-mat for dense frames.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use crate::nn::mlp::Activation;
use crate::telemetry::Counter;

/// Rows per register tile (the `R` in the RxT micro-kernel).
pub const TILE_ROWS: usize = 4;
/// Output lanes per register tile (one 256-bit f32 vector).
pub const TILE_LANES: usize = 8;

/// [`matvec`] routes to the zero-skip kernel at this zero fraction. The
/// skip kernel trades one branch per lane for the skipped row: scalar vs
/// scalar, the trade measures out to roughly a quarter of lanes dead.
pub const MATVEC_SPARSE_THRESHOLD: f32 = 0.25;
/// [`matmat`]'s `Auto` dispatch abandons the tiled kernel for the per-row
/// skip kernel at this zero fraction: scalar skipping must beat 8-wide
/// dense FMA, which needs most lanes dead, not just a quarter.
pub const MATMAT_SPARSE_THRESHOLD: f32 = 0.75;
/// [`conv_block_choice`]'s `Auto` keeps the direct (sparsity-skipping)
/// conv kernel at this frame-block zero fraction; MinAtar planes usually
/// sit well above it.
pub const CONV_SPARSE_THRESHOLD: f32 = 0.75;

// ---------------------------------------------------------------------------
// dispatch telemetry
// ---------------------------------------------------------------------------

// Dispatch-outcome counters (`kernels.matmat.*` / `kernels.conv.*`):
// the handles are resolved once and cached in process statics, so a
// bump on the hot path is the cached-handle fast path — one relaxed
// load + branch when telemetry is off, one relaxed fetch-add when on.
static MAT_REFERENCE: OnceLock<Counter> = OnceLock::new();
static MAT_TILED: OnceLock<Counter> = OnceLock::new();
static MAT_SPARSE: OnceLock<Counter> = OnceLock::new();
static CONV_DIRECT: OnceLock<Counter> = OnceLock::new();
static CONV_IM2COL: OnceLock<Counter> = OnceLock::new();

fn bump(cell: &OnceLock<Counter>, name: &str) {
    cell.get_or_init(|| crate::telemetry::counter(name)).add(1);
}

// ---------------------------------------------------------------------------
// kernel selection
// ---------------------------------------------------------------------------

/// Mat-mat kernel selection (process-wide default; nets may override
/// per instance).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatKernel {
    /// Sparsity-counting dispatch: tiled for dense blocks, per-row skip
    /// kernel for mostly-zero blocks.
    Auto,
    /// The pre-tiling row loop over the adaptive [`matvec`].
    Reference,
    /// The register-tiled kernel, unconditionally.
    Tiled,
}

impl MatKernel {
    fn from_u8(v: u8) -> MatKernel {
        match v {
            1 => MatKernel::Reference,
            2 => MatKernel::Tiled,
            _ => MatKernel::Auto,
        }
    }

    fn to_u8(self) -> u8 {
        match self {
            MatKernel::Auto => 0,
            MatKernel::Reference => 1,
            MatKernel::Tiled => 2,
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<MatKernel> {
        match name {
            "auto" => Ok(MatKernel::Auto),
            "reference" | "ref" => Ok(MatKernel::Reference),
            "tiled" => Ok(MatKernel::Tiled),
            _ => anyhow::bail!("unknown matmat kernel {name:?} (auto | reference | tiled)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MatKernel::Auto => "auto",
            MatKernel::Reference => "reference",
            MatKernel::Tiled => "tiled",
        }
    }
}

/// Conv kernel selection (process-wide default; nets may override
/// per instance).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvKernel {
    /// Sparsity x size heuristic per frame block ([`conv_block_choice`]).
    Auto,
    /// The direct 6-loop kernel with zero-pixel skipping.
    Direct,
    /// Patch gather + one tiled mat-mat per frame.
    Im2col,
}

impl ConvKernel {
    fn from_u8(v: u8) -> ConvKernel {
        match v {
            1 => ConvKernel::Direct,
            2 => ConvKernel::Im2col,
            _ => ConvKernel::Auto,
        }
    }

    fn to_u8(self) -> u8 {
        match self {
            ConvKernel::Auto => 0,
            ConvKernel::Direct => 1,
            ConvKernel::Im2col => 2,
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<ConvKernel> {
        match name {
            "auto" => Ok(ConvKernel::Auto),
            "direct" => Ok(ConvKernel::Direct),
            "im2col" => Ok(ConvKernel::Im2col),
            _ => anyhow::bail!("unknown conv kernel {name:?} (auto | direct | im2col)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ConvKernel::Auto => "auto",
            ConvKernel::Direct => "direct",
            ConvKernel::Im2col => "im2col",
        }
    }
}

static MAT_KERNEL: AtomicU8 = AtomicU8::new(0);
static CONV_KERNEL: AtomicU8 = AtomicU8::new(0);

/// Process-wide mat-mat kernel selection (read on every forward; Relaxed
/// atomics, negligible cost).
pub fn mat_kernel() -> MatKernel {
    MatKernel::from_u8(MAT_KERNEL.load(Ordering::Relaxed))
}

pub fn set_mat_kernel(k: MatKernel) {
    MAT_KERNEL.store(k.to_u8(), Ordering::Relaxed);
}

/// Process-wide conv kernel selection.
pub fn conv_kernel() -> ConvKernel {
    ConvKernel::from_u8(CONV_KERNEL.load(Ordering::Relaxed))
}

pub fn set_conv_kernel(k: ConvKernel) {
    CONV_KERNEL.store(k.to_u8(), Ordering::Relaxed);
}

/// Apply config-file kernel overrides (the `kernels.matmat` /
/// `kernels.conv` keys) for A/B runs. `None` leaves a selection as is.
pub fn configure(matmat: Option<&str>, conv: Option<&str>) -> anyhow::Result<()> {
    if let Some(name) = matmat {
        set_mat_kernel(MatKernel::from_name(name)?);
    }
    if let Some(name) = conv {
        set_conv_kernel(ConvKernel::from_name(name)?);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// sparsity accounting
// ---------------------------------------------------------------------------

/// Number of exactly-zero lanes in `x`.
pub fn count_zeros(x: &[f32]) -> usize {
    x.iter().filter(|&&v| v == 0.0).count()
}

/// Fraction of exactly-zero lanes in `x` (0.0 for an empty slice).
pub fn zero_fraction(x: &[f32]) -> f32 {
    if x.is_empty() {
        0.0
    } else {
        count_zeros(x) as f32 / x.len() as f32
    }
}

/// The [`matvec`] routing decision: skip kernel iff at least
/// [`MATVEC_SPARSE_THRESHOLD`] of the first `in_dim` lanes are zero.
/// (The old `any(|v| v == 0.0)` prescan routed a 1-zero-in-256 input to
/// the slow skip kernel; counting fixes that.)
pub fn route_matvec_sparse(x: &[f32], in_dim: usize) -> bool {
    let n = in_dim.min(x.len());
    if n == 0 {
        return false;
    }
    count_zeros(&x[..n]) as f32 >= MATVEC_SPARSE_THRESHOLD * n as f32
}

// ---------------------------------------------------------------------------
// matvec kernels
// ---------------------------------------------------------------------------

/// `dst[o] = act(sum_i x[i] * w[i, o] + b[o])`, w row-major [in, out],
/// skipping all-zero input lanes. Iterating rows of `w` keeps the access
/// pattern sequential (cache-friendly for the [in, out] layout jax uses);
/// the zero skip wins when `x` is a post-relu hidden activation with a
/// substantial fraction of dead lanes.
#[inline]
pub fn matvec_sparse(w: &[f32], b: &[f32], x: &[f32], dst: &mut [f32], in_dim: usize,
                     out_dim: usize, act: Activation) {
    dst.copy_from_slice(b);
    for (i, &xi) in x.iter().enumerate().take(in_dim) {
        if xi == 0.0 {
            continue; // relu sparsity: skip dead rows
        }
        let row = &w[i * out_dim..(i + 1) * out_dim];
        for (d, &wv) in dst.iter_mut().zip(row) {
            *d += xi * wv;
        }
    }
    for d in dst.iter_mut() {
        *d = act.apply(*d);
    }
}

/// Same contract as [`matvec_sparse`] but branch-free: for fully-dense
/// inputs (normalized observations never hit exactly 0.0) the per-element
/// zero check is a mispredicted branch in the innermost loop for nothing.
#[inline]
pub fn matvec_dense(w: &[f32], b: &[f32], x: &[f32], dst: &mut [f32], in_dim: usize,
                    out_dim: usize, act: Activation) {
    dst.copy_from_slice(b);
    for (i, &xi) in x.iter().enumerate().take(in_dim) {
        let row = &w[i * out_dim..(i + 1) * out_dim];
        for (d, &wv) in dst.iter_mut().zip(row) {
            *d += xi * wv;
        }
    }
    for d in dst.iter_mut() {
        *d = act.apply(*d);
    }
}

/// Adaptive matvec: one O(in) zero count routes mostly-dense inputs to
/// the branch-free kernel and inputs past [`MATVEC_SPARSE_THRESHOLD`] to
/// the sparsity-skip kernel (the count is amortized by the O(in*out)
/// inner loop). See [`route_matvec_sparse`].
#[inline]
pub fn matvec(w: &[f32], b: &[f32], x: &[f32], dst: &mut [f32], in_dim: usize,
              out_dim: usize, act: Activation) {
    if route_matvec_sparse(x, in_dim) {
        matvec_sparse(w, b, x, dst, in_dim, out_dim, act);
    } else {
        matvec_dense(w, b, x, dst, in_dim, out_dim, act);
    }
}

// ---------------------------------------------------------------------------
// mat-mat kernels
// ---------------------------------------------------------------------------

/// The pre-tiling reference mat-mat: forward `rows` inputs `x: [rows, in]`
/// through ONE weight matrix into `dst: [rows, out]` as a row loop over
/// the adaptive [`matvec`]. Kept as the parity oracle and the scalar
/// fallback for very sparse blocks.
#[inline]
pub fn matmat_reference(w: &[f32], b: &[f32], x: &[f32], dst: &mut [f32], in_dim: usize,
                        out_dim: usize, rows: usize, act: Activation) {
    debug_assert_eq!(x.len(), rows * in_dim);
    debug_assert_eq!(dst.len(), rows * out_dim);
    for r in 0..rows {
        matvec(
            w,
            b,
            &x[r * in_dim..(r + 1) * in_dim],
            &mut dst[r * out_dim..(r + 1) * out_dim],
            in_dim,
            out_dim,
            act,
        );
    }
}

/// One `RN x TILE_LANES` register tile band: all full 8-lane tiles of
/// rows `r0..r0+RN`, then the lane remainder. `RN` is const so the row
/// loop unrolls; the lane loop over a stack copy of the weight row
/// autovectorizes to one FMA per row per k.
#[inline(always)]
fn tile_row_band<const RN: usize>(w: &[f32], b: &[f32], x: &[f32], dst: &mut [f32],
                                  in_dim: usize, out_dim: usize, r0: usize) {
    let mut o = 0;
    while o + TILE_LANES <= out_dim {
        let mut acc = [[0.0f32; TILE_LANES]; RN];
        for k in 0..in_dim {
            let wrow: [f32; TILE_LANES] =
                w[k * out_dim + o..k * out_dim + o + TILE_LANES].try_into().unwrap();
            for (ri, lanes) in acc.iter_mut().enumerate() {
                let xv = x[(r0 + ri) * in_dim + k];
                for (a, &wv) in lanes.iter_mut().zip(&wrow) {
                    *a += xv * wv;
                }
            }
        }
        for (ri, lanes) in acc.iter().enumerate() {
            let dr = &mut dst[(r0 + ri) * out_dim + o..(r0 + ri) * out_dim + o + TILE_LANES];
            for ((d, &a), &bv) in dr.iter_mut().zip(lanes).zip(&b[o..o + TILE_LANES]) {
                *d = a + bv;
            }
        }
        o += TILE_LANES;
    }
    if o < out_dim {
        tile_edge::<RN>(w, b, x, dst, in_dim, out_dim, r0, o);
    }
}

/// Lane-remainder tile: the trailing `out_dim - o0 < TILE_LANES` output
/// columns of rows `r0..r0+RN`. Same accumulator array, only the live
/// prefix of each lane row is touched.
#[inline(always)]
fn tile_edge<const RN: usize>(w: &[f32], b: &[f32], x: &[f32], dst: &mut [f32],
                              in_dim: usize, out_dim: usize, r0: usize, o0: usize) {
    let on = out_dim - o0;
    let mut acc = [[0.0f32; TILE_LANES]; RN];
    for k in 0..in_dim {
        let wrow = &w[k * out_dim + o0..k * out_dim + o0 + on];
        for (ri, lanes) in acc.iter_mut().enumerate() {
            let xv = x[(r0 + ri) * in_dim + k];
            for (a, &wv) in lanes[..on].iter_mut().zip(wrow) {
                *a += xv * wv;
            }
        }
    }
    for (ri, lanes) in acc.iter().enumerate() {
        let dr = &mut dst[(r0 + ri) * out_dim + o0..(r0 + ri) * out_dim + o0 + on];
        for ((d, &a), &bv) in dr.iter_mut().zip(&lanes[..on]).zip(&b[o0..o0 + on]) {
            *d = a + bv;
        }
    }
}

/// Register-tiled mat-mat: `dst[r, o] = act(x[r, :] @ w[:, o] + b[o])`
/// over [`TILE_ROWS`]`x`[`TILE_LANES`] output tiles with unrolled local
/// accumulators (see the module docs for the tile shape and layout
/// contract). Handles every `rows`/`out_dim`, including non-tile
/// multiples, via const-generic row remainders and a masked lane edge.
pub fn matmat_tiled(w: &[f32], b: &[f32], x: &[f32], dst: &mut [f32], in_dim: usize,
                    out_dim: usize, rows: usize, act: Activation) {
    debug_assert_eq!(w.len(), in_dim * out_dim);
    debug_assert_eq!(b.len(), out_dim);
    debug_assert_eq!(x.len(), rows * in_dim);
    debug_assert_eq!(dst.len(), rows * out_dim);
    let mut r = 0;
    while r + TILE_ROWS <= rows {
        tile_row_band::<TILE_ROWS>(w, b, x, dst, in_dim, out_dim, r);
        r += TILE_ROWS;
    }
    match rows - r {
        1 => tile_row_band::<1>(w, b, x, dst, in_dim, out_dim, r),
        2 => tile_row_band::<2>(w, b, x, dst, in_dim, out_dim, r),
        3 => tile_row_band::<3>(w, b, x, dst, in_dim, out_dim, r),
        _ => {}
    }
    if act != Activation::None {
        for d in dst.iter_mut() {
            *d = act.apply(*d);
        }
    }
}

/// Mat-mat with an explicit kernel choice (per-instance overrides and
/// benches go through here). `Auto` counts the block's zero lanes once:
/// past [`MATMAT_SPARSE_THRESHOLD`] the scalar skip kernel wins over
/// dense 8-wide FMA, anything denser is tiled.
#[inline]
pub fn matmat_with(kernel: MatKernel, w: &[f32], b: &[f32], x: &[f32], dst: &mut [f32],
                   in_dim: usize, out_dim: usize, rows: usize, act: Activation) {
    match kernel {
        MatKernel::Reference => {
            bump(&MAT_REFERENCE, "kernels.matmat.reference");
            matmat_reference(w, b, x, dst, in_dim, out_dim, rows, act);
        }
        MatKernel::Tiled => {
            bump(&MAT_TILED, "kernels.matmat.tiled");
            matmat_tiled(w, b, x, dst, in_dim, out_dim, rows, act);
        }
        MatKernel::Auto => {
            if zero_fraction(&x[..rows * in_dim]) >= MATMAT_SPARSE_THRESHOLD {
                bump(&MAT_SPARSE, "kernels.matmat.sparse");
                for r in 0..rows {
                    matvec_sparse(
                        w,
                        b,
                        &x[r * in_dim..(r + 1) * in_dim],
                        &mut dst[r * out_dim..(r + 1) * out_dim],
                        in_dim,
                        out_dim,
                        act,
                    );
                }
            } else {
                bump(&MAT_TILED, "kernels.matmat.tiled");
                matmat_tiled(w, b, x, dst, in_dim, out_dim, rows, act);
            }
        }
    }
}

/// Row-blocked mat-mat behind the process-wide kernel selection — the
/// default dispatch of
/// [`PopMlp::forward_block`](crate::nn::pop_mlp::PopMlp::forward_block)
/// per member run.
#[inline]
pub fn matmat(w: &[f32], b: &[f32], x: &[f32], dst: &mut [f32], in_dim: usize,
              out_dim: usize, rows: usize, act: Activation) {
    matmat_with(mat_kernel(), w, b, x, dst, in_dim, out_dim, rows, act);
}

// ---------------------------------------------------------------------------
// conv kernels
// ---------------------------------------------------------------------------

/// VALID conv + relu of ONE HWC frame against ONE HWIO filter:
/// `frame: [h, wd, in_ch]` flat, `w: [kh, kw, in_ch, f]` flat,
/// `out: [ho, wo, f]` flat. Zero input pixels are skipped (MinAtar-style
/// frames are sparse binary planes, so most lanes are dead) — this is
/// the direct kernel the sparsity heuristic keeps for mostly-empty
/// frames.
pub fn conv2d_valid_relu(
    w: &[f32],
    b: &[f32],
    frame: &[f32],
    out: &mut [f32],
    kh: usize,
    kw: usize,
    in_ch: usize,
    f: usize,
    h: usize,
    wd: usize,
) {
    let (ho, wo) = (h - kh + 1, wd - kw + 1);
    debug_assert_eq!(frame.len(), h * wd * in_ch);
    debug_assert_eq!(out.len(), ho * wo * f);
    for oy in 0..ho {
        for ox in 0..wo {
            let dst = &mut out[(oy * wo + ox) * f..(oy * wo + ox + 1) * f];
            dst.copy_from_slice(b);
            for ky in 0..kh {
                for kx in 0..kw {
                    let iy = oy + ky;
                    let ix = ox + kx;
                    let px = &frame[(iy * wd + ix) * in_ch..];
                    for c in 0..in_ch {
                        let xv = px[c];
                        if xv == 0.0 {
                            continue; // sparse binary frames: skip zeros
                        }
                        let wrow = &w[((ky * kw + kx) * in_ch + c) * f..];
                        for (d, &wv) in dst.iter_mut().zip(&wrow[..f]) {
                            *d += xv * wv;
                        }
                    }
                }
            }
            for d in dst.iter_mut() {
                *d = d.max(0.0);
            }
        }
    }
}

/// Gather ONE HWC frame's `[ho*wo, kh*kw*in_ch]` im2col patch matrix
/// into `scratch`. Each patch row is assembled from `kh` contiguous
/// `kw*in_ch` frame runs (HWC keeps a kernel row's pixels adjacent), so
/// the gather is `kh` memcpys per output pixel, not a scalar scatter.
pub fn im2col_gather(frame: &[f32], scratch: &mut [f32], kh: usize, kw: usize, in_ch: usize,
                     h: usize, wd: usize) {
    let (ho, wo) = (h - kh + 1, wd - kw + 1);
    let krow = kw * in_ch;
    let patch = kh * krow;
    debug_assert_eq!(frame.len(), h * wd * in_ch);
    debug_assert_eq!(scratch.len(), ho * wo * patch);
    for oy in 0..ho {
        for ox in 0..wo {
            let dst = &mut scratch[(oy * wo + ox) * patch..(oy * wo + ox + 1) * patch];
            for ky in 0..kh {
                let src = &frame[((oy + ky) * wd + ox) * in_ch..][..krow];
                dst[ky * krow..(ky + 1) * krow].copy_from_slice(src);
            }
        }
    }
}

/// VALID conv + relu via im2col: gather the frame's patch matrix into the
/// reusable `scratch`, then run ONE register-tiled mat-mat against the
/// filter — `[kh, kw, in_ch, f]` row-major IS the `[kh*kw*in_ch, f]`
/// weight matrix, so no filter reshuffle happens. Same contract as
/// [`conv2d_valid_relu`].
pub fn conv2d_im2col_relu(
    w: &[f32],
    b: &[f32],
    frame: &[f32],
    out: &mut [f32],
    scratch: &mut Vec<f32>,
    kh: usize,
    kw: usize,
    in_ch: usize,
    f: usize,
    h: usize,
    wd: usize,
) {
    let (ho, wo) = (h - kh + 1, wd - kw + 1);
    let patch = kh * kw * in_ch;
    debug_assert_eq!(frame.len(), h * wd * in_ch);
    debug_assert_eq!(out.len(), ho * wo * f);
    scratch.resize(ho * wo * patch, 0.0);
    im2col_gather(frame, scratch, kh, kw, in_ch, h, wd);
    matmat_tiled(w, b, scratch, out, patch, f, ho * wo, Activation::Relu);
}

/// Resolve a conv kernel request for one `[n, H*W*C]` frame block.
/// `Direct`/`Im2col` pass through; `Auto` applies the sparsity x size
/// heuristic: direct when the tile cannot fill (`f <` [`TILE_LANES`] or
/// `out_rows <` [`TILE_ROWS`]) or when the block is mostly zeros
/// (≥ [`CONV_SPARSE_THRESHOLD`]), im2col otherwise.
pub fn conv_block_choice(requested: ConvKernel, frames: &[f32], out_rows: usize,
                         f: usize) -> ConvKernel {
    let chosen = match requested {
        ConvKernel::Auto => {
            if f < TILE_LANES
                || out_rows < TILE_ROWS
                || zero_fraction(frames) >= CONV_SPARSE_THRESHOLD
            {
                ConvKernel::Direct
            } else {
                ConvKernel::Im2col
            }
        }
        k => k,
    };
    match chosen {
        ConvKernel::Direct => bump(&CONV_DIRECT, "kernels.conv.direct"),
        ConvKernel::Im2col => bump(&CONV_IM2COL, "kernels.conv.im2col"),
        ConvKernel::Auto => unreachable!("Auto always resolves"),
    }
    chosen
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn assert_close(got: &[f32], want: &[f32], tol: f32, ctx: &str) {
        assert_eq!(got.len(), want.len(), "{ctx}: length");
        for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
            assert!(
                (g - w).abs() <= tol * w.abs().max(1.0),
                "{ctx}: lane {i}: {g} vs {w}"
            );
        }
    }

    /// The satellite routing fix, pinned at the boundary: a single zero
    /// in a 256-lane input must stay on the dense kernel; the skip
    /// kernel engages only at >= 25% zeros.
    #[test]
    fn matvec_routing_boundary() {
        let mut dense = vec![1.0f32; 256];
        dense[17] = 0.0; // the old any-zero prescan sent this to the slow path
        assert!(!route_matvec_sparse(&dense, 256));

        let mut x = vec![1.0f32; 64];
        for v in x.iter_mut().take(15) {
            *v = 0.0;
        }
        assert!(!route_matvec_sparse(&x, 64), "15/64 = 23.4% must stay dense");
        x[15] = 0.0;
        assert!(route_matvec_sparse(&x, 64), "16/64 = 25% must route sparse");

        assert!(!route_matvec_sparse(&[1.0, 2.0, 3.0], 3));
        assert!(route_matvec_sparse(&[0.0; 8], 8));
        assert!(!route_matvec_sparse(&[], 0));
    }

    #[test]
    fn zero_accounting() {
        assert_eq!(count_zeros(&[0.0, 1.0, 0.0, -0.0]), 3); // -0.0 == 0.0
        assert_eq!(zero_fraction(&[]), 0.0);
        assert!((zero_fraction(&[0.0, 1.0]) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn tiled_matches_reference_hand_case() {
        // 2 inputs, 3 outputs, 2 rows: small enough to hand-check and
        // exercises both remainder paths (rows < 4, lanes < 8).
        let w = vec![1.0, 0.0, -1.0, 0.0, 2.0, 1.0]; // [2, 3]
        let b = vec![0.0, -1.0, 0.5];
        let x = vec![1.0, 2.0, -1.0, 0.0];
        let mut want = vec![0.0f32; 6];
        let mut got = vec![0.0f32; 6];
        matmat_reference(&w, &b, &x, &mut want, 2, 3, 2, Activation::Relu);
        matmat_tiled(&w, &b, &x, &mut got, 2, 3, 2, Activation::Relu);
        assert_close(&got, &want, 1e-6, "hand case");
        // row 0: [1*1+2*0, 1*0+2*2-1, -1+2+0.5] = [1, 3, 1.5]
        assert_eq!(&got[..3], &[1.0, 3.0, 1.5]);
    }

    #[test]
    fn tiled_matches_reference_tile_multiples_and_edges() {
        let mut rng = Rng::new(41);
        // dims straddling the 4x8 tile: exact multiples, remainders, tiny
        for &(i, o, rows) in &[
            (8usize, 8usize, 4usize),
            (16, 8, 8),
            (5, 8, 4),
            (8, 11, 5),
            (1, 1, 1),
            (3, 7, 2),
            (67, 33, 13),
            (256, 256, 4),
        ] {
            let mut w = vec![0.0f32; i * o];
            let mut b = vec![0.0f32; o];
            let mut x = vec![0.0f32; rows * i];
            rng.fill_normal(&mut w, 0.5);
            rng.fill_normal(&mut b, 0.5);
            rng.fill_normal(&mut x, 1.0);
            for act in [Activation::None, Activation::Relu, Activation::Tanh] {
                let mut want = vec![0.0f32; rows * o];
                let mut got = vec![0.0f32; rows * o];
                matmat_reference(&w, &b, &x, &mut want, i, o, rows, act);
                matmat_tiled(&w, &b, &x, &mut got, i, o, rows, act);
                assert_close(&got, &want, 1e-5, &format!("i{i} o{o} rows{rows} {act:?}"));
            }
        }
    }

    #[test]
    fn matmat_with_auto_routes_sparse_blocks_to_skip_kernel() {
        let mut rng = Rng::new(42);
        let (i, o, rows) = (32usize, 16usize, 6usize);
        let mut w = vec![0.0f32; i * o];
        let mut b = vec![0.0f32; o];
        rng.fill_normal(&mut w, 0.5);
        rng.fill_normal(&mut b, 0.5);
        // 90% zeros: Auto must still be parity with the dense kernels
        let mut x = vec![0.0f32; rows * i];
        for v in x.iter_mut() {
            if rng.below(10) == 0 {
                *v = rng.normal() as f32;
            }
        }
        let mut want = vec![0.0f32; rows * o];
        let mut got = vec![0.0f32; rows * o];
        matmat_reference(&w, &b, &x, &mut want, i, o, rows, Activation::Relu);
        matmat_with(MatKernel::Auto, &w, &b, &x, &mut got, i, o, rows, Activation::Relu);
        assert_close(&got, &want, 1e-5, "sparse auto");
    }

    #[test]
    fn im2col_gather_lays_out_patches() {
        // 3x3 single-channel frame, 2x2 kernel: 4 patches of 4.
        #[rustfmt::skip]
        let frame = vec![
            1.0, 2.0, 3.0,
            4.0, 5.0, 6.0,
            7.0, 8.0, 9.0,
        ];
        let mut col = vec![0.0f32; 4 * 4];
        im2col_gather(&frame, &mut col, 2, 2, 1, 3, 3);
        assert_eq!(&col[0..4], &[1.0, 2.0, 4.0, 5.0]); // patch (0,0)
        assert_eq!(&col[4..8], &[2.0, 3.0, 5.0, 6.0]); // patch (0,1)
        assert_eq!(&col[8..12], &[4.0, 5.0, 7.0, 8.0]); // patch (1,0)
        assert_eq!(&col[12..16], &[5.0, 6.0, 8.0, 9.0]); // patch (1,1)
    }

    #[test]
    fn im2col_conv_matches_direct() {
        let mut rng = Rng::new(43);
        for &(h, wd, c, k, f) in &[
            (10usize, 10usize, 4usize, 3usize, 16usize),
            (6, 5, 2, 3, 4),
            (5, 5, 1, 2, 9),
            (4, 4, 3, 1, 8),
        ] {
            let mut w = vec![0.0f32; k * k * c * f];
            let mut b = vec![0.0f32; f];
            rng.fill_normal(&mut w, 0.4);
            rng.fill_normal(&mut b, 0.2);
            // half binary-sparse, half dense lanes
            let mut frame = vec![0.0f32; h * wd * c];
            for (i, v) in frame.iter_mut().enumerate() {
                *v = if i % 2 == 0 {
                    (rng.below(4) == 0) as u8 as f32
                } else {
                    rng.normal() as f32
                };
            }
            let (ho, wo) = (h - k + 1, wd - k + 1);
            let mut want = vec![0.0f32; ho * wo * f];
            let mut got = vec![0.0f32; ho * wo * f];
            let mut scratch = Vec::new();
            conv2d_valid_relu(&w, &b, &frame, &mut want, k, k, c, f, h, wd);
            conv2d_im2col_relu(&w, &b, &frame, &mut got, &mut scratch, k, k, c, f, h, wd);
            assert_close(&got, &want, 1e-5, &format!("{h}x{wd}x{c} k{k} f{f}"));
            assert_eq!(scratch.len(), ho * wo * k * k * c);
        }
    }

    #[test]
    fn conv_block_choice_heuristic() {
        let dense = vec![1.0f32; 400];
        let sparse = {
            let mut v = vec![0.0f32; 400];
            for x in v.iter_mut().take(40) {
                *x = 1.0;
            }
            v
        };
        // explicit requests pass through untouched
        assert_eq!(conv_block_choice(ConvKernel::Direct, &dense, 64, 16), ConvKernel::Direct);
        assert_eq!(conv_block_choice(ConvKernel::Im2col, &sparse, 64, 16), ConvKernel::Im2col);
        // auto: dense + big enough -> im2col
        assert_eq!(conv_block_choice(ConvKernel::Auto, &dense, 64, 16), ConvKernel::Im2col);
        // auto: mostly-zero MinAtar-style block -> direct
        assert_eq!(conv_block_choice(ConvKernel::Auto, &sparse, 64, 16), ConvKernel::Direct);
        // auto: too few lanes or rows for the tile -> direct
        assert_eq!(conv_block_choice(ConvKernel::Auto, &dense, 64, 4), ConvKernel::Direct);
        assert_eq!(conv_block_choice(ConvKernel::Auto, &dense, 2, 16), ConvKernel::Direct);
    }

    #[test]
    fn kernel_names_roundtrip_and_reject_unknown() {
        for k in [MatKernel::Auto, MatKernel::Reference, MatKernel::Tiled] {
            assert_eq!(MatKernel::from_name(k.name()).unwrap(), k);
        }
        for k in [ConvKernel::Auto, ConvKernel::Direct, ConvKernel::Im2col] {
            assert_eq!(ConvKernel::from_name(k.name()).unwrap(), k);
        }
        assert!(MatKernel::from_name("fast").is_err());
        assert!(ConvKernel::from_name("winograd").is_err());
        assert!(configure(Some("nope"), None).is_err());
        assert!(configure(None, Some("nope")).is_err());
    }

    /// The process-wide selection is only a default; every choice is
    /// numerically parity, so concurrent tests flipping it stay safe.
    #[test]
    fn configure_sets_process_defaults() {
        configure(Some("tiled"), Some("im2col")).unwrap();
        assert_eq!(mat_kernel(), MatKernel::Tiled);
        assert_eq!(conv_kernel(), ConvKernel::Im2col);
        configure(Some("auto"), Some("auto")).unwrap();
        assert_eq!(mat_kernel(), MatKernel::Auto);
        assert_eq!(conv_kernel(), ConvKernel::Auto);
    }
}
