//! Conv-net forward pass matching `python/compile/networks.py::dqn_apply`
//! for one population member: 3x3 VALID conv (NHWC/HWIO) + relu, flatten,
//! then an MLP head.
//!
//! [`ConvNet`] is the P=1 facade over the population-batched
//! [`PopConvNet`](crate::nn::pop_conv::PopConvNet) — the same kernel-layer
//! conv ([`crate::nn::kernels`]) and packed head run both paths, so
//! scalar and block inference cannot drift apart.

use crate::nn::kernels::ConvKernel;
use crate::nn::mlp::Mlp;
use crate::nn::pop_conv::PopConvNet;

/// One population member's DQN conv net — a scalar facade over
/// [`PopConvNet`] with population size 1.
#[derive(Clone, Debug)]
pub struct ConvNet {
    inner: PopConvNet,
}

impl ConvNet {
    pub fn new(
        w: Vec<f32>,
        b: Vec<f32>,
        kh: usize,
        kw: usize,
        in_ch: usize,
        features: usize,
        h: usize,
        wd: usize,
        head: Mlp,
    ) -> Self {
        ConvNet {
            inner: PopConvNet::new(1, w, b, kh, kw, in_ch, features, h, wd, head.into_pop_mlp()),
        }
    }

    pub fn out_hw(&self) -> (usize, usize) {
        self.inner.out_hw()
    }

    pub fn set_conv(&mut self, w: &[f32], b: &[f32]) {
        self.inner.set_member_conv(0, w, b);
    }

    /// Pin the conv kernel (`None` follows the process-wide selection).
    pub fn set_kernel(&mut self, kernel: Option<ConvKernel>) {
        self.inner.set_kernel(kernel);
    }

    /// Forward one frame `[H, W, C]` (flattened HWC) -> q-values.
    pub fn forward(&mut self, frame: &[f32], out: &mut [f32]) {
        self.inner.forward_block(&[0], frame, out);
    }

    pub fn forward_vec(&mut self, frame: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.inner.out_dim()];
        self.forward(frame, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::mlp::Activation;

    /// 3x3 frame, 1 channel, 2x2 identity-ish filter -> hand-checkable.
    #[test]
    fn conv_matches_hand_computation() {
        // 2x2 filter with single weight at (0,0): conv = top-left pixel.
        let w = vec![1.0, 0.0, 0.0, 0.0]; // [kh=2,kw=2,c=1,f=1]
        let b = vec![0.5];
        let mut head = Mlp::new(Activation::Relu, Activation::None);
        head.push_layer(vec![1.0, 1.0, 1.0, 1.0], vec![0.0], 4, 1); // sum
        let mut net = ConvNet::new(w, b, 2, 2, 1, 1, 3, 3, head);
        #[rustfmt::skip]
        let frame = vec![
            1.0, 2.0, 3.0,
            4.0, 5.0, 6.0,
            7.0, 8.0, 9.0,
        ];
        // conv out (2x2): relu(pixel + 0.5) at (0,0),(0,1),(1,0),(1,1)
        //   = [1.5, 2.5, 4.5, 5.5]; head sums -> 14.0
        let y = net.forward_vec(&frame);
        assert!((y[0] - 14.0).abs() < 1e-6);
    }

    #[test]
    fn multi_channel_accumulates() {
        // 1x1 filter, 2 channels -> f=1 with weights [2, 3]
        let w = vec![2.0, 3.0];
        let b = vec![0.0];
        let mut head = Mlp::new(Activation::Relu, Activation::None);
        head.push_layer(vec![1.0], vec![0.0], 1, 1);
        let mut net = ConvNet::new(w, b, 1, 1, 2, 1, 1, 1, head);
        let y = net.forward_vec(&[10.0, 1.0]);
        assert!((y[0] - 23.0).abs() < 1e-6);
    }

    #[test]
    fn relu_in_conv_applies() {
        let w = vec![-1.0];
        let b = vec![0.0];
        let mut head = Mlp::new(Activation::Relu, Activation::None);
        head.push_layer(vec![1.0], vec![0.0], 1, 1);
        let mut net = ConvNet::new(w, b, 1, 1, 1, 1, 1, 1, head);
        assert_eq!(net.forward_vec(&[5.0])[0], 0.0);
    }

    #[test]
    fn set_conv_updates_output() {
        let mut head = Mlp::new(Activation::Relu, Activation::None);
        head.push_layer(vec![1.0], vec![0.0], 1, 1);
        let mut net = ConvNet::new(vec![1.0], vec![0.0], 1, 1, 1, 1, 1, 1, head);
        assert!((net.forward_vec(&[2.0])[0] - 2.0).abs() < 1e-6);
        net.set_conv(&[3.0], &[1.0]);
        assert!((net.forward_vec(&[2.0])[0] - 7.0).abs() < 1e-6);
    }
}
