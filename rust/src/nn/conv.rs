//! Conv-net forward pass matching `python/compile/networks.py::dqn_apply`
//! (one population member): 3x3 VALID conv (NHWC/HWIO) + relu, flatten,
//! then an MLP head. Used by DQN actors on the MinAtar-style env.

use crate::nn::mlp::Mlp;

#[derive(Clone, Debug)]
pub struct ConvNet {
    /// Conv filter, HWIO layout `[kh, kw, in_ch, features]` flattened.
    w: Vec<f32>,
    b: Vec<f32>,
    kh: usize,
    kw: usize,
    in_ch: usize,
    features: usize,
    /// Input frame H, W.
    h: usize,
    wd: usize,
    pub head: Mlp,
    conv_out: Vec<f32>,
}

impl ConvNet {
    #[allow(clippy::too_many_arguments)]
    pub fn new(w: Vec<f32>, b: Vec<f32>, kh: usize, kw: usize, in_ch: usize,
               features: usize, h: usize, wd: usize, head: Mlp) -> Self {
        assert_eq!(w.len(), kh * kw * in_ch * features, "conv filter size");
        assert_eq!(b.len(), features, "conv bias size");
        let (ho, wo) = (h - kh + 1, wd - kw + 1);
        assert_eq!(head.in_dim(), ho * wo * features, "head input dim");
        ConvNet { w, b, kh, kw, in_ch, features, h, wd, head,
                  conv_out: vec![0.0; ho * wo * features] }
    }

    pub fn out_hw(&self) -> (usize, usize) {
        (self.h - self.kh + 1, self.wd - self.kw + 1)
    }

    pub fn set_conv(&mut self, w: &[f32], b: &[f32]) {
        assert_eq!(w.len(), self.w.len());
        assert_eq!(b.len(), self.b.len());
        self.w.copy_from_slice(w);
        self.b.copy_from_slice(b);
    }

    /// Forward one frame `[H, W, C]` (flattened HWC) -> q-values.
    pub fn forward(&mut self, frame: &[f32], out: &mut [f32]) {
        assert_eq!(frame.len(), self.h * self.wd * self.in_ch, "frame size");
        let (ho, wo) = self.out_hw();
        let f = self.features;
        // VALID conv + relu, NHWC x HWIO.
        for oy in 0..ho {
            for ox in 0..wo {
                let dst = &mut self.conv_out[(oy * wo + ox) * f..(oy * wo + ox + 1) * f];
                dst.copy_from_slice(&self.b);
                for ky in 0..self.kh {
                    for kx in 0..self.kw {
                        let iy = oy + ky;
                        let ix = ox + kx;
                        let px = &frame[(iy * self.wd + ix) * self.in_ch..];
                        for c in 0..self.in_ch {
                            let xv = px[c];
                            if xv == 0.0 {
                                continue; // sparse binary frames: skip zeros
                            }
                            let wrow = &self.w[((ky * self.kw + kx) * self.in_ch + c) * f..];
                            for (d, &wv) in dst.iter_mut().zip(&wrow[..f]) {
                                *d += xv * wv;
                            }
                        }
                    }
                }
                for d in dst.iter_mut() {
                    *d = d.max(0.0);
                }
            }
        }
        self.head.forward(&self.conv_out, out);
    }

    pub fn forward_vec(&mut self, frame: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.head.out_dim()];
        self.forward(frame, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::mlp::Activation;

    /// 3x3 frame, 1 channel, 2x2 identity-ish filter -> hand-checkable.
    #[test]
    fn conv_matches_hand_computation() {
        // 2x2 filter with single weight at (0,0): conv = top-left pixel.
        let w = vec![1.0, 0.0, 0.0, 0.0]; // [kh=2,kw=2,c=1,f=1]
        let b = vec![0.5];
        let mut head = Mlp::new(Activation::Relu, Activation::None);
        head.push_layer(vec![1.0, 1.0, 1.0, 1.0], vec![0.0], 4, 1); // sum
        let mut net = ConvNet::new(w, b, 2, 2, 1, 1, 3, 3, head);
        #[rustfmt::skip]
        let frame = vec![
            1.0, 2.0, 3.0,
            4.0, 5.0, 6.0,
            7.0, 8.0, 9.0,
        ];
        // conv out (2x2): relu(pixel + 0.5) at (0,0),(0,1),(1,0),(1,1)
        //   = [1.5, 2.5, 4.5, 5.5]; head sums -> 14.0
        let y = net.forward_vec(&frame);
        assert!((y[0] - 14.0).abs() < 1e-6);
    }

    #[test]
    fn multi_channel_accumulates() {
        // 1x1 filter, 2 channels -> f=1 with weights [2, 3]
        let w = vec![2.0, 3.0];
        let b = vec![0.0];
        let mut head = Mlp::new(Activation::Relu, Activation::None);
        head.push_layer(vec![1.0], vec![0.0], 1, 1);
        let mut net = ConvNet::new(w, b, 1, 1, 2, 1, 1, 1, head);
        let y = net.forward_vec(&[10.0, 1.0]);
        assert!((y[0] - 23.0).abs() < 1e-6);
    }

    #[test]
    fn relu_in_conv_applies() {
        let w = vec![-1.0];
        let b = vec![0.0];
        let mut head = Mlp::new(Activation::Relu, Activation::None);
        head.push_layer(vec![1.0], vec![0.0], 1, 1);
        let mut net = ConvNet::new(w, b, 1, 1, 1, 1, 1, 1, head);
        assert_eq!(net.forward_vec(&[5.0])[0], 0.0);
    }
}
