//! Population-batched conv-net inference for the pixel/DQN actor hot path.
//!
//! # Layout contract
//!
//! [`PopConvNet`] packs every member's conv filter in structure-of-arrays
//! form: `w: f32[P, kh, kw, in_ch, features]` (member-major HWIO per
//! member) and `b: f32[P, features]` — byte-identical to the flat
//! train-state fields `{prefix}/conv/w` / `{prefix}/conv/b` that
//! `python/compile/networks.py::conv_fields` serializes into the manifest.
//! The q-head is a packed [`PopMlp`] over the `{prefix}/head/*` fields.
//! Because the packing matches the manifest layout exactly,
//! [`PopConvNet::sync_from_state`] refreshes ALL members with one
//! contiguous copy per field — replacing the per-sync
//! `convnet_from_state` reallocation (P strided per-agent reads plus P
//! fresh `Vec`s) the scalar path needed.
//!
//! # Forward
//!
//! [`PopConvNet::forward_block`] forwards an `[n, H*W*C]` frame block in
//! one call; row `k` uses member `members[k]`'s filter and head weights.
//! Consecutive rows owned by the same member are convolved back to back
//! with that member's filter hot in cache, then the whole `[n, flat]`
//! activation block goes through [`PopMlp::forward_block`] in one pass.
//! The scalar [`ConvNet`](crate::nn::conv::ConvNet) is the P=1 special
//! case and delegates here.
//!
//! The conv itself runs through the kernel layer
//! ([`crate::nn::kernels`]): one direct-vs-im2col decision per block
//! ([`kernels::conv_block_choice`]), then either the sparsity-skipping
//! direct kernel ([`conv2d_valid_relu`]) or the im2col gather + tiled
//! matmat ([`kernels::conv2d_im2col_relu`]) per frame. Both scratches
//! (`conv_out`, `im2col`) are reused across calls; see
//! [`PopConvNet::scratch_bytes`] / [`PopConvNet::reserve_scratch`].

use crate::manifest::Artifact;
use crate::nn::kernels::{self, ConvKernel};
use crate::nn::pop_mlp::PopMlp;

pub use crate::nn::kernels::conv2d_valid_relu;

/// All population members' DQN conv nets in one packed
/// structure-of-arrays net (conv filter bank + [`PopMlp`] q-head).
#[derive(Clone, Debug)]
pub struct PopConvNet {
    pop: usize,
    /// `[P, kh, kw, in_ch, features]` flat, member-major (manifest layout).
    w: Vec<f32>,
    /// `[P, features]` flat.
    b: Vec<f32>,
    kh: usize,
    kw: usize,
    in_ch: usize,
    features: usize,
    /// Input frame H, W.
    h: usize,
    wd: usize,
    pub head: PopMlp,
    /// Conv activation scratch `[n, ho*wo*features]`, grown on demand.
    conv_out: Vec<f32>,
    /// im2col patch scratch `[ho*wo, kh*kw*in_ch]`, grown on demand.
    im2col: Vec<f32>,
    /// Per-instance conv kernel override; `None` follows the process-wide
    /// selection ([`kernels::conv_kernel`]).
    kernel: Option<ConvKernel>,
}

impl PopConvNet {
    pub fn new(
        pop: usize,
        w: Vec<f32>,
        b: Vec<f32>,
        kh: usize,
        kw: usize,
        in_ch: usize,
        features: usize,
        h: usize,
        wd: usize,
        head: PopMlp,
    ) -> Self {
        assert!(pop > 0, "population must be non-empty");
        assert_eq!(w.len(), pop * kh * kw * in_ch * features, "conv filter size");
        assert_eq!(b.len(), pop * features, "conv bias size");
        assert_eq!(head.pop(), pop, "head population mismatch");
        let (ho, wo) = (h - kh + 1, wd - kw + 1);
        assert_eq!(head.in_dim(), ho * wo * features, "head input dim");
        PopConvNet {
            pop,
            w,
            b,
            kh,
            kw,
            in_ch,
            features,
            h,
            wd,
            head,
            conv_out: Vec::new(),
            im2col: Vec::new(),
            kernel: None,
        }
    }

    pub fn pop(&self) -> usize {
        self.pop
    }

    /// Pin this net to one conv kernel (`None` restores the process-wide
    /// selection). All kernels are numerically parity; this exists for
    /// A/B benchmarking and tests.
    pub fn set_kernel(&mut self, kernel: Option<ConvKernel>) {
        self.kernel = kernel;
    }

    /// Total bytes held by the forward scratch buffers (conv activations,
    /// im2col patches, and the head's layer scratch). Grown on demand —
    /// call [`Self::reserve_scratch`] at spawn to make this report the
    /// steady-state footprint up front.
    pub fn scratch_bytes(&self) -> usize {
        (self.conv_out.capacity() + self.im2col.capacity()) * std::mem::size_of::<f32>()
            + self.head.scratch_bytes()
    }

    /// Pre-size every forward scratch for `rows`-row blocks so the hot
    /// path never allocates and [`Self::scratch_bytes`] is meaningful at
    /// spawn time.
    pub fn reserve_scratch(&mut self, rows: usize) {
        let (ho, wo) = self.out_hw();
        let flat = ho * wo * self.features;
        let patch = self.kh * self.kw * self.in_ch;
        self.conv_out.reserve(rows * flat);
        self.im2col.reserve(ho * wo * patch);
        self.head.reserve_scratch(rows);
    }

    /// Input frame length `H * W * C`.
    pub fn frame_len(&self) -> usize {
        self.h * self.wd * self.in_ch
    }

    pub fn out_hw(&self) -> (usize, usize) {
        (self.h - self.kh + 1, self.wd - self.kw + 1)
    }

    /// Q-values per frame (= the head's output dim).
    pub fn out_dim(&self) -> usize {
        self.head.out_dim()
    }

    /// One member's conv `(w, b)` slices (`[kh, kw, in_ch, f]` / `[f]`).
    pub fn member_conv(&self, member: usize) -> (&[f32], &[f32]) {
        assert!(member < self.pop, "member out of range");
        let ws = self.kh * self.kw * self.in_ch * self.features;
        (
            &self.w[member * ws..(member + 1) * ws],
            &self.b[member * self.features..(member + 1) * self.features],
        )
    }

    /// Replace ONE member's conv filter in place.
    pub fn set_member_conv(&mut self, member: usize, w: &[f32], b: &[f32]) {
        assert!(member < self.pop, "member out of range");
        let ws = self.kh * self.kw * self.in_ch * self.features;
        assert_eq!(w.len(), ws, "conv filter size");
        assert_eq!(b.len(), self.features, "conv bias size");
        self.w[member * ws..(member + 1) * ws].copy_from_slice(w);
        self.b[member * self.features..(member + 1) * self.features].copy_from_slice(b);
    }

    /// Replace ALL members' conv filters from packed `[P, kh, kw, C, F]` /
    /// `[P, F]` slices — one memcpy per array.
    pub fn set_conv_packed(&mut self, w: &[f32], b: &[f32]) {
        assert_eq!(w.len(), self.w.len(), "conv filter size");
        assert_eq!(b.len(), self.b.len(), "conv bias size");
        self.w.copy_from_slice(w);
        self.b.copy_from_slice(b);
    }

    /// Refresh every member from a host copy of the flat train state in
    /// one pass: `{prefix}/conv/w` is stored `[P, kh, kw, C, F]` flat —
    /// exactly this net's packing — so the filter bank, the bias bank, and
    /// each head layer are one contiguous copy per field.
    pub fn sync_from_state(
        &mut self,
        artifact: &Artifact,
        state: &[f32],
        prefix: &str,
    ) -> anyhow::Result<()> {
        let w = artifact.read(state, &format!("{prefix}/conv/w"))?;
        let b = artifact.read(state, &format!("{prefix}/conv/b"))?;
        self.set_conv_packed(w, b);
        self.head.sync_from_state(artifact, state, &format!("{prefix}/head"))
    }

    /// Forward a frame block `frames: [n, H*W*C]` in one call; row `k`
    /// uses member `members[k]`'s weights. Writes q-values
    /// `out: [n, out_dim]`. Consecutive rows with the same member reuse
    /// that member's filter back to back.
    pub fn forward_block(&mut self, members: &[usize], frames: &[f32], out: &mut [f32]) {
        let n = members.len();
        let fl = self.frame_len();
        let (ho, wo) = self.out_hw();
        let flat = ho * wo * self.features;
        assert_eq!(frames.len(), n * fl, "frame block size mismatch");
        assert_eq!(out.len(), n * self.out_dim(), "out block size mismatch");
        debug_assert!(members.iter().all(|&m| m < self.pop), "member out of range");
        // Take the scratches out of `self` for the duration of the pass
        // so the filter bank stays borrowable (allocation-free steady
        // state).
        let mut conv_out = std::mem::take(&mut self.conv_out);
        let mut im2col = std::mem::take(&mut self.im2col);
        conv_out.resize(n * flat, 0.0);
        let ws = self.kh * self.kw * self.in_ch * self.features;
        let f = self.features;
        // One direct-vs-im2col decision per block: the whole block shares
        // one sparsity profile (same env, same step), so per-frame
        // re-counting would only add overhead.
        let requested = self.kernel.unwrap_or_else(kernels::conv_kernel);
        let choice = kernels::conv_block_choice(requested, frames, ho * wo, f);
        let mut row = 0;
        while row < n {
            let m = members[row];
            let mut end = row + 1;
            while end < n && members[end] == m {
                end += 1;
            }
            let mw = &self.w[m * ws..(m + 1) * ws];
            let mb = &self.b[m * f..(m + 1) * f];
            for k in row..end {
                let frame = &frames[k * fl..(k + 1) * fl];
                let dst = &mut conv_out[k * flat..(k + 1) * flat];
                match choice {
                    ConvKernel::Im2col => kernels::conv2d_im2col_relu(
                        mw,
                        mb,
                        frame,
                        dst,
                        &mut im2col,
                        self.kh,
                        self.kw,
                        self.in_ch,
                        f,
                        self.h,
                        self.wd,
                    ),
                    _ => conv2d_valid_relu(
                        mw,
                        mb,
                        frame,
                        dst,
                        self.kh,
                        self.kw,
                        self.in_ch,
                        f,
                        self.h,
                        self.wd,
                    ),
                }
            }
            row = end;
        }
        self.head.forward_block(members, &conv_out, out);
        self.conv_out = conv_out;
        self.im2col = im2col;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{Artifact, Dtype, EnvDesc, Field};
    use crate::nn::conv::ConvNet;
    use crate::nn::mlp::{Activation, Mlp};
    use crate::util::rng::Rng;

    const FRAME: (usize, usize, usize) = (6, 5, 2);
    const K: usize = 3;
    const FEATS: usize = 4;
    const HEAD_HIDDEN: usize = 8;
    const N_ACTIONS: usize = 3;

    struct Member {
        cw: Vec<f32>,
        cb: Vec<f32>,
        head: Vec<(Vec<f32>, Vec<f32>)>,
    }

    fn head_dims() -> [usize; 3] {
        let (h, w, _) = FRAME;
        [(h - K + 1) * (w - K + 1) * FEATS, HEAD_HIDDEN, N_ACTIONS]
    }

    fn random_members(rng: &mut Rng, pop: usize) -> Vec<Member> {
        let (_, _, c) = FRAME;
        let dims = head_dims();
        (0..pop)
            .map(|_| {
                let mut cw = vec![0.0f32; K * K * c * FEATS];
                let mut cb = vec![0.0f32; FEATS];
                rng.fill_normal(&mut cw, 0.5);
                rng.fill_normal(&mut cb, 0.2);
                let head = dims
                    .windows(2)
                    .map(|d| {
                        let mut w = vec![0.0f32; d[0] * d[1]];
                        let mut b = vec![0.0f32; d[1]];
                        rng.fill_normal(&mut w, 0.3);
                        rng.fill_normal(&mut b, 0.1);
                        (w, b)
                    })
                    .collect();
                Member { cw, cb, head }
            })
            .collect()
    }

    fn pack(members: &[Member]) -> PopConvNet {
        let (h, w, c) = FRAME;
        let dims = head_dims();
        let pop = members.len();
        let mut head = PopMlp::new(pop, Activation::Relu, Activation::None);
        for (li, d) in dims.windows(2).enumerate() {
            let mut hw = Vec::new();
            let mut hb = Vec::new();
            for m in members {
                hw.extend_from_slice(&m.head[li].0);
                hb.extend_from_slice(&m.head[li].1);
            }
            head.push_layer(hw, hb, d[0], d[1]);
        }
        let mut cw = Vec::new();
        let mut cb = Vec::new();
        for m in members {
            cw.extend_from_slice(&m.cw);
            cb.extend_from_slice(&m.cb);
        }
        PopConvNet::new(pop, cw, cb, K, K, c, FEATS, h, w, head)
    }

    fn scalar_net(m: &Member) -> ConvNet {
        let (h, w, c) = FRAME;
        let dims = head_dims();
        let mut head = Mlp::new(Activation::Relu, Activation::None);
        for (li, d) in dims.windows(2).enumerate() {
            head.push_layer(m.head[li].0.clone(), m.head[li].1.clone(), d[0], d[1]);
        }
        ConvNet::new(m.cw.clone(), m.cb.clone(), K, K, c, FEATS, h, w, head)
    }

    /// The tentpole parity contract: PopConvNet::forward_block row k ==
    /// member k's scalar ConvNet::forward, at pop 1/4/16, tol 1e-5.
    #[test]
    fn forward_block_matches_scalar_convnets() {
        let (h, w, c) = FRAME;
        let fl = h * w * c;
        let mut rng = Rng::new(31);
        for &pop in &[1usize, 4, 16] {
            let members = random_members(&mut rng, pop);
            let mut net = pack(&members);
            // one row per member plus duplicate rows (same-member runs)
            let mut ids: Vec<usize> = (0..pop).collect();
            ids.push(0);
            ids.push(pop - 1);
            let n = ids.len();
            // mix of binary {0,1} planes (the MinAtar case) and dense rows
            let mut frames = vec![0.0f32; n * fl];
            for (i, v) in frames.iter_mut().enumerate() {
                *v = if i % 2 == 0 {
                    (rng.below(3) == 0) as u8 as f32
                } else {
                    rng.normal() as f32
                };
            }
            let mut out = vec![0.0f32; n * N_ACTIONS];
            net.forward_block(&ids, &frames, &mut out);
            for (k, &m) in ids.iter().enumerate() {
                let want = scalar_net(&members[m]).forward_vec(&frames[k * fl..(k + 1) * fl]);
                for (j, &wv) in want.iter().enumerate() {
                    let gv = out[k * N_ACTIONS + j];
                    assert!(
                        (gv - wv).abs() < 1e-5,
                        "pop {pop} row {k} member {m} q {j}: {gv} vs {wv}"
                    );
                }
            }
        }
    }

    /// sync_from_state pulls the packed conv + head fields with the
    /// manifest layout (one contiguous lane per field).
    #[test]
    fn sync_from_state_reads_packed_fields() {
        let (pop, kh, c, f) = (2usize, 1usize, 1usize, 2usize);
        let (h, w) = (2usize, 2usize);
        let flat = h * w * f; // 1x1 conv keeps spatial dims
        let n_act = 2usize;
        let sizes = [pop * kh * kh * c * f, pop * f, pop * flat * n_act, pop * n_act];
        let names = ["q/conv/w", "q/conv/b", "q/head/w0", "q/head/b0"];
        let shapes: [Vec<usize>; 4] = [
            vec![pop, kh, kh, c, f],
            vec![pop, f],
            vec![pop, flat, n_act],
            vec![pop, n_act],
        ];
        let mut fields = Vec::new();
        let mut offset = 0;
        for i in 0..4 {
            fields.push(Field {
                name: names[i].into(),
                offset,
                size: sizes[i],
                shape: shapes[i].clone(),
                dtype: Dtype::F32,
                init: "zeros".into(),
                group: "critic".into(),
                per_agent: true,
            });
            offset += sizes[i];
        }
        let art = Artifact::new(
            "t".into(),
            std::path::PathBuf::new(),
            "dqn".into(),
            "minatar".into(),
            EnvDesc::default(),
            pop,
            1,
            4,
            vec![],
            offset,
            "state".into(),
            vec![],
            fields,
            vec![],
        );
        let state: Vec<f32> = (0..offset).map(|v| v as f32).collect();
        let mut head = PopMlp::new(pop, Activation::Relu, Activation::None);
        head.push_layer(vec![0.0; pop * flat * n_act], vec![0.0; pop * n_act], flat, n_act);
        let (zw, zb) = (vec![0.0; sizes[0]], vec![0.0; sizes[1]]);
        let mut net = PopConvNet::new(pop, zw, zb, kh, kh, c, f, h, w, head);
        net.sync_from_state(&art, &state, "q").unwrap();
        for m in 0..pop {
            let (cw, cb) = net.member_conv(m);
            assert_eq!(cw[0], (m * kh * kh * c * f) as f32);
            assert_eq!(cb[0], (sizes[0] + m * f) as f32);
            let (hw, hb) = net.head.member_layer(m, 0);
            assert_eq!(hw[0], (sizes[0] + sizes[1] + m * flat * n_act) as f32);
            assert_eq!(hb[0], (sizes[0] + sizes[1] + sizes[2] + m * n_act) as f32);
        }
    }

    /// Pinning the net to each conv kernel must give 1e-5-identical
    /// q-values through the full forward (conv + head).
    #[test]
    fn forward_block_kernel_override_parity() {
        let (h, w, c) = FRAME;
        let fl = h * w * c;
        let mut rng = Rng::new(47);
        let members = random_members(&mut rng, 4);
        let ids = [0usize, 1, 1, 2, 3, 3];
        let n = ids.len();
        let mut frames = vec![0.0f32; n * fl];
        rng.fill_normal(&mut frames, 1.0);
        let mut direct = vec![0.0f32; n * N_ACTIONS];
        let mut im2col = vec![0.0f32; n * N_ACTIONS];
        let mut net = pack(&members);
        net.set_kernel(Some(ConvKernel::Direct));
        net.forward_block(&ids, &frames, &mut direct);
        net.set_kernel(Some(ConvKernel::Im2col));
        net.forward_block(&ids, &frames, &mut im2col);
        for (k, (&dv, &iv)) in direct.iter().zip(&im2col).enumerate() {
            assert!((dv - iv).abs() < 1e-5, "q {k}: direct {dv} vs im2col {iv}");
        }
    }

    /// scratch_bytes reports the reserved footprint at spawn and the hot
    /// path never grows past the reservation.
    #[test]
    fn scratch_accounting_reports_reserved_bytes() {
        let (h, w, c) = FRAME;
        let fl = h * w * c;
        let mut rng = Rng::new(53);
        let members = random_members(&mut rng, 2);
        let mut net = pack(&members);
        assert_eq!(net.scratch_bytes(), 0, "fresh net holds no scratch");
        let rows = 6;
        net.reserve_scratch(rows);
        let (ho, wo) = net.out_hw();
        let floor = (rows * ho * wo * FEATS + ho * wo * K * K * c) * 4;
        let reserved = net.scratch_bytes();
        assert!(reserved >= floor, "{reserved} < {floor}");
        let ids = [0usize, 0, 1, 1, 0, 1];
        let mut frames = vec![0.0f32; rows * fl];
        rng.fill_normal(&mut frames, 1.0);
        let mut out = vec![0.0f32; rows * N_ACTIONS];
        net.set_kernel(Some(ConvKernel::Im2col));
        net.forward_block(&ids, &frames, &mut out);
        assert_eq!(net.scratch_bytes(), reserved, "forward_block must not realloc");
    }

    #[test]
    #[should_panic(expected = "head input dim")]
    fn mismatched_head_panics() {
        let head = {
            let mut h = PopMlp::new(1, Activation::Relu, Activation::None);
            h.push_layer(vec![0.0; 3], vec![0.0; 3], 1, 3); // wrong in_dim
            h
        };
        let _ = PopConvNet::new(1, vec![0.0; 4], vec![0.0; 1], 2, 2, 1, 1, 3, 3, head);
    }
}
