//! MLP forward pass matching `python/compile/networks.py::mlp_apply`.
//!
//! Weight convention is identical to the jax side: layer `l` maps
//! `h @ w[l] + b[l]` with `w[l]: [in, out]` stored row-major, relu between
//! hidden layers and a configurable final activation.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    None,
    Relu,
    Tanh,
}

impl Activation {
    #[inline]
    pub fn apply(self, x: f32) -> f32 {
        match self {
            Activation::None => x,
            Activation::Relu => x.max(0.0),
            Activation::Tanh => x.tanh(),
        }
    }
}

/// One population member's MLP (weights borrowed or owned as flat vecs).
#[derive(Clone, Debug)]
pub struct Mlp {
    /// Per layer: (w flat [in*out], b [out], in, out)
    layers: Vec<(Vec<f32>, Vec<f32>, usize, usize)>,
    pub hidden_act: Activation,
    pub final_act: Activation,
    /// Scratch buffers reused across calls (allocation-free hot path).
    scratch: [Vec<f32>; 2],
}

impl Mlp {
    pub fn new(hidden_act: Activation, final_act: Activation) -> Self {
        Mlp { layers: Vec::new(), hidden_act, final_act, scratch: [Vec::new(), Vec::new()] }
    }

    /// Append a layer; `w` is `[in, out]` row-major, `b` is `[out]`.
    pub fn push_layer(&mut self, w: Vec<f32>, b: Vec<f32>, in_dim: usize, out_dim: usize) {
        assert_eq!(w.len(), in_dim * out_dim, "weight size mismatch");
        assert_eq!(b.len(), out_dim, "bias size mismatch");
        self.layers.push((w, b, in_dim, out_dim));
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn in_dim(&self) -> usize {
        self.layers.first().map(|l| l.2).unwrap_or(0)
    }

    pub fn out_dim(&self) -> usize {
        self.layers.last().map(|l| l.3).unwrap_or(0)
    }

    /// Replace layer weights in place (parameter sync without realloc).
    pub fn set_layer(&mut self, li: usize, w: &[f32], b: &[f32]) {
        let (lw, lb, i, o) = &mut self.layers[li];
        assert_eq!(w.len(), *i * *o);
        assert_eq!(b.len(), *o);
        lw.copy_from_slice(w);
        lb.copy_from_slice(b);
    }

    /// Forward one observation. Writes into `out` (len = out_dim).
    pub fn forward(&mut self, obs: &[f32], out: &mut [f32]) {
        assert_eq!(obs.len(), self.in_dim(), "obs dim mismatch");
        assert_eq!(out.len(), self.out_dim(), "out dim mismatch");
        let n_layers = self.layers.len();
        // Double-buffer through scratch to stay allocation-free: take the
        // buffers out of `self` for the duration of the pass.
        let mut src = std::mem::take(&mut self.scratch[0]);
        let mut dst = std::mem::take(&mut self.scratch[1]);
        src.clear();
        src.extend_from_slice(obs);
        for (li, (w, b, in_dim, out_dim)) in self.layers.iter().enumerate() {
            let act = if li + 1 == n_layers { self.final_act } else { self.hidden_act };
            dst.resize(*out_dim, 0.0);
            matvec(w, b, &src, &mut dst, *in_dim, *out_dim, act);
            std::mem::swap(&mut src, &mut dst);
        }
        out.copy_from_slice(&src[..out.len()]);
        self.scratch[0] = src;
        self.scratch[1] = dst;
    }

    /// Forward returning a fresh Vec (convenience for tests).
    pub fn forward_vec(&mut self, obs: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.out_dim()];
        self.forward(obs, &mut out);
        out
    }
}

/// `dst[o] = act(sum_i x[i] * w[i, o] + b[o])`, w row-major [in, out].
/// Iterating rows of `w` keeps the access pattern sequential (cache-
/// friendly for the [in, out] layout jax uses).
#[inline]
fn matvec(w: &[f32], b: &[f32], x: &[f32], dst: &mut [f32], in_dim: usize,
          out_dim: usize, act: Activation) {
    dst.copy_from_slice(b);
    for (i, &xi) in x.iter().enumerate().take(in_dim) {
        if xi == 0.0 {
            continue; // relu sparsity: skip dead rows
        }
        let row = &w[i * out_dim..(i + 1) * out_dim];
        for (d, &wv) in dst.iter_mut().zip(row) {
            *d += xi * wv;
        }
    }
    for d in dst.iter_mut() {
        *d = act.apply(*d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Mlp {
        // 2 -> 3 -> 1, hand-computable weights
        let mut m = Mlp::new(Activation::Relu, Activation::Tanh);
        m.push_layer(
            vec![1.0, 0.0, -1.0, /* row x0 */ 0.0, 2.0, 1.0 /* row x1 */],
            vec![0.0, -1.0, 0.5],
            2,
            3,
        );
        m.push_layer(vec![1.0, 1.0, 1.0], vec![0.1], 3, 1);
        m
    }

    #[test]
    fn forward_matches_hand_computation() {
        let mut m = tiny();
        // x = [1, 2]: z1 = [1*1+2*0, 1*0+2*2-1, 1*-1+2*1+0.5] = [1, 3, 1.5]
        // relu -> same; z2 = 1+3+1.5+0.1 = 5.6; tanh(5.6)
        let y = m.forward_vec(&[1.0, 2.0]);
        assert!((y[0] - 5.6f32.tanh()).abs() < 1e-6);
    }

    #[test]
    fn relu_clips_negatives() {
        let mut m = tiny();
        // x = [-1, 0]: z1 = [-1, 1, 1.5] -> relu [0, 1, 1.5]
        // wait: z1 = [-1*1, -1*0-1, -1*-1+0.5] = [-1, -1, 1.5] -> [0,0,1.5]
        // z2 = 1.5 + 0.1 = 1.6
        let y = m.forward_vec(&[-1.0, 0.0]);
        assert!((y[0] - 1.6f32.tanh()).abs() < 1e-6);
    }

    #[test]
    fn set_layer_updates_output() {
        let mut m = tiny();
        let before = m.forward_vec(&[1.0, 2.0])[0];
        m.set_layer(1, &[0.0, 0.0, 0.0], &[0.0]);
        let after = m.forward_vec(&[1.0, 2.0])[0];
        assert_ne!(before, after);
        assert_eq!(after, 0.0);
    }

    #[test]
    fn repeated_forward_is_stable() {
        let mut m = tiny();
        let a = m.forward_vec(&[0.3, -0.7]);
        let b = m.forward_vec(&[0.3, -0.7]);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "obs dim mismatch")]
    fn wrong_obs_dim_panics() {
        let mut m = tiny();
        m.forward_vec(&[1.0]);
    }
}
