//! MLP forward pass matching `python/compile/networks.py::mlp_apply`.
//!
//! Weight convention is identical to the jax side: layer `l` maps
//! `h @ w[l] + b[l]` with `w[l]: [in, out]` stored row-major, relu between
//! hidden layers and a configurable final activation.
//!
//! The scalar [`Mlp`] is the one-member special case of the
//! population-batched [`PopMlp`](crate::nn::pop_mlp::PopMlp) and delegates
//! its forward pass to it. The compute kernels — [`matvec_sparse`],
//! [`matvec_dense`], the zero-counting adaptive [`matvec`], and the
//! tiled/reference [`matmat`] dispatch — live in the kernel layer
//! ([`crate::nn::kernels`]) and are re-exported here for compatibility.

use crate::nn::pop_mlp::PopMlp;

pub use crate::nn::kernels::{matmat, matvec, matvec_dense, matvec_sparse};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    None,
    Relu,
    Tanh,
}

impl Activation {
    #[inline]
    pub fn apply(self, x: f32) -> f32 {
        match self {
            Activation::None => x,
            Activation::Relu => x.max(0.0),
            Activation::Tanh => x.tanh(),
        }
    }
}

/// One population member's MLP — a scalar facade over [`PopMlp`] with
/// population size 1 (the 1-agent case of the vectorized actor path).
#[derive(Clone, Debug)]
pub struct Mlp {
    inner: PopMlp,
}

impl Mlp {
    pub fn new(hidden_act: Activation, final_act: Activation) -> Self {
        Mlp { inner: PopMlp::new(1, hidden_act, final_act) }
    }

    /// Append a layer; `w` is `[in, out]` row-major, `b` is `[out]`.
    pub fn push_layer(&mut self, w: Vec<f32>, b: Vec<f32>, in_dim: usize, out_dim: usize) {
        self.inner.push_layer(w, b, in_dim, out_dim);
    }

    pub fn num_layers(&self) -> usize {
        self.inner.num_layers()
    }

    pub fn in_dim(&self) -> usize {
        self.inner.in_dim()
    }

    pub fn out_dim(&self) -> usize {
        self.inner.out_dim()
    }

    pub fn hidden_act(&self) -> Activation {
        self.inner.hidden_act
    }

    pub fn final_act(&self) -> Activation {
        self.inner.final_act
    }

    /// Replace layer weights in place (parameter sync without realloc).
    pub fn set_layer(&mut self, li: usize, w: &[f32], b: &[f32]) {
        self.inner.set_member_layer(0, li, w, b);
    }

    /// Forward one observation. Writes into `out` (len = out_dim).
    pub fn forward(&mut self, obs: &[f32], out: &mut [f32]) {
        self.inner.forward_block(&[0], obs, out);
    }

    /// Forward returning a fresh Vec (convenience for tests).
    pub fn forward_vec(&mut self, obs: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.out_dim()];
        self.forward(obs, &mut out);
        out
    }

    /// Unwrap into the inner one-member [`PopMlp`] (e.g. to serve as the
    /// head of a scalar conv net built on the population path).
    pub fn into_pop_mlp(self) -> PopMlp {
        self.inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny() -> Mlp {
        // 2 -> 3 -> 1, hand-computable weights
        let mut m = Mlp::new(Activation::Relu, Activation::Tanh);
        m.push_layer(
            vec![1.0, 0.0, -1.0, /* row x0 */ 0.0, 2.0, 1.0 /* row x1 */],
            vec![0.0, -1.0, 0.5],
            2,
            3,
        );
        m.push_layer(vec![1.0, 1.0, 1.0], vec![0.1], 3, 1);
        m
    }

    #[test]
    fn forward_matches_hand_computation() {
        let mut m = tiny();
        // x = [1, 2]: z1 = [1*1+2*0, 1*0+2*2-1, 1*-1+2*1+0.5] = [1, 3, 1.5]
        // relu -> same; z2 = 1+3+1.5+0.1 = 5.6; tanh(5.6)
        let y = m.forward_vec(&[1.0, 2.0]);
        assert!((y[0] - 5.6f32.tanh()).abs() < 1e-6);
    }

    #[test]
    fn relu_clips_negatives() {
        let mut m = tiny();
        // x = [-1, 0]: z1 = [-1*1, -1*0-1, -1*-1+0.5] = [-1, -1, 1.5] -> [0,0,1.5]
        // z2 = 1.5 + 0.1 = 1.6
        let y = m.forward_vec(&[-1.0, 0.0]);
        assert!((y[0] - 1.6f32.tanh()).abs() < 1e-6);
    }

    #[test]
    fn set_layer_updates_output() {
        let mut m = tiny();
        let before = m.forward_vec(&[1.0, 2.0])[0];
        m.set_layer(1, &[0.0, 0.0, 0.0], &[0.0]);
        let after = m.forward_vec(&[1.0, 2.0])[0];
        assert_ne!(before, after);
        assert_eq!(after, 0.0);
    }

    #[test]
    fn repeated_forward_is_stable() {
        let mut m = tiny();
        let a = m.forward_vec(&[0.3, -0.7]);
        let b = m.forward_vec(&[0.3, -0.7]);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "obs dim mismatch")]
    fn wrong_obs_dim_panics() {
        let mut m = tiny();
        m.forward_vec(&[1.0]);
    }

    #[test]
    fn dense_and_sparse_kernels_agree() {
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let i = 1 + rng.below(24);
            let o = 1 + rng.below(24);
            let mut w = vec![0.0f32; i * o];
            let mut b = vec![0.0f32; o];
            rng.fill_normal(&mut w, 1.0);
            rng.fill_normal(&mut b, 1.0);
            // mix of dense, zero, and negative lanes
            let mut x = vec![0.0f32; i];
            for v in x.iter_mut() {
                *v = if rng.below(3) == 0 { 0.0 } else { rng.normal() as f32 };
            }
            let mut d1 = vec![0.0f32; o];
            let mut d2 = vec![0.0f32; o];
            let mut d3 = vec![0.0f32; o];
            matvec_sparse(&w, &b, &x, &mut d1, i, o, Activation::Tanh);
            matvec_dense(&w, &b, &x, &mut d2, i, o, Activation::Tanh);
            matvec(&w, &b, &x, &mut d3, i, o, Activation::Tanh);
            for k in 0..o {
                assert!((d1[k] - d2[k]).abs() < 1e-6, "{} vs {}", d1[k], d2[k]);
                // matvec routes to one of the two by zero count; either
                // way it must agree
                assert!((d1[k] - d3[k]).abs() < 1e-6, "{} vs {}", d1[k], d3[k]);
            }
        }
    }

    #[test]
    fn matmat_matches_per_row_matvec() {
        // matmat dispatches to the tiled kernel by default, whose
        // accumulation order differs from matvec's — parity is 1e-5,
        // not bitwise.
        let mut rng = Rng::new(8);
        let (i, o, rows) = (5, 4, 3);
        let mut w = vec![0.0f32; i * o];
        let mut b = vec![0.0f32; o];
        let mut x = vec![0.0f32; rows * i];
        rng.fill_normal(&mut w, 1.0);
        rng.fill_normal(&mut b, 1.0);
        rng.fill_normal(&mut x, 1.0);
        let mut got = vec![0.0f32; rows * o];
        matmat(&w, &b, &x, &mut got, i, o, rows, Activation::Relu);
        for r in 0..rows {
            let mut want = vec![0.0f32; o];
            matvec(&w, &b, &x[r * i..(r + 1) * i], &mut want, i, o, Activation::Relu);
            for (k, &wv) in want.iter().enumerate() {
                let gv = got[r * o + k];
                assert!((gv - wv).abs() < 1e-5, "row {r} out {k}: {gv} vs {wv}");
            }
        }
    }
}
