//! Population-batched MLP inference for the actor hot path.
//!
//! # Layout contract
//!
//! [`PopMlp`] packs every member's weights in structure-of-arrays form:
//! layer `l` stores `w: f32[P, in, out]` (member-major, then row-major
//! `[in, out]` per member) and `b: f32[P, out]`. This is byte-identical to
//! the flat train-state fields `{prefix}/w{l}` / `{prefix}/b{l}` that
//! `python/compile/layout.py` serializes into the manifest and that the
//! Pallas kernel `python/compile/kernels/pop_linear.py` consumes
//! (`y[p, b, o] = act(x[p, b, i] @ w[p, i, o] + bias[p, o])`). Because the
//! packing matches the manifest layout exactly, [`PopMlp::sync_from_state`]
//! refreshes ALL members with one contiguous copy per field, instead of
//! the P strided per-agent row reads the scalar path needed.
//!
//! # Forward
//!
//! [`PopMlp::forward_block`] forwards an `[n, in]` observation block in
//! one call; row `k` uses member `members[k]`'s weights. Consecutive rows
//! owned by the same member are forwarded as one row-blocked mat-mat
//! through the kernel layer ([`crate::nn::kernels`] — register-tiled by
//! default, overridable per instance) with that member's weight matrix
//! hot in cache — note that in today's actor loop each agent owns exactly
//! one env, so runs have length 1 and the win comes from the single
//! dispatch, shared scratch, and the packed one-pass weight sync; the run
//! blocking pays off once a member owns several rows (multiple envs per
//! agent, evaluation sweeps). The scalar [`Mlp`](crate::nn::mlp::Mlp) is
//! the P=1 special case and delegates here.

use crate::manifest::Artifact;
use crate::nn::kernels::{self, matmat_with, MatKernel};
use crate::nn::mlp::Activation;

#[derive(Clone, Debug)]
struct PopLayer {
    /// `[P, in, out]` flat, member-major.
    w: Vec<f32>,
    /// `[P, out]` flat.
    b: Vec<f32>,
    in_dim: usize,
    out_dim: usize,
}

/// All population members' MLPs in one packed structure-of-arrays net.
#[derive(Clone, Debug)]
pub struct PopMlp {
    pop: usize,
    layers: Vec<PopLayer>,
    pub hidden_act: Activation,
    pub final_act: Activation,
    /// Scratch buffers reused across calls (allocation-free hot path).
    scratch: [Vec<f32>; 2],
    /// Per-instance mat-mat kernel override; `None` follows the
    /// process-wide selection ([`kernels::mat_kernel`]).
    kernel: Option<MatKernel>,
}

impl PopMlp {
    pub fn new(pop: usize, hidden_act: Activation, final_act: Activation) -> Self {
        assert!(pop > 0, "population must be non-empty");
        PopMlp {
            pop,
            layers: Vec::new(),
            hidden_act,
            final_act,
            scratch: [Vec::new(), Vec::new()],
            kernel: None,
        }
    }

    /// Force a mat-mat kernel for THIS net (A/B benches and parity
    /// tests); `None` restores the process-wide selection.
    pub fn set_kernel(&mut self, kernel: Option<MatKernel>) {
        self.kernel = kernel;
    }

    /// Bytes currently reserved by the double-buffered forward scratch.
    pub fn scratch_bytes(&self) -> usize {
        self.scratch.iter().map(|s| s.capacity() * std::mem::size_of::<f32>()).sum()
    }

    /// Pre-size the forward scratch for `rows`-row blocks so the hot
    /// path never allocates and [`Self::scratch_bytes`] reports the
    /// steady-state footprint already at spawn.
    pub fn reserve_scratch(&mut self, rows: usize) {
        let wide = self.layers.iter().map(|l| l.in_dim.max(l.out_dim)).max().unwrap_or(0);
        for s in &mut self.scratch {
            s.reserve(rows * wide);
        }
    }

    pub fn pop(&self) -> usize {
        self.pop
    }

    /// Append a layer; `w` is `[P, in, out]` flat, `b` is `[P, out]` flat.
    pub fn push_layer(&mut self, w: Vec<f32>, b: Vec<f32>, in_dim: usize, out_dim: usize) {
        assert_eq!(w.len(), self.pop * in_dim * out_dim, "weight size mismatch");
        assert_eq!(b.len(), self.pop * out_dim, "bias size mismatch");
        if let Some(last) = self.layers.last() {
            assert_eq!(in_dim, last.out_dim, "layer dim chain mismatch");
        }
        self.layers.push(PopLayer { w, b, in_dim, out_dim });
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn in_dim(&self) -> usize {
        self.layers.first().map(|l| l.in_dim).unwrap_or(0)
    }

    pub fn out_dim(&self) -> usize {
        self.layers.last().map(|l| l.out_dim).unwrap_or(0)
    }

    /// One member's `(w, b)` slices of layer `li` (`[in, out]` / `[out]`).
    pub fn member_layer(&self, member: usize, li: usize) -> (&[f32], &[f32]) {
        assert!(member < self.pop, "member out of range");
        let l = &self.layers[li];
        let ws = l.in_dim * l.out_dim;
        (
            &l.w[member * ws..(member + 1) * ws],
            &l.b[member * l.out_dim..(member + 1) * l.out_dim],
        )
    }

    /// Replace ONE member's weights of layer `li` in place.
    pub fn set_member_layer(&mut self, member: usize, li: usize, w: &[f32], b: &[f32]) {
        assert!(member < self.pop, "member out of range");
        let l = &mut self.layers[li];
        let ws = l.in_dim * l.out_dim;
        assert_eq!(w.len(), ws, "weight size mismatch");
        assert_eq!(b.len(), l.out_dim, "bias size mismatch");
        l.w[member * ws..(member + 1) * ws].copy_from_slice(w);
        l.b[member * l.out_dim..(member + 1) * l.out_dim].copy_from_slice(b);
    }

    /// Replace ALL members' weights of layer `li` from packed `[P, in, out]`
    /// / `[P, out]` slices — one memcpy per array.
    pub fn set_layer_packed(&mut self, li: usize, w: &[f32], b: &[f32]) {
        let l = &mut self.layers[li];
        assert_eq!(w.len(), l.w.len(), "weight size mismatch");
        assert_eq!(b.len(), l.b.len(), "bias size mismatch");
        l.w.copy_from_slice(w);
        l.b.copy_from_slice(b);
    }

    /// Refresh every member from a host copy of the flat train state in one
    /// pass: the manifest stores `{prefix}/w{l}` as `[P, in, out]` flat —
    /// exactly this net's packing — so each layer is one contiguous copy
    /// per field (no per-agent strided reads).
    pub fn sync_from_state(
        &mut self,
        artifact: &Artifact,
        state: &[f32],
        prefix: &str,
    ) -> anyhow::Result<()> {
        for li in 0..self.layers.len() {
            let w = artifact.read(state, &format!("{prefix}/w{li}"))?;
            let b = artifact.read(state, &format!("{prefix}/b{li}"))?;
            self.set_layer_packed(li, w, b);
        }
        Ok(())
    }

    /// Forward an observation block `obs: [n, in_dim]` in one call; row `k`
    /// uses member `members[k]`'s weights. Writes `out: [n, out_dim]`.
    /// Consecutive rows with the same member are forwarded as one
    /// row-blocked mat-mat.
    pub fn forward_block(&mut self, members: &[usize], obs: &[f32], out: &mut [f32]) {
        let n = members.len();
        assert!(self.num_layers() > 0, "forward on empty PopMlp");
        assert_eq!(obs.len(), n * self.in_dim(), "obs dim mismatch");
        assert_eq!(out.len(), n * self.out_dim(), "out dim mismatch");
        debug_assert!(members.iter().all(|&m| m < self.pop), "member out of range");
        let n_layers = self.layers.len();
        // Resolve the kernel once per pass: instance override beats the
        // process-wide selection.
        let kernel = self.kernel.unwrap_or_else(kernels::mat_kernel);
        // Double-buffer through scratch to stay allocation-free: take the
        // buffers out of `self` for the duration of the pass.
        let mut src = std::mem::take(&mut self.scratch[0]);
        let mut dst = std::mem::take(&mut self.scratch[1]);
        src.clear();
        src.extend_from_slice(obs);
        for (li, layer) in self.layers.iter().enumerate() {
            let act = if li + 1 == n_layers { self.final_act } else { self.hidden_act };
            let (i, o) = (layer.in_dim, layer.out_dim);
            dst.resize(n * o, 0.0);
            let ws = i * o;
            let mut row = 0;
            while row < n {
                let m = members[row];
                let mut end = row + 1;
                while end < n && members[end] == m {
                    end += 1;
                }
                matmat_with(
                    kernel,
                    &layer.w[m * ws..(m + 1) * ws],
                    &layer.b[m * o..(m + 1) * o],
                    &src[row * i..end * i],
                    &mut dst[row * o..end * o],
                    i,
                    o,
                    end - row,
                    act,
                );
                row = end;
            }
            std::mem::swap(&mut src, &mut dst);
        }
        out.copy_from_slice(&src[..out.len()]);
        self.scratch[0] = src;
        self.scratch[1] = dst;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{Artifact, Dtype, EnvDesc, Field};
    use crate::nn::mlp::Mlp;
    use crate::util::rng::Rng;

    /// Random per-member layer stack [(w, b); layers] for given dims.
    fn random_members(
        rng: &mut Rng,
        pop: usize,
        dims: &[usize],
    ) -> Vec<Vec<(Vec<f32>, Vec<f32>)>> {
        (0..pop)
            .map(|_| {
                dims.windows(2)
                    .map(|d| {
                        let mut w = vec![0.0f32; d[0] * d[1]];
                        let mut b = vec![0.0f32; d[1]];
                        rng.fill_normal(&mut w, 0.7);
                        rng.fill_normal(&mut b, 0.3);
                        (w, b)
                    })
                    .collect()
            })
            .collect()
    }

    fn pack(members: &[Vec<(Vec<f32>, Vec<f32>)>], dims: &[usize]) -> PopMlp {
        let mut net = PopMlp::new(members.len(), Activation::Relu, Activation::Tanh);
        for (li, d) in dims.windows(2).enumerate() {
            let mut w = Vec::new();
            let mut b = Vec::new();
            for m in members {
                w.extend_from_slice(&m[li].0);
                b.extend_from_slice(&m[li].1);
            }
            net.push_layer(w, b, d[0], d[1]);
        }
        net
    }

    #[test]
    fn forward_block_matches_scalar_members() {
        let mut rng = Rng::new(20);
        for &pop in &[1usize, 4, 16] {
            let dims = [3usize, 8, 5, 2];
            let members = random_members(&mut rng, pop, &dims);
            let mut net = pack(&members, &dims);
            // one row per member plus some duplicate/reordered rows
            let mut ids: Vec<usize> = (0..pop).collect();
            ids.push(0);
            ids.push(pop - 1);
            let mut obs = vec![0.0f32; ids.len() * dims[0]];
            rng.fill_normal(&mut obs, 1.0);
            let mut out = vec![0.0f32; ids.len() * dims[3]];
            net.forward_block(&ids, &obs, &mut out);
            for (k, &m) in ids.iter().enumerate() {
                let mut scalar = Mlp::new(Activation::Relu, Activation::Tanh);
                for (li, d) in dims.windows(2).enumerate() {
                    scalar.push_layer(
                        members[m][li].0.clone(),
                        members[m][li].1.clone(),
                        d[0],
                        d[1],
                    );
                }
                let want = scalar.forward_vec(&obs[k * dims[0]..(k + 1) * dims[0]]);
                for (j, &wv) in want.iter().enumerate() {
                    let gv = out[k * dims[3] + j];
                    assert!(
                        (gv - wv).abs() < 1e-5,
                        "pop {pop} row {k} member {m} out {j}: {gv} vs {wv}"
                    );
                }
            }
        }
    }

    #[test]
    fn sync_from_state_is_one_pass_per_field() {
        let (pop, i, o) = (3usize, 2usize, 4usize);
        let fields = vec![
            Field {
                name: "policy/w0".into(),
                offset: 0,
                size: pop * i * o,
                shape: vec![pop, i, o],
                dtype: Dtype::F32,
                init: "zeros".into(),
                group: "policy".into(),
                per_agent: true,
            },
            Field {
                name: "policy/b0".into(),
                offset: pop * i * o,
                size: pop * o,
                shape: vec![pop, o],
                dtype: Dtype::F32,
                init: "zeros".into(),
                group: "policy".into(),
                per_agent: true,
            },
        ];
        let state_size = pop * i * o + pop * o;
        let art = Artifact::new(
            "t".into(),
            std::path::PathBuf::new(),
            "td3".into(),
            "pendulum".into(),
            EnvDesc::default(),
            pop,
            1,
            4,
            vec![],
            state_size,
            "state".into(),
            vec![],
            fields,
            vec![],
        );
        let state: Vec<f32> = (0..state_size).map(|v| v as f32).collect();
        let mut net = PopMlp::new(pop, Activation::None, Activation::None);
        net.push_layer(vec![0.0; pop * i * o], vec![0.0; pop * o], i, o);
        net.sync_from_state(&art, &state, "policy").unwrap();
        for m in 0..pop {
            let (w, b) = net.member_layer(m, 0);
            assert_eq!(w[0], (m * i * o) as f32);
            assert_eq!(b[0], (pop * i * o + m * o) as f32);
        }
    }

    /// Reference vs tiled kernel through the same net: forward_block is
    /// kernel-parity (≤1e-5) whichever dispatch is forced.
    #[test]
    fn forward_block_kernel_override_parity() {
        let mut rng = Rng::new(21);
        let dims = [7usize, 33, 12];
        let members = random_members(&mut rng, 4, &dims);
        let mut net = pack(&members, &dims);
        let ids = [0usize, 1, 1, 2, 3, 3, 3];
        let mut obs = vec![0.0f32; ids.len() * dims[0]];
        rng.fill_normal(&mut obs, 1.0);
        let mut reference = vec![0.0f32; ids.len() * dims[2]];
        let mut tiled = vec![0.0f32; ids.len() * dims[2]];
        net.set_kernel(Some(MatKernel::Reference));
        net.forward_block(&ids, &obs, &mut reference);
        net.set_kernel(Some(MatKernel::Tiled));
        net.forward_block(&ids, &obs, &mut tiled);
        for (k, (&r, &t)) in reference.iter().zip(&tiled).enumerate() {
            assert!((r - t).abs() < 1e-5, "lane {k}: {r} vs {t}");
        }
    }

    #[test]
    fn scratch_accounting_reports_reserved_bytes() {
        let mut rng = Rng::new(22);
        let dims = [3usize, 16, 2];
        let members = random_members(&mut rng, 2, &dims);
        let mut net = pack(&members, &dims);
        assert_eq!(net.scratch_bytes(), 0, "no scratch before first use");
        net.reserve_scratch(8);
        // two buffers, each at least 8 rows x the widest dim (16 lanes)
        assert!(net.scratch_bytes() >= 2 * 8 * 16 * 4, "{}", net.scratch_bytes());
        let before = net.scratch_bytes();
        let mut out = vec![0.0f32; 4 * dims[2]];
        let mut obs = vec![0.0f32; 4 * dims[0]];
        rng.fill_normal(&mut obs, 1.0);
        net.forward_block(&[0, 0, 1, 1], &obs, &mut out);
        assert_eq!(net.scratch_bytes(), before, "reserve covers the forward pass");
    }

    #[test]
    #[should_panic(expected = "layer dim chain mismatch")]
    fn mismatched_chain_panics() {
        let mut net = PopMlp::new(1, Activation::Relu, Activation::None);
        net.push_layer(vec![0.0; 6], vec![0.0; 3], 2, 3);
        net.push_layer(vec![0.0; 4], vec![0.0; 2], 2, 2); // in != prev out
    }
}
