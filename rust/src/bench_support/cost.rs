//! Cloud-cost model (paper Table 1): averaged posted $/h over three cloud
//! platforms for every accelerator the paper benchmarks, plus the
//! CPU-core baseline. Fig 3 divides measured runtimes by these prices.

/// (name, dollars per hour) — paper Table 1, verbatim.
pub const PRICES: &[(&str, f64)] = &[
    ("K80", 0.45),
    ("T4", 0.34),
    ("V100", 2.61),
    ("A100", 2.98),
    ("CPU_CORE", 0.062), // one Intel Xeon 2.80GHz core with 2GB RAM
];

pub fn price_per_hour(accelerator: &str) -> Option<f64> {
    PRICES.iter().find(|(n, _)| *n == accelerator).map(|(_, p)| *p)
}

/// Dollars spent running `seconds` of wall time on `accelerator`.
pub fn cost_of(accelerator: &str, seconds: f64) -> Option<f64> {
    price_per_hour(accelerator).map(|p| p * seconds / 3600.0)
}

/// Relative speedup-per-dollar of accelerator vs the CPU-per-agent
/// baseline (Fig 3's two panels: runtime ratio and cost ratio).
///
/// * `acc_seconds`: measured update-step time on the accelerator
///   (whole population, vectorized).
/// * `cpu_seconds`: measured update-step time of ONE agent on one core
///   (the baseline allocates one core per agent, so its wall time is
///   constant in population size while its cost scales with it).
pub fn fig3_ratios(accelerator: &str, acc_seconds: f64, cpu_seconds: f64,
                   pop: usize) -> Option<(f64, f64)> {
    let acc_price = price_per_hour(accelerator)?;
    let cpu_price = price_per_hour("CPU_CORE")?;
    let runtime_ratio = acc_seconds / cpu_seconds;
    let acc_cost = acc_price * acc_seconds;
    let cpu_cost = cpu_price * cpu_seconds * pop as f64;
    Some((runtime_ratio, acc_cost / cpu_cost))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_prices_present() {
        for name in ["K80", "T4", "V100", "A100", "CPU_CORE"] {
            assert!(price_per_hour(name).is_some(), "{name}");
        }
        assert_eq!(price_per_hour("TPU"), None);
        assert!((price_per_hour("T4").unwrap() - 0.34).abs() < 1e-12);
    }

    #[test]
    fn cost_scales_linearly() {
        let c1 = cost_of("A100", 3600.0).unwrap();
        assert!((c1 - 2.98).abs() < 1e-9);
        let c2 = cost_of("A100", 1800.0).unwrap();
        assert!((c1 / c2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn fig3_cpu_baseline_cost_grows_with_pop() {
        // same measured times, doubling pop halves relative accel cost
        let (_, cost_ratio_10) = fig3_ratios("T4", 1.0, 1.0, 10).unwrap();
        let (_, cost_ratio_20) = fig3_ratios("T4", 1.0, 1.0, 20).unwrap();
        assert!((cost_ratio_10 / cost_ratio_20 - 2.0).abs() < 1e-9);
    }
}
