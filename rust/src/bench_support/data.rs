//! Shared helpers for the bench binaries: synthetic preloaded batches and
//! artifact-sweep utilities.

use crate::manifest::{Artifact, Dtype, Manifest};
use crate::runtime::Runtime;
use crate::util::rng::Rng;

/// Upload one set of random batches for an artifact (the paper's protocol
/// preloads training data on the accelerator before timing update steps).
pub fn random_batches(rt: &Runtime, art: &Artifact, rng: &mut Rng)
                      -> anyhow::Result<Vec<xla::PjRtBuffer>> {
    let mut out = Vec::new();
    for inp in &art.inputs[1..] {
        let n = inp.numel();
        let buf = match inp.dtype {
            Dtype::I32 => {
                let data: Vec<i32> = (0..n).map(|_| rng.below(3) as i32).collect();
                rt.upload_i32(&data, &inp.shape)?
            }
            _ => {
                let mut data = vec![0.0f32; n];
                if inp.name == "done" {
                    for v in data.iter_mut() {
                        *v = (rng.below(10) == 0) as u8 as f32;
                    }
                } else {
                    rng.fill_normal(&mut data, 0.5);
                }
                rt.upload_f32(&data, &inp.shape)?
            }
        };
        out.push(buf);
    }
    Ok(out)
}

/// The paper's network size — sweeps are restricted to artifacts with
/// this hidden geometry so population sizes are comparable.
pub const PAPER_HIDDEN: &[usize] = &[256, 256];

/// All pops for which an (algo, env, num_steps) artifact with the paper's
/// hidden sizes exists, sorted.
pub fn available_pops(m: &Manifest, algo: &str, env: &str, num_steps: usize)
                      -> Vec<usize> {
    let mut pops: Vec<usize> = m
        .artifacts
        .values()
        .filter(|a| a.algo == algo && a.env == env && a.num_steps == num_steps
                && a.output == "state" && a.hidden == PAPER_HIDDEN)
        .map(|a| a.pop)
        .collect();
    pops.sort_unstable();
    pops.dedup();
    pops
}

/// Warn once when a sweep is empty because bench artifacts are missing.
pub fn require_artifacts(pops: &[usize], what: &str) -> bool {
    if pops.is_empty() {
        eprintln!(
            "[bench] no artifacts for {what}; run `make bench-artifacts` first \
             (skipping this sweep)"
        );
        return false;
    }
    true
}
