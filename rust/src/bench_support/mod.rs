//! Benchmark harness + cost model (criterion is not in the image).
pub mod cost;
pub mod data;
pub mod harness;
