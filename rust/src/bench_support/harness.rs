//! Micro-benchmark harness (criterion is not in the image): warmup +
//! timed iterations with mean/std/percentiles, CSV-friendly reporting.

use crate::util::stats::{percentile, Running};
use crate::telemetry::Stopwatch;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub min_ms: f64,
}

impl BenchResult {
    pub fn csv_header() -> &'static str {
        "name,iters,mean_ms,std_ms,p50_ms,p90_ms,min_ms"
    }

    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{:.4},{:.4},{:.4},{:.4},{:.4}",
            self.name, self.iters, self.mean_ms, self.std_ms, self.p50_ms,
            self.p90_ms, self.min_ms
        )
    }
}

pub struct Bench {
    pub warmup_iters: usize,
    pub iters: usize,
    /// Stop early once this much wall time was spent measuring (0 = never).
    pub max_seconds: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 3, iters: 20, max_seconds: 30.0 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup_iters: 1, iters: 5, max_seconds: 10.0 }
    }

    /// Time `f` (one call = one measured iteration).
    pub fn run(&self, name: &str, mut f: impl FnMut()) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        let mut stats = Running::new();
        let total = Stopwatch::start();
        for _ in 0..self.iters {
            let sw = Stopwatch::start();
            f();
            let ms = sw.elapsed_ms();
            samples.push(ms);
            stats.push(ms);
            if self.max_seconds > 0.0 && total.elapsed_s() > self.max_seconds {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean_ms: stats.mean(),
            std_ms: stats.std(),
            p50_ms: percentile(&samples, 50.0),
            p90_ms: percentile(&samples, 90.0),
            min_ms: samples[0],
        }
    }
}

/// Arithmetic throughput in GFLOP/s given the flop count of ONE measured
/// iteration and its mean wall time — the kernel-bench figure of merit
/// (`flops / (ms * 1e6)` since 1 ms = 1e6 ns and 1 GFLOP = 1e9 flops).
pub fn gflops(flops_per_iter: f64, mean_ms: f64) -> f64 {
    if mean_ms <= 0.0 {
        0.0
    } else {
        flops_per_iter / (mean_ms * 1e6)
    }
}

/// Write results to stdout (pretty) and `results/<file>.csv`.
pub fn report(file: &str, results: &[BenchResult]) -> anyhow::Result<()> {
    std::fs::create_dir_all("results")?;
    let path = format!("results/{file}.csv");
    let mut text = String::from(BenchResult::csv_header());
    text.push('\n');
    println!("\n== {file} ==");
    println!("{:<48} {:>8} {:>10} {:>10}", "name", "iters", "mean_ms", "p50_ms");
    for r in results {
        println!("{:<48} {:>8} {:>10.3} {:>10.3}", r.name, r.iters, r.mean_ms, r.p50_ms);
        text.push_str(&r.csv_row());
        text.push('\n');
    }
    std::fs::write(&path, text)?;
    println!("-> {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep() {
        let b = Bench { warmup_iters: 1, iters: 5, max_seconds: 0.0 };
        let r = b.run("sleep", || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert_eq!(r.iters, 5);
        assert!(r.mean_ms >= 1.5, "mean {}", r.mean_ms);
        assert!(r.min_ms <= r.p50_ms && r.p50_ms <= r.p90_ms);
    }

    #[test]
    fn gflops_converts_flops_and_ms() {
        // 2e9 flops in 1000 ms = 2 GFLOP/s
        assert!((gflops(2e9, 1000.0) - 2.0).abs() < 1e-12);
        assert_eq!(gflops(1e9, 0.0), 0.0, "degenerate timing must not divide by zero");
    }

    #[test]
    fn bench_respects_time_budget() {
        let b = Bench { warmup_iters: 0, iters: 1000, max_seconds: 0.05 };
        let r = b.run("sleep", || std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(r.iters < 1000);
    }
}
