//! # FastPBRL
//!
//! Rust + JAX + Pallas reproduction of *"Fast Population-Based
//! Reinforcement Learning on a Single Machine"* (Flajolet et al., ICML
//! 2022): train a population of N RL agents on one machine with one
//! accelerator at barely more than the cost of a single agent, by
//! vectorizing the update step over the population.
//!
//! Architecture (see `DESIGN.md`):
//! * **L1** — Pallas population-batched linear kernel (build time,
//!   `python/compile/kernels/`).
//! * **L2** — jax population update steps for TD3/SAC/DQN/CEM-RL/DvD over
//!   a flat train-state vector, AOT-lowered to HLO text
//!   (`python/compile/updates/`, `aot.py`).
//! * **L3** — this crate: the coordinator that owns environments, replay,
//!   actors, PBT/CEM/DvD controllers, and executes the lowered update
//!   steps through PJRT with device-resident state.

// Block-structured hot paths (replay inserts/samples, vectorized env
// steps, conv kernels) pass their parallel `[n, ...]` field slices as
// separate arguments by design; the argument-count lint fights that idiom.
#![allow(clippy::too_many_arguments)]

pub mod bench_support;
pub mod coordinator;
pub mod data;
pub mod envs;
pub mod manifest;
pub mod nn;
pub mod replay;
pub mod runtime;
pub mod telemetry;
pub mod util;

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Default results directory for benches/examples.
pub const RESULTS_DIR: &str = "results";
