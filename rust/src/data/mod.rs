//! Actor/learner data pipeline (paper Appendix A).
pub mod pipeline;
