//! Actor/learner data pipeline (paper Appendix A): block transport for
//! the continuous-control AND pixel/DQN actor paths (see
//! [`pipeline::BlockPool`] and its two instantiations,
//! [`pipeline::ActorPool`] and [`pipeline::PixelActorPool`]).
pub mod pipeline;
pub mod supervisor;
