//! Actor-pool supervision: structured exit events, per-thread heartbeats,
//! restart bookkeeping with capped exponential backoff, and (behind the
//! `fault-inject` feature) a deterministic fault-injection plan.
//!
//! The paper's premise — a population trains at barely more than the cost
//! of one agent — only holds if one bad actor thread cannot cost the whole
//! multi-hour run. The pieces here let the learner treat its actor pool
//! like a supervised process tree: every thread body runs under
//! `catch_unwind` and reports an [`ActorExit`] on the pool's event
//! channel; every thread bumps a [`Heartbeats`] slot each loop iteration
//! so a learner-side watchdog can flag stalls; and a [`RestartTracker`]
//! decides when a dead thread may be respawned (capped exponential
//! backoff, bounded by `max_restarts` per thread).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Why an actor thread's loop ended.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ExitCause {
    /// Clean exit: the stop flag was observed (or the channel closed).
    Finished,
    /// The loop body panicked; the payload's message, when extractable.
    Panic(String),
}

impl ExitCause {
    /// Does this exit warrant a respawn? Clean stops do not.
    pub fn is_failure(&self) -> bool {
        matches!(self, ExitCause::Panic(_))
    }
}

/// Structured report sent by a dying actor thread over the pool's event
/// channel — the learner's only reliable signal that a thread is gone
/// (a panic inside `std::thread::spawn` is otherwise silent, and the
/// learner would just watch a slowly starving block channel).
#[derive(Clone, Debug)]
pub struct ActorExit {
    /// Actor-thread index within the pool.
    pub thread: usize,
    /// Agents the thread owned (round-robin partition at spawn).
    pub agents: Vec<usize>,
    pub cause: ExitCause,
}

/// Extract a human-readable message from a `catch_unwind` payload.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

/// Per-thread liveness timestamps. Each actor stores "millis since the
/// pool's epoch" into its slot once per loop iteration (one relaxed
/// atomic store — noise next to an env step); the learner-side watchdog
/// reads them to flag threads that have neither produced blocks nor
/// exited: livelocks, runaway env steps, injected stalls.
#[derive(Clone)]
pub struct Heartbeats {
    epoch: Instant,
    beats: Arc<Vec<AtomicU64>>,
}

impl Heartbeats {
    pub fn new(threads: usize) -> Self {
        Heartbeats {
            epoch: Instant::now(),
            beats: Arc::new((0..threads).map(|_| AtomicU64::new(0)).collect()),
        }
    }

    pub fn threads(&self) -> usize {
        self.beats.len()
    }

    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    /// Record that `thread` is alive right now.
    pub fn beat(&self, thread: usize) {
        if let Some(b) = self.beats.get(thread) {
            b.store(self.now_ms(), Ordering::Relaxed);
        }
    }

    /// Milliseconds since `thread` last beat.
    pub fn millis_since(&self, thread: usize) -> u64 {
        match self.beats.get(thread) {
            Some(b) => self.now_ms().saturating_sub(b.load(Ordering::Relaxed)),
            None => 0,
        }
    }

    /// Is `thread` stalled under the given timeout? `timeout_ms == 0`
    /// disables the watchdog.
    pub fn is_stalled(&self, thread: usize, timeout_ms: u64) -> bool {
        timeout_ms > 0 && self.millis_since(thread) > timeout_ms
    }
}

/// Restart limits for failed actor threads.
#[derive(Clone, Copy, Debug)]
pub struct RestartPolicy {
    /// Respawns allowed per thread over the run (0 = never respawn).
    pub max_restarts: u32,
    /// First-restart backoff; doubles per subsequent restart.
    pub backoff_base_ms: u64,
    /// Backoff growth cap.
    pub backoff_cap_ms: u64,
}

impl Default for RestartPolicy {
    fn default() -> Self {
        RestartPolicy { max_restarts: 3, backoff_base_ms: 100, backoff_cap_ms: 5_000 }
    }
}

impl RestartPolicy {
    /// Backoff before restart number `restart` (1-based): capped
    /// exponential `base * 2^(restart-1)`.
    pub fn backoff(&self, restart: u32) -> Duration {
        let exp = restart.saturating_sub(1).min(16);
        let ms = self.backoff_base_ms.saturating_mul(1u64 << exp);
        Duration::from_millis(ms.min(self.backoff_cap_ms))
    }
}

/// Outcome of reporting a thread failure to the tracker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RestartDecision {
    /// Respawn once the backoff elapses (poll [`RestartTracker::due`]).
    Scheduled,
    /// The thread exhausted its restart budget; its agents stay down.
    GaveUp,
}

#[derive(Clone, Copy, Debug, Default)]
struct ThreadRestarts {
    restarts: u32,
    pending_at: Option<Instant>,
    gave_up: bool,
}

/// Learner-side bookkeeping of actor-thread failures: which threads are
/// waiting out a backoff, which are out of budget, and how many restarts
/// happened in total (the `Summary.actor_restarts` metric). Time is
/// passed in by the caller so the schedule is testable without sleeping.
pub struct RestartTracker {
    policy: RestartPolicy,
    threads: Vec<ThreadRestarts>,
}

impl RestartTracker {
    pub fn new(policy: RestartPolicy, threads: usize) -> Self {
        RestartTracker { policy, threads: vec![ThreadRestarts::default(); threads] }
    }

    /// Record a thread failure; schedules a respawn or gives up.
    pub fn on_failure(&mut self, thread: usize, now: Instant) -> RestartDecision {
        let Some(t) = self.threads.get_mut(thread) else { return RestartDecision::GaveUp };
        if t.gave_up || t.restarts >= self.policy.max_restarts {
            t.gave_up = true;
            return RestartDecision::GaveUp;
        }
        t.restarts += 1;
        t.pending_at = Some(now + self.policy.backoff(t.restarts));
        RestartDecision::Scheduled
    }

    /// Threads whose backoff has elapsed — respawn them now. Each thread
    /// is returned once per scheduled restart.
    pub fn due(&mut self, now: Instant) -> Vec<usize> {
        let mut out = Vec::new();
        for (i, t) in self.threads.iter_mut().enumerate() {
            if let Some(at) = t.pending_at {
                if now >= at {
                    t.pending_at = None;
                    out.push(i);
                }
            }
        }
        out
    }

    /// Total restarts performed (scheduled) across all threads.
    pub fn total_restarts(&self) -> u64 {
        self.threads.iter().map(|t| t.restarts as u64).sum()
    }

    /// Threads that exhausted their restart budget.
    pub fn gave_up(&self) -> Vec<usize> {
        self.threads
            .iter()
            .enumerate()
            .filter(|(_, t)| t.gave_up)
            .map(|(i, _)| i)
            .collect()
    }
}

/// Deterministic fault injection for resilience tests: panics and stalls
/// keyed on (actor thread, loop iteration), NaN-poisoning keyed on
/// (population member, learner update count). Compiled only under the
/// `fault-inject` feature so release builds carry zero overhead; faults
/// fire on an actor's first incarnation only (`generation == 0`), so a
/// respawned thread proves the recovery path instead of re-dying.
#[cfg(feature = "fault-inject")]
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// `(thread, iteration)`: panic that actor loop at that iteration.
    pub actor_panics: Vec<(usize, usize)>,
    /// `(thread, iteration, millis)`: sleep that long at that iteration.
    pub actor_stalls: Vec<(usize, usize, u64)>,
    /// `(member, update)`: NaN-poison that member's params once the
    /// learner passes that many updates.
    pub nan_members: Vec<(usize, u64)>,
    /// Absolute update counts at which the learner's next update-step
    /// execution reports a simulated PJRT device loss (each threshold
    /// fires once per trainer; the message classifies as
    /// `FaultKind::DeviceLost`, exercising the rebuild-and-re-upload
    /// recovery path in place).
    pub device_errors: Vec<u64>,
    /// `abort()` the whole trainer process at the first sync point whose
    /// absolute update count reaches this threshold. Fires only in a
    /// trainer that did NOT resume from a checkpoint (the run's first
    /// incarnation), mirroring the generation-0 gating of actor faults:
    /// the watchdog-restarted process proves the recovery path instead
    /// of re-dying forever.
    pub process_abort: Option<u64>,
}

#[cfg(feature = "fault-inject")]
impl FaultPlan {
    /// Actor-side hook, called at the top of each loop iteration.
    /// Panics when the plan says so (first incarnation only).
    pub fn actor_tick(&self, thread: usize, iteration: usize, generation: u64) {
        if generation != 0 {
            return;
        }
        for &(t, at, ms) in &self.actor_stalls {
            if t == thread && at == iteration {
                std::thread::sleep(Duration::from_millis(ms));
            }
        }
        for &(t, at) in &self.actor_panics {
            if t == thread && at == iteration {
                panic!("fault-inject: planned panic (thread {thread}, iteration {iteration})");
            }
        }
    }

    /// Members whose poisoning update threshold is now crossed.
    pub fn members_due(&self, updates_done: u64) -> Vec<usize> {
        self.nan_members
            .iter()
            .filter(|&&(_, at)| updates_done >= at)
            .map(|&(m, _)| m)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RestartPolicy { max_restarts: 10, backoff_base_ms: 100, backoff_cap_ms: 1000 };
        assert_eq!(p.backoff(1), Duration::from_millis(100));
        assert_eq!(p.backoff(2), Duration::from_millis(200));
        assert_eq!(p.backoff(3), Duration::from_millis(400));
        assert_eq!(p.backoff(4), Duration::from_millis(800));
        assert_eq!(p.backoff(5), Duration::from_millis(1000)); // capped
        assert_eq!(p.backoff(30), Duration::from_millis(1000)); // shift-safe
    }

    #[test]
    fn tracker_schedules_until_budget_then_gives_up() {
        let p = RestartPolicy { max_restarts: 2, backoff_base_ms: 10, backoff_cap_ms: 100 };
        let mut tr = RestartTracker::new(p, 2);
        let t0 = Instant::now();
        assert_eq!(tr.on_failure(0, t0), RestartDecision::Scheduled);
        // not due before the backoff elapses
        assert!(tr.due(t0).is_empty());
        assert_eq!(tr.due(t0 + Duration::from_millis(10)), vec![0]);
        // second failure: longer backoff, still within budget
        assert_eq!(tr.on_failure(0, t0), RestartDecision::Scheduled);
        assert!(tr.due(t0 + Duration::from_millis(10)).is_empty());
        assert_eq!(tr.due(t0 + Duration::from_millis(20)), vec![0]);
        // budget exhausted
        assert_eq!(tr.on_failure(0, t0), RestartDecision::GaveUp);
        assert_eq!(tr.total_restarts(), 2);
        assert_eq!(tr.gave_up(), vec![0]);
        // other threads unaffected
        assert_eq!(tr.on_failure(1, t0), RestartDecision::Scheduled);
        // out-of-range thread ids never schedule
        assert_eq!(tr.on_failure(9, t0), RestartDecision::GaveUp);
    }

    #[test]
    fn zero_budget_never_respawns() {
        let p = RestartPolicy { max_restarts: 0, ..RestartPolicy::default() };
        let mut tr = RestartTracker::new(p, 1);
        assert_eq!(tr.on_failure(0, Instant::now()), RestartDecision::GaveUp);
        assert_eq!(tr.total_restarts(), 0);
    }

    #[test]
    fn heartbeats_flag_stalls_per_thread() {
        let hb = Heartbeats::new(2);
        assert_eq!(hb.threads(), 2);
        hb.beat(0);
        std::thread::sleep(Duration::from_millis(30));
        hb.beat(1);
        assert!(hb.millis_since(0) >= 25);
        assert!(hb.millis_since(1) < 25);
        assert!(hb.is_stalled(0, 20));
        assert!(!hb.is_stalled(1, 20));
        // timeout 0 disables the watchdog; unknown slots are never stalled
        assert!(!hb.is_stalled(0, 0));
        assert!(!hb.is_stalled(7, 20));
    }

    #[test]
    fn exit_cause_classifies_failures() {
        assert!(!ExitCause::Finished.is_failure());
        assert!(ExitCause::Panic("boom".into()).is_failure());
        let p: Box<dyn std::any::Any + Send> = Box::new("boom");
        assert_eq!(panic_message(p.as_ref()), "boom");
        let p: Box<dyn std::any::Any + Send> = Box::new(String::from("owned"));
        assert_eq!(panic_message(p.as_ref()), "owned");
        let p: Box<dyn std::any::Any + Send> = Box::new(42usize);
        assert_eq!(panic_message(p.as_ref()), "non-string panic payload");
    }

    #[cfg(feature = "fault-inject")]
    #[test]
    fn fault_plan_is_deterministic_and_generation_gated() {
        let plan = FaultPlan {
            actor_panics: vec![(0, 5)],
            actor_stalls: vec![(1, 2, 1)],
            nan_members: vec![(2, 100), (0, 50)],
            ..FaultPlan::default()
        };
        // wrong thread/iteration: no panic
        plan.actor_tick(0, 4, 0);
        plan.actor_tick(1, 5, 0);
        // respawned incarnation never re-fires
        plan.actor_tick(0, 5, 1);
        // the planned (thread, iteration) does panic
        let r = std::panic::catch_unwind(|| plan.actor_tick(0, 5, 0));
        assert!(r.is_err());
        assert!(plan.members_due(49).is_empty());
        assert_eq!(plan.members_due(50), vec![0]);
        let mut due = plan.members_due(200);
        due.sort();
        assert_eq!(due, vec![0, 2]);
    }
}
