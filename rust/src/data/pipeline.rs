//! Actor/learner data pipeline (paper Appendix A).
//!
//! Actor threads own their environment copies and native policy networks;
//! they publish transitions through a bounded channel (the paper's queue
//! with a maximum size — actors block when the learner lags) and refresh
//! their weights from the shared [`ParamView`] whenever the learner
//! publishes a new version (non-blocking for the learner).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::population::ParamView;
use crate::envs::make_env;
use crate::manifest::Artifact;
use crate::nn::from_state::{mlp_from_state, sync_mlp_from_state};
use crate::nn::mlp::Activation;
use crate::util::rng::Rng;

/// One environment transition from agent `agent`.
pub struct Transition {
    pub agent: usize,
    pub obs: Vec<f32>,
    pub act: Vec<f32>,
    pub rew: f32,
    pub next_obs: Vec<f32>,
    pub done: bool,
}

pub enum ActorMsg {
    Step(Transition),
    /// An episode finished with this undiscounted return.
    Episode { agent: usize, ret: f64, steps: usize },
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// Deterministic tanh policy + additive N(0, expl_noise) exploration.
    Td3,
    /// Squashed-Gaussian head `[mu, log_std]`; exploration = sampling.
    Sac,
}

impl PolicyKind {
    pub fn for_algo(algo: &str) -> PolicyKind {
        if algo.starts_with("sac") {
            PolicyKind::Sac
        } else {
            PolicyKind::Td3
        }
    }
}

pub struct ActorConfig {
    pub env: String,
    pub policy: PolicyKind,
    /// Uniform-random actions for this many initial steps per agent.
    pub warmup_steps: usize,
    /// TD3 exploration noise std (read from state field "expl_noise" when
    /// present, this is the fallback).
    pub expl_noise: f32,
    /// Bounded queue size (backpressure).
    pub queue_cap: usize,
    pub seed: u64,
    /// Update:env-step ratio target for actor throttling (0 = unthrottled).
    pub ratio: f64,
    /// Extra env steps actors may run ahead of `updates / ratio`.
    pub lead_steps: u64,
}

impl Default for ActorConfig {
    fn default() -> Self {
        ActorConfig {
            env: "pendulum".into(),
            policy: PolicyKind::Td3,
            warmup_steps: 500,
            expl_noise: 0.1,
            queue_cap: 4096,
            seed: 0,
            ratio: 1.0,
            lead_steps: 2048,
        }
    }
}

/// Shared counters for actor throttling (paper Appendix A: "agents are
/// blocked ... if the process handling the accelerator is lagging behind").
#[derive(Clone, Default)]
pub struct Throttle {
    /// Update steps completed by the learner.
    pub updates: Arc<AtomicU64>,
    /// Environment steps taken by all actors.
    pub env_steps: Arc<AtomicU64>,
}

impl Throttle {
    pub fn new() -> Self {
        Self::default()
    }

    /// May actors take another environment step?
    fn may_step(&self, cfg: &ActorConfig, pop: u64) -> bool {
        if cfg.ratio <= 0.0 {
            return true;
        }
        let env = self.env_steps.load(Ordering::Relaxed);
        let upd = self.updates.load(Ordering::Relaxed);
        let warmup = cfg.warmup_steps as u64 * pop;
        env < warmup + (upd as f64 / cfg.ratio) as u64 + cfg.lead_steps
    }
}

pub struct ActorPool {
    pub rx: Receiver<ActorMsg>,
    stop: Arc<AtomicBool>,
    handles: Vec<JoinHandle<()>>,
}

impl ActorPool {
    /// Spawn `n_threads` actor threads covering all `artifact.pop` agents.
    pub fn spawn(
        artifact: &Artifact,
        view: ParamView,
        cfg: ActorConfig,
        n_threads: usize,
        throttle: Throttle,
    ) -> anyhow::Result<ActorPool> {
        let pop = artifact.pop;
        let n_threads = n_threads.clamp(1, pop);
        let (tx, rx) = std::sync::mpsc::sync_channel(cfg.queue_cap);
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for t in 0..n_threads {
            let agents: Vec<usize> = (0..pop).filter(|a| a % n_threads == t).collect();
            let tx = tx.clone();
            let stop2 = stop.clone();
            let view2 = view.clone();
            let art = artifact.clone();
            let th = throttle.clone();
            let cfg2 = ActorConfig { seed: cfg.seed.wrapping_add(1000 + t as u64), ..clone_cfg(&cfg) };
            handles.push(std::thread::spawn(move || {
                actor_loop(&art, view2, &cfg2, &agents, tx, stop2, th);
            }));
        }
        Ok(ActorPool { rx, stop, handles })
    }

    pub fn stop(self) {
        self.stop.store(true, Ordering::Relaxed);
        // drain so blocked senders can observe the stop flag
        while self.rx.try_recv().is_ok() {}
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn clone_cfg(c: &ActorConfig) -> ActorConfig {
    ActorConfig {
        env: c.env.clone(),
        policy: c.policy,
        warmup_steps: c.warmup_steps,
        expl_noise: c.expl_noise,
        queue_cap: c.queue_cap,
        seed: c.seed,
        ratio: c.ratio,
        lead_steps: c.lead_steps,
    }
}

fn actor_loop(
    artifact: &Artifact,
    view: ParamView,
    cfg: &ActorConfig,
    agents: &[usize],
    tx: SyncSender<ActorMsg>,
    stop: Arc<AtomicBool>,
    throttle: Throttle,
) {
    let mut rng = Rng::new(cfg.seed);
    let mut envs: Vec<_> = agents.iter().map(|_| make_env(&cfg.env).unwrap()).collect();
    let (ha, fa) = match cfg.policy {
        PolicyKind::Td3 => (Activation::Relu, Activation::Tanh),
        PolicyKind::Sac => (Activation::Relu, Activation::None),
    };
    let mut host = Vec::new();
    let mut version = view.fetch_if_newer(0, &mut host);
    let mut mlps: Vec<_> = agents
        .iter()
        .map(|&a| mlp_from_state(artifact, &host, "policy", a, ha, fa).unwrap())
        .collect();

    let obs_dim = envs[0].obs_dim();
    let act_dim = envs[0].act_dim();
    let mut obs: Vec<Vec<f32>> = envs
        .iter_mut()
        .map(|e| {
            let mut o = vec![0.0; obs_dim];
            e.reset(&mut rng, &mut o);
            o
        })
        .collect();
    let mut ep_ret = vec![0.0f64; agents.len()];
    let mut ep_steps = vec![0usize; agents.len()];
    let mut steps_taken = vec![0usize; agents.len()];
    let mut raw = vec![0.0f32; mlps[0].out_dim()];
    let mut act = vec![0.0f32; act_dim];
    let mut next_obs = vec![0.0f32; obs_dim];

    let pop_total = artifact.pop as u64;
    'outer: loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // Ratio throttling: wait while actors are too far ahead of the
        // learner (paper Appendix A blocking rule).
        if !throttle.may_step(cfg, pop_total) {
            std::thread::sleep(std::time::Duration::from_micros(200));
            continue;
        }
        // Non-blocking parameter refresh.
        let v2 = view.fetch_if_newer(version, &mut host);
        if v2 > version {
            version = v2;
            for (k, &a) in agents.iter().enumerate() {
                let _ = sync_mlp_from_state(artifact, &host, "policy", a, &mut mlps[k]);
            }
        }
        for (k, &agent) in agents.iter().enumerate() {
            // action selection
            if steps_taken[k] < cfg.warmup_steps {
                rng.fill_uniform(&mut act, -1.0, 1.0);
            } else {
                mlps[k].forward(&obs[k], &mut raw);
                select_action(cfg.policy, &raw, &mut act, expl_noise_for(
                    artifact, &host, agent, cfg.expl_noise), &mut rng);
            }
            let (rew, done) = envs[k].step(&act, &mut next_obs);
            ep_ret[k] += rew as f64;
            ep_steps[k] += 1;
            steps_taken[k] += 1;
            throttle.env_steps.fetch_add(1, Ordering::Relaxed);
            let horizon_hit = ep_steps[k] >= envs[k].horizon();
            let msg = ActorMsg::Step(Transition {
                agent,
                obs: obs[k].clone(),
                act: act.clone(),
                rew,
                next_obs: next_obs.clone(),
                done,
            });
            if send_blocking(&tx, msg, &stop).is_err() {
                break 'outer;
            }
            obs[k].copy_from_slice(&next_obs);
            if done || horizon_hit {
                let ep = ActorMsg::Episode { agent, ret: ep_ret[k], steps: ep_steps[k] };
                if send_blocking(&tx, ep, &stop).is_err() {
                    break 'outer;
                }
                ep_ret[k] = 0.0;
                ep_steps[k] = 0;
                envs[k].reset(&mut rng, &mut obs[k]);
            }
        }
    }
}

/// Per-agent exploration noise from the state when the field exists.
fn expl_noise_for(artifact: &Artifact, host: &[f32], agent: usize, fallback: f32) -> f32 {
    match artifact.field("expl_noise") {
        Ok(f) if f.per_agent && agent < f.shape[0] && !host.is_empty() => {
            host[f.offset + agent * f.agent_stride()]
        }
        _ => fallback,
    }
}

fn select_action(kind: PolicyKind, raw: &[f32], act: &mut [f32], noise: f32, rng: &mut Rng) {
    match kind {
        PolicyKind::Td3 => {
            for (a, &r) in act.iter_mut().zip(raw) {
                *a = (r + (rng.normal() as f32) * noise).clamp(-1.0, 1.0);
            }
        }
        PolicyKind::Sac => {
            let half = raw.len() / 2;
            for i in 0..act.len() {
                let mu = raw[i];
                let log_std = raw[half + i].clamp(-20.0, 2.0);
                let eps = rng.normal() as f32;
                act[i] = (mu + log_std.exp() * eps).tanh();
            }
        }
    }
}

/// Bounded-channel send that keeps checking the stop flag (so shutdown
/// never deadlocks against a full queue).
fn send_blocking(
    tx: &SyncSender<ActorMsg>,
    mut msg: ActorMsg,
    stop: &AtomicBool,
) -> Result<(), ()> {
    loop {
        match tx.try_send(msg) {
            Ok(()) => return Ok(()),
            Err(TrySendError::Full(m)) => {
                if stop.load(Ordering::Relaxed) {
                    return Err(());
                }
                msg = m;
                std::thread::yield_now();
            }
            Err(TrySendError::Disconnected(_)) => return Err(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_action_td3_clamps() {
        let mut rng = Rng::new(0);
        let raw = [0.99f32, -0.99];
        let mut act = [0.0f32; 2];
        for _ in 0..100 {
            select_action(PolicyKind::Td3, &raw, &mut act, 0.5, &mut rng);
            assert!(act.iter().all(|a| (-1.0..=1.0).contains(a)));
        }
    }

    #[test]
    fn select_action_sac_uses_both_halves() {
        let mut rng = Rng::new(1);
        // mu = 0, log_std = -20 (≈ deterministic): action ≈ tanh(0) = 0
        let raw = [0.0f32, 0.0, -20.0, -20.0];
        let mut act = [9.0f32; 2];
        select_action(PolicyKind::Sac, &raw, &mut act, 0.0, &mut rng);
        assert!(act.iter().all(|a| a.abs() < 1e-3));
    }

    #[test]
    fn policy_kind_from_algo() {
        assert_eq!(PolicyKind::for_algo("sac"), PolicyKind::Sac);
        assert_eq!(PolicyKind::for_algo("td3"), PolicyKind::Td3);
        assert_eq!(PolicyKind::for_algo("cem"), PolicyKind::Td3);
    }
}
