//! Actor/learner data pipeline (paper Appendix A), vectorized over the
//! population axis — for BOTH the continuous-control and the pixel/DQN
//! paths, which share one block-transport substrate.
//!
//! Actor threads own their environment copies and a packed population
//! network ([`PopMlp`](crate::nn::PopMlp) policies for continuous control,
//! [`PopConvNet`](crate::nn::PopConvNet) q-nets for pixels); each
//! iteration they forward ALL owned agents' observations as one block,
//! step a vectorized env ([`VecEnv`] / [`PixelVecEnv`]) against one action
//! block, and publish the resulting transitions as ONE contiguous block
//! message — no per-transition `Vec` clones. Blocks flow through a bounded
//! channel (the paper's queue with a maximum size — actors block when the
//! learner lags) and are recycled back to their actor thread after the
//! learner drains them, so the steady-state loop is allocation-free.
//! Actors refresh their weights from the shared [`ParamView`] whenever the
//! learner publishes a new version (non-blocking for the learner) — one
//! contiguous copy per parameter field for the whole population.
//!
//! The channel + per-thread recycling lanes + stop/throttle machinery is
//! generic over the block type ([`BlockPool`] over [`TransportBlock`]).
//! Two instantiations exist:
//!
//! * [`ActorPool`] — continuous control: [`TransitionBlock`] rows of f32
//!   obs/act, TD3/SAC action selection (`actor_loop`).
//! * [`PixelActorPool`] — DQN: [`PixelTransitionBlock`] rows carrying
//!   frames as u8 `{0,1}` planes (4x less channel bandwidth than f32, and
//!   exactly [`PixelReplayBuffer`](crate::replay::PixelReplayBuffer)'s
//!   storage dtype) with epsilon-greedy action selection over the block's
//!   q-values; per-agent epsilon comes from the state field `eps_greedy`
//!   (the `HyperSpec::dqn` space) when present.
//!
//! **Direct-ingest (sink) mode.** When the learner uses a sharded shared
//! replay ([`ShardedReplay`](crate::replay::ShardedReplay)), the pool is
//! spawned with one [`RowSink`] per thread and the actor loops switch
//! transport: instead of sending each filled block over the channel and
//! waiting for the learner to drain + recycle it, a thread pushes the
//! block's rows straight into its own replay stripe under that stripe's
//! lock and reuses the block in place — zero channel traffic, zero
//! learner round-trip. Finished episodes ride a separate unbounded lane
//! ([`BlockPool::poll_episode`]) since they no longer travel inside
//! blocks. In sink mode the only backpressure is the ratio throttle
//! (the bounded channel no longer pushes back), so sink-mode pools
//! should always run with `ratio > 0`.
//!
//! The pool is **supervised**: every thread body runs under
//! `catch_unwind` and reports a structured
//! [`ActorExit`](crate::data::supervisor::ActorExit) on [`BlockPool`]'s
//! event channel when it dies (panic or clean stop), every thread bumps a
//! [`Heartbeats`](crate::data::supervisor::Heartbeats) slot each loop
//! iteration for the learner-side stall watchdog, and
//! [`BlockPool::respawn`] restarts a dead thread in place (fresh recycle
//! lane, bumped incarnation `generation`). Dropping the pool sets the
//! stop flag and joins all threads, so error paths never leak actors.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::population::ParamView;
use crate::data::supervisor::{panic_message, ActorExit, ExitCause, Heartbeats};
use crate::envs::pixel_vec_env::PixelVecEnv;
use crate::envs::vec_env::{EpisodeEnd, VecEnv};
use crate::manifest::Artifact;
use crate::nn::from_state::{conv_field_dims, pop_convnet_from_state, pop_mlp_from_state};
use crate::nn::mlp::Activation;
use crate::util::log::info;
use crate::util::rng::Rng;
use crate::util::stats::argmax;

/// One finished episode with this undiscounted return, tagged by agent.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EpisodeReport {
    pub agent: usize,
    pub ret: f64,
    pub steps: usize,
}

/// A recyclable actor→learner message. After the learner drains a block
/// it goes back to the spawning thread's return lane for reuse. The
/// routing hooks (`thread`/`reset`) are what the shared transport
/// ([`BlockPool`]) needs to move blocks without knowing their payload;
/// the row accessors (`rows`/`agents`/`episodes`) are what the generic
/// learner loop ([`Trainer`](crate::coordinator::trainer::Trainer))
/// needs to group rows into per-agent replay runs and harvest episode
/// returns without knowing the domain.
pub trait TransportBlock: Send + 'static {
    /// Spawning actor-thread index (the recycling route).
    fn thread(&self) -> usize;
    /// Clear for reuse (capacity and agent ids are kept).
    fn reset(&mut self);
    /// Valid rows in the block.
    fn rows(&self) -> usize;
    /// Agent id per row (sorted runs of equal ids).
    fn agents(&self) -> &[usize];
    /// Episodes that finished during the block's iteration.
    fn episodes(&self) -> &[EpisodeReport];
}

/// One actor iteration's transitions for all of the thread's agents, in
/// flat structure-of-arrays form: row `k` is agent `agents[k]`'s
/// transition, fields are contiguous `[n, ...]` blocks that the learner
/// feeds straight into
/// [`ReplayBuffer::push_batch`](crate::replay::ReplayBuffer::push_batch)
/// — no per-transition heap traffic. Finished episodes ride along in
/// `episodes`.
pub struct TransitionBlock {
    /// Spawning actor-thread index (the recycling route).
    thread: usize,
    /// Valid rows (row capacity is fixed at construction).
    pub n: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
    /// Agent id per row `[rows]`; sorted runs of equal ids.
    pub agents: Vec<usize>,
    /// `[rows, obs_dim]`
    pub obs: Vec<f32>,
    /// `[rows, act_dim]`
    pub act: Vec<f32>,
    /// `[rows]`
    pub rew: Vec<f32>,
    /// `[rows, obs_dim]`
    pub next_obs: Vec<f32>,
    /// `[rows]`, 0.0/1.0 (horizon cap excluded)
    pub done: Vec<f32>,
    /// Episodes that finished during this iteration.
    pub episodes: Vec<EpisodeReport>,
}

impl TransitionBlock {
    /// Preallocate a block with one row per entry of `agents`.
    pub fn new(thread: usize, agents: &[usize], obs_dim: usize, act_dim: usize) -> Self {
        let rows = agents.len();
        TransitionBlock {
            thread,
            n: 0,
            obs_dim,
            act_dim,
            agents: agents.to_vec(),
            obs: vec![0.0; rows * obs_dim],
            act: vec![0.0; rows * act_dim],
            rew: vec![0.0; rows],
            next_obs: vec![0.0; rows * obs_dim],
            done: vec![0.0; rows],
            episodes: Vec::new(),
        }
    }

    pub fn thread(&self) -> usize {
        self.thread
    }

    /// Clear for reuse (capacity and agent ids are kept).
    pub fn reset(&mut self) {
        self.n = 0;
        self.episodes.clear();
    }

    pub fn obs_row(&self, k: usize) -> &[f32] {
        &self.obs[k * self.obs_dim..(k + 1) * self.obs_dim]
    }

    pub fn act_row(&self, k: usize) -> &[f32] {
        &self.act[k * self.act_dim..(k + 1) * self.act_dim]
    }

    pub fn next_obs_row(&self, k: usize) -> &[f32] {
        &self.next_obs[k * self.obs_dim..(k + 1) * self.obs_dim]
    }
}

impl TransportBlock for TransitionBlock {
    fn thread(&self) -> usize {
        TransitionBlock::thread(self)
    }

    fn reset(&mut self) {
        TransitionBlock::reset(self)
    }

    fn rows(&self) -> usize {
        self.n
    }

    fn agents(&self) -> &[usize] {
        &self.agents
    }

    fn episodes(&self) -> &[EpisodeReport] {
        &self.episodes
    }
}

/// The pixel path's transport unit: like [`TransitionBlock`] but frames
/// travel as u8 `{0,1}` planes (MinAtar-style binary frames) — a 4x
/// bandwidth saving over f32 on the actor channel, and exactly the dtype
/// [`PixelReplayBuffer::push_batch`](crate::replay::PixelReplayBuffer::push_batch)
/// stores, so the learner-side insert is a straight memcpy.
pub struct PixelTransitionBlock {
    /// Spawning actor-thread index (the recycling route).
    thread: usize,
    /// Valid rows (row capacity is fixed at construction).
    pub n: usize,
    pub frame_len: usize,
    /// Agent id per row `[rows]`; sorted runs of equal ids.
    pub agents: Vec<usize>,
    /// `[rows, frame_len]` u8 {0,1} planes.
    pub obs: Vec<u8>,
    /// `[rows]` discrete actions.
    pub act: Vec<i32>,
    /// `[rows]`
    pub rew: Vec<f32>,
    /// `[rows, frame_len]` u8 {0,1} planes.
    pub next_obs: Vec<u8>,
    /// `[rows]`, 0.0/1.0 (horizon cap excluded)
    pub done: Vec<f32>,
    /// Episodes that finished during this iteration.
    pub episodes: Vec<EpisodeReport>,
}

impl PixelTransitionBlock {
    /// Preallocate a block with one row per entry of `agents`.
    pub fn new(thread: usize, agents: &[usize], frame_len: usize) -> Self {
        let rows = agents.len();
        PixelTransitionBlock {
            thread,
            n: 0,
            frame_len,
            agents: agents.to_vec(),
            obs: vec![0; rows * frame_len],
            act: vec![0; rows],
            rew: vec![0.0; rows],
            next_obs: vec![0; rows * frame_len],
            done: vec![0.0; rows],
            episodes: Vec::new(),
        }
    }

    pub fn thread(&self) -> usize {
        self.thread
    }

    /// Clear for reuse (capacity and agent ids are kept).
    pub fn reset(&mut self) {
        self.n = 0;
        self.episodes.clear();
    }

    pub fn obs_row(&self, k: usize) -> &[u8] {
        &self.obs[k * self.frame_len..(k + 1) * self.frame_len]
    }

    pub fn next_obs_row(&self, k: usize) -> &[u8] {
        &self.next_obs[k * self.frame_len..(k + 1) * self.frame_len]
    }
}

impl TransportBlock for PixelTransitionBlock {
    fn thread(&self) -> usize {
        PixelTransitionBlock::thread(self)
    }

    fn reset(&mut self) {
        PixelTransitionBlock::reset(self)
    }

    fn rows(&self) -> usize {
        self.n
    }

    fn agents(&self) -> &[usize] {
        &self.agents
    }

    fn episodes(&self) -> &[EpisodeReport] {
        &self.episodes
    }
}

/// Quantize f32 `{0,1}`-plane frames to the u8 wire/storage format
/// (nonzero -> 1). `src.len()` must equal `dst.len()`.
pub fn quantize_frames(src: &[f32], dst: &mut [u8]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = (s != 0.0) as u8;
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// Deterministic tanh policy + additive N(0, expl_noise) exploration.
    Td3,
    /// Squashed-Gaussian head `[mu, log_std]`; exploration = sampling.
    Sac,
}

impl PolicyKind {
    pub fn for_algo(algo: &str) -> PolicyKind {
        if algo.starts_with("sac") {
            PolicyKind::Sac
        } else {
            PolicyKind::Td3
        }
    }
}

#[derive(Clone, Debug)]
pub struct ActorConfig {
    pub env: String,
    pub policy: PolicyKind,
    /// Uniform-random actions for this many initial steps per agent.
    pub warmup_steps: usize,
    /// TD3 exploration noise std (read from state field "expl_noise" when
    /// present, this is the fallback).
    pub expl_noise: f32,
    /// Bounded queue size in BLOCKS (backpressure); one block carries one
    /// transition per agent of the sending thread.
    pub queue_cap: usize,
    pub seed: u64,
    /// Update:env-step ratio target for actor throttling (0 = unthrottled).
    pub ratio: f64,
    /// Extra env steps actors may run ahead of `updates / ratio`.
    pub lead_steps: u64,
    /// Backoff sleep while ratio-throttled, in microseconds.
    pub throttle_sleep_us: u64,
    /// Deterministic fault injection (tests only; see
    /// [`FaultPlan`](crate::data::supervisor::FaultPlan)).
    #[cfg(feature = "fault-inject")]
    pub fault_plan: Option<Arc<crate::data::supervisor::FaultPlan>>,
}

impl Default for ActorConfig {
    fn default() -> Self {
        ActorConfig {
            env: "pendulum".into(),
            policy: PolicyKind::Td3,
            warmup_steps: 500,
            expl_noise: 0.1,
            // one block ≈ one transition per owned agent, so a few hundred
            // in flight already decouples actors from the learner's drain
            // cadence without hoarding pop x cap transitions
            queue_cap: 256,
            seed: 0,
            ratio: 1.0,
            lead_steps: 2048,
            throttle_sleep_us: 200,
            #[cfg(feature = "fault-inject")]
            fault_plan: None,
        }
    }
}

/// Configuration of the pixel/DQN actor loop (the discrete-action mirror
/// of [`ActorConfig`]).
#[derive(Clone, Debug)]
pub struct PixelActorConfig {
    pub env: String,
    /// Uniform-random actions for this many initial steps per agent.
    pub warmup_steps: usize,
    /// Epsilon-greedy exploration rate fallback; the per-agent state field
    /// "eps_greedy" (the `HyperSpec::dqn` search space) takes precedence
    /// when the artifact carries it.
    pub eps_greedy: f32,
    /// Bounded queue size in BLOCKS (backpressure).
    pub queue_cap: usize,
    pub seed: u64,
    /// Update:env-step ratio target for actor throttling (0 = unthrottled).
    pub ratio: f64,
    /// Extra env steps actors may run ahead of `updates / ratio`.
    pub lead_steps: u64,
    /// Backoff sleep while ratio-throttled, in microseconds.
    pub throttle_sleep_us: u64,
    /// Deterministic fault injection (tests only; see
    /// [`FaultPlan`](crate::data::supervisor::FaultPlan)).
    #[cfg(feature = "fault-inject")]
    pub fault_plan: Option<Arc<crate::data::supervisor::FaultPlan>>,
}

impl Default for PixelActorConfig {
    fn default() -> Self {
        PixelActorConfig {
            env: "minatar".into(),
            warmup_steps: 500,
            eps_greedy: 0.1,
            queue_cap: 256,
            seed: 0,
            ratio: 0.0,
            lead_steps: 2048,
            throttle_sleep_us: 200,
            #[cfg(feature = "fault-inject")]
            fault_plan: None,
        }
    }
}

/// Shared counters for actor throttling (paper Appendix A: "agents are
/// blocked ... if the process handling the accelerator is lagging behind").
#[derive(Clone, Default)]
pub struct Throttle {
    /// Update steps completed by the learner.
    pub updates: Arc<AtomicU64>,
    /// Environment steps taken by all actors.
    pub env_steps: Arc<AtomicU64>,
}

impl Throttle {
    pub fn new() -> Self {
        Self::default()
    }

    /// May actors take another environment step? `warmup_total` is the
    /// population-wide warmup step budget (steps before the ratio bites).
    pub fn may_step_with(&self, ratio: f64, warmup_total: u64, lead_steps: u64) -> bool {
        if ratio <= 0.0 {
            return true;
        }
        let env = self.env_steps.load(Ordering::Relaxed);
        let upd = self.updates.load(Ordering::Relaxed);
        env < warmup_total + (upd as f64 / ratio) as u64 + lead_steps
    }

    /// May actors take another environment step?
    pub fn may_step(&self, cfg: &ActorConfig, pop: u64) -> bool {
        self.may_step_with(cfg.ratio, cfg.warmup_steps as u64 * pop, cfg.lead_steps)
    }
}

/// A consumer of transport-block rows that actors can feed directly,
/// bypassing the block channel — in practice a replay stripe
/// ([`StripeSink`](crate::replay::StripeSink)). Implementations must be
/// internally synchronized (`push_rows` takes `&self` from many actor
/// threads).
pub trait RowSink<B>: Send + Sync {
    /// Insert rows `start..end` of `block`, preserving row order.
    fn push_rows(&self, block: &B, start: usize, end: usize);
}

/// One actor thread's direct-ingest endpoints: its replay stripe plus
/// the episode lane that replaces in-block episode transport. Cloned on
/// respawn so every incarnation of a thread feeds the same stripe.
pub struct ActorSink<B> {
    /// The thread's replay stripe (shared with the learner's sampler).
    pub rows: Arc<dyn RowSink<B>>,
    /// Unbounded lane carrying finished-episode reports to the learner.
    pub episodes: Sender<EpisodeReport>,
}

impl<B> Clone for ActorSink<B> {
    fn clone(&self) -> Self {
        ActorSink { rows: Arc::clone(&self.rows), episodes: self.episodes.clone() }
    }
}

/// Everything one actor-thread incarnation needs from the pool: its
/// identity (`thread`, `generation`), the agents it owns, the transport
/// endpoints, the stop flag, and its heartbeat slot. Handed to the pool's
/// [`ActorBody`] on every (re)spawn.
pub struct ActorScope<B: TransportBlock> {
    /// Actor-thread index within the pool.
    pub thread: usize,
    /// Incarnation count: 0 on first spawn, +1 per [`BlockPool::respawn`].
    pub generation: u64,
    /// Agents this thread owns (round-robin partition, stable across
    /// respawns).
    pub agents: Vec<usize>,
    pub tx: SyncSender<B>,
    pub recycle: Receiver<B>,
    pub stop: Arc<AtomicBool>,
    pub heartbeats: Heartbeats,
    /// Direct-ingest mode: when set, the loop pushes rows into this sink
    /// and never touches `tx`/`recycle`.
    pub sink: Option<ActorSink<B>>,
}

/// A respawnable actor-loop body. The pool keeps it for the lifetime of
/// the run so [`BlockPool::respawn`] can restart a dead thread with a
/// fresh [`ActorScope`].
type ActorBody<B> = Arc<dyn Fn(ActorScope<B>) + Send + Sync>;

/// Run one actor incarnation under `catch_unwind` and report the exit on
/// the pool's event channel — the supervision contract: a panicking actor
/// is never silent.
fn launch<B: TransportBlock>(
    body: ActorBody<B>,
    scope: ActorScope<B>,
    events: Sender<ActorExit>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let thread = scope.thread;
        let agents = scope.agents.clone();
        let cause = match std::panic::catch_unwind(AssertUnwindSafe(|| body(scope))) {
            Ok(()) => ExitCause::Finished,
            Err(payload) => ExitCause::Panic(panic_message(payload.as_ref())),
        };
        let _ = events.send(ActorExit { thread, agents, cause });
    })
}

/// Actor thread pool plus its block transport, generic over the block
/// type: a bounded channel of filled blocks (learner side: `rx`) and one
/// bounded return lane per thread for drained blocks (the allocation-free
/// steady state). [`ActorPool`] and [`PixelActorPool`] are its two
/// instantiations.
///
/// Supervision surface: [`BlockPool::poll_exit`] yields structured
/// [`ActorExit`] events, [`BlockPool::heartbeats`] exposes per-thread
/// liveness for a stall watchdog, and [`BlockPool::respawn`] restarts a
/// failed thread. Dropping the pool (or calling [`BlockPool::stop`])
/// joins every thread.
pub struct BlockPool<B: TransportBlock> {
    pub rx: Receiver<B>,
    /// Kept for respawns (the channel stays open for the pool's life).
    tx: SyncSender<B>,
    /// Per-thread return lanes for spent blocks (index = thread).
    recycle: Vec<SyncSender<B>>,
    stop: Arc<AtomicBool>,
    handles: Vec<JoinHandle<()>>,
    /// The loop body, retained so dead threads can be respawned.
    body: ActorBody<B>,
    /// Agents per thread (stable across respawns).
    agents_by_thread: Vec<Vec<usize>>,
    /// Incarnation count per thread.
    generations: Vec<u64>,
    heartbeats: Heartbeats,
    events: Receiver<ActorExit>,
    event_tx: Sender<ActorExit>,
    queue_cap: usize,
    /// Direct-ingest mode: per-thread row sinks (empty = channel mode).
    /// Retained so a respawned incarnation re-binds to the same stripe.
    sinks: Vec<Arc<dyn RowSink<B>>>,
    /// Episode lane endpoints (sink mode only).
    episode_tx: Option<Sender<EpisodeReport>>,
    episode_rx: Option<Receiver<EpisodeReport>>,
}

/// The continuous-control actor pool ([`TransitionBlock`] transport).
pub type ActorPool = BlockPool<TransitionBlock>;

/// The pixel/DQN actor pool ([`PixelTransitionBlock`] transport).
pub type PixelActorPool = BlockPool<PixelTransitionBlock>;

impl<B: TransportBlock> BlockPool<B> {
    /// Hand a drained block back to its actor thread for reuse (the
    /// allocation-free steady state). Dropped silently if the thread is
    /// gone or its return lane is full — the actor then allocates afresh.
    pub fn recycle(&self, mut block: B) {
        block.reset();
        if let Some(lane) = self.recycle.get(block.thread()) {
            let _ = lane.try_send(block);
        }
    }

    /// Number of actor threads (dead or alive).
    pub fn threads(&self) -> usize {
        self.agents_by_thread.len()
    }

    /// The agents owned by `thread`.
    pub fn thread_agents(&self, thread: usize) -> &[usize] {
        &self.agents_by_thread[thread]
    }

    /// Per-thread liveness timestamps for the learner-side watchdog.
    pub fn heartbeats(&self) -> &Heartbeats {
        &self.heartbeats
    }

    /// Next structured actor-exit event, if any (non-blocking).
    pub fn poll_exit(&self) -> Option<ActorExit> {
        self.events.try_recv().ok()
    }

    /// Next finished-episode report from the sink-mode episode lane, if
    /// any (non-blocking). Always `None` in channel mode, where episodes
    /// ride inside blocks instead.
    pub fn poll_episode(&self) -> Option<EpisodeReport> {
        self.episode_rx.as_ref().and_then(|rx| rx.try_recv().ok())
    }

    /// Restart a dead thread's loop in place: fresh recycle lane, bumped
    /// `generation`, same agents. Returns false once the pool is
    /// stopping (or for an unknown thread index). Respawning a thread
    /// that is still alive is a caller bug — the two incarnations would
    /// race on the env; only respawn threads that reported an exit.
    pub fn respawn(&mut self, thread: usize) -> bool {
        if thread >= self.agents_by_thread.len() || self.stop.load(Ordering::Relaxed) {
            return false;
        }
        let (rtx, rrx) = std::sync::mpsc::sync_channel(self.queue_cap.max(4));
        self.recycle[thread] = rtx;
        self.generations[thread] += 1;
        // fresh beat so the watchdog doesn't instantly re-flag the thread
        // for time it spent dead
        self.heartbeats.beat(thread);
        let scope = ActorScope {
            thread,
            generation: self.generations[thread],
            agents: self.agents_by_thread[thread].clone(),
            tx: self.tx.clone(),
            recycle: rrx,
            stop: self.stop.clone(),
            heartbeats: self.heartbeats.clone(),
            // sink mode: the new incarnation re-binds to the SAME stripe
            // its predecessor fed — stripe assignment is stable across
            // respawns, like the agent partition.
            sink: self.sink_for(thread),
        };
        self.handles.push(launch(self.body.clone(), scope, self.event_tx.clone()));
        true
    }

    /// The direct-ingest endpoints for `thread` (None in channel mode).
    fn sink_for(&self, thread: usize) -> Option<ActorSink<B>> {
        let tx = self.episode_tx.as_ref()?;
        let rows = Arc::clone(&self.sinks[thread % self.sinks.len()]);
        Some(ActorSink { rows, episodes: tx.clone() })
    }

    /// Set the stop flag, unblock senders, and join every thread.
    /// Idempotent — also what [`Drop`] runs, so early `?` returns in a
    /// training loop can never leak live actor threads.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // drain so blocked senders can observe the stop flag
        while self.rx.try_recv().is_ok() {}
        for h in std::mem::take(&mut self.handles) {
            let _ = h.join();
        }
    }

    pub fn stop(mut self) {
        self.shutdown();
    }
}

impl<B: TransportBlock> Drop for BlockPool<B> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Shared pool scaffolding: partition `pop` agents round-robin over
/// `n_threads`, wire the block channel + per-thread recycling lanes + the
/// supervision side channel (exit events, heartbeats), and launch each
/// thread's loop under `catch_unwind`. A non-empty `sinks` switches the
/// pool into direct-ingest mode: thread `t` is bound to sink
/// `t % sinks.len()` and an episode lane replaces in-block episode
/// transport.
fn spawn_block_pool<B: TransportBlock>(
    pop: usize,
    n_threads: usize,
    queue_cap: usize,
    body: ActorBody<B>,
    sinks: Vec<Arc<dyn RowSink<B>>>,
) -> BlockPool<B> {
    let n_threads = n_threads.clamp(1, pop);
    let (tx, rx) = std::sync::mpsc::sync_channel(queue_cap);
    let (event_tx, events) = std::sync::mpsc::channel();
    let (episode_tx, episode_rx) = if sinks.is_empty() {
        (None, None)
    } else {
        let (etx, erx) = std::sync::mpsc::channel();
        (Some(etx), Some(erx))
    };
    let stop = Arc::new(AtomicBool::new(false));
    let heartbeats = Heartbeats::new(n_threads);
    let mut handles = Vec::new();
    let mut recycle = Vec::new();
    let mut agents_by_thread = Vec::new();
    for t in 0..n_threads {
        let agents: Vec<usize> = (0..pop).filter(|a| a % n_threads == t).collect();
        let (rtx, rrx) = std::sync::mpsc::sync_channel(queue_cap.max(4));
        recycle.push(rtx);
        heartbeats.beat(t); // liveness clock starts at spawn, not first block
        let sink = episode_tx.as_ref().map(|etx| ActorSink {
            rows: Arc::clone(&sinks[t % sinks.len()]),
            episodes: etx.clone(),
        });
        let scope = ActorScope {
            thread: t,
            generation: 0,
            agents: agents.clone(),
            tx: tx.clone(),
            recycle: rrx,
            stop: stop.clone(),
            heartbeats: heartbeats.clone(),
            sink,
        };
        agents_by_thread.push(agents);
        handles.push(launch(body.clone(), scope, event_tx.clone()));
    }
    BlockPool {
        rx,
        tx,
        recycle,
        stop,
        handles,
        body,
        agents_by_thread,
        generations: vec![0; n_threads],
        heartbeats,
        events,
        event_tx,
        queue_cap,
        sinks,
        episode_tx,
        episode_rx,
    }
}

impl BlockPool<TransitionBlock> {
    /// Spawn `n_threads` continuous-control actor threads covering all
    /// `artifact.pop` agents (channel transport).
    pub fn spawn(
        artifact: &Artifact,
        view: ParamView,
        cfg: ActorConfig,
        n_threads: usize,
        throttle: Throttle,
    ) -> anyhow::Result<ActorPool> {
        Self::spawn_with_sinks(artifact, view, cfg, n_threads, throttle, Vec::new())
    }

    /// Like [`ActorPool::spawn`], but a non-empty `sinks` puts the pool
    /// in direct-ingest mode: thread `t` pushes its blocks straight into
    /// `sinks[t % sinks.len()]` (its replay stripe) instead of the block
    /// channel.
    pub fn spawn_with_sinks(
        artifact: &Artifact,
        view: ParamView,
        cfg: ActorConfig,
        n_threads: usize,
        throttle: Throttle,
        sinks: Vec<Arc<dyn RowSink<TransitionBlock>>>,
    ) -> anyhow::Result<ActorPool> {
        // Validate the env/artifact pairing (metadata only — no weight
        // copies) on the caller's thread: a mismatch must surface as
        // this Result, not as a panic inside a spawned actor thread
        // (which the learner would only ever see as a silently idle
        // channel).
        let probe = VecEnv::new(&cfg.env, 1)?;
        let out = validate_mlp_chain(artifact, "policy", probe.obs_dim())?;
        let want = match cfg.policy {
            PolicyKind::Td3 => probe.act_dim(),
            PolicyKind::Sac => 2 * probe.act_dim(), // [mu, log_std] head
        };
        anyhow::ensure!(
            out == want,
            "artifact {} policy outputs {out} dims but env {:?} needs {want} for a {:?} head",
            artifact.name,
            cfg.env,
            cfg.policy
        );
        let art = artifact.clone();
        let queue_cap = cfg.queue_cap;
        let body: ActorBody<TransitionBlock> = Arc::new(move |scope: ActorScope<_>| {
            // per-incarnation seed: respawned actors explore fresh
            // trajectories instead of replaying the run that crashed
            let seed = cfg
                .seed
                .wrapping_add(1000 + scope.thread as u64)
                .wrapping_add(scope.generation.wrapping_mul(0x9E37_79B9));
            let cfg2 = ActorConfig { seed, ..cfg.clone() };
            actor_loop(&art, view.clone(), &cfg2, scope, throttle.clone());
        });
        Ok(spawn_block_pool(artifact.pop, n_threads, queue_cap, body, sinks))
    }
}

impl BlockPool<PixelTransitionBlock> {
    /// Spawn `n_threads` pixel/DQN actor threads covering all
    /// `artifact.pop` agents (channel transport).
    pub fn spawn(
        artifact: &Artifact,
        view: ParamView,
        cfg: PixelActorConfig,
        n_threads: usize,
        throttle: Throttle,
    ) -> anyhow::Result<PixelActorPool> {
        Self::spawn_with_sinks(artifact, view, cfg, n_threads, throttle, Vec::new())
    }

    /// Like [`PixelActorPool::spawn`], but a non-empty `sinks` puts the
    /// pool in direct-ingest mode: thread `t` pushes its blocks straight
    /// into `sinks[t % sinks.len()]` (its replay stripe) instead of the
    /// block channel.
    pub fn spawn_with_sinks(
        artifact: &Artifact,
        view: ParamView,
        cfg: PixelActorConfig,
        n_threads: usize,
        throttle: Throttle,
        sinks: Vec<Arc<dyn RowSink<PixelTransitionBlock>>>,
    ) -> anyhow::Result<PixelActorPool> {
        // Validate the env name and artifact layout on the caller's
        // thread (e.g. the 84x84 Atari conv stack stores q/conv0/* and
        // q/conv1/*, not q/conv/* — that must error here, not panic in a
        // spawned thread and leave the learner polling an idle channel).
        let probe = PixelVecEnv::new(&cfg.env, 1)?;
        validate_pixel_layout(artifact, probe.frame(), probe.n_actions())?;
        let art = artifact.clone();
        let queue_cap = cfg.queue_cap;
        let body: ActorBody<PixelTransitionBlock> = Arc::new(move |scope: ActorScope<_>| {
            let seed = cfg
                .seed
                .wrapping_add(1000 + scope.thread as u64)
                .wrapping_add(scope.generation.wrapping_mul(0x9E37_79B9));
            let cfg2 = PixelActorConfig { seed, ..cfg.clone() };
            pixel_actor_loop(&art, view.clone(), &cfg2, scope, throttle.clone());
        });
        Ok(spawn_block_pool(artifact.pop, n_threads, queue_cap, body, sinks))
    }
}

fn actor_loop(
    artifact: &Artifact,
    view: ParamView,
    cfg: &ActorConfig,
    scope: ActorScope<TransitionBlock>,
    throttle: Throttle,
) {
    let ActorScope { thread, generation, agents, tx, recycle, stop, heartbeats, sink } = scope;
    let _ = generation; // used by the fault-inject hook only
    let agents = &agents[..];
    let mut rng = Rng::new(cfg.seed);
    let n = agents.len();
    let mut venv = VecEnv::new(&cfg.env, n).unwrap();
    let (ha, fa) = match cfg.policy {
        PolicyKind::Td3 => (Activation::Relu, Activation::Tanh),
        PolicyKind::Sac => (Activation::Relu, Activation::None),
    };
    let mut host = Vec::new();
    let mut version = view.fetch_if_newer(0, &mut host);
    let mut policy = pop_mlp_from_state(artifact, &host, "policy", ha, fa).unwrap();
    policy.reserve_scratch(n);

    let obs_dim = venv.obs_dim();
    let act_dim = venv.act_dim();
    let out_dim = policy.out_dim();
    let mut raw = vec![0.0f32; n * out_dim];
    let mut acts = vec![0.0f32; n * act_dim];
    let mut noise: Vec<f32> = agents
        .iter()
        .map(|&a| hyper_for(artifact, &host, "expl_noise", a, cfg.expl_noise))
        .collect();
    let mut episodes: Vec<EpisodeEnd> = Vec::new();
    let mut block = TransitionBlock::new(thread, agents, obs_dim, act_dim);
    venv.reset_all(&mut rng);
    // Per-thread telemetry handles, resolved once outside the loop so a
    // record is a relaxed fetch-add (or one load + branch when off).
    let tm = crate::telemetry::ActorMetrics::for_thread(thread);

    let mut iters: usize = 0;
    let pop_total = artifact.pop as u64;
    loop {
        heartbeats.beat(thread);
        if stop.load(Ordering::Relaxed) {
            break;
        }
        #[cfg(feature = "fault-inject")]
        if let Some(plan) = &cfg.fault_plan {
            plan.actor_tick(thread, iters, generation);
        }
        // Ratio throttling: wait while actors are too far ahead of the
        // learner (paper Appendix A blocking rule).
        if !throttle.may_step(cfg, pop_total) {
            std::thread::sleep(std::time::Duration::from_micros(cfg.throttle_sleep_us));
            continue;
        }
        // Non-blocking parameter refresh: one contiguous copy per layer
        // field for the whole population.
        let v2 = view.fetch_if_newer(version, &mut host);
        if v2 > version {
            version = v2;
            let _ = policy.sync_from_state(artifact, &host, "policy");
            for (k, &a) in agents.iter().enumerate() {
                noise[k] = hyper_for(artifact, &host, "expl_noise", a, cfg.expl_noise);
            }
        }
        // Action selection for the whole block.
        if iters < cfg.warmup_steps {
            rng.fill_uniform(&mut acts, -1.0, 1.0);
        } else {
            let _fwd = crate::telemetry::timed(&tm.forward);
            policy.forward_block(agents, venv.obs(), &mut raw);
            for k in 0..n {
                select_action(
                    cfg.policy,
                    &raw[k * out_dim..(k + 1) * out_dim],
                    &mut acts[k * act_dim..(k + 1) * act_dim],
                    noise[k],
                    &mut rng,
                );
            }
        }
        // Record the pre-step observations, then step every env; the
        // VecEnv writes next_obs/rew/done straight into the block.
        block.obs.copy_from_slice(venv.obs());
        block.act.copy_from_slice(&acts);
        episodes.clear();
        {
            let _step = crate::telemetry::timed(&tm.env_step);
            venv.step_into(&mut rng, &acts, &mut block.next_obs, &mut block.rew,
                           &mut block.done, &mut episodes);
        }
        block.n = n;
        for e in &episodes {
            block.episodes.push(EpisodeReport {
                agent: agents[e.slot],
                ret: e.ret,
                steps: e.steps,
            });
        }
        iters += 1;
        throttle.env_steps.fetch_add(n as u64, Ordering::Relaxed);
        tm.env_steps.add(n as u64);
        tm.blocks.add(1);
        match &sink {
            // Direct-ingest mode: push the rows straight into this
            // thread's replay stripe and reuse the block in place — no
            // channel hop, no learner round-trip, allocation-free.
            Some(sk) => {
                let _pub = crate::telemetry::timed(&tm.publish);
                sk.rows.push_rows(&block, 0, block.n);
                for e in block.episodes.drain(..) {
                    let _ = sk.episodes.send(e);
                }
                block.reset();
            }
            None => {
                let _pub = crate::telemetry::timed(&tm.publish);
                if send_blocking(&tx, block, &stop, || heartbeats.beat(thread)).is_err() {
                    break;
                }
                // Reuse a drained block when the learner returned one;
                // allocate only when the recycle lane is empty (cold
                // start / learner busy).
                block = match recycle.try_recv() {
                    Ok(b) => b,
                    Err(_) => TransitionBlock::new(thread, agents, obs_dim, act_dim),
                };
            }
        }
    }
}

/// The pixel/DQN mirror of [`actor_loop`]: PopConvNet block q-values,
/// epsilon-greedy selection, PixelVecEnv stepping, and u8-frame block
/// transport.
fn pixel_actor_loop(
    artifact: &Artifact,
    view: ParamView,
    cfg: &PixelActorConfig,
    scope: ActorScope<PixelTransitionBlock>,
    throttle: Throttle,
) {
    let ActorScope { thread, generation, agents, tx, recycle, stop, heartbeats, sink } = scope;
    let agents = &agents[..];
    let mut rng = Rng::new(cfg.seed);
    let n = agents.len();
    let mut venv = PixelVecEnv::new(&cfg.env, n).unwrap();
    let frame = venv.frame();
    let frame_len = venv.frame_len();
    let mut host = Vec::new();
    let mut version = view.fetch_if_newer(0, &mut host);
    let mut qnet = pop_convnet_from_state(artifact, &host, "q", frame).unwrap();
    qnet.reserve_scratch(n);
    if generation == 0 && thread == 0 {
        // Scratch hygiene: the conv/im2col buffers grow with the block
        // size; surface the steady-state footprint once at spawn so
        // large-pop memory spikes are visible.
        info(&format!(
            "pixel actor scratch: {} bytes/thread ({} rows)",
            qnet.scratch_bytes(),
            n
        ));
    }

    let n_actions = qnet.out_dim();
    let mut q = vec![0.0f32; n * n_actions];
    let mut acts = vec![0usize; n];
    let mut next_obs = vec![0.0f32; n * frame_len];
    let mut eps: Vec<f32> = agents
        .iter()
        .map(|&a| hyper_for(artifact, &host, "eps_greedy", a, cfg.eps_greedy))
        .collect();
    let mut episodes: Vec<EpisodeEnd> = Vec::new();
    let mut block = PixelTransitionBlock::new(thread, agents, frame_len);
    venv.reset_all(&mut rng);
    // Per-thread telemetry handles (see actor_loop).
    let tm = crate::telemetry::ActorMetrics::for_thread(thread);

    let mut iters: usize = 0;
    let warmup_total = cfg.warmup_steps as u64 * artifact.pop as u64;
    loop {
        heartbeats.beat(thread);
        if stop.load(Ordering::Relaxed) {
            break;
        }
        #[cfg(feature = "fault-inject")]
        if let Some(plan) = &cfg.fault_plan {
            plan.actor_tick(thread, iters, generation);
        }
        // Ratio throttling (paper Appendix A blocking rule).
        if !throttle.may_step_with(cfg.ratio, warmup_total, cfg.lead_steps) {
            std::thread::sleep(std::time::Duration::from_micros(cfg.throttle_sleep_us));
            continue;
        }
        // Non-blocking parameter refresh: one contiguous copy for the
        // whole population's conv filters and per head layer field.
        let v2 = view.fetch_if_newer(version, &mut host);
        if v2 > version {
            version = v2;
            let _ = qnet.sync_from_state(artifact, &host, "q");
            for (k, &a) in agents.iter().enumerate() {
                eps[k] = hyper_for(artifact, &host, "eps_greedy", a, cfg.eps_greedy);
            }
        }
        // Epsilon-greedy action selection over the block's q-values.
        if iters < cfg.warmup_steps {
            for a in acts.iter_mut() {
                *a = rng.below(n_actions);
            }
        } else {
            let _fwd = crate::telemetry::timed(&tm.forward);
            qnet.forward_block(agents, venv.obs(), &mut q);
            for k in 0..n {
                acts[k] = if rng.uniform() < eps[k] as f64 {
                    rng.below(n_actions)
                } else {
                    argmax(&q[k * n_actions..(k + 1) * n_actions])
                };
            }
        }
        // Record the pre-step frames (quantized to the u8 wire format),
        // step every env, then quantize the outcome frames.
        quantize_frames(venv.obs(), &mut block.obs);
        for (d, &a) in block.act.iter_mut().zip(&acts) {
            *d = a as i32;
        }
        episodes.clear();
        {
            let _step = crate::telemetry::timed(&tm.env_step);
            venv.step_into(&mut rng, &acts, &mut next_obs, &mut block.rew, &mut block.done,
                           &mut episodes);
        }
        quantize_frames(&next_obs, &mut block.next_obs);
        block.n = n;
        for e in &episodes {
            block.episodes.push(EpisodeReport {
                agent: agents[e.slot],
                ret: e.ret,
                steps: e.steps,
            });
        }
        iters += 1;
        throttle.env_steps.fetch_add(n as u64, Ordering::Relaxed);
        tm.env_steps.add(n as u64);
        tm.blocks.add(1);
        match &sink {
            // Direct-ingest mode: see actor_loop — same contract, u8
            // frame planes land in the stripe without requantization.
            Some(sk) => {
                let _pub = crate::telemetry::timed(&tm.publish);
                sk.rows.push_rows(&block, 0, block.n);
                for e in block.episodes.drain(..) {
                    let _ = sk.episodes.send(e);
                }
                block.reset();
            }
            None => {
                let _pub = crate::telemetry::timed(&tm.publish);
                if send_blocking(&tx, block, &stop, || heartbeats.beat(thread)).is_err() {
                    break;
                }
                block = match recycle.try_recv() {
                    Ok(b) => b,
                    Err(_) => PixelTransitionBlock::new(thread, agents, frame_len),
                };
            }
        }
    }
}

/// Metadata-only walk of the packed MLP chain `{prefix}/w{li}`
/// (rank-3 `[P, in, out]` fields, consistent dim chain from `in_dim`);
/// returns the final output dim. Shared by both spawn validations.
fn validate_mlp_chain(artifact: &Artifact, prefix: &str, in_dim: usize) -> anyhow::Result<usize> {
    let mut dim = in_dim;
    let mut li = 0;
    while let Ok(lw) = artifact.field(&format!("{prefix}/w{li}")) {
        anyhow::ensure!(lw.shape.len() == 3, "{prefix}/w{li}: expected [P, in, out]");
        anyhow::ensure!(
            lw.shape[0] == artifact.pop,
            "{prefix}/w{li}: leading axis {} != pop {}",
            lw.shape[0],
            artifact.pop
        );
        anyhow::ensure!(
            lw.shape[1] == dim,
            "{prefix}/w{li}: input dim {} != expected {dim}",
            lw.shape[1]
        );
        dim = lw.shape[2];
        li += 1;
    }
    anyhow::ensure!(li > 0, "artifact {} has no {prefix} layers", artifact.name);
    Ok(dim)
}

/// Metadata-only check that `artifact` carries a MinAtar-style DQN
/// layout (`q/conv/*` + a `q/head/*` chain) compatible with the env's
/// frame shape and action count — no weight copies, so pairing mistakes
/// surface as cheap spawn-time errors instead of panics in actor
/// threads. The conv invariant itself lives in
/// [`conv_field_dims`](crate::nn::from_state::conv_field_dims).
fn validate_pixel_layout(
    artifact: &Artifact,
    frame: (usize, usize, usize),
    n_actions: usize,
) -> anyhow::Result<()> {
    let (h, wd, _) = frame;
    let (kh, kw, feats) = conv_field_dims(artifact, "q", frame)?;
    let flat = (h - kh + 1) * (wd - kw + 1) * feats;
    let out = validate_mlp_chain(artifact, "q/head", flat)?;
    anyhow::ensure!(
        out == n_actions,
        "artifact {} q-head outputs {out} q-values but the env has {n_actions} actions",
        artifact.name
    );
    Ok(())
}

/// Per-agent hyperparameter from the state when the field exists (e.g.
/// "expl_noise" for TD3 actors, "eps_greedy" for DQN actors).
fn hyper_for(artifact: &Artifact, host: &[f32], name: &str, agent: usize, fallback: f32) -> f32 {
    match artifact.field(name) {
        Ok(f) if f.per_agent && agent < f.shape[0] && !host.is_empty() => {
            host[f.offset + agent * f.agent_stride()]
        }
        _ => fallback,
    }
}

fn select_action(kind: PolicyKind, raw: &[f32], act: &mut [f32], noise: f32, rng: &mut Rng) {
    match kind {
        PolicyKind::Td3 => {
            for (a, &r) in act.iter_mut().zip(raw) {
                *a = (r + (rng.normal() as f32) * noise).clamp(-1.0, 1.0);
            }
        }
        PolicyKind::Sac => {
            let half = raw.len() / 2;
            for i in 0..act.len() {
                let mu = raw[i];
                let log_std = raw[half + i].clamp(-20.0, 2.0);
                let eps = rng.normal() as f32;
                act[i] = (mu + log_std.exp() * eps).tanh();
            }
        }
    }
}

/// Bounded-channel send that keeps checking the stop flag (so shutdown
/// never deadlocks against a full queue). `beat` keeps the sender's
/// heartbeat fresh while it waits on a full queue — a backpressured
/// actor is blocked, not stalled.
fn send_blocking<T>(
    tx: &SyncSender<T>,
    mut msg: T,
    stop: &AtomicBool,
    beat: impl Fn(),
) -> Result<(), ()> {
    loop {
        match tx.try_send(msg) {
            Ok(()) => return Ok(()),
            Err(TrySendError::Full(m)) => {
                if stop.load(Ordering::Relaxed) {
                    return Err(());
                }
                beat();
                msg = m;
                std::thread::yield_now();
            }
            Err(TrySendError::Disconnected(_)) => return Err(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::RatioGate;

    #[test]
    fn select_action_td3_clamps() {
        let mut rng = Rng::new(0);
        let raw = [0.99f32, -0.99];
        let mut act = [0.0f32; 2];
        for _ in 0..100 {
            select_action(PolicyKind::Td3, &raw, &mut act, 0.5, &mut rng);
            assert!(act.iter().all(|a| (-1.0..=1.0).contains(a)));
        }
    }

    #[test]
    fn select_action_sac_uses_both_halves() {
        let mut rng = Rng::new(1);
        // mu = 0, log_std = -20 (≈ deterministic): action ≈ tanh(0) = 0
        let raw = [0.0f32, 0.0, -20.0, -20.0];
        let mut act = [9.0f32; 2];
        select_action(PolicyKind::Sac, &raw, &mut act, 0.0, &mut rng);
        assert!(act.iter().all(|a| a.abs() < 1e-3));
    }

    #[test]
    fn policy_kind_from_algo() {
        assert_eq!(PolicyKind::for_algo("sac"), PolicyKind::Sac);
        assert_eq!(PolicyKind::for_algo("td3"), PolicyKind::Td3);
        assert_eq!(PolicyKind::for_algo("cem"), PolicyKind::Td3);
    }

    #[test]
    fn transition_block_rows_and_recycling_reset() {
        let agents = [2usize, 5, 7];
        let mut b = TransitionBlock::new(1, &agents, 2, 1);
        assert_eq!(b.thread(), 1);
        b.obs.copy_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        b.act.copy_from_slice(&[0.1, 0.2, 0.3]);
        b.n = 3;
        b.episodes.push(EpisodeReport { agent: 5, ret: 1.0, steps: 7 });
        assert_eq!(b.obs_row(1), &[3.0, 4.0]);
        assert_eq!(b.act_row(2), &[0.3]);
        b.reset();
        assert_eq!(b.n, 0);
        assert!(b.episodes.is_empty());
        assert_eq!(b.agents, &agents); // ids survive recycling
    }

    #[test]
    fn pixel_block_quantizes_and_recycles() {
        let agents = [0usize, 3];
        let mut b = PixelTransitionBlock::new(2, &agents, 4);
        assert_eq!(b.thread(), 2);
        // quantization: any nonzero plane value -> 1
        quantize_frames(&[0.0, 1.0, 0.5, 0.0, 1.0, 0.0, 0.0, 2.0], &mut b.obs);
        assert_eq!(b.obs, vec![0, 1, 1, 0, 1, 0, 0, 1]);
        assert_eq!(b.obs_row(1), &[1, 0, 0, 1]);
        b.act.copy_from_slice(&[2, 0]);
        b.n = 2;
        b.episodes.push(EpisodeReport { agent: 3, ret: 4.0, steps: 9 });
        b.reset();
        assert_eq!(b.n, 0);
        assert!(b.episodes.is_empty());
        assert_eq!(b.agents, &agents); // ids survive recycling
        assert_eq!(b.next_obs_row(0), &[0, 0, 0, 0]);
    }

    /// Actors must stall within `lead_steps` of the ratio target and
    /// resume exactly when learner updates buy more headroom.
    #[test]
    fn throttle_stalls_within_lead_and_resumes_after_updates() {
        let cfg = ActorConfig {
            ratio: 1.0,
            lead_steps: 100,
            warmup_steps: 0,
            ..Default::default()
        };
        let th = Throttle::new();
        let mut taken = 0u64;
        while th.may_step(&cfg, 1) {
            th.env_steps.fetch_add(1, Ordering::Relaxed);
            taken += 1;
            assert!(taken <= 100, "actor ran past its lead budget");
        }
        assert_eq!(taken, 100);
        // learner progress frees exactly updates/ratio more steps
        th.updates.fetch_add(50, Ordering::Relaxed);
        assert!(th.may_step(&cfg, 1));
        let mut extra = 0u64;
        while th.may_step(&cfg, 1) {
            th.env_steps.fetch_add(1, Ordering::Relaxed);
            extra += 1;
            assert!(extra <= 50);
        }
        assert_eq!(extra, 50);
        // unthrottled config never stalls
        let free = ActorConfig { ratio: 0.0, ..Default::default() };
        assert!(th.may_step(&free, 1));
        // the raw form (used by the pixel loop) agrees with the cfg form
        assert_eq!(
            th.may_step(&cfg, 1),
            th.may_step_with(cfg.ratio, 0, cfg.lead_steps)
        );
    }

    /// Closed loop of Throttle (actor side) against RatioGate (learner
    /// side): both make progress, neither runs away from the shared
    /// ratio target, and the system cannot deadlock.
    #[test]
    fn throttle_and_ratio_gate_converge_jointly() {
        let pop = 4u64;
        let cfg = ActorConfig {
            ratio: 0.5,
            lead_steps: 64,
            warmup_steps: 25,
            ..Default::default()
        };
        let th = Throttle::new();
        let mut gate = RatioGate::new(cfg.ratio, 8.0, cfg.warmup_steps as u64 * pop);
        let mut stalled_in_a_row = 0u32;
        for _ in 0..20_000 {
            let mut progressed = false;
            if th.may_step(&cfg, pop) {
                th.env_steps.fetch_add(1, Ordering::Relaxed);
                gate.on_env_steps(1);
                progressed = true;
            }
            if gate.may_update(1) {
                gate.on_update_steps(1);
                th.updates.fetch_add(1, Ordering::Relaxed);
                progressed = true;
            }
            if progressed {
                stalled_in_a_row = 0;
            } else {
                stalled_in_a_row += 1;
                assert!(stalled_in_a_row < 2, "actor/learner deadlock");
            }
            // actor side never exceeds warmup + updates/ratio + lead
            let env = th.env_steps.load(Ordering::Relaxed);
            let upd = th.updates.load(Ordering::Relaxed);
            let bound =
                cfg.warmup_steps as u64 * pop + (upd as f64 / cfg.ratio) as u64 + cfg.lead_steps;
            assert!(env <= bound, "env {env} > bound {bound}");
            // learner side never exceeds target * counted env steps + slack
            let counted = env.saturating_sub(cfg.warmup_steps as u64 * pop);
            assert!(
                upd as f64 <= cfg.ratio * counted as f64 + 8.0 + 1e-9,
                "upd {upd} vs counted {counted}"
            );
        }
        assert!(th.env_steps.load(Ordering::Relaxed) > cfg.warmup_steps as u64 * pop);
        assert!(th.updates.load(Ordering::Relaxed) > 0);
    }

    /// A body that returns cleanly reports `Finished`; the pool joins all
    /// threads on `stop` and agents partition round-robin.
    #[test]
    fn block_pool_reports_clean_exits() {
        let body: ActorBody<TransitionBlock> = Arc::new(|scope: ActorScope<TransitionBlock>| {
            scope.heartbeats.beat(scope.thread);
            let b = TransitionBlock::new(scope.thread, &scope.agents, 1, 1);
            let _ = scope.tx.send(b);
        });
        let pool = spawn_block_pool(4, 2, 4, body, Vec::new());
        assert_eq!(pool.threads(), 2);
        assert_eq!(pool.thread_agents(0), &[0, 2]);
        assert_eq!(pool.thread_agents(1), &[1, 3]);
        for _ in 0..2 {
            let b = pool
                .rx
                .recv_timeout(std::time::Duration::from_secs(10))
                .expect("each thread sends one block");
            pool.recycle(b);
        }
        let mut finished = Vec::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while finished.len() < 2 {
            assert!(std::time::Instant::now() < deadline, "missing exit events");
            if let Some(e) = pool.poll_exit() {
                assert!(!e.cause.is_failure(), "clean return must not be a failure");
                assert_eq!(e.agents, pool.thread_agents(e.thread));
                finished.push(e.thread);
            } else {
                std::thread::yield_now();
            }
        }
        finished.sort_unstable();
        assert_eq!(finished, vec![0, 1]);
        pool.stop();
    }

    /// A panicking body surfaces as a structured `Panic` exit (message
    /// preserved) and `respawn` restarts the thread with a bumped
    /// generation — the next incarnation runs in its place.
    #[test]
    fn block_pool_respawns_after_panic() {
        let body: ActorBody<TransitionBlock> = Arc::new(|scope: ActorScope<TransitionBlock>| {
            if scope.generation == 0 {
                panic!("planned pipeline-test panic");
            }
            // respawned incarnation: prove liveness, then idle until stop
            let b = TransitionBlock::new(scope.thread, &scope.agents, 1, 1);
            let _ = scope.tx.send(b);
            while !scope.stop.load(Ordering::Relaxed) {
                scope.heartbeats.beat(scope.thread);
                std::thread::yield_now();
            }
        });
        let mut pool = spawn_block_pool(2, 1, 4, body, Vec::new());
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        let exit = loop {
            assert!(std::time::Instant::now() < deadline, "no panic exit observed");
            match pool.poll_exit() {
                Some(e) => break e,
                None => std::thread::yield_now(),
            }
        };
        assert_eq!(exit.thread, 0);
        assert_eq!(exit.agents, vec![0, 1]);
        assert!(exit.cause.is_failure());
        match &exit.cause {
            ExitCause::Panic(msg) => assert!(msg.contains("planned pipeline-test panic")),
            other => panic!("expected Panic, got {other:?}"),
        }
        assert!(pool.respawn(0));
        // the generation-1 incarnation is alive and producing blocks
        let b = pool
            .rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("respawned thread sends a block");
        assert_eq!(b.thread(), 0);
        pool.stop();
    }

    /// Sink mode: rows land in each thread's bound stripe without any
    /// channel traffic, episodes arrive over the pool's episode lane,
    /// and a respawned incarnation re-binds to the same stripe.
    #[test]
    fn block_pool_sink_mode_ingests_and_rebinds_on_respawn() {
        use crate::replay::{Replay, ReplayBuffer, ShardedReplay};
        let sharded = ShardedReplay::new(vec![
            ReplayBuffer::new(64, 1, 1),
            ReplayBuffer::new(64, 1, 1),
        ]);
        let sinks: Vec<Arc<dyn RowSink<TransitionBlock>>> = (0..2)
            .map(|t| Arc::new(sharded.sink_for_thread(t)) as Arc<dyn RowSink<TransitionBlock>>)
            .collect();
        // gen 0 pushes 3 blocks then exits; gen 1 pushes 2 then exits.
        // Each block carries one row per owned agent + 1 episode report.
        let body: ActorBody<TransitionBlock> = Arc::new(|scope: ActorScope<TransitionBlock>| {
            let sink = scope.sink.as_ref().expect("pool spawned in sink mode");
            let mut b = TransitionBlock::new(scope.thread, &scope.agents, 1, 1);
            let blocks = if scope.generation == 0 { 3 } else { 2 };
            for i in 0..blocks {
                b.n = scope.agents.len();
                b.rew.iter_mut().for_each(|r| *r = i as f32);
                b.episodes.push(EpisodeReport {
                    agent: scope.agents[0],
                    ret: i as f64,
                    steps: 1,
                });
                sink.rows.push_rows(&b, 0, b.n);
                for e in b.episodes.drain(..) {
                    let _ = sink.episodes.send(e);
                }
                b.reset();
            }
        });
        let mut pool = spawn_block_pool(4, 2, 4, body, sinks);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        let wait_exits = |pool: &BlockPool<TransitionBlock>, n: usize| {
            let mut exits = 0;
            while exits < n {
                assert!(std::time::Instant::now() < deadline, "missing exit events");
                match pool.poll_exit() {
                    Some(e) => {
                        assert!(!e.cause.is_failure());
                        exits += 1;
                    }
                    None => std::thread::yield_now(),
                }
            }
        };
        wait_exits(&pool, 2);
        // 2 threads x 3 blocks x 2 agents, split evenly over the stripes;
        // nothing ever crossed the block channel
        assert_eq!(sharded.stripe_lens(), vec![6, 6]);
        assert!(pool.rx.try_recv().is_err(), "sink mode must not use the channel");

        // respawn thread 0: generation 1 feeds the SAME stripe
        assert!(pool.respawn(0));
        wait_exits(&pool, 1);
        assert_eq!(sharded.stripe_lens(), vec![10, 6]);
        assert_eq!(sharded.len(), 16);

        // all 8 episode reports (3+3 gen 0, 2 respawn) on the lane
        let mut episodes = 0;
        while pool.poll_episode().is_some() {
            episodes += 1;
        }
        assert_eq!(episodes, 8);
        pool.stop();
    }

    /// Dropping the pool (the early-`?` path in `Trainer::run`) sets the
    /// stop flag and joins every thread; respawn is refused once stopping.
    #[test]
    fn block_pool_drop_stops_threads() {
        let running = Arc::new(AtomicU64::new(0));
        let r = running.clone();
        let body: ActorBody<TransitionBlock> = Arc::new(move |scope: ActorScope<TransitionBlock>| {
            r.fetch_add(1, Ordering::SeqCst);
            while !scope.stop.load(Ordering::Relaxed) {
                std::thread::yield_now();
            }
            r.fetch_sub(1, Ordering::SeqCst);
        });
        let mut pool = spawn_block_pool(2, 2, 4, body, Vec::new());
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while running.load(Ordering::SeqCst) < 2 {
            assert!(std::time::Instant::now() < deadline, "threads never started");
            std::thread::yield_now();
        }
        pool.shutdown();
        assert_eq!(running.load(Ordering::SeqCst), 0, "shutdown must join all threads");
        assert!(!pool.respawn(0), "respawn after shutdown must be refused");
        drop(pool); // second shutdown via Drop: must be a no-op, not a hang
    }
}
