//! Actor/learner data pipeline (paper Appendix A), vectorized over the
//! population axis.
//!
//! Actor threads own their environment copies and a packed
//! [`PopMlp`](crate::nn::PopMlp) policy; each iteration they forward ALL
//! owned agents' observations as one `[n, obs_dim]` block, step a
//! [`VecEnv`] against one `[n, act_dim]` action matrix, and publish the
//! resulting transitions as ONE contiguous [`TransitionBlock`] message —
//! no per-transition `Vec` clones. Blocks flow through a bounded channel
//! (the paper's queue with a maximum size — actors block when the learner
//! lags) and are recycled back to their actor thread after the learner
//! drains them, so the steady-state loop is allocation-free. Actors
//! refresh their weights from the shared [`ParamView`] whenever the
//! learner publishes a new version (non-blocking for the learner) — one
//! contiguous copy per layer field for the whole population.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::population::ParamView;
use crate::envs::vec_env::{EpisodeEnd, VecEnv};
use crate::manifest::Artifact;
use crate::nn::from_state::pop_mlp_from_state;
use crate::nn::mlp::Activation;
use crate::util::rng::Rng;

/// One finished episode with this undiscounted return, tagged by agent.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EpisodeReport {
    pub agent: usize,
    pub ret: f64,
    pub steps: usize,
}

/// One actor iteration's transitions for all of the thread's agents, in
/// flat structure-of-arrays form: row `k` is agent `agents[k]`'s
/// transition, fields are contiguous `[n, ...]` blocks that the learner
/// feeds straight into [`ReplayBuffer::push_batch`]
/// (`crate::replay::ReplayBuffer::push_batch`) — no per-transition heap
/// traffic. Finished episodes ride along in `episodes`.
pub struct TransitionBlock {
    /// Spawning actor-thread index (the recycling route).
    thread: usize,
    /// Valid rows (row capacity is fixed at construction).
    pub n: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
    /// Agent id per row `[rows]`; sorted runs of equal ids.
    pub agents: Vec<usize>,
    /// `[rows, obs_dim]`
    pub obs: Vec<f32>,
    /// `[rows, act_dim]`
    pub act: Vec<f32>,
    /// `[rows]`
    pub rew: Vec<f32>,
    /// `[rows, obs_dim]`
    pub next_obs: Vec<f32>,
    /// `[rows]`, 0.0/1.0 (horizon cap excluded)
    pub done: Vec<f32>,
    /// Episodes that finished during this iteration.
    pub episodes: Vec<EpisodeReport>,
}

impl TransitionBlock {
    /// Preallocate a block with one row per entry of `agents`.
    pub fn new(thread: usize, agents: &[usize], obs_dim: usize, act_dim: usize) -> Self {
        let rows = agents.len();
        TransitionBlock {
            thread,
            n: 0,
            obs_dim,
            act_dim,
            agents: agents.to_vec(),
            obs: vec![0.0; rows * obs_dim],
            act: vec![0.0; rows * act_dim],
            rew: vec![0.0; rows],
            next_obs: vec![0.0; rows * obs_dim],
            done: vec![0.0; rows],
            episodes: Vec::new(),
        }
    }

    pub fn thread(&self) -> usize {
        self.thread
    }

    /// Clear for reuse (capacity and agent ids are kept).
    pub fn reset(&mut self) {
        self.n = 0;
        self.episodes.clear();
    }

    pub fn obs_row(&self, k: usize) -> &[f32] {
        &self.obs[k * self.obs_dim..(k + 1) * self.obs_dim]
    }

    pub fn act_row(&self, k: usize) -> &[f32] {
        &self.act[k * self.act_dim..(k + 1) * self.act_dim]
    }

    pub fn next_obs_row(&self, k: usize) -> &[f32] {
        &self.next_obs[k * self.obs_dim..(k + 1) * self.obs_dim]
    }
}

pub enum ActorMsg {
    /// One actor iteration's transitions as a contiguous block.
    Batch(TransitionBlock),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// Deterministic tanh policy + additive N(0, expl_noise) exploration.
    Td3,
    /// Squashed-Gaussian head `[mu, log_std]`; exploration = sampling.
    Sac,
}

impl PolicyKind {
    pub fn for_algo(algo: &str) -> PolicyKind {
        if algo.starts_with("sac") {
            PolicyKind::Sac
        } else {
            PolicyKind::Td3
        }
    }
}

#[derive(Clone, Debug)]
pub struct ActorConfig {
    pub env: String,
    pub policy: PolicyKind,
    /// Uniform-random actions for this many initial steps per agent.
    pub warmup_steps: usize,
    /// TD3 exploration noise std (read from state field "expl_noise" when
    /// present, this is the fallback).
    pub expl_noise: f32,
    /// Bounded queue size in BLOCKS (backpressure); one block carries one
    /// transition per agent of the sending thread.
    pub queue_cap: usize,
    pub seed: u64,
    /// Update:env-step ratio target for actor throttling (0 = unthrottled).
    pub ratio: f64,
    /// Extra env steps actors may run ahead of `updates / ratio`.
    pub lead_steps: u64,
    /// Backoff sleep while ratio-throttled, in microseconds.
    pub throttle_sleep_us: u64,
}

impl Default for ActorConfig {
    fn default() -> Self {
        ActorConfig {
            env: "pendulum".into(),
            policy: PolicyKind::Td3,
            warmup_steps: 500,
            expl_noise: 0.1,
            // one block ≈ one transition per owned agent, so a few hundred
            // in flight already decouples actors from the learner's drain
            // cadence without hoarding pop x cap transitions
            queue_cap: 256,
            seed: 0,
            ratio: 1.0,
            lead_steps: 2048,
            throttle_sleep_us: 200,
        }
    }
}

/// Shared counters for actor throttling (paper Appendix A: "agents are
/// blocked ... if the process handling the accelerator is lagging behind").
#[derive(Clone, Default)]
pub struct Throttle {
    /// Update steps completed by the learner.
    pub updates: Arc<AtomicU64>,
    /// Environment steps taken by all actors.
    pub env_steps: Arc<AtomicU64>,
}

impl Throttle {
    pub fn new() -> Self {
        Self::default()
    }

    /// May actors take another environment step?
    pub fn may_step(&self, cfg: &ActorConfig, pop: u64) -> bool {
        if cfg.ratio <= 0.0 {
            return true;
        }
        let env = self.env_steps.load(Ordering::Relaxed);
        let upd = self.updates.load(Ordering::Relaxed);
        let warmup = cfg.warmup_steps as u64 * pop;
        env < warmup + (upd as f64 / cfg.ratio) as u64 + cfg.lead_steps
    }
}

pub struct ActorPool {
    pub rx: Receiver<ActorMsg>,
    /// Per-thread return lanes for spent blocks (index = thread).
    recycle: Vec<SyncSender<TransitionBlock>>,
    stop: Arc<AtomicBool>,
    handles: Vec<JoinHandle<()>>,
}

impl ActorPool {
    /// Spawn `n_threads` actor threads covering all `artifact.pop` agents.
    pub fn spawn(
        artifact: &Artifact,
        view: ParamView,
        cfg: ActorConfig,
        n_threads: usize,
        throttle: Throttle,
    ) -> anyhow::Result<ActorPool> {
        let pop = artifact.pop;
        let n_threads = n_threads.clamp(1, pop);
        let (tx, rx) = std::sync::mpsc::sync_channel(cfg.queue_cap);
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        let mut recycle = Vec::new();
        for t in 0..n_threads {
            let agents: Vec<usize> = (0..pop).filter(|a| a % n_threads == t).collect();
            let (rtx, rrx) = std::sync::mpsc::sync_channel(cfg.queue_cap.max(4));
            recycle.push(rtx);
            let tx = tx.clone();
            let stop2 = stop.clone();
            let view2 = view.clone();
            let art = artifact.clone();
            let th = throttle.clone();
            let cfg2 = ActorConfig { seed: cfg.seed.wrapping_add(1000 + t as u64), ..cfg.clone() };
            handles.push(std::thread::spawn(move || {
                actor_loop(&art, view2, &cfg2, t, &agents, tx, rrx, stop2, th);
            }));
        }
        Ok(ActorPool { rx, recycle, stop, handles })
    }

    /// Hand a drained block back to its actor thread for reuse (the
    /// allocation-free steady state). Dropped silently if the thread is
    /// gone or its return lane is full — the actor then allocates afresh.
    pub fn recycle(&self, mut block: TransitionBlock) {
        block.reset();
        if let Some(lane) = self.recycle.get(block.thread) {
            let _ = lane.try_send(block);
        }
    }

    pub fn stop(self) {
        self.stop.store(true, Ordering::Relaxed);
        // drain so blocked senders can observe the stop flag
        while self.rx.try_recv().is_ok() {}
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn actor_loop(
    artifact: &Artifact,
    view: ParamView,
    cfg: &ActorConfig,
    thread: usize,
    agents: &[usize],
    tx: SyncSender<ActorMsg>,
    recycle: Receiver<TransitionBlock>,
    stop: Arc<AtomicBool>,
    throttle: Throttle,
) {
    let mut rng = Rng::new(cfg.seed);
    let n = agents.len();
    let mut venv = VecEnv::new(&cfg.env, n).unwrap();
    let (ha, fa) = match cfg.policy {
        PolicyKind::Td3 => (Activation::Relu, Activation::Tanh),
        PolicyKind::Sac => (Activation::Relu, Activation::None),
    };
    let mut host = Vec::new();
    let mut version = view.fetch_if_newer(0, &mut host);
    let mut policy = pop_mlp_from_state(artifact, &host, "policy", ha, fa).unwrap();

    let obs_dim = venv.obs_dim();
    let act_dim = venv.act_dim();
    let out_dim = policy.out_dim();
    let mut raw = vec![0.0f32; n * out_dim];
    let mut acts = vec![0.0f32; n * act_dim];
    let mut noise: Vec<f32> = agents
        .iter()
        .map(|&a| expl_noise_for(artifact, &host, a, cfg.expl_noise))
        .collect();
    let mut episodes: Vec<EpisodeEnd> = Vec::new();
    let mut block = TransitionBlock::new(thread, agents, obs_dim, act_dim);
    venv.reset_all(&mut rng);

    let mut iters: usize = 0;
    let pop_total = artifact.pop as u64;
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // Ratio throttling: wait while actors are too far ahead of the
        // learner (paper Appendix A blocking rule).
        if !throttle.may_step(cfg, pop_total) {
            std::thread::sleep(std::time::Duration::from_micros(cfg.throttle_sleep_us));
            continue;
        }
        // Non-blocking parameter refresh: one contiguous copy per layer
        // field for the whole population.
        let v2 = view.fetch_if_newer(version, &mut host);
        if v2 > version {
            version = v2;
            let _ = policy.sync_from_state(artifact, &host, "policy");
            for (k, &a) in agents.iter().enumerate() {
                noise[k] = expl_noise_for(artifact, &host, a, cfg.expl_noise);
            }
        }
        // Action selection for the whole block.
        if iters < cfg.warmup_steps {
            rng.fill_uniform(&mut acts, -1.0, 1.0);
        } else {
            policy.forward_block(agents, venv.obs(), &mut raw);
            for k in 0..n {
                select_action(
                    cfg.policy,
                    &raw[k * out_dim..(k + 1) * out_dim],
                    &mut acts[k * act_dim..(k + 1) * act_dim],
                    noise[k],
                    &mut rng,
                );
            }
        }
        // Record the pre-step observations, then step every env; the
        // VecEnv writes next_obs/rew/done straight into the block.
        block.obs.copy_from_slice(venv.obs());
        block.act.copy_from_slice(&acts);
        episodes.clear();
        venv.step_into(&mut rng, &acts, &mut block.next_obs, &mut block.rew, &mut block.done,
                       &mut episodes);
        block.n = n;
        for e in &episodes {
            block.episodes.push(EpisodeReport {
                agent: agents[e.slot],
                ret: e.ret,
                steps: e.steps,
            });
        }
        iters += 1;
        throttle.env_steps.fetch_add(n as u64, Ordering::Relaxed);
        if send_blocking(&tx, ActorMsg::Batch(block), &stop).is_err() {
            break;
        }
        // Reuse a drained block when the learner returned one; allocate
        // only when the recycle lane is empty (cold start / learner busy).
        block = match recycle.try_recv() {
            Ok(b) => b,
            Err(_) => TransitionBlock::new(thread, agents, obs_dim, act_dim),
        };
    }
}

/// Per-agent exploration noise from the state when the field exists.
fn expl_noise_for(artifact: &Artifact, host: &[f32], agent: usize, fallback: f32) -> f32 {
    match artifact.field("expl_noise") {
        Ok(f) if f.per_agent && agent < f.shape[0] && !host.is_empty() => {
            host[f.offset + agent * f.agent_stride()]
        }
        _ => fallback,
    }
}

fn select_action(kind: PolicyKind, raw: &[f32], act: &mut [f32], noise: f32, rng: &mut Rng) {
    match kind {
        PolicyKind::Td3 => {
            for (a, &r) in act.iter_mut().zip(raw) {
                *a = (r + (rng.normal() as f32) * noise).clamp(-1.0, 1.0);
            }
        }
        PolicyKind::Sac => {
            let half = raw.len() / 2;
            for i in 0..act.len() {
                let mu = raw[i];
                let log_std = raw[half + i].clamp(-20.0, 2.0);
                let eps = rng.normal() as f32;
                act[i] = (mu + log_std.exp() * eps).tanh();
            }
        }
    }
}

/// Bounded-channel send that keeps checking the stop flag (so shutdown
/// never deadlocks against a full queue).
fn send_blocking(
    tx: &SyncSender<ActorMsg>,
    mut msg: ActorMsg,
    stop: &AtomicBool,
) -> Result<(), ()> {
    loop {
        match tx.try_send(msg) {
            Ok(()) => return Ok(()),
            Err(TrySendError::Full(m)) => {
                if stop.load(Ordering::Relaxed) {
                    return Err(());
                }
                msg = m;
                std::thread::yield_now();
            }
            Err(TrySendError::Disconnected(_)) => return Err(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::RatioGate;

    #[test]
    fn select_action_td3_clamps() {
        let mut rng = Rng::new(0);
        let raw = [0.99f32, -0.99];
        let mut act = [0.0f32; 2];
        for _ in 0..100 {
            select_action(PolicyKind::Td3, &raw, &mut act, 0.5, &mut rng);
            assert!(act.iter().all(|a| (-1.0..=1.0).contains(a)));
        }
    }

    #[test]
    fn select_action_sac_uses_both_halves() {
        let mut rng = Rng::new(1);
        // mu = 0, log_std = -20 (≈ deterministic): action ≈ tanh(0) = 0
        let raw = [0.0f32, 0.0, -20.0, -20.0];
        let mut act = [9.0f32; 2];
        select_action(PolicyKind::Sac, &raw, &mut act, 0.0, &mut rng);
        assert!(act.iter().all(|a| a.abs() < 1e-3));
    }

    #[test]
    fn policy_kind_from_algo() {
        assert_eq!(PolicyKind::for_algo("sac"), PolicyKind::Sac);
        assert_eq!(PolicyKind::for_algo("td3"), PolicyKind::Td3);
        assert_eq!(PolicyKind::for_algo("cem"), PolicyKind::Td3);
    }

    #[test]
    fn transition_block_rows_and_recycling_reset() {
        let agents = [2usize, 5, 7];
        let mut b = TransitionBlock::new(1, &agents, 2, 1);
        assert_eq!(b.thread(), 1);
        b.obs.copy_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        b.act.copy_from_slice(&[0.1, 0.2, 0.3]);
        b.n = 3;
        b.episodes.push(EpisodeReport { agent: 5, ret: 1.0, steps: 7 });
        assert_eq!(b.obs_row(1), &[3.0, 4.0]);
        assert_eq!(b.act_row(2), &[0.3]);
        b.reset();
        assert_eq!(b.n, 0);
        assert!(b.episodes.is_empty());
        assert_eq!(b.agents, &agents); // ids survive recycling
    }

    /// Actors must stall within `lead_steps` of the ratio target and
    /// resume exactly when learner updates buy more headroom.
    #[test]
    fn throttle_stalls_within_lead_and_resumes_after_updates() {
        let cfg = ActorConfig {
            ratio: 1.0,
            lead_steps: 100,
            warmup_steps: 0,
            ..Default::default()
        };
        let th = Throttle::new();
        let mut taken = 0u64;
        while th.may_step(&cfg, 1) {
            th.env_steps.fetch_add(1, Ordering::Relaxed);
            taken += 1;
            assert!(taken <= 100, "actor ran past its lead budget");
        }
        assert_eq!(taken, 100);
        // learner progress frees exactly updates/ratio more steps
        th.updates.fetch_add(50, Ordering::Relaxed);
        assert!(th.may_step(&cfg, 1));
        let mut extra = 0u64;
        while th.may_step(&cfg, 1) {
            th.env_steps.fetch_add(1, Ordering::Relaxed);
            extra += 1;
            assert!(extra <= 50);
        }
        assert_eq!(extra, 50);
        // unthrottled config never stalls
        let free = ActorConfig { ratio: 0.0, ..Default::default() };
        assert!(th.may_step(&free, 1));
    }

    /// Closed loop of Throttle (actor side) against RatioGate (learner
    /// side): both make progress, neither runs away from the shared
    /// ratio target, and the system cannot deadlock.
    #[test]
    fn throttle_and_ratio_gate_converge_jointly() {
        let pop = 4u64;
        let cfg = ActorConfig {
            ratio: 0.5,
            lead_steps: 64,
            warmup_steps: 25,
            ..Default::default()
        };
        let th = Throttle::new();
        let mut gate = RatioGate::new(cfg.ratio, 8.0, cfg.warmup_steps as u64 * pop);
        let mut stalled_in_a_row = 0u32;
        for _ in 0..20_000 {
            let mut progressed = false;
            if th.may_step(&cfg, pop) {
                th.env_steps.fetch_add(1, Ordering::Relaxed);
                gate.on_env_steps(1);
                progressed = true;
            }
            if gate.may_update(1) {
                gate.on_update_steps(1);
                th.updates.fetch_add(1, Ordering::Relaxed);
                progressed = true;
            }
            if progressed {
                stalled_in_a_row = 0;
            } else {
                stalled_in_a_row += 1;
                assert!(stalled_in_a_row < 2, "actor/learner deadlock");
            }
            // actor side never exceeds warmup + updates/ratio + lead
            let env = th.env_steps.load(Ordering::Relaxed);
            let upd = th.updates.load(Ordering::Relaxed);
            let bound =
                cfg.warmup_steps as u64 * pop + (upd as f64 / cfg.ratio) as u64 + cfg.lead_steps;
            assert!(env <= bound, "env {env} > bound {bound}");
            // learner side never exceeds target * counted env steps + slack
            let counted = env.saturating_sub(cfg.warmup_steps as u64 * pop);
            assert!(
                upd as f64 <= cfg.ratio * counted as f64 + 8.0 + 1e-9,
                "upd {upd} vs counted {counted}"
            );
        }
        assert!(th.env_steps.load(Ordering::Relaxed) > cfg.warmup_steps as u64 * pop);
        assert!(th.updates.load(Ordering::Relaxed) > 0);
    }
}
