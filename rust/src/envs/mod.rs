//! Environment substrate.
//!
//! The paper trains on MuJoCo Gym locomotion tasks and Atari 2600 games;
//! neither is available in this image, so we implement substitutes that
//! preserve what the paper's claims depend on (DESIGN.md "Substitutions"):
//! matching observation/action tensor shapes, ~millisecond CPU step times
//! (paper Table 2), dense learnable rewards, and episodic structure.
//!
//! * [`locomotion`]: a deterministic torque-driven N-segment locomotor ODE,
//!   instantiated with the dimensionalities of HalfCheetah/Hopper/Walker2d/
//!   Ant/Humanoid/Swimmer.
//! * [`pendulum`]: the classic swing-up task (fast; used by tests and the
//!   quickstart example).
//! * [`minatar`]: a MinAtar-style 10x10x4 Breakout for the DQN pipeline.
//! * [`vec_env`]: batched stepping of n env copies over contiguous
//!   `[n, obs_dim]` / `[n, act_dim]` blocks (the actor fast path).
//! * [`pixel_vec_env`]: the same block contract for discrete-action
//!   [`PixelEnv`]s — a `[n]` action vector against `[n, H*W*C]` frame
//!   blocks with per-slot auto-reset (the pixel/DQN actor fast path).

pub mod locomotion;
pub mod minatar;
pub mod minatar_extra;
pub mod normalize;
pub mod pendulum;
pub mod pixel_vec_env;
pub mod vec_env;

pub use pixel_vec_env::PixelVecEnv;
pub use vec_env::{EpisodeEnd, VecEnv};

use crate::util::rng::Rng;

/// A continuous-control environment (actions in [-1, 1]^act_dim).
pub trait Env: Send {
    fn obs_dim(&self) -> usize;
    fn act_dim(&self) -> usize;
    /// Episode length cap.
    fn horizon(&self) -> usize;
    /// Reset and write the initial observation.
    fn reset(&mut self, rng: &mut Rng, obs: &mut [f32]);
    /// Advance one step; writes the next observation, returns (reward, done).
    /// `done` excludes the horizon cap (the caller tracks step counts).
    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> (f32, bool);
    fn name(&self) -> &'static str;
}

/// A discrete-action pixel environment (DQN path).
pub trait PixelEnv: Send {
    /// Frame shape (H, W, C); observations are HWC-flattened f32 in [0,1].
    fn frame(&self) -> (usize, usize, usize);
    fn n_actions(&self) -> usize;
    fn horizon(&self) -> usize;
    fn reset(&mut self, rng: &mut Rng, obs: &mut [f32]);
    fn step(&mut self, action: usize, rng: &mut Rng, obs: &mut [f32]) -> (f32, bool);
    fn name(&self) -> &'static str;
}

/// Construct a continuous env by its registry name.
pub fn make_env(name: &str) -> anyhow::Result<Box<dyn Env>> {
    match name {
        "pendulum" => Ok(Box::new(pendulum::Pendulum::new())),
        "halfcheetah" | "hopper" | "walker2d" | "ant" | "humanoid" | "swimmer" => {
            Ok(Box::new(locomotion::Locomotion::by_name(name)?))
        }
        other => anyhow::bail!("unknown env {other:?}"),
    }
}

/// Construct a pixel env by its registry name.
pub fn make_pixel_env(name: &str) -> anyhow::Result<Box<dyn PixelEnv>> {
    match name {
        "minatar" | "breakout" => Ok(Box::new(minatar::Breakout::new())),
        "asterix" => Ok(Box::new(minatar_extra::Asterix::new())),
        "spaceinvaders" => Ok(Box::new(minatar_extra::SpaceInvaders::new())),
        other => anyhow::bail!("unknown pixel env {other:?}"),
    }
}

pub fn env_names() -> &'static [&'static str] {
    &["pendulum", "halfcheetah", "hopper", "walker2d", "ant", "humanoid", "swimmer"]
}

/// Roll out a policy for one episode; returns (return, steps).
pub fn rollout(
    env: &mut dyn Env,
    rng: &mut Rng,
    mut policy: impl FnMut(&[f32], &mut [f32]),
) -> (f64, usize) {
    let mut obs = vec![0.0f32; env.obs_dim()];
    let mut act = vec![0.0f32; env.act_dim()];
    env.reset(rng, &mut obs);
    let mut ret = 0.0f64;
    for t in 0..env.horizon() {
        policy(&obs, &mut act);
        let (r, done) = env.step(&act, &mut obs);
        ret += r as f64;
        if done {
            return (ret, t + 1);
        }
    }
    (ret, env.horizon())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_builds_all() {
        for name in env_names() {
            let env = make_env(name).unwrap();
            assert!(env.obs_dim() > 0);
            assert!(env.act_dim() > 0);
            assert_eq!(env.name(), *name);
        }
        assert!(make_env("nope").is_err());
    }

    #[test]
    fn rollout_zero_policy_terminates() {
        let mut env = make_env("pendulum").unwrap();
        let mut rng = Rng::new(0);
        let (ret, steps) = rollout(env.as_mut(), &mut rng, |_, a| a.fill(0.0));
        assert!(steps <= env.horizon());
        assert!(ret.is_finite());
    }
}
