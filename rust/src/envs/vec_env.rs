//! Batched environment stepping for the vectorized actor path.
//!
//! [`VecEnv`] owns `n` copies of one environment and steps them all
//! against contiguous `[n, act_dim]` action / `[n, obs_dim]` observation
//! matrices, so the actor loop issues one call per iteration instead of
//! one per agent (the env-side half of the paper's population batching;
//! cf. GPU-vectorized population stepping in Shahid et al. 2024).
//!
//! Per-slot episode bookkeeping (undiscounted return, step count, horizon
//! cap) and auto-reset live here: a slot whose episode ends is reset
//! immediately and its fresh observation replaces the terminal one in the
//! internal `[n, obs_dim]` current-observation matrix, while the terminal
//! observation is still delivered to the caller's `next_obs` block (what
//! replay needs). The `done` flags written exclude the horizon cap,
//! matching the [`Env`] trait contract (done = bootstrap mask).

use crate::envs::{make_env, Env};
use crate::util::rng::Rng;

/// One finished episode: which slot, its return, and its length.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EpisodeEnd {
    pub slot: usize,
    pub ret: f64,
    pub steps: usize,
}

/// `n` same-named environments stepped as one `[n, ...]` block.
pub struct VecEnv {
    envs: Vec<Box<dyn Env>>,
    obs_dim: usize,
    act_dim: usize,
    /// Current observation matrix `[n, obs_dim]` (post-auto-reset).
    obs: Vec<f32>,
    ep_ret: Vec<f64>,
    ep_steps: Vec<usize>,
}

impl VecEnv {
    /// Build `n` copies of the registry env `name`.
    pub fn new(name: &str, n: usize) -> anyhow::Result<VecEnv> {
        anyhow::ensure!(n > 0, "VecEnv needs at least one slot");
        let envs = (0..n)
            .map(|_| make_env(name))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(VecEnv::from_envs(envs))
    }

    /// Wrap pre-built environments (all must share obs/act dims).
    pub fn from_envs(envs: Vec<Box<dyn Env>>) -> VecEnv {
        assert!(!envs.is_empty(), "VecEnv needs at least one slot");
        let obs_dim = envs[0].obs_dim();
        let act_dim = envs[0].act_dim();
        debug_assert!(envs.iter().all(|e| e.obs_dim() == obs_dim && e.act_dim() == act_dim));
        let n = envs.len();
        VecEnv {
            obs: vec![0.0; n * obs_dim],
            ep_ret: vec![0.0; n],
            ep_steps: vec![0; n],
            envs,
            obs_dim,
            act_dim,
        }
    }

    pub fn len(&self) -> usize {
        self.envs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.envs.is_empty()
    }

    pub fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    pub fn act_dim(&self) -> usize {
        self.act_dim
    }

    pub fn horizon(&self) -> usize {
        self.envs[0].horizon()
    }

    /// The current `[n, obs_dim]` observation matrix (already reflects
    /// auto-resets from the last `step_into`).
    pub fn obs(&self) -> &[f32] {
        &self.obs
    }

    /// Reset every slot, writing initial observations into the internal
    /// current-observation matrix.
    pub fn reset_all(&mut self, rng: &mut Rng) {
        let od = self.obs_dim;
        for (k, env) in self.envs.iter_mut().enumerate() {
            env.reset(rng, &mut self.obs[k * od..(k + 1) * od]);
            self.ep_ret[k] = 0.0;
            self.ep_steps[k] = 0;
        }
    }

    /// Reset every slot and write the initial `[n, obs_dim]` block into
    /// `obs` (also kept internally).
    pub fn reset_into(&mut self, rng: &mut Rng, obs: &mut [f32]) {
        assert_eq!(obs.len(), self.envs.len() * self.obs_dim, "obs block size mismatch");
        self.reset_all(rng);
        obs.copy_from_slice(&self.obs);
    }

    /// Step every slot with the `[n, act_dim]` action block.
    ///
    /// Writes the transition outputs `next_obs: [n, obs_dim]` (terminal
    /// observations where an episode ended), `rew: [n]`, `done: [n]`
    /// (1.0 = env termination, horizon cap excluded), appends one
    /// [`EpisodeEnd`] per finished episode, and auto-resets those slots
    /// (their fresh observation appears in [`VecEnv::obs`], not in
    /// `next_obs`).
    pub fn step_into(
        &mut self,
        rng: &mut Rng,
        acts: &[f32],
        next_obs: &mut [f32],
        rew: &mut [f32],
        done: &mut [f32],
        episodes: &mut Vec<EpisodeEnd>,
    ) {
        let n = self.envs.len();
        let (od, ad) = (self.obs_dim, self.act_dim);
        assert_eq!(acts.len(), n * ad, "act block size mismatch");
        assert_eq!(next_obs.len(), n * od, "next_obs block size mismatch");
        assert_eq!(rew.len(), n, "rew block size mismatch");
        assert_eq!(done.len(), n, "done block size mismatch");
        for k in 0..n {
            let out = &mut next_obs[k * od..(k + 1) * od];
            let (r, d) = self.envs[k].step(&acts[k * ad..(k + 1) * ad], out);
            rew[k] = r;
            done[k] = if d { 1.0 } else { 0.0 };
            self.ep_ret[k] += r as f64;
            self.ep_steps[k] += 1;
            let horizon_hit = self.ep_steps[k] >= self.envs[k].horizon();
            if d || horizon_hit {
                episodes.push(EpisodeEnd {
                    slot: k,
                    ret: self.ep_ret[k],
                    steps: self.ep_steps[k],
                });
                self.ep_ret[k] = 0.0;
                self.ep_steps[k] = 0;
                self.envs[k].reset(rng, &mut self.obs[k * od..(k + 1) * od]);
            } else {
                self.obs[k * od..(k + 1) * od].copy_from_slice(out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_scalar_env_loop() {
        // identical seeds => VecEnv stepping reproduces a hand-rolled
        // per-env loop exactly (same rng consumption order).
        let n = 3;
        let mut venv = VecEnv::new("pendulum", n).unwrap();
        let mut rng_v = Rng::new(42);
        let mut rng_s = Rng::new(42);
        let (od, ad) = (venv.obs_dim(), venv.act_dim());
        let mut obs_v = vec![0.0f32; n * od];
        venv.reset_into(&mut rng_v, &mut obs_v);
        assert_eq!(venv.obs(), &obs_v[..]);

        let mut envs: Vec<_> = (0..n).map(|_| make_env("pendulum").unwrap()).collect();
        let mut obs_s = vec![0.0f32; n * od];
        for (k, e) in envs.iter_mut().enumerate() {
            e.reset(&mut rng_s, &mut obs_s[k * od..(k + 1) * od]);
        }
        assert_eq!(venv.obs(), &obs_s[..]);

        let mut acts = vec![0.0f32; n * ad];
        let mut next = vec![0.0f32; n * od];
        let mut rew = vec![0.0f32; n];
        let mut done = vec![0.0f32; n];
        let mut eps = Vec::new();
        for t in 0..50 {
            for (k, a) in acts.iter_mut().enumerate() {
                *a = (((t + k) % 7) as f32 / 3.5 - 1.0).clamp(-1.0, 1.0);
            }
            venv.step_into(&mut rng_v, &acts, &mut next, &mut rew, &mut done, &mut eps);
            let mut next_s = vec![0.0f32; od];
            for k in 0..n {
                let (r, d) = envs[k].step(&acts[k * ad..(k + 1) * ad], &mut next_s);
                assert_eq!(rew[k], r, "step {t} slot {k}");
                assert_eq!(done[k] > 0.5, d);
                assert_eq!(&next[k * od..(k + 1) * od], &next_s[..]);
            }
        }
        assert!(eps.is_empty(), "pendulum horizon 200 not hit in 50 steps");
    }

    #[test]
    fn auto_reset_reports_episodes_and_keeps_stepping() {
        let mut venv = VecEnv::new("pendulum", 2).unwrap();
        let mut rng = Rng::new(7);
        venv.reset_all(&mut rng);
        let horizon = venv.horizon();
        let (od, ad) = (venv.obs_dim(), venv.act_dim());
        let acts = vec![0.0f32; 2 * ad];
        let mut next = vec![0.0f32; 2 * od];
        let mut rew = vec![0.0f32; 2];
        let mut done = vec![0.0f32; 2];
        let mut eps = Vec::new();
        for _ in 0..(2 * horizon + 5) {
            venv.step_into(&mut rng, &acts, &mut next, &mut rew, &mut done, &mut eps);
        }
        // both slots finished two horizon-capped episodes each
        assert_eq!(eps.len(), 4, "episodes: {eps:?}");
        for e in &eps {
            assert!(e.slot < 2);
            assert_eq!(e.steps, horizon);
            assert!(e.ret.is_finite());
        }
        // bookkeeping restarted: episode counters are mid-flight again
        assert!(venv.ep_steps.iter().all(|&s| s > 0 && s < horizon));
    }

    #[test]
    #[should_panic(expected = "act block size mismatch")]
    fn wrong_act_block_panics() {
        let mut venv = VecEnv::new("pendulum", 2).unwrap();
        let mut rng = Rng::new(0);
        venv.reset_all(&mut rng);
        let mut next = vec![0.0f32; 2 * venv.obs_dim()];
        let (mut r, mut d) = (vec![0.0; 2], vec![0.0; 2]);
        venv.step_into(&mut rng, &[0.0], &mut next, &mut r, &mut d, &mut Vec::new());
    }
}
