//! Running observation normalization (Welford over env streams).
//!
//! Locomotion RL implementations commonly standardize observations with
//! running statistics shared between actors and the learner. The wrapper
//! keeps the paper's Env interface so it can be slotted into the actor
//! pipeline via config; statistics are snapshotted so the learner's
//! batches and the actors' observations stay consistent.

use super::Env;
use crate::util::rng::Rng;

/// Running per-dimension mean/variance (Welford, merge-free single stream).
#[derive(Clone, Debug)]
pub struct RunningNorm {
    count: f64,
    mean: Vec<f64>,
    m2: Vec<f64>,
    pub clip: f32,
}

impl RunningNorm {
    pub fn new(dim: usize, clip: f32) -> Self {
        RunningNorm { count: 0.0, mean: vec![0.0; dim], m2: vec![0.0; dim], clip }
    }

    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    pub fn update(&mut self, obs: &[f32]) {
        debug_assert_eq!(obs.len(), self.mean.len());
        self.count += 1.0;
        for (i, &x) in obs.iter().enumerate() {
            let d = x as f64 - self.mean[i];
            self.mean[i] += d / self.count;
            self.m2[i] += d * (x as f64 - self.mean[i]);
        }
    }

    pub fn normalize(&self, obs: &mut [f32]) {
        if self.count < 2.0 {
            return;
        }
        for (i, o) in obs.iter_mut().enumerate() {
            let var = (self.m2[i] / (self.count - 1.0)).max(1e-8);
            let z = ((*o as f64 - self.mean[i]) / var.sqrt()) as f32;
            *o = z.clamp(-self.clip, self.clip);
        }
    }
}

/// Env wrapper applying (and updating) running normalization.
pub struct NormalizedEnv {
    inner: Box<dyn Env>,
    pub norm: RunningNorm,
    /// Freeze statistics (evaluation mode).
    pub frozen: bool,
}

impl NormalizedEnv {
    pub fn new(inner: Box<dyn Env>, clip: f32) -> Self {
        let dim = inner.obs_dim();
        NormalizedEnv { inner, norm: RunningNorm::new(dim, clip), frozen: false }
    }
}

impl Env for NormalizedEnv {
    fn obs_dim(&self) -> usize {
        self.inner.obs_dim()
    }

    fn act_dim(&self) -> usize {
        self.inner.act_dim()
    }

    fn horizon(&self) -> usize {
        self.inner.horizon()
    }

    fn reset(&mut self, rng: &mut Rng, obs: &mut [f32]) {
        self.inner.reset(rng, obs);
        if !self.frozen {
            self.norm.update(obs);
        }
        self.norm.normalize(obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> (f32, bool) {
        let (r, d) = self.inner.step(action, obs);
        if !self.frozen {
            self.norm.update(obs);
        }
        self.norm.normalize(obs);
        (r, d)
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::make_env;

    #[test]
    fn running_norm_matches_batch_stats() {
        let mut rng = Rng::new(0);
        let mut norm = RunningNorm::new(2, 10.0);
        let mut xs = Vec::new();
        for _ in 0..2000 {
            let x = [rng.normal_scaled(5.0, 2.0) as f32, rng.normal_scaled(-3.0, 0.5) as f32];
            norm.update(&x);
            xs.push(x);
        }
        let mut probe = [5.0f32, -3.0];
        norm.normalize(&mut probe);
        // the distribution means normalize to ~0
        assert!(probe[0].abs() < 0.1, "{probe:?}");
        assert!(probe[1].abs() < 0.15, "{probe:?}");
        // a +1-sigma point normalizes to ~1
        let mut hi = [7.0f32, -2.5];
        norm.normalize(&mut hi);
        assert!((hi[0] - 1.0).abs() < 0.1, "{hi:?}");
        assert!((hi[1] - 1.0).abs() < 0.15, "{hi:?}");
    }

    #[test]
    fn clipping_bounds_output() {
        let mut norm = RunningNorm::new(1, 2.0);
        for i in 0..100 {
            norm.update(&[(i % 3) as f32]);
        }
        let mut extreme = [1e9f32];
        norm.normalize(&mut extreme);
        assert!(extreme[0] <= 2.0);
    }

    #[test]
    fn wrapper_normalizes_env_stream() {
        let mut env = NormalizedEnv::new(make_env("halfcheetah").unwrap(), 5.0);
        let mut rng = Rng::new(1);
        let mut obs = vec![0.0f32; env.obs_dim()];
        env.reset(&mut rng, &mut obs);
        let act = vec![0.3; env.act_dim()];
        for _ in 0..500 {
            env.step(&act, &mut obs);
            assert!(obs.iter().all(|v| v.is_finite() && v.abs() <= 5.0));
        }
        // frozen mode stops updating statistics
        env.frozen = true;
        let count_before = env.norm.count;
        env.step(&act, &mut obs);
        assert_eq!(env.norm.count, count_before);
    }

    #[test]
    fn degenerate_dimensions_do_not_blow_up() {
        let mut norm = RunningNorm::new(1, 3.0);
        for _ in 0..50 {
            norm.update(&[42.0]); // zero variance
        }
        let mut x = [42.0f32];
        norm.normalize(&mut x);
        assert!(x[0].is_finite());
    }
}
