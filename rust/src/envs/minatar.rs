//! MinAtar-style Breakout: the Atari-2600 substitute for the DQN pipeline
//! (see DESIGN.md "Substitutions" — one CPU core cannot drive 84x84x4
//! frames, so the pixel code path is reproduced at 10x10x4 with the same
//! conv->fc architecture).
//!
//! Channels: 0 = paddle, 1 = ball, 2 = ball trail, 3 = bricks.
//! Actions: 0 = no-op, 1 = left, 2 = right. Reward +1 per brick. The
//! episode ends when the ball falls past the paddle. Rows of bricks
//! respawn once cleared, so long games keep scoring.

use super::PixelEnv;
use crate::util::rng::Rng;

pub const H: usize = 10;
pub const W: usize = 10;
pub const C: usize = 4;
pub const N_ACTIONS: usize = 3;

pub struct Breakout {
    paddle_x: usize,
    ball_x: i32,
    ball_y: i32,
    dx: i32,
    dy: i32,
    last_x: i32,
    last_y: i32,
    bricks: [[bool; W]; 3],
}

impl Breakout {
    pub fn new() -> Self {
        Breakout {
            paddle_x: W / 2,
            ball_x: 0,
            ball_y: 3,
            dx: 1,
            dy: 1,
            last_x: 0,
            last_y: 3,
            bricks: [[true; W]; 3],
        }
    }

    fn respawn_bricks_if_cleared(&mut self) {
        if self.bricks.iter().all(|row| row.iter().all(|b| !b)) {
            self.bricks = [[true; W]; 3];
        }
    }

    fn write_obs(&self, obs: &mut [f32]) {
        obs.fill(0.0);
        let set = |obs: &mut [f32], y: usize, x: usize, c: usize| {
            obs[(y * W + x) * C + c] = 1.0;
        };
        set(obs, H - 1, self.paddle_x, 0);
        if (0..H as i32).contains(&self.ball_y) {
            set(obs, self.ball_y as usize, self.ball_x as usize, 1);
        }
        if (0..H as i32).contains(&self.last_y) {
            set(obs, self.last_y as usize, self.last_x as usize, 2);
        }
        for (row, cols) in self.bricks.iter().enumerate() {
            for (x, &alive) in cols.iter().enumerate() {
                if alive {
                    set(obs, row + 1, x, 3);
                }
            }
        }
    }
}

impl Default for Breakout {
    fn default() -> Self {
        Self::new()
    }
}

impl PixelEnv for Breakout {
    fn frame(&self) -> (usize, usize, usize) {
        (H, W, C)
    }

    fn n_actions(&self) -> usize {
        N_ACTIONS
    }

    fn horizon(&self) -> usize {
        1000
    }

    fn reset(&mut self, rng: &mut Rng, obs: &mut [f32]) {
        *self = Breakout::new();
        self.ball_x = rng.below(W) as i32;
        self.dx = if rng.below(2) == 0 { 1 } else { -1 };
        self.paddle_x = rng.below(W);
        self.last_x = self.ball_x;
        self.last_y = self.ball_y;
        self.write_obs(obs);
    }

    fn step(&mut self, action: usize, _rng: &mut Rng, obs: &mut [f32]) -> (f32, bool) {
        debug_assert!(action < N_ACTIONS);
        match action {
            1 => self.paddle_x = self.paddle_x.saturating_sub(1),
            2 => self.paddle_x = (self.paddle_x + 1).min(W - 1),
            _ => {}
        }
        self.last_x = self.ball_x;
        self.last_y = self.ball_y;

        let mut reward = 0.0f32;
        let mut nx = self.ball_x + self.dx;
        let mut ny = self.ball_y + self.dy;
        // wall bounces
        if !(0..W as i32).contains(&nx) {
            self.dx = -self.dx;
            nx = self.ball_x + self.dx;
        }
        if ny < 0 {
            self.dy = -self.dy;
            ny = self.ball_y + self.dy;
        }
        // brick hit (rows 1..=3)
        if (1..=3).contains(&ny) {
            let row = (ny - 1) as usize;
            let col = nx as usize;
            if self.bricks[row][col] {
                self.bricks[row][col] = false;
                reward += 1.0;
                self.dy = -self.dy;
                ny = self.ball_y + self.dy;
                self.respawn_bricks_if_cleared();
            }
        }
        // paddle / bottom
        let mut done = false;
        if ny >= (H - 1) as i32 {
            if nx == self.paddle_x as i32 {
                self.dy = -1;
                ny = self.ball_y + self.dy;
            } else {
                done = true;
            }
        }
        self.ball_x = nx.clamp(0, W as i32 - 1);
        self.ball_y = ny.clamp(0, H as i32 - 1);
        self.write_obs(obs);
        (reward, done)
    }

    fn name(&self) -> &'static str {
        "breakout"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs_buf() -> Vec<f32> {
        vec![0.0; H * W * C]
    }

    #[test]
    fn obs_is_one_hot_planes() {
        let mut env = Breakout::new();
        let mut rng = Rng::new(0);
        let mut obs = obs_buf();
        env.reset(&mut rng, &mut obs);
        // exactly one paddle pixel, one ball pixel, one trail pixel
        let count = |c: usize| -> usize {
            (0..H * W).filter(|i| obs[i * C + c] == 1.0).count()
        };
        assert_eq!(count(0), 1);
        assert_eq!(count(1), 1);
        assert_eq!(count(2), 1);
        assert_eq!(count(3), 3 * W);
        assert!(obs.iter().all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn episode_ends_when_ball_missed() {
        let mut env = Breakout::new();
        let mut rng = Rng::new(1);
        let mut obs = obs_buf();
        env.reset(&mut rng, &mut obs);
        // hold paddle at left wall; eventually the ball falls elsewhere
        let mut done = false;
        for _ in 0..500 {
            let (_, d) = env.step(1, &mut rng, &mut obs);
            if d {
                done = true;
                break;
            }
        }
        assert!(done);
    }

    #[test]
    fn bricks_give_reward_and_respawn() {
        let mut env = Breakout::new();
        let mut rng = Rng::new(2);
        let mut obs = obs_buf();
        env.reset(&mut rng, &mut obs);
        // lead-track the ball (aim at its next column); reset on miss and
        // keep counting — a competent policy must accrue rewards
        let mut total = 0.0;
        for _ in 0..3000 {
            let target = env.ball_x + env.dx;
            let act = if target < env.paddle_x as i32 {
                1
            } else if target > env.paddle_x as i32 {
                2
            } else {
                0
            };
            let (r, d) = env.step(act, &mut rng, &mut obs);
            total += r;
            if d {
                env.reset(&mut rng, &mut obs);
            }
        }
        assert!(total >= 3.0, "tracking paddle should score, got {total}");
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut env = Breakout::new();
            let mut rng = Rng::new(5);
            let mut obs = obs_buf();
            env.reset(&mut rng, &mut obs);
            let mut tot = 0.0;
            for t in 0..100 {
                let (r, d) = env.step(t % 3, &mut rng, &mut obs);
                tot += r;
                if d {
                    break;
                }
            }
            (tot, obs)
        };
        assert_eq!(run(), run());
    }
}
