//! Classic pendulum swing-up (Gym `Pendulum-v1` dynamics, action rescaled
//! to [-1, 1]). Fast and quickly learnable — the default env for tests and
//! the quickstart end-to-end example.

use super::Env;
use crate::util::rng::Rng;

const MAX_SPEED: f64 = 8.0;
const MAX_TORQUE: f64 = 2.0;
const DT: f64 = 0.05;
const G: f64 = 10.0;
const M: f64 = 1.0;
const L: f64 = 1.0;

pub struct Pendulum {
    theta: f64,
    theta_dot: f64,
}

impl Pendulum {
    pub fn new() -> Self {
        Pendulum { theta: 0.0, theta_dot: 0.0 }
    }

    fn write_obs(&self, obs: &mut [f32]) {
        obs[0] = self.theta.cos() as f32;
        obs[1] = self.theta.sin() as f32;
        obs[2] = self.theta_dot as f32;
    }
}

impl Default for Pendulum {
    fn default() -> Self {
        Self::new()
    }
}

fn angle_normalize(x: f64) -> f64 {
    let two_pi = 2.0 * std::f64::consts::PI;
    ((x + std::f64::consts::PI).rem_euclid(two_pi)) - std::f64::consts::PI
}

impl Env for Pendulum {
    fn obs_dim(&self) -> usize {
        3
    }

    fn act_dim(&self) -> usize {
        1
    }

    fn horizon(&self) -> usize {
        200
    }

    fn reset(&mut self, rng: &mut Rng, obs: &mut [f32]) {
        self.theta = rng.uniform_in(-std::f64::consts::PI, std::f64::consts::PI);
        self.theta_dot = rng.uniform_in(-1.0, 1.0);
        self.write_obs(obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> (f32, bool) {
        let u = (action[0].clamp(-1.0, 1.0) as f64) * MAX_TORQUE;
        let th = angle_normalize(self.theta);
        let cost = th * th + 0.1 * self.theta_dot * self.theta_dot + 0.001 * u * u;
        let acc = 3.0 * G / (2.0 * L) * self.theta.sin() + 3.0 / (M * L * L) * u;
        self.theta_dot = (self.theta_dot + acc * DT).clamp(-MAX_SPEED, MAX_SPEED);
        self.theta += self.theta_dot * DT;
        self.write_obs(obs);
        (-cost as f32, false)
    }

    fn name(&self) -> &'static str {
        "pendulum"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reward_is_best_upright() {
        let mut env = Pendulum::new();
        env.theta = std::f64::consts::PI; // gym convention: 0 is upright...
        env.theta_dot = 0.0;
        let mut obs = [0.0f32; 3];
        let (r_down, _) = env.step(&[0.0], &mut obs);
        let mut env2 = Pendulum::new();
        env2.theta = 0.0;
        env2.theta_dot = 0.0;
        let (r_up, _) = env2.step(&[0.0], &mut obs);
        assert!(r_up > r_down);
        assert!(r_up <= 0.0); // cost-based reward is non-positive
    }

    #[test]
    fn speed_is_clamped() {
        let mut env = Pendulum::new();
        let mut rng = Rng::new(0);
        let mut obs = [0.0f32; 3];
        env.reset(&mut rng, &mut obs);
        for _ in 0..500 {
            env.step(&[1.0], &mut obs);
        }
        assert!(env.theta_dot.abs() <= MAX_SPEED);
        assert!(obs.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn angle_normalize_wraps() {
        assert!((angle_normalize(2.0 * std::f64::consts::PI)).abs() < 1e-12);
        // 3π wraps to ±π (both represent the same angle)
        assert!((angle_normalize(3.0 * std::f64::consts::PI).abs()
            - std::f64::consts::PI)
            .abs()
            < 1e-9);
    }
}
