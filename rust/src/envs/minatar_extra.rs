//! Additional MinAtar-style games (Asterix, Space Invaders) — the DQN
//! pixel substrate beyond Breakout, matching MinAtar's 10x10 grids and
//! channel-plane observations. Artifacts for them are generated on demand
//! (`python -m compile.aot --spec dqn:asterix:p2:k1:b32`).

use super::PixelEnv;
use crate::util::rng::Rng;

pub const H: usize = 10;
pub const W: usize = 10;

// ---------------------------------------------------------------------------
// Asterix: collect treasure, dodge enemies crossing the screen.
// Channels: 0 = player, 1 = enemy, 2 = treasure, 3 = direction trail.
// Actions: 0 noop, 1 left, 2 right, 3 up, 4 down.
// ---------------------------------------------------------------------------

pub struct Asterix {
    px: usize,
    py: usize,
    /// (y, x, dir, is_gold); one entity per row 1..=8
    entities: Vec<(usize, i32, i32, bool)>,
    spawn_timer: usize,
}

impl Asterix {
    pub const N_ACTIONS: usize = 5;

    pub fn new() -> Self {
        Asterix { px: W / 2, py: H / 2, entities: Vec::new(), spawn_timer: 0 }
    }

    fn write_obs(&self, obs: &mut [f32]) {
        obs.fill(0.0);
        let c = 4;
        obs[(self.py * W + self.px) * c] = 1.0;
        for &(row, x, dir, gold) in &self.entities {
            if (0..W as i32).contains(&x) {
                let ch = if gold { 2 } else { 1 };
                obs[(row * W + x as usize) * c + ch] = 1.0;
                let trail = x - dir;
                if (0..W as i32).contains(&trail) {
                    obs[(row * W + trail as usize) * c + 3] = 1.0;
                }
            }
        }
    }
}

impl Default for Asterix {
    fn default() -> Self {
        Self::new()
    }
}

impl PixelEnv for Asterix {
    fn frame(&self) -> (usize, usize, usize) {
        (H, W, 4)
    }

    fn n_actions(&self) -> usize {
        Self::N_ACTIONS
    }

    fn horizon(&self) -> usize {
        1000
    }

    fn reset(&mut self, rng: &mut Rng, obs: &mut [f32]) {
        *self = Asterix::new();
        self.px = rng.below(W);
        self.py = 1 + rng.below(H - 2);
        self.write_obs(obs);
    }

    fn step(&mut self, action: usize, rng: &mut Rng, obs: &mut [f32]) -> (f32, bool) {
        match action {
            1 => self.px = self.px.saturating_sub(1),
            2 => self.px = (self.px + 1).min(W - 1),
            3 => self.py = self.py.saturating_sub(1).max(1),
            4 => self.py = (self.py + 1).min(H - 2),
            _ => {}
        }
        // spawn entities on a timer
        self.spawn_timer += 1;
        if self.spawn_timer >= 3 && self.entities.len() < 6 {
            self.spawn_timer = 0;
            let row = 1 + rng.below(H - 2);
            if !self.entities.iter().any(|e| e.0 == row) {
                let from_left = rng.below(2) == 0;
                let gold = rng.below(3) == 0;
                self.entities.push((
                    row,
                    if from_left { 0 } else { W as i32 - 1 },
                    if from_left { 1 } else { -1 },
                    gold,
                ));
            }
        }
        // move entities, detect collisions
        let (px, py) = (self.px as i32, self.py);
        let mut reward = 0.0f32;
        let mut dead = false;
        self.entities.retain_mut(|e| {
            e.1 += e.2;
            if e.0 == py && e.1 == px {
                if e.3 {
                    reward += 1.0;
                    return false; // treasure collected
                }
                dead = true;
            }
            (0..W as i32).contains(&e.1)
        });
        self.write_obs(obs);
        (reward, dead)
    }

    fn name(&self) -> &'static str {
        "asterix"
    }
}

// ---------------------------------------------------------------------------
// Space Invaders: shoot the descending alien grid, dodge its bombs.
// Channels: 0 = cannon, 1 = aliens, 2 = friendly shot, 3 = alien bomb.
// Actions: 0 noop, 1 left, 2 right, 3 fire.
// ---------------------------------------------------------------------------

pub struct SpaceInvaders {
    px: usize,
    aliens: [[bool; W]; 3],
    alien_y: usize,
    alien_dir: i32,
    move_timer: usize,
    shot: Option<(i32, usize)>, // (y, x)
    bombs: Vec<(i32, usize)>,
}

impl SpaceInvaders {
    pub const N_ACTIONS: usize = 4;

    pub fn new() -> Self {
        let mut aliens = [[false; W]; 3];
        for row in aliens.iter_mut() {
            for (x, a) in row.iter_mut().enumerate() {
                *a = (2..8).contains(&x);
            }
        }
        SpaceInvaders {
            px: W / 2,
            aliens,
            alien_y: 1,
            alien_dir: 1,
            move_timer: 0,
            shot: None,
            bombs: Vec::new(),
        }
    }

    fn alien_bounds(&self) -> Option<(usize, usize)> {
        let mut lo = None;
        let mut hi = None;
        for row in &self.aliens {
            for (x, &a) in row.iter().enumerate() {
                if a {
                    lo = Some(lo.map_or(x, |l: usize| l.min(x)));
                    hi = Some(hi.map_or(x, |h: usize| h.max(x)));
                }
            }
        }
        lo.zip(hi)
    }

    fn write_obs(&self, obs: &mut [f32]) {
        obs.fill(0.0);
        let c = 4;
        obs[((H - 1) * W + self.px) * c] = 1.0;
        for (r, row) in self.aliens.iter().enumerate() {
            let y = self.alien_y + r;
            if y >= H {
                continue;
            }
            for (x, &a) in row.iter().enumerate() {
                if a {
                    obs[(y * W + x) * c + 1] = 1.0;
                }
            }
        }
        if let Some((y, x)) = self.shot {
            if (0..H as i32).contains(&y) {
                obs[(y as usize * W + x) * c + 2] = 1.0;
            }
        }
        for &(y, x) in &self.bombs {
            if (0..H as i32).contains(&y) {
                obs[(y as usize * W + x) * c + 3] = 1.0;
            }
        }
    }
}

impl Default for SpaceInvaders {
    fn default() -> Self {
        Self::new()
    }
}

impl PixelEnv for SpaceInvaders {
    fn frame(&self) -> (usize, usize, usize) {
        (H, W, 4)
    }

    fn n_actions(&self) -> usize {
        Self::N_ACTIONS
    }

    fn horizon(&self) -> usize {
        1000
    }

    fn reset(&mut self, rng: &mut Rng, obs: &mut [f32]) {
        *self = SpaceInvaders::new();
        self.px = rng.below(W);
        self.write_obs(obs);
    }

    fn step(&mut self, action: usize, rng: &mut Rng, obs: &mut [f32]) -> (f32, bool) {
        match action {
            1 => self.px = self.px.saturating_sub(1),
            2 => self.px = (self.px + 1).min(W - 1),
            3 => {
                if self.shot.is_none() {
                    self.shot = Some((H as i32 - 2, self.px));
                }
            }
            _ => {}
        }
        let mut reward = 0.0f32;
        // friendly shot travels up, kills the lowest alien in its column
        if let Some((y, x)) = self.shot.take() {
            let ny = y - 1;
            let mut hit = false;
            for r in (0..3).rev() {
                let ay = self.alien_y + r;
                if ay as i32 == ny && self.aliens[r][x] {
                    self.aliens[r][x] = false;
                    reward += 1.0;
                    hit = true;
                    break;
                }
            }
            if !hit && ny >= 0 {
                self.shot = Some((ny, x));
            }
        }
        // alien march (speeds up as ranks thin)
        let alive: usize = self.aliens.iter().flatten().filter(|&&a| a).count();
        let period = 1 + alive / 12;
        self.move_timer += 1;
        if self.move_timer >= period {
            self.move_timer = 0;
            if let Some((lo, hi)) = self.alien_bounds() {
                if (self.alien_dir > 0 && hi + 1 >= W)
                    || (self.alien_dir < 0 && lo == 0)
                {
                    self.alien_dir = -self.alien_dir;
                    self.alien_y += 1;
                } else {
                    for row in self.aliens.iter_mut() {
                        if self.alien_dir > 0 {
                            row.rotate_right(1);
                        } else {
                            row.rotate_left(1);
                        }
                    }
                }
            }
            // random alien drops a bomb
            if alive > 0 && rng.below(2) == 0 && self.bombs.len() < 3 {
                let cols: Vec<usize> = (0..W)
                    .filter(|&x| self.aliens.iter().any(|r| r[x]))
                    .collect();
                let x = cols[rng.below(cols.len())];
                self.bombs.push((self.alien_y as i32 + 2, x));
            }
        }
        // bombs fall
        let px = self.px;
        let mut dead = false;
        self.bombs.retain_mut(|b| {
            b.0 += 1;
            if b.0 as usize == H - 1 && b.1 == px {
                dead = true;
            }
            (b.0 as usize) < H
        });
        // aliens reaching the cannon row: game over; cleared wave respawns
        if self.alien_y + 2 >= H - 1 {
            dead = true;
        }
        if alive == 0 {
            let fresh = SpaceInvaders::new();
            self.aliens = fresh.aliens;
            self.alien_y = 1;
        }
        self.write_obs(obs);
        (reward, dead)
    }

    fn name(&self) -> &'static str {
        "spaceinvaders"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buf() -> Vec<f32> {
        vec![0.0; H * W * 4]
    }

    #[test]
    fn asterix_treasure_gives_reward_enemy_kills() {
        let mut env = Asterix::new();
        let mut rng = Rng::new(0);
        let mut obs = buf();
        env.reset(&mut rng, &mut obs);
        // run a no-op policy; both outcomes must be reachable over seeds
        let mut saw_reward = false;
        let mut saw_death = false;
        for seed in 0..30 {
            let mut rng = Rng::new(seed);
            env.reset(&mut rng, &mut obs);
            for _ in 0..400 {
                let (r, d) = env.step(0, &mut rng, &mut obs);
                if r > 0.0 {
                    saw_reward = true;
                }
                if d {
                    saw_death = true;
                    break;
                }
            }
            if saw_reward && saw_death {
                break;
            }
        }
        assert!(saw_death, "enemies never caught a stationary player");
    }

    #[test]
    fn asterix_obs_planes_are_binary() {
        let mut env = Asterix::new();
        let mut rng = Rng::new(1);
        let mut obs = buf();
        env.reset(&mut rng, &mut obs);
        for t in 0..100 {
            let (_, d) = env.step(t % 5, &mut rng, &mut obs);
            assert!(obs.iter().all(|&v| v == 0.0 || v == 1.0));
            if d {
                break;
            }
        }
    }

    #[test]
    fn space_invaders_shooting_scores() {
        let mut env = SpaceInvaders::new();
        let mut rng = Rng::new(2);
        let mut obs = buf();
        env.reset(&mut rng, &mut obs);
        let mut total = 0.0;
        for t in 0..600 {
            // fire whenever possible, wiggle otherwise
            let act = if t % 3 == 0 { 3 } else { 1 + (t / 7) % 2 };
            let (r, d) = env.step(act, &mut rng, &mut obs);
            total += r;
            if d {
                env.reset(&mut rng, &mut obs);
            }
        }
        assert!(total >= 2.0, "spray-and-pray should hit aliens, got {total}");
    }

    #[test]
    fn space_invaders_march_descends_and_ends_game() {
        let mut env = SpaceInvaders::new();
        let mut rng = Rng::new(3);
        let mut obs = buf();
        env.reset(&mut rng, &mut obs);
        let mut done = false;
        for _ in 0..1000 {
            let (_, d) = env.step(0, &mut rng, &mut obs);
            if d {
                done = true;
                break;
            }
        }
        assert!(done, "un-opposed aliens must eventually reach the cannon");
    }

    #[test]
    fn frames_match_registry() {
        assert_eq!(Asterix::new().frame(), (10, 10, 4));
        assert_eq!(Asterix::N_ACTIONS, 5);
        assert_eq!(SpaceInvaders::new().frame(), (10, 10, 4));
        assert_eq!(SpaceInvaders::N_ACTIONS, 4);
    }
}
