//! Deterministic N-segment locomotor ODE — the MuJoCo-Gym substitute.
//!
//! Model: a chain of `n` torque-driven joints with stiffness, damping and
//! nearest-neighbour coupling, attached to a body that gains forward
//! velocity from "paddling" — the thrust of joint `i` is
//! `sin(theta_i) * theta_dot_i`, so cyclic joint motion (fast through the
//! positive-sine region, slow back) propels the body, giving policies a
//! genuinely learnable gait. Reward is MuJoCo-Gym-shaped:
//! `forward_velocity - ctrl_cost * |a|^2` (+ a survival bonus for the
//! tasks that can fall).
//!
//! Each named task matches the Gym observation/action dimensionalities
//! (Ant uses the 27-dim proprioceptive observation) so the AOT artifacts,
//! replay layout and network shapes are identical to the paper's setup;
//! see DESIGN.md "Substitutions".

use super::Env;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct LocomotionSpec {
    pub name: &'static str,
    pub obs_dim: usize,
    pub n_joints: usize,
    pub dt: f64,
    pub substeps: usize,
    pub gear: f64,
    pub stiffness: f64,
    pub damping: f64,
    pub coupling: f64,
    pub thrust_gain: f64,
    pub body_friction: f64,
    pub ctrl_cost: f64,
    /// Survival bonus per step (hopper/walker/humanoid).
    pub alive_bonus: f64,
    /// Terminate when mean |theta| exceeds this (0 = never, cheetah-like).
    pub fall_angle: f64,
    pub horizon: usize,
}

pub fn spec_by_name(name: &str) -> anyhow::Result<LocomotionSpec> {
    let base = LocomotionSpec {
        name: "halfcheetah",
        obs_dim: 17,
        n_joints: 6,
        dt: 0.05,
        substeps: 4,
        gear: 8.0,
        stiffness: 4.0,
        damping: 1.0,
        coupling: 1.5,
        thrust_gain: 1.5,
        body_friction: 1.2,
        ctrl_cost: 0.1,
        alive_bonus: 0.0,
        fall_angle: 0.0,
        horizon: 1000,
    };
    Ok(match name {
        "halfcheetah" => base,
        "hopper" => LocomotionSpec {
            name: "hopper",
            obs_dim: 11,
            n_joints: 3,
            alive_bonus: 1.0,
            fall_angle: 1.1,
            gear: 6.0,
            ctrl_cost: 1e-3,
            ..base
        },
        "walker2d" => LocomotionSpec {
            name: "walker2d",
            obs_dim: 17,
            n_joints: 6,
            alive_bonus: 1.0,
            fall_angle: 1.3,
            ctrl_cost: 1e-3,
            ..base
        },
        "ant" => LocomotionSpec {
            name: "ant",
            obs_dim: 27,
            n_joints: 8,
            gear: 10.0,
            coupling: 2.0,
            ctrl_cost: 0.5,
            alive_bonus: 1.0,
            fall_angle: 0.0,
            ..base
        },
        "humanoid" => LocomotionSpec {
            name: "humanoid",
            obs_dim: 376,
            n_joints: 17,
            gear: 12.0,
            alive_bonus: 5.0,
            fall_angle: 1.0,
            ctrl_cost: 0.1,
            ..base
        },
        "swimmer" => LocomotionSpec {
            name: "swimmer",
            obs_dim: 8,
            n_joints: 2,
            gear: 4.0,
            stiffness: 2.0,
            alive_bonus: 0.0,
            fall_angle: 0.0,
            ctrl_cost: 1e-4,
            ..base
        },
        other => anyhow::bail!("unknown locomotion task {other:?}"),
    })
}

pub struct Locomotion {
    pub spec: LocomotionSpec,
    theta: Vec<f64>,
    theta_dot: Vec<f64>,
    vx: f64,
    x: f64,
}

impl Locomotion {
    pub fn by_name(name: &str) -> anyhow::Result<Self> {
        Ok(Self::new(spec_by_name(name)?))
    }

    pub fn new(spec: LocomotionSpec) -> Self {
        let n = spec.n_joints;
        Locomotion { spec, theta: vec![0.0; n], theta_dot: vec![0.0; n], vx: 0.0, x: 0.0 }
    }

    pub fn forward_distance(&self) -> f64 {
        self.x
    }

    fn write_obs(&self, obs: &mut [f32]) {
        // Layout: [vx, theta..., theta_dot..., trig features...] padded to
        // obs_dim with sin/cos of joint angles (deterministic features so
        // every named task's obs_dim is filled exactly).
        let n = self.spec.n_joints;
        debug_assert_eq!(obs.len(), self.spec.obs_dim);
        let mut i = 0;
        obs[i] = self.vx as f32;
        i += 1;
        for j in 0..n {
            if i < obs.len() {
                obs[i] = self.theta[j] as f32;
                i += 1;
            }
        }
        for j in 0..n {
            if i < obs.len() {
                obs[i] = self.theta_dot[j] as f32;
                i += 1;
            }
        }
        let mut k = 0usize;
        while i < obs.len() {
            let j = k % n;
            let harmonic = (k / n / 2 + 1) as f64;
            obs[i] = if (k / n) % 2 == 0 {
                (harmonic * self.theta[j]).sin() as f32
            } else {
                (harmonic * self.theta[j]).cos() as f32
            };
            i += 1;
            k += 1;
        }
    }
}

impl Env for Locomotion {
    fn obs_dim(&self) -> usize {
        self.spec.obs_dim
    }

    fn act_dim(&self) -> usize {
        self.spec.n_joints
    }

    fn horizon(&self) -> usize {
        self.spec.horizon
    }

    fn reset(&mut self, rng: &mut Rng, obs: &mut [f32]) {
        for t in self.theta.iter_mut() {
            *t = rng.uniform_in(-0.1, 0.1);
        }
        for t in self.theta_dot.iter_mut() {
            *t = rng.uniform_in(-0.1, 0.1);
        }
        self.vx = 0.0;
        self.x = 0.0;
        self.write_obs(obs);
    }

    fn step(&mut self, action: &[f32], obs: &mut [f32]) -> (f32, bool) {
        let s = &self.spec;
        let n = s.n_joints;
        debug_assert_eq!(action.len(), n);
        let h = s.dt / s.substeps as f64;
        let mut ctrl2 = 0.0;
        for &a in action {
            let a = a.clamp(-1.0, 1.0) as f64;
            ctrl2 += a * a;
        }
        for _ in 0..s.substeps {
            let mut thrust = 0.0;
            for j in 0..n {
                let a = (action[j].clamp(-1.0, 1.0)) as f64;
                let left = if j > 0 { self.theta[j - 1] } else { 0.0 };
                let right = if j + 1 < n { self.theta[j + 1] } else { 0.0 };
                let acc = s.gear * a - s.stiffness * self.theta[j]
                    - s.damping * self.theta_dot[j]
                    + s.coupling * (left + right - 2.0 * self.theta[j]);
                // semi-implicit Euler
                self.theta_dot[j] += h * acc;
                self.theta[j] += h * self.theta_dot[j];
                thrust += self.theta[j].sin() * self.theta_dot[j];
            }
            self.vx += h * (s.thrust_gain * thrust - s.body_friction * self.vx);
            self.x += h * self.vx;
        }
        let reward = self.vx + s.alive_bonus - s.ctrl_cost * ctrl2;
        let fallen = if s.fall_angle > 0.0 {
            let mean_abs: f64 =
                self.theta.iter().map(|t| t.abs()).sum::<f64>() / n as f64;
            mean_abs > s.fall_angle
        } else {
            false
        };
        self.write_obs(obs);
        (reward as f32, fallen)
    }

    fn name(&self) -> &'static str {
        self.spec.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_match_paper_tasks() {
        for (name, obs, act) in [
            ("halfcheetah", 17, 6),
            ("hopper", 11, 3),
            ("walker2d", 17, 6),
            ("ant", 27, 8),
            ("humanoid", 376, 17),
            ("swimmer", 8, 2),
        ] {
            let e = Locomotion::by_name(name).unwrap();
            assert_eq!(e.obs_dim(), obs, "{name} obs");
            assert_eq!(e.act_dim(), act, "{name} act");
        }
    }

    #[test]
    fn deterministic_given_seed_and_actions() {
        let run = || {
            let mut env = Locomotion::by_name("halfcheetah").unwrap();
            let mut rng = Rng::new(42);
            let mut obs = vec![0.0; env.obs_dim()];
            env.reset(&mut rng, &mut obs);
            let act = vec![0.5; env.act_dim()];
            let mut total = 0.0;
            for _ in 0..50 {
                let (r, _) = env.step(&act, &mut obs);
                total += r;
            }
            (total, obs)
        };
        let (r1, o1) = run();
        let (r2, o2) = run();
        assert_eq!(r1, r2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn paddling_moves_forward() {
        // An oscillating "gait" should out-run both zero and constant
        // torques, demonstrating the task is learnable (not degenerate).
        fn distance(policy: impl Fn(usize, usize) -> f32) -> f64 {
            let mut env = Locomotion::by_name("halfcheetah").unwrap();
            let mut rng = Rng::new(1);
            let mut obs = vec![0.0; env.obs_dim()];
            env.reset(&mut rng, &mut obs);
            let mut act = vec![0.0; env.act_dim()];
            for t in 0..400 {
                for (j, a) in act.iter_mut().enumerate() {
                    *a = policy(t, j);
                }
                env.step(&act, &mut obs);
            }
            env.forward_distance()
        }
        let zero = distance(|_, _| 0.0);
        // phase-shifted sawtooth-ish paddling
        let gait = distance(|t, j| {
            let phase = t as f32 * 0.35 + j as f32 * 1.0;
            // asymmetric stroke: strong positive push, weak recovery
            if phase.sin() > 0.0 { 1.0 } else { -0.25 }
        });
        assert!(
            gait > zero + 1.0,
            "gait should progress: gait={gait:.2} zero={zero:.2}"
        );
    }

    #[test]
    fn hopper_falls_on_extreme_torque() {
        let mut env = Locomotion::by_name("hopper").unwrap();
        let mut rng = Rng::new(3);
        let mut obs = vec![0.0; env.obs_dim()];
        env.reset(&mut rng, &mut obs);
        let act = vec![1.0; env.act_dim()];
        let mut done = false;
        for _ in 0..env.horizon() {
            let (_, d) = env.step(&act, &mut obs);
            if d {
                done = true;
                break;
            }
        }
        assert!(done, "constant max torque should topple the hopper");
    }

    #[test]
    fn observations_stay_finite() {
        let mut env = Locomotion::by_name("ant").unwrap();
        let mut rng = Rng::new(4);
        let mut obs = vec![0.0; env.obs_dim()];
        env.reset(&mut rng, &mut obs);
        let mut act = vec![0.0; env.act_dim()];
        for t in 0..1000 {
            for (j, a) in act.iter_mut().enumerate() {
                *a = ((t * (j + 1)) as f32 * 0.7).sin();
            }
            env.step(&act, &mut obs);
        }
        assert!(obs.iter().all(|v| v.is_finite()));
    }
}
