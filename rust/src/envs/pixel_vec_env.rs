//! Batched pixel-environment stepping for the vectorized DQN actor path.
//!
//! [`PixelVecEnv`] is the discrete-action mirror of
//! [`VecEnv`](crate::envs::vec_env::VecEnv): it owns `n` copies of one
//! [`PixelEnv`] and steps them all against a `[n]` action vector and
//! contiguous `[n, frame_len]` observation blocks, so the pixel actor
//! loop issues one call per iteration instead of one per agent.
//!
//! Per-slot episode bookkeeping (undiscounted return, step count, horizon
//! cap) and auto-reset follow the same contract as `VecEnv`: a slot whose
//! episode ends is reset immediately and its fresh frame replaces the
//! terminal one in the internal `[n, frame_len]` current-observation
//! matrix, while the terminal frame is still delivered to the caller's
//! `next_obs` block (what replay needs). The `done` flags written exclude
//! the horizon cap (done = bootstrap mask).

use crate::envs::vec_env::EpisodeEnd;
use crate::envs::{make_pixel_env, PixelEnv};
use crate::util::rng::Rng;

/// `n` same-named pixel environments stepped as one `[n, ...]` block.
pub struct PixelVecEnv {
    envs: Vec<Box<dyn PixelEnv>>,
    frame: (usize, usize, usize),
    frame_len: usize,
    n_actions: usize,
    /// Current observation matrix `[n, frame_len]` (post-auto-reset).
    obs: Vec<f32>,
    ep_ret: Vec<f64>,
    ep_steps: Vec<usize>,
}

impl PixelVecEnv {
    /// Build `n` copies of the registry pixel env `name`.
    pub fn new(name: &str, n: usize) -> anyhow::Result<PixelVecEnv> {
        anyhow::ensure!(n > 0, "PixelVecEnv needs at least one slot");
        let envs = (0..n)
            .map(|_| make_pixel_env(name))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(PixelVecEnv::from_envs(envs))
    }

    /// Wrap pre-built environments (all must share frame/action dims).
    pub fn from_envs(envs: Vec<Box<dyn PixelEnv>>) -> PixelVecEnv {
        assert!(!envs.is_empty(), "PixelVecEnv needs at least one slot");
        let frame = envs[0].frame();
        let n_actions = envs[0].n_actions();
        debug_assert!(envs.iter().all(|e| e.frame() == frame && e.n_actions() == n_actions));
        let frame_len = frame.0 * frame.1 * frame.2;
        let n = envs.len();
        PixelVecEnv {
            obs: vec![0.0; n * frame_len],
            ep_ret: vec![0.0; n],
            ep_steps: vec![0; n],
            envs,
            frame,
            frame_len,
            n_actions,
        }
    }

    pub fn len(&self) -> usize {
        self.envs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.envs.is_empty()
    }

    /// Frame shape (H, W, C).
    pub fn frame(&self) -> (usize, usize, usize) {
        self.frame
    }

    /// Flattened frame length `H * W * C`.
    pub fn frame_len(&self) -> usize {
        self.frame_len
    }

    pub fn n_actions(&self) -> usize {
        self.n_actions
    }

    pub fn horizon(&self) -> usize {
        self.envs[0].horizon()
    }

    /// The current `[n, frame_len]` observation matrix (already reflects
    /// auto-resets from the last `step_into`).
    pub fn obs(&self) -> &[f32] {
        &self.obs
    }

    /// Reset every slot, writing initial frames into the internal
    /// current-observation matrix.
    pub fn reset_all(&mut self, rng: &mut Rng) {
        let fl = self.frame_len;
        for (k, env) in self.envs.iter_mut().enumerate() {
            env.reset(rng, &mut self.obs[k * fl..(k + 1) * fl]);
            self.ep_ret[k] = 0.0;
            self.ep_steps[k] = 0;
        }
    }

    /// Reset every slot and write the initial `[n, frame_len]` block into
    /// `obs` (also kept internally).
    pub fn reset_into(&mut self, rng: &mut Rng, obs: &mut [f32]) {
        assert_eq!(obs.len(), self.envs.len() * self.frame_len, "obs block size mismatch");
        self.reset_all(rng);
        obs.copy_from_slice(&self.obs);
    }

    /// Step every slot with the `[n]` action vector.
    ///
    /// Writes the transition outputs `next_obs: [n, frame_len]` (terminal
    /// frames where an episode ended), `rew: [n]`, `done: [n]` (1.0 = env
    /// termination, horizon cap excluded), appends one [`EpisodeEnd`] per
    /// finished episode, and auto-resets those slots (their fresh frame
    /// appears in [`PixelVecEnv::obs`], not in `next_obs`).
    pub fn step_into(
        &mut self,
        rng: &mut Rng,
        acts: &[usize],
        next_obs: &mut [f32],
        rew: &mut [f32],
        done: &mut [f32],
        episodes: &mut Vec<EpisodeEnd>,
    ) {
        let n = self.envs.len();
        let fl = self.frame_len;
        assert_eq!(acts.len(), n, "act block size mismatch");
        assert_eq!(next_obs.len(), n * fl, "next_obs block size mismatch");
        assert_eq!(rew.len(), n, "rew block size mismatch");
        assert_eq!(done.len(), n, "done block size mismatch");
        for k in 0..n {
            debug_assert!(acts[k] < self.n_actions, "action out of range");
            let out = &mut next_obs[k * fl..(k + 1) * fl];
            let (r, d) = self.envs[k].step(acts[k], rng, out);
            rew[k] = r;
            done[k] = if d { 1.0 } else { 0.0 };
            self.ep_ret[k] += r as f64;
            self.ep_steps[k] += 1;
            let horizon_hit = self.ep_steps[k] >= self.envs[k].horizon();
            if d || horizon_hit {
                episodes.push(EpisodeEnd {
                    slot: k,
                    ret: self.ep_ret[k],
                    steps: self.ep_steps[k],
                });
                self.ep_ret[k] = 0.0;
                self.ep_steps[k] = 0;
                self.envs[k].reset(rng, &mut self.obs[k * fl..(k + 1) * fl]);
            } else {
                self.obs[k * fl..(k + 1) * fl].copy_from_slice(out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GAMES: [&str; 3] = ["breakout", "asterix", "spaceinvaders"];

    /// Identical seeds => PixelVecEnv stepping reproduces a hand-rolled
    /// per-env loop exactly (same rng consumption order), including the
    /// auto-reset replacement in the current-observation matrix, across
    /// all three MinAtar-style games.
    #[test]
    fn matches_scalar_env_loop_all_games() {
        for game in GAMES {
            let n = 3;
            let mut venv = PixelVecEnv::new(game, n).unwrap();
            let mut rng_v = Rng::new(42);
            let mut rng_s = Rng::new(42);
            let fl = venv.frame_len();
            let n_act = venv.n_actions();
            let horizon = venv.horizon();
            let mut obs_v = vec![0.0f32; n * fl];
            venv.reset_into(&mut rng_v, &mut obs_v);
            assert_eq!(venv.obs(), &obs_v[..]);

            let mut envs: Vec<_> = (0..n).map(|_| make_pixel_env(game).unwrap()).collect();
            let mut cur_s = vec![0.0f32; n * fl];
            for (k, e) in envs.iter_mut().enumerate() {
                e.reset(&mut rng_s, &mut cur_s[k * fl..(k + 1) * fl]);
            }
            assert_eq!(venv.obs(), &cur_s[..]);

            let mut ep_steps = vec![0usize; n];
            let mut acts = vec![0usize; n];
            let mut next = vec![0.0f32; n * fl];
            let mut rew = vec![0.0f32; n];
            let mut done = vec![0.0f32; n];
            let mut eps = Vec::new();
            let mut next_s = vec![0.0f32; fl];
            for t in 0..300 {
                for (k, a) in acts.iter_mut().enumerate() {
                    *a = (t + 2 * k) % n_act;
                }
                venv.step_into(&mut rng_v, &acts, &mut next, &mut rew, &mut done, &mut eps);
                for k in 0..n {
                    let (r, d) = envs[k].step(acts[k], &mut rng_s, &mut next_s);
                    assert_eq!(rew[k], r, "{game} step {t} slot {k}");
                    assert_eq!(done[k] > 0.5, d, "{game} step {t} slot {k}");
                    assert_eq!(&next[k * fl..(k + 1) * fl], &next_s[..], "{game} step {t}");
                    ep_steps[k] += 1;
                    if d || ep_steps[k] >= horizon {
                        ep_steps[k] = 0;
                        envs[k].reset(&mut rng_s, &mut cur_s[k * fl..(k + 1) * fl]);
                    } else {
                        cur_s[k * fl..(k + 1) * fl].copy_from_slice(&next_s);
                    }
                }
                // current matrix reflects auto-resets exactly like the
                // scalar loop's bookkeeping
                assert_eq!(venv.obs(), &cur_s[..], "{game} step {t}");
            }
        }
    }

    /// Episodes are reported with sane slots/returns and stepping
    /// continues seamlessly after every auto-reset.
    #[test]
    fn auto_reset_reports_episodes_and_keeps_stepping() {
        for game in GAMES {
            let n = 2;
            let mut venv = PixelVecEnv::new(game, n).unwrap();
            let mut rng = Rng::new(7);
            venv.reset_all(&mut rng);
            let fl = venv.frame_len();
            let n_act = venv.n_actions();
            let horizon = venv.horizon();
            let mut next = vec![0.0f32; n * fl];
            let mut rew = vec![0.0f32; n];
            let mut done = vec![0.0f32; n];
            let mut eps = Vec::new();
            let mut acts = vec![0usize; n];
            for _ in 0..2500 {
                for a in acts.iter_mut() {
                    *a = rng.below(n_act); // random policy
                }
                venv.step_into(&mut rng, &acts, &mut next, &mut rew, &mut done, &mut eps);
            }
            assert!(!eps.is_empty(), "{game}: no episode finished in 2500 steps");
            for e in &eps {
                assert!(e.slot < n, "{game}: bad slot {}", e.slot);
                assert!(e.steps >= 1 && e.steps <= horizon, "{game}: steps {}", e.steps);
                assert!(e.ret.is_finite());
            }
            // bookkeeping restarted: counters are mid-flight again
            assert!(venv.ep_steps.iter().all(|&s| s < horizon), "{game}");
            // frames stay binary planes
            assert!(venv.obs().iter().all(|&v| v == 0.0 || v == 1.0), "{game}");
        }
    }

    #[test]
    #[should_panic(expected = "act block size mismatch")]
    fn wrong_act_block_panics() {
        let mut venv = PixelVecEnv::new("breakout", 2).unwrap();
        let mut rng = Rng::new(0);
        venv.reset_all(&mut rng);
        let fl = venv.frame_len();
        let mut next = vec![0.0f32; 2 * fl];
        let (mut r, mut d) = (vec![0.0; 2], vec![0.0; 2]);
        venv.step_into(&mut rng, &[0], &mut next, &mut r, &mut d, &mut Vec::new());
    }
}
