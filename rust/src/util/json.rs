//! Minimal JSON parser + writer (serde is not in the image).
//!
//! Parses the subset of JSON that `artifacts/manifest.json` and the result
//! files use: objects, arrays, strings (with escapes), numbers, booleans,
//! null. Numbers are kept as f64 (all manifest integers fit exactly).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= (1u64 << 53) as f64 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")`
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hex: String = (0..4)
                            .filter_map(|_| self.bump().map(|c| c as char))
                            .collect();
                        let cp = u32::from_str_radix(&hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        // Surrogate pairs: manifest content is ASCII; accept
                        // BMP scalars, replace others.
                        s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < (1u64 << 53) as f64 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience constructors for building result JSON.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(j.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.path("a").unwrap().as_arr().unwrap()[2].path("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.path("d").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1 2]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"fields":[{"name":"w0","offset":0,"shape":[4,17,256]}],"size":123}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_string() {
        let j = Json::parse("\"héllo \\u0041\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo A"));
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::parse("4").unwrap().as_usize(), Some(4));
        assert_eq!(Json::parse("4.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
    }
}
