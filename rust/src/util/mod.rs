//! Substrate utilities the image's crate set forced us to build from
//! scratch: RNG, JSON, CLI parsing, config files, stats, timing, logging.

pub mod cli;
pub mod config;
pub mod json;
pub mod log;
pub mod plot;
pub mod rng;
pub mod stats;
pub mod timer;
