//! Metrics logging: CSV + JSONL writers used by trainers and benches.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Append-only CSV writer with a fixed header.
///
/// Metrics are best-effort: a write failure mid-run (disk full, deleted
/// output dir) must not abort hours of training, so the first I/O error
/// warns once and disables the logger — later `row`/`flush` calls become
/// no-ops. Arity mismatches are caller bugs and still error hard.
pub struct CsvLogger {
    w: BufWriter<File>,
    columns: Vec<String>,
    pub path: PathBuf,
    disabled: bool,
    #[cfg(test)]
    force_fail: bool,
}

impl CsvLogger {
    pub fn create(path: impl AsRef<Path>, columns: &[&str]) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(&path)?);
        writeln!(w, "{}", columns.join(","))?;
        Ok(CsvLogger {
            w,
            columns: columns.iter().map(|s| s.to_string()).collect(),
            path,
            disabled: false,
            #[cfg(test)]
            force_fail: false,
        })
    }

    /// Has a write failure already switched this logger off?
    pub fn is_disabled(&self) -> bool {
        self.disabled
    }

    fn disable(&mut self, err: &dyn std::fmt::Display) {
        self.disabled = true;
        warn(&format!(
            "csv logging to {} disabled after write error: {err} (training continues)",
            self.path.display()
        ));
    }

    fn write_line(&mut self, line: &str) -> std::io::Result<()> {
        #[cfg(test)]
        if self.force_fail {
            return Err(std::io::Error::other("forced csv failure"));
        }
        writeln!(self.w, "{line}")
    }

    pub fn row(&mut self, values: &[f64]) -> anyhow::Result<()> {
        anyhow::ensure!(
            values.len() == self.columns.len(),
            "csv row arity {} != header {}",
            values.len(),
            self.columns.len()
        );
        if self.disabled {
            return Ok(());
        }
        let line = values
            .iter()
            .map(|v| format!("{v}"))
            .collect::<Vec<_>>()
            .join(",");
        if let Err(e) = self.write_line(&line) {
            self.disable(&e);
        }
        Ok(())
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        if self.disabled {
            return Ok(());
        }
        if let Err(e) = self.w.flush() {
            self.disable(&e);
        }
        Ok(())
    }
}

/// Append-only JSONL writer (one `Json` per line, flushed per line so
/// tailing readers see complete records).
///
/// Same degradation contract as [`CsvLogger`]: telemetry output is
/// best-effort, so the first I/O error warns once and disables the
/// logger instead of erroring mid-run — later `write` calls are no-ops.
pub struct JsonlLogger {
    w: BufWriter<File>,
    pub path: PathBuf,
    disabled: bool,
    #[cfg(test)]
    force_fail: bool,
}

impl JsonlLogger {
    pub fn create(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        Ok(JsonlLogger {
            w: BufWriter::new(File::create(&path)?),
            path,
            disabled: false,
            #[cfg(test)]
            force_fail: false,
        })
    }

    /// Has a write failure already switched this logger off?
    pub fn is_disabled(&self) -> bool {
        self.disabled
    }

    fn write_line(&mut self, v: &Json) -> std::io::Result<()> {
        #[cfg(test)]
        if self.force_fail {
            return Err(std::io::Error::other("forced jsonl failure"));
        }
        writeln!(self.w, "{v}")?;
        self.w.flush()
    }

    pub fn write(&mut self, v: &Json) {
        if self.disabled {
            return;
        }
        if let Err(e) = self.write_line(v) {
            self.disabled = true;
            warn(&format!(
                "jsonl logging to {} disabled after write error: {e} (run continues)",
                self.path.display()
            ));
        }
    }
}

/// Stderr progress line, throttled by the caller.
pub fn info(msg: &str) {
    eprintln!("[fastpbrl] {msg}");
}

/// Stderr warning line (degraded-but-continuing conditions).
pub fn warn(msg: &str) {
    eprintln!("[fastpbrl] WARN: {msg}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_writes_rows() {
        let dir = std::env::temp_dir().join("fastpbrl_test_csv");
        let path = dir.join("x.csv");
        let mut l = CsvLogger::create(&path, &["a", "b"]).unwrap();
        l.row(&[1.0, 2.5]).unwrap();
        l.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2.5\n");
        assert!(l.row(&[1.0]).is_err());
    }

    #[test]
    fn csv_write_failure_degrades_to_disabled_not_error() {
        let dir = std::env::temp_dir().join("fastpbrl_test_csv_degrade");
        let path = dir.join("x.csv");
        let mut l = CsvLogger::create(&path, &["a", "b"]).unwrap();
        l.row(&[1.0, 2.0]).unwrap();
        l.force_fail = true;
        // I/O failure: warn-once-and-disable, never an abort
        assert!(l.row(&[3.0, 4.0]).is_ok());
        assert!(l.is_disabled());
        assert!(l.row(&[5.0, 6.0]).is_ok()); // no-op now
        assert!(l.flush().is_ok());
        // arity bugs still error hard even while disabled
        assert!(l.row(&[1.0]).is_err());
        // only the pre-failure row reached disk
        l.force_fail = false;
        l.disabled = false;
        l.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }

    #[test]
    fn jsonl_roundtrips() {
        let dir = std::env::temp_dir().join("fastpbrl_test_jsonl");
        let path = dir.join("x.jsonl");
        let mut l = JsonlLogger::create(&path).unwrap();
        l.write(&crate::util::json::obj(vec![("k", crate::util::json::num(3.0))]));
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(text.trim()).unwrap();
        assert_eq!(parsed.path("k").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn jsonl_write_failure_degrades_to_disabled_not_error() {
        let dir = std::env::temp_dir().join("fastpbrl_test_jsonl_degrade");
        let path = dir.join("x.jsonl");
        let mut l = JsonlLogger::create(&path).unwrap();
        let line = |n: f64| crate::util::json::obj(vec![("k", crate::util::json::num(n))]);
        l.write(&line(1.0));
        l.force_fail = true;
        // I/O failure: warn-once-and-disable, never an abort
        l.write(&line(2.0));
        assert!(l.is_disabled());
        l.force_fail = false;
        l.write(&line(3.0)); // no-op now
        assert!(l.is_disabled());
        // only the pre-failure line reached disk
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 1);
        assert!(text.contains(":1"));
    }
}
