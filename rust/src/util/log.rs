//! Metrics logging: CSV + JSONL writers used by trainers and benches.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Append-only CSV writer with a fixed header.
pub struct CsvLogger {
    w: BufWriter<File>,
    columns: Vec<String>,
    pub path: PathBuf,
}

impl CsvLogger {
    pub fn create(path: impl AsRef<Path>, columns: &[&str]) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(&path)?);
        writeln!(w, "{}", columns.join(","))?;
        Ok(CsvLogger {
            w,
            columns: columns.iter().map(|s| s.to_string()).collect(),
            path,
        })
    }

    pub fn row(&mut self, values: &[f64]) -> anyhow::Result<()> {
        anyhow::ensure!(
            values.len() == self.columns.len(),
            "csv row arity {} != header {}",
            values.len(),
            self.columns.len()
        );
        let line = values
            .iter()
            .map(|v| format!("{v}"))
            .collect::<Vec<_>>()
            .join(",");
        writeln!(self.w, "{line}")?;
        Ok(())
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

/// Append-only JSONL writer (one `Json` per line).
pub struct JsonlLogger {
    w: BufWriter<File>,
    pub path: PathBuf,
}

impl JsonlLogger {
    pub fn create(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        Ok(JsonlLogger { w: BufWriter::new(File::create(&path)?), path })
    }

    pub fn write(&mut self, v: &Json) -> anyhow::Result<()> {
        writeln!(self.w, "{v}")?;
        self.w.flush()?;
        Ok(())
    }
}

/// Stderr progress line, throttled by the caller.
pub fn info(msg: &str) {
    eprintln!("[fastpbrl] {msg}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_writes_rows() {
        let dir = std::env::temp_dir().join("fastpbrl_test_csv");
        let path = dir.join("x.csv");
        let mut l = CsvLogger::create(&path, &["a", "b"]).unwrap();
        l.row(&[1.0, 2.5]).unwrap();
        l.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2.5\n");
        assert!(l.row(&[1.0]).is_err());
    }

    #[test]
    fn jsonl_roundtrips() {
        let dir = std::env::temp_dir().join("fastpbrl_test_jsonl");
        let path = dir.join("x.jsonl");
        let mut l = JsonlLogger::create(&path).unwrap();
        l.write(&crate::util::json::obj(vec![("k", crate::util::json::num(3.0))]))
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(text.trim()).unwrap();
        assert_eq!(parsed.path("k").unwrap().as_f64(), Some(3.0));
    }
}
