//! Tiny CLI argument parser (clap is not in the image).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed getters and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

pub struct Cli {
    pub program: &'static str,
    pub about: &'static str,
    specs: Vec<ArgSpec>,
}

impl Cli {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Cli { program, about, specs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str,
               help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for s in &self.specs {
            let kind = if s.is_flag { "" } else { " <value>" };
            let dflt = s
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_else(|| if s.is_flag { String::new() } else { " (required)".into() });
            out.push_str(&format!("  --{}{kind}\t{}{dflt}\n", s.name, s.help));
        }
        out
    }

    pub fn parse(&self, argv: &[String]) -> anyhow::Result<Args> {
        let mut args = Args::default();
        for s in &self.specs {
            if let Some(d) = s.default {
                args.values.insert(s.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                anyhow::bail!("{}", self.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{key}\n{}", self.usage()))?;
                if spec.is_flag {
                    anyhow::ensure!(inline.is_none(), "--{key} takes no value");
                    args.flags.push(key);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?
                                .clone()
                        }
                    };
                    args.values.insert(key, v);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        for s in &self.specs {
            if !s.is_flag && !args.values.contains_key(s.name) {
                anyhow::bail!("missing required --{}\n{}", s.name, self.usage());
            }
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or("")
    }

    pub fn get_usize(&self, name: &str) -> anyhow::Result<usize> {
        self.get(name)
            .parse()
            .map_err(|_| anyhow::anyhow!("--{name} must be an integer, got {:?}", self.get(name)))
    }

    pub fn get_u64(&self, name: &str) -> anyhow::Result<u64> {
        self.get(name)
            .parse()
            .map_err(|_| anyhow::anyhow!("--{name} must be an integer, got {:?}", self.get(name)))
    }

    pub fn get_u32(&self, name: &str) -> anyhow::Result<u32> {
        self.get(name)
            .parse()
            .map_err(|_| anyhow::anyhow!("--{name} must be an integer, got {:?}", self.get(name)))
    }

    pub fn get_f64(&self, name: &str) -> anyhow::Result<f64> {
        self.get(name)
            .parse()
            .map_err(|_| anyhow::anyhow!("--{name} must be a number, got {:?}", self.get(name)))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Comma-separated list of usizes ("1,2,5,10").
    pub fn get_usize_list(&self, name: &str) -> anyhow::Result<Vec<usize>> {
        self.get(name)
            .split(',')
            .filter(|t| !t.is_empty())
            .map(|t| {
                t.trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--{name}: bad integer {t:?}"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let cli = Cli::new("t", "test").opt("pop", "4", "population").flag("fast", "go fast");
        let a = cli.parse(&argv(&["--pop", "8", "--fast"])).unwrap();
        assert_eq!(a.get_usize("pop").unwrap(), 8);
        assert!(a.has_flag("fast"));
        let a = cli.parse(&argv(&[])).unwrap();
        assert_eq!(a.get_usize("pop").unwrap(), 4);
        assert!(!a.has_flag("fast"));
    }

    #[test]
    fn equals_form_and_positional() {
        let cli = Cli::new("t", "test").opt("env", "pendulum", "env name");
        let a = cli.parse(&argv(&["--env=hopper", "extra"])).unwrap();
        assert_eq!(a.get("env"), "hopper");
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn required_and_unknown() {
        let cli = Cli::new("t", "test").req("out", "output file");
        assert!(cli.parse(&argv(&[])).is_err());
        assert!(cli.parse(&argv(&["--nope", "1"])).is_err());
        assert!(cli.parse(&argv(&["--out", "x"])).is_ok());
    }

    #[test]
    fn usize_list() {
        let cli = Cli::new("t", "test").opt("pops", "1,2,5", "pop sizes");
        let a = cli.parse(&argv(&[])).unwrap();
        assert_eq!(a.get_usize_list("pops").unwrap(), vec![1, 2, 5]);
    }
}
