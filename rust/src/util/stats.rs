//! Small statistics helpers shared by the bench harness and trainers.

/// Online mean/variance (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Running {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }
}

/// Percentile of a sample (linear interpolation, like numpy's default).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile(&v, 50.0)
}

/// Greedy argmax over one row of values (first index wins ties) — e.g.
/// q-value action selection in the pixel actor loop and the pixel
/// throughput bench, which must break ties identically.
pub fn argmax(q: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in q.iter().enumerate().skip(1) {
        if v > q[best] {
            best = i;
        }
    }
    best
}

/// Chi-squared goodness-of-fit statistic of observed cell `counts`
/// against a uniform expectation (df = counts.len() - 1). Used by the
/// sharded-replay uniformity suite: under uniform sampling the statistic
/// concentrates around df with variance 2*df.
pub fn chi_squared_uniform(counts: &[u64]) -> f64 {
    let n: u64 = counts.iter().sum();
    if counts.is_empty() || n == 0 {
        return 0.0;
    }
    let expected = n as f64 / counts.len() as f64;
    counts
        .iter()
        .map(|&c| {
            let d = c as f64 - expected;
            d * d / expected
        })
        .sum()
}

/// Indices that would sort `xs` descending (best-first ranking).
pub fn argsort_desc(xs: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - 6.2).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 6.2) * (x - 6.2)).sum::<f64>() / 4.0;
        assert!((r.var() - direct_var).abs() < 1e-12);
        assert_eq!(r.min, 1.0);
        assert_eq!(r.max, 16.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn argsort_desc_ranks() {
        assert_eq!(argsort_desc(&[3.0, 1.0, 2.0]), vec![0, 2, 1]);
    }

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
        assert_eq!(argmax(&[0.0, -1.0, 7.0]), 2);
    }

    #[test]
    fn chi_squared_uniform_scores() {
        // perfectly uniform counts -> 0
        assert_eq!(chi_squared_uniform(&[10, 10, 10, 10]), 0.0);
        // grossly skewed counts blow far past df + 5*sqrt(2 df)
        let skewed = chi_squared_uniform(&[40, 0, 0, 0]);
        assert!(skewed > 3.0 + 5.0 * (6.0f64).sqrt(), "chi2 {skewed}");
        // degenerate inputs are defined as 0
        assert_eq!(chi_squared_uniform(&[]), 0.0);
        assert_eq!(chi_squared_uniform(&[0, 0]), 0.0);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((median(&[1.0, 2.0, 3.0, 4.0]) - 2.5).abs() < 1e-12);
    }
}
