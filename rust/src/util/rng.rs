//! Deterministic pseudo-random number generation.
//!
//! The image ships no `rand` crate, so the coordinator owns its own RNG:
//! a SplitMix64-seeded PCG64 (XSL-RR) generator with the usual helper
//! distributions (uniform, normal via Ziggurat-free Box–Muller, integer
//! ranges, permutations). Everything downstream (environment resets,
//! exploration noise, PBT resampling, CEM sampling) is reproducible from
//! one seed.

/// SplitMix64: used to expand one u64 seed into generator state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32: small, fast, statistically solid for simulation use.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second Box–Muller normal.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let state = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1;
        let mut rng = Rng { state, inc, spare_normal: None };
        rng.next_u32(); // advance past the (correlated) initial state
        rng
    }

    /// Derive an independent stream (for per-agent / per-thread RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let state = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1;
        let mut rng = Rng { state, inc, spare_normal: None };
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Log-uniform in [lo, hi) (PBT learning-rate prior).
    pub fn log_uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo, "log_uniform_in requires 0 < lo < hi");
        (self.uniform_in(lo.ln(), hi.ln())).exp()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire's nearly-divisionless method on 64 bits.
        let m = (self.next_u64() as u128).wrapping_mul(n as u128);
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (caches the spare).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill a slice with N(0, std) f32 samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = (self.normal() as f32) * std;
        }
    }

    /// Fill a slice with U(lo, hi) f32 samples.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.uniform_in(lo as f64, hi as f64) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx
    }

    /// Sample k distinct indices from 0..n (k <= n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx = self.permutation(n);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(4);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.08, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[rng.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn log_uniform_in_range() {
        let mut rng = Rng::new(6);
        for _ in 0..1000 {
            let v = rng.log_uniform_in(3e-5, 3e-3);
            assert!((3e-5..3e-3).contains(&v));
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut rng = Rng::new(8);
        let mut p = rng.permutation(50);
        p.sort_unstable();
        assert_eq!(p, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_k_distinct() {
        let mut rng = Rng::new(9);
        let k = rng.choose_k(20, 8);
        let mut s = k.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
        assert!(k.iter().all(|&i| i < 20));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(10);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
