//! Terminal plotting: render learning curves from results CSVs as ASCII
//! charts (`fastpbrl report`). No plotting library in the image — and a
//! paper-reproduction repo should let you see Fig 5/6-style curves
//! without leaving the terminal.

/// Render one or more (x, y) series as an ASCII chart.
pub fn ascii_chart(
    series: &[(&str, &[(f64, f64)])],
    width: usize,
    height: usize,
    x_label: &str,
    y_label: &str,
) -> String {
    let width = width.max(16);
    let height = height.max(4);
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|(_, s)| s.iter().copied())
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    if pts.is_empty() {
        return "(no data)\n".into();
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let marks = ['*', 'o', '+', 'x', '#', '@'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, s)) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for &(x, y) in s.iter() {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let cx = (((x - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let cy = (((y - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = mark;
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{y_label}\n"));
    for (i, row) in grid.iter().enumerate() {
        let yv = y1 - (y1 - y0) * i as f64 / (height - 1) as f64;
        out.push_str(&format!("{yv:>10.1} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>11}+{}\n", "", "-".repeat(width)));
    out.push_str(&format!("{:>12}{:<.0}{}{:>.0}   ({x_label})\n", "", x0,
                          " ".repeat(width.saturating_sub(12)), x1));
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (name, _))| format!("{} {}", marks[i % marks.len()], name))
        .collect();
    out.push_str(&format!("  legend: {}\n", legend.join("   ")));
    out
}

/// Parse a results CSV (header + float rows) into named columns.
pub fn parse_csv(text: &str) -> anyhow::Result<(Vec<String>, Vec<Vec<f64>>)> {
    let mut lines = text.lines();
    let header: Vec<String> = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty csv"))?
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let mut cols = vec![Vec::new(); header.len()];
    for (lineno, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let cells: Vec<&str> = line.split(',').collect();
        anyhow::ensure!(
            cells.len() == header.len(),
            "csv row {} arity {} != header {}",
            lineno + 2,
            cells.len(),
            header.len()
        );
        for (c, cell) in cells.iter().enumerate() {
            cols[c].push(cell.trim().parse::<f64>().unwrap_or(f64::NAN));
        }
    }
    Ok((header, cols))
}

/// Extract an (x, y) series by column names.
pub fn series<'a>(header: &[String], cols: &'a [Vec<f64>], x: &str, y: &str)
                  -> anyhow::Result<Vec<(f64, f64)>> {
    let xi = header
        .iter()
        .position(|h| h == x)
        .ok_or_else(|| anyhow::anyhow!("no column {x:?} (have {header:?})"))?;
    let yi = header
        .iter()
        .position(|h| h == y)
        .ok_or_else(|| anyhow::anyhow!("no column {y:?} (have {header:?})"))?;
    Ok(cols[xi].iter().copied().zip(cols[yi].iter().copied()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chart_renders_points_and_legend() {
        let s = vec![(0.0, 0.0), (5.0, 5.0), (10.0, 10.0)];
        let out = ascii_chart(&[("diag", &s)], 20, 5, "x", "y");
        assert!(out.contains('*'));
        assert!(out.contains("legend: * diag"));
        // monotone series: first grid row (max y) must contain the mark
        let first_row = out.lines().nth(1).unwrap();
        assert!(first_row.contains('*'), "{out}");
    }

    #[test]
    fn chart_handles_empty_and_constant() {
        assert_eq!(ascii_chart(&[("e", &[])], 10, 4, "x", "y"), "(no data)\n");
        let c = vec![(0.0, 3.0), (1.0, 3.0)];
        let out = ascii_chart(&[("c", &c)], 10, 4, "x", "y");
        assert!(out.contains('*'));
    }

    #[test]
    fn csv_parse_and_series() {
        let text = "a,b,c\n1,2,3\n4,5,6\n";
        let (h, cols) = parse_csv(text).unwrap();
        assert_eq!(h, vec!["a", "b", "c"]);
        let s = series(&h, &cols, "a", "c").unwrap();
        assert_eq!(s, vec![(1.0, 3.0), (4.0, 6.0)]);
        assert!(series(&h, &cols, "a", "zzz").is_err());
    }

    #[test]
    fn csv_rejects_ragged_rows() {
        assert!(parse_csv("a,b\n1\n").is_err());
    }
}
