//! Run configuration: a small INI/TOML-flavoured `key = value` format with
//! `[section]` headers, comments, and typed getters. Used by the launcher
//! so experiments are reproducible from a checked-in file, with CLI
//! overrides applied on top (`--set section.key=value`).

use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    /// Flattened `section.key -> value` map (keys in the preamble have no
    /// section prefix).
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn parse(text: &str) -> anyhow::Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                let name = body
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: unclosed section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = v.trim().trim_matches('"').to_string();
            values.insert(key, val);
        }
        Ok(Config { values })
    }

    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| anyhow::anyhow!("reading {:?}: {e}", path.as_ref()))?;
        Self::parse(&text)
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    /// Apply `key=value` override strings (CLI `--set`).
    pub fn apply_overrides(&mut self, overrides: &[String]) -> anyhow::Result<()> {
        for o in overrides {
            let (k, v) = o
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("override must be key=value: {o:?}"))?;
            self.set(k.trim(), v.trim());
        }
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("{key} must be an integer, got {v:?}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("{key} must be an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("{key} must be a number, got {v:?}")),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> anyhow::Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => anyhow::bail!("{key} must be a boolean, got {v:?}"),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
seed = 7          # comment
[train]
pop = 8
lr = 3e-4
vectorized = true
name = "run a"
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_usize("seed", 0).unwrap(), 7);
        assert_eq!(c.get_u64("seed", 0).unwrap(), 7);
        assert_eq!(c.get_u64("train.missing", 33).unwrap(), 33);
        assert_eq!(c.get_usize("train.pop", 0).unwrap(), 8);
        assert!((c.get_f64("train.lr", 0.0).unwrap() - 3e-4).abs() < 1e-12);
        assert!(c.get_bool("train.vectorized", false).unwrap());
        assert_eq!(c.get("train.name"), Some("run a"));
    }

    #[test]
    fn defaults_and_overrides() {
        let mut c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_usize("train.batch", 256).unwrap(), 256);
        c.apply_overrides(&["train.pop=20".to_string()]).unwrap();
        assert_eq!(c.get_usize("train.pop", 0).unwrap(), 20);
        assert!(c.apply_overrides(&["nonsense".to_string()]).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[open\n").is_err());
        assert!(Config::parse("novalue\n").is_err());
        assert!(Config::parse("k = x").unwrap().get_usize("k", 0).is_err());
    }
}
