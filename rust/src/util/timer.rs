//! Wall-clock timing helpers.

use std::time::Instant;

/// Scoped stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    pub fn restart(&mut self) -> f64 {
        let dt = self.elapsed_s();
        self.start = Instant::now();
        dt
    }
}

/// Accumulates time spent in named phases (update step, env step, sync…).
#[derive(Default, Debug, Clone)]
pub struct PhaseTimer {
    phases: Vec<(String, f64, u64)>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, phase: &str, seconds: f64) {
        if let Some(e) = self.phases.iter_mut().find(|e| e.0 == phase) {
            e.1 += seconds;
            e.2 += 1;
        } else {
            self.phases.push((phase.to_string(), seconds, 1));
        }
    }

    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let sw = Stopwatch::start();
        let out = f();
        self.add(phase, sw.elapsed_s());
        out
    }

    pub fn total(&self, phase: &str) -> f64 {
        self.phases.iter().find(|e| e.0 == phase).map(|e| e.1).unwrap_or(0.0)
    }

    pub fn count(&self, phase: &str) -> u64 {
        self.phases.iter().find(|e| e.0 == phase).map(|e| e.2).unwrap_or(0)
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, secs, n) in &self.phases {
            out.push_str(&format!(
                "{name}: {secs:.3}s over {n} calls ({:.3} ms/call)\n",
                secs / (*n as f64) * 1e3
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.elapsed_ms() >= 4.0);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut t = PhaseTimer::new();
        t.add("a", 0.5);
        t.add("a", 0.25);
        t.add("b", 1.0);
        assert!((t.total("a") - 0.75).abs() < 1e-12);
        assert_eq!(t.count("a"), 2);
        assert_eq!(t.count("missing"), 0);
        assert!(t.report().contains("a:"));
    }
}
