//! Compatibility shim: the timing helpers moved into the telemetry
//! subsystem ([`crate::telemetry::instrument`]), where they share one
//! abstraction with the registry-backed phase timers. This re-export
//! keeps the historical `util::timer` path compiling (benches, examples,
//! downstream users); new code should import from [`crate::telemetry`].

pub use crate::telemetry::instrument::{PhaseTimer, Stopwatch};
