//! Replay buffer for the discrete-action pixel pipeline (DQN).
//!
//! Frames are stored as u8 {0,1} planes (MinAtar-style binary frames) and
//! expanded to f32 at sample time — an 4x memory saving that mirrors the
//! uint8 frame storage of Atari replay buffers.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct PixelReplayBuffer {
    capacity: usize,
    frame_len: usize,
    len: usize,
    head: usize,
    obs: Vec<u8>,
    act: Vec<i32>,
    rew: Vec<f32>,
    next_obs: Vec<u8>,
    done: Vec<f32>,
    pub total_inserted: u64,
}

impl PixelReplayBuffer {
    pub fn new(capacity: usize, frame_len: usize) -> Self {
        PixelReplayBuffer {
            capacity,
            frame_len,
            len: 0,
            head: 0,
            obs: vec![0; capacity * frame_len],
            act: vec![0; capacity],
            rew: vec![0.0; capacity],
            next_obs: vec![0; capacity * frame_len],
            done: vec![0.0; capacity],
            total_inserted: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn push(&mut self, obs: &[f32], act: usize, rew: f32, next_obs: &[f32], done: bool) {
        debug_assert_eq!(obs.len(), self.frame_len);
        let i = self.head;
        for (d, &s) in self.obs[i * self.frame_len..].iter_mut().zip(obs) {
            *d = (s != 0.0) as u8;
        }
        for (d, &s) in self.next_obs[i * self.frame_len..].iter_mut().zip(next_obs) {
            *d = (s != 0.0) as u8;
        }
        self.act[i] = act as i32;
        self.rew[i] = rew;
        self.done[i] = if done { 1.0 } else { 0.0 };
        self.head = (self.head + 1) % self.capacity;
        self.len = (self.len + 1).min(self.capacity);
        self.total_inserted += 1;
    }

    pub fn sample_into(
        &self,
        rng: &mut Rng,
        batch: usize,
        obs: &mut [f32],
        act: &mut [i32],
        rew: &mut [f32],
        next_obs: &mut [f32],
        done: &mut [f32],
    ) {
        assert!(self.len > 0, "sampling from empty replay buffer");
        let fl = self.frame_len;
        for b in 0..batch {
            let i = rng.below(self.len);
            for (d, &s) in obs[b * fl..(b + 1) * fl].iter_mut()
                .zip(&self.obs[i * fl..(i + 1) * fl]) {
                *d = s as f32;
            }
            for (d, &s) in next_obs[b * fl..(b + 1) * fl].iter_mut()
                .zip(&self.next_obs[i * fl..(i + 1) * fl]) {
                *d = s as f32;
            }
            act[b] = self.act[i];
            rew[b] = self.rew[i];
            done[b] = self.done[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_binary_frames() {
        let mut buf = PixelReplayBuffer::new(4, 6);
        let frame = [1.0, 0.0, 1.0, 0.0, 0.0, 1.0];
        let next = [0.0, 1.0, 0.0, 1.0, 1.0, 0.0];
        buf.push(&frame, 2, 1.5, &next, true);
        let mut rng = Rng::new(0);
        let (mut o, mut a, mut r, mut no, mut d) =
            (vec![0.0; 6], vec![0i32; 1], vec![0.0; 1], vec![0.0; 6], vec![0.0; 1]);
        buf.sample_into(&mut rng, 1, &mut o, &mut a, &mut r, &mut no, &mut d);
        assert_eq!(o, frame);
        assert_eq!(no, next);
        assert_eq!(a[0], 2);
        assert_eq!(r[0], 1.5);
        assert_eq!(d[0], 1.0);
    }

    #[test]
    fn ring_wraps() {
        let mut buf = PixelReplayBuffer::new(2, 1);
        for k in 0..5 {
            buf.push(&[1.0], k, k as f32, &[0.0], false);
        }
        assert_eq!(buf.len(), 2);
        assert_eq!(buf.total_inserted, 5);
        let mut rng = Rng::new(1);
        let (mut o, mut a, mut r, mut no, mut d) =
            (vec![0.0; 1], vec![0i32; 1], vec![0.0; 1], vec![0.0; 1], vec![0.0; 1]);
        for _ in 0..20 {
            buf.sample_into(&mut rng, 1, &mut o, &mut a, &mut r, &mut no, &mut d);
            assert!(r[0] >= 3.0);
        }
    }
}
