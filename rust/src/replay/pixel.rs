//! Replay buffer for the discrete-action pixel pipeline (DQN).
//!
//! Frames are stored as u8 {0,1} planes (MinAtar-style binary frames) and
//! expanded to f32 at sample time — an 4x memory saving that mirrors the
//! uint8 frame storage of Atari replay buffers.

use crate::data::pipeline::PixelTransitionBlock;
use crate::replay::{Replay, Staging};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct PixelReplayBuffer {
    capacity: usize,
    frame_len: usize,
    len: usize,
    head: usize,
    obs: Vec<u8>,
    act: Vec<i32>,
    rew: Vec<f32>,
    next_obs: Vec<u8>,
    done: Vec<f32>,
    pub total_inserted: u64,
}

impl PixelReplayBuffer {
    pub fn new(capacity: usize, frame_len: usize) -> Self {
        PixelReplayBuffer {
            capacity,
            frame_len,
            len: 0,
            head: 0,
            obs: vec![0; capacity * frame_len],
            act: vec![0; capacity],
            rew: vec![0.0; capacity],
            next_obs: vec![0; capacity * frame_len],
            done: vec![0.0; capacity],
            total_inserted: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Drop all contents (PBT exploit step over DQN replaces an agent's
    /// data lineage exactly like the continuous buffer does).
    pub fn clear(&mut self) {
        self.len = 0;
        self.head = 0;
    }

    pub fn push(&mut self, obs: &[f32], act: usize, rew: f32, next_obs: &[f32], done: bool) {
        debug_assert_eq!(obs.len(), self.frame_len);
        let i = self.head;
        for (d, &s) in self.obs[i * self.frame_len..].iter_mut().zip(obs) {
            *d = (s != 0.0) as u8;
        }
        for (d, &s) in self.next_obs[i * self.frame_len..].iter_mut().zip(next_obs) {
            *d = (s != 0.0) as u8;
        }
        self.act[i] = act as i32;
        self.rew[i] = rew;
        self.done[i] = if done { 1.0 } else { 0.0 };
        self.head = (self.head + 1) % self.capacity;
        self.len = (self.len + 1).min(self.capacity);
        self.total_inserted += 1;
    }

    /// Insert `n` transitions from contiguous `[n, ...]` blocks in one
    /// call — one `copy_from_slice` per field per contiguous ring run (at
    /// most two runs unless `n > capacity`). Frames arrive already
    /// quantized to the buffer's u8 `{0,1}` storage (the
    /// [`PixelTransitionBlock`](crate::data::pipeline::PixelTransitionBlock)
    /// wire format), so insertion is a straight memcpy. Row order is
    /// preserved: the result is exactly `n` repeated
    /// [`PixelReplayBuffer::push`] calls.
    pub fn push_batch(
        &mut self,
        n: usize,
        obs: &[u8],
        act: &[i32],
        rew: &[f32],
        next_obs: &[u8],
        done: &[f32],
    ) {
        let fl = self.frame_len;
        debug_assert_eq!(obs.len(), n * fl);
        debug_assert_eq!(act.len(), n);
        debug_assert_eq!(rew.len(), n);
        debug_assert_eq!(next_obs.len(), n * fl);
        debug_assert_eq!(done.len(), n);
        let mut row = 0;
        while row < n {
            let i = self.head;
            let run = (n - row).min(self.capacity - i);
            self.obs[i * fl..(i + run) * fl].copy_from_slice(&obs[row * fl..(row + run) * fl]);
            self.next_obs[i * fl..(i + run) * fl]
                .copy_from_slice(&next_obs[row * fl..(row + run) * fl]);
            self.act[i..i + run].copy_from_slice(&act[row..row + run]);
            self.rew[i..i + run].copy_from_slice(&rew[row..row + run]);
            self.done[i..i + run].copy_from_slice(&done[row..row + run]);
            self.head = (self.head + run) % self.capacity;
            self.len = (self.len + run).min(self.capacity);
            self.total_inserted += run as u64;
            row += run;
        }
    }

    pub fn sample_into(
        &self,
        rng: &mut Rng,
        batch: usize,
        obs: &mut [f32],
        act: &mut [i32],
        rew: &mut [f32],
        next_obs: &mut [f32],
        done: &mut [f32],
    ) {
        assert!(self.len > 0, "sampling from empty replay buffer");
        let fl = self.frame_len;
        for b in 0..batch {
            let i = rng.below(self.len);
            for (d, &s) in obs[b * fl..(b + 1) * fl].iter_mut()
                .zip(&self.obs[i * fl..(i + 1) * fl]) {
                *d = s as f32;
            }
            for (d, &s) in next_obs[b * fl..(b + 1) * fl].iter_mut()
                .zip(&self.next_obs[i * fl..(i + 1) * fl]) {
                *d = s as f32;
            }
            act[b] = self.act[i];
            rew[b] = self.rew[i];
            done[b] = self.done[i];
        }
    }
}

/// The pixel/DQN side of the unified replay interface: block rows are u8
/// `[n, frame_len]` planes + i32 actions handed straight to
/// [`PixelReplayBuffer::push_batch`] (no requantization), and sampling
/// expands frames to f32 while actions land in the i32 staging input.
impl Replay for PixelReplayBuffer {
    type Block = PixelTransitionBlock;

    fn len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn clear(&mut self) {
        PixelReplayBuffer::clear(self)
    }

    fn push_rows(&mut self, block: &PixelTransitionBlock, start: usize, end: usize) {
        let fl = block.frame_len;
        debug_assert_eq!(fl, self.frame_len);
        self.push_batch(
            end - start,
            &block.obs[start * fl..end * fl],
            &block.act[start..end],
            &block.rew[start..end],
            &block.next_obs[start * fl..end * fl],
            &block.done[start..end],
        );
    }

    fn sample_slot(&self, rng: &mut Rng, batch: usize, st: &mut Staging, slot: usize) {
        let fl = self.frame_len;
        debug_assert_eq!(st.stride(0), batch * fl);
        debug_assert_eq!(st.stride(1), batch);
        // canonical transition input order: obs, act(i32), rew, next_obs,
        // done — the act slot lives in the i32 staging lane.
        let (s0, rest) = st.f32s.split_at_mut(1);
        let (_act_f32, rest) = rest.split_at_mut(1);
        let (s2, rest) = rest.split_at_mut(1);
        let (s3, s4) = rest.split_at_mut(1);
        let act = &mut st.i32s[1][slot * batch..(slot + 1) * batch];
        self.sample_into(
            rng,
            batch,
            &mut s0[0][slot * batch * fl..(slot + 1) * batch * fl],
            act,
            &mut s2[0][slot * batch..(slot + 1) * batch],
            &mut s3[0][slot * batch * fl..(slot + 1) * batch * fl],
            &mut s4[0][slot * batch..(slot + 1) * batch],
        );
    }

    fn copy_row(&self, row: usize, batch: usize, st: &mut Staging, slot: usize, pos: usize) {
        debug_assert!(row < self.len, "row {row} out of {} live rows", self.len);
        let fl = self.frame_len;
        let frame_base = slot * batch * fl + pos * fl;
        let row1 = slot * batch + pos;
        for (d, &s) in st.f32s[0][frame_base..frame_base + fl]
            .iter_mut()
            .zip(&self.obs[row * fl..(row + 1) * fl])
        {
            *d = s as f32;
        }
        for (d, &s) in st.f32s[3][frame_base..frame_base + fl]
            .iter_mut()
            .zip(&self.next_obs[row * fl..(row + 1) * fl])
        {
            *d = s as f32;
        }
        st.i32s[1][row1] = self.act[row];
        st.f32s[2][row1] = self.rew[row];
        st.f32s[4][row1] = self.done[row];
    }

    fn total_inserted(&self) -> u64 {
        self.total_inserted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_binary_frames() {
        let mut buf = PixelReplayBuffer::new(4, 6);
        let frame = [1.0, 0.0, 1.0, 0.0, 0.0, 1.0];
        let next = [0.0, 1.0, 0.0, 1.0, 1.0, 0.0];
        buf.push(&frame, 2, 1.5, &next, true);
        let mut rng = Rng::new(0);
        let (mut o, mut a, mut r, mut no, mut d) =
            (vec![0.0; 6], vec![0i32; 1], vec![0.0; 1], vec![0.0; 6], vec![0.0; 1]);
        buf.sample_into(&mut rng, 1, &mut o, &mut a, &mut r, &mut no, &mut d);
        assert_eq!(o, frame);
        assert_eq!(no, next);
        assert_eq!(a[0], 2);
        assert_eq!(r[0], 1.5);
        assert_eq!(d[0], 1.0);
    }

    /// push_batch must be byte-identical to the same rows pushed one by
    /// one — including head position, live length, and wraparound order.
    #[test]
    fn push_batch_equals_repeated_push() {
        let mut rng = Rng::new(11);
        for case in 0..200 {
            let cap = 1 + rng.below(12);
            let fl = 1 + rng.below(5);
            let mut a = PixelReplayBuffer::new(cap, fl);
            let mut b = PixelReplayBuffer::new(cap, fl);
            for _ in 0..6 {
                // batch sizes deliberately straddle the capacity (n > cap
                // wraps more than once)
                let n = 1 + rng.below(2 * cap);
                // random binary frames, both as f32 planes (push) and
                // pre-quantized u8 (push_batch wire format)
                let obs_f: Vec<f32> = (0..n * fl).map(|_| (rng.below(2) as f32)).collect();
                let nobs_f: Vec<f32> = (0..n * fl).map(|_| (rng.below(2) as f32)).collect();
                let obs_u: Vec<u8> = obs_f.iter().map(|&v| (v != 0.0) as u8).collect();
                let nobs_u: Vec<u8> = nobs_f.iter().map(|&v| (v != 0.0) as u8).collect();
                let act: Vec<i32> = (0..n).map(|_| rng.below(5) as i32).collect();
                let mut rew = vec![0.0f32; n];
                rng.fill_normal(&mut rew, 1.0);
                let done: Vec<f32> = (0..n).map(|_| (rng.below(2) == 0) as u8 as f32).collect();
                a.push_batch(n, &obs_u, &act, &rew, &nobs_u, &done);
                for r in 0..n {
                    b.push(
                        &obs_f[r * fl..(r + 1) * fl],
                        act[r] as usize,
                        rew[r],
                        &nobs_f[r * fl..(r + 1) * fl],
                        done[r] > 0.5,
                    );
                }
                assert_eq!(a.len, b.len, "case {case}");
                assert_eq!(a.head, b.head, "case {case}");
                assert_eq!(a.total_inserted, b.total_inserted, "case {case}");
                assert_eq!(a.obs, b.obs, "case {case}");
                assert_eq!(a.act, b.act, "case {case}");
                assert_eq!(a.rew, b.rew, "case {case}");
                assert_eq!(a.next_obs, b.next_obs, "case {case}");
                assert_eq!(a.done, b.done, "case {case}");
            }
        }
    }

    /// Sampling after push_batch keeps rows aligned across all arrays:
    /// the reward value identifies the row, and the obs/next_obs planes
    /// must carry that row's bit pattern.
    #[test]
    fn push_batch_rows_stay_aligned_under_sampling() {
        let fl = 4;
        let cap = 16;
        let mut buf = PixelReplayBuffer::new(cap, fl);
        let n = 10;
        let mut obs = vec![0u8; n * fl];
        let mut nobs = vec![0u8; n * fl];
        let mut act = vec![0i32; n];
        let mut rew = vec![0.0f32; n];
        let mut done = vec![0.0f32; n];
        for r in 0..n {
            for j in 0..fl {
                obs[r * fl + j] = ((r >> j) & 1) as u8; // bit pattern of r
                nobs[r * fl + j] = ((!r >> j) & 1) as u8;
            }
            act[r] = (r % 3) as i32;
            rew[r] = r as f32;
            done[r] = (r % 2) as f32;
        }
        buf.push_batch(n, &obs, &act, &rew, &nobs, &done);
        let mut rng = Rng::new(3);
        let (mut o, mut a, mut r, mut no, mut d) =
            (vec![0.0; fl], vec![0i32; 1], vec![0.0; 1], vec![0.0; fl], vec![0.0; 1]);
        for _ in 0..100 {
            buf.sample_into(&mut rng, 1, &mut o, &mut a, &mut r, &mut no, &mut d);
            let row = r[0] as usize;
            assert!(row < n);
            for j in 0..fl {
                assert_eq!(o[j], ((row >> j) & 1) as f32, "row {row} bit {j}");
                assert_eq!(no[j], ((!row >> j) & 1) as f32, "row {row} bit {j}");
            }
            assert_eq!(a[0], (row % 3) as i32);
            assert_eq!(d[0], (row % 2) as f32);
        }
    }

    #[test]
    fn ring_wraps() {
        let mut buf = PixelReplayBuffer::new(2, 1);
        for k in 0..5 {
            buf.push(&[1.0], k, k as f32, &[0.0], false);
        }
        assert_eq!(buf.len(), 2);
        assert_eq!(buf.total_inserted, 5);
        let mut rng = Rng::new(1);
        let (mut o, mut a, mut r, mut no, mut d) =
            (vec![0.0; 1], vec![0i32; 1], vec![0.0; 1], vec![0.0; 1], vec![0.0; 1]);
        for _ in 0..20 {
            buf.sample_into(&mut rng, 1, &mut o, &mut a, &mut r, &mut no, &mut d);
            assert!(r[0] >= 3.0);
        }
    }
}
