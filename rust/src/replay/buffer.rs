//! FIFO replay buffer (structure-of-arrays ring).
//!
//! One buffer per agent when data must not mix (PBT), or a single shared
//! one (CEM-RL, DvD), mirroring Appendix A of the paper. Sampling writes
//! directly into caller-provided slices so batch assembly for the whole
//! population fills the `[P, B, ...]` host staging buffer with no
//! intermediate allocation.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ReplayBuffer {
    capacity: usize,
    obs_dim: usize,
    act_dim: usize,
    len: usize,
    head: usize,
    obs: Vec<f32>,
    act: Vec<f32>,
    rew: Vec<f32>,
    next_obs: Vec<f32>,
    done: Vec<f32>,
    /// Total transitions ever inserted (for update/insert ratio control).
    pub total_inserted: u64,
}

impl ReplayBuffer {
    pub fn new(capacity: usize, obs_dim: usize, act_dim: usize) -> Self {
        assert!(capacity > 0);
        ReplayBuffer {
            capacity,
            obs_dim,
            act_dim,
            len: 0,
            head: 0,
            obs: vec![0.0; capacity * obs_dim],
            act: vec![0.0; capacity * act_dim],
            rew: vec![0.0; capacity],
            next_obs: vec![0.0; capacity * obs_dim],
            done: vec![0.0; capacity],
            total_inserted: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn push(&mut self, obs: &[f32], act: &[f32], rew: f32, next_obs: &[f32], done: bool) {
        debug_assert_eq!(obs.len(), self.obs_dim);
        debug_assert_eq!(act.len(), self.act_dim);
        debug_assert_eq!(next_obs.len(), self.obs_dim);
        let i = self.head;
        self.obs[i * self.obs_dim..(i + 1) * self.obs_dim].copy_from_slice(obs);
        self.act[i * self.act_dim..(i + 1) * self.act_dim].copy_from_slice(act);
        self.rew[i] = rew;
        self.next_obs[i * self.obs_dim..(i + 1) * self.obs_dim].copy_from_slice(next_obs);
        self.done[i] = if done { 1.0 } else { 0.0 };
        self.head = (self.head + 1) % self.capacity;
        self.len = (self.len + 1).min(self.capacity);
        self.total_inserted += 1;
    }

    /// Sample `batch` transitions uniformly with replacement into the
    /// destination slices (each sized for exactly one agent's batch).
    pub fn sample_into(
        &self,
        rng: &mut Rng,
        batch: usize,
        obs: &mut [f32],
        act: &mut [f32],
        rew: &mut [f32],
        next_obs: &mut [f32],
        done: &mut [f32],
    ) {
        assert!(self.len > 0, "sampling from empty replay buffer");
        debug_assert_eq!(obs.len(), batch * self.obs_dim);
        debug_assert_eq!(act.len(), batch * self.act_dim);
        debug_assert_eq!(rew.len(), batch);
        debug_assert_eq!(next_obs.len(), batch * self.obs_dim);
        debug_assert_eq!(done.len(), batch);
        for b in 0..batch {
            let i = rng.below(self.len);
            obs[b * self.obs_dim..(b + 1) * self.obs_dim]
                .copy_from_slice(&self.obs[i * self.obs_dim..(i + 1) * self.obs_dim]);
            act[b * self.act_dim..(b + 1) * self.act_dim]
                .copy_from_slice(&self.act[i * self.act_dim..(i + 1) * self.act_dim]);
            rew[b] = self.rew[i];
            next_obs[b * self.obs_dim..(b + 1) * self.obs_dim]
                .copy_from_slice(&self.next_obs[i * self.obs_dim..(i + 1) * self.obs_dim]);
            done[b] = self.done[i];
        }
    }

    /// Drop all contents (PBT exploit step replaces an agent's data
    /// lineage by clearing its buffer — hyperparameters changed, so the
    /// old off-policy data's distribution did too).
    pub fn clear(&mut self) {
        self.len = 0;
        self.head = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push_n(buf: &mut ReplayBuffer, n: usize) {
        for i in 0..n {
            let v = i as f32;
            buf.push(&[v, v], &[v], v, &[v + 1.0, v + 1.0], i % 2 == 0);
        }
    }

    #[test]
    fn fifo_overwrites_oldest() {
        let mut buf = ReplayBuffer::new(4, 2, 1);
        push_n(&mut buf, 6);
        assert_eq!(buf.len(), 4);
        assert_eq!(buf.total_inserted, 6);
        // sample many; every reward must come from the last 4 pushes {2..5}
        let mut rng = Rng::new(0);
        let (mut o, mut a, mut r, mut no, mut d) =
            (vec![0.0; 2], vec![0.0; 1], vec![0.0; 1], vec![0.0; 2], vec![0.0; 1]);
        for _ in 0..100 {
            buf.sample_into(&mut rng, 1, &mut o, &mut a, &mut r, &mut no, &mut d);
            assert!((2.0..=5.0).contains(&r[0]), "stale transition {}", r[0]);
            assert_eq!(no[0], r[0] + 1.0); // rows stay aligned across arrays
            assert_eq!(o[0], r[0]);
        }
    }

    #[test]
    fn sample_covers_contents() {
        let mut buf = ReplayBuffer::new(16, 1, 1);
        for i in 0..16 {
            buf.push(&[i as f32], &[0.0], i as f32, &[0.0], false);
        }
        let mut rng = Rng::new(1);
        let mut seen = [false; 16];
        let (mut o, mut a, mut r, mut no, mut d) =
            (vec![0.0; 8], vec![0.0; 8], vec![0.0; 8], vec![0.0; 8], vec![0.0; 8]);
        for _ in 0..50 {
            buf.sample_into(&mut rng, 8, &mut o, &mut a, &mut r, &mut no, &mut d);
            for &x in &r {
                seen[x as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn clear_resets() {
        let mut buf = ReplayBuffer::new(8, 2, 1);
        push_n(&mut buf, 5);
        buf.clear();
        assert!(buf.is_empty());
        push_n(&mut buf, 1);
        assert_eq!(buf.len(), 1);
    }

    #[test]
    #[should_panic(expected = "empty replay")]
    fn sampling_empty_panics() {
        let buf = ReplayBuffer::new(4, 1, 1);
        let mut rng = Rng::new(0);
        let (mut o, mut a, mut r, mut no, mut d) =
            (vec![0.0; 1], vec![0.0; 1], vec![0.0; 1], vec![0.0; 1], vec![0.0; 1]);
        buf.sample_into(&mut rng, 1, &mut o, &mut a, &mut r, &mut no, &mut d);
    }
}
