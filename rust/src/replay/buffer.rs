//! FIFO replay buffer (structure-of-arrays ring).
//!
//! One buffer per agent when data must not mix (PBT), or a single shared
//! one (CEM-RL, DvD), mirroring Appendix A of the paper. Sampling writes
//! directly into caller-provided slices so batch assembly for the whole
//! population fills the `[P, B, ...]` host staging buffer with no
//! intermediate allocation.

use crate::data::pipeline::TransitionBlock;
use crate::replay::{Replay, Staging};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ReplayBuffer {
    capacity: usize,
    obs_dim: usize,
    act_dim: usize,
    len: usize,
    head: usize,
    obs: Vec<f32>,
    act: Vec<f32>,
    rew: Vec<f32>,
    next_obs: Vec<f32>,
    done: Vec<f32>,
    /// Total transitions ever inserted (for update/insert ratio control).
    pub total_inserted: u64,
}

impl ReplayBuffer {
    pub fn new(capacity: usize, obs_dim: usize, act_dim: usize) -> Self {
        assert!(capacity > 0);
        ReplayBuffer {
            capacity,
            obs_dim,
            act_dim,
            len: 0,
            head: 0,
            obs: vec![0.0; capacity * obs_dim],
            act: vec![0.0; capacity * act_dim],
            rew: vec![0.0; capacity],
            next_obs: vec![0.0; capacity * obs_dim],
            done: vec![0.0; capacity],
            total_inserted: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn push(&mut self, obs: &[f32], act: &[f32], rew: f32, next_obs: &[f32], done: bool) {
        debug_assert_eq!(obs.len(), self.obs_dim);
        debug_assert_eq!(act.len(), self.act_dim);
        debug_assert_eq!(next_obs.len(), self.obs_dim);
        let i = self.head;
        self.obs[i * self.obs_dim..(i + 1) * self.obs_dim].copy_from_slice(obs);
        self.act[i * self.act_dim..(i + 1) * self.act_dim].copy_from_slice(act);
        self.rew[i] = rew;
        self.next_obs[i * self.obs_dim..(i + 1) * self.obs_dim].copy_from_slice(next_obs);
        self.done[i] = if done { 1.0 } else { 0.0 };
        self.head = (self.head + 1) % self.capacity;
        self.len = (self.len + 1).min(self.capacity);
        self.total_inserted += 1;
    }

    /// Insert `n` transitions from contiguous `[n, ...]` blocks in one
    /// call — one `copy_from_slice` per field per contiguous ring run
    /// (at most two runs unless `n > capacity`). Row order is preserved,
    /// so the result is exactly `n` repeated [`ReplayBuffer::push`] calls;
    /// `done` uses the same 0.0/1.0 encoding the buffer stores.
    pub fn push_batch(
        &mut self,
        n: usize,
        obs: &[f32],
        act: &[f32],
        rew: &[f32],
        next_obs: &[f32],
        done: &[f32],
    ) {
        debug_assert_eq!(obs.len(), n * self.obs_dim);
        debug_assert_eq!(act.len(), n * self.act_dim);
        debug_assert_eq!(rew.len(), n);
        debug_assert_eq!(next_obs.len(), n * self.obs_dim);
        debug_assert_eq!(done.len(), n);
        let (od, ad) = (self.obs_dim, self.act_dim);
        let mut row = 0;
        while row < n {
            let i = self.head;
            let run = (n - row).min(self.capacity - i);
            self.obs[i * od..(i + run) * od].copy_from_slice(&obs[row * od..(row + run) * od]);
            self.act[i * ad..(i + run) * ad].copy_from_slice(&act[row * ad..(row + run) * ad]);
            self.rew[i..i + run].copy_from_slice(&rew[row..row + run]);
            self.next_obs[i * od..(i + run) * od]
                .copy_from_slice(&next_obs[row * od..(row + run) * od]);
            self.done[i..i + run].copy_from_slice(&done[row..row + run]);
            self.head = (self.head + run) % self.capacity;
            self.len = (self.len + run).min(self.capacity);
            self.total_inserted += run as u64;
            row += run;
        }
    }

    /// Sample `batch` transitions uniformly with replacement into the
    /// destination slices (each sized for exactly one agent's batch).
    pub fn sample_into(
        &self,
        rng: &mut Rng,
        batch: usize,
        obs: &mut [f32],
        act: &mut [f32],
        rew: &mut [f32],
        next_obs: &mut [f32],
        done: &mut [f32],
    ) {
        assert!(self.len > 0, "sampling from empty replay buffer");
        debug_assert_eq!(obs.len(), batch * self.obs_dim);
        debug_assert_eq!(act.len(), batch * self.act_dim);
        debug_assert_eq!(rew.len(), batch);
        debug_assert_eq!(next_obs.len(), batch * self.obs_dim);
        debug_assert_eq!(done.len(), batch);
        for b in 0..batch {
            let i = rng.below(self.len);
            obs[b * self.obs_dim..(b + 1) * self.obs_dim]
                .copy_from_slice(&self.obs[i * self.obs_dim..(i + 1) * self.obs_dim]);
            act[b * self.act_dim..(b + 1) * self.act_dim]
                .copy_from_slice(&self.act[i * self.act_dim..(i + 1) * self.act_dim]);
            rew[b] = self.rew[i];
            next_obs[b * self.obs_dim..(b + 1) * self.obs_dim]
                .copy_from_slice(&self.next_obs[i * self.obs_dim..(i + 1) * self.obs_dim]);
            done[b] = self.done[i];
        }
    }

    /// Drop all contents (PBT exploit step replaces an agent's data
    /// lineage by clearing its buffer — hyperparameters changed, so the
    /// old off-policy data's distribution did too).
    pub fn clear(&mut self) {
        self.len = 0;
        self.head = 0;
    }
}

/// The continuous-control side of the unified replay interface: block
/// rows are f32 `[n, obs_dim]` / `[n, act_dim]` slices handed straight to
/// [`ReplayBuffer::push_batch`], and sampling fills all five staging
/// inputs as f32.
impl Replay for ReplayBuffer {
    type Block = TransitionBlock;

    fn len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn clear(&mut self) {
        ReplayBuffer::clear(self)
    }

    fn push_rows(&mut self, block: &TransitionBlock, start: usize, end: usize) {
        let (od, ad) = (block.obs_dim, block.act_dim);
        debug_assert_eq!(od, self.obs_dim);
        debug_assert_eq!(ad, self.act_dim);
        self.push_batch(
            end - start,
            &block.obs[start * od..end * od],
            &block.act[start * ad..end * ad],
            &block.rew[start..end],
            &block.next_obs[start * od..end * od],
            &block.done[start..end],
        );
    }

    fn sample_slot(&self, rng: &mut Rng, batch: usize, st: &mut Staging, slot: usize) {
        let (od, ad) = (self.obs_dim, self.act_dim);
        debug_assert_eq!(st.stride(0), batch * od);
        debug_assert_eq!(st.stride(1), batch * ad);
        // canonical transition input order: obs, act, rew, next_obs, done
        let (s0, rest) = st.f32s.split_at_mut(1);
        let (s1, rest) = rest.split_at_mut(1);
        let (s2, rest) = rest.split_at_mut(1);
        let (s3, s4) = rest.split_at_mut(1);
        self.sample_into(
            rng,
            batch,
            &mut s0[0][slot * batch * od..(slot + 1) * batch * od],
            &mut s1[0][slot * batch * ad..(slot + 1) * batch * ad],
            &mut s2[0][slot * batch..(slot + 1) * batch],
            &mut s3[0][slot * batch * od..(slot + 1) * batch * od],
            &mut s4[0][slot * batch..(slot + 1) * batch],
        );
    }

    fn copy_row(&self, row: usize, batch: usize, st: &mut Staging, slot: usize, pos: usize) {
        debug_assert!(row < self.len, "row {row} out of {} live rows", self.len);
        let (od, ad) = (self.obs_dim, self.act_dim);
        let vec_base = slot * batch * od + pos * od;
        let row1 = slot * batch + pos;
        st.f32s[0][vec_base..vec_base + od]
            .copy_from_slice(&self.obs[row * od..(row + 1) * od]);
        let act_base = slot * batch * ad + pos * ad;
        st.f32s[1][act_base..act_base + ad]
            .copy_from_slice(&self.act[row * ad..(row + 1) * ad]);
        st.f32s[2][row1] = self.rew[row];
        st.f32s[3][vec_base..vec_base + od]
            .copy_from_slice(&self.next_obs[row * od..(row + 1) * od]);
        st.f32s[4][row1] = self.done[row];
    }

    fn total_inserted(&self) -> u64 {
        self.total_inserted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push_n(buf: &mut ReplayBuffer, n: usize) {
        for i in 0..n {
            let v = i as f32;
            buf.push(&[v, v], &[v], v, &[v + 1.0, v + 1.0], i % 2 == 0);
        }
    }

    #[test]
    fn fifo_overwrites_oldest() {
        let mut buf = ReplayBuffer::new(4, 2, 1);
        push_n(&mut buf, 6);
        assert_eq!(buf.len(), 4);
        assert_eq!(buf.total_inserted, 6);
        // sample many; every reward must come from the last 4 pushes {2..5}
        let mut rng = Rng::new(0);
        let (mut o, mut a, mut r, mut no, mut d) =
            (vec![0.0; 2], vec![0.0; 1], vec![0.0; 1], vec![0.0; 2], vec![0.0; 1]);
        for _ in 0..100 {
            buf.sample_into(&mut rng, 1, &mut o, &mut a, &mut r, &mut no, &mut d);
            assert!((2.0..=5.0).contains(&r[0]), "stale transition {}", r[0]);
            assert_eq!(no[0], r[0] + 1.0); // rows stay aligned across arrays
            assert_eq!(o[0], r[0]);
        }
    }

    #[test]
    fn sample_covers_contents() {
        let mut buf = ReplayBuffer::new(16, 1, 1);
        for i in 0..16 {
            buf.push(&[i as f32], &[0.0], i as f32, &[0.0], false);
        }
        let mut rng = Rng::new(1);
        let mut seen = [false; 16];
        let (mut o, mut a, mut r, mut no, mut d) =
            (vec![0.0; 8], vec![0.0; 8], vec![0.0; 8], vec![0.0; 8], vec![0.0; 8]);
        for _ in 0..50 {
            buf.sample_into(&mut rng, 8, &mut o, &mut a, &mut r, &mut no, &mut d);
            for &x in &r {
                seen[x as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn clear_resets() {
        let mut buf = ReplayBuffer::new(8, 2, 1);
        push_n(&mut buf, 5);
        buf.clear();
        assert!(buf.is_empty());
        push_n(&mut buf, 1);
        assert_eq!(buf.len(), 1);
    }

    /// push_batch must be byte-identical to the same rows pushed one by
    /// one — including head position, live length, and wraparound order.
    #[test]
    fn push_batch_equals_repeated_push() {
        let mut rng = Rng::new(9);
        for case in 0..200 {
            let cap = 1 + rng.below(12);
            let (od, ad) = (1 + rng.below(3), 1 + rng.below(2));
            let mut a = ReplayBuffer::new(cap, od, ad);
            let mut b = ReplayBuffer::new(cap, od, ad);
            for _ in 0..6 {
                // batch sizes deliberately straddle the capacity (n > cap
                // wraps more than once)
                let n = 1 + rng.below(2 * cap);
                let mut obs = vec![0.0f32; n * od];
                let mut act = vec![0.0f32; n * ad];
                let mut rew = vec![0.0f32; n];
                let mut nobs = vec![0.0f32; n * od];
                let mut done = vec![0.0f32; n];
                rng.fill_normal(&mut obs, 1.0);
                rng.fill_normal(&mut act, 1.0);
                rng.fill_normal(&mut rew, 1.0);
                rng.fill_normal(&mut nobs, 1.0);
                for d in done.iter_mut() {
                    *d = (rng.below(2) == 0) as u8 as f32;
                }
                a.push_batch(n, &obs, &act, &rew, &nobs, &done);
                for r in 0..n {
                    b.push(
                        &obs[r * od..(r + 1) * od],
                        &act[r * ad..(r + 1) * ad],
                        rew[r],
                        &nobs[r * od..(r + 1) * od],
                        done[r] > 0.5,
                    );
                }
                assert_eq!(a.len, b.len, "case {case}");
                assert_eq!(a.head, b.head, "case {case}");
                assert_eq!(a.total_inserted, b.total_inserted, "case {case}");
                assert_eq!(a.obs, b.obs, "case {case}");
                assert_eq!(a.act, b.act, "case {case}");
                assert_eq!(a.rew, b.rew, "case {case}");
                assert_eq!(a.next_obs, b.next_obs, "case {case}");
                assert_eq!(a.done, b.done, "case {case}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "empty replay")]
    fn sampling_empty_panics() {
        let buf = ReplayBuffer::new(4, 1, 1);
        let mut rng = Rng::new(0);
        let (mut o, mut a, mut r, mut no, mut d) =
            (vec![0.0; 1], vec![0.0; 1], vec![0.0; 1], vec![0.0; 1], vec![0.0; 1]);
        buf.sample_into(&mut rng, 1, &mut o, &mut a, &mut r, &mut no, &mut d);
    }
}
