//! Update-step / environment-step ratio control (paper Appendix A).
//!
//! The replay machinery "block[s] sampling calls (if needed) to guarantee
//! that the update steps per environment step ratio remains close to the
//! target" and conversely blocks actors when the learner lags. This gate
//! encodes that bookkeeping; the blocking itself lives in the pipeline
//! (which owns the condvars).

#[derive(Clone, Debug)]
pub struct RatioGate {
    /// Target update steps per environment step (1.0 in SOTA setups).
    pub target: f64,
    /// Tolerance band before blocking either side.
    pub slack: f64,
    /// Environment interactions that do not count toward the ratio
    /// (initial random-exploration fill).
    pub warmup_env_steps: u64,
    env_steps: u64,
    update_steps: u64,
}

impl RatioGate {
    pub fn new(target: f64, slack: f64, warmup_env_steps: u64) -> Self {
        assert!(target > 0.0);
        assert!(slack >= 0.0);
        RatioGate { target, slack, warmup_env_steps, env_steps: 0, update_steps: 0 }
    }

    pub fn on_env_steps(&mut self, n: u64) {
        self.env_steps += n;
    }

    pub fn on_update_steps(&mut self, n: u64) {
        self.update_steps += n;
    }

    pub fn env_steps(&self) -> u64 {
        self.env_steps
    }

    pub fn update_steps(&self) -> u64 {
        self.update_steps
    }

    fn counted_env_steps(&self) -> u64 {
        self.env_steps.saturating_sub(self.warmup_env_steps)
    }

    /// May the learner take `n` more update steps without running ahead of
    /// the target ratio?
    pub fn may_update(&self, n: u64) -> bool {
        let env = self.counted_env_steps();
        if env == 0 {
            return false;
        }
        (self.update_steps + n) as f64 <= self.target * env as f64 + self.slack
    }

    /// May actors take more environment steps without leaving the learner
    /// hopelessly behind? (Bounded lead keeps data near on-policy-ish.)
    pub fn may_step_env(&self, n: u64) -> bool {
        let env = self.counted_env_steps() + n;
        // actors may lead by `slack` updates' worth of steps
        self.update_steps as f64 + self.slack >= self.target * env as f64 - self.slack.max(1.0)
            || self.env_steps < self.warmup_env_steps
            || (env as f64) * self.target <= self.update_steps as f64 + self.slack
    }

    pub fn ratio(&self) -> f64 {
        let env = self.counted_env_steps();
        if env == 0 {
            0.0
        } else {
            self.update_steps as f64 / env as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_blocks_updates() {
        let mut g = RatioGate::new(1.0, 0.0, 100);
        g.on_env_steps(50);
        assert!(!g.may_update(1));
        g.on_env_steps(60);
        assert!(g.may_update(10));
        assert!(!g.may_update(11));
    }

    #[test]
    fn ratio_tracks_target() {
        let mut g = RatioGate::new(1.0, 0.0, 0);
        g.on_env_steps(1000);
        g.on_update_steps(1000);
        assert!((g.ratio() - 1.0).abs() < 1e-12);
        assert!(!g.may_update(1));
        g.on_env_steps(50);
        assert!(g.may_update(50));
    }

    #[test]
    fn fractional_target() {
        let mut g = RatioGate::new(0.25, 0.0, 0);
        g.on_env_steps(100);
        assert!(g.may_update(25));
        assert!(!g.may_update(26));
    }

    #[test]
    fn slack_allows_batching() {
        let mut g = RatioGate::new(1.0, 50.0, 0);
        g.on_env_steps(100);
        g.on_update_steps(100);
        // 50 more updates fit inside the slack band
        assert!(g.may_update(50));
        assert!(!g.may_update(51));
    }
}
