//! Update-step / environment-step ratio control (paper Appendix A).
//!
//! The replay machinery "block[s] sampling calls (if needed) to guarantee
//! that the update steps per environment step ratio remains close to the
//! target" and conversely blocks actors when the learner lags. This gate
//! encodes that bookkeeping; the blocking itself lives in the pipeline
//! (which owns the condvars).

#[derive(Clone, Debug)]
pub struct RatioGate {
    /// Target update steps per environment step (1.0 in SOTA setups).
    pub target: f64,
    /// Tolerance band before blocking either side.
    pub slack: f64,
    /// Environment interactions that do not count toward the ratio
    /// (initial random-exploration fill).
    pub warmup_env_steps: u64,
    env_steps: u64,
    update_steps: u64,
}

impl RatioGate {
    pub fn new(target: f64, slack: f64, warmup_env_steps: u64) -> Self {
        assert!(target > 0.0);
        assert!(slack >= 0.0);
        RatioGate { target, slack, warmup_env_steps, env_steps: 0, update_steps: 0 }
    }

    pub fn on_env_steps(&mut self, n: u64) {
        self.env_steps += n;
    }

    pub fn on_update_steps(&mut self, n: u64) {
        self.update_steps += n;
    }

    pub fn env_steps(&self) -> u64 {
        self.env_steps
    }

    pub fn update_steps(&self) -> u64 {
        self.update_steps
    }

    fn counted_env_steps(&self) -> u64 {
        self.env_steps.saturating_sub(self.warmup_env_steps)
    }

    /// May the learner take `n` more update steps without running ahead of
    /// the target ratio?
    pub fn may_update(&self, n: u64) -> bool {
        let env = self.counted_env_steps();
        if env == 0 {
            return false;
        }
        (self.update_steps + n) as f64 <= self.target * env as f64 + self.slack
    }

    /// May actors take `n` more environment steps without leaving the
    /// learner hopelessly behind? (Bounded lead keeps data near
    /// on-policy-ish.)
    ///
    /// Exactly symmetric with [`RatioGate::may_update`], modulo warmup:
    /// one tolerance band of `slack` update steps around the target line
    /// `update_steps = target * counted_env_steps`, evaluated after the
    /// `n` steps would land. The band is floored at `1 + target` update
    /// steps — the minimum both sides together must be able to owe for
    /// the pair to make progress at `slack = 0` (one update spends one
    /// unit of learner credit, one env step costs `target` units; with a
    /// smaller band fractional targets such as 1.5 deadlock, e.g. at
    /// env=1/updates=1 neither side may move inside a band of 1.5).
    ///
    /// An earlier version OR-ed three overlapping conditions and added
    /// `slack.max(1.0)` on top of `slack`, so the permitted actor lead
    /// was double-banded (~`2 * slack / target` uncounted steps at
    /// fractional targets) and asymmetric with the learner side.
    pub fn may_step_env(&self, n: u64) -> bool {
        if self.env_steps + n <= self.warmup_env_steps {
            return true;
        }
        let env = (self.env_steps + n).saturating_sub(self.warmup_env_steps);
        self.target * env as f64 <= self.update_steps as f64 + self.slack.max(1.0 + self.target)
    }

    pub fn ratio(&self) -> f64 {
        let env = self.counted_env_steps();
        if env == 0 {
            0.0
        } else {
            self.update_steps as f64 / env as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_blocks_updates() {
        let mut g = RatioGate::new(1.0, 0.0, 100);
        g.on_env_steps(50);
        assert!(!g.may_update(1));
        g.on_env_steps(60);
        assert!(g.may_update(10));
        assert!(!g.may_update(11));
    }

    #[test]
    fn ratio_tracks_target() {
        let mut g = RatioGate::new(1.0, 0.0, 0);
        g.on_env_steps(1000);
        g.on_update_steps(1000);
        assert!((g.ratio() - 1.0).abs() < 1e-12);
        assert!(!g.may_update(1));
        g.on_env_steps(50);
        assert!(g.may_update(50));
    }

    #[test]
    fn fractional_target() {
        let mut g = RatioGate::new(0.25, 0.0, 0);
        g.on_env_steps(100);
        assert!(g.may_update(25));
        assert!(!g.may_update(26));
    }

    #[test]
    fn slack_allows_batching() {
        let mut g = RatioGate::new(1.0, 50.0, 0);
        g.on_env_steps(100);
        g.on_update_steps(100);
        // 50 more updates fit inside the slack band
        assert!(g.may_update(50));
        assert!(!g.may_update(51));
    }

    #[test]
    fn warmup_steps_are_always_allowed() {
        let g = RatioGate::new(1.0, 0.0, 100);
        assert!(g.may_step_env(100));
        // past warmup the band takes over: floor is 1 + target = 2
        assert!(g.may_step_env(102));
        assert!(!g.may_step_env(103));
    }

    #[test]
    fn env_band_is_symmetric_with_update_band() {
        // One band of `slack` update steps on either side of the target
        // line: actors may lead by slack/target env steps, the learner by
        // slack updates.
        let mut g = RatioGate::new(1.0, 64.0, 0);
        assert!(g.may_step_env(64));
        assert!(!g.may_step_env(65));
        g.on_env_steps(64);
        assert!(g.may_update(128)); // 64 owed + 64 slack
        assert!(!g.may_update(129));
    }

    #[test]
    fn fractional_target_lead_is_single_banded() {
        // target 0.25, slack 8: the permitted uncounted lead is
        // slack/target = 32 env steps — the old triple-condition form
        // allowed (slack + slack)/target = 64.
        let g = RatioGate::new(0.25, 8.0, 0);
        assert!(g.may_step_env(32));
        assert!(!g.may_step_env(33));
    }

    #[test]
    fn zero_slack_floor_keeps_both_sides_live() {
        // At slack = 0 the band floor (1 + target) still lets the first
        // env step through so the pair can bootstrap.
        let g = RatioGate::new(4.0, 0.0, 0);
        assert!(g.may_step_env(1));
        assert!(!g.may_step_env(2));
    }

    #[test]
    fn joint_gate_never_deadlocks() {
        // Greedy interleave: at every state at least one side may act.
        // Includes fractional targets > 1, which deadlock if the band
        // floor is anything below 1 + target.
        for &target in &[0.25, 0.5, 1.0, 1.5, 2.9, 4.0] {
            for &slack in &[0.0, 2.0, 8.0] {
                let mut g = RatioGate::new(target, slack, 10);
                for i in 0..5000 {
                    if g.may_update(1) {
                        g.on_update_steps(1);
                    } else if g.may_step_env(1) {
                        g.on_env_steps(1);
                    } else {
                        panic!(
                            "deadlock at target={target} slack={slack} iter={i}: \
                             env={} updates={}",
                            g.env_steps(),
                            g.update_steps()
                        );
                    }
                }
                // |updates - target*env| stays inside the band on either
                // side, so the realized ratio converges like band/env.
                let counted = (g.env_steps() - g.warmup_env_steps) as f64;
                let band = slack.max(1.0 + target);
                let tol = (band + 1.0) / counted + 1e-9;
                let err = (g.ratio() - target).abs();
                assert!(
                    err <= tol,
                    "ratio {} drifted from target {target} (slack {slack}, tol {tol})",
                    g.ratio()
                );
            }
        }
    }
}
