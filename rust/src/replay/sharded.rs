//! Striped shared replay: N ingest stripes behind per-stripe locks,
//! sampled jointly (paper Appendix A at large populations).
//!
//! With one shared [`ReplayBuffer`](crate::replay::ReplayBuffer), every
//! actor block funnels through the learner's drain loop and one insert
//! path — at large populations ingestion serializes behind the learner.
//! [`ShardedReplay`] stripes any [`Replay`] implementation N ways
//! (default: one stripe per actor thread): each actor pushes its
//! transport-block runs straight into its own stripe through a
//! [`StripeSink`] under a lightweight per-stripe mutex, so insertion
//! contention is per-thread, not global, and blocks never round-trip
//! through the learner.
//!
//! Sampling stays distribution-identical to the single buffer: the
//! learner draws each transition index uniformly over the *total* live
//! rows and maps it to (stripe, local row) — a length-weighted joint
//! sample. With one stripe the RNG call sequence and the staged bytes
//! are exactly those of the wrapped buffer, which is what the parity
//! tests below pin down.
//!
//! Lock ordering: actors only ever lock their own single stripe; the
//! learner locks stripes in ascending index order (`sample_slot`,
//! `clear`, the aggregate accessors), so lock acquisition is cycle-free.
//! Poisoned stripe locks (an actor thread panicking mid-push) are
//! recovered, not propagated: ring `len`/`head` are updated only after
//! the row copies, so the stored prefix is always consistent and the
//! supervisor can respawn the actor onto the same stripe.

use std::sync::{Arc, Mutex, MutexGuard, TryLockError};

use crate::data::pipeline::{RowSink, TransportBlock};
use crate::replay::{Replay, Staging};
use crate::telemetry;
use crate::util::rng::Rng;

/// Poison-tolerant lock: a panicked actor cannot leave a stripe
/// half-written (length advances after the copies), so the data behind a
/// poisoned mutex is still valid.
fn lock<R>(stripe: &Mutex<R>) -> MutexGuard<'_, R> {
    stripe.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// A shared replay buffer striped N ways. Implements [`Replay`] over the
/// same block type as the wrapped buffer, so the trainer, warmup
/// accounting and both domains use it unchanged (`len`/`capacity`/
/// `total_inserted` aggregate across stripes, `clear` clears all stripes
/// coherently, `sample_slot` samples jointly weighted by live length).
pub struct ShardedReplay<R: Replay> {
    stripes: Vec<Arc<Mutex<R>>>,
}

impl<R: Replay> ShardedReplay<R> {
    /// Wrap `stripes` (at least one) as one striped buffer.
    pub fn new(stripes: Vec<R>) -> ShardedReplay<R> {
        assert!(!stripes.is_empty(), "ShardedReplay needs at least one stripe");
        ShardedReplay { stripes: stripes.into_iter().map(|s| Arc::new(Mutex::new(s))).collect() }
    }

    pub fn num_stripes(&self) -> usize {
        self.stripes.len()
    }

    /// The ingest sink for actor thread `thread` (stripe
    /// `thread % num_stripes`). Clones share the stripe, so a respawned
    /// incarnation of the thread re-binds to the same stripe.
    pub fn sink_for_thread(&self, thread: usize) -> StripeSink<R> {
        let s = thread % self.stripes.len();
        StripeSink {
            stripe: Arc::clone(&self.stripes[s]),
            metrics: StripeMetrics::for_stripe(s),
        }
    }
}

/// Telemetry handles for one stripe (`replay.stripe.{s}.*`), resolved
/// once at sink construction so the push path never touches the registry
/// map. Sinks onto the same stripe share the underlying cells.
#[derive(Clone)]
struct StripeMetrics {
    pushes: telemetry::Counter,
    contended: telemetry::Counter,
    fill: telemetry::Gauge,
}

impl StripeMetrics {
    fn for_stripe(s: usize) -> StripeMetrics {
        StripeMetrics {
            pushes: telemetry::counter(&format!("replay.stripe.{s}.pushes")),
            contended: telemetry::counter(&format!("replay.stripe.{s}.contended")),
            fill: telemetry::gauge(&format!("replay.stripe.{s}.fill")),
        }
    }
}

impl<R> Replay for ShardedReplay<R>
where
    R: Replay,
    R::Block: TransportBlock,
{
    type Block = R::Block;

    fn len(&self) -> usize {
        self.stripes.iter().map(|s| lock(s).len()).sum()
    }

    fn capacity(&self) -> usize {
        self.stripes.iter().map(|s| lock(s).capacity()).sum()
    }

    fn clear(&mut self) {
        // ascending index order, same as sampling — coherent on PBT
        // exploit: after clear() returns, every stripe is empty.
        for s in &self.stripes {
            lock(s).clear();
        }
    }

    fn push_rows(&mut self, block: &R::Block, start: usize, end: usize) {
        // learner-side drain path (non-sink mode): route the block to its
        // producing thread's stripe, same placement the sinks would use.
        let stripe = block.thread() % self.stripes.len();
        lock(&self.stripes[stripe]).push_rows(block, start, end);
    }

    fn sample_slot(&self, rng: &mut Rng, batch: usize, staging: &mut Staging, slot: usize) {
        // Hold every stripe for the whole slot so the draw is over one
        // consistent snapshot of live lengths.
        let guards: Vec<MutexGuard<'_, R>> = self.stripes.iter().map(|s| lock(s)).collect();
        let lens: Vec<usize> = guards.iter().map(|g| g.len()).collect();
        let total: usize = lens.iter().sum();
        assert!(total > 0, "sampling from empty replay buffer");
        for pos in 0..batch {
            // One uniform draw over all live rows, then locate the
            // stripe: length-weighted joint sampling. With one stripe
            // this is bit-for-bit the wrapped buffer's own stream.
            let mut row = rng.below(total);
            let mut stripe = 0;
            while row >= lens[stripe] {
                row -= lens[stripe];
                stripe += 1;
            }
            guards[stripe].copy_row(row, batch, staging, slot, pos);
        }
    }

    fn copy_row(&self, row: usize, batch: usize, staging: &mut Staging, slot: usize, pos: usize) {
        // global coordinate: stripes concatenated in index order
        let mut row = row;
        for s in &self.stripes {
            let g = lock(s);
            if row < g.len() {
                g.copy_row(row, batch, staging, slot, pos);
                return;
            }
            row -= g.len();
        }
        panic!("copy_row past live rows");
    }

    fn total_inserted(&self) -> u64 {
        self.stripes.iter().map(|s| lock(s).total_inserted()).sum()
    }

    fn stripe_lens(&self) -> Vec<usize> {
        self.stripes.iter().map(|s| lock(s).len()).collect()
    }
}

/// An actor thread's handle on its own stripe: [`RowSink::push_rows`]
/// takes the per-stripe lock, inserts the rows, and returns — no channel
/// hop, no learner round-trip. Cloned for respawn so every incarnation
/// of a thread feeds the same stripe.
pub struct StripeSink<R: Replay> {
    stripe: Arc<Mutex<R>>,
    metrics: StripeMetrics,
}

impl<R: Replay> Clone for StripeSink<R> {
    fn clone(&self) -> Self {
        StripeSink { stripe: Arc::clone(&self.stripe), metrics: self.metrics.clone() }
    }
}

impl<R: Replay> RowSink<R::Block> for StripeSink<R> {
    fn push_rows(&self, block: &R::Block, start: usize, end: usize) {
        // Try-lock first so lock-held collisions (the learner sampling,
        // or a sibling thread sharing this stripe) are observable as the
        // `contended` counter; fall back to the blocking lock.
        let mut g = match self.stripe.try_lock() {
            Ok(g) => g,
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
            Err(TryLockError::WouldBlock) => {
                self.metrics.contended.add(1);
                lock(&self.stripe)
            }
        };
        g.push_rows(block, start, end);
        self.metrics.pushes.add(1);
        self.metrics.fill.set(g.len() as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::pipeline::{PixelTransitionBlock, TransitionBlock};
    use crate::manifest::Dtype;
    use crate::replay::{PixelReplayBuffer, ReplayBuffer};
    use crate::util::stats::chi_squared_uniform;

    fn continuous_block(thread: usize, rows: usize, od: usize, ad: usize, id0: f32)
        -> TransitionBlock {
        let agents: Vec<usize> = (0..rows).collect();
        let mut block = TransitionBlock::new(thread, &agents, od, ad);
        for r in 0..rows {
            let id = id0 + r as f32;
            for j in 0..od {
                block.obs[r * od + j] = 10.0 * id + j as f32;
                block.next_obs[r * od + j] = 1000.0 + 10.0 * id + j as f32;
            }
            for j in 0..ad {
                block.act[r * ad + j] = -id;
            }
            block.rew[r] = id;
            block.done[r] = (r % 2) as f32;
        }
        block.n = rows;
        block
    }

    fn pixel_block(thread: usize, rows: usize, fl: usize, id0: f32) -> PixelTransitionBlock {
        let agents: Vec<usize> = (0..rows).collect();
        let mut block = PixelTransitionBlock::new(thread, &agents, fl);
        for r in 0..rows {
            let id = id0 as usize + r;
            for j in 0..fl {
                block.obs[r * fl + j] = ((id >> j) & 1) as u8;
                block.next_obs[r * fl + j] = ((!id >> j) & 1) as u8;
            }
            block.act[r] = (id % 7) as i32;
            block.rew[r] = id0 + r as f32;
            block.done[r] = (id % 2) as f32;
        }
        block.n = rows;
        block
    }

    fn continuous_staging(batch: usize, od: usize, ad: usize, slots: usize) -> Staging {
        Staging::new(
            &[
                (Dtype::F32, batch * od),
                (Dtype::F32, batch * ad),
                (Dtype::F32, batch),
                (Dtype::F32, batch * od),
                (Dtype::F32, batch),
            ],
            slots,
        )
    }

    fn pixel_staging(batch: usize, fl: usize, slots: usize) -> Staging {
        Staging::new(
            &[
                (Dtype::F32, batch * fl),
                (Dtype::I32, batch),
                (Dtype::F32, batch),
                (Dtype::F32, batch * fl),
                (Dtype::F32, batch),
            ],
            slots,
        )
    }

    /// 1 stripe must be byte-identical to the wrapped buffer through
    /// `dyn Replay`: same RNG stream consumed, same staged bytes.
    #[test]
    fn one_stripe_matches_wrapped_buffer_continuous() {
        let (od, ad, cap, batch) = (3usize, 2usize, 32usize, 5usize);
        let mut sharded: Box<dyn Replay<Block = TransitionBlock>> =
            Box::new(ShardedReplay::new(vec![ReplayBuffer::new(cap, od, ad)]));
        let mut plain: Box<dyn Replay<Block = TransitionBlock>> =
            Box::new(ReplayBuffer::new(cap, od, ad));
        let mut id = 0.0;
        for (thread, rows) in [(0usize, 7usize), (3, 5), (1, 9)] {
            let block = continuous_block(thread, rows, od, ad, id);
            id += rows as f32;
            sharded.push_rows(&block, 0, rows);
            plain.push_rows(&block, 0, rows);
        }
        assert_eq!(sharded.len(), plain.len());
        assert_eq!(sharded.capacity(), plain.capacity());
        assert_eq!(sharded.total_inserted(), plain.total_inserted());

        let slots = 2;
        let mut st_s = continuous_staging(batch, od, ad, slots);
        let mut st_p = continuous_staging(batch, od, ad, slots);
        let mut rng_s = Rng::new(42);
        let mut rng_p = Rng::new(42);
        for slot in 0..slots {
            sharded.sample_slot(&mut rng_s, batch, &mut st_s, slot);
            plain.sample_slot(&mut rng_p, batch, &mut st_p, slot);
        }
        assert_eq!(st_s.f32s, st_p.f32s);
        // identical stream position afterwards too
        assert_eq!(rng_s.below(1 << 30), rng_p.below(1 << 30));

        sharded.clear();
        assert!(sharded.is_empty());
    }

    /// Pixel domain: same 1-stripe parity contract, including the i32
    /// action lane and u8 -> f32 frame expansion.
    #[test]
    fn one_stripe_matches_wrapped_buffer_pixel() {
        let (fl, cap, batch) = (6usize, 32usize, 4usize);
        let mut sharded: Box<dyn Replay<Block = PixelTransitionBlock>> =
            Box::new(ShardedReplay::new(vec![PixelReplayBuffer::new(cap, fl)]));
        let mut plain: Box<dyn Replay<Block = PixelTransitionBlock>> =
            Box::new(PixelReplayBuffer::new(cap, fl));
        let mut id = 0.0;
        for (thread, rows) in [(2usize, 6usize), (0, 8), (5, 4)] {
            let block = pixel_block(thread, rows, fl, id);
            id += rows as f32;
            sharded.push_rows(&block, 0, rows);
            plain.push_rows(&block, 0, rows);
        }
        assert_eq!(sharded.len(), plain.len());
        assert_eq!(sharded.total_inserted(), plain.total_inserted());

        let mut st_s = pixel_staging(batch, fl, 1);
        let mut st_p = pixel_staging(batch, fl, 1);
        let mut rng_s = Rng::new(7);
        let mut rng_p = Rng::new(7);
        sharded.sample_slot(&mut rng_s, batch, &mut st_s, 0);
        plain.sample_slot(&mut rng_p, batch, &mut st_p, 0);
        assert_eq!(st_s.f32s, st_p.f32s);
        assert_eq!(st_s.i32s, st_p.i32s);
    }

    /// N stripes: aggregated `len`/`capacity`/`total_inserted`, per-block
    /// thread routing, per-stripe occupancy, and coherent `clear`.
    #[test]
    fn stripes_aggregate_route_and_clear() {
        let (od, ad) = (2usize, 1usize);
        let stripes: Vec<ReplayBuffer> = (0..3).map(|_| ReplayBuffer::new(8, od, ad)).collect();
        let mut sharded = ShardedReplay::new(stripes);
        assert_eq!(sharded.num_stripes(), 3);
        // threads 0..5 route t % 3; rows per thread chosen unequal
        for (thread, rows) in [(0usize, 2usize), (1, 3), (2, 1), (3, 4), (4, 2)] {
            let block = continuous_block(thread, rows, od, ad, 0.0);
            sharded.push_rows(&block, 0, rows);
        }
        // stripe 0 <- threads 0,3 (2+4); stripe 1 <- threads 1,4 (3+2);
        // stripe 2 <- thread 2 (1)
        assert_eq!(sharded.stripe_lens(), vec![6, 5, 1]);
        assert_eq!(sharded.len(), 12);
        assert_eq!(sharded.capacity(), 24);
        assert_eq!(sharded.total_inserted(), 12);

        sharded.clear();
        assert_eq!(sharded.stripe_lens(), vec![0, 0, 0]);
        assert!(sharded.is_empty());
        assert_eq!(sharded.total_inserted(), 12, "monotonic across clear");
    }

    /// Sinks bind a thread to stripe `thread % N` and survive cloning
    /// (the respawn path re-uses a clone of the original sink).
    #[test]
    fn sink_routes_to_bound_stripe() {
        let (od, ad) = (1usize, 1usize);
        let sharded = ShardedReplay::new(vec![
            ReplayBuffer::new(8, od, ad),
            ReplayBuffer::new(8, od, ad),
        ]);
        let s0 = sharded.sink_for_thread(0);
        let s3 = sharded.sink_for_thread(3); // 3 % 2 == 1
        let respawned = s3.clone();
        s0.push_rows(&continuous_block(0, 2, od, ad, 0.0), 0, 2);
        s3.push_rows(&continuous_block(3, 1, od, ad, 2.0), 0, 1);
        respawned.push_rows(&continuous_block(3, 3, od, ad, 3.0), 0, 3);
        assert_eq!(sharded.stripe_lens(), vec![2, 4]);
    }

    fn assert_uniform(counts: &[u64]) {
        let df = (counts.len() - 1) as f64;
        let chi2 = chi_squared_uniform(counts);
        // mean df, variance 2*df: five sigma keeps the fixed-seed test
        // deterministic-safe while catching any stripe weighting bias
        let limit = df + 5.0 * (2.0 * df).sqrt();
        assert!(chi2 < limit, "chi2 {chi2} over limit {limit} (counts {counts:?})");
    }

    /// Joint sampling across unequal stripes is uniform over the live
    /// rows — the length weighting exactly cancels stripe imbalance.
    #[test]
    fn joint_sampling_is_uniform_continuous() {
        let (od, ad, batch) = (1usize, 1usize, 32usize);
        let stripes: Vec<ReplayBuffer> = (0..4).map(|_| ReplayBuffer::new(16, od, ad)).collect();
        let mut sharded = ShardedReplay::new(stripes);
        // unequal live lengths 5/9/3/13 = 30 rows, rew = global row id
        let mut id = 0.0;
        for (thread, rows) in [(0usize, 5usize), (1, 9), (2, 3), (3, 13)] {
            let block = continuous_block(thread, rows, od, ad, id);
            id += rows as f32;
            sharded.push_rows(&block, 0, rows);
        }
        let total = 30usize;
        assert_eq!(sharded.len(), total);
        let mut counts = vec![0u64; total];
        let mut st = continuous_staging(batch, od, ad, 1);
        let mut rng = Rng::new(1234);
        for _ in 0..2000 {
            sharded.sample_slot(&mut rng, batch, &mut st, 0);
            for &r in st.slot_f32(2, 0).iter() {
                counts[r as usize] += 1;
            }
        }
        assert_uniform(&counts);
    }

    /// Same uniformity contract on the pixel buffer.
    #[test]
    fn joint_sampling_is_uniform_pixel() {
        let (fl, batch) = (3usize, 32usize);
        let stripes: Vec<PixelReplayBuffer> =
            (0..3).map(|_| PixelReplayBuffer::new(16, fl)).collect();
        let mut sharded = ShardedReplay::new(stripes);
        let mut id = 0.0;
        for (thread, rows) in [(0usize, 4usize), (1, 11), (2, 7)] {
            let block = pixel_block(thread, rows, fl, id);
            id += rows as f32;
            sharded.push_rows(&block, 0, rows);
        }
        let total = 22usize;
        assert_eq!(sharded.len(), total);
        let mut counts = vec![0u64; total];
        let mut st = pixel_staging(batch, fl, 1);
        let mut rng = Rng::new(99);
        for _ in 0..2000 {
            sharded.sample_slot(&mut rng, batch, &mut st, 0);
            for &r in st.slot_f32(2, 0).iter() {
                counts[r as usize] += 1;
            }
        }
        assert_uniform(&counts);
    }
}
