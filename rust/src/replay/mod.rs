//! Replay storage + update/insert ratio control (paper Appendix A).

pub mod buffer;
pub mod pixel;
pub mod ratio;

pub use buffer::ReplayBuffer;
pub use pixel::PixelReplayBuffer;
pub use ratio::RatioGate;

use crate::util::rng::Rng;

/// Batch staging area for a whole population: flat `[P, B, ...]` host
/// buffers matching the artifact's batch inputs, filled per-agent by
/// `ReplayBuffer::sample_into`.
pub struct BatchStage {
    pub pop: usize,
    pub batch: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
    pub obs: Vec<f32>,
    pub act: Vec<f32>,
    pub rew: Vec<f32>,
    pub next_obs: Vec<f32>,
    pub done: Vec<f32>,
}

impl BatchStage {
    pub fn new(pop: usize, batch: usize, obs_dim: usize, act_dim: usize) -> Self {
        BatchStage {
            pop,
            batch,
            obs_dim,
            act_dim,
            obs: vec![0.0; pop * batch * obs_dim],
            act: vec![0.0; pop * batch * act_dim],
            rew: vec![0.0; pop * batch],
            next_obs: vec![0.0; pop * batch * obs_dim],
            done: vec![0.0; pop * batch],
        }
    }

    /// Fill agent `i`'s slice of every array from its replay buffer.
    pub fn fill_agent(&mut self, i: usize, buf: &ReplayBuffer, rng: &mut Rng) {
        assert!(i < self.pop);
        let (b, od, ad) = (self.batch, self.obs_dim, self.act_dim);
        buf.sample_into(
            rng,
            b,
            &mut self.obs[i * b * od..(i + 1) * b * od],
            &mut self.act[i * b * ad..(i + 1) * b * ad],
            &mut self.rew[i * b..(i + 1) * b],
            &mut self.next_obs[i * b * od..(i + 1) * b * od],
            &mut self.done[i * b..(i + 1) * b],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_agent_targets_correct_slice() {
        let mut stage = BatchStage::new(3, 4, 2, 1);
        let mut buf = ReplayBuffer::new(8, 2, 1);
        for k in 0..8 {
            let v = 100.0 + k as f32;
            buf.push(&[v, v], &[v], v, &[v, v], false);
        }
        let mut rng = Rng::new(0);
        stage.fill_agent(1, &buf, &mut rng);
        // agent 0 and 2 slices untouched (still zero)
        assert!(stage.rew[0..4].iter().all(|&v| v == 0.0));
        assert!(stage.rew[8..12].iter().all(|&v| v == 0.0));
        assert!(stage.rew[4..8].iter().all(|&v| v >= 100.0));
        assert!(stage.obs[1 * 4 * 2..2 * 4 * 2].iter().all(|&v| v >= 100.0));
    }
}
