//! Replay storage + update/insert ratio control (paper Appendix A).
//!
//! Both training domains store transitions behind one interface: the
//! [`Replay`] trait abstracts over [`ReplayBuffer`] (continuous control,
//! f32 obs/act rows) and [`PixelReplayBuffer`] (DQN, u8 frame planes +
//! i32 actions) so the generic learner loop
//! ([`Trainer`](crate::coordinator::trainer::Trainer)) inserts transport
//! blocks and assembles `[k?, P, B, ...]` update batches without knowing
//! which domain it is driving. [`Staging`] is the host-side batch
//! assembly area the trait fills slot by slot. [`ShardedReplay`] stripes
//! any `Replay` N ways behind per-stripe locks so actor threads can
//! ingest concurrently while the learner samples jointly across stripes.
//!
//! # The cross-domain transition contract
//!
//! Every artifact's batch inputs follow one canonical transition order —
//! `obs, act, rew, next_obs, done` (the layout emitted by the python
//! side's `transition_batch_args`) — and both buffers stage fields in
//! exactly that input order. `done` is encoded as `0.0` (episode
//! continues) or `1.0` (terminal transition) in f32, in transport blocks,
//! in storage, and in staged batches alike; the update steps consume it
//! directly as the bootstrap mask `1 - done`. Any new domain or buffer
//! must preserve both conventions or the shared learner loop will stage
//! fields under the wrong inputs.

pub mod buffer;
pub mod pixel;
pub mod ratio;
pub mod sharded;

pub use buffer::ReplayBuffer;
pub use pixel::PixelReplayBuffer;
pub use ratio::RatioGate;
pub use sharded::{ShardedReplay, StripeSink};

use crate::manifest::{Artifact, Dtype};
use crate::util::rng::Rng;

/// Host staging for one vectorized update execution: one flat buffer per
/// batch input of the artifact (f32 or i32 following the input's dtype),
/// each shaped `[k?, P, B, ...]` and filled slot by slot through
/// [`Replay::sample_slot`] — slot `step * pop + agent` is one agent's
/// batch for one chained update step. The canonical transition input
/// order is `obs, act, rew, next_obs, done` (the layout emitted by the
/// python side's `transition_batch_args`).
pub struct Staging {
    /// One buffer per input; empty when that input is not f32.
    pub f32s: Vec<Vec<f32>>,
    /// One buffer per input; empty when that input is not i32.
    pub i32s: Vec<Vec<i32>>,
    strides: Vec<usize>,
}

impl Staging {
    /// Build from an explicit per-input layout of `(dtype, slot_stride)`
    /// pairs, with `slots` (= num_steps * pop) slots per input.
    pub fn new(layout: &[(Dtype, usize)], slots: usize) -> Staging {
        let mut f32s = Vec::with_capacity(layout.len());
        let mut i32s = Vec::with_capacity(layout.len());
        let mut strides = Vec::with_capacity(layout.len());
        for (dt, stride) in layout {
            f32s.push(if *dt == Dtype::F32 { vec![0.0; stride * slots] } else { Vec::new() });
            i32s.push(if *dt == Dtype::I32 { vec![0; stride * slots] } else { Vec::new() });
            strides.push(*stride);
        }
        Staging { f32s, i32s, strides }
    }

    /// Build for an artifact's batch inputs (`inputs[1..]` — the leading
    /// input is the train state itself and is never staged).
    ///
    /// Every batch input's element count must divide evenly into
    /// `num_steps * pop` slots; a remainder means the artifact's batch
    /// layout disagrees with its own pop/num_steps metadata, and slicing
    /// it anyway would silently corrupt every staged batch.
    pub fn for_artifact(artifact: &Artifact) -> anyhow::Result<Staging> {
        let slots = (artifact.num_steps * artifact.pop).max(1);
        let mut layout: Vec<(Dtype, usize)> = Vec::new();
        for input in artifact.inputs.get(1..).unwrap_or(&[]) {
            anyhow::ensure!(
                input.numel() % slots == 0,
                "artifact '{}': batch input '{}' has {} elements (shape {:?}), \
                 not divisible by num_steps * pop = {} slots — malformed batch layout",
                artifact.name,
                input.name,
                input.numel(),
                input.shape,
                slots
            );
            layout.push((input.dtype.clone(), input.numel() / slots));
        }
        Ok(Staging::new(&layout, slots))
    }

    /// Number of staged inputs.
    pub fn num_inputs(&self) -> usize {
        self.strides.len()
    }

    /// Per-slot element stride of input `input`.
    pub fn stride(&self, input: usize) -> usize {
        self.strides[input]
    }

    /// Slot `slot` of f32 input `input`.
    pub fn slot_f32(&mut self, input: usize, slot: usize) -> &mut [f32] {
        let s = self.strides[input];
        &mut self.f32s[input][slot * s..(slot + 1) * s]
    }

    /// Slot `slot` of i32 input `input`.
    pub fn slot_i32(&mut self, input: usize, slot: usize) -> &mut [i32] {
        let s = self.strides[input];
        &mut self.i32s[input][slot * s..(slot + 1) * s]
    }
}

/// The unified replay interface both training domains implement — the
/// learner loop's whole view of storage. `Block` ties a buffer to the
/// transport block type whose rows it ingests
/// ([`TransitionBlock`](crate::data::pipeline::TransitionBlock) for
/// [`ReplayBuffer`],
/// [`PixelTransitionBlock`](crate::data::pipeline::PixelTransitionBlock)
/// for [`PixelReplayBuffer`]). Implementations must preserve row order on
/// insert (a `push_rows` equals that many repeated single pushes) and
/// draw the same uniform sample stream for the same RNG state, so the
/// two buffers behave identically through `dyn Replay`.
pub trait Replay: Send {
    /// Transport block type whose rows this buffer stores.
    type Block;

    /// Live transitions.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Ring capacity.
    fn capacity(&self) -> usize;

    /// Drop all contents (PBT exploit replaces an agent's data lineage —
    /// its hyperparameters changed, so the old data's distribution did
    /// too).
    fn clear(&mut self);

    /// Insert rows `start..end` of a transport block as one contiguous
    /// batch (one copy per field per ring run).
    fn push_rows(&mut self, block: &Self::Block, start: usize, end: usize);

    /// Sample `batch` transitions uniformly with replacement into slot
    /// `slot` of the staging buffers.
    fn sample_slot(&self, rng: &mut Rng, batch: usize, staging: &mut Staging, slot: usize);

    /// Copy one stored transition (`row`, in insertion-ring coordinates,
    /// `< len()`) into position `pos` of slot `slot` of the staging
    /// buffers, exactly as `sample_slot` would place draw number `pos` of
    /// a `batch`-sized sample. This is the primitive [`ShardedReplay`]
    /// composes to sample jointly across stripes while staying
    /// byte-identical to the wrapped buffer's own sample stream.
    fn copy_row(&self, row: usize, batch: usize, staging: &mut Staging, slot: usize, pos: usize);

    /// Total transitions ever inserted (monotonic; not reset by `clear`).
    /// The trainer's warmup accounting reads this.
    fn total_inserted(&self) -> u64;

    /// Live length of each ingest stripe. Single buffers are one stripe;
    /// [`ShardedReplay`] reports per-stripe occupancy so the trainer can
    /// surface fill imbalance without downcasting through `dyn Replay`.
    fn stripe_lens(&self) -> Vec<usize> {
        vec![self.len()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::pipeline::{PixelTransitionBlock, TransitionBlock};

    #[test]
    fn for_artifact_rejects_indivisible_inputs() {
        use crate::manifest::{BatchInput, EnvDesc};
        use std::path::PathBuf;
        let inputs = |obs_numel: usize| {
            vec![
                BatchInput { name: "state".into(), shape: vec![10], dtype: Dtype::F32 },
                BatchInput { name: "obs".into(), shape: vec![obs_numel], dtype: Dtype::F32 },
            ]
        };
        let art = |obs_numel: usize| {
            crate::manifest::Artifact::new(
                "synthetic".into(),
                PathBuf::new(),
                "td3".into(),
                "pendulum".into(),
                EnvDesc::default(),
                2, // pop
                3, // num_steps -> 6 slots
                4,
                vec![],
                10,
                "state".into(),
                vec![],
                vec![],
                inputs(obs_numel),
            )
        };
        // divisible: 6 slots x stride 2
        let st = Staging::for_artifact(&art(12)).expect("divisible layout must build");
        assert_eq!(st.num_inputs(), 1);
        assert_eq!(st.stride(0), 2);
        // indivisible: 13 elements over 6 slots would truncate
        let err = Staging::for_artifact(&art(13)).expect_err("must reject truncating layout");
        let msg = format!("{err}");
        assert!(msg.contains("obs") && msg.contains("13") && msg.contains("6"), "got: {msg}");
    }

    #[test]
    fn staging_layout_and_slots() {
        // obs [B,2] f32, act [B] i32, rew [B] f32 — two slots
        let layout = [(Dtype::F32, 8), (Dtype::I32, 4), (Dtype::F32, 4)];
        let mut st = Staging::new(&layout, 2);
        assert_eq!(st.num_inputs(), 3);
        assert_eq!(st.f32s[0].len(), 16);
        assert!(st.f32s[1].is_empty());
        assert_eq!(st.i32s[1].len(), 8);
        st.slot_f32(0, 1).fill(7.0);
        assert!(st.f32s[0][..8].iter().all(|&v| v == 0.0), "slot 0 untouched");
        assert!(st.f32s[0][8..].iter().all(|&v| v == 7.0));
        st.slot_i32(1, 0).fill(3);
        assert_eq!(&st.i32s[1], &[3, 3, 3, 3, 0, 0, 0, 0]);
    }

    /// Continuous domain: inserts and samples through `dyn Replay` must
    /// match the inherent `push_batch`/`sample_into` byte for byte
    /// (ordering parity — the satellite contract of the unified trait).
    #[test]
    fn replay_trait_matches_inherent_continuous() {
        let (od, ad, cap, rows, batch) = (2usize, 1usize, 8usize, 4usize, 3usize);
        let agents = [0usize, 0, 1, 1];
        let mut block = TransitionBlock::new(0, &agents, od, ad);
        for r in 0..rows {
            for j in 0..od {
                block.obs[r * od + j] = (10 * r + j) as f32;
                block.next_obs[r * od + j] = (100 + 10 * r + j) as f32;
            }
            block.act[r] = r as f32;
            block.rew[r] = r as f32;
            block.done[r] = (r % 2) as f32;
        }
        block.n = rows;

        let mut via_trait = ReplayBuffer::new(cap, od, ad);
        {
            let dynbuf: &mut dyn Replay<Block = TransitionBlock> = &mut via_trait;
            dynbuf.push_rows(&block, 0, rows);
            assert_eq!(dynbuf.len(), rows);
            assert_eq!(dynbuf.capacity(), cap);
        }
        let mut direct = ReplayBuffer::new(cap, od, ad);
        direct.push_batch(rows, &block.obs, &block.act, &block.rew, &block.next_obs,
                          &block.done);

        // same rng stream -> same sampled rows, landing in the right slot
        let layout = [
            (Dtype::F32, batch * od),
            (Dtype::F32, batch * ad),
            (Dtype::F32, batch),
            (Dtype::F32, batch * od),
            (Dtype::F32, batch),
        ];
        let mut st = Staging::new(&layout, 2);
        let mut rng_t = Rng::new(7);
        (&via_trait as &dyn Replay<Block = TransitionBlock>)
            .sample_slot(&mut rng_t, batch, &mut st, 1);
        let mut rng_d = Rng::new(7);
        let (mut o, mut a, mut r, mut no, mut d) = (
            vec![0.0f32; batch * od],
            vec![0.0f32; batch * ad],
            vec![0.0f32; batch],
            vec![0.0f32; batch * od],
            vec![0.0f32; batch],
        );
        direct.sample_into(&mut rng_d, batch, &mut o, &mut a, &mut r, &mut no, &mut d);
        assert_eq!(st.slot_f32(0, 1), &o[..]);
        assert_eq!(st.slot_f32(1, 1), &a[..]);
        assert_eq!(st.slot_f32(2, 1), &r[..]);
        assert_eq!(st.slot_f32(3, 1), &no[..]);
        assert_eq!(st.slot_f32(4, 1), &d[..]);
        // slot 0 stays zeroed
        assert!(st.slot_f32(0, 0).iter().all(|&v| v == 0.0));

        // clear through the trait empties the ring
        (&mut via_trait as &mut dyn Replay<Block = TransitionBlock>).clear();
        assert!(via_trait.is_empty());
    }

    /// Pixel domain: same parity contract — u8 frames and i32 actions
    /// route through the identical trait surface.
    #[test]
    fn replay_trait_matches_inherent_pixel() {
        let (fl, cap, rows, batch) = (4usize, 8usize, 4usize, 3usize);
        let agents = [0usize, 1, 2, 3];
        let mut block = PixelTransitionBlock::new(0, &agents, fl);
        for r in 0..rows {
            for j in 0..fl {
                block.obs[r * fl + j] = ((r >> j) & 1) as u8;
                block.next_obs[r * fl + j] = ((!r >> j) & 1) as u8;
            }
            block.act[r] = r as i32;
            block.rew[r] = r as f32;
            block.done[r] = (r % 2) as f32;
        }
        block.n = rows;

        let mut via_trait = PixelReplayBuffer::new(cap, fl);
        {
            let dynbuf: &mut dyn Replay<Block = PixelTransitionBlock> = &mut via_trait;
            dynbuf.push_rows(&block, 0, rows);
            assert_eq!(dynbuf.len(), rows);
        }
        let mut direct = PixelReplayBuffer::new(cap, fl);
        direct.push_batch(rows, &block.obs, &block.act, &block.rew, &block.next_obs,
                          &block.done);

        let layout = [
            (Dtype::F32, batch * fl),
            (Dtype::I32, batch),
            (Dtype::F32, batch),
            (Dtype::F32, batch * fl),
            (Dtype::F32, batch),
        ];
        let mut st = Staging::new(&layout, 2);
        let mut rng_t = Rng::new(11);
        (&via_trait as &dyn Replay<Block = PixelTransitionBlock>)
            .sample_slot(&mut rng_t, batch, &mut st, 0);
        let mut rng_d = Rng::new(11);
        let (mut o, mut a, mut r, mut no, mut d) = (
            vec![0.0f32; batch * fl],
            vec![0i32; batch],
            vec![0.0f32; batch],
            vec![0.0f32; batch * fl],
            vec![0.0f32; batch],
        );
        direct.sample_into(&mut rng_d, batch, &mut o, &mut a, &mut r, &mut no, &mut d);
        assert_eq!(st.slot_f32(0, 0), &o[..]);
        assert_eq!(st.slot_i32(1, 0), &a[..]);
        assert_eq!(st.slot_f32(2, 0), &r[..]);
        assert_eq!(st.slot_f32(3, 0), &no[..]);
        assert_eq!(st.slot_f32(4, 0), &d[..]);
        assert!(st.slot_f32(0, 1).iter().all(|&v| v == 0.0), "slot 1 untouched");

        (&mut via_trait as &mut dyn Replay<Block = PixelTransitionBlock>).clear();
        assert!(via_trait.is_empty());
    }

    /// Partial-run insert: push_rows(start, end) must land exactly the
    /// addressed rows, in order — the learner's per-agent run grouping
    /// depends on it.
    #[test]
    fn push_rows_respects_run_bounds() {
        let (od, ad) = (1usize, 1usize);
        let agents = [0usize, 0, 1];
        let mut block = TransitionBlock::new(0, &agents, od, ad);
        block.obs.copy_from_slice(&[10.0, 20.0, 30.0]);
        block.act.copy_from_slice(&[1.0, 2.0, 3.0]);
        block.rew.copy_from_slice(&[0.1, 0.2, 0.3]);
        block.next_obs.copy_from_slice(&[11.0, 21.0, 31.0]);
        block.done.copy_from_slice(&[0.0, 1.0, 0.0]);
        block.n = 3;
        let mut buf = ReplayBuffer::new(4, od, ad);
        let dynbuf: &mut dyn Replay<Block = TransitionBlock> = &mut buf;
        dynbuf.push_rows(&block, 1, 3); // rows 1..3 only
        assert_eq!(dynbuf.len(), 2);
        let mut rng = Rng::new(0);
        let (mut o, mut a, mut r, mut no, mut d) =
            (vec![0.0; 1], vec![0.0; 1], vec![0.0; 1], vec![0.0; 1], vec![0.0; 1]);
        for _ in 0..50 {
            buf.sample_into(&mut rng, 1, &mut o, &mut a, &mut r, &mut no, &mut d);
            assert!(o[0] == 20.0 || o[0] == 30.0, "row 0 must not be present");
            assert_eq!(no[0], o[0] + 1.0);
        }
    }
}
